"""Sweep worker: one host's vmap lane-slice of a sharded Monte-Carlo sweep.

Usage (spawned by ``streaming/launcher.py``; runnable by hand for debugs):

    python -m repro.streaming.worker <workdir>/spec.json <shard_idx>

Rebuilds its engines/schedules from the spec (seed-deterministic graph
constructions — no pickled objects cross the host boundary), loads the cov
stacks from ``problem.npz``, runs ``sdot_sweep`` over its shard's seed
slice, and publishes ``{q, error_traces, seeds, ledger}`` atomically into
its own checkpoint dir ``<workdir>/worker_<shard>/result`` via
``checkpoint/manager.save_tree`` — the CommLedger travels as a registered
pytree.  If a valid result is already published the worker exits
immediately (idempotent relaunch).

With ``spec["sweep_chunk"]`` set, the shard's sweep runs through the
unified runtime's CHUNKED driver: the sweep-RunState (case x seed lane
axes riding on every buffer) checkpoints into
``<workdir>/worker_<shard>/ckpt`` every ``sweep_chunk`` outer iterations,
so a worker killed mid-sweep resumes MID-GRID from its checkpointed state
— bitwise equal to the uninterrupted sweep — instead of recomputing the
shard from scratch. The published result records ``resumed_steps`` (how
many outer iterations the restored state already carried) for the
launcher's resume report.
"""
from __future__ import annotations

import json
import os
import shutil
import sys


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    spec_path, shard = argv[0], int(argv[1])
    workdir = os.path.dirname(os.path.abspath(spec_path))
    with open(spec_path) as f:
        spec = json.load(f)

    out_dir = os.path.join(workdir, f"worker_{shard}", "result")

    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint.manager import CheckpointManager, save_tree
    from repro.core.sweep import sdot_sweep
    from repro.streaming.launcher import (_load_result, build_engine,
                                          build_schedule, spec_fingerprint)

    # idempotent relaunch — but only for a result stamped with THIS spec's
    # fingerprint: a hand-run worker in a reused workdir must not keep a
    # shard computed under an older spec
    if _load_result(workdir, spec, shard) is not None:
        print(f"worker {shard}: result already published, nothing to do")
        return 0
    shutil.rmtree(out_dir, ignore_errors=True)

    seeds = spec["shards"][shard]
    if not seeds:
        raise ValueError(f"worker {shard} got an empty seed shard")
    problem = np.load(os.path.join(workdir, "problem.npz"))
    engines = [build_engine(c["topology"]) for c in spec["cases"]]
    schedules = [build_schedule(c.get("schedule"), spec["t_outer"],
                                spec["t_c"]) for c in spec["cases"]]
    if spec["ragged"]:
        # a 1-element list is stored once; sdot_sweep zip-broadcasts it
        covs = [jnp.asarray(problem[f"covs_{ci}"])
                for ci in range(spec["n_cov_stacks"])]
    else:
        covs = jnp.asarray(problem["covs"])
    q_true = (jnp.asarray(problem["q_true"]) if spec["has_q_true"]
              else None)

    sweep_chunk = spec.get("sweep_chunk")
    manager = None
    if sweep_chunk:
        # chunked-resumable shard: the sweep-RunState checkpoints at every
        # chunk boundary, and a restarted worker continues mid-grid
        manager = CheckpointManager(
            os.path.join(workdir, f"worker_{shard}", "ckpt"))

    sw = sdot_sweep(covs=covs, engines=engines, schedules=schedules,
                    r=spec["r"], t_outer=spec["t_outer"], t_c=spec["t_c"],
                    seeds=seeds, q_true=q_true,
                    manager=manager, chunk_size=sweep_chunk)
    # the step the runtime ACTUALLY restored (a corrupt/stale newest
    # checkpoint falls back, so this can be less than the dir's latest step)
    resumed_steps = sw.resumed_step

    # the stamped fingerprint lets the launcher reject this result if the
    # workdir is later reused with a different spec
    tree = {"q": sw.q, "seeds": jnp.asarray(np.asarray(seeds)),
            "ledger": sw.ledger,
            "resumed_steps": jnp.asarray(resumed_steps, jnp.int32),
            "spec_fp": jnp.asarray(spec_fingerprint(spec), jnp.int32)}
    if spec["has_q_true"]:
        tree["error_traces"] = jnp.asarray(sw.error_traces)
    if spec["ragged"]:
        tree["node_counts"] = jnp.asarray(sw.node_counts)
    save_tree(out_dir, tree, step=shard)
    if manager is not None:
        # the published result supersedes the intermediate sweep state
        shutil.rmtree(manager.root, ignore_errors=True)
    print(f"worker {shard}: published {len(seeds)} seed lanes -> {out_dir}"
          + (f" (resumed from outer step {resumed_steps})"
             if resumed_steps else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
