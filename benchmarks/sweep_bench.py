"""Fused algorithm-zoo + Monte-Carlo sweep-engine benchmark.

Two measurements, both against the eager oracles at the paper's figure
scales:

* **zoo** — fused (single-scan) vs eager (per-iteration dispatch) walltime
  for F-DOT at Fig.-6 scale and for every distributed baseline at the
  Fig.-4/5 configs (DSA, DPGD, DeEPCA, SeqDistPM sample-partitioned; d-PM
  feature-partitioned). Each case also asserts fused-vs-eager subspace-error
  traces match to <= 1e-4 and the communication ledgers are identical.
* **sweep** — the vmapped Monte-Carlo engine (core/sweep.py): one compiled
  call for seeds x (topology, schedule) cases vs a Python loop over the
  already-fused per-seed runs.

Usage:
    PYTHONPATH=src python -m benchmarks.sweep_bench [--smoke]
    PYTHONPATH=src python -m benchmarks.run sweep_bench

Writes BENCH_fused_zoo.json (acceptance artifact; --smoke writes a sibling
.smoke.json so CI never clobbers the committed full-scale numbers).
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import d_pm, deepca, dpgd, dsa, seq_dist_pm
from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.fdot import fdot
from repro.core.linalg import eigh_topr
from repro.core.metrics import CommLedger
from repro.core.sdot import sdot
from repro.core.sweep import sdot_sweep
from repro.core.topology import erdos_renyi, ring
from repro.data.pipeline import gaussian_eigengap_data, partition_features

from .common import Row, sample_problem

N, D, N_PER = 10, 20, 1000        # Fig. 4/5 sample-partitioned scale
FD_D, FD_N = 10, 500              # Fig. 6 feature-partitioned scale


def _block(out):
    """Block on whichever device arrays a zoo/sweep call returned."""
    obj = out[0] if isinstance(out, tuple) else out
    if hasattr(obj, "q_nodes"):
        arr = obj.q_nodes
    elif hasattr(obj, "q_blocks"):
        arr = obj.q_blocks[0]
    else:
        arr = obj
    jax.block_until_ready(arr)
    return out


def _time(fn, repeats=1):
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = _block(fn())
        best = min(best, time.perf_counter() - t0)
    return best, out


def _zoo_case(label, fused_fn, eager_fn, trace_of, ledger_of, repeats):
    _time(fused_fn)                                   # warmup: compile
    fused_s, fres = _time(fused_fn, repeats)
    eager_s, eres = _time(eager_fn)                   # eager: 1 rep (slow)
    tf, te = np.asarray(trace_of(fres)), np.asarray(trace_of(eres))
    maxdiff = float(np.abs(tf - te).max())
    assert maxdiff <= 1e-4, f"{label}: fused/eager traces diverge ({maxdiff})"
    lf, le = ledger_of(fres), ledger_of(eres)
    ledger_equal = (lf.p2p == le.p2p and lf.matrices == le.matrices
                    and lf.scalars == le.scalars)
    assert ledger_equal, f"{label}: fused/eager ledgers differ"
    return {
        "case": label,
        "fused_ms": round(fused_s * 1e3, 2),
        "eager_ms": round(eager_s * 1e3, 2),
        "speedup": round(eager_s / fused_s, 1),
        "trace_maxdiff": maxdiff,
        "ledger_equal": ledger_equal,
        "final_err": float(tf[-1]),
    }


def run_zoo(smoke: bool):
    scale = 5 if smoke else 1
    repeats = 1 if smoke else 3
    covs, q_true = sample_problem(d=D, r=5, n_nodes=N, n_per=N_PER, gap=0.5,
                                  seed=0)
    eng = DenseConsensus(erdos_renyi(N, 0.5, seed=1))

    x, _, _ = gaussian_eigengap_data(FD_D, FD_N, 3, 0.5, seed=0)
    _, q_true_f = eigh_topr(x @ x.T, 3)
    fblocks = partition_features(x, N)

    def led(fn, *a, **kw):
        ledger = CommLedger()
        out = fn(*a, ledger=ledger, **kw)
        return out + (ledger,)

    t_o = 100 // scale
    cases = [
        ("fdot/fig6/r3", lambda f: (fdot(
            data_blocks=fblocks, engine=eng, r=3, t_outer=t_o, t_c=50,
            q_true=q_true_f, fused=f),)),
        ("dsa/fig45", lambda f: led(dsa, covs, eng, 5,
                                    t_outer=500 // scale, lr=0.05,
                                    q_true=q_true, fused=f)),
        ("dpgd/fig45", lambda f: led(dpgd, covs, eng, 5,
                                     t_outer=500 // scale, lr=0.05,
                                     q_true=q_true, fused=f)),
        ("deepca/fig45", lambda f: led(deepca, covs, eng, 5,
                                       t_outer=100 // scale, t_mix=3,
                                       q_true=q_true, fused=f)),
        ("seq_dist_pm/fig45", lambda f: led(seq_dist_pm, covs, eng, 5,
                                            iters_per_vec=20 // scale + 1,
                                            t_c=50, q_true=q_true, fused=f)),
        ("d_pm/fig6", lambda f: led(d_pm, fblocks, eng, 3,
                                    iters_per_vec=33 // scale + 1, t_c=50,
                                    q_true=q_true_f, fused=f)),
    ]

    def trace_of(out):
        first = out[0]
        return first.error_trace if hasattr(first, "error_trace") else out[1]

    def ledger_of(out):
        first = out[0]
        return first.ledger if hasattr(first, "ledger") else out[-1]

    return [_zoo_case(label, lambda make=make: make(True),
                      lambda make=make: make(False), trace_of, ledger_of,
                      repeats)
            for label, make in cases]


def run_sweep(smoke: bool):
    """Vmapped MC sweep (one device call) vs a loop of per-seed fused runs."""
    t_outer = 20 if smoke else 100
    seeds = list(range(4 if smoke else 16))
    covs, q_true = sample_problem(d=D, r=5, n_nodes=N, n_per=N_PER, gap=0.5,
                                  seed=0)
    engines = [DenseConsensus(erdos_renyi(N, 0.5, seed=1)),
               DenseConsensus(ring(N))]
    schedules = [consensus_schedule("const", t_outer, t_max=50),
                 consensus_schedule("lin2", t_outer, cap=50)]

    sweep = lambda: sdot_sweep(covs=covs, engines=engines,
                               schedules=schedules, r=5, t_outer=t_outer,
                               seeds=seeds, q_true=q_true)
    _time(lambda: _wrap_sweep(sweep))                 # warmup: compile
    one_call_s, res = _time(lambda: _wrap_sweep(sweep))

    def loop():
        traces = []
        for eng, sched in zip(engines, schedules):
            for s in seeds:
                r = sdot(covs=covs, engine=eng, r=5, t_outer=t_outer,
                         schedule=sched, seed=s, q_true=q_true)
                traces.append(r.error_trace)
        return np.stack(traces)
    loop_s_t0 = time.perf_counter()
    loop_traces = loop()
    loop_s = time.perf_counter() - loop_s_t0

    got = res.error_traces.reshape(-1, t_outer)
    maxdiff = float(np.abs(got - loop_traces).max())
    assert maxdiff <= 1e-4, f"sweep vs per-seed traces diverge ({maxdiff})"
    runs = len(seeds) * len(engines)
    return [{
        "case": f"sdot_sweep/{len(engines)}cases_x_{len(seeds)}seeds",
        "runs": runs,
        "one_call_ms": round(one_call_s * 1e3, 2),
        "per_run_loop_ms": round(loop_s * 1e3 / runs, 2),
        "loop_ms": round(loop_s * 1e3, 2),
        "speedup_vs_fused_loop": round(loop_s / one_call_s, 1),
        "trace_maxdiff": maxdiff,
    }]


def _wrap_sweep(sweep):
    res = sweep()
    jax.block_until_ready(res.q)
    return res


def run_bench(smoke: bool = False):
    return {"zoo": run_zoo(smoke), "sweep": run_sweep(smoke)}


def run():
    """benchmarks.run entry point."""
    results = run_bench(smoke=False)
    rows = []
    for rec in results["zoo"]:
        rows.append(Row(f"fused_zoo/{rec['case']}", rec["fused_ms"] * 1e3,
                        {"eager_ms": rec["eager_ms"],
                         "speedup": rec["speedup"],
                         "final_err": f"{rec['final_err']:.2e}"}))
    for rec in results["sweep"]:
        rows.append(Row(f"fused_zoo/{rec['case']}", rec["one_call_ms"] * 1e3,
                        {"loop_ms": rec["loop_ms"],
                         "speedup": rec["speedup_vs_fused_loop"]}))
    return rows


def main():
    smoke = "--smoke" in sys.argv
    results = run_bench(smoke=smoke)
    out = {
        "bench": "fused_zoo",
        "scale": {"fig45": {"n_nodes": N, "d": D, "n_per": N_PER},
                  "fig6": {"n_nodes": N, "d": FD_D, "n": FD_N}},
        "smoke": smoke,
        "backend": jax.default_backend(),
        **results,
    }
    print(json.dumps(out, indent=2))
    name = "BENCH_fused_zoo.smoke.json" if smoke else "BENCH_fused_zoo.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")
    if not smoke:
        bars = {rec["case"]: (10.0 if rec["case"].startswith("fdot") else 5.0)
                for rec in results["zoo"]}
        below = [(rec["case"], rec["speedup"]) for rec in results["zoo"]
                 if rec["speedup"] < bars[rec["case"]]]
        if below:
            print(f"# WARNING: speedups below bar: {below}")
            sys.exit(1)


if __name__ == "__main__":
    main()
