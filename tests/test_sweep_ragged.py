"""Ragged-N sweep paths for fdot_sweep / baseline_sweep (shared sweep_utils).

``sdot_sweep`` grew identity padding in PR 3 (tested in test_bdot_fused.py);
these tests pin the same contract for the feature-partitioned sweep (zero-
slab padding, no mask needed) and the cov-based baselines (identity covs +
node-masked trace): stacked mixed-node-count cases reproduce the per-case
unpadded runs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.consensus import DenseConsensus
from repro.core.fdot import fdot
from repro.core.linalg import eigh_topr
from repro.core.sweep import baseline_sweep, fdot_sweep
from repro.core.sweep_utils import (case_node_masks, pad_covs_identity,
                                    pad_weights_identity, pad_zero_nodes)
from repro.core.topology import erdos_renyi, ring
from repro.data.pipeline import (gaussian_eigengap_data, partition_features,
                                 partition_samples)

SEEDS = [0, 1]


def _cov_problem(n_nodes, d=16, r=4, n_per=200):
    x, _, _ = gaussian_eigengap_data(d, n_nodes * n_per, r, 0.7, seed=0)
    blocks = partition_samples(x, n_nodes)
    covs = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
    _, q_true = eigh_topr(covs.sum(0), r)
    return covs, q_true


@pytest.fixture(scope="module")
def cov_cases():
    covs6, q_true = _cov_problem(6)
    covs10, _ = _cov_problem(10)
    engines = [DenseConsensus(erdos_renyi(6, 0.6, seed=1)),
               DenseConsensus(ring(10))]
    return dict(covs=[covs6, covs10], engines=engines, q_true=q_true)


@pytest.fixture(scope="module")
def feature_cases():
    x, _, _ = gaussian_eigengap_data(18, 300, 4, 0.6, seed=2)
    _, q_true = eigh_topr(x @ x.T / x.shape[1], 4)
    return dict(
        blocks=[partition_features(x, 3), partition_features(x, 5)],
        engines=[DenseConsensus(erdos_renyi(3, 0.9, seed=1)),
                 DenseConsensus(ring(5))],
        q_true=q_true)


# ---------------------------------------------------------------------------
# sweep_utils
# ---------------------------------------------------------------------------
def test_pad_weights_identity_isolates():
    w = np.full((3, 3), 1.0 / 3)
    out = pad_weights_identity(w, 5)
    assert out.shape == (5, 5)
    np.testing.assert_array_equal(out[:3, 3:], 0.0)
    np.testing.assert_array_equal(out[3:, :3], 0.0)
    np.testing.assert_array_equal(out[3:, 3:], np.eye(2))
    assert np.allclose(out.sum(1), 1.0)          # still doubly stochastic


def test_pad_helpers_shapes():
    covs = jnp.ones((3, 4, 4))
    assert pad_covs_identity(covs, 5).shape == (5, 4, 4)
    np.testing.assert_array_equal(np.asarray(pad_covs_identity(covs, 5)[3:]),
                                  np.broadcast_to(np.eye(4), (2, 4, 4)))
    slabs = jnp.ones((3, 6, 7))
    padded = pad_zero_nodes(slabs, 5)
    assert padded.shape == (5, 6, 7)
    np.testing.assert_array_equal(np.asarray(padded[3:]), 0.0)
    masks = case_node_masks([3, 5], 5)
    np.testing.assert_array_equal(np.asarray(masks),
                                  [[1, 1, 1, 0, 0], [1, 1, 1, 1, 1]])


# ---------------------------------------------------------------------------
# fdot_sweep: zero-slab padding
# ---------------------------------------------------------------------------
def test_fdot_ragged_sweep_matches_unpadded_runs(feature_cases):
    fc = feature_cases
    sw = fdot_sweep(data_blocks=fc["blocks"], engines=fc["engines"], r=4,
                    t_outer=6, t_c=20, seeds=SEEDS, q_true=fc["q_true"])
    assert sw.error_traces.shape == (2, 2, 6)
    np.testing.assert_array_equal(sw.node_counts, [3, 5])
    for ci, (eng, blocks) in enumerate(zip(fc["engines"], fc["blocks"])):
        for si, s in enumerate(SEEDS):
            res = fdot(data_blocks=blocks, engine=eng, r=4, t_outer=6,
                       t_c=20, seed=s, q_true=fc["q_true"])
            np.testing.assert_allclose(sw.error_traces[ci, si],
                                       res.error_trace, rtol=1e-4,
                                       atol=1e-6)


def test_fdot_ragged_sweep_ledger(feature_cases):
    fc = feature_cases
    sw = fdot_sweep(data_blocks=fc["blocks"], engines=fc["engines"], r=4,
                    t_outer=6, t_c=20, seeds=SEEDS)
    from repro.core.metrics import CommLedger
    led = CommLedger()
    for eng, blocks in zip(fc["engines"], fc["blocks"]):
        for s in SEEDS:
            res = fdot(data_blocks=blocks, engine=eng, r=4, t_outer=6,
                       t_c=20, seed=s)
            led = led.merged(res.ledger)
    assert sw.ledger.p2p == led.p2p
    assert sw.ledger.scalars == led.scalars


def test_fdot_ragged_rejects_mismatches(feature_cases):
    fc = feature_cases
    with pytest.raises(ValueError, match="node count"):
        fdot_sweep(data_blocks=[fc["blocks"][0], fc["blocks"][0]],
                   engines=fc["engines"], r=4, t_outer=3, seeds=[0])
    short = [b[:-1] for b in fc["blocks"][1]]       # drops feature rows
    with pytest.raises(ValueError, match="same d features"):
        fdot_sweep(data_blocks=[fc["blocks"][0], short],
                   engines=fc["engines"], r=4, t_outer=3, seeds=[0])


# ---------------------------------------------------------------------------
# baseline_sweep: identity padding + node-masked trace
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["dsa", "dpgd", "deepca"])
def test_baseline_ragged_sweep_matches_unpadded_runs(cov_cases, name):
    cc = cov_cases
    sw = baseline_sweep(name, covs=cc["covs"], engines=cc["engines"], r=4,
                        t_outer=8, seeds=SEEDS, q_true=cc["q_true"])
    assert sw.error_traces.shape == (2, 2, 8)
    np.testing.assert_array_equal(sw.node_counts, [6, 10])
    fn = {"dsa": B.dsa, "dpgd": B.dpgd, "deepca": B.deepca}[name]
    for ci, (eng, cv) in enumerate(zip(cc["engines"], cc["covs"])):
        for si, s in enumerate(SEEDS):
            _, errs = fn(cv, eng, 4, 8, q_true=cc["q_true"], seed=s)
            np.testing.assert_allclose(sw.error_traces[ci, si], errs,
                                       rtol=1e-4, atol=1e-6)
        n_c = eng.graph.n_nodes
        # padded nodes stay isolated: real-node estimates match too
        _, _ = fn(cc["covs"][ci], eng, 4, 8, seed=SEEDS[0])


def test_baseline_single_engine_list_squeezes(cov_cases):
    cc = cov_cases
    sw = baseline_sweep("dsa", covs=[cc["covs"][0]],
                        engines=[cc["engines"][0]], r=4, t_outer=5,
                        seeds=SEEDS, q_true=cc["q_true"])
    assert sw.error_traces.shape == (2, 5)          # no case axis
    assert sw.node_counts is None
    # and equals the classic single-engine path exactly
    ref = baseline_sweep("dsa", covs=cc["covs"][0],
                         engine=cc["engines"][0], r=4, t_outer=5,
                         seeds=SEEDS, q_true=cc["q_true"])
    np.testing.assert_array_equal(sw.error_traces, ref.error_traces)


def test_baseline_ragged_rejections(cov_cases):
    cc = cov_cases
    with pytest.raises(ValueError, match="not both"):
        baseline_sweep("dsa", covs=cc["covs"], engine=cc["engines"][0],
                       engines=cc["engines"], r=4, t_outer=3, seeds=[0])
    with pytest.raises(ValueError, match="single-case"):
        baseline_sweep("seq_dist_pm", covs=cc["covs"],
                       engines=cc["engines"], r=4, iters_per_vec=3,
                       seeds=[0])
    with pytest.raises(ValueError, match="node count"):
        baseline_sweep("dsa", covs=[cc["covs"][0], cc["covs"][0]],
                       engines=cc["engines"], r=4, t_outer=3, seeds=[0])
