"""Figs. 4 & 5 — S-DOT/SA-DOT vs centralized OI, SeqPM and distributed
baselines (SeqDistPM, DSA, DPGD, DeEPCA), for distinct and repeated top
eigenvalues. Paper setting: N=10, n_i=1000, d=20.

Emits the final subspace error of each method at an equal *total iteration*
budget (outer x inner for consensus methods) — the paper's x-axis.
"""
from __future__ import annotations

import jax

from repro.core.baselines import deepca, dpgd, dsa, seq_dist_pm, seq_pm
from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.linalg import orthonormal_init
from repro.core.oi import oi_trace
from repro.core.metrics import subspace_error
from repro.core.sdot import sadot, sdot
from repro.core.topology import erdos_renyi

from .common import Row, sample_problem, timed

N, D, N_PER = 10, 20, 1000


def _case(gap: float, r: int, repeated: bool):
    covs, q_true = sample_problem(d=D, r=r, n_nodes=N, n_per=N_PER, gap=gap,
                                  seed=0, repeated_top=repeated)
    m = covs.sum(0)
    eng = DenseConsensus(erdos_renyi(N, 0.5, seed=1))
    rows = []
    tag = f"fig{'5' if repeated else '4'}/gap{gap}/r{r}"

    t_o = 100
    q0 = orthonormal_init(jax.random.PRNGKey(0), D, r)
    _, tr = oi_trace(m, q0, t_o, metric=lambda q: subspace_error(q_true, q))
    rows.append(Row(f"{tag}/OI", 0.0, {"final_err": f"{float(tr[-1]):.2e}",
                                       "iters": t_o}))

    _, errs = seq_pm(m, r, iters_per_vec=t_o // r, q_true=q_true)
    rows.append(Row(f"{tag}/SeqPM", 0.0, {"final_err": f"{errs[-1]:.2e}",
                                          "iters": len(errs)}))

    res, us = timed(sdot, covs=covs, engine=eng, r=r, t_outer=t_o, t_c=50,
                    q_true=q_true)
    rows.append(Row(f"{tag}/S-DOT", us,
                    {"final_err": f"{res.error_trace[-1]:.2e}",
                     "total_iters": t_o * 50}))

    res, us = timed(sadot, covs=covs, engine=eng, r=r, t_outer=t_o,
                    schedule_kind="lin1", cap=50, q_true=q_true)
    rows.append(Row(f"{tag}/SA-DOT", us,
                    {"final_err": f"{res.error_trace[-1]:.2e}",
                     "total_iters": int(res.consensus_trace.sum())}))

    (_, errs), us = timed(seq_dist_pm, covs, eng, r, iters_per_vec=t_o // r,
                          t_c=50, q_true=q_true)
    rows.append(Row(f"{tag}/SeqDistPM", us,
                    {"final_err": f"{errs[-1]:.2e}",
                     "total_iters": t_o * 50}))

    (_, errs), us = timed(dsa, covs, eng, r, t_outer=t_o * 5, lr=0.05,
                          q_true=q_true)
    rows.append(Row(f"{tag}/DSA", us, {"final_err": f"{errs[-1]:.2e}",
                                       "iters": t_o * 5}))

    (_, errs), us = timed(dpgd, covs, eng, r, t_outer=t_o * 5, lr=0.05,
                          q_true=q_true)
    rows.append(Row(f"{tag}/DPGD", us, {"final_err": f"{errs[-1]:.2e}",
                                        "iters": t_o * 5}))

    (_, errs), us = timed(deepca, covs, eng, r, t_outer=t_o, t_mix=3,
                          q_true=q_true)
    rows.append(Row(f"{tag}/DeEPCA", us, {"final_err": f"{errs[-1]:.2e}",
                                          "total_iters": t_o * 3}))
    return rows


def run():
    rows = []
    rows += _case(0.5, 5, repeated=False)
    rows += _case(0.8, 3, repeated=False)
    rows += _case(0.5, 4, repeated=True)    # Fig. 5: lambda_1=...=lambda_r
    return rows
