import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# Delta-scaled roofline extraction (single-pod mesh), one JSON per cell.
#
# Why not read the dry-run numbers directly? cost_analysis() counts a
# lax.scan body ONCE regardless of trip count, so any scan-over-layers cost
# is a ~1/n_groups undercount (and collectives inside the scan likewise).
# Here each cell is compiled twice, UNROLLED, at full width but with 1 and 2
# layer-groups:
#
#     cost(G) = outside + G * body    (exactly, since every group is
#                                      structurally identical)
#  => body = cost(2) - cost(1),  total = cost(1) + (G - 1) * body.
#
# The extrapolation is exact for FLOPs and collective bytes; for HBM bytes it
# is exact modulo XLA fusing across the group boundary (second-order). The
# full-depth compile in launch/dryrun.py remains the proof that the sharding
# and memory plan hold at depth; this module supplies the roofline numerators.
"""Roofline driver — see header comment above the docstring for method.

Usage:
  python -m repro.launch.roofline --arch qwen2-7b --shape train_4k
  python -m repro.launch.roofline --all --out experiments/roofline
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_arch, valid_cells
from ..configs.base import ModelConfig, ShapeConfig
from ..launch.dryrun import abstract_state, input_specs, model_flops
from ..launch.hlo_analysis import collective_bytes, roofline_terms
from ..launch.mesh import HW, make_production_mesh
from ..optim.adamw import AdamWConfig, adamw_update


def _cfg_groups(cfg: ModelConfig, g: int) -> ModelConfig:
    return dataclasses.replace(cfg, n_layers=len(cfg.block_pattern) * g)


def _compile_cost(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                  remat: bool, constrain_acts: bool = True,
                  q_chunk: int = 1024) -> Dict[str, float]:
    """Lower+compile one UNROLLED variant; return per-device cost numbers."""
    from ..models import sharding as shd
    from ..models.transformer import decode_step, forward
    from ..train.step import loss_fn

    opt = AdamWConfig()
    abs_state = abstract_state(cfg, shape, mesh, opt)
    ins = input_specs(cfg, shape, mesh)
    aspecs = shd.activation_specs(cfg, mesh, shape.global_batch) \
        if constrain_acts else None

    if shape.kind == "train":
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(
                params, batch, cfg, remat=remat, unroll_layers=True,
                act_specs=aspecs)
            return adamw_update(grads, opt_state, params, opt)

        with mesh:
            lowered = jax.jit(train_step).lower(
                abs_state["params"], abs_state["opt"], ins)
    elif shape.kind == "prefill":
        def prefill(params, batch):
            return forward(params, batch, cfg, remat=False, unroll_layers=True,
                           act_specs=aspecs)

        with mesh:
            lowered = jax.jit(prefill).lower(abs_state["params"], ins)
    else:
        def serve(params, state, tokens):
            return decode_step(params, state, tokens, cfg, unroll_layers=True,
                               act_specs=aspecs)

        with mesh:
            lowered = jax.jit(serve).lower(
                abs_state["params"], abs_state["decode_state"], ins["tokens"])

    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):  # jax < 0.5 returns a per-program list
        ca = ca[0] if ca else {}
    coll = collective_bytes(compiled.as_text(), mesh.size)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "wire": coll.wire_bytes,
        "coll_by_kind": coll.by_kind,
        "coll_count": coll.count,
    }


def run_cell(arch: str, shape_id: str, *, remat: bool = True,
             constrain_acts: bool = True, mesh_shape: str | None = None,
             kv_quant: bool = False,
             out_path: str | None = None) -> Dict[str, Any]:
    cfg = get_arch(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    shape = SHAPES[shape_id]
    if shape_id == "long_500k" and not cfg.subquadratic:
        res = {"arch": arch, "shape": shape_id, "status": "skipped"}
        if out_path:
            json.dump(res, open(out_path, "w"), indent=1)
        return res

    if mesh_shape:
        dims = tuple(int(t) for t in mesh_shape.split(","))
        assert len(dims) == 2 and dims[0] * dims[1] == 256, mesh_shape
        mesh = jax.make_mesh(dims, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=False)
    g_total = cfg.n_groups
    t0 = time.time()
    c1 = _compile_cost(_cfg_groups(cfg, 1), shape, mesh, remat=remat,
                       constrain_acts=constrain_acts)
    c2 = _compile_cost(_cfg_groups(cfg, 2), shape, mesh, remat=remat,
                       constrain_acts=constrain_acts)

    def extrap(key):
        body = c2[key] - c1[key]
        return c1[key] + (g_total - 1) * body

    flops_dev = extrap("flops")
    bytes_dev = extrap("bytes")
    wire_dev = extrap("wire")
    coll_kind = {k: c1["coll_by_kind"].get(k, 0.0) +
                 (g_total - 1) * (c2["coll_by_kind"].get(k, 0.0)
                                  - c1["coll_by_kind"].get(k, 0.0))
                 for k in set(c1["coll_by_kind"]) | set(c2["coll_by_kind"])}

    mf = model_flops(cfg, shape)
    total_flops = flops_dev * mesh.size
    terms = roofline_terms(flops_per_dev=flops_dev, bytes_per_dev=bytes_dev,
                           wire_bytes_per_dev=wire_dev, hw=HW)
    # roofline fraction: useful model FLOPs per chip-second at the bound
    mfu_at_bound = (mf / mesh.size / HW.PEAK_FLOPS_BF16) / terms["bound_s"] \
        if terms["bound_s"] else None
    res = {
        "arch": arch, "shape": shape_id, "status": "ok",
        "n_devices": mesh.size, "n_groups": g_total,
        "elapsed_s": round(time.time() - t0, 1),
        "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
        "wire_bytes_per_dev": wire_dev,
        "collectives_by_kind": coll_kind,
        "model_flops": mf,
        "useful_flops_frac": mf / total_flops if total_flops else None,
        "roofline": terms,
        "mfu_at_bound": mfu_at_bound,
    }
    if out_path:
        json.dump(res, open(out_path, "w"), indent=1)
    return res


def _run_all(out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    failures = []
    for cell in valid_cells():
        tag = f"{cell['arch']}__{cell['shape']}"
        out = os.path.join(out_dir, tag + ".json")
        if os.path.exists(out):
            print(f"[skip cached] {tag}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.roofline",
               "--arch", cell["arch"], "--shape", cell["shape"], "--out", out]
        print(f"[run] {tag}", flush=True)
        r = subprocess.run(cmd, capture_output=True, text=True)
        if r.returncode != 0:
            failures.append((tag, r.stderr[-1500:]))
            print(f"[FAIL] {tag}\n{r.stderr[-1500:]}", flush=True)
    print(f"done; {len(failures)} failures")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--remat", default="full", choices=["full", "names", "none"])
    ap.add_argument("--no-act-constraints", action="store_true",
                    help="baseline mode: no activation sharding constraints")
    ap.add_argument("--mesh-shape", default=None,
                    help="alternative single-pod logical shape, e.g. 64,4")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (decode cells)")
    ap.add_argument("--out")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    if args.all:
        _run_all(args.out or "experiments/roofline")
        return
    res = run_cell(args.arch, args.shape,
                   remat={"full": True, "names": "names", "none": False}[args.remat],
                   constrain_acts=not args.no_act_constraints,
                   mesh_shape=args.mesh_shape, kv_quant=args.kv_quant,
                   out_path=args.out)
    print(json.dumps(res, indent=1, default=str))


if __name__ == "__main__":
    main()
