"""F-DOT — feature-wise distributed orthogonal iteration (Alg. 2).

Node i holds a feature slab X_i in R^{d_i x n}. One outer iteration:
  1. Z_i = X_i^T Q_i                              (local, n x r)
  2. consensus-average + debias -> S ~= sum_j X_j^T Q_j at every node
  3. V_i = X_i S_i                                (local, d_i x r)
  4. distributed QR of the stacked V via distributed CholeskyQR2:
       G_i = V_i^T V_i ; G = consensus-sum G_i (r x r traffic only);
       R = chol(G)^T ; Q_i = V_i R^{-1}     (x2 passes)

Step 4 replaces the push-sum Householder scheme of paper ref [12] with a
TPU-native equivalent (DESIGN.md sec.2): identical span, MXU-friendly, and the
per-round network payload shrinks from d_i x r to r x r.

Execution modes (``fused`` flag, same architecture as sdot.py):
  * fused (default) — the ragged slabs are zero-padded to one (N, d_max, n)
    stack (exact: padded rows are null in every product) and the ENTIRE
    t_outer loop — batched slab products (Pallas (node, sample-block)
    kernels on TPU, fused einsum elsewhere; kernels/slab_ops.py), masked
    consensus with the device debias table, and the in-scan distributed
    CholeskyQR2 — runs as one jitted ``lax.scan``. The error trace is
    computed on device from the padded slabs; communication is accounted in
    closed form. Zero host syncs per iteration.
  * eager (``fused=False``) — the original per-iteration Python loop over
    ragged slab lists. Kept as the correctness oracle
    (tests/test_fused_zoo.py) and for step-by-step debugging.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import runtime
from .async_gossip import masked_async_rounds
from .consensus import (DenseConsensus, consensus_schedule, debias_table,
                        debiased_gossip)
from .linalg import orthonormal_init
from .netfaults import (masked_faulty_rounds, realized_debias,
                        sample_fault_blocks)
from .metrics import CommLedger, subspace_error, subspace_error_from_cross
from ..kernels import ops as kops

__all__ = ["FDOTResult", "fdot", "fdot_program", "distributed_cholesky_qr",
           "pad_feature_slabs", "unpad_feature_slabs", "split_pad_rows"]


@dataclasses.dataclass
class FDOTResult:
    q_blocks: List[jnp.ndarray]     # per-node slabs Q_{f,i} (d_i x r)
    error_trace: Optional[np.ndarray]
    ledger: CommLedger

    @property
    def q_full(self) -> jnp.ndarray:
        return jnp.concatenate(self.q_blocks, axis=0)


def pad_feature_slabs(blocks: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Zero-pad ragged (d_i, m) node slabs to one (N, d_max, m) stack.

    Exact for every product in Alg. 2: a padded row is null on both sides of
    X^T Q, contributes a zero row to X S, and adds nothing to V^T V.
    """
    d_max = max(int(b.shape[0]) for b in blocks)
    return jnp.stack([
        jnp.pad(b, ((0, d_max - b.shape[0]), (0, 0))) for b in blocks])


def unpad_feature_slabs(stack: jnp.ndarray, dims: Sequence[int]) -> List[jnp.ndarray]:
    """Inverse of pad_feature_slabs given the true per-node row counts."""
    return [stack[i, :di] for i, di in enumerate(dims)]


def split_pad_rows(full: jnp.ndarray, dims: Sequence[int]) -> jnp.ndarray:
    """Split a stacked (d, r) matrix into per-node row slabs and zero-pad to
    one (N, d_max, r) stack (the layout of the fused F-DOT/d-PM iterates)."""
    offs = np.cumsum([0] + list(dims))
    return pad_feature_slabs(
        [full[offs[i]:offs[i + 1]] for i in range(len(dims))])


def distributed_cholesky_qr(
    v_blocks: Sequence[jnp.ndarray],
    engine: DenseConsensus,
    t_c: int,
    ledger: Optional[CommLedger] = None,
    passes: int = 2,
    awake_pad: Optional[int] = None,
    faults_pad: Optional[int] = None,
    node_up=None,
) -> List[jnp.ndarray]:
    """Distributed QR of row-partitioned V = [V_1; ...; V_N] via CholeskyQR.

    Only r x r Gram matrices cross the network. With passes=2 this is
    CholeskyQR2 and the result is orthonormal to ~machine precision.

    ``awake_pad``: with an async engine, draw each pass's awake masks padded
    to (awake_pad, N) — the layout the fused whole-run executors use — so a
    seeded eager run replays the fused scan's realized rounds exactly.
    ``faults_pad``/``node_up`` are the network-fault twin: each pass draws
    its fault blocks padded to (faults_pad, ...) and gossips the Grams under
    the iteration's crash mask.
    """
    r = v_blocks[0].shape[1]
    blocks = [v.astype(jnp.float32) for v in v_blocks]
    inject = awake_pad is not None and hasattr(engine, "sample_awake")
    inject_faults = (faults_pad is not None
                     and hasattr(engine, "sample_faults"))
    for _ in range(passes):
        grams = jnp.stack([b.T @ b for b in blocks])              # (N, r, r)
        if inject_faults:
            faults = engine.sample_faults(t_c, t_max=faults_pad)
            gsum = engine.run_debiased(grams, t_c, ledger, faults=faults,
                                       node_up=node_up)
        elif inject:
            awake = engine.sample_awake(t_c, t_max=awake_pad)
            gsum = engine.run_debiased(grams, t_c, ledger, awake=awake)
        else:
            gsum = engine.run_debiased(grams, t_c, ledger)        # approx sum
        new_blocks = []
        for i, b in enumerate(blocks):
            g = 0.5 * (gsum[i] + gsum[i].T) + 1e-10 * jnp.eye(r, dtype=b.dtype)
            rr = jnp.linalg.cholesky(g).T
            new_blocks.append(
                jax.scipy.linalg.solve_triangular(rr.T, b.T, lower=True).T)
        blocks = new_blocks
    return blocks


def _solve_from_gram_sum(gsum, v):
    """Finish one in-scan CholeskyQR pass from consensus-summed Grams:
    symmetrize + jitter, Cholesky, and the per-node triangular solve over
    the padded (N, d_max, r) slabs. Shared by the sync (_qr_pass) and async
    (_fdot_async_outer_body) executors so the numerics cannot diverge."""
    r = v.shape[-1]
    g = (0.5 * (gsum + jnp.swapaxes(gsum, 1, 2))
         + 1e-10 * jnp.eye(r, dtype=v.dtype))
    rr = jnp.swapaxes(jnp.linalg.cholesky(g), 1, 2)               # upper R
    solve = lambda R, b: jax.scipy.linalg.solve_triangular(
        jnp.swapaxes(R, 0, 1), b.T, lower=True).T
    return jax.vmap(solve)(rr, v)


def _qr_pass(w, table, v, t_qr, t_max):
    """One in-scan distributed CholeskyQR pass over padded slabs (N,d_max,r)."""
    grams = jnp.einsum("idr,ids->irs", v, v)                      # (N, r, r)
    gsum = debiased_gossip(w, table, grams, t_qr, t_max)
    return _solve_from_gram_sum(gsum, v)


def _fdot_outer_body(x_pad, w, table, qtrue_pad, *, t_max: int, t_c_qr: int,
                     passes: int, trace_err: bool):
    """Build the per-outer-iteration body ``(q_pad, t_c) -> (q_new, err)``.

    One definition feeds every runtime driver (monolithic, chunked, sweep —
    via ``_fdot_build_body``), so a run split at chunk boundaries replays
    the monolithic scan bit for bit. No node mask
    is needed here (unlike the S-DOT body): ragged-N F-DOT cases pad with
    all-zero slabs, which contribute exactly nothing to every product
    including the error cross term.
    """

    def outer(q_pad, t_c):
        z0 = kops.batched_slab_tq(x_pad, q_pad)                  # (N, n, r)
        s = debiased_gossip(w, table, z0, t_c, t_max)
        v = kops.batched_slab_apply(x_pad, s).astype(jnp.float32)
        for _ in range(passes):
            v = _qr_pass(w, table, v, jnp.int32(t_c_qr), t_c_qr)
        if trace_err:
            cross = jnp.einsum("idr,ids->rs", qtrue_pad, v)      # Q^T Qhat
            err = subspace_error_from_cross(cross)
        else:
            err = jnp.float32(0.0)
        return v, err

    return outer


def _fdot_async_outer_body(x_pad, w, adj, p_awake, qtrue_pad, *, t_max: int,
                           t_c_qr: int, passes: int, trace_err: bool):
    """Async twin of ``_fdot_outer_body``: carry is ``(q_pad, rng key)``.

    Three key splits per outer iteration (partial-product phase, QR pass 1,
    QR pass 2) in the order the eager oracle consumes them; carrying the key
    makes chunked resume exact for straggler F-DOT runs.
    """
    n = w.shape[0]

    def gossip(key, z, t_c):
        key, sub = jax.random.split(key)
        awake = jax.random.bernoulli(sub, p_awake, (t_max, n))
        out, sends, counts = masked_async_rounds(w, adj, awake, t_c, z)
        return key, out, sends, counts

    def outer(carry, t_c):
        q_pad, key = carry
        z0 = kops.batched_slab_tq(x_pad, q_pad)                  # (N, n, r)
        key, s, sd, cnt = gossip(key, z0, t_c)
        v = kops.batched_slab_apply(x_pad, s).astype(jnp.float32)
        sends, counts = [sd], [cnt]
        for _ in range(passes):
            grams = jnp.einsum("idr,ids->irs", v, v)             # (N, r, r)
            key, gsum, sd, cnt = gossip(key, grams, jnp.int32(t_c_qr))
            sends.append(sd)
            counts.append(cnt)
            v = _solve_from_gram_sum(gsum, v)
        if trace_err:
            cross = jnp.einsum("idr,ids->rs", qtrue_pad, v)      # Q^T Qhat
            err = subspace_error_from_cross(cross)
        else:
            err = jnp.float32(0.0)
        return (v, key), (err, jnp.stack(sends), jnp.stack(counts))

    return outer


def _fdot_faulty_outer_body(x_pad, w, adj, params, node_up_sched, table,
                            qtrue_pad, *, t_max: int, t_c_qr: int,
                            passes: int, trace_err: bool, debias: str):
    """Network-fault twin of ``_fdot_async_outer_body``: carry is
    ``((q_pad, ge, t), key)``.

    Three key splits per outer iteration (partial-product phase, QR pass 1,
    QR pass 2) in eager-oracle order, each drawing its own padded fault
    blocks and threading the Gilbert–Elliott state through sequentially.
    The iteration's crash mask (one ``node_up_sched`` row, selected by the
    carried counter) holds for all three phases, and a crashed node's slab
    is frozen at the end of the iteration.
    """
    n = w.shape[0]

    def gossip(key, ge, node_up, z, t_c):
        key, sub = jax.random.split(key)
        blocks = sample_fault_blocks(sub, n, t_max)
        out, p, ge, sends, counts = masked_faulty_rounds(
            w, adj, params, node_up, ge, blocks, t_c, z)
        if debias == "realized":
            out = realized_debias(out, p)
        else:
            row = jnp.take(table, t_c, axis=0)
            out = out / row.astype(out.dtype).reshape(
                (-1,) + (1,) * (out.ndim - 1))
        return key, ge, out, sends, counts

    def outer(carry, t_c):
        (q_pad, ge, t), key = carry
        node_up = jnp.take(node_up_sched, t, axis=0)             # (N,)
        z0 = kops.batched_slab_tq(x_pad, q_pad)                  # (N, n, r)
        key, ge, s, sd, cnt = gossip(key, ge, node_up, z0, t_c)
        v = kops.batched_slab_apply(x_pad, s).astype(jnp.float32)
        sends, counts = [sd], [cnt]
        for _ in range(passes):
            grams = jnp.einsum("idr,ids->irs", v, v)             # (N, r, r)
            key, ge, gsum, sd, cnt = gossip(key, ge, node_up, grams,
                                            jnp.int32(t_c_qr))
            sends.append(sd)
            counts.append(cnt)
            v = _solve_from_gram_sum(gsum, v)
        up = node_up.reshape((-1, 1, 1)) > 0
        q_new = jnp.where(up, v, q_pad)                          # freeze
        if trace_err:
            cross = jnp.einsum("idr,ids->rs", qtrue_pad, q_new)  # Q^T Qhat
            err = subspace_error_from_cross(cross)
        else:
            err = jnp.float32(0.0)
        return ((q_new, ge, t + 1), key), (err, jnp.stack(sends),
                                           jnp.stack(counts))

    return outer


def _fdot_build_body(operands, *, t_max: int, t_c_qr: int, passes: int,
                     trace_err: bool, is_async: bool,
                     is_faulty: bool = False, debias: str = "realized"):
    """Runtime body builder for F-DOT (the Program protocol's
    ``build_body``) — adapts the same outer-iteration bodies the monolithic
    executor uses, so every driver steps through identical math. Async
    programs make three key splits per outer iteration (partial-product
    phase, QR pass 1, QR pass 2) in eager-oracle order."""
    if is_faulty:
        x_pad, w, adj, params, node_up_sched, table, qtrue_pad = operands
        return _fdot_faulty_outer_body(x_pad, w, adj, params, node_up_sched,
                                       table, qtrue_pad, t_max=t_max,
                                       t_c_qr=t_c_qr, passes=passes,
                                       trace_err=trace_err, debias=debias)
    if is_async:
        x_pad, w, adj, p_awake, qtrue_pad = operands
        return _fdot_async_outer_body(x_pad, w, adj, p_awake, qtrue_pad,
                                      t_max=t_max, t_c_qr=t_c_qr,
                                      passes=passes, trace_err=trace_err)
    x_pad, w, table, qtrue_pad = operands
    return runtime.sync_body(
        _fdot_outer_body(x_pad, w, table, qtrue_pad, t_max=t_max,
                         t_c_qr=t_c_qr, passes=passes, trace_err=trace_err))


def fdot_program(
    *,
    data_blocks: Sequence[jnp.ndarray],
    engine,
    r: int,
    t_outer: int,
    t_c: int = 50,
    t_c_qr: Optional[int] = None,
    schedule: Optional[np.ndarray] = None,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
) -> runtime.Program:
    """Register an F-DOT run with the unified executor runtime.

    ``runtime.run_monolithic`` reproduces ``fdot(fused=True)``;
    ``runtime.run_chunked`` is the restartable twin (streaming/resume.py),
    including async engines — the per-iteration RNG splits ride in the
    checkpointed key.
    """
    prep = _prepare_fdot(data_blocks=data_blocks, engine=engine, r=r,
                         t_outer=t_outer, t_c=t_c, t_c_qr=t_c_qr,
                         schedule=schedule, q_init=q_init, q_true=q_true,
                         seed=seed)
    x_pad, q0_pad, qtrue_pad = prep["pads"]()
    t_max, t_c_qr, passes = prep["t_max"], prep["t_c_qr"], prep["passes"]
    trace_err, is_async = prep["trace_err"], prep["is_async"]
    is_faulty = prep["is_faulty"]
    debias = engine.debias if is_faulty else "realized"
    sched_np = prep["schedule"]
    n_samples, dims = prep["n_samples"], prep["dims"]
    q0 = q0_pad

    if is_faulty:
        n_nodes = prep["n_nodes"]
        node_up_sched = jnp.asarray(engine.faults.validate(
            n_nodes, t_outer).node_up(t_outer, n_nodes))
        operands = (x_pad, engine._w, engine._adj, engine._params,
                    node_up_sched, debias_table(engine._w, t_max),
                    qtrue_pad)
        key0, tail = engine._key, (1 + passes, t_max)
        q0 = (q0_pad, engine._ge, jnp.int32(0))
    elif is_async:
        operands = (x_pad, engine._w, engine._adj,
                    jnp.asarray(engine.p_awake, jnp.float32), qtrue_pad)
        key0, tail = engine._key, (1 + passes, t_max)
    else:
        if not hasattr(engine, "debias_table"):
            raise ValueError("fused F-DOT needs a fused-capable engine "
                             "(debias_table) or an async engine")
        operands = (x_pad, engine._w, engine.debias_table(t_max), qtrue_pad)
        key0, tail = None, ()

    def finalize(state: runtime.RunState, done: int) -> FDOTResult:
        adj = engine.graph.adjacency
        q_pad = state.q[0] if is_faulty else state.q
        if is_async or is_faulty:
            if done == t_outer:
                engine._key = state.key
                if is_faulty:
                    engine._ge = state.q[1]
            ledger = runtime.async_ledger(
                sched_np[:done], state.sends[:done], state.counts[:done],
                lambda s: (float(s[:, 0].sum()) * n_samples * r
                           + float(s[:, 1:].sum()) * r * r),
                lambda t_c_t: [((0,), t_c_t)] + [((1 + p,), t_c_qr)
                                                 for p in range(passes)])
        else:
            ledger = CommLedger()
            bpe = getattr(engine, "payload_bytes_per_elem", 4.0)
            ledger.log_gossip_rounds(sched_np[:done], adj, n_samples * r,
                                     bytes_per_elem=bpe)
            ledger.log_gossip_rounds(np.full(done, passes * t_c_qr), adj,
                                     r * r, bytes_per_elem=bpe)
        return FDOTResult(
            q_blocks=unpad_feature_slabs(q_pad, dims),
            error_trace=(np.asarray(state.errs[:done]) if trace_err
                         else None),
            ledger=ledger,
        )

    return runtime.Program(
        build_body=_fdot_build_body,
        operands=operands,
        statics=(("t_max", t_max), ("t_c_qr", t_c_qr), ("passes", passes),
                 ("trace_err", trace_err), ("is_async", is_async),
                 ("is_faulty", is_faulty), ("debias", debias)),
        xs=sched_np,
        q0=q0,
        key0=key0,
        tail=tail,
        finalize=finalize,
    )


def _prepare_fdot(*, data_blocks, engine, r, t_outer, t_c, t_c_qr, schedule,
                  q_init, q_true, seed):
    """Validate + normalize an F-DOT run's inputs into device-ready pieces.

    Shared by ``fdot`` and the chunked streaming executor
    (``streaming/resume.py``) — both build the padded slab stacks, schedule
    array, and initial iterate here, so a chunked run starts from literally
    the same device values as the monolithic one.
    """
    n_nodes = engine.graph.n_nodes
    if len(data_blocks) != n_nodes:
        raise ValueError("need one feature slab per node")
    dims = [int(x.shape[0]) for x in data_blocks]
    d = sum(dims)
    n_samples = data_blocks[0].shape[1]
    t_c_qr = t_c if t_c_qr is None else t_c_qr
    passes = 2

    if schedule is None:
        schedule = consensus_schedule("const", t_outer, t_max=t_c)
    elif len(schedule) < t_outer:
        raise ValueError(f"schedule has {len(schedule)} entries but "
                         f"t_outer={t_outer}")
    schedule = np.asarray(schedule[:t_outer])

    if q_init is None:
        q_init = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    # split the common init into per-node slabs
    offs = np.cumsum([0] + dims)
    q_blocks = [q_init[offs[i]:offs[i + 1]] for i in range(n_nodes)]

    is_faulty = hasattr(engine, "sample_faults")
    is_async = (not is_faulty) and hasattr(engine, "sample_awake")
    t_max = int(max(schedule.max(), t_c_qr)) if t_outer else 0
    trace_err = q_true is not None

    def pads():
        # built lazily: only the fused/chunked executors consume the padded
        # stacks — the eager oracle iterates the ragged blocks directly and
        # must not pay the duplicated (N, d_max, n) device copy
        x_pad = pad_feature_slabs(data_blocks)
        q0_pad = pad_feature_slabs(q_blocks)
        qtrue_pad = (split_pad_rows(q_true, dims) if trace_err
                     else jnp.zeros_like(q0_pad))
        return x_pad, q0_pad, qtrue_pad

    return dict(
        n_nodes=n_nodes, dims=dims, d=d, n_samples=n_samples,
        t_c_qr=int(t_c_qr), passes=passes, schedule=schedule,
        sched_dev=jnp.asarray(schedule, jnp.int32), q_blocks=q_blocks,
        is_async=is_async, is_faulty=is_faulty, t_max=t_max,
        trace_err=trace_err, pads=pads,
    )


def fdot(
    *,
    data_blocks: Sequence[jnp.ndarray],   # node i: X_i (d_i x n)
    engine: DenseConsensus,
    r: int,
    t_outer: int,
    t_c: int = 50,
    t_c_qr: Optional[int] = None,
    schedule: Optional[np.ndarray] = None,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
    fused: bool = True,
) -> FDOTResult:
    """Run F-DOT over a simulated network (Alg. 2).

    ``schedule`` overrides ``t_c`` with per-outer-iteration consensus budgets
    for the partial-product phase (the QR phase keeps the constant
    ``t_c_qr``). ``fused=True`` (default) executes the whole run as a single
    compiled scan over zero-padded slabs (a thin shim over
    ``runtime.run_monolithic``); ``fused=False`` is the eager
    per-iteration oracle.
    """
    # async / faulty engines get their own whole-run scan; any other engine
    # without the scan interface runs eagerly
    if fused and (hasattr(engine, "sample_awake")
                  or hasattr(engine, "sample_faults")
                  or hasattr(engine, "debias_table")):
        return runtime.run_monolithic(fdot_program(
            data_blocks=data_blocks, engine=engine, r=r, t_outer=t_outer,
            t_c=t_c, t_c_qr=t_c_qr, schedule=schedule, q_init=q_init,
            q_true=q_true, seed=seed))

    prep = _prepare_fdot(data_blocks=data_blocks, engine=engine, r=r,
                         t_outer=t_outer, t_c=t_c, t_c_qr=t_c_qr,
                         schedule=schedule, q_init=q_init, q_true=q_true,
                         seed=seed)
    t_c_qr, passes = prep["t_c_qr"], prep["passes"]
    schedule, q_blocks = prep["schedule"], prep["q_blocks"]
    is_async, t_max = prep["is_async"], prep["t_max"]
    is_faulty = prep["is_faulty"]
    if is_faulty:
        n_nodes = prep["n_nodes"]
        node_up_sched = engine.faults.validate(n_nodes, t_outer).node_up(
            t_outer, n_nodes)

    ledger = CommLedger()
    errs = [] if q_true is not None else None
    for t in range(t_outer):
        # step 1-2: consensus over the (n x r) partial products
        z0 = jnp.stack([x.T @ q for x, q in zip(data_blocks, q_blocks)])
        if is_faulty:
            node_up = node_up_sched[t]
            faults = engine.sample_faults(int(schedule[t]), t_max=t_max)
            s = engine.run_debiased(z0, int(schedule[t]), ledger,
                                    faults=faults, node_up=node_up)
        elif is_async:
            awake = engine.sample_awake(int(schedule[t]), t_max=t_max)
            s = engine.run_debiased(z0, int(schedule[t]), ledger,
                                    awake=awake)
        else:
            s = engine.run_debiased(z0, int(schedule[t]), ledger)
        # step 3: local expansion
        v_blocks = [x @ s[i] for i, x in enumerate(data_blocks)]
        # step 4: distributed orthonormalization
        new_blocks = distributed_cholesky_qr(
            v_blocks, engine, t_c_qr, ledger, passes=passes,
            awake_pad=t_max if is_async else None,
            faults_pad=t_max if is_faulty else None,
            node_up=node_up if is_faulty else None)
        if is_faulty:
            # crashed nodes freeze their slab for the iteration
            q_blocks = [nb if node_up[i] > 0 else qb
                        for i, (nb, qb) in enumerate(zip(new_blocks,
                                                         q_blocks))]
        else:
            q_blocks = new_blocks
        if errs is not None:
            q_full = jnp.concatenate(q_blocks, axis=0)
            errs.append(float(subspace_error(q_true, q_full)))
    error_trace = np.asarray(errs) if errs is not None else None

    return FDOTResult(
        q_blocks=q_blocks,
        error_trace=error_trace,
        ledger=ledger,
    )
