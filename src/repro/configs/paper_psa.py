"""The paper's own workload: distributed PSA over sample-partitioned data.

Not an LM architecture — this config parameterizes the S-DOT/SA-DOT runs and
the PSA-compression feature of the training stack.
"""
from .base import PSAConfig

CONFIG = PSAConfig(enabled=True, rank=64, refresh_every=32,
                   oi_iters=2, gossip_rounds=4, error_feedback=True)
