"""Beyond-paper performance features added during the §Perf hillclimb:
int8 KV cache, shard-local MoE dispatch, selective remat, cross-pod HLO
traffic attribution."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced_config
from repro.data.pipeline import make_lm_batch
from repro.models.moe import apply_moe, moe_capacity
from repro.models.transformer import (decode_step, forward, init_decode_state,
                                      init_params)


# ---------------------------------------------------------------------------
# int8 KV cache
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("aid", ["qwen2-7b", "musicgen-medium"])
def test_int8_kv_cache_decode_close_to_bf16(aid):
    cfg = dataclasses.replace(reduced_config(get_arch(aid)), kv_quant=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = make_lm_batch(cfg, 0, 0, 2, 12)["tokens"]
    want = forward(params, {"tokens": toks}, cfg, remat=False)
    st = init_decode_state(cfg, 2, 12)
    assert st["caches"][next(iter(st["caches"]))]["k"].dtype == jnp.int8
    outs = []
    for t in range(12):
        lg, st = decode_step(params, st, toks[:, t:t + 1], cfg)
        outs.append(lg)
    got = jnp.concatenate(outs, 1)
    rel = float(jnp.abs(got.astype(jnp.float32)
                        - want.astype(jnp.float32)).max()) \
        / float(jnp.abs(want).max())
    assert rel < 0.06, rel


def test_int8_cache_halves_capacity():
    from repro.models.attention import init_kv_cache
    cfg = reduced_config(get_arch("qwen2-7b"))
    c_bf16 = init_kv_cache(cfg, 2, 64, 1)
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    c_int8 = init_kv_cache(cfg_q, 2, 64, 1)
    bytes_bf16 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c_bf16))
    bytes_int8 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c_int8))
    assert bytes_int8 < 0.6 * bytes_bf16


# ---------------------------------------------------------------------------
# shard-local MoE dispatch
# ---------------------------------------------------------------------------
def _moe_setup(cap_factor=64.0):
    cfg = reduced_config(get_arch("phi3.5-moe-42b-a6.6b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cap_factor))
    params = init_params(jax.random.PRNGKey(0), cfg)
    gp = jax.tree.map(lambda l: l[0], params["groups"])
    mp = next(v["ffn"] for v in gp.values()
              if isinstance(v, dict) and "router" in v.get("ffn", {}))
    return cfg, mp


@pytest.mark.parametrize("n_shards", [2, 4])
def test_shard_local_dispatch_matches_global(n_shards):
    """With no capacity drops, n-shard local routing == global routing."""
    cfg, mp = _moe_setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y1 = apply_moe(mp, x, cfg)
    y2 = apply_moe(mp, x, cfg,
                   act_specs={"moe": {"dp": None, "e": None,
                                      "n_dp": n_shards}})
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


def test_shard_local_dispatch_indivisible_tokens_falls_back():
    cfg, mp = _moe_setup()
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 5, cfg.d_model))  # t=15
    y = apply_moe(mp, x, cfg, act_specs={"moe": {"dp": None, "e": None,
                                                 "n_dp": 4}})
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_moe_capacity_drops_are_bounded():
    """Tight capacity: output stays finite and within gate-weighted range."""
    cfg, mp = _moe_setup(cap_factor=1.0)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16, cfg.d_model))
    y = apply_moe(mp, x, cfg)
    assert bool(jnp.isfinite(y).all())


def test_moe_capacity_rounding():
    from repro.configs.base import MoEConfig
    m = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=1.25)
    assert moe_capacity(m, 64) % 8 == 0
    assert moe_capacity(m, 64) >= 1.25 * 2 * 64 / 4


# ---------------------------------------------------------------------------
# selective remat
# ---------------------------------------------------------------------------
def test_selective_remat_same_loss_and_grads():
    from repro.train.step import loss_fn
    cfg = reduced_config(get_arch("h2o-danube-1.8b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_lm_batch(cfg, 0, 0, 2, 16)
    outs = {}
    for mode in (True, "names", False):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg,
                                                  remat=mode)
        outs[mode] = (float(loss), grads)
    assert outs[True][0] == pytest.approx(outs["names"][0], rel=1e-5)
    assert outs[True][0] == pytest.approx(outs[False][0], rel=1e-5)
    for a, b in zip(jax.tree.leaves(outs[True][1]),
                    jax.tree.leaves(outs["names"][1])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


# ---------------------------------------------------------------------------
# cross-pod HLO attribution
# ---------------------------------------------------------------------------
def test_cross_pod_bytes_classifier():
    from repro.launch.hlo_analysis import cross_pod_bytes
    hlo = """
  %a = f32[256]{0} all-reduce(f32[256]{0} %x), replica_groups=[2,4]<=[8]
  %b = f32[256]{0} all-reduce(f32[256]{0} %x), replica_groups=[4,2]<=[2,4]T(1,0)
  %c = f32[64]{0} collective-permute(f32[64]{0} %z), source_target_pairs={{0,4},{4,0}}
  %d = f32[64]{0} collective-permute(f32[64]{0} %z), source_target_pairs={{0,1},{1,0}}
"""
    out = cross_pod_bytes(hlo, 8, pod_size=4)
    # %a: groups {0..3},{4..7} -> intra; %b: groups pair across pods -> cross
    # %c crosses (0<->4); %d intra
    wire_a = 256 * 4 * 2 * 3 / 4
    wire_b = 256 * 4 * 2 * 1 / 2
    assert out["intra_pod_bytes"] == pytest.approx(wire_a + 64 * 4)
    assert out["cross_pod_bytes"] == pytest.approx(wire_b + 64 * 4)


def test_iota_group_materialization():
    from repro.launch.hlo_analysis import _groups_on_line
    g = _groups_on_line("replica_groups=[2,4]<=[8]", 8)
    assert g == [[0, 1, 2, 3], [4, 5, 6, 7]]
    g = _groups_on_line("replica_groups=[4,2]<=[2,4]T(1,0)", 8)
    assert g == [[0, 4], [1, 5], [2, 6], [3, 7]]
