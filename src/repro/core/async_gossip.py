"""Asynchronous / straggler-tolerant consensus — the paper's §V future work.

The paper measures (Table V) that one slow node stalls the whole synchronous
network every iteration and concludes that mitigating stragglers "requires
dealing with asynchronicity in the networks", left as future work. This
module implements it:

* ``AsyncConsensus`` — a gossip engine in which every round each node is
  awake independently with probability ``p_awake``; sleeping nodes neither
  send nor mix (their neighbors renormalize their weights over the awake
  subgraph, preserving double stochasticity per round, so the average is
  conserved and the iteration remains a valid consensus step).
* ``straggler_wall_clock`` — a wall-clock model comparing the synchronous
  network (every round costs the slowest node's delay) with the async one
  (a delayed node simply misses rounds; the round time stays nominal but
  more rounds are needed for the same contraction).

Execution modes (``fused`` flag, same architecture as the rest of core/):
  * fused (default) — the awake masks for all ``t_c`` rounds are pre-sampled
    with ``jax.random``, and the per-round doubly-stochastic matrices, the
    gossip recursion, the realized mixing-matrix product (for the exact
    debias), and the per-round send/awake counts are all built inside one
    jitted ``lax.scan``. One device dispatch per call instead of one host
    round-trip per gossip round.
  * host (``fused=False``) — the original pure-NumPy float64 loop, one
    ``_round_matrix`` sample + einsum per round. Kept as the correctness
    oracle (tests/test_fused_zoo.py runs both on identical injected masks).

The headline result (benchmarks/async_straggler.py): with one persistent
straggler of delay D >> t_round, synchronous S-DOT pays (t_round + D) per
round while async S-DOT pays t_round per round and only ~1/N of the mixing
opportunities are lost — wall-clock speedup approaching (t_round + D) /
t_round for large networks, at a modest increase in rounds-to-floor.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .consensus import realized_round_weights, safe_debias_scale
from .metrics import CommLedger
from .topology import Graph, local_degree_weights

__all__ = ["AsyncConsensus", "masked_async_rounds", "straggler_wall_clock"]


def masked_async_rounds(w, adj, awake, t_c, z_stack):
    """Traceable async gossip: ``t_c`` realized rounds + realized debias.

    w: (N, N) nominal weights; adj: (N, N) 0/1 adjacency; awake: (T, N) bool
    pre-sampled masks; t_c: number of live rounds (may be a *traced* budget
    read from a schedule array — rounds i >= t_c are masked out of the z / p
    recursion and contribute zero sends/counts, so the whole-run fused
    executors in sdot.py / fdot.py can call this inside their outer scan);
    z_stack: (N, ...). Returns (debiased z, (T,) directed sends per round,
    (T,) awake-node counts per round) — masked rounds report 0.0 for both.

    An all-asleep round renormalizes to the exact identity matrix (every
    weight returns to the diagonal) with zero sends, and the debias guard
    (``safe_debias_scale``) divides by 1.0 wherever the realized product
    carries no mass — an all-degenerate call returns its input bit-for-bit
    instead of scaling it by 1e6.
    """
    n = w.shape[0]
    off = ~jnp.eye(n, dtype=bool)
    wz = w.astype(z_stack.dtype)

    def round_(carry, inp):
        z, p = carry
        a, i = inp
        live = i < t_c
        both = jnp.outer(a, a)
        w_off, dd = realized_round_weights(wz, both, off)
        w_round = w_off + jnp.diag(dd)
        z_next = jnp.einsum("ij,j...->i...", w_round, z)
        # only column 0 of the realized product is ever read (the debias
        # weight), so carry the (N,) vector p = Pi W e_1, not the (N, N)
        # product — O(N^2) per round instead of O(N^3)
        p_next = w_round @ p
        sends = jnp.sum(jnp.where(off & both, adj, 0.0))
        count = jnp.sum(a.astype(jnp.float32))
        z = jnp.where(live, z_next, z)
        p = jnp.where(live, p_next, p)
        return (z, p), (jnp.where(live, sends, 0.0),
                        jnp.where(live, count, 0.0))

    e1 = jnp.zeros((n,), z_stack.dtype).at[0].set(1.0)
    (z, p), (sends, counts) = jax.lax.scan(
        round_, (z_stack, e1), (awake, jnp.arange(awake.shape[0])))
    scale = safe_debias_scale(p)                   # realized [Pi W e_1]_i
    bshape = (-1,) + (1,) * (z_stack.ndim - 1)
    return z / scale.reshape(bshape), sends, counts


@functools.partial(jax.jit, static_argnums=())
def _fused_async_run(w, adj, awake, z_stack):
    """All awake rounds of ``awake`` + realized-product debias, on device.

    Thin jitted wrapper over masked_async_rounds with every round live
    (t_c == T). Recompiles per distinct T (the scan length) —
    constant-budget callers compile once.
    """
    return masked_async_rounds(w, adj, awake, jnp.int32(awake.shape[0]),
                               z_stack)


@dataclasses.dataclass
class AsyncConsensus:
    """Gossip with per-round random node availability.

    Each round, node i is awake w.p. ``p_awake[i]``. The effective mixing
    matrix for the round keeps only edges between awake nodes and returns
    every skipped weight to the diagonal — doubly stochastic by
    construction, so sum_i Z_i is invariant and the debiasing of Alg. 1
    still applies (we track the realized product of mixing matrices for the
    exact per-node debias weight).
    """

    graph: Graph
    p_awake: np.ndarray          # (N,) probability each node is awake
    seed: int = 0
    fused: bool = True           # device-side scan vs host NumPy loop

    def __post_init__(self):
        self.weights = local_degree_weights(self.graph)
        self._rng = np.random.default_rng(self.seed)
        self._key = jax.random.PRNGKey(self.seed)
        if np.isscalar(self.p_awake) or np.ndim(self.p_awake) == 0:
            self.p_awake = np.full(self.graph.n_nodes, float(self.p_awake))
        self._w = jnp.asarray(self.weights, jnp.float32)
        self._adj = jnp.asarray(self.graph.adjacency, jnp.float32)

    def _round_matrix(self) -> Tuple[np.ndarray, np.ndarray]:
        """Sample one realized round: returns ``(w, awake)`` where ``w`` is
        the (N, N) doubly-stochastic mixing matrix over the awake subgraph
        and ``awake`` the (N,) bool availability mask drawn this round."""
        awake = self._rng.random(self.graph.n_nodes) < self.p_awake
        return self._apply_mask(awake), awake

    def _apply_mask(self, awake: np.ndarray) -> np.ndarray:
        """Realized mixing matrix for a given awake mask (host reference)."""
        w = self.weights.copy()
        n = self.graph.n_nodes
        mask = np.outer(awake, awake)
        off = ~np.eye(n, dtype=bool)
        dropped = np.where(off & ~mask, w, 0.0)
        w = np.where(off & mask, w, 0.0)
        dd = self.weights.diagonal() + dropped.sum(axis=1)
        # degenerate-row guard (mirrors realized_round_weights): a node with
        # no surviving link has an exactly-1 diagonal, not a 1 +- ulp sum
        isolated = ~(off & mask).any(axis=1)
        np.fill_diagonal(w, np.where(isolated, 1.0, dd))
        return w

    def sample_awake(self, t_c: int, t_max: Optional[int] = None) -> jnp.ndarray:
        """Pre-sample (t_c, N) awake masks from the engine's jax.random
        stream (each call advances the stream, mirroring the host rng).

        ``t_max`` pads the underlying draw to (t_max, N) and returns the
        first t_c rows. This matters for bit-level replay of the whole-run
        fused executors: they draw one (t_max, N) mask block per outer
        iteration inside the scan (static shape), so an eager oracle that
        wants the SAME realized rounds must draw with the same padded shape
        (a (t_c, N) threefry draw is NOT a prefix of the (t_max, N) one).
        """
        self._key, sub = jax.random.split(self._key)
        rows = int(t_c if t_max is None else t_max)
        masks = jax.random.bernoulli(
            sub, jnp.asarray(self.p_awake, jnp.float32),
            (rows, self.graph.n_nodes))
        return masks[:int(t_c)]

    def run_debiased(self, z_stack: jnp.ndarray, t_c: int,
                     ledger: Optional[CommLedger] = None,
                     awake: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """t_c async rounds + exact realized debias: approximates sum_j Z_j.

        ``awake`` optionally injects the (>= t_c, N) availability masks (used
        by the device-vs-host equivalence tests); only the first t_c rows are
        consumed, exactly like the host loop. By default the fused path draws
        them from jax.random and the host path from the NumPy rng.
        """
        if awake is not None and awake.shape[0] < int(t_c):
            raise ValueError(f"awake has {awake.shape[0]} rounds but "
                             f"t_c={t_c}")
        if self.fused:
            return self._run_fused(z_stack, int(t_c), ledger, awake)
        return self._run_host(z_stack, int(t_c), ledger, awake)

    def _run_fused(self, z_stack, t_c, ledger, awake):
        if awake is None:
            awake = self.sample_awake(t_c)
        else:
            awake = awake[:t_c]
        z = jnp.asarray(z_stack, jnp.float32)
        out, sends, counts = _fused_async_run(
            self._w, self._adj, jnp.asarray(awake, bool), z)
        if ledger is not None:
            sends = np.asarray(sends, np.float64)
            payload = float(np.prod(z_stack.shape[1:]))
            ledger.p2p += float(sends.sum())
            ledger.matrices += float(sends.sum())
            ledger.scalars += float(sends.sum()) * payload
            ledger.log_awake_rounds(np.asarray(counts))
        return out

    def _run_host(self, z_stack, t_c, ledger, awake):
        n = self.graph.n_nodes
        off = ~np.eye(n, dtype=bool)
        z = np.asarray(z_stack, np.float64)
        prod = np.eye(n)
        for t in range(t_c):
            if awake is None:
                w, a = self._round_matrix()
            else:
                a = np.asarray(awake[t], bool)
                w = self._apply_mask(a)
            z = np.einsum("ij,j...->i...", w, z)
            prod = w @ prod
            if ledger is not None:
                sends = float(((w > 0) & off).sum())   # off-diag messages
                ledger.p2p += sends
                ledger.matrices += sends
                ledger.scalars += sends * np.prod(z_stack.shape[1:])
                ledger.log_awake_rounds([int(a.sum())])
        p = prod[:, 0]                             # realized [Pi W e_1]_i
        scale = np.where(p > 1e-6, p, 1.0)         # same guard as the scan
        bshape = (-1,) + (1,) * (z_stack.ndim - 1)
        return jnp.asarray(z / scale.reshape(bshape), jnp.float32)


def straggler_wall_clock(*, n_nodes: int, t_round: float, delay: float,
                         rounds_sync: int, rounds_async: int) -> dict:
    """Wall-clock model, one persistent straggler (paper Table V setting).

    Synchronous: every round blocks on the straggler -> (t_round + delay).
    Asynchronous: rounds never block (the straggler is simply asleep while
    busy); it is awake a fraction t_round/(t_round+delay) of rounds.
    """
    sync = rounds_sync * (t_round + delay)
    async_ = rounds_async * t_round
    return {
        "sync_s": sync,
        "async_s": async_,
        "speedup": sync / async_ if async_ else float("inf"),
        "straggler_duty_cycle": t_round / (t_round + delay),
    }
