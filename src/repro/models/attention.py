"""GQA attention: blockwise-XLA path (portable), Pallas path (TPU), KV cache.

The model default is ``blockwise_attention`` — a pure-XLA online-softmax
attention double-scanned over query/key chunks. It never materializes the
(s x s) logits, so its HLO byte traffic matches a flash kernel (this is what
the dry-run rooflines measure), it compiles on any backend, and its chunk
sizes mirror the Pallas BlockSpecs. On TPU the Pallas kernel in
``repro.kernels`` is selected with ``use_pallas=True``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops as kops
from .layers import init_dense, rope

__all__ = ["init_attn", "apply_attn", "init_kv_cache", "blockwise_attention"]

_NEG = -1e30


def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, q_chunk: int = 1024,
                        k_chunk: int = 1024, q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax attention, scanned over chunks. q: (b,h,sq,hd)."""
    b, h, sq, hd = q.shape
    skv = k.shape[2]
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, skv)
    assert sq % q_chunk == 0 and skv % k_chunk == 0
    nq, nk = sq // q_chunk, skv // k_chunk
    scale = hd ** -0.5

    if nq == 1 and nk == 1:
        # single-chunk fast path: same ops as one (q_step, k_step) pass, no
        # scans — less dispatch for short sequences, and the only form whose
        # VJP the legacy (jax<0.5) partial-auto partitioner can partition
        # (scan VJPs CHECK-crash there; see core/compat.py)
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(skv)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None], logits, _NEG)
        m = jnp.maximum(_NEG, logits.max(-1, keepdims=True))
        p = jnp.where(mask[None, None], jnp.exp(logits - m), 0.0)
        l = p.sum(-1, keepdims=True)
        l = jnp.where(l == 0.0, 1.0, l)
        acc = jnp.einsum("bhqk,bhkd->bhqd", p, v,
                         preferred_element_type=jnp.float32)
        return (acc / l).astype(q.dtype)

    qb = q.reshape(b, h, nq, q_chunk, hd).transpose(2, 0, 1, 3, 4)
    kb = k.reshape(b, h, nk, k_chunk, hd).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, h, nk, k_chunk, hd).transpose(2, 0, 1, 3, 4)

    # Chunk indices ride in the scan CARRY (counters), not as iota xs:
    # scanning over a jnp.arange CHECK-crashes the legacy (jax<0.5) SPMD
    # partitioner inside partial-auto shard_map regions (IsManualSubgroup,
    # iota device-group expansion) — see core/compat.py. Counter carries
    # compute the identical positions.
    def q_step(qi, qblk):
        qpos = qi * q_chunk + jnp.arange(q_chunk) + q_offset      # (qc,)
        init = (jnp.int32(0),
                jnp.full((b, h, q_chunk, 1), _NEG, jnp.float32),
                jnp.zeros((b, h, q_chunk, 1), jnp.float32),
                jnp.zeros((b, h, q_chunk, hd), jnp.float32))

        def k_step(carry, kv_blk):
            ki, m, l, acc = carry
            kblk, vblk = kv_blk
            kpos = ki * k_chunk + jnp.arange(k_chunk)              # (kc,)
            logits = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            logits = jnp.where(mask[None, None], logits, _NEG)
            m_new = jnp.maximum(m, logits.max(-1, keepdims=True))
            p = jnp.where(mask[None, None], jnp.exp(logits - m_new), 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + p.sum(-1, keepdims=True)
            acc_new = alpha * acc + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vblk, preferred_element_type=jnp.float32)
            return (ki + 1, m_new, l_new, acc_new), None

        (_, m, l, acc), _ = jax.lax.scan(k_step, init, (kb, vb))
        l = jnp.where(l == 0.0, 1.0, l)
        return qi + 1, (acc / l).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, jnp.int32(0), qb)
    return outs.transpose(1, 2, 0, 3, 4).reshape(b, h, sq, hd)


def init_attn(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = cfg.jnp_dtype
    p = {
        "wq": init_dense(ks[0], d, nq * hd, dt),
        "wk": init_dense(ks[1], d, nkv * hd, dt),
        "wv": init_dense(ks[2], d, nkv * hd, dt),
        "wo": init_dense(ks[3], nq * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int):
    """Stacked-over-layers ring-buffer KV cache for attention layers.

    With ``cfg.kv_quant`` entries are int8 with a per-(token, head) absmax
    scale — half the capacity and read traffic of bf16.
    """
    hd = cfg.hd
    shape = (n_layers, batch, cfg.n_kv_heads, max_len, hd)
    if cfg.kv_quant:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
            "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
        }
    return {
        "k": jnp.zeros(shape, cfg.jnp_dtype),
        "v": jnp.zeros(shape, cfg.jnp_dtype),
    }


def _quantize_kv(x):
    """(b, kv, 1, hd) -> int8 values + f32 absmax scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale * 127.0),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _project_qkv(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    hd = cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_attn(p, x, cfg: ModelConfig, *, window: Optional[int] = None,
               positions=None, cache=None, cache_index=None,
               use_pallas: bool = False, q_chunk: int = 1024,
               k_chunk: int = 1024, act_specs=None):
    """Full-sequence path (cache is None) or single-step decode path.

    Decode: x is (b, 1, d); cache = {"k","v"} slabs (b, nkv, S, hd) for THIS
    layer; cache_index = current length (traced scalar). Returns (out, cache).
    """
    b, s, _ = x.shape
    if positions is None:
        if cache is None:
            positions = jnp.arange(s)[None].repeat(b, 0)
        else:
            positions = jnp.full((b, 1), cache_index, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)

    if cache is None:
        rep = cfg.n_heads // cfg.n_kv_heads
        kf = jnp.repeat(k, rep, axis=1)
        vf = jnp.repeat(v, rep, axis=1)
        if act_specs is not None and act_specs.get("attn_q") is not None:
            q = jax.lax.with_sharding_constraint(q, act_specs["attn_q"])
            kf = jax.lax.with_sharding_constraint(kf, act_specs["attn_kv"])
            vf = jax.lax.with_sharding_constraint(vf, act_specs["attn_kv"])
        if use_pallas:
            out = kops.flash_attention(q, kf, vf, causal=True, window=window)
        else:
            out = blockwise_attention(q, kf, vf, causal=True, window=window,
                                      q_chunk=q_chunk, k_chunk=k_chunk)
        new_cache = None
    else:
        max_len = cache["k"].shape[2]
        # ring-buffer position (SWA uses max_len == window)
        slot = jnp.mod(cache_index, max_len)
        if cfg.kv_quant:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, slot, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, slot, 0))
            cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                               (0, 0, slot, 0))
            cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                               (0, 0, slot, 0))
            kd = ck.astype(jnp.float32) * cks / 127.0
            vd = cv.astype(jnp.float32) * cvs / 127.0
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        else:
            kd = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
            vd = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))
            new_cache = {"k": kd, "v": vd}
        rep = cfg.n_heads // cfg.n_kv_heads
        kf = jnp.repeat(kd, rep, axis=1)
        vf = jnp.repeat(vd, rep, axis=1)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                            kf.astype(jnp.float32)) * (cfg.hd ** -0.5)
        # valid = filled slots only (ring semantics: all slots < min(idx+1, S))
        filled = jnp.minimum(cache_index + 1, max_len)
        valid = jnp.arange(max_len)[None, None, None, :] < filled
        logits = jnp.where(valid, logits, _NEG)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf.astype(jnp.float32))
        out = out.astype(x.dtype)

    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    return out @ p["wo"], new_cache
