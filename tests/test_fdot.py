"""F-DOT (Alg. 2) and the distributed CholeskyQR it relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import DenseConsensus
from repro.core.fdot import distributed_cholesky_qr, fdot
from repro.core.linalg import eigh_topr, orthonormal_init
from repro.core.metrics import subspace_error
from repro.core.topology import erdos_renyi
from repro.data.pipeline import gaussian_eigengap_data, partition_features


@pytest.fixture(scope="module")
def fprob():
    d, r, n_nodes = 20, 5, 10
    x, c, _ = gaussian_eigengap_data(d, 4000, r, 0.7, seed=0)
    _, q_true = eigh_topr(x @ x.T, r)
    blocks = partition_features(x, n_nodes)
    eng = DenseConsensus(erdos_renyi(n_nodes, 0.5, seed=1))
    return dict(d=d, r=r, n_nodes=n_nodes, x=x, blocks=blocks, eng=eng,
                q_true=q_true)


def test_fdot_converges(fprob):
    res = fdot(data_blocks=fprob["blocks"], engine=fprob["eng"], r=fprob["r"],
               t_outer=80, t_c=50, q_true=fprob["q_true"])
    assert res.error_trace[-1] < 1e-5


def test_fdot_blocks_assemble_to_orthonormal(fprob):
    res = fdot(data_blocks=fprob["blocks"], engine=fprob["eng"], r=fprob["r"],
               t_outer=40, t_c=50)
    q = res.q_full
    gram = q.T @ q
    np.testing.assert_allclose(np.asarray(gram), np.eye(fprob["r"]), atol=1e-3)


def test_fdot_uneven_feature_split(fprob):
    """d=20 over 7 nodes: last node gets the remainder slab."""
    blocks = partition_features(fprob["x"], 7)
    assert sum(b.shape[0] for b in blocks) == fprob["d"]
    eng = DenseConsensus(erdos_renyi(7, 0.6, seed=2))
    res = fdot(data_blocks=blocks, engine=eng, r=fprob["r"], t_outer=80,
               t_c=50, q_true=fprob["q_true"])
    assert res.error_trace[-1] < 1e-5


def test_fdot_single_feature_per_node():
    """The paper's Fig. 6 setting: d == N, one feature per node."""
    d = r = None
    n_nodes = 10
    x, c, _ = gaussian_eigengap_data(n_nodes, 2000, 3, 0.5, seed=5)
    _, q_true = eigh_topr(x @ x.T, 3)
    blocks = partition_features(x, n_nodes)
    assert all(b.shape[0] == 1 for b in blocks)
    eng = DenseConsensus(erdos_renyi(n_nodes, 0.5, seed=6))
    res = fdot(data_blocks=blocks, engine=eng, r=3, t_outer=100, t_c=50,
               q_true=q_true)
    assert res.error_trace[-1] < 1e-5


def test_distributed_cholesky_qr_orthonormalizes(fprob):
    rng = np.random.default_rng(3)
    dims = [2, 3, 1, 4, 2, 3, 2, 1, 1, 1]
    v_blocks = [jnp.asarray(rng.standard_normal((di, 4)), jnp.float32) * 3.0
                for di in dims]
    out = distributed_cholesky_qr(v_blocks, fprob["eng"], t_c=120)
    q = jnp.concatenate(out, 0)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(4), atol=1e-4)
    # span preserved (2e-6: the fp32 gossip/QR chain lands within a hair of
    # 1e-6 on some BLAS builds — observed 1.04e-6 on this container's seed)
    v = jnp.concatenate(v_blocks, 0)
    assert float(subspace_error(jnp.linalg.qr(v)[0], q)) < 2e-6


def test_distributed_qr_single_pass_worse_than_two(fprob):
    rng = np.random.default_rng(4)
    # ill-conditioned V stresses CholeskyQR; pass 2 should fix orthogonality
    base = rng.standard_normal((20, 4))
    base[:, 3] = base[:, 0] + 1e-3 * base[:, 3]
    blocks = [jnp.asarray(base[i * 2:(i + 1) * 2], jnp.float32) for i in range(10)]
    q1 = jnp.concatenate(
        distributed_cholesky_qr(blocks, fprob["eng"], t_c=200, passes=1), 0)
    q2 = jnp.concatenate(
        distributed_cholesky_qr(blocks, fprob["eng"], t_c=200, passes=2), 0)
    e1 = float(jnp.abs(q1.T @ q1 - jnp.eye(4)).max())
    e2 = float(jnp.abs(q2.T @ q2 - jnp.eye(4)).max())
    assert e2 <= e1 + 1e-7
    assert e2 < 1e-4


def test_fdot_ledger_counts(fprob):
    res = fdot(data_blocks=fprob["blocks"], engine=fprob["eng"], r=fprob["r"],
               t_outer=5, t_c=10)
    edges = fprob["eng"].graph.adjacency.sum()
    # per outer iter: t_c rounds for the (n x r) product + 2 QR passes x t_c
    assert res.ledger.p2p == 5 * (10 + 2 * 10) * edges
