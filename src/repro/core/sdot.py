"""S-DOT and SA-DOT — sample-wise distributed orthogonal iteration (Alg. 1).

The two algorithms share one implementation; they differ only in the
per-outer-iteration consensus budget ``schedule`` (constant for S-DOT,
increasing for SA-DOT — see ``consensus_schedule``).

Engines:
  * ``sdot`` — simulation over an explicit graph (DenseConsensus). All N node
    states are carried as a stacked (N, d, r) array; this is what reproduces
    the paper's tables.
  * ``sdot_spmd_step`` — the building block used when node == TPU pod; exact
    psum intra-pod, gossip inter-pod (see optim/psa_compress.py).

Execution modes (``fused`` flag):
  * fused (default) — the ENTIRE run is one jitted ``lax.scan`` over outer
    iterations: per-iteration consensus budgets are read from the schedule
    array, the inner gossip is a masked scan (so varying T_{c,t} stays
    traceable), debiasing indexes a precomputed device table of W^t e_1
    rows, and the error trace is computed on device and returned as one
    (T_o,) array. Zero host syncs per iteration, one compile per
    (shapes, t_max) signature, communication accounted in closed form.
    With an ``AsyncConsensus`` engine the whole straggler run is ALSO one
    scan: the RNG key rides in the scan carry, each outer iteration draws
    its (t_max, N) awake-mask block and runs masked realized-matrix gossip
    (exact realized debias), and the per-round send/awake counts come back
    as stacked scan outputs — one dispatch for a whole Table-V run.
  * eager (``fused=False``) — the original Python loop, one dispatch chain
    per outer iteration. Kept as the bit-level correctness oracle
    (tests/test_sdot_fused.py) and for step-by-step debugging. With an
    async engine the eager loop draws the same padded (t_max, N) mask
    blocks, so seeded eager runs replay the fused executor round for round.

``sdot_spmd`` is the node == TPU-pod twin of the fused executor: the same
whole-run scan runs *inside* shard_map over a mesh axis (masked
ppermute/all_gather gossip + the device debias table), so a multi-pod run is
one compiled SPMD program instead of one collective dispatch per iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import runtime
from .async_gossip import masked_async_rounds
from .compat import shard_map
from .consensus import (DenseConsensus, consensus_schedule, debias_table,
                        debiased_gossip)
from .netfaults import (masked_faulty_rounds, realized_debias,
                        sample_fault_blocks)
from .linalg import cholesky_qr2, orthonormal_init
from .metrics import CommLedger, mean_subspace_error, subspace_error
from ..kernels import ops as kops

__all__ = ["SDOTResult", "sdot", "sadot", "sdot_program", "sdot_spmd",
           "local_cov_apply"]


@dataclasses.dataclass
class SDOTResult:
    q_nodes: jnp.ndarray            # (N, d, r) final per-node estimates
    error_trace: Optional[np.ndarray]   # (T_o,) mean subspace error vs q_true
    consensus_trace: np.ndarray     # (T_o,) consensus rounds used per outer iter
    ledger: CommLedger              # communication accounting

    @property
    def q_mean(self) -> jnp.ndarray:
        """Consensus-averaged estimate (for reporting; nodes already agree)."""
        return self.q_nodes.mean(axis=0)


def local_cov_apply(covs: jnp.ndarray, q_nodes: jnp.ndarray) -> jnp.ndarray:
    """Step 5 of Alg. 1 at every node: Z_i = M_i Q_i. covs: (N,d,d)."""
    return jnp.einsum("nde,ner->ndr", covs, q_nodes)


def _stack_data(xs: Sequence[jnp.ndarray]):
    """Zero-pad ragged node blocks (d, n_i) to one (N, d, n_max) stack.

    Padding is exact for the gram apply (padded columns are null in both
    matmuls); the true n_i go along for the normalizer.
    """
    n_true = np.array([x.shape[1] for x in xs], np.float32)
    n_max = int(n_true.max())
    stack = jnp.stack([
        jnp.pad(x, ((0, 0), (0, n_max - x.shape[1]))) for x in xs])
    return stack, jnp.asarray(n_true)


def _apply_operand(operand, mode: str, q_nodes):
    """Step 5 of Alg. 1 for either operand layout (cov stack or raw data).

    The data mode is gram-free — Z_i = X_i (X_i^T Q_i), never forming the
    (d x d) M_i — and serves all nodes with ONE batched gram-apply dispatch
    (Pallas (node, column-block) kernel on TPU, fused einsum elsewhere)
    instead of a per-node Python loop; both the fused scan body and the
    eager loop call through here.
    """
    if mode == "cov":
        return local_cov_apply(operand, q_nodes)
    x_stack, n_true = operand
    return kops.batched_gram_apply(x_stack, q_nodes, n_true)


def _sync_outer_body(operand, w, table, q_true, node_mask, *, mode: str,
                     t_max: int, trace_err: bool):
    """Build the per-outer-iteration body ``(q_nodes, t_c) -> (q_new, err)``.

    ONE definition feeds every runtime driver (monolithic, chunked, sweep —
    via ``_sdot_build_body``), so a run split at arbitrary chunk boundaries
    replays the monolithic scan bit for bit — the math cannot drift between
    the callers.
    """

    def outer(q_nodes, t_c):
        z0 = _apply_operand(operand, mode, q_nodes)              # (N, d, r)
        v = debiased_gossip(w, table, z0, t_c, t_max)
        q_new = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)      # per-node QR
        err = (mean_subspace_error(q_true, q_new, node_mask) if trace_err
               else jnp.float32(0.0))
        return q_new, err

    return outer


def _async_outer_body(operand, w, adj, p_awake, q_true, *, mode: str,
                      t_max: int, trace_err: bool):
    """Async twin of ``_sync_outer_body``: carry is ``(q_nodes, rng key)``.

    Each call splits the key, draws the iteration's (t_max, N) awake-mask
    block, and runs realized-matrix gossip — the key ride in the carry is
    exactly what makes chunked resume exact for straggler runs: checkpointing
    the carried key restores the stream mid-run with no replay.
    """
    n = w.shape[0]

    def outer(carry, t_c):
        q_nodes, key = carry
        key, sub = jax.random.split(key)
        awake = jax.random.bernoulli(sub, p_awake, (t_max, n))
        z0 = _apply_operand(operand, mode, q_nodes)              # (N, d, r)
        v, sends, counts = masked_async_rounds(w, adj, awake, t_c, z0)
        q_new = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)
        err = (mean_subspace_error(q_true, q_new) if trace_err
               else jnp.float32(0.0))
        return (q_new, key), (err, sends, counts)

    return outer


def _faulty_outer_body(operand, w, adj, params, node_up_sched, table,
                       q_true, *, mode: str, t_max: int, trace_err: bool,
                       debias: str):
    """Network-fault twin of ``_async_outer_body``: the carry is
    ``((q_nodes, ge, t), key)``.

    Each outer iteration splits the key, pre-samples its (t_max, N, N) /
    (t_max, N) fault blocks (the edge-mask twin of the awake-mask draw),
    reads the iteration's crash mask from the (T, N) ``node_up_sched``
    operand via the carried iteration counter ``t``, and runs realized
    edge-mask gossip. The Gilbert–Elliott state ``ge`` and the counter ride
    in the carry, so chunked resume replays bursts and crash windows
    exactly. Crashed nodes contribute no edges and their iterate is FROZEN
    (the QR update is masked), so on rejoin they re-sync from neighbors
    through ordinary gossip. ``debias``: "realized" divides by the carried
    realized mixing product (self-healing); "nominal" divides by the
    fault-free W^t e_1 table row (the uncorrected benchmark arm).
    """
    n = w.shape[0]

    def outer(carry, t_c):
        (q_nodes, ge, t), key = carry
        key, sub = jax.random.split(key)
        blocks = sample_fault_blocks(sub, n, t_max)
        node_up = jnp.take(node_up_sched, t, axis=0)             # (N,)
        z0 = _apply_operand(operand, mode, q_nodes)              # (N, d, r)
        z, p, ge_new, sends, counts = masked_faulty_rounds(
            w, adj, params, node_up, ge, blocks, t_c, z0)
        if debias == "realized":
            v = realized_debias(z, p)
        else:
            row = jnp.take(table, t_c, axis=0)
            v = z / row.astype(z.dtype).reshape((-1,) + (1,) * (z.ndim - 1))
        q_qr = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)
        up = node_up.reshape((-1,) + (1,) * (q_nodes.ndim - 1)) > 0
        q_new = jnp.where(up, q_qr, q_nodes)                     # freeze
        err = (mean_subspace_error(q_true, q_new) if trace_err
               else jnp.float32(0.0))
        return ((q_new, ge_new, t + 1), key), (err, sends, counts)

    return outer


def _sdot_build_body(operands, *, mode: str, t_max: int, trace_err: bool,
                     is_async: bool, is_faulty: bool = False,
                     debias: str = "realized"):
    """Runtime body builder for S-DOT/SA-DOT (the Program protocol's
    ``build_body``) — a thin adapter over the SAME outer-iteration bodies
    the executors have always used, so every driver (monolithic, chunked,
    sweep) steps through identical per-iteration math."""
    if mode == "cov":
        op, rest = operands[0], operands[1:]
    else:
        op, rest = (operands[0], operands[1]), operands[2:]
    if is_faulty:
        w, adj, params, node_up_sched, table, q_true = rest
        return _faulty_outer_body(op, w, adj, params, node_up_sched, table,
                                  q_true, mode=mode, t_max=t_max,
                                  trace_err=trace_err, debias=debias)
    if is_async:
        w, adj, p_awake, q_true = rest
        return _async_outer_body(op, w, adj, p_awake, q_true, mode=mode,
                                 t_max=t_max, trace_err=trace_err)
    w, table, q_true, node_mask = rest
    return runtime.sync_body(
        _sync_outer_body(op, w, table, q_true, node_mask, mode=mode,
                         t_max=t_max, trace_err=trace_err))


def sdot_program(
    *,
    covs=None,
    data: Optional[Sequence[jnp.ndarray]] = None,
    engine,
    r: int,
    t_outer: int,
    schedule: Optional[np.ndarray] = None,
    t_c: int = 50,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
) -> runtime.Program:
    """Register an S-DOT/SA-DOT run with the unified executor runtime.

    Built from the same ``_prepare_sdot`` pieces as the eager oracle, so a
    Program run under any driver starts from literally the same device
    values. ``runtime.run_monolithic`` reproduces ``sdot(fused=True)``;
    ``runtime.run_chunked`` is the restartable twin (streaming/resume.py).
    """
    prep = _prepare_sdot(covs=covs, data=data, engine=engine, r=r,
                         t_outer=t_outer, schedule=schedule, t_c=t_c,
                         q_init=q_init, q_true=q_true, seed=seed)
    n, d = prep["n"], prep["d"]
    t_max, trace_err, q_arg = prep["t_max"], prep["trace_err"], prep["q_arg"]
    sched_np = prep["sched_np"]
    is_async = prep["is_async"]
    is_faulty = prep["is_faulty"]
    mode = prep["mode"]
    debias = engine.debias if is_faulty else "realized"
    q0 = prep["q_nodes"]
    op_flat = ((prep["operand"],) if mode == "cov" else
               tuple(prep["operand"]))
    if is_faulty:
        node_up_sched = jnp.asarray(
            engine.faults.validate(n, t_outer).node_up(t_outer, n))
        operands = op_flat + (engine._w, engine._adj, engine._params,
                              node_up_sched, debias_table(engine._w, t_max),
                              q_arg)
        key0, tail = engine._key, (t_max,)
        q0 = (q0, engine._ge, jnp.int32(0))
    elif is_async:
        operands = op_flat + (engine._w, engine._adj,
                              jnp.asarray(engine.p_awake, jnp.float32),
                              q_arg)
        key0, tail = engine._key, (t_max,)
    else:
        if not hasattr(engine, "debias_table"):
            raise ValueError("fused S-DOT needs a fused-capable engine "
                             "(debias_table) or an async engine")
        operands = op_flat + (engine._w, engine.debias_table(t_max), q_arg,
                              jnp.ones((n,), jnp.float32))
        key0, tail = None, ()
    payload = d * r

    def finalize(state: runtime.RunState, done: int) -> SDOTResult:
        q_nodes = state.q[0] if is_faulty else state.q
        if is_async or is_faulty:
            if done == t_outer:
                engine._key = state.key   # same stream position as eager
                if is_faulty:
                    engine._ge = state.q[1]   # burst state carries over too
            ledger = runtime.async_ledger(
                sched_np[:done], state.sends[:done], state.counts[:done],
                lambda s: float(s.sum()) * payload,
                lambda t_c_t: [(slice(None), t_c_t)])
        else:
            ledger = CommLedger()
            ledger.log_gossip_rounds(sched_np[:done],
                                     engine.graph.adjacency, payload,
                                     bytes_per_elem=getattr(
                                         engine, "payload_bytes_per_elem",
                                         4.0))
        return SDOTResult(
            q_nodes=q_nodes,
            error_trace=(np.asarray(state.errs[:done]) if trace_err
                         else None),
            consensus_trace=sched_np[:done],
            ledger=ledger,
        )

    return runtime.Program(
        build_body=_sdot_build_body,
        operands=operands,
        statics=(("mode", mode), ("t_max", t_max), ("trace_err", trace_err),
                 ("is_async", is_async), ("is_faulty", is_faulty),
                 ("debias", debias)),
        xs=sched_np,
        q0=q0,
        key0=key0,
        tail=tail,
        finalize=finalize,
    )


def _prepare_sdot(*, covs, data, engine, r, t_outer, schedule, t_c, q_init,
                  q_true, seed):
    """Validate + normalize a run's inputs into device-ready pieces.

    Shared by ``sdot`` and the chunked streaming executor
    (``streaming/resume.py``): both construct the operand stack, schedule
    array, debias-table bounds, and initial iterate through this one helper,
    so a chunked run starts from literally the same device values as the
    monolithic one. Returns a dict of run pieces.
    """
    if (covs is None) == (data is None):
        raise ValueError("provide exactly one of covs / data")
    n = engine.graph.n_nodes
    if covs is not None:
        d = covs.shape[1]
        if covs.shape[0] != n:
            raise ValueError("covs leading dim must equal number of nodes")
    else:
        d = data[0].shape[0]
        if len(data) != n:
            raise ValueError("need one data block per node")

    if schedule is None:
        schedule = consensus_schedule("const", t_outer, t_max=t_c)
    elif len(schedule) < t_outer:
        # fail loudly: the fused scan would silently truncate the run and
        # the eager loop would IndexError mid-flight
        raise ValueError(f"schedule has {len(schedule)} entries but "
                         f"t_outer={t_outer}")
    if q_init is None:
        q_init = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    # all nodes start from the same Q_init (Theorem 1 requires it)
    q_nodes = jnp.broadcast_to(q_init[None], (n, d, r))

    is_faulty = hasattr(engine, "sample_faults")
    is_async = (not is_faulty) and hasattr(engine, "sample_awake")
    sched_np = np.asarray(schedule[:t_outer])
    t_max = int(sched_np.max()) if t_outer else 0
    trace_err = q_true is not None
    q_arg = q_true if trace_err else jnp.zeros((d, r), q_nodes.dtype)
    if covs is not None:
        operand, mode = covs, "cov"
    else:
        operand, mode = _stack_data(data), "data"
    return dict(
        n=n, d=d, operand=operand, mode=mode, q_nodes=q_nodes,
        schedule=schedule, sched_np=sched_np,
        sched_dev=jnp.asarray(sched_np, jnp.int32), t_max=t_max,
        trace_err=trace_err, q_arg=q_arg, is_async=is_async,
        is_faulty=is_faulty,
    )


def sdot(
    *,
    covs: Optional[jnp.ndarray] = None,
    data: Optional[Sequence[jnp.ndarray]] = None,
    engine: DenseConsensus,
    r: int,
    t_outer: int,
    schedule: Optional[np.ndarray] = None,
    t_c: int = 50,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
    fused: bool = True,
) -> SDOTResult:
    """Run S-DOT / SA-DOT over a simulated network.

    Exactly one of ``covs`` (N, d, d) or ``data`` (list of (d, n_i)) must be
    given. ``schedule`` overrides ``t_c`` (constant) and makes this SA-DOT.
    ``fused=True`` (default) executes the whole run as a single compiled
    scan (a thin shim over ``runtime.run_monolithic``); ``fused=False`` is
    the eager per-iteration oracle.
    """
    # async / faulty engines get their own whole-run scan (the RNG key —
    # and for faults the Gilbert–Elliott state — rides in the carry); any
    # other engine without the scan interface runs eagerly
    if fused and (hasattr(engine, "sample_awake")
                  or hasattr(engine, "sample_faults")
                  or hasattr(engine, "debias_table")):
        return runtime.run_monolithic(sdot_program(
            covs=covs, data=data, engine=engine, r=r, t_outer=t_outer,
            schedule=schedule, t_c=t_c, q_init=q_init, q_true=q_true,
            seed=seed))

    prep = _prepare_sdot(covs=covs, data=data, engine=engine, r=r,
                         t_outer=t_outer, schedule=schedule, t_c=t_c,
                         q_init=q_init, q_true=q_true, seed=seed)
    operand, mode = prep["operand"], prep["mode"]
    q_nodes, schedule = prep["q_nodes"], prep["schedule"]
    t_max = prep["t_max"]
    is_async = prep["is_async"]
    is_faulty = prep["is_faulty"]
    if is_faulty:
        n = engine.graph.n_nodes
        node_up_sched = engine.faults.validate(n, t_outer).node_up(
            t_outer, n)

    ledger = CommLedger()
    errs = [] if q_true is not None else None
    for t in range(t_outer):
        z0 = _apply_operand(operand, mode, q_nodes)               # (N, d, r)
        if is_faulty:
            # draw with the fused executor's padded shape so a seeded
            # eager run replays the fused scan fault for fault
            blocks = engine.sample_faults(int(schedule[t]), t_max=t_max)
            node_up = node_up_sched[t]
            v = engine.run_debiased(z0, int(schedule[t]), ledger,
                                    faults=blocks, node_up=node_up)
            q_qr = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)
            up = node_up.reshape((-1,) + (1,) * (q_nodes.ndim - 1)) > 0
            q_nodes = jnp.where(up, q_qr, q_nodes)   # crashed nodes freeze
            if errs is not None:
                e = jax.vmap(lambda qq: subspace_error(q_true, qq))(q_nodes)
                errs.append(float(e.mean()))
            continue
        if is_async:
            # draw with the fused executor's padded shape so a seeded
            # eager run replays the fused scan round for round
            awake = engine.sample_awake(int(schedule[t]), t_max=t_max)
            v = engine.run_debiased(z0, int(schedule[t]), ledger,
                                    awake=awake)
        else:
            v = engine.run_debiased(z0, int(schedule[t]), ledger)
        q_nodes = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)
        if errs is not None:
            e = jax.vmap(lambda qq: subspace_error(q_true, qq))(q_nodes)
            errs.append(float(e.mean()))
    error_trace = np.asarray(errs) if errs is not None else None

    return SDOTResult(
        q_nodes=q_nodes,
        error_trace=error_trace,
        consensus_trace=np.asarray(schedule[:t_outer]),
        ledger=ledger,
    )


def sadot(*, schedule_kind: str = "lin2", cap: Optional[int] = None,
          t_outer: int, **kw) -> SDOTResult:
    """SA-DOT convenience wrapper: increasing consensus schedule."""
    sched = consensus_schedule(schedule_kind, t_outer, cap=cap)
    return sdot(t_outer=t_outer, schedule=sched, **kw)


def sdot_spmd(
    *,
    covs: jnp.ndarray,
    engine,                                   # consensus.SpmdConsensus
    r: int,
    t_outer: int,
    schedule: Optional[np.ndarray] = None,
    t_c: int = 50,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
) -> SDOTResult:
    """Whole-run S-DOT/SA-DOT as ONE compiled SPMD program over a mesh axis.

    The node == pod execution mode: node i's covariance block lives on mesh
    position i along ``engine.axis`` and the entire t_outer loop — local
    apply, masked collective gossip (``SpmdConsensus.gossip_rounds_masked``:
    weighted ppermute rounds on a ring, all_gather + local mix otherwise),
    the device debias-table row gather, per-node CholeskyQR2, and the
    pmean'd error trace — runs inside a single jitted shard_map. One compile
    and one dispatch per run instead of one collective chain per outer
    iteration; numerically identical to the fused ``DenseConsensus`` run
    for the same W (tests/test_spmd.py pins it).
    """
    n = engine.n
    if covs.shape[0] != n:
        raise ValueError("covs leading dim must equal the mesh axis size")
    d = covs.shape[1]
    if schedule is None:
        schedule = consensus_schedule("const", t_outer, t_max=t_c)
    elif len(schedule) < t_outer:
        raise ValueError(f"schedule has {len(schedule)} entries but "
                         f"t_outer={t_outer}")
    sched_np = np.asarray(schedule[:t_outer])
    t_max = int(sched_np.max()) if t_outer else 0
    if q_init is None:
        q_init = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    q_nodes = jnp.broadcast_to(q_init[None], (n, d, r))
    trace_err = q_true is not None
    q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
    table = engine.debias_table(t_max)
    sched_dev = jnp.asarray(sched_np, jnp.int32)

    def local_fn(cov, q0, sched, tab, qt):
        # cov/q0: (1, d, d) / (1, d, r) local blocks; sched/tab/qt replicated
        def outer(q, tc):
            z = cov[0] @ q
            z = engine.gossip_rounds_masked(z, tc, t_max)
            z = engine.debias_by_table(z, tab, tc)
            q_new = cholesky_qr2(z)[0]
            err = (jax.lax.pmean(subspace_error(qt, q_new), engine.axis)
                   if trace_err else jnp.float32(0.0))
            return q_new, err

        qf, errs = jax.lax.scan(outer, q0[0], sched)
        return qf[None], errs

    spec, rep = P(engine.axis), P()
    fn = shard_map(local_fn, mesh=engine.mesh,
                   in_specs=(spec, spec, rep, rep, rep),
                   out_specs=(spec, rep))
    q_nodes, errs = jax.jit(fn)(covs, q_nodes, sched_dev, table, q_arg)

    ledger = CommLedger()
    ledger.log_gossip_rounds(sched_np, engine.graph.adjacency, d * r)
    return SDOTResult(
        q_nodes=q_nodes,
        error_trace=np.asarray(errs) if trace_err else None,
        consensus_trace=sched_np,
        ledger=ledger,
    )
