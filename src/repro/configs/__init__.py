"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib
from typing import Dict

from .base import SHAPES, ModelConfig, PSAConfig, ShapeConfig  # noqa: F401

_ARCH_MODULES = {
    "xlstm-1.3b": "xlstm_1_3b",
    "internlm2-20b": "internlm2_20b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "command-r-35b": "command_r_35b",
    "qwen2-7b": "qwen2_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "paligemma-3b": "paligemma_3b",
    "musicgen-medium": "musicgen_medium",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_arch(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; one of {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f".{_ARCH_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def get_shape(shape_id: str) -> ShapeConfig:
    return SHAPES[shape_id]


def get_psa_config() -> PSAConfig:
    mod = importlib.import_module(".paper_psa", __package__)
    return mod.CONFIG


def valid_cells():
    """All 40 (arch, shape) cells with their run/skip status.

    long_500k is skipped for pure full-attention archs (needs sub-quadratic
    token mixing — see DESIGN.md §Arch-applicability); a skip is recorded,
    not silently dropped.
    """
    cells = []
    for aid in ARCH_IDS:
        cfg = get_arch(aid)
        for sid, shp in SHAPES.items():
            skip = (sid == "long_500k" and not cfg.subquadratic)
            reason = "full-attention arch: 500k decode cache infeasible" if skip else ""
            cells.append({"arch": aid, "shape": sid, "skip": skip, "reason": reason})
    return cells


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    import dataclasses
    small = dict(
        n_layers=len(cfg.block_pattern),
        d_model=64,
        n_heads=max(2, min(cfg.n_heads, 4)),
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128 if cfg.d_ff > 0 else 0,
        vocab_size=256,
        head_dim=16 if cfg.head_dim is not None else None,
        window=min(cfg.window, 32) if cfg.window else None,
        mlstm_chunk=16,
        n_prefix_tokens=4 if cfg.n_prefix_tokens else 0,
        dtype="float32",
    )
    if cfg.moe is not None:
        import dataclasses as dc
        small["moe"] = dc.replace(cfg.moe, n_experts=4, top_k=2, d_expert=64,
                                  n_shared_experts=min(cfg.moe.n_shared_experts, 1))
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
