"""Fault-tolerant checkpointing with elastic re-shard on restore.

Design (no tensorstore in this container, so .npz shards + a JSON manifest):

* **Atomicity**: a checkpoint directory is written under ``step_<n>.tmp`` and
  os.rename'd into place only after every shard and the manifest have been
  fsync'd — a job killed mid-write can never leave a "latest" that is
  half-written, so restart always finds a valid step.
* **Elasticity**: ``restore(..., mesh=new_mesh, specs=...)`` re-shards on
  load via jax.device_put against the *new* mesh — the saved artifact is
  mesh-agnostic (full arrays per leaf), so a job can come back on a different
  device count (scale up/down after node failures).
* **Retention**: keep_last prunes old steps; a corrupt/partial dir (no
  manifest) is ignored by ``latest_step`` and garbage-collected.
* **Async**: ``save(..., blocking=False)`` runs serialization in a worker
  thread so the train loop's critical path only pays for the host transfer.

On a real multi-host fleet each host writes only its addressable shards and
the manifest records the global shape/sharding — the single-process container
degenerates to full arrays, same format.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from ..obs import get_journal

__all__ = ["CheckpointManager", "save_tree", "restore_tree"]

_MANIFEST = "manifest.json"


def _to_npz_safe(arr: np.ndarray) -> np.ndarray:
    """npz cannot store ml_dtypes (bf16, fp8); persist those as flat bytes
    (shape+dtype live in the manifest)."""
    if arr.dtype.kind in "biufc":   # standard numeric dtypes round-trip
        return arr
    return np.ascontiguousarray(arr).reshape(-1).view(np.uint8)


def _from_npz_safe(arr: np.ndarray, dtype_name: str, shape) -> np.ndarray:
    if arr.dtype.name == dtype_name:      # stored natively
        return arr
    import ml_dtypes  # jax dependency, always present
    dt = np.dtype(getattr(ml_dtypes, dtype_name))
    return arr.view(dt).reshape(shape)


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_tree(path: str, tree: Any, step: int) -> None:
    """Atomic write of a pytree snapshot into ``path`` (a step directory).

    The tmp dir is writer-unique (pid-suffixed) so two fenced writers — a
    lease victim and the worker that stole its shard — never collide on the
    staging dir; shard results are deterministic, so whichever rename lands
    last publishes the same bits."""
    tmp = f"{path}.tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    arrs = {f"leaf_{i}": _to_npz_safe(h) for i, h in enumerate(host)}
    np.savez(os.path.join(tmp, "shards.npz"), **arrs)
    manifest = {
        "step": step,
        "names": names,
        "dtypes": [h.dtype.name for h in host],
        "shapes": [list(np.shape(l)) for l in leaves],
        "format": 1,
    }
    mpath = os.path.join(tmp, _MANIFEST)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    try:
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)    # atomic publish
    except OSError:
        # a concurrent fenced writer won the rename; its snapshot is
        # byte-equivalent (deterministic recompute), so losing the race IS
        # a successful publish — drop our staging dir and move on
        if os.path.exists(os.path.join(path, _MANIFEST)):
            shutil.rmtree(tmp, ignore_errors=True)
        else:
            raise


def restore_tree(path: str, like: Any, *, mesh=None, specs=None) -> Any:
    """Load a snapshot; optionally re-shard onto ``mesh`` with ``specs``.

    ``like`` provides the pytree structure (its leaf values are ignored).
    """
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shards.npz"))
    names, _, treedef = _flatten_with_names(like)
    if names != manifest["names"]:
        raise ValueError(
            "checkpoint tree mismatch:\n saved=%s\n want=%s"
            % (manifest["names"][:5], names[:5]))
    leaves = [_from_npz_safe(data[f"leaf_{i}"], manifest["dtypes"][i],
                             manifest["shapes"][i])
              for i in range(len(names))]
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        spec_flat = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: x is None or isinstance(x, PartitionSpec))[0]
        if len(spec_flat) != len(leaves):
            raise ValueError("spec tree does not match checkpoint tree")
        leaves = [
            jax.device_put(leaf, NamedSharding(mesh, sp)) if sp is not None
            else jax.device_put(leaf)
            for leaf, sp in zip(leaves, spec_flat)]
    else:
        leaves = [_put_preserving_dtype(l) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _put_preserving_dtype(leaf: np.ndarray):
    """device_put unless it would silently change the saved dtype.

    With jax x64 disabled, device_put downcasts float64/int64 leaves to
    32-bit — which corrupted e.g. restored CommLedger counters above 2^24.
    Such leaves stay as host numpy arrays at their manifest dtype; callers
    that need them on device opted into 32-bit anyway."""
    out = jax.device_put(leaf)
    return leaf if out.dtype != leaf.dtype else out


class CheckpointManager:
    """Directory layout: <root>/step_<n>/{shards.npz, manifest.json}.

    ``on_save`` (optional) is invoked synchronously with the step number at
    the top of every ``save`` — the chunk-boundary hook the fleet uses for
    heartbeat touches, lease renewals, and chaos injection, with no
    branches in the runtime's chunk driver.

    ``pin(step)`` / ``unpin(step)`` exempt a step from ``keep_last``
    retention: a pinned step is never garbage-collected, however many newer
    steps churn past it. Pins are durable marker files (``pin_<n>``) in the
    root — a restarted process (or a different one sharing the directory)
    sees them — which is what lets the serving layer keep its "last good
    served subspace" alive while per-tick service snapshots cycle."""

    def __init__(self, root: str, keep_last: int = 3, on_save=None):
        self.root = root
        self.keep_last = keep_last
        self.on_save = on_save
        os.makedirs(root, exist_ok=True)
        self._worker: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def _pin_path(self, step: int) -> str:
        return os.path.join(self.root, f"pin_{step:08d}")

    # -- retention pins -----------------------------------------------------
    def pin(self, step: int) -> None:
        """Exempt ``step`` from GC until ``unpin`` (durable across restarts)."""
        with open(self._pin_path(step), "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        get_journal().event("ckpt_pin", "checkpoint", step=step)

    def unpin(self, step: int) -> None:
        try:
            os.remove(self._pin_path(step))
        except FileNotFoundError:
            return
        get_journal().event("ckpt_unpin", "checkpoint", step=step)

    def pinned_steps(self):
        out = []
        for name in os.listdir(self.root):
            if name.startswith("pin_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def all_steps(self):
        out = []
        for name in os.listdir(self.root):
            full = os.path.join(self.root, name)
            # ".tmp" anywhere excludes both legacy "step_N.tmp" staging
            # dirs and the writer-unique "step_N.tmp-<pid>" form; a torn
            # dir (no manifest — e.g. chaos deleted it mid-step) is
            # skipped the same way so latest_step never lands on it
            if name.startswith("step_") and ".tmp" not in name \
                    and os.path.exists(os.path.join(full, _MANIFEST)):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        # The span OPENS before on_save fires: a chaos kill injected at the
        # boundary hook leaves an orphaned span_start in the journal, which
        # is exactly how forensics names the phase the worker died in. The
        # span covers the caller-visible critical path — for async saves
        # that is the host transfer + thread handoff, not the write itself
        # (the worker thread journals ckpt_write when it lands).
        sp = get_journal().begin("ckpt_save", "checkpoint", step=step,
                                 blocking=blocking)
        self.wait()  # never two writers
        if self.on_save is not None:
            self.on_save(step)
        if blocking:
            self._save(step, tree)
        else:
            host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
            self._worker = threading.Thread(
                target=self._save, args=(step, host_tree, True), daemon=True)
            self._worker.start()
        sp.end()

    def _save(self, step: int, tree: Any, async_write: bool = False) -> None:
        save_tree(self._step_dir(step), tree, step)
        if async_write:
            # only the async path marks write completion separately — it
            # lands after the caller's ckpt_save span closed; a blocking
            # save's span end IS the completion record
            get_journal().event("ckpt_write", "checkpoint", step=step)
        self._gc()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def restore(self, like: Any, step: Optional[int] = None, *,
                mesh=None, specs=None):
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        with get_journal().span("ckpt_restore", "checkpoint", step=step):
            tree = restore_tree(self._step_dir(step), like, mesh=mesh,
                                specs=specs)
        return tree, step

    def _gc(self) -> None:
        # remove stale tmp dirs (crashed writers, any ".tmp"/".tmp-<pid>"
        # suffix) and old steps
        for name in os.listdir(self.root):
            if ".tmp" in name:
                shutil.rmtree(os.path.join(self.root, name), ignore_errors=True)
        steps = self.all_steps()
        pinned = set(self.pinned_steps())
        removed = []
        for s in steps[:-self.keep_last] if self.keep_last else []:
            if s in pinned:
                continue   # pinned steps survive keep_last churn
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
            removed.append(s)
        if removed:
            get_journal().event("ckpt_gc", "checkpoint", removed=removed,
                                pinned=sorted(pinned))
