"""Streaming subsystem: online ingestion, chunked crash-resume, launcher.

The load-bearing assertions are *bitwise*: a run checkpointed and restored
at any chunk boundary must reproduce the uninterrupted fused run's error
trace, final iterate, and comm ledger exactly — including the async
straggler RNG carry. The launcher's merged multi-process sweep must match
the single-process sweep at float32 epsilon (XLA may schedule a width-1
vmap lane-slice differently; everything else is identical arithmetic).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import baselines as B
from repro.core.async_gossip import AsyncConsensus
from repro.core.bdot import bdot
from repro.core.consensus import DenseConsensus
from repro.core.fdot import fdot
from repro.core.linalg import eigh_topr
from repro.core.metrics import CommLedger
from repro.core.sdot import sdot
from repro.core.sweep import sdot_sweep
from repro.core.topology import complete, erdos_renyi, ring
from repro.data.pipeline import (eigengap_stream, partition_features,
                                 partition_samples)
from repro.streaming.ingest import (CovSketch, FrequentDirections,
                                    StreamingIngestor)
from repro.streaming.launcher import (build_engine, build_schedule,
                                      launch_sweep)
from repro.streaming.resume import (RunState, baseline_chunked, bdot_chunked,
                                    fdot_chunked, sdot_chunked)

D, R, N = 14, 3, 6
T_OUTER, T_C, CHUNK = 12, 15, 5


@pytest.fixture(scope="module")
def stream_problem():
    batch_fn, c_pop, q_pop = eigengap_stream(D, R, 0.7, seed=0)
    ing = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn, batch_size=30)
    ing.ingest(20)
    covs = ing.cov_stack()
    _, q_true = eigh_topr(covs.sum(0), R)
    return dict(batch_fn=batch_fn, covs=covs, q_true=q_true,
                graph=erdos_renyi(N, 0.5, seed=1))


# ---------------------------------------------------------------------------
# ingestion
# ---------------------------------------------------------------------------
def test_exact_sketch_matches_batch_pipeline(stream_problem):
    """Streamed covs == partitioning each micro-batch and batching the cov:
    node i's accumulated samples are exactly its per-batch column shards."""
    batch_fn = stream_problem["batch_fn"]
    per_node = [[] for _ in range(N)]
    for t in range(20):
        for i, b in enumerate(partition_samples(batch_fn(t, 30), N)):
            per_node[i].append(b)
    blocks = [jnp.concatenate(bs, axis=1) for bs in per_node]
    want = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
    np.testing.assert_allclose(np.asarray(stream_problem["covs"]),
                               np.asarray(want), rtol=1e-5, atol=1e-6)


def test_ingestor_checkpoint_resume_is_bitwise(tmp_path, stream_problem):
    """Kill-and-restart mid-stream: the stateless stream + checkpointed
    sketch state reproduce the uninterrupted ingestion exactly."""
    batch_fn = stream_problem["batch_fn"]
    full = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                             batch_size=30).ingest(10)

    part = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                             batch_size=30).ingest(4)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(part.step, part.state())

    fresh = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                              batch_size=30)
    tree, _ = mgr.restore(fresh.state())
    fresh.restore(tree)
    assert fresh.step == 4
    fresh.ingest(6)
    np.testing.assert_array_equal(np.asarray(fresh.cov_stack()),
                                  np.asarray(full.cov_stack()))
    np.testing.assert_array_equal(fresh.samples_per_node,
                                  full.samples_per_node)


def test_frequent_directions_error_bound(stream_problem):
    """||X X^T - B^T B||_2 <= accumulated shrink mass, per node."""
    batch_fn = stream_problem["batch_fn"]
    ell = 10
    fd = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn, batch_size=30,
                           sketch="fd", ell=ell)
    fd.ingest(12)
    exact = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                              batch_size=30).ingest(12)
    sm = np.asarray(exact.sketch.second_moment)
    bb = np.asarray(jnp.einsum("nld,nle->nde", fd.sketch.sketch,
                               fd.sketch.sketch))
    loss = np.asarray(fd.sketch.shrink_loss)
    for i in range(N):
        gap = np.linalg.norm(sm[i] - bb[i], ord=2)
        assert gap <= loss[i] * (1 + 1e-4) + 1e-4
    # and the bound is non-trivial (the sketch actually compresses)
    assert (loss > 0).all()


def test_ingestor_rejects_ragged_batch(stream_problem):
    with pytest.raises(ValueError, match="divide evenly"):
        StreamingIngestor(n_nodes=N, d=D,
                          batch_fn=stream_problem["batch_fn"], batch_size=31)


def test_cov_stack_before_ingest_raises(stream_problem):
    """0/0 must fail at the call site, not emit an all-NaN operand stack."""
    fresh = StreamingIngestor(n_nodes=N, d=D,
                              batch_fn=stream_problem["batch_fn"],
                              batch_size=30)
    with pytest.raises(ValueError, match="ingest"):
        fresh.cov_stack()


def test_fd_rejects_ell_over_d():
    with pytest.raises(ValueError, match="ell"):
        FrequentDirections.init(2, 8, 9)


def test_ritz_tracking_estimates_global_spectrum(stream_problem):
    """track_top=K: the per-batch Rayleigh–Ritz step converges to the top
    K+1 eigenpairs of the accumulated GLOBAL covariance, for both sketches,
    without ever eigendecomposing the (N, d, d) stack."""
    batch_fn = stream_problem["batch_fn"]
    for kw in ({}, {"sketch": "fd", "ell": 10}):
        ing = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                                batch_size=30, track_top=R, **kw)
        ing.ingest(25)
        total = float(np.asarray(ing.sketch.counts).sum())
        glob = np.asarray(ing.sketch.apply_sum(jnp.eye(D))) / total
        vals, vecs = eigh_topr(jnp.asarray(glob), R + 1)
        np.testing.assert_allclose(ing.ritz_values, np.asarray(vals),
                                   rtol=5e-3, atol=5e-3)
        assert float(jnp.linalg.norm(
            ing.top_basis().T @ vecs[:, :R])) == pytest.approx(
                np.sqrt(R), abs=1e-2)
        assert ing.eigengap == pytest.approx(
            float(vals[R - 1] - vals[R]), abs=1e-2)


def test_ritz_state_checkpoint_roundtrip_bitwise(tmp_path, stream_problem):
    """Satellite: the tracked Ritz basis/values ride in the checkpointed
    state — a restored ingestor continues the spectrum estimate bitwise."""
    batch_fn = stream_problem["batch_fn"]
    mk = lambda: StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                                   batch_size=30, track_top=R, ritz_seed=5)
    full = mk().ingest(12)
    part = mk().ingest(5)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(part.step, part.state())
    fresh = mk()
    tree, _ = mgr.restore(fresh.state())
    fresh.restore(tree).ingest(7)
    np.testing.assert_array_equal(np.asarray(fresh._ritz_basis),
                                  np.asarray(full._ritz_basis))
    np.testing.assert_array_equal(fresh.ritz_values, full.ritz_values)
    assert fresh.eigengap == full.eigengap
    np.testing.assert_array_equal(np.asarray(fresh.cov_stack()),
                                  np.asarray(full.cov_stack()))


def test_untracked_state_layout_unchanged(stream_problem):
    """Without track_top the checkpoint tree keeps the pre-serving layout
    (no ritz keys), so old snapshots restore against new code."""
    ing = StreamingIngestor(n_nodes=N, d=D,
                            batch_fn=stream_problem["batch_fn"],
                            batch_size=30)
    assert set(ing.state()) == {"step", "sketch"}
    with pytest.raises(ValueError, match="track_top"):
        ing.eigengap
    with pytest.raises(ValueError, match="track_top"):
        ing.top_basis()


def test_track_top_validation(stream_problem):
    with pytest.raises(ValueError, match="track_top"):
        StreamingIngestor(n_nodes=N, d=D,
                          batch_fn=stream_problem["batch_fn"],
                          batch_size=30, track_top=D)


# ---------------------------------------------------------------------------
# registered pytrees
# ---------------------------------------------------------------------------
def test_ledger_checkpoints_as_pytree(tmp_path):
    """CommLedger round-trips through checkpoint/manager.py with its
    list-valued awake_counts intact (stacking keeps working after restore).
    Counters are float64 at table scale (> 2^24) — restore must not let a
    device_put with x64 disabled downcast them to float32."""
    led = CommLedger(p2p=123456789.0, matrices=10.0, scalars=9.876543219e12)
    led.log_awake_rounds([3, 4, 5])
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"ledger": led})
    got, _ = mgr.restore({"ledger": CommLedger()})
    restored = got["ledger"]
    assert restored.p2p == led.p2p
    assert restored.scalars == led.scalars
    assert restored.awake_counts == [3, 4, 5]
    restored.log_awake_rounds([7])            # stacking intact post-restore
    assert restored.awake_counts == [3, 4, 5, 7]
    assert restored.mean_awake() == pytest.approx(np.mean([3, 4, 5, 7]))


def test_runstate_is_pytree():
    st = RunState(q=jnp.zeros((2, 3, 1)), key=jnp.zeros((2,), jnp.uint32),
                  step=jnp.int32(4), errs=jnp.zeros(7),
                  sends=jnp.zeros((7, 2)), counts=jnp.zeros((7, 2)))
    leaves = jax.tree.leaves(st)
    assert len(leaves) == 6
    st2 = jax.tree.map(lambda x: x, st)
    assert isinstance(st2, RunState) and int(st2.step) == 4


# ---------------------------------------------------------------------------
# chunked crash-resume: bit-identical traces, ledgers, iterates
# ---------------------------------------------------------------------------
def _assert_ledgers_equal(a, b):
    assert a.p2p == b.p2p
    assert a.matrices == b.matrices
    assert a.scalars == b.scalars
    assert a.awake_counts == b.awake_counts


def _async_engine():
    return AsyncConsensus(erdos_renyi(N, 0.5, seed=1), p_awake=0.8, seed=5)


@pytest.mark.parametrize("kill_at", [1, 2])
def test_sdot_sync_crash_resume_bitwise(tmp_path, stream_problem, kill_at):
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    mono = sdot(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                q_true=p["q_true"])
    mgr = CheckpointManager(str(tmp_path / f"k{kill_at}"))
    part = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER,
                        t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                        manager=mgr, max_chunks=kill_at)
    assert len(part.error_trace) == min(kill_at * CHUNK, T_OUTER)
    res = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER,
                       t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                       manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)
    np.testing.assert_array_equal(np.asarray(res.q_nodes),
                                  np.asarray(mono.q_nodes))
    _assert_ledgers_equal(res.ledger, mono.ledger)


@pytest.mark.parametrize("kill_at", [1, 2])
def test_sdot_async_crash_resume_bitwise(tmp_path, stream_problem, kill_at):
    """The straggler path: the RNG key rides in the checkpointed RunState,
    so the restored run continues the SAME awake-mask realization, and the
    realized ledger (sends + awake counts) survives the crash too."""
    p = stream_problem
    mono = sdot(covs=p["covs"], engine=_async_engine(), r=R, t_outer=T_OUTER,
                t_c=T_C, q_true=p["q_true"])
    mgr = CheckpointManager(str(tmp_path / f"k{kill_at}"))
    eng2 = _async_engine()
    sdot_chunked(covs=p["covs"], engine=eng2, r=R, t_outer=T_OUTER, t_c=T_C,
                 q_true=p["q_true"], chunk_size=CHUNK, manager=mgr,
                 max_chunks=kill_at)
    eng3 = _async_engine()
    res = sdot_chunked(covs=p["covs"], engine=eng3, r=R, t_outer=T_OUTER,
                       t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                       manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)
    np.testing.assert_array_equal(np.asarray(res.q_nodes),
                                  np.asarray(mono.q_nodes))
    _assert_ledgers_equal(res.ledger, mono.ledger)
    # the engine's RNG stream position matches the uninterrupted run's
    eng_mono = _async_engine()
    sdot(covs=p["covs"], engine=eng_mono, r=R, t_outer=T_OUTER, t_c=T_C)
    np.testing.assert_array_equal(np.asarray(eng3._key),
                                  np.asarray(eng_mono._key))


@pytest.mark.parametrize("kill_at", [1, 2])
def test_sdot_netfaults_crash_resume_bitwise(tmp_path, stream_problem,
                                             kill_at):
    """The net-fault path: the RNG key AND the per-edge Gilbert–Elliott
    burst state ride the checkpointed carry, so a faulty run killed at a
    chunk boundary resumes the SAME realized fault sequence — drops,
    bursts, and a crash window STRADDLING the boundary replay exactly."""
    from repro.core.netfaults import FaultyConsensus, NetFaultModel
    p = stream_problem
    model = NetFaultModel(p_drop=0.15, p_bad=0.1, p_good=0.4,
                          crash_windows=((0, 4, 3),))   # spans the t=5 cut
    mk = lambda: FaultyConsensus(graph=p["graph"], faults=model, seed=9)
    mono = sdot(covs=p["covs"], engine=mk(), r=R, t_outer=T_OUTER, t_c=T_C,
                q_true=p["q_true"])
    mgr = CheckpointManager(str(tmp_path / f"k{kill_at}"))
    sdot_chunked(covs=p["covs"], engine=mk(), r=R, t_outer=T_OUTER, t_c=T_C,
                 q_true=p["q_true"], chunk_size=CHUNK, manager=mgr,
                 max_chunks=kill_at)
    eng3 = mk()
    res = sdot_chunked(covs=p["covs"], engine=eng3, r=R, t_outer=T_OUTER,
                       t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                       manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)
    np.testing.assert_array_equal(np.asarray(res.q_nodes),
                                  np.asarray(mono.q_nodes))
    _assert_ledgers_equal(res.ledger, mono.ledger)
    # the engine's RNG stream position AND burst state land where the
    # uninterrupted run's do
    eng_mono = mk()
    sdot(covs=p["covs"], engine=eng_mono, r=R, t_outer=T_OUTER, t_c=T_C)
    np.testing.assert_array_equal(np.asarray(eng3._key),
                                  np.asarray(eng_mono._key))
    np.testing.assert_array_equal(np.asarray(eng3._ge),
                                  np.asarray(eng_mono._ge))


@pytest.mark.parametrize("kill_at", [1, 2])
def test_fdot_crash_resume_bitwise(tmp_path, kill_at):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((16, 240)), jnp.float32)
    _, q_true = eigh_topr(x @ x.T / x.shape[1], R)
    blocks = partition_features(x, 4)
    eng = DenseConsensus(erdos_renyi(4, 0.9, seed=1))
    mono = fdot(data_blocks=blocks, engine=eng, r=R, t_outer=9, t_c=T_C,
                q_true=q_true)
    mgr = CheckpointManager(str(tmp_path))
    fdot_chunked(data_blocks=blocks, engine=eng, r=R, t_outer=9, t_c=T_C,
                 q_true=q_true, chunk_size=4, manager=mgr, max_chunks=kill_at)
    res = fdot_chunked(data_blocks=blocks, engine=eng, r=R, t_outer=9,
                       t_c=T_C, q_true=q_true, chunk_size=4, manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)
    np.testing.assert_array_equal(np.asarray(res.q_full),
                                  np.asarray(mono.q_full))
    _assert_ledgers_equal(res.ledger, mono.ledger)


def test_corrupt_latest_checkpoint_recovery(tmp_path, stream_problem):
    """A torn latest snapshot (manifest present, shards unreadable) must not
    kill the run: resume falls back to the newest restorable step and the
    final trace is still bit-identical."""
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    mono = sdot(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                q_true=p["q_true"])
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                 q_true=p["q_true"], chunk_size=CHUNK, manager=mgr,
                 max_chunks=2)
    steps = mgr.all_steps()
    assert len(steps) == 2
    # corrupt the newest step's shard file, manifest intact
    shard = os.path.join(tmp_path, f"step_{steps[-1]:08d}", "shards.npz")
    with open(shard, "wb") as f:
        f.write(b"not an npz")
    res = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER,
                       t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                       manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)
    _assert_ledgers_equal(res.ledger, mono.ledger)


def test_all_checkpoints_corrupt_falls_back_to_fresh(tmp_path,
                                                     stream_problem):
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    mono = sdot(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                q_true=p["q_true"])
    mgr = CheckpointManager(str(tmp_path))
    sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                 q_true=p["q_true"], chunk_size=CHUNK, manager=mgr,
                 max_chunks=1)
    for s in mgr.all_steps():
        with open(os.path.join(tmp_path, f"step_{s:08d}", "shards.npz"),
                  "wb") as f:
            f.write(b"garbage")
    res = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER,
                       t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                       manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)


def test_stale_checkpoint_dir_rejected_with_warning(tmp_path,
                                                    stream_problem):
    """A checkpoint dir from a run with a different t_outer must not be
    silently resumed (the buffers have the wrong length): the run warns,
    starts fresh, and still produces the correct full-length trace."""
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    mgr = CheckpointManager(str(tmp_path))
    sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                 q_true=p["q_true"], chunk_size=CHUNK, manager=mgr,
                 max_chunks=1)
    longer = T_OUTER + 8
    mono = sdot(covs=p["covs"], engine=eng, r=R, t_outer=longer, t_c=T_C,
                q_true=p["q_true"])
    with pytest.warns(UserWarning, match="none restored"):
        res = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=longer,
                           t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                           manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)


def test_chunk_size_invariance(stream_problem):
    """The trace must not depend on where the chunk boundaries fall."""
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    mono = sdot(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                q_true=p["q_true"])
    for chunk in (1, 4, T_OUTER, T_OUTER + 7):
        res = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER,
                           t_c=T_C, q_true=p["q_true"], chunk_size=chunk)
        np.testing.assert_array_equal(res.error_trace, mono.error_trace)


# ---------------------------------------------------------------------------
# generic run_chunked over the rest of the zoo: B-DOT + the baselines
# (no family-specific chunking code exists for these — the coverage below
# pins the unified runtime's generic driver)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def grid_problem():
    """A 2 x 3 B-DOT grid over a ragged feature/sample partition."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((12, 120)), jnp.float32)
    _, q_true = eigh_topr(x @ x.T / x.shape[1], R)
    d_rows, n_cols = [7, 5], [50, 40, 30]
    blocks, o = [], 0
    for di in d_rows:
        row, c = [], 0
        for nj in n_cols:
            row.append(x[o:o + di, c:c + nj])
            c += nj
        blocks.append(row)
        o += di
    return dict(
        blocks=blocks, q_true=q_true,
        col_engines=[DenseConsensus(complete(2)) for _ in n_cols],
        row_engines=[DenseConsensus(ring(3)) for _ in d_rows])


def _bdot_kw(g):
    return dict(blocks=g["blocks"], col_engines=g["col_engines"],
                row_engines=g["row_engines"], r=R, t_outer=9, t_c=10,
                q_true=g["q_true"])


@pytest.mark.parametrize("kill_at", [1, 2])
def test_bdot_crash_resume_bitwise(tmp_path, grid_problem, kill_at):
    """B-DOT could not checkpoint at all before the unified runtime; the
    generic chunked driver gives it kill-at-any-chunk-boundary resume that
    is bit-identical to the monolithic fused run."""
    g = grid_problem
    mono = bdot(**_bdot_kw(g))
    mgr = CheckpointManager(str(tmp_path / f"k{kill_at}"))
    part = bdot_chunked(chunk_size=4, manager=mgr, max_chunks=kill_at,
                        **_bdot_kw(g))
    assert len(part.error_trace) == min(kill_at * 4, 9)
    res = bdot_chunked(chunk_size=4, manager=mgr, **_bdot_kw(g))
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)
    np.testing.assert_array_equal(np.asarray(res.q_full),
                                  np.asarray(mono.q_full))
    _assert_ledgers_equal(res.ledger, mono.ledger)


def test_bdot_corrupt_latest_checkpoint_recovery(tmp_path, grid_problem):
    """The corrupt-latest fallback is driver-level, so B-DOT inherits it:
    a torn newest snapshot falls back to the previous restorable step."""
    g = grid_problem
    mono = bdot(**_bdot_kw(g))
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    bdot_chunked(chunk_size=4, manager=mgr, max_chunks=2, **_bdot_kw(g))
    steps = mgr.all_steps()
    assert len(steps) == 2
    shard = os.path.join(tmp_path, f"step_{steps[-1]:08d}", "shards.npz")
    with open(shard, "wb") as f:
        f.write(b"not an npz")
    res = bdot_chunked(chunk_size=4, manager=mgr, **_bdot_kw(g))
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)
    _assert_ledgers_equal(res.ledger, mono.ledger)


@pytest.mark.parametrize("name", ["deepca", "dsa", "seq_dist_pm"])
def test_baseline_chunked_crash_resume_bitwise(tmp_path, stream_problem,
                                               name):
    """Chunked baselines resume bit-identically: DeEPCA's pytree carry
    (q, s, mq_prev) and the sequential-deflation flattened index both ride
    the generic RunState."""
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    led = CommLedger()
    if name == "seq_dist_pm":
        kw = dict(covs=p["covs"], iters_per_vec=4, t_c=T_C)
        q_m, e_m = B.seq_dist_pm(p["covs"], eng, R, 4, t_c=T_C,
                                 q_true=p["q_true"], ledger=led)
    else:
        kw = dict(covs=p["covs"], t_outer=T_OUTER)
        q_m, e_m = getattr(B, name)(p["covs"], eng, R, T_OUTER,
                                    q_true=p["q_true"], ledger=led)
    mgr = CheckpointManager(str(tmp_path))
    part = baseline_chunked(name, engine=eng, r=R, q_true=p["q_true"],
                            chunk_size=5, manager=mgr, max_chunks=1, **kw)
    assert len(part.error_trace) == 5
    res = baseline_chunked(name, engine=eng, r=R, q_true=p["q_true"],
                           chunk_size=5, manager=mgr, **kw)
    np.testing.assert_array_equal(res.error_trace, e_m)
    np.testing.assert_array_equal(np.asarray(res.q), np.asarray(q_m))
    _assert_ledgers_equal(res.ledger, led)


def test_baseline_chunked_dpm_crash_resume_bitwise(tmp_path):
    """The feature-partitioned sequential baseline chunks over the
    flattened (vector, inner-iteration) index too."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((16, 240)), jnp.float32)
    _, q_true = eigh_topr(x @ x.T / x.shape[1], R)
    blocks = partition_features(x, 4)
    eng = DenseConsensus(erdos_renyi(4, 0.9, seed=1))
    led = CommLedger()
    q_m, e_m = B.d_pm(blocks, eng, R, 4, t_c=T_C, q_true=q_true, ledger=led)
    mgr = CheckpointManager(str(tmp_path))
    baseline_chunked("d_pm", data_blocks=blocks, engine=eng, r=R,
                     iters_per_vec=4, t_c=T_C, q_true=q_true, chunk_size=7,
                     manager=mgr, max_chunks=1)
    res = baseline_chunked("d_pm", data_blocks=blocks, engine=eng, r=R,
                           iters_per_vec=4, t_c=T_C, q_true=q_true,
                           chunk_size=7, manager=mgr)
    np.testing.assert_array_equal(res.error_trace, e_m)
    np.testing.assert_array_equal(np.asarray(res.q), np.asarray(q_m))
    _assert_ledgers_equal(res.ledger, led)


def test_baseline_stale_checkpoint_dir_rejected(tmp_path, stream_problem):
    """A baseline checkpoint dir from a different t_outer is rejected with
    the runtime's warning (the buffers have the wrong length) and the run
    restarts cleanly — same driver-level behaviour the sdot path pins."""
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    mgr = CheckpointManager(str(tmp_path))
    baseline_chunked("dsa", covs=p["covs"], engine=eng, r=R,
                     t_outer=T_OUTER, q_true=p["q_true"], chunk_size=5,
                     manager=mgr, max_chunks=1)
    longer = T_OUTER + 6
    _, e_m = B.dsa(p["covs"], eng, R, longer, q_true=p["q_true"])
    with pytest.warns(UserWarning, match="none restored"):
        res = baseline_chunked("dsa", covs=p["covs"], engine=eng, r=R,
                               t_outer=longer, q_true=p["q_true"],
                               chunk_size=5, manager=mgr)
    np.testing.assert_array_equal(res.error_trace, e_m)


def test_baseline_corrupt_checkpoint_fallback(tmp_path, stream_problem):
    """Corrupt-latest fallback under the generic driver for a baseline."""
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    _, e_m = B.dsa(p["covs"], eng, R, T_OUTER, q_true=p["q_true"])
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    baseline_chunked("dsa", covs=p["covs"], engine=eng, r=R,
                     t_outer=T_OUTER, q_true=p["q_true"], chunk_size=5,
                     manager=mgr, max_chunks=2)
    steps = mgr.all_steps()
    shard = os.path.join(tmp_path, f"step_{steps[-1]:08d}", "shards.npz")
    with open(shard, "wb") as f:
        f.write(b"garbage")
    res = baseline_chunked("dsa", covs=p["covs"], engine=eng, r=R,
                           t_outer=T_OUTER, q_true=p["q_true"],
                           chunk_size=5, manager=mgr)
    np.testing.assert_array_equal(res.error_trace, e_m)


# ---------------------------------------------------------------------------
# chunked-resumable sweeps: the sweep-RunState checkpoints mid-grid
# ---------------------------------------------------------------------------
def test_sweep_chunked_resume_bitwise(tmp_path, stream_problem):
    """A killed chunked sweep resumes mid-grid from its checkpointed
    sweep-RunState, bitwise equal to the uninterrupted sweep (trace, final
    estimates, and aggregate ledger)."""
    p = stream_problem
    engines = [DenseConsensus(p["graph"]), DenseConsensus(ring(N))]
    kw = dict(covs=p["covs"], engines=engines, r=R, t_outer=T_OUTER,
              t_c=T_C, seeds=[0, 1], q_true=p["q_true"])
    mono = sdot_sweep(**kw)
    mgr = CheckpointManager(str(tmp_path))
    part = sdot_sweep(manager=mgr, chunk_size=CHUNK, max_chunks=1, **kw)
    assert part.steps_done == CHUNK
    assert part.error_traces.shape == (2, 2, CHUNK)
    res = sdot_sweep(manager=mgr, chunk_size=CHUNK, **kw)
    assert res.steps_done == T_OUTER
    assert part.resumed_step == 0 and res.resumed_step == CHUNK
    np.testing.assert_array_equal(res.error_traces, mono.error_traces)
    np.testing.assert_array_equal(np.asarray(res.q), np.asarray(mono.q))
    _assert_ledgers_equal(res.ledger, mono.ledger)


def test_sweep_resumed_step_reflects_corrupt_fallback(tmp_path,
                                                      stream_problem):
    """resumed_step reports the step the runtime ACTUALLY restored — a
    torn newest checkpoint falls back one chunk, and the report must not
    overstate progress from the directory listing."""
    p = stream_problem
    kw = dict(covs=p["covs"], engines=DenseConsensus(p["graph"]), r=R,
              t_outer=T_OUTER, t_c=T_C, seeds=[0, 1], q_true=p["q_true"])
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    sdot_sweep(manager=mgr, chunk_size=4, max_chunks=2, **kw)
    steps = mgr.all_steps()
    assert steps == [4, 8]
    shard = os.path.join(tmp_path, f"step_{steps[-1]:08d}", "shards.npz")
    with open(shard, "wb") as f:
        f.write(b"torn")
    res = sdot_sweep(manager=mgr, chunk_size=4, **kw)
    assert res.resumed_step == 4
    mono = sdot_sweep(**kw)
    np.testing.assert_array_equal(res.error_traces, mono.error_traces)


# ---------------------------------------------------------------------------
# multi-process launcher
# ---------------------------------------------------------------------------
def test_launcher_matches_single_process(tmp_path, stream_problem):
    p = stream_problem
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1},
              "schedule": {"kind": "lin2", "cap": T_C}}]
    seeds = [0, 1, 2, 3]
    engines = [build_engine(c["topology"]) for c in cases]
    schedules = [build_schedule(c["schedule"], 8, T_C) for c in cases]
    ref = sdot_sweep(covs=p["covs"], engines=engines, schedules=schedules,
                     r=R, t_outer=8, t_c=T_C, seeds=seeds,
                     q_true=p["q_true"])
    sw = launch_sweep(covs=p["covs"], cases=cases, r=R, t_outer=8, t_c=T_C,
                      seeds=seeds, q_true=p["q_true"],
                      workdir=str(tmp_path), n_workers=2)
    np.testing.assert_allclose(sw.error_traces, ref.error_traces,
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(sw.q), np.asarray(ref.q),
                               rtol=1e-6, atol=1e-7)
    assert list(sw.seeds) == seeds
    assert sw.ledger.p2p == ref.ledger.p2p
    assert sw.ledger.scalars == ref.ledger.scalars

    # relaunch with published shards: no recompute, same merged result
    sw2 = launch_sweep(covs=p["covs"], cases=cases, r=R, t_outer=8, t_c=T_C,
                       seeds=seeds, q_true=p["q_true"],
                       workdir=str(tmp_path), n_workers=2)
    np.testing.assert_array_equal(sw2.error_traces, sw.error_traces)

    # reusing the workdir with a CHANGED spec must not merge stale shards:
    # the stamped spec fingerprint forces a relaunch
    sw3 = launch_sweep(covs=p["covs"], cases=cases, r=R, t_outer=6, t_c=T_C,
                       seeds=seeds, q_true=p["q_true"],
                       workdir=str(tmp_path), n_workers=2)
    assert sw3.error_traces.shape == (len(seeds), 6)
    np.testing.assert_allclose(sw3.error_traces, ref.error_traces[:, :6],
                               rtol=1e-6, atol=1e-7)


def test_launcher_ragged_shared_covs(tmp_path, stream_problem):
    """Ragged-covs mode with ONE shared stack: stored once in problem.npz,
    zip-broadcast worker-side; merged result matches the single-process
    ragged sweep."""
    p = stream_problem
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1}},
             {"topology": {"kind": "ring", "n": N}}]
    seeds = [0, 1]
    engines = [build_engine(c["topology"]) for c in cases]
    ref = sdot_sweep(covs=[p["covs"]], engines=engines, r=R, t_outer=5,
                     t_c=T_C, seeds=seeds, q_true=p["q_true"])
    sw = launch_sweep(covs=[p["covs"]], cases=cases, r=R, t_outer=5,
                      t_c=T_C, seeds=seeds, q_true=p["q_true"],
                      workdir=str(tmp_path), n_workers=2)
    np.testing.assert_allclose(sw.error_traces, ref.error_traces,
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(sw.node_counts, ref.node_counts)
    # the shared stack was written once, not once per case
    problem = np.load(os.path.join(tmp_path, "problem.npz"))
    assert "covs_0" in problem and "covs_1" not in problem


def test_launcher_rejects_mismatched_case_covs(tmp_path, stream_problem):
    """A covs list that cannot zip-broadcast with the cases fails up front
    (before any worker spawn), matching sdot_sweep's contract."""
    p = stream_problem
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1}}] * 3
    with pytest.raises(ValueError, match="zip-broadcast"):
        launch_sweep(covs=[p["covs"], p["covs"]], cases=cases, r=R,
                     t_outer=4, seeds=[0], workdir=str(tmp_path),
                     n_workers=1)


def test_launcher_worker_resumes_mid_grid(tmp_path, stream_problem):
    """A worker killed mid-sweep leaves a checkpointed sweep-RunState in
    its ckpt dir; the relaunched worker resumes MID-GRID from it (the
    resume report records the restored outer step) and the merged result
    is bitwise equal to an uninterrupted chunked launch."""
    p = stream_problem
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1},
              "schedule": {"kind": "lin2", "cap": T_C}}]
    seeds = [0, 1, 2, 3]
    kw = dict(covs=p["covs"], cases=cases, r=R, t_outer=8, t_c=T_C,
              seeds=seeds, q_true=p["q_true"], n_workers=2, sweep_chunk=3)

    full = launch_sweep(workdir=str(tmp_path / "full"), **kw)
    assert full.resume_report["worker_resumed_steps"] == {0: 0, 1: 0}

    # simulate worker 0 killed after its first chunk: pre-populate its
    # ckpt dir with the partial sweep-RunState of its seed shard
    wd = tmp_path / "killed"
    engines = [build_engine(c["topology"]) for c in cases]
    schedules = [build_schedule(c["schedule"], 8, T_C) for c in cases]
    mgr = CheckpointManager(str(wd / "worker_0" / "ckpt"))
    sdot_sweep(covs=p["covs"], engines=engines, schedules=schedules, r=R,
               t_outer=8, t_c=T_C, seeds=seeds[:2], q_true=p["q_true"],
               manager=mgr, chunk_size=3, max_chunks=1)

    res = launch_sweep(workdir=str(wd), **kw)
    assert res.resume_report["worker_resumed_steps"][0] == 3
    assert res.resume_report["worker_resumed_steps"][1] == 0
    np.testing.assert_array_equal(res.error_traces, full.error_traces)
    np.testing.assert_array_equal(np.asarray(res.q), np.asarray(full.q))
    assert res.ledger.p2p == full.ledger.p2p

    # a rerun reuses both published shards: the whole grid is skipped
    res2 = launch_sweep(workdir=str(wd), **kw)
    assert res2.resume_report["reused_shards"] == [0, 1]
    assert res2.resume_report["skipped_grid_points"] == len(seeds)
    np.testing.assert_array_equal(res2.error_traces, res.error_traces)


def test_launcher_net_faults_matches_single_process(tmp_path,
                                                    stream_problem):
    """A net-fault document threads launcher -> spec -> worker: every
    worker wraps its engines in FaultyConsensus and the merged result
    matches the single-process netfault_sweep. The document enters the
    spec fingerprint, so a CHANGED fault model must NOT reuse the
    published shards."""
    from repro.core.netfaults import FaultyConsensus
    from repro.core.sweep import netfault_sweep
    from repro.streaming import chaos

    p = stream_problem
    doc = {"p_drop": 0.2, "burst": {"p_bad": 0.05, "p_good": 0.5},
           "crash": [{"node": 0, "start": 2, "len": 2}], "seed": 11}
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1}}]
    seeds = [0, 1, 2]
    model, fseed, deb = chaos.net_fault_model_from_dict(doc)
    engines = [FaultyConsensus(graph=build_engine(cases[0]["topology"]).graph,
                               faults=model, seed=fseed, debias=deb)]
    ref = netfault_sweep(covs=p["covs"], engines=engines, r=R, t_outer=6,
                         t_c=T_C, seeds=seeds, q_true=p["q_true"])
    kw = dict(covs=p["covs"], cases=cases, r=R, t_outer=6, t_c=T_C,
              seeds=seeds, q_true=p["q_true"], workdir=str(tmp_path),
              n_workers=2)
    sw = launch_sweep(net_faults=doc, **kw)
    np.testing.assert_allclose(sw.error_traces, ref.error_traces,
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(sw.q), np.asarray(ref.q),
                               rtol=1e-6, atol=1e-7)
    assert sw.ledger.p2p == ref.ledger.p2p

    # same document again: published shards are reused wholesale
    sw2 = launch_sweep(net_faults=doc, **kw)
    assert sw2.resume_report["reused_shards"] == [0, 1]
    np.testing.assert_array_equal(sw2.error_traces, sw.error_traces)

    # a different fault model changes the fingerprint: no stale reuse
    sw3 = launch_sweep(net_faults=dict(doc, p_drop=0.4), **kw)
    assert sw3.resume_report["reused_shards"] == []
    assert float(np.max(np.abs(sw3.error_traces - sw.error_traces))) > 0


def test_launcher_net_faults_rejects_ragged(tmp_path, stream_problem):
    """Per-case ragged covs cannot share one (C, T, N) node-up stack:
    the launcher fails up front with a clear message."""
    p = stream_problem
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1}}]
    with pytest.raises(ValueError, match="uniform node count"):
        launch_sweep(covs=[p["covs"]], cases=cases, r=R, t_outer=4,
                     seeds=[0], workdir=str(tmp_path), n_workers=1,
                     net_faults={"p_drop": 0.1})


def test_launcher_reuses_results_published_without_resumed_steps(
        tmp_path, stream_problem):
    """Shards published before the resumed_steps leaf existed must still be
    reused — never recompute a valid multi-day shard over a reporting
    field."""
    from repro.checkpoint.manager import save_tree
    from repro.streaming.launcher import _result_dir, spec_fingerprint

    p = stream_problem
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1}}]
    seeds = [0, 1]
    kw = dict(covs=p["covs"], cases=cases, r=R, t_outer=5, t_c=T_C,
              seeds=seeds, q_true=p["q_true"], n_workers=1)
    ref = launch_sweep(workdir=str(tmp_path / "ref"), **kw)

    # publish worker 0's result in the PRE-resumed_steps format
    wd = tmp_path / "legacy"
    wd.mkdir()
    spec = {"algo": "sdot", "r": R, "t_outer": 5, "t_c": T_C,
            "cases": cases, "shards": [seeds], "ragged": False,
            "n_cov_stacks": 1, "has_q_true": True, "sweep_chunk": None}
    engines = [build_engine(c["topology"]) for c in cases]
    sw = sdot_sweep(covs=p["covs"], engines=engines, r=R, t_outer=5,
                    t_c=T_C, seeds=seeds, q_true=p["q_true"])
    save_tree(_result_dir(str(wd), 0),
              {"q": sw.q, "seeds": jnp.asarray(np.asarray(seeds)),
               "ledger": sw.ledger,
               "spec_fp": jnp.asarray(spec_fingerprint(spec), jnp.int32),
               "error_traces": jnp.asarray(sw.error_traces)}, step=0)
    res = launch_sweep(workdir=str(wd), **kw)
    assert res.resume_report["reused_shards"] == [0]
    assert res.resume_report["worker_resumed_steps"][0] == 0
    np.testing.assert_array_equal(res.error_traces, ref.error_traces)


def test_launcher_spec_change_invalidates_sweep_checkpoints(
        tmp_path, stream_problem):
    """Re-using a workdir with a changed spec must clear the workers'
    intermediate sweep checkpoints (their shapes/content belong to the old
    grid) — published results are already fingerprint-guarded."""
    p = stream_problem
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1}}]
    kw = dict(covs=p["covs"], cases=cases, r=R, t_c=T_C,
              seeds=[0, 1], q_true=p["q_true"], n_workers=1, sweep_chunk=3)
    launch_sweep(workdir=str(tmp_path), t_outer=8, **kw)
    # plant a stale ckpt dir, then relaunch with a different t_outer
    ckpt = tmp_path / "worker_0" / "ckpt"
    ckpt.mkdir(parents=True, exist_ok=True)
    (ckpt / "step_00000003").mkdir()
    res = launch_sweep(workdir=str(tmp_path), t_outer=6, **kw)
    assert not ckpt.exists() or not any(ckpt.iterdir())
    assert res.error_traces.shape == (2, 6)
