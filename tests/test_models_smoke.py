"""Per-architecture smoke tests on REDUCED configs (assignment requirement):
one forward + one train step on CPU, asserting shapes and finiteness; plus
prefill/decode parity, which is the strongest cheap correctness check a
decoder stack has."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, reduced_config
from repro.data.pipeline import make_lm_batch
from repro.models.transformer import (decode_step, forward, init_decode_state,
                                      init_params)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.train.step import loss_fn

B, S = 2, 32


def _setup(aid):
    cfg = reduced_config(get_arch(aid))
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_lm_batch(cfg, 0, 0, B, S)
    return cfg, params, batch


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_forward_shapes_finite(aid):
    cfg, params, batch = _setup(aid)
    logits = forward(params, batch, cfg, remat=False)
    if cfg.frontend == "audio_codec":
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{aid}: non-finite logits"


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_train_step_finite_and_updates(aid):
    cfg, params, batch = _setup(aid)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1)
    state = adamw_init(params, opt)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg, remat=True)
    assert np.isfinite(float(loss))
    gnorm_leaves = [float(jnp.abs(g).max()) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(g) for g in gnorm_leaves)
    new_params, new_state, gnorm = adamw_update(grads, state, params, opt)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert moved
    assert int(new_state["step"]) == 1


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_decode_matches_prefill(aid):
    """Teacher-forced decode must reproduce the forward logits step by step.
    This exercises KV caches, recurrent states, RoPE offsets and windows.

    Two legitimate sources of divergence are removed, not tolerated:
      * MoE capacity drops depend on the co-batched tokens — parity needs a
        capacity factor large enough that nothing is ever dropped;
      * the VLM prefix splice feeds different prefix *content* in forward vs
        raw-token decode — parity is checked on a pure token stream.
    """
    import dataclasses
    cfg = reduced_config(get_arch(aid))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_lm_batch(cfg, 0, 0, B, S)
    toks = batch["tokens"][:, :12]
    want = forward(params, {"tokens": toks}, cfg, remat=False)

    state = init_decode_state(cfg, B, 12)
    outs = []
    for t in range(12):
        tok = toks[:, t:t + 1]
        lg, state = decode_step(params, state, tok, cfg)
        outs.append(lg)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_swa_decode_ring_buffer():
    """Sliding-window cache shorter than the sequence still matches the
    windowed full-attention reference."""
    cfg = reduced_config(get_arch("h2o-danube-1.8b"), window=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = make_lm_batch(cfg, 0, 0, 1, 24)["tokens"]
    want = forward(params, {"tokens": toks}, cfg, remat=False)
    state = init_decode_state(cfg, 1, 24)
    outs = []
    for t in range(24):
        lg, state = decode_step(params, state, toks[:, t:t + 1], cfg)
        outs.append(lg)
    got = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-2,
                               atol=5e-2)


def test_moe_router_balance_not_degenerate():
    """Top-k routing on random inputs should not collapse to one expert."""
    from repro.models.moe import apply_moe
    cfg = reduced_config(get_arch("phi3.5-moe-42b-a6.6b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    # find an moe block
    gp = jax.tree.map(lambda l: l[0], params["groups"])
    moe_params = None
    for k, v in gp.items():
        if isinstance(v, dict) and "ffn" in v and "router" in v["ffn"]:
            moe_params = v["ffn"]
            break
    assert moe_params is not None
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    out = apply_moe(moe_params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())


def test_loss_decreases_over_steps():
    """30 steps of AdamW on a fixed tiny batch must reduce the loss — the
    cheapest end-to-end 'learning happens' check."""
    cfg = reduced_config(get_arch("qwen2-7b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5)
    state = adamw_init(params, opt)
    batch = make_lm_batch(cfg, 0, 0, 2, 16)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg,
                                                  remat=False)
        p, s, _ = adamw_update(grads, state, params, opt)
        return p, s, loss

    losses = []
    for _ in range(30):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses[::10]
