"""Table V — straggler effect. The paper injects a 0.01 s sleep at one random
node per iteration of a *synchronous* network and measures wall time.

We reproduce it two ways:
  * measured — actually run the simulation loop with the injected delay
    (scaled down: T_o=50) and compare wall clocks;
  * analytic — the bulk-synchronous model in launch/analytic_cost.py
    (straggler costs the whole network `delay` every iteration).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.consensus import DenseConsensus
from repro.core.sdot import sdot
from repro.core.topology import erdos_renyi
from repro.launch.analytic_cost import straggler_slowdown

from .common import Row, sample_problem

T_O = 50


def _run_with_delay(covs, eng, r, q_true, delay: float):
    """Outer loop with an injected per-iteration straggler sleep (the
    simulation is bulk-synchronous: one slow node stalls the round)."""
    t0 = time.perf_counter()
    # run one outer iteration at a time so the sleep lands on the sync point
    import jax.numpy as jnp
    from repro.core.linalg import orthonormal_init
    import jax
    q = None
    res = sdot(covs=covs, engine=eng, r=r, t_outer=1, t_c=50, q_true=q_true)
    t_iter_base = None
    t0 = time.perf_counter()
    for t in range(T_O):
        res = sdot(covs=covs, engine=eng, r=r, t_outer=1, t_c=50,
                   q_init=res.q_nodes[0], q_true=q_true)
        if delay:
            time.sleep(delay)
    return time.perf_counter() - t0


def run():
    rows = []
    for n, p in ((10, 0.5), (20, 0.25)):
        covs, q_true = sample_problem(d=20, r=5, n_nodes=n, n_per=500,
                                      gap=0.7, seed=0)
        eng = DenseConsensus(erdos_renyi(n, p, seed=1))
        t_plain = _run_with_delay(covs, eng, 5, q_true, 0.0)
        t_strag = _run_with_delay(covs, eng, 5, q_true, 0.01)
        t_step = t_plain / T_O
        model = straggler_slowdown(n_nodes=n, t_step=t_step, delay=0.01) / \
            straggler_slowdown(n_nodes=n, t_step=t_step, delay=0.0)
        rows.append(Row(
            f"table5/N{n}p{p}", t_strag * 1e6,
            {"time_s_no_straggler": round(t_plain, 3),
             "time_s_straggler": round(t_strag, 3),
             "measured_slowdown": round(t_strag / t_plain, 2),
             "model_slowdown": round(model, 2)}))
    return rows
