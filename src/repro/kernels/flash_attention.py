"""Pallas TPU kernel: blocked causal (optionally sliding-window) attention.

Online-softmax ("flash") attention for the LM-side prefill path. Grid is
(batch*heads, q_blocks, kv_blocks) with the kv dimension innermost; running
max / normalizer / accumulator live in VMEM scratch and the output block is
written once, on the last kv step.

VMEM working set per step: (bq + 2*bk) * hd * 4B + softmax tiles — with
bq = bk = 128, hd = 128 this is ~200 KiB, far under the ~16 MiB VMEM budget,
leaving headroom for the compiler's double buffering of the K/V streams.

The sliding-window mask makes this the kernel for h2o-danube (SWA) and
recurrentgemma (local attention) as well; `window=None` is full causal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window, block_q: int,
                  block_k: int, q_offset: int, kv_valid: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (bq, hd)
    k = k_ref[0]                                   # (bk, hd)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    # absolute positions; q_offset aligns real queries to the END of the real
    # kv stream so the same kernel serves prefill (sq == skv) and chunked
    # decode (sq < skv); kv_valid masks back-padding of the key stream.
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + q_offset
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_valid
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, _NEG_INF)

    m_prev = m_scr[...]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)                    # kill fully-masked rows
    alpha = jnp.exp(m_prev - m_new)                # rescale old state
    l_new = alpha * l_scr[...] + p.sum(axis=1, keepdims=True)
    acc = alpha * acc_scr[...] + jax.lax.dot_general(
        p, v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)            # padded rows: emit zeros
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "q_offset", "kv_valid", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int | None = None,
                           scale: float | None = None, block_q: int = 128,
                           block_k: int = 128, q_offset: int = 0,
                           kv_valid: int | None = None,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (b, h, sq, hd); k, v: (b, h, skv, hd) — same head counts (wrapper
    expands GQA groups). sq % block_q == skv % block_k == 0 (ops.py pads).

    ``q_offset``: absolute position of the first (real) query row relative to
    the key stream. ``kv_valid``: number of real (unpadded) key rows.
    """
    b, h, sq, hd = q.shape
    _, _, skv, _ = k.shape
    scale = (hd ** -0.5) if scale is None else scale
    kv_valid = skv if kv_valid is None else kv_valid

    qr = q.reshape(b * h, sq, hd)
    kr = k.reshape(b * h, skv, hd)
    vr = v.reshape(b * h, skv, hd)
    grid = (b * h, sq // block_q, skv // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, q_offset=q_offset, kv_valid=kv_valid)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, hd)
