"""repro — distributed PSA (S-DOT / SA-DOT / F-DOT) training framework in JAX."""
__version__ = "1.0.0"
