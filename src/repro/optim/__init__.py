from .adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from .psa_compress import (compress_grads, compression_ratio,  # noqa: F401
                           psa_init, psa_refresh)
