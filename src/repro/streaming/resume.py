"""Chunked-resumable fused runs: whole-run scans that outlast a job.

PRs 1-3 fused entire S-DOT/F-DOT runs into one ``lax.scan`` — maximal
throughput, but a run killed at iteration 900/1000 restarts from zero.
This module refactors the whole-run scan into an outer loop over
*chunks* of outer iterations, carrying a ``RunState`` pytree that
round-trips through ``checkpoint/manager.py``:

    prep (core/sdot._prepare_sdot / core/fdot._prepare_fdot)
      -> restore latest valid RunState (or init fresh)
      -> per chunk: one jitted scan over sched[step : step+chunk] built from
         the SAME outer-iteration body as the monolithic executor
         (core/sdot._sync_outer_body etc.), trace buffers updated in place
         via dynamic_update_slice
      -> checkpoint (atomic, async) at every chunk boundary
      -> final SDOTResult / FDOTResult assembled from the completed buffers

**Resume invariant** (pinned in tests/test_streaming.py): a run killed at
any chunk boundary, restored, and continued produces the *bit-identical*
error trace, iterate, and comm ledger of the uninterrupted run.  Three
things make this exact rather than approximate:

* chunking a ``lax.scan`` is exact — the chunk program is compiled from the
  same outer body, and XLA's per-iteration arithmetic does not depend on
  the scan length (verified bitwise on CPU);
* the async RNG key rides in ``RunState`` — each outer iteration's awake
  draw depends only on the carried key, so the restored run continues the
  straggler realization mid-stream with no replay;
* the async ledger is derived from the (T_o, ...) send/count buffers in
  ``RunState``, not from host accumulation, so it survives the crash too.

A corrupt or half-written latest checkpoint (crashed writer) is skipped:
``_restore_any`` walks the manager's steps newest-first and falls back to
the newest restorable snapshot, or a fresh start.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..core.fdot import (FDOTResult, _fdot_async_outer_body, _fdot_outer_body,
                         _prepare_fdot, unpad_feature_slabs)
from ..core.metrics import CommLedger
from ..core.sdot import (SDOTResult, _async_outer_body, _prepare_sdot,
                         _sync_outer_body)

__all__ = ["RunState", "sdot_chunked", "fdot_chunked"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RunState:
    """Everything a fused run needs to continue from a chunk boundary.

    Registered pytree: checkpoints through ``checkpoint/manager.py`` with no
    ad-hoc field plucking, and flows through the jitted chunk programs as a
    native container. Sync runs carry zero-size send/count buffers; async
    runs carry the full (T_o, ...) stacked outputs so the realized ledger
    survives a crash.
    """

    q: jnp.ndarray            # (N, d, r) iterate (padded slabs for F-DOT)
    key: jnp.ndarray          # async RNG carry (zeros for sync runs)
    step: jnp.ndarray         # () int32 — outer iterations completed
    errs: jnp.ndarray         # (T_o,) error-trace buffer, filled up to step
    sends: jnp.ndarray        # async (T_o, ...) per-round sends, else (T_o, 0)
    counts: jnp.ndarray       # async (T_o, ...) awake counts, else (T_o, 0)

    def tree_flatten(self):
        return ((self.q, self.key, self.step, self.errs, self.sends,
                 self.counts), None)

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)


def _init_state(q0, key, t_outer: int, tail_shape=()) -> RunState:
    return RunState(
        q=q0,
        key=(key if key is not None else jnp.zeros((), jnp.uint32)),
        step=jnp.int32(0),
        errs=jnp.zeros((t_outer,), jnp.float32),
        sends=jnp.zeros((t_outer,) + tail_shape, jnp.float32),
        counts=jnp.zeros((t_outer,) + tail_shape, jnp.float32),
    )


# ---------------------------------------------------------------------------
# jitted chunk programs (one compile per distinct chunk length)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("mode", "t_max", "trace_err"))
def _sdot_sync_chunk(state, operand, w, table, sched_chunk, q_true, node_mask,
                     *, mode: str, t_max: int, trace_err: bool):
    outer = _sync_outer_body(operand, w, table, q_true, node_mask,
                             mode=mode, t_max=t_max, trace_err=trace_err)
    q, errs_c = jax.lax.scan(outer, state.q, sched_chunk)
    return dataclasses.replace(
        state, q=q,
        step=state.step + sched_chunk.shape[0],
        errs=jax.lax.dynamic_update_slice(state.errs, errs_c, (state.step,)))


@functools.partial(jax.jit, static_argnames=("mode", "t_max", "trace_err"))
def _sdot_async_chunk(state, operand, w, adj, p_awake, sched_chunk, q_true,
                      *, mode: str, t_max: int, trace_err: bool):
    outer = _async_outer_body(operand, w, adj, p_awake, q_true,
                              mode=mode, t_max=t_max, trace_err=trace_err)
    (q, key), (errs_c, sends_c, counts_c) = jax.lax.scan(
        outer, (state.q, state.key), sched_chunk)
    at = (state.step,) + (0,) * (state.sends.ndim - 1)
    return RunState(
        q=q, key=key, step=state.step + sched_chunk.shape[0],
        errs=jax.lax.dynamic_update_slice(state.errs, errs_c, (state.step,)),
        sends=jax.lax.dynamic_update_slice(state.sends, sends_c, at),
        counts=jax.lax.dynamic_update_slice(state.counts, counts_c, at))


@functools.partial(jax.jit,
                   static_argnames=("t_max", "t_c_qr", "passes", "trace_err"))
def _fdot_sync_chunk(state, x_pad, w, table, sched_chunk, qtrue_pad,
                     *, t_max: int, t_c_qr: int, passes: int,
                     trace_err: bool):
    outer = _fdot_outer_body(x_pad, w, table, qtrue_pad, t_max=t_max,
                             t_c_qr=t_c_qr, passes=passes,
                             trace_err=trace_err)
    q, errs_c = jax.lax.scan(outer, state.q, sched_chunk)
    return dataclasses.replace(
        state, q=q,
        step=state.step + sched_chunk.shape[0],
        errs=jax.lax.dynamic_update_slice(state.errs, errs_c, (state.step,)))


@functools.partial(jax.jit,
                   static_argnames=("t_max", "t_c_qr", "passes", "trace_err"))
def _fdot_async_chunk(state, x_pad, w, adj, p_awake, sched_chunk, qtrue_pad,
                      *, t_max: int, t_c_qr: int, passes: int,
                      trace_err: bool):
    outer = _fdot_async_outer_body(x_pad, w, adj, p_awake, qtrue_pad,
                                   t_max=t_max, t_c_qr=t_c_qr, passes=passes,
                                   trace_err=trace_err)
    (q, key), (errs_c, sends_c, counts_c) = jax.lax.scan(
        outer, (state.q, state.key), sched_chunk)
    at = (state.step,) + (0,) * (state.sends.ndim - 1)
    return RunState(
        q=q, key=key, step=state.step + sched_chunk.shape[0],
        errs=jax.lax.dynamic_update_slice(state.errs, errs_c, (state.step,)),
        sends=jax.lax.dynamic_update_slice(state.sends, sends_c, at),
        counts=jax.lax.dynamic_update_slice(state.counts, counts_c, at))


# ---------------------------------------------------------------------------
# restore / drive helpers
# ---------------------------------------------------------------------------
def _restore_any(manager: Optional[CheckpointManager], like: RunState):
    """Newest restorable snapshot, skipping corrupt/half-written steps.

    A crashed writer can leave the latest step directory unreadable (the
    manager's atomic rename protects against *partial* publishes, but a
    torn disk or an operator cp can still corrupt shards). Walk the steps
    newest-first; the first one that restores wins; none -> fresh start."""
    if manager is None:
        return None
    steps = manager.all_steps()
    for step in reversed(steps):
        try:
            state, _ = manager.restore(like, step=step)
        except Exception:
            continue
        # restore_tree checks tree structure, not shapes — a snapshot from
        # a run with a different t_outer (or engine size) unflattens fine
        # but its buffers are the wrong length; reject it here so stale
        # directories can't silently produce truncated/overwritten traces
        shapes_ok = all(jax.tree.leaves(jax.tree.map(
            lambda a, b: np.shape(a) == np.shape(b), state, like)))
        if shapes_ok:
            return state
    if steps:
        # every snapshot rejected — distinguish "fresh directory" from a
        # probable operator error (e.g. resuming with a different t_outer
        # or engine shape, which changes the RunState buffer shapes)
        warnings.warn(
            f"{len(steps)} checkpoint step(s) in {manager.root} exist but "
            "none restored against this run's RunState shapes — starting "
            "from iteration 0 (wrong t_outer / engine for this directory?)")
    return None


def _drive_chunks(state: RunState, t_outer: int, chunk_size: int,
                  run_chunk, manager: Optional[CheckpointManager],
                  max_chunks: Optional[int]) -> RunState:
    """The outer chunk loop: scan a chunk, checkpoint, repeat.

    The completed-step counter is mirrored on the host (read from the
    device exactly once, at restore) so chunk programs enqueue back-to-back
    with NO per-chunk device sync — without checkpointing, a chunked run is
    pure dispatch pipelining over the monolithic scan. Saves are async
    (``blocking=False``) so serialization overlaps the next chunk's
    compute; the manager's atomic rename guarantees a kill mid-save leaves
    the previous step intact. ``max_chunks`` lets tests and benchmarks
    simulate a job killed at a chunk boundary."""
    step = int(state.step)                   # the one host sync (restore)
    done = 0
    while step < t_outer:
        if max_chunks is not None and done >= max_chunks:
            break
        length = min(chunk_size, t_outer - step)
        state = run_chunk(state, step, length)
        step += length
        if manager is not None:
            manager.save(step, state, blocking=False)
        done += 1
    if manager is not None:
        manager.wait()
    return state


def _async_ledger(sched_np, sends, counts, payload_fn, slices) -> CommLedger:
    """Rebuild the realized async ledger from the RunState buffers."""
    ledger = CommLedger()
    sends_np = np.asarray(sends, np.float64)
    counts_np = np.asarray(counts)
    total = float(sends_np.sum())
    ledger.p2p += total
    ledger.matrices += total
    ledger.scalars += payload_fn(sends_np)
    for t in range(len(sched_np)):
        for sl, rounds in slices(int(sched_np[t])):
            ledger.log_awake_rounds(counts_np[t][sl][:rounds])
    return ledger


def sdot_chunked(
    *,
    covs=None,
    data: Optional[Sequence[jnp.ndarray]] = None,
    engine,
    r: int,
    t_outer: int,
    schedule: Optional[np.ndarray] = None,
    t_c: int = 50,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
    chunk_size: int = 10,
    manager: Optional[CheckpointManager] = None,
    max_chunks: Optional[int] = None,
) -> SDOTResult:
    """Chunked-resumable S-DOT/SA-DOT: the fused run, restartable.

    Same contract as ``core.sdot.sdot(fused=True)`` — bit-identical trace,
    iterate, and ledger — but the whole-run scan is executed
    ``chunk_size`` outer iterations at a time with the ``RunState``
    checkpointed through ``manager`` at every chunk boundary.  If
    ``manager`` already holds a snapshot of this run, execution resumes
    from it (callers own directory hygiene: one run per checkpoint root).
    ``max_chunks`` stops after that many chunks (simulating a killed job)
    — the return value then covers only the completed prefix.
    """
    prep = _prepare_sdot(covs=covs, data=data, engine=engine, r=r,
                         t_outer=t_outer, schedule=schedule, t_c=t_c,
                         q_init=q_init, q_true=q_true, seed=seed)
    operand, mode = prep["operand"], prep["mode"]
    t_max, trace_err, q_arg = prep["t_max"], prep["trace_err"], prep["q_arg"]
    sched_np = prep["sched_np"]
    is_async = prep["is_async"]
    n = prep["n"]

    if is_async:
        like = _init_state(prep["q_nodes"], engine._key, t_outer, (t_max,))
        p_awake = jnp.asarray(engine.p_awake, jnp.float32)

        def run_chunk(state, k0, length):
            return _sdot_async_chunk(
                state, operand, engine._w, engine._adj, p_awake,
                jnp.asarray(sched_np[k0:k0 + length], jnp.int32), q_arg,
                mode=mode, t_max=t_max, trace_err=trace_err)
    else:
        if not hasattr(engine, "debias_table"):
            raise ValueError("sdot_chunked needs a fused-capable engine "
                             "(debias_table) or an async engine")
        like = _init_state(prep["q_nodes"], None, t_outer)
        table = engine.debias_table(t_max)
        ones = jnp.ones((n,), jnp.float32)

        def run_chunk(state, k0, length):
            return _sdot_sync_chunk(
                state, operand, engine._w, table,
                jnp.asarray(sched_np[k0:k0 + length], jnp.int32), q_arg,
                ones, mode=mode, t_max=t_max, trace_err=trace_err)

    state = _restore_any(manager, like) or like
    state = _drive_chunks(state, t_outer, chunk_size, run_chunk, manager,
                          max_chunks)
    done = int(state.step)

    ledger = CommLedger()
    payload = prep["d"] * r
    if is_async:
        if done == t_outer:
            engine._key = state.key   # same stream position as the fused run
        ledger = _async_ledger(
            sched_np[:done], state.sends[:done], state.counts[:done],
            lambda s: float(s.sum()) * payload,
            lambda t_c_t: [(slice(None), t_c_t)])
    else:
        ledger.log_gossip_rounds(sched_np[:done], engine.graph.adjacency,
                                 payload)
    return SDOTResult(
        q_nodes=state.q,
        error_trace=(np.asarray(state.errs[:done]) if trace_err else None),
        consensus_trace=sched_np[:done],
        ledger=ledger,
    )


def fdot_chunked(
    *,
    data_blocks: Sequence[jnp.ndarray],
    engine,
    r: int,
    t_outer: int,
    t_c: int = 50,
    t_c_qr: Optional[int] = None,
    schedule: Optional[np.ndarray] = None,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
    chunk_size: int = 10,
    manager: Optional[CheckpointManager] = None,
    max_chunks: Optional[int] = None,
) -> FDOTResult:
    """Chunked-resumable F-DOT: ``core.fdot.fdot(fused=True)``, restartable.

    Same resume contract as ``sdot_chunked`` (bit-identical trace / slabs /
    ledger across kill-and-restore at chunk boundaries), including async
    engines — the three-per-iteration RNG splits ride in the checkpointed
    key."""
    prep = _prepare_fdot(data_blocks=data_blocks, engine=engine, r=r,
                         t_outer=t_outer, t_c=t_c, t_c_qr=t_c_qr,
                         schedule=schedule, q_init=q_init, q_true=q_true,
                         seed=seed)
    x_pad, q0_pad, qtrue_pad = prep["pads"]()
    t_max, t_c_qr, passes = prep["t_max"], prep["t_c_qr"], prep["passes"]
    trace_err, is_async = prep["trace_err"], prep["is_async"]
    sched_np = prep["schedule"]

    if is_async:
        like = _init_state(q0_pad, engine._key, t_outer,
                           (1 + passes, t_max))
        p_awake = jnp.asarray(engine.p_awake, jnp.float32)

        def run_chunk(state, k0, length):
            return _fdot_async_chunk(
                state, x_pad, engine._w, engine._adj, p_awake,
                jnp.asarray(sched_np[k0:k0 + length], jnp.int32), qtrue_pad,
                t_max=t_max, t_c_qr=t_c_qr, passes=passes,
                trace_err=trace_err)
    else:
        if not hasattr(engine, "debias_table"):
            raise ValueError("fdot_chunked needs a fused-capable engine "
                             "(debias_table) or an async engine")
        like = _init_state(q0_pad, None, t_outer)
        table = engine.debias_table(t_max)

        def run_chunk(state, k0, length):
            return _fdot_sync_chunk(
                state, x_pad, engine._w, table,
                jnp.asarray(sched_np[k0:k0 + length], jnp.int32), qtrue_pad,
                t_max=t_max, t_c_qr=t_c_qr, passes=passes,
                trace_err=trace_err)

    state = _restore_any(manager, like) or like
    state = _drive_chunks(state, t_outer, chunk_size, run_chunk, manager,
                          max_chunks)
    done = int(state.step)

    n_samples, d = prep["n_samples"], prep["d"]
    adj = engine.graph.adjacency
    ledger = CommLedger()
    if is_async:
        if done == t_outer:
            engine._key = state.key
        ledger = _async_ledger(
            sched_np[:done], state.sends[:done], state.counts[:done],
            lambda s: (float(s[:, 0].sum()) * n_samples * r
                       + float(s[:, 1:].sum()) * r * r),
            lambda t_c_t: [((0,), t_c_t)] + [((1 + p,), t_c_qr)
                                             for p in range(passes)])
    else:
        ledger.log_gossip_rounds(sched_np[:done], adj, n_samples * r)
        ledger.log_gossip_rounds(np.full(done, passes * t_c_qr), adj, r * r)
    return FDOTResult(
        q_blocks=unpad_feature_slabs(state.q, prep["dims"]),
        error_trace=(np.asarray(state.errs[:done]) if trace_err else None),
        ledger=ledger,
    )
