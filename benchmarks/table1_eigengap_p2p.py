"""Table I — P2P communications, S-DOT vs SA-DOT, across eigengaps.

Paper setting: N=20, ER p=0.25, r=5, T_o=200, consensus schedules
{ceil(0.5t+1), t+1, 2t+1, 50}; data d=20, n_i=500 per node.
"""
from __future__ import annotations

from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.sdot import sdot
from repro.core.topology import erdos_renyi

from .common import PAPER_SCHEDULES, Row, p2p_per_node_k, sample_problem, timed

N, P, R, T_O, D, N_PER = 20, 0.25, 5, 200, 20, 500


def run():
    rows = []
    g = erdos_renyi(N, P, seed=1)
    eng = DenseConsensus(g)
    for gap in (0.3, 0.7, 0.9):
        covs, q_true = sample_problem(d=D, r=R, n_nodes=N, n_per=N_PER,
                                      gap=gap, seed=0)
        for label, (kind, cap) in PAPER_SCHEDULES.items():
            sched = consensus_schedule(kind, T_O, t_max=50, cap=cap)
            res, us = timed(sdot, covs=covs, engine=eng, r=R, t_outer=T_O,
                            schedule=sched, q_true=q_true)
            rows.append(Row(
                f"table1/gap{gap}/Tc={label}", us,
                {"p2p_k": round(res.ledger.per_node_p2p(N) / 1e3, 2),
                 "p2p_k_model": round(p2p_per_node_k(g, int(sched.sum())), 2),
                 "final_err": f"{res.error_trace[-1]:.2e}"}))
    return rows
