"""Pallas TPU kernel: fused gram-apply  V = X (X^T Q).

This is the compute hot spot of S-DOT (Alg. 1, Step 5): every node applies
its local covariance M_i = X_i X_i^T / n_i to the subspace iterate Q. For
large d, materializing M_i (d x d) is HBM-hostile; the fused form streams X
through VMEM once per column-block and performs two MXU matmuls per tile:

    for each column block X_b (d x bn):   S_b = X_b^T Q   (bn x r)
                                          V  += X_b S_b   (d  x r)

Arithmetic intensity: 4*d*bn*r flops per (d*bn + d*r) * bytes moved — for
r = 128 this is comfortably compute-bound on the MXU.

Grid layout: (n_blocks,) outer sequential grid walks column blocks; the
(d x r) output block is revisited every step and accumulated in VMEM
(TPU grids are sequential, so accumulation over the grid is safe). Both d and
r must be padded to multiples of 128 by the wrapper (ops.py); bn is the
column tile, chosen so (d*bn + d*r + bn*r) * 4 bytes fits VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gram_apply_pallas", "batched_gram_apply_pallas"]


def _gram_kernel(x_ref, q_ref, v_ref):
    """One grid step: accumulate X_b (X_b^T Q) into the output block."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        v_ref[...] = jnp.zeros_like(v_ref)

    x = x_ref[...]          # (d, bn)
    q = q_ref[...]          # (d, r)
    s = jax.lax.dot_general(
        x, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # X_b^T Q: (bn, r)
    v = jax.lax.dot_general(
        x, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # X_b S: (d, r)
    v_ref[...] += v.astype(v_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gram_apply_pallas(x: jnp.ndarray, q: jnp.ndarray, *, block_n: int = 512,
                      interpret: bool = False) -> jnp.ndarray:
    """V = X (X^T Q); shapes (d, n) x (d, r) -> (d, r), n % block_n == 0.

    Call through ops.gram_apply which pads/normalizes and picks block sizes.
    """
    d, n = x.shape
    d2, r = q.shape
    assert d == d2, "x and q must share the feature dimension"
    assert n % block_n == 0, "ops.py pads n to a block multiple"
    n_blocks = n // block_n

    out = pl.pallas_call(
        _gram_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((d, block_n), lambda j: (0, j)),   # X column block
            pl.BlockSpec((d, r), lambda j: (0, 0)),         # Q (resident)
        ],
        out_specs=pl.BlockSpec((d, r), lambda j: (0, 0)),   # V (accumulated)
        out_shape=jax.ShapeDtypeStruct((d, r), jnp.float32),
        interpret=interpret,
    )(x, q)
    return out


def _batched_gram_kernel(x_ref, q_ref, v_ref):
    """One (i, j) grid step: accumulate X_{i,b} (X_{i,b}^T Q_i) into V_i.

    The column-block index j is the fast (innermost) grid dimension, so each
    node's output block is revisited j = 0..n_blocks-1 consecutively —
    sequential TPU grids make the accumulation safe; init happens at j == 0.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        v_ref[...] = jnp.zeros_like(v_ref)

    x = x_ref[0]            # (d, bn) — node i's column block
    q = q_ref[0]            # (d, r)  — node i's iterate
    s = jax.lax.dot_general(
        x, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # X_b^T Q: (bn, r)
    v = jax.lax.dot_general(
        x, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # X_b S: (d, r)
    v_ref[0, ...] += v.astype(v_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def batched_gram_apply_pallas(x_stack: jnp.ndarray, q_stack: jnp.ndarray, *,
                              block_n: int = 512,
                              interpret: bool = False) -> jnp.ndarray:
    """V[i] = X_i (X_i^T Q_i) for all nodes in one kernel launch.

    x_stack: (N, d, n) zero-padded node data (ragged n_i padded to a common
    n — exact, padded columns contribute X_b S_b = 0); q_stack: (N, d, r).
    Grid is (node, column-block); one launch replaces N separate gram-apply
    dispatches, which is what lets the whole S-DOT scan body stay fused.
    Call through ops.batched_gram_apply, which pads and normalizes by the
    true per-node sample counts.
    """
    n_nodes, d, n = x_stack.shape
    n2, d2, r = q_stack.shape
    assert n_nodes == n2 and d == d2, "x_stack and q_stack must align"
    assert n % block_n == 0, "ops.py pads n to a block multiple"
    n_blocks = n // block_n

    out = pl.pallas_call(
        _batched_gram_kernel,
        grid=(n_nodes, n_blocks),
        in_specs=[
            pl.BlockSpec((1, d, block_n), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, d, r), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d, r), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_nodes, d, r), jnp.float32),
        interpret=interpret,
    )(x_stack, q_stack)
    return out
