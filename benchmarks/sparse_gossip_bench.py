"""Sparse-topology gossip at scale: SparseW/ELL-SpMM vs the dense einsum.

The paper's experiments (and this repo's table reproductions) run on
N <= 200 node overlays where a dense (N, N) mixing matrix is free. The
overlay families the connectivity tradeoffs are *about* — small-world
(Watts-Strogatz), scale-free (Barabasi-Albert), geometric (RGG) — have
O(N) edges at the 1k-10k-node scale, so dense mixing pays O(N^2 k) per
round for >99% zeros. This benchmark measures what ``SparseW`` mixing
(kernels/ops.ell_spmm: Pallas ELL kernel on TPU, gather/einsum fallback
on CPU) buys over the dense einsum across N x topology:

* **walltime grid** — N in {200, 1000, 4000, 10000} x {ws, ba, rgg}:
  best-of interleaved walltime of ``t_c`` gossip rounds on a (N, K)
  payload, dense vs sparse engine (identical graphs and weights), plus
  the deterministic weight-storage footprint (dense N^2 f32 vs ELL
  idx+val+diag+nnz) — the memory axis of the tradeoff. Acceptance
  (full run): sparse wins at every N >= 4000 on at least one topology,
  and never loses by more than 1.2x at N = 200.
* **bf16 accuracy-vs-bytes curve** — consensus-sum (``run_debiased``)
  on WS(1000) for growing round budgets, f32 vs bf16 gossip payloads:
  relative error against the exact sum vs the comm ledger's
  ``payload_bytes`` (priced at 2 bytes/elem for bf16 — the ledger is
  the source of truth for the bytes axis). bf16 halves the wire bytes
  and floors at quantization error; f32 keeps converging.
* **equivalence guard** — every timed pair also checks dense and
  sparse outputs agree to f32 tolerance, so the speedup is never
  measured against a wrong answer.

Usage:
    PYTHONPATH=src python -m benchmarks.sparse_gossip_bench [--smoke]

Writes BENCH_sparse_gossip.json (or .smoke.json) at the repo root. The
smoke run covers N in {200, 1000} on WS only with the assertions relaxed
to the equivalence guard (CI containers jitter too much for timing
gates).
"""
from __future__ import annotations

import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.consensus import DenseConsensus
from repro.core.metrics import CommLedger
from repro.core.topology import (barabasi_albert, random_geometric,
                                 watts_strogatz)
from repro.kernels.ops import ell_spmm_path

from .common import interleaved_best_of

K = 16                 # payload columns per node (d*r-style block, flattened)
TOPOLOGIES = {
    "ws": lambda n: watts_strogatz(n, k=6, p=0.1, seed=1),
    "ba": lambda n: barabasi_albert(n, m=3, seed=1),
    "rgg": lambda n: random_geometric(n, seed=1),
}


def _weight_bytes(eng: DenseConsensus) -> int:
    """Deterministic device-weight footprint (the memory axis)."""
    if eng.is_sparse:
        sw = eng._w
        mirror = 0 if sw.dense_off is None else sw.dense_off.size * 4
        return int(sw.ell_idx.size * 4 + sw.ell_val.size * 4
                   + sw.diag.size * 4 + sw.row_nnz.size * 4 + mirror)
    n = eng.graph.n_nodes
    return n * n * 4


def _time_pair(graph, t_c: int, repeats: int, seed: int):
    """Best-of walltime of t_c gossip rounds, dense vs sparse engine."""
    n = graph.n_nodes
    dense = DenseConsensus(graph, sparse=False)
    sparse = DenseConsensus(graph, sparse=True)
    z = jnp.asarray(np.random.default_rng(seed)
                    .standard_normal((n, K)).astype(np.float32))
    run_d = lambda: dense.run(z, t_c)
    run_s = lambda: sparse.run(z, t_c)
    outs = (jax.block_until_ready(run_d()),
            jax.block_until_ready(run_s()))          # compile both
    gap = float(jnp.max(jnp.abs(outs[0] - outs[1])))
    scale = float(jnp.max(jnp.abs(outs[0]))) + 1e-12
    best, _ = interleaved_best_of(
        [("dense", run_d), ("sparse", run_s)], repeats=repeats,
        sync=jax.block_until_ready)
    sw = sparse._w
    return {
        "n": n,
        "t_c": t_c,
        "density": round(graph.density, 6),
        "ell_width": sw.ell_width,
        "nnz": sw.nnz,
        "kernel_path": ell_spmm_path(n, sw.ell_width, K),
        "dense_ms": round(best["dense"] * 1e3, 3),
        "sparse_ms": round(best["sparse"] * 1e3, 3),
        "speedup_x": round(best["dense"] / best["sparse"], 3),
        "dense_weight_bytes": _weight_bytes(dense),
        "sparse_weight_bytes": _weight_bytes(sparse),
        "weight_bytes_ratio": round(_weight_bytes(dense)
                                    / _weight_bytes(sparse), 1),
        "rel_gap": gap / scale,
    }


def _bf16_curve(n: int, budgets, seed: int):
    """Consensus-sum accuracy vs ledger wire bytes, f32 vs bf16 payloads.

    Uses a well-connected small-world overlay (spectral gap ~0.34, so the
    budget range actually spans unconverged -> converged): f32 keeps
    converging toward the exact sum while bf16 floors at quantization
    error having moved HALF the wire bytes per round.
    """
    g = watts_strogatz(n, k=20, p=0.5, seed=1)
    z = jnp.asarray(np.random.default_rng(seed)
                    .standard_normal((n, K)).astype(np.float32))
    true_sum = np.asarray(z, np.float64).sum(axis=0)
    rows = []
    for payload in (None, "bfloat16"):
        eng = DenseConsensus(g, sparse=True, payload_dtype=payload)
        for t_c in budgets:
            ledger = CommLedger()
            out = eng.run_debiased(z, t_c, ledger)
            err = np.asarray(out, np.float64) - true_sum[None, :]
            rel = float(np.sqrt((err ** 2).mean())
                        / np.sqrt((true_sum ** 2).mean()))
            rows.append({
                "mode": "f32" if payload is None else "bf16",
                "t_c": t_c,
                "rel_err": rel,
                "payload_bytes": ledger.payload_bytes,
                "bytes_per_elem": eng.payload_bytes_per_elem,
            })
    return rows


def run_bench(smoke: bool = False):
    if smoke:
        grid = [(200, "ws"), (1000, "ws")]
        budgets = (8, 32)
    else:
        grid = [(n, t) for n in (200, 1000, 4000, 10000)
                for t in ("ws", "ba", "rgg")]
        budgets = (8, 16, 32, 64)

    walltime = []
    for n, topo in grid:
        # more rounds + repeats at small N to integrate over timer noise;
        # fewer at 10k where a single dense run is already seconds
        t_c = 50 if n <= 200 else (20 if n <= 1000 else (10 if n <= 4000
                                                         else 5))
        repeats = 5 if n <= 1000 else (3 if n <= 4000 else 2)
        if smoke:
            t_c, repeats = min(t_c, 10), 2
        row = _time_pair(TOPOLOGIES[topo](n), t_c, repeats, seed=n)
        row["topology"] = topo
        walltime.append(row)
        print(f"# {topo} n={n}: dense {row['dense_ms']}ms "
              f"sparse {row['sparse_ms']}ms ({row['speedup_x']}x), "
              f"L={row['ell_width']}, {row['kernel_path']}",
              file=sys.stderr)
        assert row["rel_gap"] <= 1e-4, row   # equivalence guard, all runs

    results = {"walltime_grid": walltime,
               "bf16_curve": _bf16_curve(1000 if not smoke else 200,
                                         budgets, seed=3)}

    if not smoke:
        for n in (4000, 10000):
            wins = [r for r in walltime if r["n"] == n
                    and r["speedup_x"] > 1.0]
            assert wins, f"sparse never beat dense at n={n}: " + json.dumps(
                [r for r in walltime if r["n"] == n])
        for r in walltime:
            if r["n"] == 200:
                assert r["speedup_x"] >= 1.0 / 1.2, r
        # bf16 moves half the bytes of f32 for the same budget
        by_mode = {m: [r for r in results["bf16_curve"] if r["mode"] == m]
                   for m in ("f32", "bf16")}
        for rf, rb in zip(by_mode["f32"], by_mode["bf16"]):
            assert rb["payload_bytes"] == rf["payload_bytes"] / 2.0, (rf, rb)
    return results


def main():
    smoke = "--smoke" in sys.argv
    out = {
        "bench": "sparse_gossip",
        "scale": {"payload_cols": K,
                  "topologies": {k: ("ws(k=6,p=0.1)" if k == "ws" else
                                     "ba(m=3)" if k == "ba" else
                                     "rgg(default radius)")
                                 for k in TOPOLOGIES}},
        "smoke": smoke,
        "backend": jax.default_backend(),
        "results": run_bench(smoke=smoke),
    }
    print(json.dumps(out, indent=2))
    name = ("BENCH_sparse_gossip.smoke.json" if smoke
            else "BENCH_sparse_gossip.json")
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
