"""Shared fixtures. NOTE: deliberately does NOT set
--xla_force_host_platform_device_count — smoke tests and benches must see the
single real CPU device; SPMD tests spawn subprocesses that set it themselves.
"""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def psa_problem():
    """Standard small PSA problem: d=20, r=5, N=10 nodes, gap 0.7."""
    import jax.numpy as jnp
    from repro.data.pipeline import gaussian_eigengap_data, partition_samples

    d, r, n_nodes, n_per = 20, 5, 10, 500
    x, c, q_pop = gaussian_eigengap_data(d, n_nodes * n_per, r, 0.7, seed=0)
    blocks = partition_samples(x, n_nodes)
    covs = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
    # ground truth of the *sample* covariance (what the algorithms estimate)
    m = covs.sum(0)
    from repro.core.linalg import eigh_topr
    _, q_true = eigh_topr(m, r)
    return dict(d=d, r=r, n_nodes=n_nodes, x=x, blocks=blocks, covs=covs,
                m=m, q_true=q_true, q_pop=q_pop)


@pytest.fixture(scope="session")
def er_engine(psa_problem):
    from repro.core.consensus import DenseConsensus
    from repro.core.topology import erdos_renyi

    g = erdos_renyi(psa_problem["n_nodes"], 0.5, seed=1)
    return DenseConsensus(g)
