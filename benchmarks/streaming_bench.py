"""Streaming subsystem benchmark: chunked-scan overhead + launcher scaling.

Two acceptance measurements for the streaming PSA subsystem:

1. **Chunked vs monolithic** — the chunked-resumable executor
   (streaming/resume.py) replays the monolithic whole-run scan bit for bit;
   this benchmark prices the operational win (restartability) in walltime:
   chunk-boundary dispatches only (no checkpointing), and with atomic
   async checkpoints at every chunk boundary.  Bar: chunking alone must
   cost < 10% over the monolithic scan.

2. **Launcher vs single process** — the multi-host sweep launcher
   (streaming/launcher.py) shards the seed grid over subprocess workers;
   its merged result must equal the single-process ``sdot_sweep`` output
   exactly (asserted here on every run), and the walltimes show where
   process sharding starts paying (worker interpreter + compile startup is
   the constant cost the fleet amortizes).

Usage:
    PYTHONPATH=src python -m benchmarks.streaming_bench [--smoke]
    PYTHONPATH=src python -m benchmarks.run streaming_bench

Writes BENCH_streaming.json (or .smoke.json) next to the repo root.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import sys
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.sdot import sdot
from repro.core.sweep import sdot_sweep
from repro.core.topology import erdos_renyi
from repro.streaming.launcher import build_engine, build_schedule, launch_sweep
from repro.streaming.resume import sdot_chunked

from .common import Row, interleaved_best_of, sample_problem

N, R = 20, 5


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out.q_nodes if hasattr(out, "q_nodes") else out)
    return time.perf_counter() - t0, out


def bench_chunked(d, t_outer, chunk_size, repeats):
    covs, q_true = sample_problem(d=d, r=R, n_nodes=N, n_per=200, gap=0.7,
                                  seed=0)
    eng = DenseConsensus(erdos_renyi(N, 0.25, seed=1))
    sched = consensus_schedule("const", t_outer, t_max=50)
    mono = lambda: sdot(covs=covs, engine=eng, r=R, t_outer=t_outer,
                        schedule=sched, q_true=q_true)
    chunked = lambda mgr: sdot_chunked(covs=covs, engine=eng, r=R,
                                       t_outer=t_outer, schedule=sched,
                                       q_true=q_true, chunk_size=chunk_size,
                                       manager=mgr)
    _timed(mono)                                     # warmup compile
    _timed(lambda: chunked(None))
    ckpt_dir = tempfile.mkdtemp(prefix="bench_ckpt_")

    def with_ckpt():
        shutil.rmtree(ckpt_dir, ignore_errors=True)
        return chunked(CheckpointManager(ckpt_dir, keep_last=2))

    # Phase 1 — the <10% acceptance bar: mono vs chunked (no disk),
    # interleaved with a rotating order (common.interleaved_best_of) so
    # machine noise hits both equally; best-of.
    # Phase 2 — checkpointing cost, measured afterwards: its disk writes
    # (page-cache churn) would otherwise poison the phase-1 measurements.
    sync = lambda out: jax.block_until_ready(out.q_nodes)
    try:
        best, results = interleaved_best_of(
            [("mono", mono), ("chunk", lambda: chunked(None))],
            repeats, sync=sync)
        best_ckpt, out_ckpt = interleaved_best_of(
            [("ckpt", with_ckpt)], repeats, sync=sync)
        best.update(best_ckpt)
        results.update(out_ckpt)
        np.testing.assert_array_equal(results["mono"].error_trace,
                                      results["chunk"].error_trace)
        np.testing.assert_array_equal(results["mono"].error_trace,
                                      results["ckpt"].error_trace)
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    mono_s, chunk_s, ckpt_s = best["mono"], best["chunk"], best["ckpt"]
    mres = results["mono"]

    return {
        "case": f"chunked/d{d}/To{t_outer}/chunk{chunk_size}",
        "monolithic_ms": round(mono_s * 1e3, 2),
        "chunked_ms": round(chunk_s * 1e3, 2),
        "chunked_ckpt_ms": round(ckpt_s * 1e3, 2),
        "chunk_overhead_pct": round((chunk_s / mono_s - 1.0) * 100, 2),
        "ckpt_overhead_pct": round((ckpt_s / mono_s - 1.0) * 100, 2),
        "chunks": -(-t_outer // chunk_size),
        "final_err": float(mres.error_trace[-1]),
    }


def bench_launcher(d, t_outer, n_seeds, n_workers):
    covs, q_true = sample_problem(d=d, r=R, n_nodes=N, n_per=200, gap=0.7,
                                  seed=0)
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.25, "seed": 1},
              "schedule": {"kind": "lin2", "cap": 50}}]
    seeds = list(range(n_seeds))
    engines = [build_engine(c["topology"]) for c in cases]
    schedules = [build_schedule(c["schedule"], t_outer, 50) for c in cases]

    single = lambda: sdot_sweep(covs=covs, engines=engines,
                                schedules=schedules, r=R, t_outer=t_outer,
                                seeds=seeds, q_true=q_true)
    single()                                         # warmup compile
    t0 = time.perf_counter()
    ref = single()
    jax.block_until_ready(ref.q)
    single_s = time.perf_counter() - t0

    workdir = tempfile.mkdtemp(prefix="bench_launch_")
    try:
        t0 = time.perf_counter()
        sw = launch_sweep(covs=covs, cases=cases, r=R, t_outer=t_outer,
                          seeds=seeds, q_true=q_true, workdir=workdir,
                          n_workers=n_workers)
        launch_s = time.perf_counter() - t0
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    # acceptance: the merged multi-process result equals the single-process
    # sweep. Lane-slices are arithmetically identical; XLA may schedule a
    # width-1 vmap differently, so the bar is float32-epsilon agreement.
    np.testing.assert_allclose(sw.error_traces, ref.error_traces,
                               rtol=1e-6, atol=1e-7)
    assert sw.ledger.p2p == ref.ledger.p2p
    max_dev = float(np.max(np.abs(sw.error_traces - ref.error_traces)))

    return {
        "case": f"launcher/{n_seeds}seeds_x_{n_workers}workers",
        "single_process_ms": round(single_s * 1e3, 2),
        "launcher_ms": round(launch_s * 1e3, 2),
        "launcher_equal": True,
        "launcher_max_trace_dev": max_dev,
        "note": "launcher cost is dominated by per-worker interpreter + "
                "compile startup; equality is the acceptance bar here",
    }


def run_bench(smoke: bool = False):
    if smoke:
        chunk_cases = [bench_chunked(d=20, t_outer=30, chunk_size=10,
                                     repeats=1)]
        launch_cases = [bench_launcher(d=20, t_outer=10, n_seeds=2,
                                       n_workers=2)]
    else:
        # T_o=400 (~0.5 s/run) so the per-chunk dispatch cost is measured
        # against a run long enough to integrate over this container's
        # +-20% throttling jitter
        chunk_cases = [
            bench_chunked(d=100, t_outer=400, chunk_size=40, repeats=7),
            bench_chunked(d=100, t_outer=400, chunk_size=100, repeats=7),
        ]
        launch_cases = [bench_launcher(d=60, t_outer=40, n_seeds=8,
                                       n_workers=4)]
    return chunk_cases + launch_cases


def run():
    """benchmarks.run entry point."""
    rows = []
    for rec in run_bench(smoke=False):
        if rec["case"].startswith("chunked"):
            rows.append(Row(
                f"streaming/{rec['case']}", rec["chunked_ms"] * 1e3,
                {"monolithic_ms": rec["monolithic_ms"],
                 "overhead_pct": rec["chunk_overhead_pct"],
                 "ckpt_overhead_pct": rec["ckpt_overhead_pct"]}))
        else:
            rows.append(Row(
                f"streaming/{rec['case']}", rec["launcher_ms"] * 1e3,
                {"single_process_ms": rec["single_process_ms"],
                 "equal": rec["launcher_equal"]}))
    return rows


def main():
    smoke = "--smoke" in sys.argv
    results = run_bench(smoke=smoke)
    out = {
        "bench": "streaming",
        "scale": {"n_nodes": N, "r": R},
        "smoke": smoke,
        "backend": jax.default_backend(),
        "results": results,
    }
    print(json.dumps(out, indent=2))
    name = "BENCH_streaming.smoke.json" if smoke else "BENCH_streaming.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")
    if not smoke:
        worst = max(r["chunk_overhead_pct"] for r in results
                    if "chunk_overhead_pct" in r)
        if worst > 10.0:
            print(f"# WARNING: chunked overhead {worst}% above the 10% bar")
            sys.exit(1)


if __name__ == "__main__":
    main()
