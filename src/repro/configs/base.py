"""Config schema for every selectable architecture and input shape."""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["MoEConfig", "ModelConfig", "ShapeConfig", "SHAPES", "PSAConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN width
    n_shared_experts: int = 0     # dense experts always active (Kimi-style)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # block pattern, cycled to n_layers. entries:
    #   attn   full causal attention
    #   swa    sliding-window attention (needs window)
    #   mlstm  xLSTM matrix-memory block (chunked linear attention)
    #   slstm  xLSTM scalar-memory block (sequential scan)
    #   rglru  RecurrentGemma gated linear recurrence
    block_pattern: Tuple[str, ...] = ("attn",)
    window: Optional[int] = None
    moe: Optional[MoEConfig] = None
    qkv_bias: bool = False
    attn_out_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    frontend: Optional[str] = None      # None | "vlm_patches" | "audio_codec"
    n_codebooks: int = 1                # audio frontend
    n_prefix_tokens: int = 0            # vlm frontend: image patch tokens
    mlstm_chunk: int = 256              # chunk length for mLSTM linear attn
    dtype: str = "bfloat16"
    # which shapes are valid (long_500k only for sub-quadratic token mixing)
    subquadratic: bool = False
    # int8 KV cache (per-token/head absmax scale) — halves decode cache
    # capacity and read traffic vs bf16 (serving optimization, §Perf)
    kv_quant: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def pattern_for_layers(self) -> Tuple[str, ...]:
        p = self.block_pattern
        assert self.n_layers % len(p) == 0, (
            f"{self.name}: n_layers {self.n_layers} not a multiple of pattern {p}")
        return p

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.block_pattern)

    def param_count(self) -> int:
        """Exact parameter count (eval_shape over the real init, cached)."""
        return _exact_param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        per_blk_all = (m.n_experts + m.n_shared_experts) * 3 * self.d_model * m.d_expert
        per_blk_act = (m.top_k + m.n_shared_experts) * 3 * self.d_model * m.d_expert
        n_moe_blocks = self.n_groups * sum(
            1 for b in self.pattern_for_layers() if b in ("attn", "swa"))
        return self.param_count() - n_moe_blocks * (per_blk_all - per_blk_act)

    def _block_params(self, blk: str) -> int:
        d, hd = self.d_model, self.hd
        n_q, n_kv = self.n_heads, self.n_kv_heads
        per = 2 * d
        if blk in ("attn", "swa"):
            per += d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
            if self.moe is not None:
                m = self.moe
                per += d * m.n_experts
                per += (m.n_experts + m.n_shared_experts) * 3 * d * m.d_expert
            elif self.d_ff > 0:
                per += 3 * d * self.d_ff
        elif blk == "mlstm":
            up = 2 * d
            per += d * 2 * up + up * d + 3 * up
        elif blk == "slstm":
            per += 4 * d * d + d * (4 * d) // 3 * 2
        elif blk == "rglru":
            per += 2 * d * d + 2 * d
            if self.d_ff > 0:
                per += 3 * d * self.d_ff
        return per


@functools.lru_cache(maxsize=None)
def _exact_param_count(cfg: "ModelConfig") -> int:
    import jax
    import numpy as _np
    from ..models.transformer import init_params

    shapes = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32))
    return int(sum(_np.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes)))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class PSAConfig:
    """Config for the paper's technique used as gradient compression."""
    enabled: bool = False
    rank: int = 64                # r — projected gradient rank
    refresh_every: int = 32       # steps between subspace (OI) refreshes
    oi_iters: int = 2             # distributed OI iterations per refresh
    gossip_rounds: int = 4        # cross-pod consensus rounds (S-DOT T_c)
    error_feedback: bool = True
