"""Network topologies and doubly-stochastic weight matrices.

Reproduces the graph constructions used in the paper's experiments
(Erdos-Renyi, ring, star) plus a 2-D torus that models a TPU pod-level
DCI interconnect, and the sparse overlay families the 1k-10k-node regime
is about: Watts-Strogatz small-world, Barabasi-Albert scale-free, and
random-geometric graphs. Weight matrices follow the "local-degree
weights" method of Xiao & Boyd '04 (paper ref [16], the construction the
paper uses for all consensus experiments) and the Metropolis-Hastings
rule.

Spectral quantities (``spectral_gap``, ``mixing_time``) route by size:
exact dense eigendecompositions for the table-scale networks, deflated
power iteration / contraction bounds beyond that — dense ``eigvals`` is
O(N^3) and was the bottleneck before gossip itself at N >= 1000.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "Graph",
    "validate_adjacency",
    "erdos_renyi",
    "ring",
    "star",
    "torus2d",
    "complete",
    "watts_strogatz",
    "barabasi_albert",
    "random_geometric",
    "local_degree_weights",
    "metropolis_weights",
    "mixing_time",
    "spectral_gap",
    "power_iteration_gap",
]


def validate_adjacency(adj: np.ndarray) -> np.ndarray:
    """Check a (N, N) adjacency: square, symmetric, zero diagonal, 0/1.

    Every generator (including the sparse families below) funnels through
    ``Graph``, whose ``__post_init__`` calls this — a malformed topology
    fails at construction, not as a silently non-stochastic weight matrix
    three layers later.
    """
    adj = np.asarray(adj)
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        raise ValueError(f"adjacency must be square, got shape {adj.shape}")
    if not np.array_equal(adj, adj.T):
        raise ValueError("adjacency must be symmetric")
    if np.any(np.diagonal(adj) != 0):
        raise ValueError("adjacency must have a zero diagonal (no self "
                         "loops)")
    if not np.isin(adj, (0, 1)).all():
        raise ValueError("adjacency entries must be 0 or 1")
    return adj


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected graph over N nodes with an adjacency matrix (no self loops)."""

    adjacency: np.ndarray  # (N, N) 0/1 symmetric, zero diagonal

    def __post_init__(self):
        validate_adjacency(self.adjacency)

    @property
    def n_nodes(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1)

    @property
    def n_edges(self) -> int:
        return int(self.adjacency.sum()) // 2

    @property
    def density(self) -> float:
        """Directed-edge fill fraction of the (N, N) matrix (diagonal
        excluded from the numerator) — the quantity the sparse-mixing
        auto-threshold keys on."""
        n = self.n_nodes
        return float(self.adjacency.sum()) / float(n * n) if n else 0.0

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adjacency[i])[0]

    def is_connected(self) -> bool:
        n = self.n_nodes
        seen = np.zeros(n, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            u = stack.pop()
            for v in self.neighbors(u):
                if not seen[v]:
                    seen[v] = True
                    stack.append(int(v))
        return bool(seen.all())


def erdos_renyi(n: int, p: float, seed: int = 0, ensure_connected: bool = True) -> Graph:
    """Erdos-Renyi G(n, p); resamples until connected (as in the paper)."""
    rng = np.random.default_rng(seed)
    for _ in range(10_000):
        upper = rng.random((n, n)) < p
        adj = np.triu(upper, k=1)
        adj = (adj | adj.T).astype(np.float64)
        g = Graph(adj)
        if not ensure_connected or g.is_connected():
            return g
    raise RuntimeError(f"could not sample a connected ER graph (n={n}, p={p})")


def ring(n: int) -> Graph:
    adj = np.zeros((n, n))
    if n >= 3:
        idx = np.arange(n)
        adj[idx, (idx + 1) % n] = 1.0
        adj[(idx + 1) % n, idx] = 1.0
    elif n == 2:
        # a 2-ring degenerates to the single edge (the wrap-around edge IS
        # the forward edge; writing both would double-count it)
        adj[0, 1] = adj[1, 0] = 1.0
    # n <= 1: the empty graph (a 1-ring's wrap-around edge would be a self
    # loop, which Graph forbids)
    return Graph(adj)


def star(n: int) -> Graph:
    adj = np.zeros((n, n))
    adj[0, 1:] = 1.0
    adj[1:, 0] = 1.0
    return Graph(adj)


def torus2d(rows: int, cols: int) -> Graph:
    """2-D torus — the topology of a TPU ICI/DCI slice."""
    n = rows * cols
    adj = np.zeros((n, n))

    def nid(r: int, c: int) -> int:
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            u = nid(r, c)
            for v in (nid(r + 1, c), nid(r, c + 1)):
                if u != v:
                    adj[u, v] = adj[v, u] = 1.0
    return Graph(adj)


def complete(n: int) -> Graph:
    adj = np.ones((n, n)) - np.eye(n)
    return Graph(adj)


def watts_strogatz(n: int, k: int = 4, p: float = 0.1, seed: int = 0,
                   ensure_connected: bool = True) -> Graph:
    """Watts-Strogatz small-world graph: a k-nearest-neighbor ring lattice
    with each edge rewired to a uniform random endpoint with probability
    ``p``. O(N) edges (nk/2), diameter O(log N) for p > 0 — the canonical
    'sparse but fast-mixing' overlay for gossip at large N.
    """
    if k % 2 or k < 2:
        raise ValueError(f"k must be even and >= 2, got {k}")
    if k >= n:
        raise ValueError(f"need k < n, got k={k}, n={n}")
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        adj = np.zeros((n, n))
        for off in range(1, k // 2 + 1):
            idx = np.arange(n)
            adj[idx, (idx + off) % n] = 1.0
            adj[(idx + off) % n, idx] = 1.0
        # rewire each lattice edge (u, u+off) with probability p
        for off in range(1, k // 2 + 1):
            for u in range(n):
                if rng.random() >= p:
                    continue
                v_old = (u + off) % n
                candidates = np.nonzero(adj[u] == 0)[0]
                candidates = candidates[candidates != u]
                if candidates.size == 0:
                    continue
                v_new = int(rng.choice(candidates))
                adj[u, v_old] = adj[v_old, u] = 0.0
                adj[u, v_new] = adj[v_new, u] = 1.0
        g = Graph(adj)
        if not ensure_connected or g.is_connected():
            return g
    raise RuntimeError(f"could not sample a connected WS graph "
                       f"(n={n}, k={k}, p={p})")


def barabasi_albert(n: int, m: int = 2, seed: int = 0) -> Graph:
    """Barabasi-Albert scale-free graph: each arriving node attaches ``m``
    edges preferentially to high-degree nodes (degree distribution
    ~ k^-3). Connected by construction; N*m edges with a few hub rows —
    the worst case for the padded-ELL width and the reason ``SparseW``
    tracks per-row nnz stats.
    """
    if not 1 <= m < n:
        raise ValueError(f"need 1 <= m < n, got m={m}, n={n}")
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n))
    # seed clique over the first m+1 nodes keeps early attachment proper
    for u in range(m + 1):
        for v in range(u + 1, m + 1):
            adj[u, v] = adj[v, u] = 1.0
    # repeated-endpoint list: sampling uniformly from it IS preferential
    # attachment (each node appears once per incident edge)
    targets = [u for u in range(m + 1) for _ in range(m)]
    for u in range(m + 1, n):
        picked: set = set()
        while len(picked) < m:
            picked.add(int(targets[rng.integers(len(targets))]))
        for v in picked:
            adj[u, v] = adj[v, u] = 1.0
            targets.append(v)
        targets.extend([u] * m)
    return Graph(adj)


def random_geometric(n: int, radius: Optional[float] = None, seed: int = 0,
                     ensure_connected: bool = True) -> Graph:
    """Random geometric graph: n uniform points in the unit square,
    connected iff within ``radius``. Default radius is 1.5x the
    connectivity threshold sqrt(log n / (pi n)) — sparse (expected degree
    O(log n)) but connected with high probability; resamples otherwise.
    Models physical-proximity overlays (sensor meshes, rack locality).
    """
    if radius is None:
        radius = 1.5 * np.sqrt(np.log(max(n, 2)) / (np.pi * n))
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        pos = rng.random((n, 2)).astype(np.float32)
        d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
        adj = (d2 <= radius * radius).astype(np.float64)
        np.fill_diagonal(adj, 0.0)
        g = Graph(adj)
        if not ensure_connected or g.is_connected():
            return g
    raise RuntimeError(f"could not sample a connected RGG "
                       f"(n={n}, radius={radius:.4f})")


def local_degree_weights(g: Graph) -> np.ndarray:
    """Doubly-stochastic W via local-degree (max-degree of edge endpoints).

    w_ij = 1 / (1 + max(deg_i, deg_j)) for (i,j) in E, w_ii = 1 - sum_j w_ij.
    This is the construction from Xiao & Boyd used by the paper.
    """
    a = g.adjacency
    deg = g.degrees
    n = g.n_nodes
    w = np.zeros((n, n))
    pair_max = np.maximum(deg[:, None], deg[None, :])
    mask = a > 0
    w[mask] = 1.0 / (1.0 + pair_max[mask])
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def metropolis_weights(g: Graph) -> np.ndarray:
    """Metropolis-Hastings weights: w_ij = 1 / max(deg_i, deg_j).

    The MH acceptance rule applied to the simple random walk (propose
    uniformly over neighbors at rate 1/deg_i, accept with min(1,
    deg_i/deg_j)) gives edge weight min(1/deg_i, 1/deg_j) =
    1/max(deg_i, deg_j); w_ii absorbs the remainder (always >= 0 since a
    row has deg_i entries each <= 1/deg_i). Doubly stochastic and
    symmetric like the local-degree rule, but WITHOUT the +1 laziness
    term — edges get strictly larger weights, and low-degree nodes shed
    all self-weight (a star's hub has w_ii = 0 here vs 1/N under
    local-degree, the distinguishing case pinned in tests). The flip side
    of no laziness: the chain can be periodic on bipartite graphs where
    some row's self-weight vanishes (ring(2) alternates forever), so
    ``mixing_time`` may be None where the local-degree chain mixes.
    """
    a = g.adjacency
    deg = g.degrees
    n = g.n_nodes
    w = np.zeros((n, n))
    mask = a > 0
    pair_max = np.maximum(deg[:, None], deg[None, :])
    w[mask] = 1.0 / pair_max[mask]
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def power_iteration_gap(matvec, n: int, iters: int = 1000,
                        seed: int = 0) -> float:
    """1 - |lambda_2| of a doubly-stochastic W given only ``matvec``.

    Deflated power iteration on B = W - (1/n) 1 1^T: the known top
    eigenpair (1, 1/sqrt(n)) is projected out of the iterate every step,
    so the growth rate is |lambda_2| — the gossip contraction factor —
    at O(cost(matvec)) per iteration instead of the O(N^3) dense
    eigendecomposition. ``matvec`` may be a host closure over a dense
    matrix or ``SparseW.mix_host`` (O(nnz)).
    """
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n)
    x -= x.mean()
    nrm = np.linalg.norm(x)
    if nrm == 0.0:
        return 1.0
    x /= nrm
    lam = 0.0
    for _ in range(iters):
        y = np.asarray(matvec(x), np.float64)
        y -= y.mean()                       # re-deflate (float drift)
        nrm = np.linalg.norm(y)
        if nrm < 1e-30:                     # W is exact averaging
            return 1.0
        lam = nrm                           # ||B x|| with ||x|| = 1
        x = y / nrm
    return float(1.0 - min(lam, 1.0))


# Above this size the exact dense routes (O(N^3) eigvals / O(N^3)-ish
# repeated W^t products) give way to power iteration and the contraction
# bound.
_EXACT_SPECTRUM_MAX_N = 512


def spectral_gap(w, method: str = "auto", iters: int = 1000,
                 seed: int = 0) -> float:
    """1 - |lambda_2(W)|; gossip contraction factor per round.

    Accepts a dense (N, N) array or a ``core.sparse.SparseW`` (anything
    with a ``mix_host`` matvec). ``method``: 'exact' forces the dense
    eigendecomposition, 'power' forces deflated power iteration, 'auto'
    (default) uses exact for small dense inputs and power iteration for
    sparse or large ones.
    """
    if hasattr(w, "mix_host"):              # SparseW (duck-typed: topology
        if method == "exact":               # must not import core.sparse)
            raise ValueError("exact spectral_gap needs a dense matrix; "
                             "use SparseW.to_dense() explicitly")
        return power_iteration_gap(w.mix_host, w.n, iters=iters, seed=seed)
    w = np.asarray(w)
    n = w.shape[0]
    if method == "exact" or (method == "auto" and n <= _EXACT_SPECTRUM_MAX_N):
        ev = np.linalg.eigvals(w)
        ev = np.sort(np.abs(ev))[::-1]
        second = ev[1] if len(ev) > 1 else 0.0
        return float(1.0 - second)
    return power_iteration_gap(lambda x: w @ x, n, iters=iters, seed=seed)


def mixing_time(w, max_t: int = 100_000, method: str = "auto") -> Optional[int]:
    """tau_mix per paper eq. (5): first t with max_i ||e_i^T W^t - 1/N|| <= 1/2.

    Returns None when the chain is periodic / non-mixing (e.g. even ring),
    mirroring the paper's observation that tau_mix -> inf for ring topologies.

    Dense inputs up to _EXACT_SPECTRUM_MAX_N nodes use the exact repeated-
    product definition (unchanged from the table reproductions); sparse
    (``SparseW``) or larger inputs use the contraction bound
    t = ceil(ln 2 / -ln |lambda_2|), which suffices since
    ||e_i^T W^t - 1/N||_2 <= |lambda_2|^t ||e_i - 1/N||_2 <= |lambda_2|^t.
    """
    sparse_like = hasattr(w, "mix_host")
    n = w.n if sparse_like else np.asarray(w).shape[0]
    if (method != "bound" and not sparse_like
            and (method == "exact" or n <= _EXACT_SPECTRUM_MAX_N)):
        w = np.asarray(w)
        target = np.full((n, n), 1.0 / n)
        wt = np.eye(n)
        for t in range(1, max_t + 1):
            wt = wt @ w
            dev = np.linalg.norm(wt - target, axis=1).max()
            if dev <= 0.5:
                return t
            if t > 64 and dev > 0.999:  # not contracting at all
                break
        return None
    lam = 1.0 - spectral_gap(w, method="power")
    if lam >= 1.0 - 1e-12:
        return None
    t = int(np.ceil(np.log(2.0) / -np.log(lam)))
    return t if t <= max_t else None
