"""recurrentgemma-2b — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427; hf]. Hybrid => long_500k runs (local-attn window cache).

The HF model is 26 layers with pattern (r, r, a) x 8 + (r, r). The scan-over-
groups stack needs n_layers % len(pattern) == 0, so we use 2 groups of a
13-entry pattern — identical 1:2 recurrent:attention ratio and layer count,
with one (r, r, r) run at the group boundary (documented deviation).
"""
from .base import ModelConfig

_PATTERN_13 = ("rglru", "rglru", "swa") * 4 + ("rglru",)

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256_000,
    block_pattern=_PATTERN_13, window=2048,
    subquadratic=True,
)
