"""Lightweight metrics registry: counters, gauges, bucketed histograms.

The aggregate twin of the span journal: instrumentation points increment
in-process metrics with near-zero cost (a dict lookup + an int add), and
the registry renders a Prometheus-style text exposition or a JSON dump the
``repro.obs`` CLI merges across processes. No background threads, no
sockets, no deps — everything is pull-based and file-backed, matching the
repo's spec/lease/result protocol.

Histograms use fixed exponential bucket bounds (default: 1 µs → ~2100 s,
factor 2), tracking count/sum/min/max plus per-bucket counts; ``p50``/
``p99`` are rank interpolations inside the landing bucket — exact enough
to replace the serving layer's ad-hoc "keep every latency in a list"
accounting at O(1) memory, and mergeable across processes because the
bounds are part of the dump.
"""
from __future__ import annotations

import bisect
import json
import math
import os
from typing import Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_buckets"]


def default_buckets() -> List[float]:
    """Exponential bounds 1e-6 * 2^k, k=0..30 (1 µs .. ~2147 s)."""
    return [1e-6 * (2.0 ** k) for k in range(31)]


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}

    def merge(self, snap: dict) -> None:
        self.value += float(snap.get("value", 0.0))


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}

    def merge(self, snap: dict) -> None:
        self.value = float(snap.get("value", self.value))   # last wins


class Histogram:
    """Fixed-bound bucketed histogram with interpolated percentiles."""

    __slots__ = ("bounds", "buckets", "count", "sum", "min", "max")

    def __init__(self, bounds: Optional[List[float]] = None):
        self.bounds = list(bounds) if bounds is not None else \
            default_buckets()
        self.buckets = [0] * (len(self.bounds) + 1)   # +overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.buckets[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, p: float) -> Optional[float]:
        """Rank-interpolated percentile estimate (None when empty)."""
        if self.count == 0:
            return None
        rank = (p / 100.0) * self.count
        seen = 0
        for i, c in enumerate(self.buckets):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else min(self.min, 0.0)
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (rank - seen) / c
                return lo + frac * (hi - lo)
            seen += c
        return self.max

    @property
    def p50(self) -> Optional[float]:
        return self.percentile(50)

    @property
    def p99(self) -> Optional[float]:
        return self.percentile(99)

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def snapshot(self) -> dict:
        return {"type": "histogram", "bounds": self.bounds,
                "buckets": list(self.buckets), "count": self.count,
                "sum": self.sum,
                "min": None if self.count == 0 else self.min,
                "max": None if self.count == 0 else self.max}

    def merge(self, snap: dict) -> None:
        if snap.get("bounds") != self.bounds:
            raise ValueError("cannot merge histograms with different bounds")
        self.buckets = [a + b for a, b in zip(self.buckets,
                                              snap["buckets"])]
        self.count += int(snap["count"])
        self.sum += float(snap["sum"])
        if snap.get("min") is not None:
            self.min = min(self.min, float(snap["min"]))
        if snap.get("max") is not None:
            self.max = max(self.max, float(snap["max"]))


class MetricsRegistry:
    """Name -> metric table with get-or-create accessors.

    Names follow Prometheus conventions (``snake_case``, unit-suffixed:
    ``_total``, ``_seconds``). ``to_prom`` renders the text exposition;
    ``dump``/``load``/``merge_snapshot`` move registries across process
    boundaries as JSON files the CLI aggregates."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = cls(*args)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Optional[List[float]] = None) -> Histogram:
        if bounds is None:
            return self._get(name, Histogram)
        return self._get(name, Histogram, bounds)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # -- serialization ------------------------------------------------------
    def snapshot(self) -> dict:
        return {name: m.snapshot() for name, m in
                sorted(self._metrics.items())}

    def dump(self, path: str) -> str:
        """Atomic JSON dump: write-then-rename, so a process crash leaves
        either the old file or the new one, never a torn mix. No fsync —
        metrics are a derived view (the journal is the source of truth and
        the CLI rebuilds span/event metrics from it), so power-loss
        durability is not worth milliseconds on the serving tick path."""
        tmp = f"{path}.tmp-{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(self.snapshot(), f)
        os.replace(tmp, path)
        return path

    def merge_snapshot(self, snap: dict) -> "MetricsRegistry":
        """Fold a ``snapshot()``/``dump`` document into this registry
        (counters/histograms add, gauges last-write-wins)."""
        for name, doc in snap.items():
            kind = doc.get("type")
            if kind == "counter":
                self.counter(name).merge(doc)
            elif kind == "gauge":
                self.gauge(name).merge(doc)
            elif kind == "histogram":
                self.histogram(name, doc["bounds"]).merge(doc)
        return self

    @classmethod
    def load(cls, path: str) -> "MetricsRegistry":
        with open(path) as f:
            return cls().merge_snapshot(json.load(f))

    # -- exposition ---------------------------------------------------------
    def to_prom(self, prefix: str = "repro") -> str:
        """Prometheus-style text exposition of every metric."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            full = f"{prefix}_{name}"
            if isinstance(m, Counter):
                lines += [f"# TYPE {full} counter",
                          f"{full} {m.value:g}"]
            elif isinstance(m, Gauge):
                lines += [f"# TYPE {full} gauge",
                          f"{full} {m.value:g}"]
            else:
                lines.append(f"# TYPE {full} histogram")
                cum = 0
                for b, c in zip(m.bounds, m.buckets):
                    cum += c
                    if c:
                        lines.append(f'{full}_bucket{{le="{b:g}"}} {cum}')
                lines += [f'{full}_bucket{{le="+Inf"}} {m.count}',
                          f"{full}_sum {m.sum:g}",
                          f"{full}_count {m.count}"]
                if m.count:
                    lines.append(f"{full}_p99 {m.p99:g}")
        return "\n".join(lines) + ("\n" if lines else "")
