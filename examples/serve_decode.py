"""Batched serving demo — prefill then token-by-token decode with KV /
recurrent-state caches, on two architectures from the assigned pool
(one attention, one sub-quadratic hybrid).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced_config
from repro.data.pipeline import make_lm_batch
from repro.models.transformer import (decode_step, forward,
                                      init_decode_state, init_params)

BATCH, PROMPT, GEN = 4, 16, 24


def serve(aid: str):
    cfg = reduced_config(get_arch(aid))
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = make_lm_batch(cfg, 0, 0, BATCH, PROMPT + GEN)["tokens"]
    prompt = toks[:, :PROMPT]

    state = init_decode_state(cfg, BATCH, PROMPT + GEN)
    step = jax.jit(lambda p, s, t: decode_step(p, s, t, cfg))

    # prefill by streaming the prompt (cache warm-up)
    t0 = time.time()
    for t in range(PROMPT):
        logits, state = step(params, state, prompt[:, t:t + 1])
    # greedy generation
    cur = jnp.argmax(logits[:, -1:, ..., :], axis=-1).reshape(BATCH, 1, -1)
    cur = cur[..., 0] if cfg.frontend != "audio_codec" else cur
    outs = [cur]
    for _ in range(GEN - 1):
        logits, state = step(params, state, outs[-1])
        nxt = jnp.argmax(logits[:, -1:, ..., :], axis=-1).reshape(BATCH, 1, -1)
        nxt = nxt[..., 0] if cfg.frontend != "audio_codec" else nxt
        outs.append(nxt)
    dt = time.time() - t0
    gen = jnp.concatenate([o.reshape(BATCH, 1, -1)[..., 0] if o.ndim > 2 else o
                           for o in outs], axis=1)
    assert bool(jnp.isfinite(logits).all())
    print(f"{aid:24s} generated {gen.shape} tokens in {dt:.1f}s "
          f"({BATCH * GEN / dt:.1f} tok/s on CPU)")
    return gen


def main():
    serve("qwen2-7b")            # GQA attention + KV cache
    serve("recurrentgemma-2b")   # RG-LRU + SWA hybrid (O(1) state/token)
    print("OK")


if __name__ == "__main__":
    main()
