"""Chaos-hardened fleet runtime: fault injection, leases, supervision.

The load-bearing property everywhere: NO fault changes the merged bits.
Whatever the chaos plan does — SIGKILL at a chunk boundary, a torn newest
checkpoint, a straggler, a dropped publish, a stolen lease — the launcher
must complete via retry/steal/fallback and the merged SweepResult must
equal the fault-free per-shard single-process reference bit for bit
(shard lane widths match, so equality is exact, not epsilon).
"""
import json
import os
import shutil
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.linalg import eigh_topr
from repro.core.sweep import sdot_sweep, slice_seed_shards
from repro.streaming import chaos
from repro.streaming.chaos import ChaosHooks, FaultPlan
from repro.streaming.fleet import (Lease, LeaseLost, LeaseStore,
                                   fleet_worker_loop, heartbeat_age,
                                   touch_heartbeat)
from repro.streaming.launcher import (_load_result, build_engine,
                                      build_schedule, launch_sweep,
                                      spec_fingerprint)
from repro.streaming.worker import run_shard

D, R, N = 14, 3, 6
T_C = 10


@pytest.fixture(scope="module")
def prob():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((D, N * 40)).astype(np.float32)
    covs = jnp.stack([jnp.asarray(b @ b.T / b.shape[1])
                      for b in np.split(x, N, axis=1)])
    _, q_true = eigh_topr(covs.sum(0), R)
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1},
              "schedule": {"kind": "lin2", "cap": T_C}}]
    return dict(covs=covs, q_true=q_true, cases=cases)


def _ref(prob, seeds, n_shards, t_outer):
    """Fault-free reference at the launcher's shard lane widths."""
    engines = [build_engine(c["topology"]) for c in prob["cases"]]
    scheds = [build_schedule(c["schedule"], t_outer, T_C)
              for c in prob["cases"]]
    parts = [sdot_sweep(covs=prob["covs"], engines=engines, schedules=scheds,
                        r=R, t_outer=t_outer, t_c=T_C, seeds=s,
                        q_true=prob["q_true"])
             for s in slice_seed_shards(seeds, n_shards)]
    return (np.concatenate([p.error_traces for p in parts], axis=0),
            np.concatenate([np.asarray(p.q) for p in parts], axis=0))


# ---------------------------------------------------------------------------
# FaultPlan + hooks
# ---------------------------------------------------------------------------
def test_faultplan_seeded_boundaries_deterministic(tmp_path):
    plan = FaultPlan([{"kind": "kill", "shard": 0},
                      {"kind": "corrupt", "shard": 1},
                      {"kind": "kill", "shard": 2, "boundary": 3}], seed=7)
    clone = FaultPlan.load(plan.dump(str(tmp_path / "plan.json")))
    for idx in range(3):
        b = plan.boundary_for(idx, 10)
        assert 1 <= b <= 10
        assert b == clone.boundary_for(idx, 10)  # replay-stable
    assert plan.boundary_for(2, 10) == 3         # pinned boundary honored
    # the seed matters: some fault lands elsewhere under a different seed
    other = FaultPlan(plan.faults, seed=8)
    assert any(plan.boundary_for(i, 1000) != other.boundary_for(i, 1000)
               for i in range(3))


def test_faultplan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown kind"):
        FaultPlan([{"kind": "explode"}])


def test_faultplan_validates_serving_kinds():
    """Satellite: the serving-layer kinds get field diagnostics too."""
    with pytest.raises(ValueError, match="fault 0: delay_query.p"):
        FaultPlan([{"kind": "delay_query", "p": 1.5}])
    with pytest.raises(ValueError, match="fault 0: delay_query.p"):
        FaultPlan([{"kind": "delay_query", "p": True}])
    with pytest.raises(ValueError, match="fault 1: delay_query.delay"):
        FaultPlan([{"kind": "kill"},
                   {"kind": "delay_query", "delay": -0.1}])
    with pytest.raises(ValueError, match="fault 0: corrupt_candidate.mode"):
        FaultPlan([{"kind": "corrupt_candidate", "mode": "shred"}])
    # well-formed serving faults load
    FaultPlan([{"kind": "delay_query", "p": 0.5, "delay": 0.05},
               {"kind": "corrupt_candidate", "mode": "scale"}])


def test_query_delay_seeded_per_request(tmp_path):
    """delay_query: deterministic in (plan seed, fault idx, req_id), hits
    ~p of requests, and independent hook instances agree — the property
    that makes serving-bench deadline expiry reproducible."""
    plan = FaultPlan([{"kind": "delay_query", "p": 0.5, "delay": 0.05}],
                     seed=3)
    h1 = ChaosHooks(plan, state_dir=str(tmp_path / "a"))
    h2 = ChaosHooks(plan, state_dir=str(tmp_path / "b"))
    delays = [h1.query_delay(i) for i in range(400)]
    assert delays == [h2.query_delay(i) for i in range(400)]  # replay-stable
    hit = sum(d > 0 for d in delays) / len(delays)
    assert 0.35 < hit < 0.65                                   # ~p
    assert {d for d in delays} <= {0.0, 0.05}
    # two delay faults stack; a different seed lands elsewhere
    plan2 = FaultPlan([{"kind": "delay_query", "p": 0.5, "delay": 0.05}],
                      seed=4)
    h3 = ChaosHooks(plan2, state_dir=str(tmp_path / "c"))
    assert [h3.query_delay(i) for i in range(400)] != delays
    assert ChaosHooks(None).query_delay(0) == 0.0              # inert


def test_mangle_candidate_one_shot_and_pinned(tmp_path):
    """corrupt_candidate: fires once (durable marker), honors the optional
    resolve-id pin, and supports both corruption modes."""
    state = str(tmp_path / "chaos_state")
    q = np.eye(6, 2, dtype=np.float32)

    plan = FaultPlan([{"kind": "corrupt_candidate", "mode": "nan",
                       "resolve": 1}])
    hooks = ChaosHooks(plan, state_dir=state)
    np.testing.assert_array_equal(hooks.mangle_candidate(q, 0), q)  # not id 1
    out = hooks.mangle_candidate(q, 1)
    assert np.isnan(out).any() and np.isfinite(q).all()
    # one-shot survives a "relaunch" (fresh hooks, same marker dir)
    relaunched = ChaosHooks(plan, state_dir=state)
    np.testing.assert_array_equal(relaunched.mangle_candidate(q, 1), q)

    scale = ChaosHooks(
        FaultPlan([{"kind": "corrupt_candidate", "mode": "scale"}]),
        state_dir=str(tmp_path / "s"))
    out = scale.mangle_candidate(q, 0)           # unpinned: first candidate
    assert np.isfinite(out).all() and np.abs(out).max() > 1e6


def test_hooks_inert_without_env(monkeypatch, tmp_path):
    """Production path: no env var -> no chaos branches, no side effects."""
    monkeypatch.delenv(chaos.ENV_PLAN, raising=False)
    hooks = chaos.hooks_from_env(shard=0, worker="0", n_boundaries=4,
                                 ckpt_root=str(tmp_path),
                                 workdir=str(tmp_path))
    assert not hooks.active
    hooks.at_boundary(1)
    hooks.after_publish(str(tmp_path))
    assert not (tmp_path / "chaos_state").exists()


# ---------------------------------------------------------------------------
# chaos equivalence: kill + corrupt + straggler + drop in ONE launch
# ---------------------------------------------------------------------------
def test_chaos_smoke_bitwise_equivalence(tmp_path):
    """The CI scenario end to end: SIGKILL at a seeded chunk boundary, a
    truncated newest checkpoint, a straggler, and a dropped publish — the
    launch completes via retry/backoff and merges bit-identically to the
    fault-free sweep (run_smoke asserts the bits AND the recovery paths:
    per-shard attempts, mid-grid resume, corrupt fallback step)."""
    summary = chaos.run_smoke(str(tmp_path), verbose=False)
    assert summary["bitwise_equal"]
    assert summary["faults"] == ["kill", "corrupt", "slow", "drop"]


def test_stall_detection_kills_hung_worker(tmp_path, prob):
    """A wedged-but-alive worker (hangs at a chunk boundary, stops
    heartbeating, never exits) is detected by heartbeat staleness within
    seconds, killed, and retried — the old launcher would have blocked on
    it for the full timeout."""
    seeds = [0, 1]
    plan = FaultPlan([{"kind": "hang", "shard": 0, "sleep": 300.0,
                       "boundary": 2}])
    t0 = time.monotonic()
    sw = launch_sweep(covs=prob["covs"], cases=prob["cases"], r=R,
                      t_outer=6, t_c=T_C, seeds=seeds,
                      q_true=prob["q_true"], workdir=str(tmp_path),
                      n_workers=2, n_shards=2, sweep_chunk=2, retries=1,
                      stall_timeout=2.0, poll_interval=0.1,
                      chaos_plan=plan, timeout=300.0)
    wall = time.monotonic() - t0
    assert wall < 120.0                      # nowhere near the 300s hang
    rep = sw.resume_report
    assert rep["attempts"][0] == 2           # hung attempt + clean retry
    # the hang fired at boundary 2 (before step 4 was written): the retry
    # resumed from the step-2 checkpoint, not from scratch
    assert rep["worker_resumed_steps"][0] == 2
    err, q = _ref(prob, seeds, 2, 6)
    np.testing.assert_array_equal(np.asarray(sw.error_traces), err)
    np.testing.assert_array_equal(np.asarray(sw.q), q)


def test_elastic_steal_from_straggler(tmp_path, prob):
    """Elastic fleet vs the paper's straggler: worker w0's per-boundary
    sleep blows through the lease TTL, the finished worker steals the
    stale lease mid-run (the victim backs off via the fencing token), and
    the merged result is still bit-identical."""
    seeds = [0, 1, 2, 3]
    plan = FaultPlan([{"kind": "slow", "worker": 0, "sleep": 4.0}])
    # Reserve shard 0 for w0 before the launch. Both fleet workers race
    # through jax import at spawn, and on a loaded box the winner can
    # otherwise drain BOTH shards before the loser takes its first lease —
    # no straggler, no steal, a flaky assert. The reservation pins the
    # roles: w0 reclaims its own lease (pick prefers owned shards) and
    # stalls on it (4s per boundary >> 0.5s TTL), w1 takes shard 1, wins,
    # and MUST steal shard 0 to finish. The stamp decays after 30s, so a
    # w0 that dies at startup only delays the steal, never deadlocks it.
    store = LeaseStore(str(tmp_path), ttl=0.5)
    reservation = store.try_acquire(0, "w0")
    reservation["renewed_at"] = time.time() + 30.0
    store._write(0, dict(reservation))
    sw = launch_sweep(covs=prob["covs"], cases=prob["cases"], r=R,
                      t_outer=8, t_c=T_C, seeds=seeds,
                      q_true=prob["q_true"], workdir=str(tmp_path),
                      n_workers=2, n_shards=2, sweep_chunk=2, retries=2,
                      elastic=True, lease_ttl=0.5, poll_interval=0.1,
                      chaos_plan=plan, timeout=300.0)
    rep = sw.resume_report
    assert rep["stolen_shards"], rep         # at least one steal happened
    for s in rep["stolen_shards"]:
        assert len(rep["lease_owners"][s]) >= 2
    err, q = _ref(prob, seeds, 2, 8)
    np.testing.assert_array_equal(np.asarray(sw.error_traces), err)
    np.testing.assert_array_equal(np.asarray(sw.q), q)


# ---------------------------------------------------------------------------
# lease protocol
# ---------------------------------------------------------------------------
def test_lease_fencing_tokens(tmp_path):
    store = LeaseStore(str(tmp_path), ttl=0.3)
    l1 = store.try_acquire(0, "a")
    assert l1 is not None and l1.token == 1
    assert store.try_acquire(0, "b") is None     # live foreign lease
    store.renew(0, "a", l1.token)
    time.sleep(0.4)                              # ... "a" goes silent
    l2 = store.try_acquire(0, "b")
    assert l2 is not None and l2.token == 2      # stolen, token bumped
    with pytest.raises(LeaseLost):
        store.renew(0, "a", l1.token)            # victim must back off
    store.release(0, "b", l2.token, done=True)
    l3 = store.try_acquire(0, "c")               # released = acquirable
    assert l3.token == 3
    assert l3.owners == ["a", "b", "c"]          # steal history visible


def test_lease_pick_prefers_never_leased_then_stalest(tmp_path):
    store = LeaseStore(str(tmp_path), ttl=0.2)
    store.try_acquire(0, "a")
    time.sleep(0.3)
    store.try_acquire(1, "b")
    time.sleep(0.25)                             # both expired, 0 staler
    assert store.pick([0, 1, 2], "b") == 1       # own lease first, even
    #                                              with 2 never leased
    assert store.pick([0, 1, 2], "z") == 2       # then never-leased
    assert store.pick([0, 1], "z") == 0          # else the stalest


def test_lease_expiry_survives_wall_clock_jumps(tmp_path):
    """Lease aging is dual-clock: the monotonic stamp decides whenever it
    is coherent, so operator ``date`` jumps and NTP steps cannot make a
    DEAD lease immortal (wall jumped forward at renewal: age would read
    negative) or a LIVE lease instantly stealable (wall jumped back)."""
    store = LeaseStore(str(tmp_path), ttl=30.0)
    lease = store.try_acquire(0, "a")

    # owner died 100 monotonic seconds ago, but its last renewal happened
    # just after the wall clock was stepped 1h into the future: wall age
    # is hugely negative -> the old wall-only code NEVER expired this
    lease["renewed_at"] = time.time() + 3600.0
    lease["renewed_mono"] = time.monotonic() - 100.0
    store._write(0, dict(lease))
    assert store.read(0).expired(30.0)
    assert store.try_acquire(0, "b") is not None      # stealable

    # live lease (renewed moments ago) + wall stepped BACK 1h: wall age
    # reads ~3600s but the monotonic pair says fresh -> not stealable
    lease2 = store.try_acquire(1, "a")
    lease2["renewed_at"] = time.time() - 3600.0
    lease2["renewed_mono"] = time.monotonic()
    store._write(1, dict(lease2))
    assert not store.read(1).expired(30.0)
    assert store.try_acquire(1, "b") is None


def test_lease_incoherent_or_missing_mono_falls_back_to_wall(tmp_path):
    """A monotonic stamp from ANOTHER boot (reads as our future) or a
    lease written by an older code version (no stamp at all) must age by
    the wall clock, not be trusted or crash."""
    store = LeaseStore(str(tmp_path), ttl=30.0)

    # pre-dual-clock lease document: no renewed_mono key, fresh wall stamp
    legacy = Lease({"owner": "a", "token": 1, "renewed_at": time.time(),
                    "owners": ["a"]})
    store._write(0, dict(legacy))
    assert not store.read(0).expired(30.0)            # wall fallback: live
    legacy["renewed_at"] = time.time() - 100.0
    store._write(0, dict(legacy))
    assert store.read(0).expired(30.0)                # wall fallback: dead

    # cross-boot stamp: a monotonic reading far ahead of ours is
    # incoherent (nm - mono << -1) -> ignored in favor of the wall age
    cross = Lease({"owner": "a", "token": 1,
                   "renewed_at": time.time() - 100.0,
                   "renewed_mono": time.monotonic() + 9e5,
                   "owners": ["a"]})
    store._write(1, dict(cross))
    assert store.read(1).expired(30.0)


def test_heartbeat_roundtrip(tmp_path):
    hb = str(tmp_path / "w" / "heartbeat")
    assert heartbeat_age(hb) is None
    touch_heartbeat(hb, step=7)
    age = heartbeat_age(hb)
    assert age is not None and age < 5.0
    with open(hb) as f:
        assert json.load(f)["step"] == 7


# ---------------------------------------------------------------------------
# elastic membership: join mid-sweep, depart without failing the launch
# ---------------------------------------------------------------------------
def test_fleet_joiner_takes_expired_lease_and_merges_identically(
        monkeypatch, tmp_path, prob):
    """A worker that LEFT mid-sweep (expired lease + checkpointed partial
    sweep-RunState) loses its shard to a worker that JOINS mid-sweep: the
    joiner steals the expired lease (fencing token bumped), resumes the
    victim's checkpoint mid-grid, and the final merge is bit-identical —
    membership changes never touch the math."""
    monkeypatch.delenv(chaos.ENV_PLAN, raising=False)
    seeds, t_outer = [0, 1, 2, 3], 6
    shards = slice_seed_shards(seeds, 2)
    spec = {"algo": "sdot", "r": R, "t_outer": t_outer, "t_c": T_C,
            "cases": prob["cases"], "shards": shards, "ragged": False,
            "n_cov_stacks": 1, "has_q_true": True, "sweep_chunk": 2}
    with open(tmp_path / "spec.json", "w") as f:
        json.dump(spec, f)
    np.savez(tmp_path / "problem.npz", covs=np.asarray(prob["covs"]),
             q_true=np.asarray(prob["q_true"]))

    # the departed worker got one chunk into shard 0, then went silent
    engines = [build_engine(c["topology"]) for c in prob["cases"]]
    scheds = [build_schedule(c["schedule"], t_outer, T_C)
              for c in prob["cases"]]
    mgr = CheckpointManager(str(tmp_path / "worker_0" / "ckpt"))
    sdot_sweep(covs=prob["covs"], engines=engines, schedules=scheds, r=R,
               t_outer=t_outer, t_c=T_C, seeds=shards[0],
               q_true=prob["q_true"], manager=mgr, chunk_size=2,
               max_chunks=1)
    store = LeaseStore(str(tmp_path), ttl=0.3)
    departed = store.try_acquire(0, "departed")
    assert departed is not None
    time.sleep(0.4)                              # ... and its lease expires

    # a joiner enters mid-sweep: steals shard 0, runs shard 1, finishes
    assert fleet_worker_loop(spec, str(tmp_path), "joiner", ttl=0.3) == 0
    snap = store.snapshot()
    assert snap[0].owners == ["departed", "joiner"]
    assert snap[0].token == departed.token + 1   # fenced steal
    assert int(_load_result(str(tmp_path), spec, 0)["resumed_steps"]) == 2

    # the launcher over the same workdir reuses both published shards and
    # the merge equals the fault-free reference exactly
    sw = launch_sweep(covs=prob["covs"], cases=prob["cases"], r=R,
                      t_outer=t_outer, t_c=T_C, seeds=seeds,
                      q_true=prob["q_true"], workdir=str(tmp_path),
                      n_workers=2, n_shards=2, sweep_chunk=2)
    assert sw.resume_report["reused_shards"] == [0, 1]
    err, q = _ref(prob, seeds, 2, t_outer)
    np.testing.assert_array_equal(np.asarray(sw.error_traces), err)
    np.testing.assert_array_equal(np.asarray(sw.q), q)


# ---------------------------------------------------------------------------
# torn checkpoints (manager + runtime fallback)
# ---------------------------------------------------------------------------
def test_latest_step_skips_torn_and_tmp_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    mgr.save(1, {"x": jnp.arange(3)})
    mgr.save(2, {"x": jnp.arange(3) + 1})
    os.remove(tmp_path / "step_00000002" / "manifest.json")   # torn mid-step
    (tmp_path / "step_00000003.tmp-123").mkdir()              # crashed writer
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1
    mgr.save(4, {"x": jnp.arange(3) + 2})                     # _gc sweeps tmp
    assert not (tmp_path / "step_00000003.tmp-123").exists()
    assert mgr.all_steps() == [1, 4]


def test_truncated_newest_checkpoint_falls_back(tmp_path, prob):
    """chaos's 'corrupt' tearing (truncate shards.npz, manifest intact)
    against a real sweep checkpoint dir: the resume must fall back one
    chunk and still finish bit-identically; a manifest-delete tear is then
    invisible to latest_step."""
    kw = dict(covs=prob["covs"],
              engines=[build_engine(c["topology"]) for c in prob["cases"]],
              schedules=[build_schedule(c["schedule"], 6, T_C)
                         for c in prob["cases"]],
              r=R, t_outer=6, t_c=T_C, seeds=[0, 1],
              q_true=prob["q_true"])
    mono = sdot_sweep(**kw)
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    sdot_sweep(manager=mgr, chunk_size=2, max_chunks=2, **kw)
    assert mgr.all_steps() == [2, 4]
    hooks = ChaosHooks(FaultPlan([]), shard=0, n_boundaries=1,
                       ckpt_root=str(tmp_path),
                       state_dir=str(tmp_path / "cs"))
    hooks._corrupt_newest("truncate")
    assert mgr.all_steps() == [2, 4]             # manifest intact, npz torn
    res = sdot_sweep(manager=mgr, chunk_size=2, **kw)
    assert res.resumed_step == 2                 # fell back past the tear
    np.testing.assert_array_equal(res.error_traces, mono.error_traces)
    np.testing.assert_array_equal(np.asarray(res.q), np.asarray(mono.q))

    hooks._corrupt_newest("manifest")            # tear the new newest
    assert mgr.latest_step() == 4


# ---------------------------------------------------------------------------
# worker crash window + launcher load-error surfacing
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def published(tmp_path_factory, prob):
    """One published single-shard launch, copied per test that mutates it."""
    wd = tmp_path_factory.mktemp("published")
    launch_sweep(covs=prob["covs"], cases=prob["cases"], r=R, t_outer=4,
                 t_c=T_C, seeds=[0], q_true=prob["q_true"],
                 workdir=str(wd), n_workers=1, sweep_chunk=2)
    with open(wd / "spec.json") as f:
        spec = json.load(f)
    return str(wd), spec


def test_relaunch_cleans_stale_ckpt_next_to_published_result(
        monkeypatch, tmp_path, prob, published):
    """The crash window between result publish and ckpt cleanup: a worker
    relaunched into that state must treat the published result as final —
    no recompute — and sweep the stale checkpoint away itself."""
    monkeypatch.delenv(chaos.ENV_PLAN, raising=False)
    src, spec = published
    wd = str(tmp_path / "wd")
    shutil.copytree(src, wd)
    before = _load_result(wd, spec, 0)
    ckpt = os.path.join(wd, "worker_0", "ckpt", "step_00000002")
    os.makedirs(ckpt)                           # the crash left this behind
    with open(os.path.join(ckpt, "junk"), "w") as f:
        f.write("stale")
    assert run_shard(spec, wd, 0) == 0
    assert not os.path.exists(os.path.dirname(ckpt))   # window closed
    after = _load_result(wd, spec, 0)
    np.testing.assert_array_equal(np.asarray(after["q"]),
                                  np.asarray(before["q"]))


def test_load_result_surfaces_unexpected_errors(monkeypatch, published):
    """Only the EXPECTED restore failure modes may be swallowed; anything
    else surfaces on the resume report instead of a silent recompute."""
    import repro.streaming.launcher as L

    wd, spec = published
    unexpected = {}
    assert L._load_result(wd, spec, 0, unexpected) is not None
    assert unexpected == {}

    def boom(*a, **k):
        raise RuntimeError("disk on fire")

    monkeypatch.setattr(L, "restore_tree", boom)
    assert L._load_result(wd, spec, 0, unexpected) is None
    assert "disk on fire" in unexpected[0]
