"""Online covariance ingestion: per-node sketches fed by micro-batches.

The paper (and its MPI implementation) materializes each node's covariance
``M_i = X_i X_i^T / n_i`` up front.  At production scale the data is a
stream: samples arrive in micro-batches, the run starts before the data
ends, and no host ever holds its full sample block.  This module closes
that gap with two per-node sketches, both maintained as ONE stacked pytree
over all simulated nodes (a single device dispatch per micro-batch):

* ``CovSketch`` — the exact running second moment ``sum_t X_t X_t^T`` plus a
  sample count.  ``cov_stack()`` is the covariance stack the batch pipeline
  would compute from the same samples — the same sum, accumulated per
  micro-batch, so it matches to float32 summation-order ulps (pinned with
  allclose in tests/test_streaming.py; ingest *resume*, by contrast, IS
  bitwise because the restored partial sums are the saved ones) — and the
  fused executors and sweep engines consume the evolving stack with zero
  API change.
* ``FrequentDirections`` — the deterministic Liberty sketch for d where the
  (d, d) second moment won't fit: per node an (ell, d) row sketch B with
  the guarantee ``||X X^T - B^T B||_2 <= shrink_loss`` (the accumulated
  shrink mass, tracked per node), ell << d rows instead of d.

``StreamingIngestor`` drives either sketch from a stateless-seeded stream
(``data/pipeline.spectrum_matched_stream`` / ``eigengap_stream``): each
micro-batch is split over nodes with the same ``partition_samples``
column-sharding the batch pipeline uses, so node i's accumulated samples
are exactly the concatenation of its per-batch shards.  The ingestor's
whole state (sketch pytree + next stream step) checkpoints through
``checkpoint/manager.py``; because the stream is stateless, a restarted
ingestor resumes at the saved step and replays the identical remainder.

With ``track_top=K`` the ingestor also carries a cheap top-(K+1)
Rayleigh–Ritz estimate of the GLOBAL covariance spectrum: one subspace-
iteration + Ritz step per ingested micro-batch against the accumulated
sketch (two sketch-applies of a (d, K+1) basis — never an eigendecomposition
of the full (N, d, d) stack), exposing ``ritz_values`` / ``eigengap`` /
``top_basis()``. This is what the serving layer's drift detector reads; the
tracked basis and values ride in the checkpointed ``state()`` so a
restarted service sees the same spectrum estimate it crashed with.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.linalg import orthonormal_init
from ..data.pipeline import partition_samples

__all__ = ["CovSketch", "FrequentDirections", "StreamingIngestor"]


def _require_samples(counts) -> None:
    """Fail at the call site instead of emitting a 0/0 all-NaN cov stack."""
    if not float(jnp.min(counts)) > 0:
        raise ValueError("cov_stack() before any batch was ingested — "
                         "call ingest() first")


@jax.jit
def _cov_update(second_moment, counts, blocks):
    """One micro-batch into the exact sketch: blocks (N, d, m)."""
    sm = second_moment + jnp.einsum("ndm,nem->nde", blocks, blocks)
    return sm, counts + jnp.float32(blocks.shape[2])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CovSketch:
    """Exact stacked running second moment: (N, d, d) + per-node counts."""

    second_moment: jnp.ndarray       # (N, d, d) running sum X X^T
    counts: jnp.ndarray              # (N,) samples seen per node

    @classmethod
    def init(cls, n_nodes: int, d: int) -> "CovSketch":
        return cls(jnp.zeros((n_nodes, d, d), jnp.float32),
                   jnp.zeros((n_nodes,), jnp.float32))

    def update(self, blocks: jnp.ndarray) -> "CovSketch":
        sm, counts = _cov_update(self.second_moment, self.counts, blocks)
        return CovSketch(sm, counts)

    def cov_stack(self) -> jnp.ndarray:
        """(N, d, d) per-node covariances M_i = sum X X^T / n_i — the exact
        operand stack ``sdot`` / ``sdot_sweep`` expect."""
        _require_samples(self.counts)
        return self.second_moment / self.counts[:, None, None]

    def apply_sum(self, v: jnp.ndarray) -> jnp.ndarray:
        """(sum_n X_n X_n^T) @ v without materializing the global matrix."""
        return jnp.einsum("nde,ek->dk", self.second_moment, v)

    def tree_flatten(self):
        return (self.second_moment, self.counts), None

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)


def _fd_shrink_one(buf, ell: int):
    """One Frequent-Directions shrink: (ell + m, d) rows -> (ell, d).

    SVD, subtract the ell-th squared singular value from every direction
    (zeroing at least one kept row), keep the top ell. Returns the new
    sketch and the shrink mass delta (the step's addition to the spectral
    error bound)."""
    _, s, vt = jnp.linalg.svd(buf, full_matrices=False)
    delta = s[ell - 1] ** 2
    s_shrunk = jnp.sqrt(jnp.maximum(s ** 2 - delta, 0.0))
    return (s_shrunk[:ell, None] * vt[:ell]), delta


@functools.partial(jax.jit, static_argnames=("ell",))
def _fd_update(sketch, counts, loss, blocks, *, ell: int):
    """One micro-batch into the FD sketch: blocks (N, d, m)."""
    buf = jnp.concatenate([sketch, jnp.swapaxes(blocks, 1, 2)], axis=1)
    new, delta = jax.vmap(lambda b: _fd_shrink_one(b, ell))(buf)
    return new, counts + jnp.float32(blocks.shape[2]), loss + delta


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FrequentDirections:
    """Stacked per-node Frequent-Directions sketches: (N, ell, d).

    Deterministic, mergeable, and ell << d memory: per node
    ``||X X^T - B^T B||_2 <= shrink_loss`` (Liberty '13 / Ghashami et al.
    '16 — the bound is the accumulated shrink mass, at most
    ``||X||_F^2 / (ell - r)`` after the standard argument)."""

    sketch: jnp.ndarray              # (N, ell, d)
    counts: jnp.ndarray              # (N,)
    shrink_loss: jnp.ndarray         # (N,) accumulated spectral-error bound

    @classmethod
    def init(cls, n_nodes: int, d: int, ell: int) -> "FrequentDirections":
        if ell > d:
            raise ValueError(f"sketch size ell={ell} exceeds d={d} — use the "
                             "exact CovSketch instead")
        return cls(jnp.zeros((n_nodes, ell, d), jnp.float32),
                   jnp.zeros((n_nodes,), jnp.float32),
                   jnp.zeros((n_nodes,), jnp.float32))

    @property
    def ell(self) -> int:
        return self.sketch.shape[1]

    def update(self, blocks: jnp.ndarray) -> "FrequentDirections":
        sk, counts, loss = _fd_update(self.sketch, self.counts,
                                      self.shrink_loss, blocks, ell=self.ell)
        return FrequentDirections(sk, counts, loss)

    def cov_stack(self) -> jnp.ndarray:
        """(N, d, d) approximate covariances B^T B / n_i (for moderate d;
        at the scales FD exists for, consume ``sketch`` directly)."""
        _require_samples(self.counts)
        return (jnp.einsum("nld,nle->nde", self.sketch, self.sketch)
                / self.counts[:, None, None])

    def apply_sum(self, v: jnp.ndarray) -> jnp.ndarray:
        """(sum_n B_n^T B_n) @ v — two (ell, d) products, never a (d, d)."""
        bv = jnp.einsum("nld,dk->nlk", self.sketch, v)
        return jnp.einsum("nld,nlk->dk", self.sketch, bv)

    def tree_flatten(self):
        return (self.sketch, self.counts, self.shrink_loss), None

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)


@jax.jit
def _ritz_step(sketch, basis):
    """One subspace-iteration + Rayleigh–Ritz step of the tracked basis.

    ``basis`` (d, k) orthonormal -> (new basis, Ritz values descending).
    Two sketch-applies and one (k, k) eigh — O(N d^2 k) for the exact
    sketch, O(N ell d k) for FD — per micro-batch, against the sketch's
    ACCUMULATED global second moment (so the estimate integrates the whole
    stream, not just the newest batch)."""
    total = jnp.maximum(sketch.counts.sum(), 1.0)
    v, _ = jnp.linalg.qr(sketch.apply_sum(basis))
    h = v.T @ sketch.apply_sum(v) / total
    h = 0.5 * (h + h.T)
    vals, vecs = jnp.linalg.eigh(h)
    order = jnp.argsort(vals)[::-1]
    return v @ vecs[:, order], vals[order]


class StreamingIngestor:
    """Drive N per-node sketches from a stateless micro-batch stream.

    ``batch_fn(step, m) -> (d, m)`` must be a pure function of (seed, step)
    — the contract of ``data/pipeline``'s stream constructors.  Every
    micro-batch is column-sharded over nodes with ``partition_samples``
    (node i always takes the i-th shard), so the accumulated per-node
    sample sets are deterministic and restart-invariant.

    ``state()`` / ``restore()`` round-trip the full ingestion state (sketch
    pytree + next step — plus the tracked Ritz basis/values when
    ``track_top`` is set) through ``checkpoint/manager.py``.
    """

    def __init__(self, *, n_nodes: int, d: int,
                 batch_fn: Callable[[int, int], jnp.ndarray],
                 batch_size: int, sketch: str = "exact",
                 ell: Optional[int] = None, start_step: int = 0,
                 track_top: Optional[int] = None, ritz_seed: int = 0):
        if batch_size % n_nodes:
            raise ValueError(f"batch_size={batch_size} must divide evenly "
                             f"over {n_nodes} nodes (partition_samples "
                             "drops remainder columns)")
        self.n_nodes = n_nodes
        self.d = d
        self.batch_fn = batch_fn
        self.batch_size = batch_size
        self.step = start_step
        if sketch == "exact":
            self.sketch = CovSketch.init(n_nodes, d)
        elif sketch == "fd":
            if ell is None:
                raise ValueError("sketch='fd' needs ell")
            self.sketch = FrequentDirections.init(n_nodes, d, ell)
        else:
            raise ValueError(f"unknown sketch kind: {sketch}")
        self.track_top = track_top
        if track_top is not None:
            if not 0 < track_top < d:
                raise ValueError(f"track_top={track_top} needs a spare "
                                 f"direction: require 0 < K < d={d} so the "
                                 "(K+1)-th Ritz value exists for the gap")
            self._ritz_basis = orthonormal_init(
                jax.random.PRNGKey(ritz_seed), d, track_top + 1)
            self._ritz_vals = jnp.zeros((track_top + 1,), jnp.float32)
        else:
            self._ritz_basis = None
            self._ritz_vals = None

    def ingest(self, n_batches: int = 1) -> "StreamingIngestor":
        """Consume the next ``n_batches`` stream steps into the sketches."""
        for _ in range(n_batches):
            x = self.batch_fn(self.step, self.batch_size)
            blocks = jnp.stack(partition_samples(x, self.n_nodes))
            self.sketch = self.sketch.update(blocks)
            if self._ritz_basis is not None:
                self._ritz_basis, self._ritz_vals = _ritz_step(
                    self.sketch, self._ritz_basis)
            self.step += 1
        return self

    # -- tracked spectrum (drift detector inputs) ---------------------------
    @property
    def ritz_values(self) -> Optional[np.ndarray]:
        """(K+1,) descending Ritz estimates of the global eigenvalues."""
        return None if self._ritz_vals is None else np.asarray(self._ritz_vals)

    @property
    def eigengap(self) -> float:
        """Tracked lambda_K - lambda_{K+1} estimate (Alg. 1's rate driver)."""
        if self._ritz_vals is None:
            raise ValueError("eigengap needs track_top set at construction")
        k = self.track_top
        return float(self._ritz_vals[k - 1] - self._ritz_vals[k])

    def top_basis(self) -> jnp.ndarray:
        """(d, K) tracked leading Ritz basis (the drift reference)."""
        if self._ritz_basis is None:
            raise ValueError("top_basis needs track_top set at construction")
        return self._ritz_basis[:, :self.track_top]

    def cov_stack(self) -> jnp.ndarray:
        """The evolving (N, d, d) operand stack for the fused executors."""
        return self.sketch.cov_stack()

    @property
    def samples_per_node(self) -> np.ndarray:
        return np.asarray(self.sketch.counts)

    # -- checkpointing ------------------------------------------------------
    def state(self) -> dict:
        """Pytree snapshot for CheckpointManager.save.

        The tracked Ritz basis/values join the tree only when tracking is
        on, so untracked ingestors keep the PR-4 checkpoint layout (old
        snapshots restore unchanged)."""
        tree = {"step": jnp.int32(self.step), "sketch": self.sketch}
        if self._ritz_basis is not None:
            tree["ritz_basis"] = self._ritz_basis
            tree["ritz_vals"] = self._ritz_vals
        return tree

    def restore(self, tree: dict) -> "StreamingIngestor":
        self.step = int(tree["step"])
        self.sketch = tree["sketch"]
        if self._ritz_basis is not None:
            self._ritz_basis = jnp.asarray(tree["ritz_basis"])
            self._ritz_vals = jnp.asarray(tree["ritz_vals"])
        return self
