"""Numerics (CholeskyQR2) and subspace metrics. Deterministic cases only —
the hypothesis sweep lives in test_linalg_property.py so this module collects
without hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.linalg import cholesky_qr, cholesky_qr2, eigh_topr, \
    orthonormal_init
from repro.core.metrics import (principal_angles, projector_distance,
                                subspace_error)


def test_cholesky_qr2_orthonormal_deterministic():
    for d, r, seed in ((4, 1, 0), (32, 5, 1), (64, 8, 2)):
        v = jax.random.normal(jax.random.PRNGKey(seed), (d, r)) * 10.0
        q, rr = cholesky_qr2(v)
        np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(r), atol=1e-5)
        np.testing.assert_allclose(np.asarray(q @ rr), np.asarray(v),
                                   rtol=2e-4, atol=2e-4)
        assert np.allclose(np.tril(np.asarray(rr), -1), 0.0, atol=1e-5)


def test_cholesky_qr2_ill_conditioned():
    rng = np.random.default_rng(0)
    v = rng.standard_normal((50, 4))
    v[:, 3] = v[:, 0] + 1e-3 * v[:, 3]   # cond ~ 1e3 (fp32 CholeskyQR2 limit
    # is cond^2 * eps < 1, i.e. cond << 3e3 — documented in linalg.py)
    q, _ = cholesky_qr2(jnp.asarray(v, jnp.float32))
    assert float(jnp.abs(q.T @ q - jnp.eye(4)).max()) < 1e-4


def test_cholesky_qr_one_pass_weaker():
    rng = np.random.default_rng(1)
    v = rng.standard_normal((50, 4))
    # cond ~1e3: inside the fp32 CholeskyQR validity range (cond^2 eps < 1)
    # so the one-pass result is finite yet visibly less orthonormal; the
    # original 1e-4 perturbation produced NaN for BOTH passes (cond^2 eps > 1)
    # and the assert compared nan <= nan
    v[:, 3] = v[:, 0] + 1e-3 * v[:, 3]
    v = jnp.asarray(v, jnp.float32)
    q1, _ = cholesky_qr(v, eps=1e-12)
    q2, _ = cholesky_qr2(v)
    e1 = float(jnp.abs(q1.T @ q1 - jnp.eye(4)).max())
    e2 = float(jnp.abs(q2.T @ q2 - jnp.eye(4)).max())
    assert e2 <= e1


def test_orthonormal_init():
    q = orthonormal_init(jax.random.PRNGKey(0), 30, 5)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(5), atol=1e-5)


def test_eigh_topr_ground_truth():
    rng = np.random.default_rng(2)
    a = rng.standard_normal((12, 12))
    m = jnp.asarray(a @ a.T, jnp.float32)
    vals, vecs = eigh_topr(m, 3)
    assert np.all(np.diff(np.asarray(vals)) <= 1e-5)   # descending
    full_vals = np.linalg.eigvalsh(np.asarray(m))[::-1]
    np.testing.assert_allclose(np.asarray(vals), full_vals[:3], rtol=1e-4)


def test_subspace_error_identities():
    q = orthonormal_init(jax.random.PRNGKey(3), 20, 4)
    assert float(subspace_error(q, q)) < 1e-6
    # invariant to right rotation
    rot = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(4), (4, 4)))[0]
    assert float(subspace_error(q, q @ rot)) < 1e-6
    # orthogonal complement: error = 1
    full = orthonormal_init(jax.random.PRNGKey(5), 20, 20)
    a, b = full[:, :4], full[:, 4:8]
    assert abs(float(subspace_error(a, b)) - 1.0) < 1e-5


def test_projector_distance_vs_subspace_error():
    """||PP - QQ||_2 = sin(theta_max); E = mean sin^2 — consistent ordering."""
    q1 = orthonormal_init(jax.random.PRNGKey(6), 20, 3)
    q2 = orthonormal_init(jax.random.PRNGKey(7), 20, 3)
    pd = float(projector_distance(q1, q2))
    se = float(subspace_error(q1, q2))
    assert 0 <= se <= pd ** 2 + 1e-6


def test_principal_angles_range():
    q1 = orthonormal_init(jax.random.PRNGKey(8), 10, 3)
    q2 = orthonormal_init(jax.random.PRNGKey(9), 10, 3)
    th = np.asarray(principal_angles(q1, q2))
    assert np.all(th >= -1e-7) and np.all(th <= np.pi / 2 + 1e-6)
