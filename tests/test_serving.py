"""Serving layer: drift-triggered warm re-solve, gated swap, crash-resume.

The load-bearing properties, in the order the service promises them:
warm starts reconverge in strictly fewer iterations than cold starts after
a seeded spectrum shift; a kill at any chunk boundary mid-re-solve resumes
bit-identically (and absolute target_step increments are idempotent, so a
re-executed service tick can never double-advance a re-solve); the quality
gate never serves a corrupted/diverged candidate; the query path sheds and
expires explicitly instead of blocking; and a restarted service replays an
identical served-subspace trajectory.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.consensus import DenseConsensus
from repro.core.linalg import eigh_topr, orthonormal_init
from repro.core.metrics import subspace_error
from repro.core.runtime import run_chunked, run_monolithic
from repro.core.sdot import sdot_program
from repro.core.topology import erdos_renyi
from repro.data.pipeline import drifting_eigengap_stream
from repro.serving.drift import DriftDetector
from repro.serving.query import QueryPath
from repro.serving.service import PSAService, ServiceConfig, service_summary
from repro.streaming.chaos import FaultPlan
from repro.streaming.ingest import StreamingIngestor

D, R, N = 12, 3, 4
T_OUTER, T_C, CHUNK = 12, 12, 3


@pytest.fixture(scope="module")
def shifted_problem():
    """A drifting stream ingested just past its shift: pre-shift covs (what
    the incumbent was solved on) and early-post-shift covs (what a
    drift-triggered re-solve faces — the detector fires while the blend is
    moderately rotated, not after the old subspace is orthogonal)."""
    batch_fn, (_, q0), (_, q1) = drifting_eigengap_stream(
        D, R, 0.6, shift_at=6, seed=0, lead=3.0, shift_lead=6.0)
    ing = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn, batch_size=32)
    ing.ingest(6)
    covs_pre = ing.cov_stack()
    ing.ingest(2)
    covs_post = ing.cov_stack()
    engine = DenseConsensus(erdos_renyi(N, 0.6, seed=1))
    return dict(covs_pre=covs_pre, covs_post=covs_post, engine=engine,
                q0=q0, q1=q1)


def _prog(covs, engine, q_init, q_true=None, t_outer=T_OUTER):
    return sdot_program(covs=covs, engine=engine, r=R, t_outer=t_outer,
                        t_c=T_C, q_init=q_init, q_true=q_true)


# ---------------------------------------------------------------------------
# warm vs cold reconvergence after a spectrum shift
# ---------------------------------------------------------------------------
def test_warm_start_reconverges_in_fewer_iterations(shifted_problem):
    """Satellite 4a: after the seeded shift, a re-solve warm-started from
    the incumbent (solved on pre-shift covs) reaches the target residual in
    STRICTLY fewer outer iterations than a cold random start."""
    p = shifted_problem
    _, q_true = eigh_topr(p["covs_post"].sum(0), R)
    # the incumbent: converged on the PRE-shift covs
    warm_q = run_monolithic(
        _prog(p["covs_pre"], p["engine"],
              orthonormal_init(jax.random.PRNGKey(3), D, R),
              t_outer=20)).q_nodes.mean(axis=0)
    assert 0.05 < float(subspace_error(q_true, warm_q)) < 0.5  # moderate
    t_long = 30
    cold = run_monolithic(_prog(
        p["covs_post"], p["engine"],
        orthonormal_init(jax.random.PRNGKey(4), D, R), q_true=q_true,
        t_outer=t_long)).error_trace
    warm = run_monolithic(_prog(
        p["covs_post"], p["engine"], warm_q, q_true=q_true,
        t_outer=t_long)).error_trace
    target = 1e-3
    assert cold.min() < target and warm.min() < target
    it_cold = int(np.argmax(cold < target)) + 1
    it_warm = int(np.argmax(warm < target)) + 1
    assert it_warm < it_cold, (it_warm, it_cold)


# ---------------------------------------------------------------------------
# kill-at-any-chunk-boundary + absolute-target idempotency
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kill_at", [1, 2, 3])
def test_resolve_kill_at_chunk_boundary_resumes_bitwise(
        tmp_path, shifted_problem, kill_at):
    """Satellite 4b: the serving re-solve (run_chunked over sdot_program)
    killed at any chunk boundary resumes bit-identically."""
    p = shifted_problem
    q_init = orthonormal_init(jax.random.PRNGKey(7), D, R)
    ref = run_monolithic(_prog(p["covs_post"], p["engine"], q_init))

    mgr = CheckpointManager(str(tmp_path))
    run_chunked(_prog(p["covs_post"], p["engine"], q_init), mgr,
                chunk_size=CHUNK, max_chunks=kill_at)       # the "kill"
    res = run_chunked(_prog(p["covs_post"], p["engine"], q_init), mgr,
                      chunk_size=CHUNK)                     # the relaunch
    np.testing.assert_array_equal(np.asarray(res.q_nodes),
                                  np.asarray(ref.q_nodes))
    np.testing.assert_array_equal(np.asarray(res.consensus_trace),
                                  np.asarray(ref.consensus_trace))


def test_target_step_increments_are_idempotent(tmp_path, shifted_problem):
    """The service advances a re-solve to ABSOLUTE targets, one increment
    per tick: the increments compose to the one-shot run bitwise, and
    re-executing an increment (a crashed tick replayed) is a no-op."""
    p = shifted_problem
    q_init = orthonormal_init(jax.random.PRNGKey(8), D, R)
    ref = run_monolithic(_prog(p["covs_post"], p["engine"], q_init))

    mgr = CheckpointManager(str(tmp_path))
    for target in (3, 6, 6, 9, 6, 12):      # repeats/regressions: no-ops
        res = run_chunked(_prog(p["covs_post"], p["engine"], q_init), mgr,
                          chunk_size=CHUNK, target_step=target)
    assert mgr.latest_step() == T_OUTER
    np.testing.assert_array_equal(np.asarray(res.q_nodes),
                                  np.asarray(ref.q_nodes))


# ---------------------------------------------------------------------------
# drift detector
# ---------------------------------------------------------------------------
def test_drift_detector_triggers_after_shift_not_before(shifted_problem):
    p = shifted_problem
    det = DriftDetector(residual_threshold=0.3, warmup=0)
    batch_fn, (_, q0), _ = drifting_eigengap_stream(
        D, R, 0.6, shift_at=6, seed=0, lead=3.0, shift_lead=6.0)
    ing = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                            batch_size=32, track_top=R)
    ing.ingest(6)
    served = ing.top_basis()                 # "solved" on pre-shift data
    pre = det.read(ing, served, baseline_gap=ing.eigengap,
                   ticks_since_swap=5)
    assert not pre.triggered, pre
    ing.ingest(10)                           # through the shift
    post = det.read(ing, served, baseline_gap=pre.eigengap,
                    ticks_since_swap=15)
    assert post.triggered and post.residual > pre.residual, (pre, post)


def test_drift_detector_warmup_suppresses_trigger():
    batch_fn, _, _ = drifting_eigengap_stream(D, R, 0.6, shift_at=0, seed=0)
    ing = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                            batch_size=32, track_top=R)
    ing.ingest(8)
    far = orthonormal_init(jax.random.PRNGKey(9), D, R)  # residual ~ 1
    det = DriftDetector(residual_threshold=0.1, warmup=3)
    assert not det.read(ing, far, baseline_gap=1.0,
                        ticks_since_swap=2).triggered
    assert det.read(ing, far, baseline_gap=1.0,
                    ticks_since_swap=3).triggered


# ---------------------------------------------------------------------------
# query path: bounded admission, deadlines, percentiles
# ---------------------------------------------------------------------------
class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class _FakeHooks:
    """query_delay stand-in: fixed delay for odd req_ids."""

    def query_delay(self, req_id):
        return 1.0 if req_id % 2 else 0.0


def test_query_path_sheds_on_full_queue():
    qp = QueryPath(capacity=3, max_batch=8, deadline_s=10.0)
    for i in range(5):
        qp.submit(i, np.zeros(D))
    assert qp.shed == 2 and len(qp) == 3
    out = qp.process(np.eye(D, R, dtype=np.float32))
    assert [rid for rid, _ in out] == [0, 1, 2]
    assert qp.summary()["shed"] == 2


def test_query_path_injected_delay_expires_against_deadline():
    clock = _FakeClock()
    qp = QueryPath(capacity=8, max_batch=8, deadline_s=0.5,
                   hooks=_FakeHooks(), clock=clock)
    for i in range(4):
        qp.submit(i, np.ones(D))
    out = qp.process(np.eye(D, R, dtype=np.float32))
    # odd req_ids carry +1.0s injected latency > 0.5s deadline: expired,
    # never answered; even ones answered with sub-deadline latency
    assert [rid for rid, _ in out] == [0, 2]
    s = qp.summary()
    assert s["answered"] == 2 and s["expired"] == 2
    assert s["p99_s"] < 0.5


def test_query_path_drain_expired_and_projection_math():
    clock = _FakeClock()
    qp = QueryPath(capacity=8, max_batch=8, deadline_s=0.5, clock=clock)
    q = np.asarray(orthonormal_init(jax.random.PRNGKey(0), D, R))
    x = np.arange(D, dtype=np.float32)
    qp.submit(0, x)
    out = qp.process(q)
    np.testing.assert_allclose(out[0][1], q.T @ x, rtol=1e-5, atol=1e-5)
    qp.submit(1, x)
    clock.t += 1.0                       # past the deadline while queued
    assert qp.drain_expired() == 1
    assert qp.summary()["expired"] == 1 and len(qp) == 0


# ---------------------------------------------------------------------------
# the service loop
# ---------------------------------------------------------------------------
def _small_cfg():
    return ServiceConfig(
        d=10, r=2, n_nodes=4, batch_size=24, gap=0.6, lead=3.0,
        shift_lead=6.0, shift_at=5, holdout_m=256, total_ticks=14,
        t_outer=8, t_c=10, resolve_chunk=2, chunks_per_tick=2,
        topology={"kind": "er", "n": 4, "p": 0.6, "seed": 1},
        warmup_ticks=1, drift_threshold=0.3, drift_warmup=2,
        queries_per_tick=4, max_batch=4, staleness_bound=12, keep_last=3)


def test_service_trajectory_and_resume_bitwise(tmp_path):
    """A stop-and-resume service replays the identical served-subspace
    trajectory: same swap ticks, same served bits, restore matches the
    pinned last-good snapshot."""
    cfg = _small_cfg()
    ref_dir = os.path.join(str(tmp_path), "ref")
    svc = PSAService(cfg, ref_dir).run()
    svc.finalize()
    ref = service_summary(ref_dir)
    assert ref["swaps"] >= 2 and ref["gate_rejects"] == 0, ref
    assert ref["max_staleness"] <= cfg.staleness_bound, ref
    assert ref["queries"]["answered"] > 0 and ref["queries"]["shed"] == 0

    res_dir = os.path.join(str(tmp_path), "resume")
    PSAService(cfg, res_dir).run(until=6)       # "crash" at tick boundary
    svc2 = PSAService(cfg, res_dir).run()       # fresh process resumes
    svc2.finalize()
    res = service_summary(res_dir)
    assert res["served_sha256"] == ref["served_sha256"], (res, ref)
    assert res["swap_ticks"] == ref["swap_ticks"], (res, ref)
    assert res["restores"] and all(
        e["pinned_match"] is not False for e in res["restores"]), res
    # the pinned step holding the last-swapped subspace survived GC churn
    mgr = CheckpointManager(os.path.join(res_dir, "state"),
                            keep_last=cfg.keep_last)
    pinned = mgr.pinned_steps()
    assert pinned == [ref["served_at"]]
    assert pinned[0] in mgr.all_steps()


def test_service_gate_rejects_corrupted_candidate(tmp_path):
    """A chaos-mangled candidate is NEVER served: the gate rejects it, the
    incumbent keeps serving, and a cold re-solve recovers."""
    cfg = _small_cfg()
    plan = FaultPlan(seed=0, faults=[
        {"kind": "corrupt_candidate", "mode": "nan", "resolve": 1}])
    svc = PSAService(cfg, str(tmp_path), plan=plan).run()
    svc.finalize()
    s = service_summary(str(tmp_path))
    assert s["gate_rejects"] == 1 and s["cold_resolves"] == 1, s
    assert s["swaps"] >= 2, s                    # recovered after the reject
    assert np.all(np.isfinite(svc.served_q))     # NaN never reached serving
    assert s["reject_ticks"], s
    # the recovered subspace tracks the post-shift truth
    err = float(subspace_error(svc.q_post, jnp.asarray(svc.served_q)))
    assert err < 0.25, err


def test_service_gate_rejects_scaled_candidate(tmp_path):
    """mode='scale' destroys orthonormality rather than finiteness — the
    gate's second check has to catch it."""
    cfg = _small_cfg()
    plan = FaultPlan(seed=0, faults=[
        {"kind": "corrupt_candidate", "mode": "scale", "resolve": 1}])
    svc = PSAService(cfg, str(tmp_path), plan=plan).run()
    svc.finalize()
    s = service_summary(str(tmp_path))
    assert s["gate_rejects"] == 1, s
    gram = svc.served_q.T @ svc.served_q
    np.testing.assert_allclose(gram, np.eye(cfg.r), atol=1e-4)
