"""Vmapped Monte-Carlo sweep engine over the fused algorithm zoo.

The paper's Figs. 4-6 are Monte-Carlo averages over random initializations
(and the tables sweep topologies and consensus schedules). With the fused
whole-run executors (sdot.py, fdot.py, baselines.py) a full sweep collapses
into a single compiled program and ONE device call:

* the **seed axis** is a ``jax.vmap`` over per-seed orthonormal inits;
* the **case axis** (topology x schedule) is a second ``vmap`` over the
  stacked weight matrices, debias tables, and schedule arrays — all dense
  (N, N) / (t_max+1, N) / (T_o,) arrays, so heterogeneous graphs stack as
  long as they share the node count;
* **ragged node counts** (the Table-II connectivity axis: ER N=10 next to
  ring N=20) stack too (shared helpers: ``sweep_utils``):
  - ``sdot_sweep`` / ``baseline_sweep`` (dsa / dpgd / deepca), covs mode:
    pass one cov stack per case and every case is padded to N_max with
    *isolated identity nodes* — W becomes block-diag(W, I) (the padding
    rows are identity, so padded nodes never mix with real ones), the
    padded covs are identity (keeping the padded iterates finite), the
    debias table is built from the padded W, and a node mask keeps the
    padded estimates out of the error trace. Padded-vs-unpadded traces are
    bit-comparable because a real node's gossip row has exact zeros
    against every padded node.
  - ``fdot_sweep``: pass one slab *list* per case and every case is padded
    to N_max with *all-zero slabs* (plus zero rows up to the sweep-wide
    d_max).  Zero slabs are self-masking — they contribute exactly nothing
    to any product in Alg. 2, including the error cross term — so the
    feature-partitioned path needs no node mask at all.

Compare: the eager zoo runs seeds x cases x t_outer Python iterations with a
host sync each — the sweep engine runs one dispatch total, and the whole
(C, S, T_o) error-trace tensor comes back in a single transfer
(benchmarks/sweep_bench.py measures the win; tests/test_fused_zoo.py pins
sweep == per-seed fused runs).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .baselines import (_fused_d_pm, _fused_deepca, _fused_dpgd, _fused_dsa,
                        _fused_seq_dist_pm)
from .consensus import DenseConsensus, consensus_schedule, debias_table
from .fdot import pad_feature_slabs, split_pad_rows
from .linalg import orthonormal_init
from .metrics import CommLedger
from .sdot import _fused_run, _stack_data, local_cov_apply
from .sweep_utils import (broadcast_per_case, case_node_masks,
                          pad_covs_identity, pad_weights_identity,
                          pad_zero_nodes)

__all__ = ["SweepResult", "sdot_sweep", "fdot_sweep", "baseline_sweep"]


@dataclasses.dataclass
class SweepResult:
    """Stacked outputs of a Monte-Carlo sweep.

    ``q`` and ``error_traces`` carry a leading case axis C (only when the
    sweep ran multiple topology/schedule cases) and a seed axis S.

    ``node_counts`` is set by ragged-N sweeps: ``q[c]`` then has node axis
    N_max and only the first ``node_counts[c]`` entries are real (the rest
    are the isolated identity-padding nodes).
    """

    q: jnp.ndarray                 # (C?, S, ...) final estimates
    error_traces: Optional[np.ndarray]   # (C?, S, T) per-seed error traces
    ledger: CommLedger             # aggregate communication over all runs
    seeds: np.ndarray
    node_counts: Optional[np.ndarray] = None

    def _traces(self) -> np.ndarray:
        if self.error_traces is None:
            raise ValueError("sweep ran without q_true — no error traces "
                             "were recorded")
        return self.error_traces

    @property
    def mean_trace(self) -> np.ndarray:
        """Monte-Carlo mean over the seed axis."""
        return self._traces().mean(axis=-2)

    @property
    def std_trace(self) -> np.ndarray:
        return self._traces().std(axis=-2)


def _seed_inits(seeds: Sequence[int], d: int, r: int) -> jnp.ndarray:
    """(S, d, r) orthonormal inits, one per Monte-Carlo seed (vmapped QR)."""
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    return jax.vmap(lambda k: orthonormal_init(k, d, r))(keys)


def _broadcast_cases(engines, schedules, t_outer, t_c, allow_ragged=False):
    """Zip-broadcast engines x schedules into C aligned cases."""
    if isinstance(engines, DenseConsensus):
        engines = [engines]
    engines = list(engines)
    if schedules is None:
        schedules = [consensus_schedule("const", t_outer, t_max=t_c)]
    elif isinstance(schedules, np.ndarray) and schedules.ndim == 1:
        schedules = [schedules]
    schedules = [np.asarray(s) for s in schedules]
    for s in schedules:
        if len(s) < t_outer:
            raise ValueError(f"schedule has {len(s)} entries but "
                             f"t_outer={t_outer}")
    c = max(len(engines), len(schedules))
    if len(engines) == 1:
        engines = engines * c
    if len(schedules) == 1:
        schedules = schedules * c
    if len(engines) != len(schedules):
        raise ValueError("engines and schedules must zip-broadcast: got "
                         f"{len(engines)} vs {len(schedules)}")
    n_nodes = engines[0].graph.n_nodes
    if not allow_ragged and any(e.graph.n_nodes != n_nodes for e in engines):
        raise ValueError("all sweep engines must share the node count")
    return engines, [s[:t_outer] for s in schedules]


# retained names for callers that grew up with the in-module helpers
_pad_weights_identity = pad_weights_identity
_pad_covs_identity = pad_covs_identity


def _case_stacks(engines, schedules, t_max):
    ws = jnp.stack([e._w for e in engines])
    tables = jnp.stack([e.debias_table(t_max) for e in engines])
    scheds = jnp.asarray(np.stack(schedules), jnp.int32)
    return ws, tables, scheds


def _squeeze_case(arr, single_case: bool):
    return arr[0] if single_case else arr


def sdot_sweep(
    *,
    covs=None,
    data: Optional[Sequence[jnp.ndarray]] = None,
    engines: Union[DenseConsensus, Sequence[DenseConsensus]],
    r: int,
    t_outer: int,
    schedules=None,
    t_c: int = 50,
    seeds: Sequence[int] = (0,),
    q_true: Optional[jnp.ndarray] = None,
) -> SweepResult:
    """Monte-Carlo S-DOT/SA-DOT sweep: seeds x (topology, schedule) cases in
    one compile + one device call.

    ``engines`` / ``schedules`` zip-broadcast into the case axis (pass one
    engine and k schedules, k engines and one schedule, or aligned lists).
    Each seed gets its own orthonormal init (the paper's Monte-Carlo axis).

    ``covs`` is either one (N, d, d) stack shared by every case, or a
    list/tuple with one (N_c, d, d) stack per case — the per-case form may
    mix node counts (the Table-II connectivity axis): every case is padded
    to N_max with isolated identity nodes (see the module docstring) and
    the result carries ``node_counts`` so callers can slice the padding
    off ``q``. Error traces are masked to the real nodes and match the
    unpadded per-case runs exactly.
    """
    if (covs is None) == (data is None):
        raise ValueError("provide exactly one of covs / data")
    per_case_covs = covs is not None and isinstance(covs, (list, tuple))
    engines, schedules = _broadcast_cases(engines, schedules, t_outer, t_c,
                                          allow_ragged=per_case_covs)
    single_case = len(engines) == 1
    n_list = [e.graph.n_nodes for e in engines]
    t_max = int(max(int(s.max()) for s in schedules)) if t_outer else 0
    trace_err = q_true is not None

    if per_case_covs:
        case_covs = broadcast_per_case([jnp.asarray(c) for c in covs],
                                       len(engines), "covs")
        for c, e in zip(case_covs, engines):
            if c.shape[0] != e.graph.n_nodes:
                raise ValueError("per-case covs must match each engine's "
                                 f"node count: got {c.shape[0]} covs for an "
                                 f"{e.graph.n_nodes}-node graph")
        d = int(case_covs[0].shape[1])
        n_max = max(n_list)
        ws = jnp.stack([jnp.asarray(pad_weights_identity(e.weights, n_max))
                        for e in engines])
        tables = jnp.stack([debias_table(w, t_max) for w in ws])
        covs_pad = jnp.stack([pad_covs_identity(c, n_max)
                              for c in case_covs])              # (C,N_max,d,d)
        masks = case_node_masks(n_list, n_max)                  # (C, N_max)
        scheds = jnp.asarray(np.stack(schedules), jnp.int32)
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        q0 = _seed_inits(seeds, d, r)                           # (S, d, r)
        q0_nodes = jnp.broadcast_to(q0[:, None],
                                    (len(seeds), n_max, d, r))

        run = lambda w, table, sched, covp, mask, q0n: _fused_run(
            covp, w, table, sched, q0n, q_arg, mask,
            mode="cov", t_max=t_max, trace_err=trace_err)
        over_seeds = jax.vmap(run, in_axes=(None, None, None, None, None, 0))
        over_cases = jax.vmap(over_seeds, in_axes=(0, 0, 0, 0, 0, None))
        q_nodes, errs = over_cases(ws, tables, scheds, covs_pad, masks,
                                   q0_nodes)
        node_counts = np.asarray(n_list)
    else:
        n = n_list[0]
        d = covs.shape[1] if covs is not None else data[0].shape[0]
        ws, tables, scheds = _case_stacks(engines, schedules, t_max)

        if covs is not None:
            operand, mode = covs, "cov"
        else:
            operand, mode = _stack_data(data), "data"
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)

        q0 = _seed_inits(seeds, d, r)                           # (S, d, r)
        q0_nodes = jnp.broadcast_to(q0[:, None], (len(seeds), n, d, r))
        ones = jnp.ones((n,), jnp.float32)

        run = lambda w, table, sched, q0n: _fused_run(
            operand, w, table, sched, q0n, q_arg, ones,
            mode=mode, t_max=t_max, trace_err=trace_err)
        over_seeds = jax.vmap(run, in_axes=(None, None, None, 0))
        over_cases = jax.vmap(over_seeds, in_axes=(0, 0, 0, None))
        q_nodes, errs = over_cases(ws, tables, scheds, q0_nodes)
        node_counts = None

    ledger = CommLedger()
    for eng, sched in zip(engines, schedules):
        for _ in seeds:
            ledger.log_gossip_rounds(sched, eng.graph.adjacency, d * r)
    return SweepResult(
        q=_squeeze_case(q_nodes, single_case),
        error_traces=(np.asarray(_squeeze_case(errs, single_case))
                      if trace_err else None),
        ledger=ledger,
        seeds=np.asarray(list(seeds)),
        node_counts=node_counts,
    )


def fdot_sweep(
    *,
    data_blocks: Sequence,
    engines: Union[DenseConsensus, Sequence[DenseConsensus]],
    r: int,
    t_outer: int,
    schedules=None,
    t_c: int = 50,
    t_c_qr: Optional[int] = None,
    seeds: Sequence[int] = (0,),
    q_true: Optional[jnp.ndarray] = None,
) -> SweepResult:
    """Monte-Carlo F-DOT sweep over padded feature slabs (Fig. 6 axis).

    ``data_blocks`` is either one slab list shared by every case, or a
    list/tuple of slab *lists* with one per case — the per-case form may mix
    node counts (different partitionings of the same d features): every case
    is padded to N_max with all-zero slabs, which are exact no-ops in every
    product of Alg. 2 (see the module docstring), so the traces match the
    unpadded per-case runs and no node mask is needed. The result carries
    ``node_counts`` so callers can slice the padding off ``q``.
    """
    from .fdot import _fused_fdot_run

    per_case = (len(data_blocks) > 0
                and isinstance(data_blocks[0], (list, tuple)))
    engines, schedules = _broadcast_cases(engines, schedules, t_outer, t_c,
                                          allow_ragged=per_case)
    single_case = len(engines) == 1
    t_c_qr = int(t_c if t_c_qr is None else t_c_qr)
    passes = 2
    t_max = int(max(max(int(s.max()) for s in schedules), t_c_qr))
    trace_err = q_true is not None

    if per_case:
        case_blocks = broadcast_per_case(data_blocks, len(engines),
                                         "data_blocks")
        n_list = []
        for blocks, e in zip(case_blocks, engines):
            if len(blocks) != e.graph.n_nodes:
                raise ValueError("per-case data_blocks must match each "
                                 f"engine's node count: got {len(blocks)} "
                                 f"slabs for an {e.graph.n_nodes}-node graph")
            n_list.append(e.graph.n_nodes)
        case_dims = [[int(x.shape[0]) for x in blocks]
                     for blocks in case_blocks]
        d = sum(case_dims[0])
        if any(sum(dims) != d for dims in case_dims):
            raise ValueError("every case must partition the same d features")
        n_samples = int(case_blocks[0][0].shape[1])
        n_max = max(n_list)
        d_slab = max(max(dims) for dims in case_dims)
        pad_case = lambda stack: pad_zero_nodes(
            jnp.pad(stack, ((0, 0), (0, d_slab - stack.shape[1]), (0, 0))),
            n_max)
        x_pads = jnp.stack([pad_case(pad_feature_slabs(blocks))
                            for blocks in case_blocks])  # (C,N_max,d_slab,n)
        ws = jnp.stack([jnp.asarray(pad_weights_identity(e.weights, n_max))
                        for e in engines])
        tables = jnp.stack([debias_table(w, t_max) for w in ws])
        scheds = jnp.asarray(np.stack(schedules), jnp.int32)
        q_seeds = _seed_inits(seeds, d, r)
        q0_pads = jnp.stack([
            jnp.stack([pad_case(split_pad_rows(q, dims)) for q in q_seeds])
            for dims in case_dims])                      # (C,S,N_max,d_slab,r)
        qtrue_pads = jnp.stack([
            (pad_case(split_pad_rows(q_true, dims)) if trace_err
             else jnp.zeros((n_max, d_slab, r), jnp.float32))
            for dims in case_dims])                      # (C,N_max,d_slab,r)

        run = lambda w, table, sched, xp, qt, q0p: _fused_fdot_run(
            xp, w, table, sched, q0p, qt,
            t_max=t_max, t_c_qr=t_c_qr, passes=passes, trace_err=trace_err)
        over_seeds = jax.vmap(run, in_axes=(None, None, None, None, None, 0))
        over_cases = jax.vmap(over_seeds, in_axes=(0, 0, 0, 0, 0, 0))
        q_pad, errs = over_cases(ws, tables, scheds, x_pads, qtrue_pads,
                                 q0_pads)
        node_counts = np.asarray(n_list)
    else:
        n_nodes = engines[0].graph.n_nodes
        if len(data_blocks) != n_nodes:
            raise ValueError("need one feature slab per node")
        dims = [int(x.shape[0]) for x in data_blocks]
        d = sum(dims)
        n_samples = int(data_blocks[0].shape[1])
        ws, tables, scheds = _case_stacks(engines, schedules, t_max)

        x_pad = pad_feature_slabs(data_blocks)
        q0_pad = jnp.stack([split_pad_rows(q, dims)
                            for q in _seed_inits(seeds, d, r)])
        qtrue_pad = (split_pad_rows(q_true, dims) if trace_err
                     else jnp.zeros_like(q0_pad[0]))

        run = lambda w, table, sched, q0p: _fused_fdot_run(
            x_pad, w, table, sched, q0p, qtrue_pad,
            t_max=t_max, t_c_qr=t_c_qr, passes=passes, trace_err=trace_err)
        over_seeds = jax.vmap(run, in_axes=(None, None, None, 0))
        over_cases = jax.vmap(over_seeds, in_axes=(0, 0, 0, None))
        q_pad, errs = over_cases(ws, tables, scheds, q0_pad)
        node_counts = None

    ledger = CommLedger()
    for eng, sched in zip(engines, schedules):
        for _ in seeds:
            ledger.log_gossip_rounds(sched, eng.graph.adjacency,
                                     n_samples * r)
            ledger.log_gossip_rounds(
                np.full(t_outer, passes * t_c_qr), eng.graph.adjacency, r * r)
    return SweepResult(
        q=_squeeze_case(q_pad, single_case),
        error_traces=(np.asarray(_squeeze_case(errs, single_case))
                      if trace_err else None),
        ledger=ledger,
        seeds=np.asarray(list(seeds)),
        node_counts=node_counts,
    )


def _baseline_case_sweep(name, case_covs, engines, r, seeds, q_true, t_outer,
                         lr, t_mix, ledger):
    """Case x seed grid for the cov-based baselines (dsa / dpgd / deepca)
    with ragged node counts: identity-padded covs + block-diag(W, I) weights
    (sweep_utils), and the node mask keeps the isolated padding nodes out of
    the consensus-mean estimate the error trace scores."""
    trace_err = q_true is not None
    s_count = len(list(seeds))
    n_list = [e.graph.n_nodes for e in engines]
    n_max = max(n_list)
    d = int(case_covs[0].shape[1])
    ws = jnp.stack([jnp.asarray(pad_weights_identity(e.weights, n_max))
                    for e in engines])
    covs_pad = jnp.stack([pad_covs_identity(c, n_max) for c in case_covs])
    masks = case_node_masks(n_list, n_max)                   # (C, N_max)
    q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
    q0 = _seed_inits(seeds, d, r)
    q0_nodes = jnp.broadcast_to(q0[:, None], (s_count, n_max, d, r))

    if name == "dsa":
        run = lambda w, covp, mask, q0n: _fused_dsa(
            covp, w, q0n, jnp.float32(lr), q_arg, mask,
            t_outer=t_outer, trace_err=trace_err)
        rounds = np.ones(t_outer)
    elif name == "dpgd":
        run = lambda w, covp, mask, q0n: _fused_dpgd(
            covp, w, q0n, jnp.float32(lr), q_arg, mask,
            t_outer=t_outer, trace_err=trace_err)
        rounds = np.ones(t_outer)
    else:
        run = lambda w, covp, mask, q0n: _fused_deepca(
            covp, w, q0n, local_cov_apply(covp, q0n), q_arg, mask,
            t_outer=t_outer, t_mix=t_mix, trace_err=trace_err)
        rounds = np.full(t_outer, t_mix)
    over_seeds = jax.vmap(run, in_axes=(None, None, None, 0))
    over_cases = jax.vmap(over_seeds, in_axes=(0, 0, 0, None))
    q, errs = over_cases(ws, covs_pad, masks, q0_nodes)
    for eng in engines:
        for _ in range(s_count):
            ledger.log_gossip_rounds(rounds, eng.graph.adjacency, d * r)
    return q, errs, np.asarray(n_list)


def baseline_sweep(
    name: str,
    *,
    covs=None,
    data_blocks: Optional[Sequence[jnp.ndarray]] = None,
    engine: Optional[DenseConsensus] = None,
    engines=None,
    r: int,
    seeds: Sequence[int] = (0,),
    q_true: Optional[jnp.ndarray] = None,
    t_outer: Optional[int] = None,
    iters_per_vec: Optional[int] = None,
    lr: float = 0.1,
    t_mix: int = 3,
    t_c: int = 50,
) -> SweepResult:
    """Monte-Carlo sweep of one fused baseline over seeds (one device call).

    ``name``: dsa | dpgd | deepca (sample-partitioned, need ``covs`` +
    ``t_outer``), seq_dist_pm (``covs`` + ``iters_per_vec``), or d_pm
    (feature-partitioned, ``data_blocks`` + ``iters_per_vec``).

    The cov-based trio also accepts ``engines`` (a list) plus per-case
    ``covs`` (a list of (N_c, d, d) stacks) with mixed node counts — the
    same ragged-N identity-padding contract as ``sdot_sweep``; the result
    then carries a case axis and ``node_counts``. The sequential-deflation
    baselines (seq_dist_pm, d_pm) are single-case only.
    """
    if engines is not None and engine is not None:
        raise ValueError("pass engine or engines, not both")
    engine_list = None
    if engines is not None:
        if isinstance(engines, DenseConsensus):
            engine = engines
        else:
            engine_list = list(engines)
    if engine is None and engine_list is None:
        raise ValueError("baseline_sweep needs an engine")

    trace_err = q_true is not None
    ledger = CommLedger()
    s_count = len(list(seeds))
    node_counts = None

    if engine_list is not None:
        if name not in ("dsa", "dpgd", "deepca"):
            raise ValueError(f"{name} does not support a ragged-N case axis "
                             "(sequential-deflation baselines are "
                             "single-case only)")
        if covs is None or t_outer is None:
            raise ValueError(f"{name} sweep needs covs and t_outer")
        if not isinstance(covs, (list, tuple)):
            covs = [covs]
        case_covs = broadcast_per_case([jnp.asarray(c) for c in covs],
                                       len(engine_list), "covs")
        for c, e in zip(case_covs, engine_list):
            if c.shape[0] != e.graph.n_nodes:
                raise ValueError("per-case covs must match each engine's "
                                 f"node count: got {c.shape[0]} covs for an "
                                 f"{e.graph.n_nodes}-node graph")
        q, errs, node_counts = _baseline_case_sweep(
            name, case_covs, engine_list, r, seeds, q_true, t_outer, lr,
            t_mix, ledger)
        if len(engine_list) == 1:
            q, errs, node_counts = q[0], errs[0], None
        return SweepResult(
            q=q,
            error_traces=np.asarray(errs) if trace_err else None,
            ledger=ledger,
            seeds=np.asarray(list(seeds)),
            node_counts=node_counts,
        )

    adj = engine.graph.adjacency

    if name in ("dsa", "dpgd", "deepca"):
        if covs is None or t_outer is None:
            raise ValueError(f"{name} sweep needs covs and t_outer")
        n, d, _ = covs.shape
        ones = jnp.ones((n,), jnp.float32)
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        q0 = _seed_inits(seeds, d, r)
        q0_nodes = jnp.broadcast_to(q0[:, None], (s_count, n, d, r))
        if name == "dsa":
            run = lambda q0n: _fused_dsa(covs, engine._w, q0n,
                                         jnp.float32(lr), q_arg, ones,
                                         t_outer=t_outer, trace_err=trace_err)
            rounds = np.ones(t_outer)
        elif name == "dpgd":
            run = lambda q0n: _fused_dpgd(covs, engine._w, q0n,
                                          jnp.float32(lr), q_arg, ones,
                                          t_outer=t_outer, trace_err=trace_err)
            rounds = np.ones(t_outer)
        else:
            run = lambda q0n: _fused_deepca(
                covs, engine._w, q0n, local_cov_apply(covs, q0n), q_arg,
                ones, t_outer=t_outer, t_mix=t_mix, trace_err=trace_err)
            rounds = np.full(t_outer, t_mix)
        q, errs = jax.vmap(run)(q0_nodes)
        for _ in range(s_count):
            ledger.log_gossip_rounds(rounds, adj, d * r)
    elif name == "seq_dist_pm":
        if covs is None or iters_per_vec is None:
            raise ValueError("seq_dist_pm sweep needs covs and iters_per_vec")
        n, d, _ = covs.shape
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        q0 = _seed_inits(seeds, d, r)
        cols0 = jnp.broadcast_to(
            jnp.swapaxes(q0, 1, 2)[:, :, None, :], (s_count, r, n, d))
        table = engine.debias_table(t_c)
        run = lambda c0: _fused_seq_dist_pm(
            covs, engine._w, table, c0, q_arg, r=r,
            iters_per_vec=iters_per_vec, t_c=t_c, t_max=t_c,
            trace_err=trace_err)
        cols, errs = jax.vmap(run)(cols0)
        q = jnp.transpose(cols, (0, 2, 3, 1))
        for _ in range(s_count):
            ledger.log_gossip_rounds(np.full(r * iters_per_vec, t_c), adj, d)
    elif name == "d_pm":
        if data_blocks is None or iters_per_vec is None:
            raise ValueError("d_pm sweep needs data_blocks and iters_per_vec")
        dims = [int(x.shape[0]) for x in data_blocks]
        d = sum(dims)
        n_samples = int(data_blocks[0].shape[1])
        x_pad = pad_feature_slabs(data_blocks)
        q0_pad = jnp.stack([split_pad_rows(q, dims)
                            for q in _seed_inits(seeds, d, r)])
        blocks0 = jnp.transpose(q0_pad, (0, 3, 1, 2))           # (S, r, N, d_max)
        qtrue_pad = (split_pad_rows(q_true, dims) if trace_err
                     else jnp.zeros_like(q0_pad[0]))
        table = engine.debias_table(t_c)
        run = lambda b0: _fused_d_pm(
            x_pad, engine._w, table, b0, qtrue_pad, r=r,
            iters_per_vec=iters_per_vec, t_c=t_c, t_max=t_c,
            trace_err=trace_err)
        blocks, errs = jax.vmap(run)(blocks0)
        q = jnp.concatenate(
            [jnp.swapaxes(blocks[:, :, i, :di], 1, 2)
             for i, di in enumerate(dims)], axis=1)             # (S, d, r)
        for _ in range(s_count):
            ledger.log_gossip_rounds(np.full(r * iters_per_vec, t_c), adj,
                                     n_samples)
    else:
        raise ValueError(f"unknown baseline: {name}")

    return SweepResult(
        q=q,
        error_traces=np.asarray(errs) if trace_err else None,
        ledger=ledger,
        seeds=np.asarray(list(seeds)),
    )
