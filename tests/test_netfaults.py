"""Self-healing gossip under network faults (core/netfaults.py): realized
renormalization/debias correctness, execution-mode bit-equality (fused scan
vs eager rounds vs host NumPy oracle), the faulty algorithm zoo, sweeps,
and the net-fault plan front door."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.fdot import fdot
from repro.core.metrics import CommLedger
from repro.core.netfaults import (FaultyConsensus, NetFaultModel,
                                  masked_faulty_rounds, realized_debias,
                                  sample_fault_blocks)
from repro.core.sdot import sdot
from repro.core.sweep import SweepResult, netfault_sweep, sdot_sweep
from repro.core.topology import erdos_renyi, ring
from repro.data.pipeline import partition_features
from repro.core.linalg import eigh_topr

N = 8


def _z(n=N, d=6, r=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, d, r)), jnp.float32)


def _model(**kw):
    base = dict(p_drop=0.25, p_bad=0.1, p_good=0.5, p_corrupt=0.05)
    base.update(kw)
    return NetFaultModel(**base)


def _engine(seed=0, g=None, **kw):
    return FaultyConsensus(graph=g or erdos_renyi(N, 0.5, seed=1),
                           faults=_model(), seed=seed, **kw)


# ---------------------------------------------------------------------------
# model validation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bad, field", [
    (dict(p_drop=1.5), "p_drop"),
    (dict(p_bad=-0.1), "p_bad"),
    (dict(p_bad=0.2, p_good=0.0), "p_good"),
    (dict(corrupt_mode="flip"), "corrupt_mode"),
    (dict(corrupt_scale=-1.0), "corrupt_scale"),
    (dict(guard_norm=0.0), "guard_norm"),
    (dict(crash_windows=((0, 2, 0),)), "crash_windows"),
    (dict(crash_windows=((-1, 2, 3),)), "crash_windows"),
])
def test_model_validation_names_field(bad, field):
    with pytest.raises(ValueError, match=field):
        NetFaultModel(**bad).validate()


def test_model_validation_bounds_against_problem():
    m = NetFaultModel(crash_windows=((9, 0, 2),))
    with pytest.raises(ValueError, match="crash_windows"):
        m.validate(n_nodes=8)
    # a crash window entirely past the horizon is an authoring error too
    m = NetFaultModel(crash_windows=((0, 10, 2),))
    with pytest.raises(ValueError, match="crash_windows"):
        m.validate(n_nodes=8, t_outer=5)


def test_node_up_marks_crash_windows():
    m = NetFaultModel(crash_windows=((1, 2, 3), (0, 0, 1)))
    up = m.node_up(6, 4)
    assert up.shape == (6, 4)
    assert up[0, 0] == 0.0 and up[1, 0] == 1.0
    assert np.all(up[2:5, 1] == 0.0) and up[5, 1] == 1.0
    assert np.all(up[:, 2:] == 1.0)


# ---------------------------------------------------------------------------
# degenerate rounds: all links down / everyone crashed -> exact identity
# ---------------------------------------------------------------------------
def test_all_links_down_round_is_identity_with_zero_sends():
    eng = FaultyConsensus(graph=erdos_renyi(N, 0.5, seed=1),
                          faults=NetFaultModel(p_drop=1.0), seed=3)
    z0 = _z()
    ledger = CommLedger()
    out = eng.run_debiased(z0, 10, ledger)
    # every round renormalizes to exact identity; debias clamp never
    # divides by ~0, so the input comes back BIT-FOR-BIT
    np.testing.assert_array_equal(np.asarray(out), np.asarray(z0))
    assert ledger.p2p == 0.0 and ledger.scalars == 0.0
    assert np.all(np.isfinite(np.asarray(out)))


def test_all_nodes_crashed_round_is_identity():
    eng = _engine()
    z0 = _z(seed=4)
    out = eng.run_debiased(z0, 5, node_up=np.zeros((N,), np.float32))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(z0))


def test_all_corrupt_degrades_to_identity():
    """A fully poisoned round must degrade to a fully dropped one: the
    norm/NaN screen rejects every payload, nothing mixes, nothing NaNs."""
    for mode in ("scale", "nan"):
        eng = FaultyConsensus(
            graph=erdos_renyi(N, 0.5, seed=1),
            faults=NetFaultModel(p_corrupt=1.0, corrupt_mode=mode), seed=5)
        z0 = _z(seed=5)
        out = eng.run_debiased(z0, 8)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(z0))


# ---------------------------------------------------------------------------
# realized round matrices stay doubly stochastic
# ---------------------------------------------------------------------------
def test_realized_round_matrix_doubly_stochastic():
    eng = _engine()
    rng = np.random.default_rng(0)
    adj = np.asarray(eng.graph.adjacency, bool)
    for _ in range(20):
        u = rng.random((N, N))
        u = np.triu(u, 1)
        u = u + u.T
        mask = adj & (u >= 0.4)
        w = eng.realized_round_matrix(mask)
        assert np.allclose(w.sum(0), 1.0, atol=1e-12)
        assert np.allclose(w.sum(1), 1.0, atol=1e-12)
        assert np.all(w >= 0.0)


# ---------------------------------------------------------------------------
# execution modes: fused scan == eager rounds (bitwise) == host oracle
# ---------------------------------------------------------------------------
def test_fused_rounds_match_eager_bitwise():
    eng, eng2 = _engine(seed=11), _engine(seed=11)
    z0 = _z(seed=1)
    node_up = jnp.ones((N,), jnp.float32).at[2].set(0.0)
    for _ in range(3):                  # burst state carries across calls
        faults = eng.sample_faults(12, t_max=20)
        faults2 = eng2.sample_faults(12, t_max=20)
        fused = masked_faulty_rounds(eng._w, eng._adj, eng._params, node_up,
                                     eng._ge, tuple(map(jnp.asarray,
                                                        faults)),
                                     jnp.int32(12), z0)
        eager = eng2.run_rounds_eager(z0, node_up, faults2)
        for a, b in zip(fused, eager):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        eng._ge, eng2._ge = fused[2], eager[2]


def test_host_oracle_matches_device_rounds():
    eng = _engine(seed=2)
    host = FaultyConsensus(graph=eng.graph, faults=eng.faults, seed=2,
                           fused=False)
    z0 = _z(seed=2)
    out_dev = eng.run_debiased(z0, 15)
    out_host = host.run_debiased(z0, 15)
    # same masks, same op order; np vs XLA einsum differ by ~1 ulp
    np.testing.assert_allclose(np.asarray(out_dev), np.asarray(out_host),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(eng._ge), np.asarray(host._ge))


def test_realized_debias_consensus_converges_under_drops():
    eng = FaultyConsensus(graph=erdos_renyi(N, 0.5, seed=1),
                          faults=NetFaultModel(p_drop=0.3), seed=0)
    z0 = _z()
    out = eng.run_debiased(z0, 300)
    assert float(jnp.abs(out - z0.sum(0)[None]).max()) < 1e-3


def test_padded_draws_slice_consistently():
    """sample_faults(t_c, t_max) must equal the first t_c rows of the
    padded draw — the contract that lets eager runs replay fused scans."""
    key = jax.random.PRNGKey(9)
    full = sample_fault_blocks(key, N, 20)
    eng = _engine(seed=9)
    got = eng.sample_faults(12, t_max=20)
    _, sub = jax.random.split(jax.random.PRNGKey(9))
    ref = sample_fault_blocks(sub, N, 20)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r[:12]))
    assert full[0].shape == (20, N, N)


# ---------------------------------------------------------------------------
# algorithm zoo under faults: fused executors vs eager oracles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sched_kind", ["const", "lin2"])
@pytest.mark.parametrize("topo", ["ring", "er"])
def test_sdot_faulty_fused_matches_eager_bitwise(psa_problem, sched_kind,
                                                 topo):
    p = psa_problem
    g = (ring(p["n_nodes"]) if topo == "ring"
         else erdos_renyi(p["n_nodes"], 0.5, seed=1))
    model = NetFaultModel(p_drop=0.2, p_bad=0.05, p_good=0.5,
                          p_corrupt=0.02, crash_windows=((0, 2, 2),))
    sched = consensus_schedule(sched_kind, 6, t_max=8, cap=8)
    run = lambda fused: sdot(
        covs=p["covs"], engine=FaultyConsensus(graph=g, faults=model,
                                               seed=7),
        r=p["r"], t_outer=6, schedule=sched, q_true=p["q_true"],
        fused=fused)
    fres, eres = run(True), run(False)
    # final iterates are BITWISE equal; the error trace is computed inside
    # the jitted scan (fused) vs per-iteration (eager), so XLA fusion can
    # move it by ~1 ulp — same pin as test_sdot_fused.py
    np.testing.assert_array_equal(np.asarray(fres.q_nodes),
                                  np.asarray(eres.q_nodes))
    np.testing.assert_allclose(fres.error_trace, eres.error_trace,
                               rtol=1e-5, atol=1e-7)
    assert fres.ledger.p2p == eres.ledger.p2p
    assert fres.ledger.scalars == eres.ledger.scalars
    assert fres.ledger.awake_counts == eres.ledger.awake_counts


def test_sdot_faulty_syncs_engine_state(psa_problem):
    """After a fused run the engine's RNG key and burst state equal the
    eager run's — chaining runs off one engine is execution-mode agnostic."""
    p = psa_problem
    g = erdos_renyi(p["n_nodes"], 0.5, seed=1)
    model = NetFaultModel(p_drop=0.2, p_bad=0.1, p_good=0.4)
    e1 = FaultyConsensus(graph=g, faults=model, seed=3)
    e2 = FaultyConsensus(graph=g, faults=model, seed=3)
    sdot(covs=p["covs"], engine=e1, r=p["r"], t_outer=4, t_c=6, fused=True)
    sdot(covs=p["covs"], engine=e2, r=p["r"], t_outer=4, t_c=6, fused=False)
    np.testing.assert_array_equal(np.asarray(e1._key), np.asarray(e2._key))
    np.testing.assert_array_equal(np.asarray(e1._ge), np.asarray(e2._ge))


def test_sdot_faulty_reaches_floor(psa_problem):
    p = psa_problem
    eng = FaultyConsensus(graph=erdos_renyi(p["n_nodes"], 0.5, seed=1),
                          faults=NetFaultModel(p_drop=0.2), seed=0)
    res = sdot(covs=p["covs"], engine=eng, r=p["r"], t_outer=60, t_c=30,
               q_true=p["q_true"])
    assert res.error_trace[-1] < 1e-5


def test_sdot_crashed_node_freezes_then_rejoins(psa_problem):
    """During its window the crashed node's iterate must not move; after
    rejoin it must re-converge with everyone else."""
    p = psa_problem
    model = NetFaultModel(crash_windows=((3, 0, 4),))
    eng = FaultyConsensus(graph=erdos_renyi(p["n_nodes"], 0.5, seed=1),
                          faults=model, seed=0)
    import repro.core.sdot as sdot_mod
    prep = sdot_mod._prepare_sdot(covs=p["covs"], data=None, engine=eng,
                                  r=p["r"], t_outer=10, t_c=10,
                                  schedule=None, q_init=None,
                                  q_true=p["q_true"], seed=0)
    q_frozen = np.asarray(prep["q_nodes"][3])
    # window [0, 4): the whole 4-iteration run leaves node 3 at its init
    eng2 = FaultyConsensus(graph=eng.graph, faults=model, seed=0)
    partial = sdot(covs=p["covs"], engine=eng2, r=p["r"], t_outer=4,
                   t_c=10, fused=False)
    np.testing.assert_array_equal(np.asarray(partial.q_nodes[3]), q_frozen)
    res = sdot(covs=p["covs"], engine=eng, r=p["r"], t_outer=16, t_c=10,
               q_true=p["q_true"], fused=False)
    assert res.error_trace[-1] < 1e-4      # rejoined and re-converged


def test_fdot_faulty_fused_matches_eager(psa_problem):
    p = psa_problem
    x = np.concatenate([np.asarray(b) for b in p["blocks"]], axis=1)
    x = jnp.asarray(x[:, :120])
    _, q_true = eigh_topr(x @ x.T / x.shape[1], p["r"])
    blocks = partition_features(x, 4)
    model = NetFaultModel(p_drop=0.15, p_bad=0.05, p_good=0.5)
    run = lambda fused: fdot(
        data_blocks=blocks,
        engine=FaultyConsensus(graph=erdos_renyi(4, 0.9, seed=1),
                               faults=model, seed=2),
        r=p["r"], t_outer=5, t_c=8, q_true=q_true, fused=fused)
    fres, eres = run(True), run(False)
    # the existing F-DOT precedent (test_fused_zoo): eager uses ragged
    # per-block matmuls, fused uses padded slabs -> allclose, not bitwise
    np.testing.assert_allclose(fres.error_trace, eres.error_trace,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(fres.q_full),
                               np.asarray(eres.q_full), rtol=1e-4,
                               atol=1e-5)
    assert fres.ledger.p2p == eres.ledger.p2p
    assert fres.ledger.awake_counts == eres.ledger.awake_counts


def test_fdot_faulty_reaches_floor(psa_problem):
    p = psa_problem
    x = np.concatenate([np.asarray(b) for b in p["blocks"]], axis=1)
    x = jnp.asarray(x[:, :160])
    _, q_true = eigh_topr(x @ x.T / x.shape[1], p["r"])
    blocks = partition_features(x, 4)
    eng = FaultyConsensus(graph=erdos_renyi(4, 0.9, seed=1),
                          faults=NetFaultModel(p_drop=0.2), seed=0)
    res = fdot(data_blocks=blocks, engine=eng, r=p["r"], t_outer=25,
               t_c=40, q_true=q_true)
    # F-DOT gossips GRAM matrices, so the realized-mixing residual feeds
    # the QR directly (not washed out like S-DOT's scalar) — the faulty
    # floor sits ~1e-4 rather than the fault-free 1e-6
    assert res.error_trace[-1] < 5e-4


# ---------------------------------------------------------------------------
# sweep lane
# ---------------------------------------------------------------------------
def test_netfault_sweep_matches_single_runs(psa_problem):
    p = psa_problem
    g1 = erdos_renyi(p["n_nodes"], 0.5, seed=1)
    g2 = ring(p["n_nodes"])
    model = NetFaultModel(p_drop=0.2, p_bad=0.05, p_good=0.5)
    engines = [FaultyConsensus(graph=g, faults=model, seed=4)
               for g in (g1, g2)]
    schedules = [consensus_schedule("const", 5, t_max=8),
                 consensus_schedule("lin2", 5, cap=8)]
    seeds = [0, 3]
    sw = netfault_sweep(covs=p["covs"], engines=engines,
                        schedules=schedules, r=p["r"], t_outer=5,
                        seeds=seeds, q_true=p["q_true"])
    assert sw.error_traces.shape == (2, 2, 5)
    for ci, (g, sched) in enumerate(zip((g1, g2), schedules)):
        for si, s in enumerate(seeds):
            eng = FaultyConsensus(graph=g, faults=model, seed=4)
            eng._key = jax.random.fold_in(eng._key, s)
            single = sdot(covs=p["covs"], engine=eng, r=p["r"], t_outer=5,
                          schedule=sched, q_true=p["q_true"], seed=s)
            np.testing.assert_allclose(sw.error_traces[ci, si],
                                       single.error_trace, atol=1e-6)


def test_netfault_sweep_requires_faulty_engines(psa_problem):
    p = psa_problem
    with pytest.raises(ValueError, match="FaultyConsensus"):
        netfault_sweep(covs=p["covs"],
                       engines=[DenseConsensus(ring(p["n_nodes"]))],
                       r=p["r"], t_outer=4, seeds=[0])


# ---------------------------------------------------------------------------
# merge_shards input validation
# ---------------------------------------------------------------------------
def _shard_tree(seeds, fp=101):
    return {"q": jnp.zeros((len(seeds), 2, 2)),
            "seeds": jnp.asarray(seeds),
            "ledger": CommLedger(),
            "spec_fp": jnp.asarray(fp, jnp.int32)}


def test_merge_shards_rejects_mismatched_fingerprints():
    with pytest.raises(ValueError, match="different sweep specs"):
        SweepResult.merge_shards([_shard_tree([0, 1], fp=101),
                                  _shard_tree([2, 3], fp=202)],
                                 n_cases=1, has_err=False, ragged=False)


def test_merge_shards_rejects_overlapping_seed_slices():
    with pytest.raises(ValueError, match="seed 1 appears in shard 0 and "
                                         "shard 1"):
        SweepResult.merge_shards([_shard_tree([0, 1]), _shard_tree([1, 2])],
                                 n_cases=1, has_err=False, ragged=False)


def test_merge_shards_accepts_disjoint_same_fp():
    sw = SweepResult.merge_shards([_shard_tree([0, 1]), _shard_tree([2])],
                                  n_cases=1, has_err=False, ragged=False)
    assert list(np.asarray(sw.seeds)) == [0, 1, 2]


# ---------------------------------------------------------------------------
# net-fault plan front door (streaming/chaos.py)
# ---------------------------------------------------------------------------
def test_net_fault_doc_validation_diagnostics():
    from repro.streaming.chaos import validate_net_fault_doc
    validate_net_fault_doc({})                    # empty = fault-free
    ok = {"seed": 1, "p_drop": 0.2, "burst": {"p_bad": 0.1, "p_good": 0.5},
          "corrupt": {"p": 0.01, "mode": "nan"},
          "crash": [{"node": 0, "start": 1, "len": 2}]}
    assert validate_net_fault_doc(ok) is ok
    for doc, msg in [
        ({"p_drop": 2.0}, r"p_drop: must be in \[0.0, 1.0\]"),
        ({"frobnicate": 1}, "frobnicate: unknown field"),
        ({"burst": {"p_bad": 0.1, "p_good": 0.0}}, "burst.p_good"),
        ({"corrupt": {"mode": "zap"}}, "corrupt.mode"),
        ({"crash": [{"node": 0, "start": 0, "len": 0}]}, r"crash\[0\].len"),
        ({"crash": [{"node": 0, "start": 0}]}, r"crash\[0\].len: missing"),
        ({"debias": "magic"}, "debias"),
    ]:
        with pytest.raises(ValueError, match=msg):
            validate_net_fault_doc(doc)


def test_net_fault_model_from_dict_roundtrip():
    from repro.streaming.chaos import net_fault_model_from_dict
    doc = {"seed": 5, "p_drop": 0.3, "burst": {"p_bad": 0.1, "p_good": 0.5},
           "corrupt": {"p": 0.02, "mode": "nan", "guard": 100.0},
           "crash": [{"node": 2, "start": 1, "len": 4}],
           "debias": "nominal"}
    model, seed, debias = net_fault_model_from_dict(doc)
    assert (seed, debias) == (5, "nominal")
    assert model.p_drop == 0.3 and model.p_bad == 0.1
    assert model.corrupt_mode == "nan" and model.guard_norm == 100.0
    assert model.crash_windows == ((2, 1, 4),)


def test_net_faults_from_env(monkeypatch, tmp_path):
    from repro.streaming import chaos
    monkeypatch.delenv(chaos.ENV_NET, raising=False)
    assert chaos.net_faults_from_env() is None
    monkeypatch.setenv(chaos.ENV_NET, '{"p_drop": 0.1}')
    assert chaos.net_faults_from_env() == {"p_drop": 0.1}
    path = tmp_path / "nf.json"
    path.write_text(json.dumps({"p_drop": 0.2, "seed": 3}))
    monkeypatch.setenv(chaos.ENV_NET, str(path))
    assert chaos.net_faults_from_env()["seed"] == 3
    monkeypatch.setenv(chaos.ENV_NET, '{"p_drop": 7}')
    with pytest.raises(ValueError, match="p_drop"):
        chaos.net_faults_from_env()


def test_validate_cli_mode(tmp_path, capsys):
    from repro.streaming import chaos
    good = tmp_path / "good.json"
    good.write_text('{"p_drop": 0.2}')
    assert chaos.main(["--validate", str(good)]) == 0
    assert "valid net-fault plan" in capsys.readouterr().out

    plan = tmp_path / "plan.json"
    plan.write_text('{"faults": [{"kind": "kill", "shard": 0}]}')
    assert chaos.main(["--validate", str(plan)]) == 0
    assert "valid process fault plan" in capsys.readouterr().out

    bad = tmp_path / "bad.json"
    bad.write_text('{"p_drop": 2.0}')
    assert chaos.main(["--validate", str(bad)]) == 1
    assert "p_drop" in capsys.readouterr().out

    torn = tmp_path / "torn.json"
    torn.write_text('{"p_drop": 0.2,\n  "seed": }')
    assert chaos.main(["--validate", str(torn)]) == 1
    out = capsys.readouterr().out
    assert f"{torn}:2:" in out and "invalid JSON" in out
