"""Multi-host Monte-Carlo sweep launcher.

``core/sweep.py`` collapses a seeds x cases grid into one compiled program —
for one process.  This module shards that grid over *hosts* (subprocess
workers standing in for hosts in this container; the same spec/result
protocol maps onto one job per machine on a real fleet):

    launch_sweep(...)
      -> writes <workdir>/spec.json (topologies, schedules, shard seed
         lists — everything a worker needs to rebuild its slice) and
         <workdir>/problem.npz (cov stacks, optional ground truth)
      -> spawns one `python -m repro.streaming.worker <spec> <shard>` per
         shard; each worker runs its vmap lane-slice of the sweep and
         publishes its result atomically (checkpoint/manager.save_tree,
         CommLedger riding along as a registered pytree) into its own
         checkpoint dir <workdir>/worker_<i>/
      -> gathers the shard results and merges them along the seed axis
         into ONE SweepResult, equal to the single-process ``sdot_sweep``
         over the full seed list (lane-slices are arithmetically
         identical; XLA may schedule a width-1 vmap differently, so
         equality is pinned at float32 epsilon in tests/test_streaming.py
         and bit-for-bit when shard widths match the full sweep's).

Shard-granular fault tolerance: a worker that already published a valid
result is never relaunched (so a killed launcher resumes where it left
off), a crashed worker is retried, and only then does the launch fail.

Topologies/schedules travel as small JSON specs (``build_engine`` /
``build_schedule``) because graph constructions are seed-deterministic —
the paper's experiment grid is fully reproducible from the spec file.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import subprocess
import sys
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import restore_tree
from ..core.consensus import DenseConsensus, consensus_schedule
from ..core.metrics import CommLedger
from ..core.sweep import SweepResult
from ..core.topology import complete, erdos_renyi, ring, star, torus2d

__all__ = ["build_engine", "build_schedule", "launch_sweep"]

_SPEC = "spec.json"
_PROBLEM = "problem.npz"


def build_engine(topo: dict) -> DenseConsensus:
    """Topology spec -> consensus engine (seed-deterministic across hosts)."""
    kind = topo["kind"]
    if kind == "ring":
        g = ring(topo["n"])
    elif kind == "star":
        g = star(topo["n"])
    elif kind == "complete":
        g = complete(topo["n"])
    elif kind == "torus2d":
        g = torus2d(topo["rows"], topo["cols"])
    elif kind == "er":
        g = erdos_renyi(topo["n"], topo["p"], seed=topo.get("seed", 0))
    else:
        raise ValueError(f"unknown topology kind: {kind}")
    return DenseConsensus(g)


def build_schedule(sched: Optional[dict], t_outer: int,
                   t_c: int) -> np.ndarray:
    """Schedule spec -> (t_outer,) consensus budgets."""
    if sched is None:
        return consensus_schedule("const", t_outer, t_max=t_c)
    if "values" in sched:
        return np.asarray(sched["values"])[:t_outer]
    return consensus_schedule(sched["kind"], t_outer,
                              t_max=sched.get("t_max", t_c),
                              cap=sched.get("cap"))


def _worker_dir(workdir: str, shard: int) -> str:
    return os.path.join(workdir, f"worker_{shard}")


def _result_dir(workdir: str, shard: int) -> str:
    return os.path.join(_worker_dir(workdir, shard), "result")


def spec_fingerprint(spec: dict) -> int:
    """Stable 31-bit digest of the sweep spec (int32-safe: jax x64 is off).

    Stamped into every worker's published result and checked before a
    shard is reused, so rerunning a workdir with a *changed* spec (more
    seeds, different cases/t_outer) relaunches instead of silently merging
    stale shards. ``sweep_chunk`` is excluded: chunking is bit-exact by
    construction, so a resume may change the chunk size without
    invalidating published shards."""
    blob = json.dumps({k: v for k, v in spec.items() if k != "sweep_chunk"},
                      sort_keys=True).encode()
    return int.from_bytes(hashlib.sha256(blob).digest()[:4], "big") >> 1


def _result_like(spec: dict, with_resumed: bool = True):
    """Structure template for restore_tree (values are ignored)."""
    like = {"q": jnp.zeros(()), "seeds": jnp.zeros(()),
            "ledger": CommLedger(),
            "spec_fp": jnp.zeros((), jnp.int32)}
    if with_resumed:
        like["resumed_steps"] = jnp.zeros((), jnp.int32)
    if spec["has_q_true"]:
        like["error_traces"] = jnp.zeros(())
    if spec["ragged"]:
        like["node_counts"] = jnp.zeros(())
    return like


def _load_result(workdir: str, spec: dict, shard: int):
    """The shard's published result, or None if absent/stale/corrupt.

    A result published under a different spec (stale workdir reuse) fails
    either the tree-structure check or the fingerprint comparison and is
    discarded so the launcher recomputes it. Results published before the
    ``resumed_steps`` leaf existed still restore (never recompute a valid
    shard over a reporting field) and report 0."""
    path = _result_dir(workdir, shard)
    if not os.path.exists(os.path.join(path, "manifest.json")):
        return None
    tree = None
    for with_resumed in (True, False):
        try:
            tree = restore_tree(path, _result_like(spec, with_resumed))
            break
        except Exception:
            continue
    if tree is None:
        return None
    if int(tree["spec_fp"]) != spec_fingerprint(spec):
        return None
    tree.setdefault("resumed_steps", 0)
    return tree


def _spawn(spec_path: str, shard: int, env) -> subprocess.Popen:
    return subprocess.Popen(
        [sys.executable, "-m", "repro.streaming.worker", spec_path,
         str(shard)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env)


def launch_sweep(
    *,
    covs,
    cases: Sequence[dict],
    r: int,
    t_outer: int,
    t_c: int = 50,
    seeds: Sequence[int],
    q_true=None,
    workdir: str,
    n_workers: int = 2,
    retries: int = 1,
    timeout: float = 900.0,
    sweep_chunk: Optional[int] = None,
) -> SweepResult:
    """Shard a ``sdot_sweep`` case x seed grid over subprocess workers.

    ``covs``: one (N, d, d) stack shared by every case, or a list with one
    stack per case (ragged node counts allowed — the workers run the same
    identity-padding path as single-process ``sdot_sweep``).  ``cases``:
    list of ``{"topology": {...}, "schedule": {...}}`` specs (see
    ``build_engine`` / ``build_schedule``).  The seed axis is split
    contiguously into ``n_workers`` shards (one vmap lane-slice each), so
    the merged result preserves seed order and equals the single-process
    sweep exactly.

    ``sweep_chunk`` turns on MID-GRID fault tolerance: each worker runs its
    shard through the runtime's chunked driver, checkpointing the
    sweep-RunState into its own ``worker_<i>/ckpt`` dir every
    ``sweep_chunk`` outer iterations — a killed worker resumes from the
    checkpoint (bitwise equal to the uninterrupted sweep) instead of
    recomputing its shard. The returned ``SweepResult.resume_report``
    records the reused shards (grid points skipped wholesale) and each
    relaunched worker's restored outer step.
    """
    os.makedirs(workdir, exist_ok=True)
    seeds = [int(s) for s in seeds]
    n_workers = max(1, min(int(n_workers), len(seeds)))
    shards = [list(map(int, s))
              for s in np.array_split(np.asarray(seeds), n_workers)]

    ragged = isinstance(covs, (list, tuple))
    if ragged and len(covs) not in (1, len(cases)):
        # enforce sdot_sweep's zip-broadcast contract before anything is
        # written, rather than as a KeyError inside every worker; a
        # 1-element list is written ONCE (not duplicated per case) and
        # broadcast worker-side by sdot_sweep itself
        raise ValueError(f"per-case covs must zip-broadcast with the "
                         f"cases: got {len(covs)} cov stacks for "
                         f"{len(cases)} cases")
    spec = {
        "algo": "sdot",
        "r": int(r),
        "t_outer": int(t_outer),
        "t_c": int(t_c),
        "cases": list(cases),
        "shards": shards,
        "ragged": ragged,
        "n_cov_stacks": len(covs) if ragged else 1,
        "has_q_true": q_true is not None,
        "sweep_chunk": int(sweep_chunk) if sweep_chunk else None,
    }
    spec_path = os.path.join(workdir, _SPEC)
    with open(spec_path, "w") as f:
        json.dump(spec, f, indent=2)

    # a changed spec invalidates the workers' intermediate sweep
    # checkpoints (published results carry their own fingerprint stamp;
    # the ckpt dirs don't, so they are guarded here at the workdir level)
    fp = str(spec_fingerprint(spec))
    fp_path = os.path.join(workdir, "spec_fp")
    if os.path.exists(fp_path):
        with open(fp_path) as f:
            if f.read().strip() != fp:
                for name in os.listdir(workdir):
                    ckpt = os.path.join(workdir, name, "ckpt")
                    if name.startswith("worker_") and os.path.isdir(ckpt):
                        shutil.rmtree(ckpt, ignore_errors=True)
    with open(fp_path, "w") as f:
        f.write(fp)

    arrays = {}
    if ragged:
        for ci, c in enumerate(covs):
            arrays[f"covs_{ci}"] = np.asarray(c)
    else:
        arrays["covs"] = np.asarray(covs)
    if q_true is not None:
        arrays["q_true"] = np.asarray(q_true)
    np.savez(os.path.join(workdir, _PROBLEM), **arrays)

    env = dict(os.environ)
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")

    # published shards are reused only if their stamped spec fingerprint
    # matches; stale/corrupt ones are cleared and recomputed
    results = {i: _load_result(workdir, spec, i) for i in range(n_workers)}
    pending = [i for i, t in results.items() if t is None]
    reused = sorted(i for i, t in results.items() if t is not None)
    for i in pending:
        shutil.rmtree(_result_dir(workdir, i), ignore_errors=True)
    for attempt in range(retries + 1):
        if not pending:
            break
        procs = {i: _spawn(spec_path, i, env) for i in pending}
        failed = []
        for i, p in procs.items():
            try:
                _out, err = p.communicate(timeout=timeout)
            except subprocess.TimeoutExpired:
                p.kill()
                _out, err = p.communicate()
            results[i] = (None if p.returncode != 0
                          else _load_result(workdir, spec, i))
            if results[i] is None:
                failed.append((i, err))
        pending = [i for i, _ in failed]
        if pending and attempt == retries:
            raise RuntimeError(
                f"sweep workers {pending} failed after {retries + 1} "
                f"attempts; last stderr:\n{failed[0][1][-2000:]}")

    # gather + merge along the seed axis (shards are contiguous slices)
    qs, errs, counts, node_counts = [], [], [], None
    ledger = CommLedger()
    seed_axis = 1 if len(cases) > 1 else 0
    resumed_steps = {}
    for i in range(n_workers):
        tree = results[i]
        qs.append(np.asarray(tree["q"]))
        counts.append(np.asarray(tree["seeds"]))
        ledger = ledger.merged(tree["ledger"])
        resumed_steps[i] = int(tree["resumed_steps"])
        if spec["has_q_true"]:
            errs.append(np.asarray(tree["error_traces"]))
        if spec["ragged"]:
            node_counts = np.asarray(tree["node_counts"])
    report = {
        # shards whose published result was reused wholesale — their whole
        # case x seed sub-grid was skipped
        "reused_shards": reused,
        "skipped_grid_points": sum(len(shards[i]) for i in reused)
        * len(cases),
        # outer step each worker's restored sweep-RunState already carried
        # (0 = computed from scratch)
        "worker_resumed_steps": resumed_steps,
    }
    return SweepResult(
        q=jnp.asarray(np.concatenate(qs, axis=seed_axis)),
        error_traces=(np.concatenate(errs, axis=seed_axis)
                      if spec["has_q_true"] else None),
        ledger=ledger,
        seeds=np.concatenate(counts),
        node_counts=node_counts,
        resume_report=report,
    )
