"""Fused (single-scan) S-DOT/SA-DOT executor vs the eager oracle.

The fused path must reproduce the eager per-iteration loop to float-op
identity: same gossip op order, debias weights from the device table instead
of host matrix_power, error trace computed on device. Tolerances are tight
(the only fp differences are f32 matvec-chain vs f64 matrix_power debias —
and debias is a per-node positive scalar, which the QR cancels entirely).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import (DenseConsensus, consensus_schedule,
                                  debias_table, debias_weights)
from repro.core.linalg import orthonormal_init
from repro.core.metrics import CommLedger
from repro.core.sdot import sadot, sdot
from repro.core.topology import erdos_renyi, ring, star


def _run_pair(engine, *, covs=None, data=None, schedule=None, t_c=50,
              t_outer=20, q_init, q_true, r):
    eager = sdot(covs=covs, data=data, engine=engine, r=r, t_outer=t_outer,
                 schedule=schedule, t_c=t_c, q_init=q_init, q_true=q_true,
                 fused=False)
    fused = sdot(covs=covs, data=data, engine=engine, r=r, t_outer=t_outer,
                 schedule=schedule, t_c=t_c, q_init=q_init, q_true=q_true,
                 fused=True)
    return eager, fused


@pytest.fixture(scope="module")
def topologies(psa_problem):
    n = psa_problem["n_nodes"]
    return {
        "er": DenseConsensus(erdos_renyi(n, 0.5, seed=1)),
        "ring": DenseConsensus(ring(n)),
    }


@pytest.mark.parametrize("topo", ["er", "ring"])
@pytest.mark.parametrize("sched_kind", ["const", "lin2"])
def test_fused_matches_eager_covs(psa_problem, topologies, topo, sched_kind):
    p = psa_problem
    eng = topologies[topo]
    q0 = orthonormal_init(jax.random.PRNGKey(3), p["d"], p["r"])
    sched = (None if sched_kind == "const"
             else consensus_schedule("lin2", 20, cap=50))
    eager, fused = _run_pair(eng, covs=p["covs"], schedule=sched, t_c=50,
                             t_outer=20, q_init=q0, q_true=p["q_true"],
                             r=p["r"])
    np.testing.assert_allclose(fused.error_trace, eager.error_trace,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(fused.q_nodes),
                               np.asarray(eager.q_nodes), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_array_equal(fused.consensus_trace,
                                  eager.consensus_trace)


@pytest.mark.parametrize("topo", ["er", "ring"])
def test_fused_matches_eager_raw_data(psa_problem, topologies, topo):
    """Gram-free data path: batched gram-apply inside the scan == the eager
    per-node list comprehension."""
    p = psa_problem
    eng = topologies[topo]
    q0 = orthonormal_init(jax.random.PRNGKey(4), p["d"], p["r"])
    eager, fused = _run_pair(eng, data=p["blocks"], t_c=50, t_outer=15,
                             q_init=q0, q_true=p["q_true"], r=p["r"])
    np.testing.assert_allclose(fused.error_trace, eager.error_trace,
                               rtol=1e-4, atol=1e-6)


def test_fused_matches_eager_ragged_data(topologies):
    """Ragged n_i: zero-padded stacking must not change the fused result."""
    rng = np.random.default_rng(0)
    d, r, n = 12, 3, 10
    sizes = rng.integers(50, 200, size=n)
    blocks = [jnp.asarray(rng.standard_normal((d, s)), jnp.float32)
              for s in sizes]
    covs = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
    from repro.core.linalg import eigh_topr
    _, q_true = eigh_topr(covs.sum(0), r)
    eng = topologies["er"]
    q0 = orthonormal_init(jax.random.PRNGKey(5), d, r)
    eager, fused = _run_pair(eng, data=blocks, t_c=30, t_outer=12, q_init=q0,
                             q_true=q_true, r=r)
    np.testing.assert_allclose(fused.error_trace, eager.error_trace,
                               rtol=1e-4, atol=1e-6)


def test_sadot_fused_default_converges(psa_problem, topologies):
    """sadot (fused default) still meets the paper's convergence bar."""
    p = psa_problem
    res = sadot(covs=p["covs"], engine=topologies["er"], r=p["r"], t_outer=60,
                schedule_kind="lin2", cap=50, q_true=p["q_true"])
    assert res.error_trace[-1] < 5e-6


def test_fused_without_q_true_has_no_trace(psa_problem, topologies):
    res = sdot(covs=psa_problem["covs"], engine=topologies["er"],
               r=psa_problem["r"], t_outer=5, t_c=10)
    assert res.error_trace is None
    assert res.q_nodes.shape == (psa_problem["n_nodes"], psa_problem["d"],
                                 psa_problem["r"])


# ---------------------------------------------------------------------------
# components: debias table, run_debiased_scan, vectorized ledger
# ---------------------------------------------------------------------------
def test_debias_table_matches_matrix_power(topologies):
    for eng in topologies.values():
        t_max = 17
        table = np.asarray(eng.debias_table(t_max))
        assert table.shape == (t_max + 1, eng.graph.n_nodes)
        for t in (0, 1, 5, 17):
            want = debias_weights(eng.weights, t)
            np.testing.assert_allclose(table[t], want, rtol=1e-5, atol=1e-6)


def test_run_debiased_scan_matches_run_debiased(topologies):
    eng = topologies["ring"]
    n = eng.graph.n_nodes
    z = jnp.asarray(np.random.default_rng(2).standard_normal((n, 6, 3)),
                    jnp.float32)
    for t_c in (1, 7, 20):
        want = eng.run_debiased(z, t_c)
        got = eng.run_debiased_scan(z, jnp.int32(t_c), t_max=20)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_vectorized_ledger_equals_loop_ledger(topologies):
    sched = consensus_schedule("lin2", 40, cap=50)
    payload = 20 * 5
    for eng in topologies.values():
        adj = eng.graph.adjacency
        loop = CommLedger()
        for t in range(len(sched)):
            for _ in range(int(sched[t])):
                loop.log_gossip_round(adj, payload)
        vec = CommLedger()
        vec.log_gossip_rounds(sched, adj, payload)
        assert vec.p2p == loop.p2p
        assert vec.matrices == loop.matrices
        assert vec.scalars == loop.scalars


def test_fused_ledger_equals_eager_ledger(psa_problem, topologies):
    p = psa_problem
    sched = consensus_schedule("lin2", 25, cap=50)
    eager, fused = _run_pair(topologies["er"], covs=p["covs"], schedule=sched,
                             t_outer=25,
                             q_init=orthonormal_init(jax.random.PRNGKey(6),
                                                     p["d"], p["r"]),
                             q_true=None, r=p["r"])
    assert fused.ledger.p2p == eager.ledger.p2p
    assert fused.ledger.matrices == eager.ledger.matrices
    assert fused.ledger.scalars == eager.ledger.scalars


def test_short_schedule_rejected(psa_problem, topologies):
    """A schedule shorter than t_outer must fail loudly in both modes."""
    p = psa_problem
    for fused in (True, False):
        with pytest.raises(ValueError, match="schedule"):
            sdot(covs=p["covs"], engine=topologies["er"], r=p["r"], t_outer=10,
                 schedule=np.array([5, 5]), fused=fused)


def test_run_debiased_scan_rejects_tc_over_tmax(topologies):
    eng = topologies["ring"]
    z = jnp.zeros((eng.graph.n_nodes, 4, 2))
    with pytest.raises(ValueError, match="t_max"):
        eng.run_debiased_scan(z, 30, t_max=20)


def test_fused_is_single_compile_across_schedules(psa_problem, topologies):
    """Two SA-DOT runs with the same shapes/t_max reuse one compiled program
    (the schedule is an operand, not a static); changing t_max recompiles.
    The program is the unified runtime's generic chunk driver — its cache
    keys on (build_body, statics, shapes), not on per-run closures."""
    from repro.core.runtime import _chunk_program
    p = psa_problem
    eng = topologies["er"]
    base = _chunk_program._cache_size()
    # t_outer=11 keeps this signature unique across the suite (the sweep
    # tests compile t_outer=10/t_max=30 first), so the count is exact
    s1 = consensus_schedule("lin1", 11, cap=30)
    s1[:] = np.minimum(s1, 30)
    s2 = consensus_schedule("lin2", 11, cap=30)
    s1[-1] = 30  # pin equal t_max for both schedules
    s2[-1] = 30
    for s in (s1, s2):
        sdot(covs=p["covs"], engine=eng, r=p["r"], t_outer=11, schedule=s,
             q_true=p["q_true"])
    assert _chunk_program._cache_size() == base + 1
