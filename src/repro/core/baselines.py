"""Baseline algorithms the paper compares against (Figs. 4-6).

Centralized:
  * ``seq_pm``       — sequential power method with deflation (SeqPM)
Distributed, sample-partitioned:
  * ``seq_dist_pm``  — SeqPM with consensus-averaged matvecs (SeqDistPM, [13])
  * ``dsa``          — distributed Sanger's algorithm (Hebbian, [19])
  * ``dpgd``         — distributed projected gradient descent ([35]-style)
  * ``deepca``       — gradient-tracking power iteration (DeEPCA, [27])
Distributed, feature-partitioned:
  * ``d_pm``         — sequential distributed power method of [10]

All return (q_estimate(s), error_trace) with the paper's metric (11) traced
per *outer* iteration so plots match the paper's x-axis conventions
(inner x outer for consensus-based methods — callers scale accordingly).

Every distributed baseline runs **fused by default** (same architecture as
sdot.py/fdot.py): the whole run is one jitted ``lax.scan``, the error trace
is computed on device, and communication is accounted in closed form
(CommLedger.log_gossip_rounds). The sequential-deflation methods
(``seq_dist_pm``, ``d_pm``) scan over the flattened (eigenvector k,
inner-iteration j) index with masked deflation — a ``fori_loop`` over
candidate deflation vectors replays the eager Gram-Schmidt order exactly, so
fused == eager to float tolerance. ``fused=False`` keeps the original eager
per-iteration loop as the correctness oracle (tests/test_fused_zoo.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .consensus import DenseConsensus, debiased_gossip
from .linalg import cholesky_qr2, orthonormal_init
from .metrics import CommLedger, subspace_error, subspace_error_from_cross
from .sdot import local_cov_apply

__all__ = ["seq_pm", "seq_dist_pm", "dsa", "dpgd", "deepca", "d_pm"]


def _trace(q_true, q):
    return float(subspace_error(q_true, q)) if q_true is not None else np.nan


def _masked_node_mean(q, node_mask):
    """Mean over the node axis restricted to ``node_mask > 0`` nodes.

    With a mask of ones this is exactly ``q.mean(0)`` (multiply-by-1.0 and
    divide-by-N reproduce the unmasked op order), so the plain sweeps are
    unchanged; the ragged-N sweep engine passes a real mask to keep the
    isolated identity-padding nodes out of the consensus-mean estimate the
    error trace is computed from."""
    m = node_mask.astype(q.dtype)
    bshape = (-1,) + (1,) * (q.ndim - 1)
    return jnp.sum(q * m.reshape(bshape), axis=0) / jnp.sum(m)


def _supports_fused(engine) -> bool:
    """Fused baselines need the dense weight matrix (+ debias table for the
    consensus-sum methods); engines without them (e.g. AsyncConsensus with
    host-side rounds disabled) fall back to the eager loop."""
    return hasattr(engine, "_w") and hasattr(engine, "debias_table")


def _finish_errs(errs, n_steps: int, trace_err: bool) -> np.ndarray:
    """Device trace -> host array; NaN-fill when no ground truth was given
    (matching the eager loop's per-iteration np.nan appends)."""
    return np.asarray(errs) if trace_err else np.full(n_steps, np.nan)


# --------------------------------------------------------------------------
# centralized sequential power method
# --------------------------------------------------------------------------
def seq_pm(m: jnp.ndarray, r: int, iters_per_vec: int, q_true=None, seed: int = 0):
    """Power method + deflation, one eigenvector at a time.

    The error trace is recorded against the *full* current estimate (later
    columns still at their random init), reproducing the paper's observation
    that sequential methods plateau high until the last vector converges.
    """
    d = m.shape[0]
    q = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    cols = [q[:, i] for i in range(r)]
    errs = []
    m_defl = m
    # deflation projector P = I - sum_j Q_j Q_j^T, accumulated incrementally
    # (one rank-1 update per converged vector instead of an O(r d^2) rebuild)
    p = jnp.eye(d)
    for k in range(r):
        v = cols[k]
        for _ in range(iters_per_vec):
            v = m_defl @ v
            # re-orthogonalize against converged columns for stability
            for j in range(k):
                v = v - cols[j] * (cols[j] @ v)
            v = v / jnp.linalg.norm(v)
            errs.append(_trace(q_true, jnp.stack(cols[:k] + [v] + cols[k + 1:], 1)))
        cols[k] = v
        p = p - jnp.outer(v, v)
        m_defl = p @ m @ p
    return jnp.stack(cols, axis=1), np.asarray(errs)


# --------------------------------------------------------------------------
# distributed sequential power method (SeqDistPM)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("r", "iters_per_vec", "t_c",
                                             "t_max", "trace_err"))
def _fused_seq_dist_pm(covs, w, table, cols0, q_true, *, r: int,
                       iters_per_vec: int, t_c: int, t_max: int,
                       trace_err: bool):
    """Whole SeqDistPM run as one scan over the flattened (k, j) index.

    cols0: (r, N, d) per-node column estimates. Deflation against converged
    vectors is a fori_loop masked to kk < k — same sequential Gram-Schmidt
    order as the eager loop.
    """

    def body(cols, m):
        k = m // iters_per_vec
        v = jnp.take(cols, k, axis=0)                          # (N, d)
        z = jnp.einsum("nde,ne->nd", covs, v)
        z = debiased_gossip(w, table, z, jnp.int32(t_c), t_max)

        def defl(kk, zz):
            u = cols[kk]
            zz_d = zz - u * jnp.sum(u * zz, axis=1, keepdims=True)
            return jnp.where(kk < k, zz_d, zz)

        z = jax.lax.fori_loop(0, r, defl, z)
        v = z / jnp.linalg.norm(z, axis=1, keepdims=True)
        cols = cols.at[k].set(v)
        err = (subspace_error(q_true, cols.mean(axis=1).T) if trace_err
               else jnp.float32(0.0))
        return cols, err

    return jax.lax.scan(body, cols0, jnp.arange(r * iters_per_vec))


def seq_dist_pm(covs: jnp.ndarray, engine: DenseConsensus, r: int,
                iters_per_vec: int, t_c: int = 50, q_true=None, seed: int = 0,
                ledger: Optional[CommLedger] = None, fused: bool = True):
    n, d, _ = covs.shape
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    closed_form = _supports_fused(engine)   # sync engines: every round equal
    fused = fused and closed_form
    n_steps = r * iters_per_vec
    if fused:
        cols0 = jnp.broadcast_to(q0.T[:, None, :], (r, n, d))
        trace_err = q_true is not None
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        cols, errs = _fused_seq_dist_pm(
            covs, engine._w, engine.debias_table(t_c), cols0, q_arg,
            r=r, iters_per_vec=iters_per_vec, t_c=t_c, t_max=t_c,
            trace_err=trace_err)
        q_nodes = jnp.transpose(cols, (1, 2, 0))               # (n, d, r)
        errs = _finish_errs(errs, n_steps, trace_err)
    else:
        cols = [jnp.broadcast_to(q0[:, k][None], (n, d)) for k in range(r)]
        errs = []
        done: list = []
        for k in range(r):
            v = cols[k]  # (n, d)
            for _ in range(iters_per_vec):
                z = jnp.einsum("nde,ne->nd", covs, v)
                # async engines log realized (awake-dependent) sends per call;
                # sync engines are accounted in closed form below
                z = engine.run_debiased(z, t_c,
                                        None if closed_form else ledger)
                # deflate against converged vectors (per node)
                for u in done:
                    z = z - u * jnp.sum(u * z, axis=1, keepdims=True)
                v = z / jnp.linalg.norm(z, axis=1, keepdims=True)
                cur = [c if i != k else v for i, c in enumerate(cols)]
                qm = jnp.stack([c.mean(0) for c in cur], axis=1)
                errs.append(_trace(q_true, qm))
            cols[k] = v
            done.append(v)
        q_nodes = jnp.stack(cols, axis=2)  # (n, d, r)
        errs = np.asarray(errs)
    if ledger is not None and closed_form:
        ledger.log_gossip_rounds(np.full(n_steps, t_c),
                                 engine.graph.adjacency, d)
    return q_nodes, errs


# --------------------------------------------------------------------------
# distributed Sanger's algorithm (DSA)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("t_outer", "trace_err"))
def _fused_dsa(covs, w, q0, lr, q_true, node_mask, *, t_outer: int,
               trace_err: bool):
    def body(q, _):
        mixed = jnp.einsum("ij,j...->i...", w.astype(q.dtype), q)
        mq = local_cov_apply(covs, q)
        qmq = jnp.einsum("ndr,nds->nrs", q, mq)
        upper = jnp.triu(qmq)
        sanger = mq - jnp.einsum("ndr,nrs->nds", q, upper)
        q_new = mixed + lr * sanger
        err = (subspace_error(q_true, _masked_node_mean(q_new, node_mask))
               if trace_err else jnp.float32(0.0))
        return q_new, err

    return jax.lax.scan(body, q0, None, length=t_outer)


def dsa(covs: jnp.ndarray, engine: DenseConsensus, r: int, t_outer: int,
        lr: float = 0.1, q_true=None, seed: int = 0,
        ledger: Optional[CommLedger] = None, fused: bool = True):
    """Q_i <- sum_j w_ij Q_j + lr * (M_i Q_i - Q_i UT(Q_i^T M_i Q_i)).

    Converges linearly to a *neighborhood* of the truth (paper Fig. 4/5).
    One gossip round per iteration (as in [19]).
    """
    n, d, _ = covs.shape
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    q = jnp.broadcast_to(q0[None], (n, d, r))
    fused = fused and _supports_fused(engine)
    if fused:
        trace_err = q_true is not None
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        q, errs = _fused_dsa(covs, engine._w, q, jnp.float32(lr), q_arg,
                             jnp.ones((n,), jnp.float32),
                             t_outer=t_outer, trace_err=trace_err)
        errs = _finish_errs(errs, t_outer, trace_err)
    else:
        errs = []
        for _ in range(t_outer):
            mixed = engine.run(q, 1)
            mq = local_cov_apply(covs, q)
            qmq = jnp.einsum("ndr,nds->nrs", q, mq)
            upper = jnp.triu(qmq)
            sanger = mq - jnp.einsum("ndr,nrs->nds", q, upper)
            q = mixed + lr * sanger
            errs.append(_trace(q_true, q.mean(0)))
        errs = np.asarray(errs)
    if ledger is not None:
        ledger.log_gossip_rounds(np.ones(t_outer), engine.graph.adjacency,
                                 d * r)
    return q, errs


# --------------------------------------------------------------------------
# distributed projected gradient descent (DPGD)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("t_outer", "trace_err"))
def _fused_dpgd(covs, w, q0, lr, q_true, node_mask, *, t_outer: int,
                trace_err: bool):
    def body(q, _):
        mixed = jnp.einsum("ij,j...->i...", w.astype(q.dtype), q)
        grad = local_cov_apply(covs, q)
        v = mixed + lr * grad
        q_new = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)
        err = (subspace_error(q_true, _masked_node_mean(q_new, node_mask))
               if trace_err else jnp.float32(0.0))
        return q_new, err

    return jax.lax.scan(body, q0, None, length=t_outer)


def dpgd(covs: jnp.ndarray, engine: DenseConsensus, r: int, t_outer: int,
         lr: float = 0.1, q_true=None, seed: int = 0,
         ledger: Optional[CommLedger] = None, fused: bool = True):
    """Trace-maximization DGD + QR retraction (converges to a neighborhood)."""
    n, d, _ = covs.shape
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    q = jnp.broadcast_to(q0[None], (n, d, r))
    fused = fused and _supports_fused(engine)
    if fused:
        trace_err = q_true is not None
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        q, errs = _fused_dpgd(covs, engine._w, q, jnp.float32(lr), q_arg,
                              jnp.ones((n,), jnp.float32),
                              t_outer=t_outer, trace_err=trace_err)
        errs = _finish_errs(errs, t_outer, trace_err)
    else:
        errs = []
        for _ in range(t_outer):
            mixed = engine.run(q, 1)
            grad = local_cov_apply(covs, q)  # d/dQ Tr(Q^T M_i Q) = 2 M_i Q
            v = mixed + lr * grad
            q = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)
            errs.append(_trace(q_true, q.mean(0)))
        errs = np.asarray(errs)
    if ledger is not None:
        ledger.log_gossip_rounds(np.ones(t_outer), engine.graph.adjacency,
                                 d * r)
    return q, errs


# --------------------------------------------------------------------------
# DeEPCA — gradient tracking + power iteration
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("t_outer", "t_mix", "trace_err"))
def _fused_deepca(covs, w, q0, s0, q_true, node_mask, *, t_outer: int,
                  t_mix: int, trace_err: bool):
    def body(carry, _):
        q, s, mq_prev = carry
        wz = w.astype(s.dtype)

        def mix(z, _):
            return jnp.einsum("ij,j...->i...", wz, z), None

        s, _ = jax.lax.scan(mix, s, None, length=t_mix)
        # sign-fixed orthonormalization (DeEPCA's rounding keeps tracking valid)
        q_new = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(s)
        sign = jnp.sign(jnp.einsum("ndr,ndr->nr", q_new, q))
        sign = jnp.where(sign == 0, 1.0, sign)
        q_new = q_new * sign[:, None, :]
        mq_new = local_cov_apply(covs, q_new)
        s = s + mq_new - mq_prev       # gradient tracking correction
        err = (subspace_error(q_true, _masked_node_mean(q_new, node_mask))
               if trace_err else jnp.float32(0.0))
        return (q_new, s, mq_new), err

    (q, s, _), errs = jax.lax.scan(body, (q0, s0, s0), None, length=t_outer)
    return q, errs


def deepca(covs: jnp.ndarray, engine: DenseConsensus, r: int, t_outer: int,
           t_mix: int = 3, q_true=None, seed: int = 0,
           ledger: Optional[CommLedger] = None, fused: bool = True):
    """Gradient-tracking power iteration (Ye & Zhang '21, paper ref [27]).

    s_i tracks (1/N) sum_j M_j Q_j exactly in the limit; a constant number of
    FastMix/gossip rounds per outer iteration suffices — that is the log-factor
    advantage over S-DOT the paper's Remark 1 concedes.
    """
    n, d, _ = covs.shape
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    q = jnp.broadcast_to(q0[None], (n, d, r))
    fused = fused and _supports_fused(engine)
    if fused:
        trace_err = q_true is not None
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        s0 = local_cov_apply(covs, q)
        q, errs = _fused_deepca(covs, engine._w, q, s0, q_arg,
                                jnp.ones((n,), jnp.float32),
                                t_outer=t_outer, t_mix=t_mix,
                                trace_err=trace_err)
        errs = _finish_errs(errs, t_outer, trace_err)
    else:
        mq_prev = local_cov_apply(covs, q)
        s = mq_prev
        errs = []
        for _ in range(t_outer):
            s = engine.run(s, t_mix)
            q_new = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(s)
            # align signs with previous iterate for smooth tracking
            sign = jnp.sign(jnp.einsum("ndr,ndr->nr", q_new, q))
            sign = jnp.where(sign == 0, 1.0, sign)
            q_new = q_new * sign[:, None, :]
            mq_new = local_cov_apply(covs, q_new)
            s = s + mq_new - mq_prev       # gradient tracking correction
            mq_prev, q = mq_new, q_new
            errs.append(_trace(q_true, q.mean(0)))
        errs = np.asarray(errs)
    if ledger is not None:
        ledger.log_gossip_rounds(np.full(t_outer, t_mix),
                                 engine.graph.adjacency, d * r)
    return q, errs


# --------------------------------------------------------------------------
# d-PM — sequential distributed power method for feature-partitioned data
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("r", "iters_per_vec", "t_c",
                                             "t_max", "trace_err"))
def _fused_d_pm(x_pad, w, table, blocks0, qtrue_pad, *, r: int,
                iters_per_vec: int, t_c: int, t_max: int, trace_err: bool):
    """Whole d-PM run as one scan over the flattened (k, j) index.

    x_pad: (N, d_max, n) zero-padded feature slabs; blocks0: (r, N, d_max)
    per-vector padded slab estimates; qtrue_pad: (N, d_max, r_true). All
    dots/norms run over the padded layout — exact, padding entries are zero.
    """

    def body(blocks, m):
        k = m // iters_per_vec
        vb = jnp.take(blocks, k, axis=0)                       # (N, d_max)
        partial = jnp.einsum("idn,id->in", x_pad, vb)          # (N, n)
        ssum = debiased_gossip(w, table, partial, jnp.int32(t_c), t_max)
        vb = jnp.einsum("idn,in->id", x_pad, ssum)

        def defl(kk, vv):
            u = blocks[kk]
            return jnp.where(kk < k, vv - u * jnp.sum(u * vv), vv)

        vb = jax.lax.fori_loop(0, r, defl, vb)
        vb = vb / jnp.linalg.norm(vb)
        blocks = blocks.at[k].set(vb)
        if trace_err:
            cross = jnp.einsum("ids,jid->sj", qtrue_pad, blocks)
            err = subspace_error_from_cross(cross)
        else:
            err = jnp.float32(0.0)
        return blocks, err

    return jax.lax.scan(body, blocks0, jnp.arange(r * iters_per_vec))


def d_pm(data_blocks: Sequence[jnp.ndarray], engine: DenseConsensus, r: int,
         iters_per_vec: int, t_c: int = 50, q_true=None, seed: int = 0,
         ledger: Optional[CommLedger] = None, fused: bool = True):
    """Scaglione et al. [10]: estimate eigenvectors one at a time, each via
    power iterations on M = X X^T executed feature-wise with consensus."""
    from .fdot import pad_feature_slabs, split_pad_rows

    dims = [int(x.shape[0]) for x in data_blocks]
    d = sum(dims)
    n_samples = int(data_blocks[0].shape[1])
    offs = np.cumsum([0] + dims)
    n_nodes = len(data_blocks)
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    closed_form = _supports_fused(engine)   # sync engines: every round equal
    fused = fused and closed_form
    n_steps = r * iters_per_vec
    if fused:
        x_pad = pad_feature_slabs(data_blocks)
        q0_pad = split_pad_rows(q0, dims)
        blocks0 = jnp.transpose(q0_pad, (2, 0, 1))             # (r, N, d_max)
        trace_err = q_true is not None
        qtrue_pad = (split_pad_rows(q_true, dims) if trace_err
                     else jnp.zeros_like(q0_pad))
        blocks, errs = _fused_d_pm(
            x_pad, engine._w, engine.debias_table(t_c), blocks0, qtrue_pad,
            r=r, iters_per_vec=iters_per_vec, t_c=t_c, t_max=t_c,
            trace_err=trace_err)
        q_full = jnp.concatenate(
            [blocks[:, i, :di].T for i, di in enumerate(dims)], axis=0)
        errs = _finish_errs(errs, n_steps, trace_err)
    else:
        blocks = [[q0[offs[i]:offs[i + 1], k] for i in range(n_nodes)]
                  for k in range(r)]
        errs = []
        done_full: list = []
        for k in range(r):
            vb = blocks[k]
            for _ in range(iters_per_vec):
                partial = jnp.stack(
                    [x.T @ v for x, v in zip(data_blocks, vb)])  # (N,n)
                ssum = engine.run_debiased(partial, t_c,
                                           None if closed_form else ledger)
                vb = [x @ ssum[i] for i, x in enumerate(data_blocks)]
                vfull = jnp.concatenate(vb)
                for u in done_full:
                    vfull = vfull - u * (u @ vfull)
                vfull = vfull / jnp.linalg.norm(vfull)
                vb = [vfull[offs[i]:offs[i + 1]] for i in range(n_nodes)]
                cur = jnp.stack(
                    [jnp.concatenate(blocks[j]) if j != k else vfull
                     for j in range(r)], 1)
                errs.append(_trace(q_true, cur))
            blocks[k] = vb
            done_full.append(jnp.concatenate(vb))
        q_full = jnp.stack([jnp.concatenate(b) for b in blocks], axis=1)
        errs = np.asarray(errs)
    if ledger is not None and closed_form:
        ledger.log_gossip_rounds(np.full(n_steps, t_c),
                                 engine.graph.adjacency, n_samples)
    return q_full, errs
