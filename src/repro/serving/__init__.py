# Always-fresh subspace serving: a long-lived, self-healing PSA service.
# drift.py  — spectrum-drift detection on the ingestor's tracked Ritz state
# query.py  — batched projection/compression query path (deadlines, bounded
#             admission queue, explicit load shedding, p50/p99 accounting)
# service.py — the tick loop: ingest -> drift -> warm re-solve (chunked,
#             crash-resumable) -> quality gate -> atomic swap -> queries,
#             plus the supervisor (heartbeat watchdog + backoff relaunch)
#             and the seeded chaos smoke scenario.
# Keep this module free of jax imports so `python -m repro.serving.service`
# controls its own flags (same convention as repro.streaming).
