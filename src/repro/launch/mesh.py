"""Production mesh builders.

Functions, not module constants, so importing never touches jax device state
(device count is locked on first backend init — the dry-run needs to set
XLA_FLAGS before that happens).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices=None, *, multi_pod: bool = False):
    """Small mesh over whatever devices exist (tests / examples)."""
    import numpy as np
    devices = jax.devices() if devices is None else devices
    n = len(devices)
    if multi_pod:
        assert n % 2 == 0 and n >= 4
        rest = n // 2
        dm = max(d for d in (1, 2, 4) if rest % d == 0)
        from jax.sharding import Mesh
        return Mesh(np.array(devices).reshape(2, rest // dm, dm),
                    ("pod", "data", "model"))
    from jax.sharding import Mesh
    dm = max(d for d in (1, 2, 4) if n % d == 0)
    return Mesh(np.array(devices).reshape(n // dm, dm), ("data", "model"))


class HW:
    """TPU v5e hardware constants used by the roofline (per chip)."""
    PEAK_FLOPS_BF16 = 197e12       # FLOP/s
    HBM_BW = 819e9                 # B/s
    ICI_LINK_BW = 50e9             # B/s per link
    HBM_BYTES = 16 * 2**30
