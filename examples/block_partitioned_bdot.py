"""B-DOT — block-partitioned distributed PSA (beyond the paper).

The paper's conclusion names data partitioned by BOTH samples and features
as the open problem for data massive in both d and n. This example runs the
B-DOT composition implemented in repro.core.bdot: a 4 x 5 grid of nodes,
each holding one (d/4 x n/5) block, estimates the global top-r eigenspace
with only block-local payloads (n_j x r column partials, d_i x r row
partials, r x r QR Grams).

Run:  PYTHONPATH=src python examples/block_partitioned_bdot.py
"""
import jax.numpy as jnp

from repro.core.bdot import bdot
from repro.core.consensus import DenseConsensus
from repro.core.linalg import eigh_topr
from repro.core.topology import erdos_renyi
from repro.data.pipeline import (gaussian_eigengap_data, partition_features,
                                 partition_samples)

D, N, R, I, J = 40, 4000, 5, 4, 5


def main():
    x, _, _ = gaussian_eigengap_data(D, N, R, 0.6, seed=0)
    _, q_true = eigh_topr(x @ x.T, R)
    fslabs = partition_features(x, I)
    blocks = [partition_samples(sl, J) for sl in fslabs]
    print(f"{I}x{J} grid; block at node (i,j): "
          f"{blocks[0][0].shape} of the global {x.shape}")

    cols = [DenseConsensus(erdos_renyi(I, 0.7, seed=j)) for j in range(J)]
    rows = [DenseConsensus(erdos_renyi(J, 0.7, seed=10 + i)) for i in range(I)]
    res = bdot(blocks=blocks, col_engines=cols, row_engines=rows, r=R,
               t_outer=60, t_c=50, q_true=q_true)

    q = res.q_full
    print(f"final subspace error: {res.error_trace[-1]:.2e}")
    print(f"orthonormality |Q^T Q - I|_max: "
          f"{float(jnp.abs(q.T @ q - jnp.eye(R)).max()):.2e}")
    print(f"largest single message: {max(N // J, D // I) * R} elems "
          f"(vs S-DOT {D * R}, F-DOT {N * R})")
    assert res.error_trace[-1] < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
