"""Crash-safe, append-only JSONL span/event journal.

One journal == one process attempt: a single ``.jsonl`` file under an
observability directory, named ``<proc>.a<attempt>.jsonl`` so a relaunched
worker opens a NEW attempt-scoped file instead of clobbering (or
interleaving confusingly with) its predecessor's trace. Every record is one
JSON object on one line, written with a SINGLE ``os.write`` to an
``O_APPEND`` descriptor — appends are atomic at the kernel level, so
concurrent writers (the async checkpoint thread, or a second process
sharing a file) interleave whole lines, never bytes, and a SIGKILL can tear
at most the final line. The reader (``read_journal``) therefore treats an
undecodable tail as expected debris and skips it.

Record schema (all records):

    ts       wall clock (time.time) at write
    mono     time.monotonic() at write — orders records within one boot
             even across wall-clock jumps
    proc     process identity ("worker_s3", "fleet_w0", "service",
             "launcher")
    pid      OS pid
    attempt  which relaunch of this proc wrote the file
    kind     "event" | "span_start" | "span"
    name     what happened ("chunk", "ckpt_save", "chaos_fired", ...)
    phase    coarse subsystem bucket ("runtime", "checkpoint", "tick", ...)
    run / shard / tick / step / ...   optional correlation ids

Spans are TWO records: ``span_start`` at entry and ``span`` (with
``dur_s``) at exit, sharing a per-journal ``sid``. A process that dies
mid-span leaves the ``span_start`` orphaned — which is exactly the
forensic signal the CLI's ``forensics`` mode uses to name the phase a dead
worker was in.

The journal is strictly OUT-OF-BAND: it only appends host-side lines, so
it can never perturb device math — runs replay bit-identical with tracing
on or off — and the disabled journal (``Journal.noop()``) costs one
attribute check per call site.
"""
from __future__ import annotations

import json
import os
import re
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Journal", "Span", "read_journal", "merge_journals",
           "journal_files", "ENV_DIR", "ENV_OBS"]

ENV_DIR = "REPRO_OBS_DIR"   # where journals go (overrides <workdir>/obs)
ENV_OBS = "REPRO_OBS"       # "0"/"off" disables journaling entirely

_FILE_RE = re.compile(r"^(?P<proc>.+)\.a(?P<attempt>\d+)\.jsonl$")

# base record schema keys a caller-supplied field must never clobber; a
# colliding field is written under an "f_" prefix instead of raising
_RESERVED = frozenset({"ts", "mono", "proc", "pid", "attempt", "kind",
                       "name"})


def _jsonable(v):
    """Coerce numpy scalars / arrays / anything exotic to JSON-safe."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
        return v.item()
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)


def _coerce(v):
    """Encoder ``default=`` hook: invoked ONLY for values json can't
    encode natively, so plain int/float/str/bool fields (the vast majority)
    pay nothing — this keeps the hot write path at a few µs per record."""
    if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
        return v.item()                         # numpy / jax scalar
    if hasattr(v, "tolist"):
        return v.tolist()                       # small arrays
    return str(v)


# one shared encoder (json.dumps with default= builds a fresh JSONEncoder
# per call; .encode() on this instance takes the C one-shot path)
_ENCODER = json.JSONEncoder(separators=(",", ":"), default=_coerce)


class Span:
    """An in-flight span; ``end()`` (or context-manager exit) writes the
    closing record. Idempotent: a double end writes nothing."""

    __slots__ = ("_j", "name", "phase", "sid", "_t0", "_fields", "_done")

    def __init__(self, journal: "Journal", name: str, phase: Optional[str],
                 sid: int, fields: Dict[str, Any]):
        self._j = journal
        self.name = name
        self.phase = phase
        self.sid = sid
        self._fields = fields
        self._done = False
        self._t0 = time.monotonic()

    def add(self, **fields) -> "Span":
        """Attach fields to the CLOSING record (e.g. a result computed
        mid-span)."""
        self._fields.update(fields)
        return self

    def end(self, ok: bool = True, **fields) -> None:
        if self._done:
            return
        self._done = True
        self._fields.update(fields)
        self._j._write("span", self.name, self.phase, sid=self.sid,
                       dur_s=round(time.monotonic() - self._t0, 6),
                       ok=bool(ok), **self._fields)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.end(ok=exc_type is None)
        return False


class _NoopSpan:
    __slots__ = ()

    def add(self, **fields):
        return self

    def end(self, ok=True, **fields):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


_NOOP_SPAN = _NoopSpan()


class Journal:
    """Append-only JSONL writer for one process attempt (module docstring).

    ``registry`` (optional, a ``repro.obs.registry.MetricsRegistry``) gets a
    ``span_<name>_seconds`` histogram observation for every closed span —
    the journal is the trace, the registry the aggregate view of the same
    instrumentation points."""

    def __init__(self, path: Optional[str], proc: str, attempt: int = 0,
                 *, registry=None, **static):
        self.path = path
        self.proc = proc
        self.attempt = int(attempt)
        self.registry = registry
        self.enabled = path is not None
        self._static = {k: _jsonable(v) for k, v in static.items()
                        if v is not None}
        self._pid = os.getpid()                 # cached: one syscall, ever
        self._sid = 0
        self._fd = None
        if self.enabled:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fd = os.open(path, os.O_APPEND | os.O_CREAT | os.O_WRONLY,
                               0o644)

    # -- constructors -------------------------------------------------------
    @classmethod
    def noop(cls) -> "Journal":
        return cls(None, proc="noop")

    @classmethod
    def open(cls, obs_dir: str, proc: str, *, attempt: Optional[int] = None,
             registry=None, **static) -> "Journal":
        """Open the next attempt-scoped journal for ``proc`` in ``obs_dir``.

        ``attempt=None`` scans existing ``<proc>.a*.jsonl`` files and takes
        the next index — a relaunched process extends the directory's
        history instead of clobbering the crashed attempt's trace."""
        os.makedirs(obs_dir, exist_ok=True)
        if attempt is None:
            prev = [-1]
            for name in os.listdir(obs_dir):
                m = _FILE_RE.match(name)
                if m and m.group("proc") == proc:
                    prev.append(int(m.group("attempt")))
            attempt = max(prev) + 1
        path = os.path.join(obs_dir, f"{proc}.a{int(attempt)}.jsonl")
        return cls(path, proc, attempt, registry=registry, **static)

    # -- writers ------------------------------------------------------------
    def _write(self, kind: str, name: str, phase: Optional[str], /,
               **fields) -> None:
        if not self.enabled:
            return
        rec = {"ts": round(time.time(), 6),
               "mono": round(time.monotonic(), 6),
               "proc": self.proc, "pid": self._pid,
               "attempt": self.attempt, "kind": kind, "name": name}
        if phase is not None:
            rec["phase"] = phase
        rec.update(self._static)
        for k, v in fields.items():
            if v is not None:
                rec["f_" + k if k in _RESERVED else k] = v
        try:
            line = _ENCODER.encode(rec) + "\n"
            os.write(self._fd, line.encode())    # ONE atomic append
        except (OSError, TypeError, ValueError):
            pass                                 # observability never raises
        if kind == "span" and self.registry is not None:
            self.registry.histogram(
                f"span_{name}_seconds").observe(fields.get("dur_s", 0.0))

    def event(self, name: str, phase: Optional[str] = None, /,
              **fields) -> None:
        self._write("event", name, phase, **fields)

    def begin(self, name: str, phase: Optional[str] = None, /, **fields):
        """Start a span: writes ``span_start`` now, returns a ``Span`` whose
        ``end()`` writes the closing ``span`` record with ``dur_s``."""
        if not self.enabled:
            return _NOOP_SPAN
        self._sid += 1
        self._write("span_start", name, phase, sid=self._sid, **fields)
        return Span(self, name, phase, self._sid, dict(fields))

    def span(self, name: str, phase: Optional[str] = None, /, **fields):
        """Context-manager form of ``begin``."""
        return self.begin(name, phase, **fields)

    def close(self) -> None:
        if self._fd is not None:
            try:
                os.close(self._fd)
            except OSError:
                pass
            self._fd = None
            self.enabled = False


# ---------------------------------------------------------------------------
# readers (torn-tail tolerant)
# ---------------------------------------------------------------------------
def read_journal(path: str) -> List[dict]:
    """All decodable records of one journal file, in write order.

    A SIGKILL can tear the final line (a partial ``os.write`` is
    impossible for the sizes here, but a torn filesystem or a copied file
    is not) — any undecodable or non-object line is SKIPPED, not raised.
    Appends from concurrent writers land as whole lines, so mid-file
    records are intact by construction."""
    out: List[dict] = []
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError:
        return out
    for line in data.split(b"\n"):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except (ValueError, UnicodeDecodeError):
            continue                    # torn tail / debris: skip cleanly
        if isinstance(rec, dict):
            out.append(rec)
    return out


def journal_files(obs_dir: str) -> List[Tuple[str, str, int]]:
    """(path, proc, attempt) for every journal in ``obs_dir``, sorted by
    (proc, attempt)."""
    out = []
    try:
        names = os.listdir(obs_dir)
    except OSError:
        return out
    for name in names:
        m = _FILE_RE.match(name)
        if m:
            out.append((os.path.join(obs_dir, name), m.group("proc"),
                        int(m.group("attempt"))))
    return sorted(out, key=lambda t: (t[1], t[2]))


def merge_journals(obs_dir: str) -> List[dict]:
    """Every record of every per-process journal in ``obs_dir``, merged
    into ONE timeline ordered by wall clock (stable: ties keep per-file
    write order, which monotonic stamps preserve within a process)."""
    records: List[dict] = []
    for path, proc, attempt in journal_files(obs_dir):
        for i, rec in enumerate(read_journal(path)):
            rec.setdefault("proc", proc)
            rec.setdefault("attempt", attempt)
            rec["_order"] = i
            records.append(rec)
    records.sort(key=lambda r: (r.get("ts", 0.0), r.get("proc", ""),
                                r["_order"]))
    for rec in records:
        rec.pop("_order", None)
    return records
