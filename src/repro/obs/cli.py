"""Run-forensics CLI over per-process span journals.

    python -m repro.obs timeline  <dir>   # merged, ordered event timeline
    python -m repro.obs summary   <dir>   # per-phase duration summaries
    python -m repro.obs prom      <dir>   # Prometheus-style exposition
    python -m repro.obs forensics <dir> [--plan plan.json] [--last N]
    python -m repro.obs gantt     <dir>   # plain-text Gantt per process

``<dir>`` is an observability directory (``*.jsonl`` journals) or a
workdir containing one under ``obs/``. All commands are pure readers —
they never touch the run's own files.

``forensics`` reconstructs, for every process attempt, the spans still
OPEN at the end of its journal (the phase a dead worker was in when it
died) and its last N records; with ``--plan`` it additionally attributes
every fault of a chaos ``FaultPlan`` to the journal record of its firing
(kind, process, boundary, enclosing phase) and exits non-zero if any
injected fault left no trace — the property the obs-smoke CI job pins.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

from .journal import journal_files, merge_journals, read_journal
from .registry import MetricsRegistry

__all__ = ["main", "resolve_obs_dir", "phase_summary", "forensics_report",
           "render_gantt", "build_exposition"]


def resolve_obs_dir(path: str) -> str:
    """Accept either an obs dir itself or a workdir containing ``obs/``."""
    if os.path.isdir(path) and journal_files(path):
        return path
    sub = os.path.join(path, "obs")
    if os.path.isdir(sub) and journal_files(sub):
        return sub
    raise SystemExit(f"{path}: no journals found (looked for *.jsonl in it "
                     f"and in {sub})")


def _fmt_fields(rec: dict, skip=("ts", "mono", "proc", "pid", "attempt",
                                 "kind", "name", "phase", "sid")) -> str:
    return " ".join(f"{k}={rec[k]}" for k in rec if k not in skip)


def _percentile(vals: List[float], p: float) -> float:
    vals = sorted(vals)
    if not vals:
        return 0.0
    i = min(len(vals) - 1, max(0, int(round(p / 100.0 * (len(vals) - 1)))))
    return vals[i]


# ---------------------------------------------------------------------------
# timeline
# ---------------------------------------------------------------------------
def render_timeline(obs_dir: str, limit: Optional[int] = None) -> str:
    records = merge_journals(obs_dir)
    if not records:
        return "(empty timeline)\n"
    t0 = records[0].get("ts", 0.0)
    lines = []
    for rec in records[-limit:] if limit else records:
        who = f"{rec.get('proc', '?')}.a{rec.get('attempt', 0)}"
        phase = f" [{rec['phase']}]" if "phase" in rec else ""
        dur = f" dur={rec['dur_s']:.4f}s" if "dur_s" in rec else ""
        lines.append(f"+{rec.get('ts', t0) - t0:9.3f}s  {who:<18} "
                     f"{rec.get('kind', '?'):<10} {rec.get('name', '?')}"
                     f"{phase}{dur}  {_fmt_fields(rec)}".rstrip())
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# per-phase summaries
# ---------------------------------------------------------------------------
def phase_summary(records: List[dict]) -> Dict[Tuple[str, str], dict]:
    """(phase, name) -> {count, total_s, mean_s, p50_s, p99_s} over closed
    spans, plus event counts under a ``count``-only entry."""
    durs: Dict[Tuple[str, str], List[float]] = {}
    events: Dict[Tuple[str, str], int] = {}
    for rec in records:
        key = (rec.get("phase", "-"), rec.get("name", "?"))
        if rec.get("kind") == "span":
            durs.setdefault(key, []).append(float(rec.get("dur_s", 0.0)))
        elif rec.get("kind") == "event":
            events[key] = events.get(key, 0) + 1
    out: Dict[Tuple[str, str], dict] = {}
    for key, vals in durs.items():
        out[key] = {"count": len(vals), "total_s": sum(vals),
                    "mean_s": sum(vals) / len(vals),
                    "p50_s": _percentile(vals, 50),
                    "p99_s": _percentile(vals, 99)}
    for key, n in events.items():
        out.setdefault(key, {"count": 0})["events"] = n
    return out


def render_summary(obs_dir: str) -> str:
    summary = phase_summary(merge_journals(obs_dir))
    if not summary:
        return "(no records)\n"
    head = (f"{'phase':<12} {'name':<22} {'spans':>6} {'total_s':>9} "
            f"{'mean_s':>9} {'p50_s':>9} {'p99_s':>9} {'events':>7}")
    lines = [head, "-" * len(head)]
    for (phase, name), s in sorted(summary.items()):
        if s.get("count"):
            lines.append(
                f"{phase:<12} {name:<22} {s['count']:>6} "
                f"{s['total_s']:>9.4f} {s['mean_s']:>9.5f} "
                f"{s['p50_s']:>9.5f} {s['p99_s']:>9.5f} "
                f"{s.get('events', ''):>7}")
        else:
            lines.append(f"{phase:<12} {name:<22} {'':>6} {'':>9} {'':>9} "
                         f"{'':>9} {'':>9} {s.get('events', 0):>7}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# prometheus exposition
# ---------------------------------------------------------------------------
def build_exposition(obs_dir: str) -> MetricsRegistry:
    """One registry for the whole run: every ``metrics.*.json`` registry
    dump merged, plus journal-derived metrics (span-duration histograms
    and event counters) so a run with no dumps still exposes its trace."""
    reg = MetricsRegistry()
    for name in sorted(os.listdir(obs_dir)):
        if name.startswith("metrics.") and name.endswith(".json"):
            try:
                with open(os.path.join(obs_dir, name)) as f:
                    reg.merge_snapshot(json.load(f))
            except (OSError, ValueError):
                continue
    for rec in merge_journals(obs_dir):
        if rec.get("kind") == "span":
            reg.histogram(
                f"span_{rec.get('name', '?')}_seconds").observe(
                    float(rec.get("dur_s", 0.0)))
        elif rec.get("kind") == "event":
            reg.counter(f"event_{rec.get('name', '?')}_total").inc()
    return reg


# ---------------------------------------------------------------------------
# forensics
# ---------------------------------------------------------------------------
def _file_forensics(path: str) -> dict:
    """Per-journal reconstruction: chronological records, the span stack,
    spans still open at EOF, and chaos firings with their enclosing
    phase."""
    records = read_journal(path)
    open_spans: Dict[int, dict] = {}
    order: List[int] = []
    firings: List[dict] = []
    for rec in records:
        kind = rec.get("kind")
        if kind == "span_start" and "sid" in rec:
            open_spans[rec["sid"]] = rec
            order.append(rec["sid"])
        elif kind == "span" and rec.get("sid") in open_spans:
            del open_spans[rec["sid"]]
            order = [s for s in order if s in open_spans]
        elif kind == "event" and rec.get("name") == "chaos_fired":
            encl = open_spans.get(order[-1]) if order else None
            firings.append({
                "rec": rec,
                "in_span": None if encl is None else encl.get("name"),
                "in_phase": None if encl is None else encl.get("phase"),
            })
    return {"records": records,
            "open": [open_spans[s] for s in order],
            "firings": firings}


def forensics_report(obs_dir: str, *, last: int = 10,
                     proc: Optional[str] = None,
                     plan_path: Optional[str] = None) -> Tuple[str, bool]:
    """(report text, ok). ``ok`` is False when a ``--plan`` fault has no
    attributable firing in any journal."""
    lines: List[str] = []
    all_firings: List[dict] = []
    files = journal_files(obs_dir)
    if proc:
        files = [f for f in files if f[1] == proc]
    for path, fproc, attempt in files:
        fx = _file_forensics(path)
        all_firings.extend(dict(f, proc=fproc, attempt=attempt)
                           for f in fx["firings"])
        records = fx["records"]
        if not records:
            lines.append(f"== {fproc}.a{attempt}: empty journal ==")
            continue
        t0 = records[0].get("ts", 0.0)
        if fx["open"]:
            state = "died during " + " > ".join(
                f"{s.get('name')}[{s.get('phase', '-')}]"
                for s in fx["open"])
        else:
            state = "no open spans at end of journal"
        lines.append(f"== {fproc}.a{attempt} — {state} ==")
        for rec in records[-last:]:
            phase = f" [{rec['phase']}]" if "phase" in rec else ""
            dur = f" dur={rec['dur_s']:.4f}s" if "dur_s" in rec else ""
            lines.append(f"  +{rec.get('ts', t0) - t0:8.3f}s "
                         f"{rec.get('kind', '?'):<10} "
                         f"{rec.get('name', '?')}{phase}{dur}  "
                         f"{_fmt_fields(rec)}".rstrip())
    ok = True
    if plan_path is not None:
        with open(plan_path) as f:
            plan = json.load(f)
        faults = plan.get("faults", [])
        lines.append("")
        lines.append(f"fault attribution ({len(faults)} planned):")
        for idx, fault in enumerate(faults):
            hits = [f for f in all_firings
                    if f["rec"].get("fault") == idx]
            tgt = ",".join(f"{k}={fault[k]}" for k in ("shard", "worker")
                           if k in fault)
            if not hits:
                ok = False
                lines.append(f"  fault #{idx} {fault.get('kind')}({tgt}) "
                             f"-> NO TRACE (unattributed)")
                continue
            for h in hits[:3]:
                rec = h["rec"]
                where = (f"{h['in_span']}/{h['in_phase']}"
                         if h["in_span"] else "top-level")
                lines.append(
                    f"  fault #{idx} {fault.get('kind')}({tgt}) -> "
                    f"{h['proc']}.a{h['attempt']} "
                    f"boundary={rec.get('boundary', rec.get('step', '?'))} "
                    f"during {where}")
            if len(hits) > 3:
                lines.append(f"    ... {len(hits) - 3} more firings")
        n_hit = sum(1 for i in range(len(faults))
                    if any(f["rec"].get("fault") == i for f in all_firings))
        lines.append(f"  {n_hit}/{len(faults)} plan faults attributed")
    return "\n".join(lines) + "\n", ok


# ---------------------------------------------------------------------------
# plain-text gantt
# ---------------------------------------------------------------------------
def render_gantt(obs_dir: str, width: int = 64) -> str:
    """One row per process attempt over the merged wall-clock range:
    ``█`` = inside a span, ``·`` = alive (records exist), ``X`` = a chaos
    fault fired in that column. Straggler shards and steals read directly
    off the row lengths."""
    files = journal_files(obs_dir)
    rows = []
    t_min, t_max = float("inf"), float("-inf")
    for path, proc, attempt in files:
        records = read_journal(path)
        if not records:
            continue
        ts = [r.get("ts", 0.0) for r in records]
        t_min, t_max = min(t_min, min(ts)), max(t_max, max(ts))
        spans, chaos = [], []
        open_at: Dict[int, float] = {}
        for rec in records:
            kind = rec.get("kind")
            if kind == "span_start" and "sid" in rec:
                open_at[rec["sid"]] = rec.get("ts", 0.0)
            elif kind == "span":
                end = rec.get("ts", 0.0)
                start = open_at.pop(rec.get("sid"), end
                                    - float(rec.get("dur_s", 0.0)))
                spans.append((start, end))
            elif kind == "event" and rec.get("name") == "chaos_fired":
                chaos.append(rec.get("ts", 0.0))
        # spans never closed run to the journal's end (death mid-span)
        spans.extend((t, max(ts)) for t in open_at.values())
        rows.append((f"{proc}.a{attempt}", min(ts), max(ts), spans, chaos))
    if not rows:
        return "(no journals)\n"
    scale = (t_max - t_min) or 1.0

    def col(t: float) -> int:
        return min(width - 1, max(0, int((t - t_min) / scale * width)))

    label_w = max(len(r[0]) for r in rows) + 2
    out = [f"{'':<{label_w}}|{'-' * width}| {scale:.2f}s total"]
    for name, lo, hi, spans, chaos in rows:
        cells = [" "] * width
        for c in range(col(lo), col(hi) + 1):
            cells[c] = "·"
        for s, e in spans:
            for c in range(col(s), col(e) + 1):
                cells[c] = "█"
        for t in chaos:
            cells[col(t)] = "X"
        out.append(f"{name:<{label_w}}|{''.join(cells)}|")
    out.append(f"{'':<{label_w}} █ span   · alive   X chaos fault fired")
    return "\n".join(out) + "\n"


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__,
                                 formatter_class=argparse
                                 .RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name in ("timeline", "summary", "prom", "gantt"):
        p = sub.add_parser(name)
        p.add_argument("dir", help="obs dir (or a workdir containing obs/)")
        if name == "timeline":
            p.add_argument("--last", type=int, default=None,
                           help="only the last N records")
        if name == "gantt":
            p.add_argument("--width", type=int, default=64)
    pf = sub.add_parser("forensics")
    pf.add_argument("dir")
    pf.add_argument("--last", type=int, default=10,
                    help="records of each journal tail to show")
    pf.add_argument("--proc", default=None,
                    help="only this process's journals")
    pf.add_argument("--plan", default=None,
                    help="chaos plan JSON: attribute every fault, exit 1 "
                         "if any left no trace")
    args = ap.parse_args(argv)
    obs_dir = resolve_obs_dir(args.dir)
    if args.cmd == "timeline":
        sys.stdout.write(render_timeline(obs_dir, limit=args.last))
    elif args.cmd == "summary":
        sys.stdout.write(render_summary(obs_dir))
    elif args.cmd == "prom":
        sys.stdout.write(build_exposition(obs_dir).to_prom())
    elif args.cmd == "gantt":
        sys.stdout.write(render_gantt(obs_dir, width=args.width))
    elif args.cmd == "forensics":
        text, ok = forensics_report(obs_dir, last=args.last, proc=args.proc,
                                    plan_path=args.plan)
        sys.stdout.write(text)
        return 0 if ok else 1
    return 0
