"""Data pipelines.

* PSA side: Gaussian generators with a *controlled r-th eigengap* — the knob
  every experiment in the paper turns — plus sample-wise / feature-wise
  partitioners.
* LM side: a stateless-seeded synthetic token stream. Statelessness is the
  fault-tolerance property: step -> batch is a pure function of (seed, step),
  so a restarted job replays the identical stream with no reader state to
  checkpoint, and any straggling host can regenerate its shard locally.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig

__all__ = ["gaussian_eigengap_data", "partition_samples", "partition_features",
           "synthetic_lm_stream", "make_lm_batch", "spectrum_matched_data",
           "spectrum_matched_stream", "eigengap_stream",
           "drifting_eigengap_stream"]


def _eigengap_cov(rng, d: int, r: int, gap: float, lead: float,
                  repeated_top: bool):
    """Controlled-gap population covariance C = U diag(evals) U^T.

    The one construction behind both the one-shot generator
    (``gaussian_eigengap_data``) and its stateless stream twin
    (``eigengap_stream``) — consuming ``rng`` identically, so the two stay
    seed-compatible by sharing code rather than by copy."""
    if repeated_top:
        top = np.full(r, lead)
    else:
        top = np.linspace(lead, lead * 0.6, r)
    tail_lead = top[-1] * gap
    tail = np.linspace(tail_lead, tail_lead * 0.1, d - r)
    evals = np.concatenate([top, tail])
    u = np.linalg.qr(rng.standard_normal((d, d)))[0]
    return u @ np.diag(evals) @ u.T, u


def _spectrum_factor(rng, d: int, alpha: float) -> np.ndarray:
    """Power-law factor L with L L^T spectrum lambda_i ~ i^-alpha (shared by
    ``spectrum_matched_data`` and ``spectrum_matched_stream``)."""
    evals = np.arange(1, d + 1, dtype=np.float64) ** (-alpha)
    u = np.linalg.qr(rng.standard_normal((d, d)))[0]
    return u * np.sqrt(evals)


def gaussian_eigengap_data(d: int, n: int, r: int, gap: float, seed: int = 0,
                           lead: float = 3.0, repeated_top: bool = False):
    """X ~ N(0, C) with lambda_{r+1}/lambda_r == gap exactly.

    repeated_top=True sets lambda_1 = ... = lambda_r (the paper's Fig. 5
    non-distinct case). Returns (X (d, n), C, Q_true (d, r)).
    """
    rng = np.random.default_rng(seed)
    c, u = _eigengap_cov(rng, d, r, gap, lead, repeated_top)
    x = np.linalg.cholesky(c + 1e-12 * np.eye(d)) @ rng.standard_normal((d, n))
    return jnp.asarray(x, jnp.float32), jnp.asarray(c, jnp.float32), \
        jnp.asarray(u[:, :r], jnp.float32)


def spectrum_matched_data(d: int, n: int, seed: int = 0, alpha: float = 1.2):
    """Synthetic stand-in for natural-image datasets: power-law spectrum
    lambda_i ~ i^-alpha (matches MNIST/CIFAR covariance decay shape)."""
    rng = np.random.default_rng(seed)
    x = _spectrum_factor(rng, d, alpha) @ rng.standard_normal((d, n))
    return jnp.asarray(x, jnp.float32)


def partition_samples(x: jnp.ndarray, n_nodes: int) -> List[jnp.ndarray]:
    """Split columns (samples) evenly over nodes (paper's sample-wise case)."""
    n = x.shape[1]
    per = n // n_nodes
    return [x[:, i * per:(i + 1) * per] for i in range(n_nodes)]


def partition_features(x: jnp.ndarray, n_nodes: int) -> List[jnp.ndarray]:
    """Split rows (features) evenly over nodes (paper's feature-wise case)."""
    d = x.shape[0]
    per = d // n_nodes
    out = []
    for i in range(n_nodes):
        hi = d if i == n_nodes - 1 else (i + 1) * per
        out.append(x[i * per:hi])
    return out


# ---------------------------------------------------------------------------
# stateless-seeded PSA sample streams (streaming covariance ingestion)
# ---------------------------------------------------------------------------
def _stream_batch_fn(chol_factor: jnp.ndarray, seed: int):
    """Wrap a (d, d) covariance factor L into a pure micro-batch function.

    ``batch(step, m) = L @ N(0, I)`` keyed by fold_in(seed, step) — the same
    statelessness contract as the LM stream: step -> batch is a pure
    function of (seed, step), so a restarted ingestor replays the identical
    stream with no reader state beyond the next step index, and any
    straggling host regenerates its shard locally.
    """
    base = jax.random.PRNGKey(seed)

    def batch(step: int, m: int) -> jnp.ndarray:
        key = jax.random.fold_in(base, step)
        return chol_factor @ jax.random.normal(key, (chol_factor.shape[0], m),
                                               jnp.float32)

    return batch


def spectrum_matched_stream(d: int, seed: int = 0, alpha: float = 1.2):
    """Stateless micro-batch twin of ``spectrum_matched_data``.

    Returns ``batch(step, m) -> (d, m)`` drawing from the same power-law
    population covariance (``_spectrum_factor``, lambda_i ~ i^-alpha).  The
    mixing basis depends only on ``seed``; the samples only on
    ``(seed, step)`` — batches are iid draws from the population, so the
    streamed second moment converges to the same covariance the one-shot
    generator samples from.
    """
    rng = np.random.default_rng(seed)
    factor = jnp.asarray(_spectrum_factor(rng, d, alpha), jnp.float32)
    return _stream_batch_fn(factor, seed)


def eigengap_stream(d: int, r: int, gap: float, seed: int = 0,
                    lead: float = 3.0, repeated_top: bool = False):
    """Stateless micro-batch twin of ``gaussian_eigengap_data``.

    Returns ``(batch_fn, C, Q_true)``: the same controlled-eigengap
    population covariance (``_eigengap_cov``), but samples arrive as pure
    ``(seed, step)`` micro-batches instead of one (d, n) matrix.
    """
    rng = np.random.default_rng(seed)
    c, u = _eigengap_cov(rng, d, r, gap, lead, repeated_top)
    factor = np.linalg.cholesky(c + 1e-12 * np.eye(d))
    return (_stream_batch_fn(jnp.asarray(factor, jnp.float32), seed),
            jnp.asarray(c, jnp.float32), jnp.asarray(u[:, :r], jnp.float32))


def drifting_eigengap_stream(d: int, r: int, gap: float, shift_at: int,
                             seed: int = 0, lead: float = 3.0,
                             shift_seed: Optional[int] = None,
                             shift_lead: Optional[float] = None):
    """An ``eigengap_stream`` whose POPULATION covariance changes mid-stream.

    Steps ``< shift_at`` draw from the pre-shift population, steps
    ``>= shift_at`` from an independently rotated one (``shift_seed``,
    default ``seed + 101``) with the same eigengap profile — the seeded
    spectrum-drift adversary for the serving layer's drift detector.
    ``shift_lead`` (default ``lead``) sets the post-shift leading
    eigenvalue: larger than ``lead`` makes the new directions dominate an
    accumulated sketch quickly (a sharp regime change), equal gives a pure
    rotation at matched energy. Still a pure function of (seed, step), so
    a restarted ingestor replays the identical drifting stream, shift
    included.

    Returns ``(batch_fn, (C0, Q0), (C1, Q1))`` — both population
    covariances and their top-r bases, for before/after ground truth.
    """
    if shift_seed is None:
        shift_seed = seed + 101
    if shift_lead is None:
        shift_lead = lead
    fn0, c0, q0 = eigengap_stream(d, r, gap, seed=seed, lead=lead)
    fn1, c1, q1 = eigengap_stream(d, r, gap, seed=shift_seed,
                                  lead=shift_lead)

    def batch(step: int, m: int) -> jnp.ndarray:
        return fn0(step, m) if step < shift_at else fn1(step, m)

    return batch, (c0, q0), (c1, q1)


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------
def make_lm_batch(cfg: ModelConfig, seed, step, batch: int, seq: int):
    """Pure function (seed, step) -> training batch; labels = next token."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2 = jax.random.split(key)
    if cfg.frontend == "audio_codec":
        shape = (batch, seq + 1, cfg.n_codebooks)
    else:
        shape = (batch, seq + 1)
    toks = jax.random.randint(k1, shape, 0, cfg.vocab_size, dtype=jnp.int32)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend == "vlm_patches":
        out["patch_embeds"] = 0.02 * jax.random.normal(
            k2, (batch, cfg.n_prefix_tokens, cfg.d_model), jnp.float32)
    return out


def synthetic_lm_stream(cfg: ModelConfig, seed: int, batch: int, seq: int,
                        start_step: int = 0):
    """Infinite restartable iterator over training batches."""
    step = start_step
    while True:
        yield step, make_lm_batch(cfg, seed, step, batch, seq)
        step += 1
