"""Consensus-averaging engines.

Two interchangeable execution engines compute the same gossip recursion
``Z_i <- sum_{j in N_i} w_ij Z_j``:

* ``DenseConsensus``   — all node blocks stacked on one device; one gossip
  round is an einsum with the (N, N) weight matrix. This is the simulation
  engine used to reproduce the paper's tables (N = 10..200 nodes).

* ``SpmdConsensus``    — node blocks sharded over a mesh axis; gossip rounds
  are executed with jax.lax collectives inside ``shard_map``. A ring topology
  (circulant W) lowers to weighted ``ppermute`` rounds — the TPU-native
  analogue of the paper's MPI point-to-point exchange. Dense/irregular
  topologies fall back to one ``all_gather`` + local mix per round.

Both engines also expose the paper's debiasing step
``V_i = Z_i^{(Tc)} / [W^{Tc} e_1]_i`` (Alg. 1, step 11).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map
from .sparse import SparseW, auto_sparse
from .topology import Graph, local_degree_weights, ring
from .metrics import CommLedger

__all__ = [
    "DenseConsensus",
    "FaultyConsensus",
    "SparseConsensus",
    "SpmdConsensus",
    "consensus_schedule",
    "debias_weights",
    "debias_table",
    "debiased_gossip",
    "gossip_mix",
    "masked_gossip",
    "realized_round_weights",
    "safe_debias_scale",
]


def __getattr__(name):
    # FaultyConsensus lives in netfaults.py (which imports this module);
    # re-export it lazily so `from repro.core.consensus import
    # FaultyConsensus` works without a circular import.
    if name == "FaultyConsensus":
        from .netfaults import FaultyConsensus
        return FaultyConsensus
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def realized_round_weights(wz, mask, off):
    """Renormalize the nominal weights over one realized round's surviving
    edges: the REALIZED-ROUND API shared by every fault model.

    ``wz``: (N, N) nominal doubly-stochastic weights; ``mask``: (N, N) bool,
    SYMMETRIC — edge (i, j) survived this round; ``off``: (N, N) bool
    off-diagonal selector. Returns ``(w_off, dd)`` where ``w_off`` keeps the
    surviving off-diagonal weights and ``dd`` is the per-node diagonal with
    every dropped weight returned to it. The realized round matrix
    ``w_off + diag(dd)`` is doubly stochastic for any symmetric mask (row
    sums are 1 by construction; column sums are 1 because mask symmetry
    makes the dropped mass per column equal the dropped mass per row), so
    the network average is conserved and the realized-product debias of
    Alg. 1 stays exact. ``masked_async_rounds`` uses this with the node
    outer-product mask; ``netfaults.masked_faulty_rounds`` with general
    edge masks (link drops, bursts, crashes, rejected payloads).

    Degenerate-row guard: a node whose every link dropped this round has a
    diagonal that is MATHEMATICALLY exactly 1 (the full nominal row sum),
    but float-summing the dropped weights yields 1 +- 1 ulp, so a long run
    of identity rounds would drift the iterate by ~1e-5. Pin fully-isolated
    rows to exactly 1.0: an all-asleep / all-links-down round becomes the
    exact identity matrix and a fully degenerate gossip call returns its
    input bit-for-bit."""
    w_off = jnp.where(off & mask, wz, 0.0)
    dropped = jnp.where(off & ~mask, wz, 0.0).sum(axis=1)
    dd = jnp.diag(wz) + dropped
    isolated = ~jnp.any(off & mask, axis=1)
    return w_off, jnp.where(isolated, jnp.ones((), wz.dtype), dd)


def safe_debias_scale(p):
    """Debias divisor from a realized mixing product ``p = [Pi W e_1]``.

    Degenerate-round guard: a round where every node sleeps (or every link
    is down) is an exact identity round, and an all-degenerate run leaves
    ``p`` at its e_1 initial value — entries that are EXACTLY zero. The old
    ``max(p, 1e-6)`` clamp divided by ~0 there, scaling the iterate by 1e6
    for no informational gain (the direction is all that survives the QR).
    Divide by 1.0 instead wherever the realized mass is below the clamp:
    same direction, bounded magnitude, and an all-degenerate gossip call
    returns its input bit-for-bit."""
    return jnp.where(p > 1e-6, p, jnp.ones((), p.dtype))


def gossip_mix(wz, z):
    """One gossip application ``out_i = sum_j w_ij z_j`` — THE dispatch
    seam between dense and sparse mixing. ``wz`` is either a dense (N, N)
    array (the einsum the paper-scale simulations always used — kept as
    the correctness oracle) or a ``core.sparse.SparseW`` (ELL SpMM via
    the Pallas kernel / gather fallback). Every consensus path — fused
    executors included — mixes through this function, so an engine
    switching to sparse storage changes ONLY the storage/kernel, not the
    algebra around it.
    """
    if isinstance(wz, SparseW):
        return wz.mix(z)
    return jnp.einsum("ij,j...->i...", wz, z)


@functools.partial(jax.jit, static_argnums=(2,))
def _dense_gossip(w, z_stack: jnp.ndarray, t_c: int) -> jnp.ndarray:
    wz = w.astype(z_stack.dtype)

    def round_(z, _):
        return gossip_mix(wz, z), None

    out, _ = jax.lax.scan(round_, z_stack, None, length=t_c)
    return out


def masked_gossip(w, z_stack: jnp.ndarray, t_c: jnp.ndarray,
                  t_max: int) -> jnp.ndarray:
    """``t_c`` gossip rounds where ``t_c`` is a *traced* value (<= t_max).

    The scan always runs ``t_max`` rounds and masks rounds past t_c, so a
    varying per-outer-iteration consensus budget stays inside one compiled
    program (this is the inner scan of the fused S-DOT executor). Round
    i < t_c applies exactly the same mix as _dense_gossip, in the same
    order — results match the eager engine to float-op identity.
    ``w`` may be dense or a ``SparseW`` (see ``gossip_mix``).
    """
    wz = w.astype(z_stack.dtype)

    def round_(z, i):
        z_next = gossip_mix(wz, z)
        return jnp.where(i < t_c, z_next, z), None

    out, _ = jax.lax.scan(round_, z_stack, jnp.arange(t_max))
    return out


@functools.partial(jax.jit, static_argnums=(1,))
def debias_table(w, t_max: int) -> jnp.ndarray:
    """Device-side debias weights [W^t e_1] for every t in 0..t_max at once.

    Returns (t_max + 1, N): row t equals ``debias_weights(w, t)`` (same
    1e-6 clamp), computed as one cumulative scan of W^T matvecs instead of a
    host-side ``np.linalg.matrix_power`` per outer iteration. Row t is
    indexed *inside* the fused executor's outer scan by the traced budget.
    ``w`` may be a ``SparseW`` (symmetric by construction, so the W^T
    matvec is the ordinary sparse mix — O(nnz) per row of the table).
    """
    n = w.shape[0]
    dtype = jnp.float32 if isinstance(w, SparseW) else w.dtype
    e1 = jnp.zeros((n,), dtype).at[0].set(1.0)

    def step(p, _):
        # SparseW is symmetric by contract, so W^T p is the ordinary mix;
        # the dense branch keeps the exact original matvec op
        p_next = w.mix(p) if isinstance(w, SparseW) else w.T @ p
        return p_next, p_next

    _, rows = jax.lax.scan(step, e1, None, length=t_max)
    table = jnp.concatenate([e1[None], rows], axis=0)
    return jnp.maximum(table, 1e-6)


def debiased_gossip(w: jnp.ndarray, table: jnp.ndarray, z_stack: jnp.ndarray,
                    t_c: jnp.ndarray, t_max: int) -> jnp.ndarray:
    """masked_gossip + debias-by-table-row: the fused executor's inner step.

    Fully traceable (t_c may be a traced budget from the schedule array);
    numerically this is run_debiased with the host matrix_power replaced by
    table[t_c]. Free function so one jit cache serves every engine with the
    same shapes.
    """
    out = masked_gossip(w, z_stack, t_c, t_max)
    scale = table[t_c]                                       # (N,)
    bshape = (-1,) + (1,) * (z_stack.ndim - 1)
    return out / scale.astype(out.dtype).reshape(bshape)


def debias_weights(w: np.ndarray, t_c: int) -> np.ndarray:
    """[W^{Tc} e_1]_i for every node i (the imperfect-averaging correction).

    Clamped away from zero: when t_c is smaller than a node's distance from
    node 0, the paper's debias weight is exactly 0 and V_i would be undefined
    (0/0). Early SA-DOT iterations hit this on sparse graphs; the clamp keeps
    the iterate finite — the local QR renormalizes, so only the *direction*
    matters and convergence is unaffected (the early iterate is inaccurate by
    design, cf. the SA-DOT schedule rationale).
    """
    n = w.shape[0]
    e1 = np.zeros(n)
    e1[0] = 1.0
    out = np.linalg.matrix_power(w.T, t_c) @ e1
    return np.maximum(out, 1e-6)


def consensus_schedule(kind: str, t_outer: int, t_max: int = 50, cap: Optional[int] = None):
    """Per-outer-iteration consensus budgets T_{c,t} used in the paper's tables.

    kind: 'const'   -> [t_max] * t_outer                      (S-DOT)
          'lin_half'-> ceil(0.5 t + 1)                         (SA-DOT, Table I)
          'lin1'    -> t + 1
          'lin2'    -> 2 t + 1
          'lin5'    -> 5 t + 1
    ``cap`` clips every entry (the paper's min(., 200) variants).
    """
    t = np.arange(1, t_outer + 1, dtype=np.float64)
    if kind == "const":
        sched = np.full(t_outer, float(t_max))
    elif kind == "lin_half":
        sched = np.ceil(0.5 * t + 1)
    elif kind == "lin1":
        sched = t + 1
    elif kind == "lin2":
        sched = 2 * t + 1
    elif kind == "lin5":
        sched = 5 * t + 1
    else:
        raise ValueError(f"unknown schedule kind: {kind}")
    if cap is not None:
        sched = np.minimum(sched, cap)
    return sched.astype(np.int64)


def _record_engine_metrics(sw: SparseW) -> None:
    """Publish a sparse engine's structure to the obs metrics registry
    (visible in ``python -m repro.obs summary``/``prom``): nnz/density
    gauges, plus a counter for the kernel path this process would select
    for its gossip rounds (host-side mirror of the traced dispatch)."""
    from ..kernels import ops as kops
    from ..obs import metrics
    reg = metrics()
    reg.gauge("gossip_sparse_nnz").set(sw.nnz)
    reg.gauge("gossip_sparse_density").set(sw.density)
    reg.gauge("gossip_sparse_ell_width").set(sw.ell_width)
    path = kops.ell_spmm_path(sw.n, sw.ell_width, 1)
    reg.counter(f"gossip_kernel_{path}_total").inc()
    if sw.payload_dtype is not None:
        reg.counter("gossip_bf16_engines_total").inc()


@dataclasses.dataclass
class DenseConsensus:
    """Single-device gossip simulator over an explicit graph.

    ``sparse`` selects the mixing storage/kernel: ``True`` stores W as a
    ``SparseW`` (padded-ELL SpMM rounds — O(nnz k) instead of O(N^2 k)),
    ``False`` forces the dense einsum, ``None`` (default) auto-enables
    sparse mixing only for networks that are both large and sparse
    (``sparse.auto_sparse`` — never at the paper's table scales, so
    existing seeded results are untouched). Either storage flows through
    the same ``gossip_mix`` seam in every fused executor, since they all
    embed ``self._w`` as a Program operand.
    """

    graph: Graph
    weights: Optional[np.ndarray] = None
    sparse: Optional[bool] = None
    payload_dtype: Optional[str] = None   # e.g. "bfloat16" (sparse only)

    def __post_init__(self):
        if self.weights is None:
            self.weights = local_degree_weights(self.graph)
        self._sparse = auto_sparse(self.graph.n_nodes, self.graph.density,
                                   self.sparse)
        if self._sparse:
            self._w = SparseW.from_dense(self.weights, self.graph.adjacency,
                                         payload_dtype=self.payload_dtype)
            _record_engine_metrics(self._w)
        elif self.payload_dtype is not None:
            raise ValueError("payload_dtype (bf16 gossip) requires the "
                             "sparse mixing path")
        else:
            self._w = jnp.asarray(self.weights)
            from ..obs import metrics
            metrics().counter("gossip_kernel_dense_total").inc()
        self._debias_tables = {}  # t_max -> (t_max+1, N) device table

    @property
    def is_sparse(self) -> bool:
        return self._sparse

    @property
    def payload_bytes_per_elem(self) -> float:
        """Wire bytes per payload element (ledger pricing): 2 when the
        sparse engine quantizes gossip payloads to bf16, else 4 (f32)."""
        return 2.0 if self.payload_dtype == "bfloat16" else 4.0

    def run(self, z_stack: jnp.ndarray, t_c: int) -> jnp.ndarray:
        """t_c gossip rounds on stacked blocks z_stack: (N, ...)."""
        return _dense_gossip(self._w, z_stack, int(t_c))

    def run_debiased(self, z_stack: jnp.ndarray, t_c: int,
                     ledger: Optional[CommLedger] = None) -> jnp.ndarray:
        """Gossip + per-node debias: approximates sum_j Z_j at every node."""
        out = self.run(z_stack, int(t_c))
        if self._sparse:
            # device-table row instead of the host O(N^3) matrix_power —
            # the whole point of the sparse engine is N where that
            # host power is unaffordable
            scale = self.debias_table(int(t_c))[int(t_c)]
        else:
            scale = jnp.asarray(debias_weights(self.weights, int(t_c)),
                                out.dtype)
        if ledger is not None:
            payload = int(np.prod(z_stack.shape[1:]))
            # closed form (identical increments per round), not an O(t_c)
            # host loop — eager B-DOT at t_c=50 was burning host time on
            # pure accounting
            ledger.log_gossip_rounds([int(t_c)], self.graph.adjacency,
                                     payload, self.payload_bytes_per_elem)
        bshape = (-1,) + (1,) * (z_stack.ndim - 1)
        return out / scale.astype(out.dtype).reshape(bshape)

    def debias_table(self, t_max: int) -> jnp.ndarray:
        """Cached (t_max + 1, N) table of [W^t e_1] rows (see debias_table)."""
        t_max = int(t_max)
        if t_max not in self._debias_tables:
            self._debias_tables[t_max] = debias_table(self._w, t_max)
        return self._debias_tables[t_max]

    def run_debiased_scan(self, z_stack: jnp.ndarray, t_c: jnp.ndarray, *,
                          t_max: int,
                          table: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Traceable twin of run_debiased, usable inside jit / lax.scan.

        ``t_c`` may be a traced int32 (the per-outer-iteration budget pulled
        from the schedule array); ``t_max`` is the static scan length (the
        schedule's max). PRECONDITION: t_c <= t_max — the masked scan caps
        gossip at t_max rounds and the table row gather clamps, so a larger
        t_c would silently return the t_max answer (checked here for
        concrete t_c; traced callers are responsible, as the fused executor
        is by construction). Gossip is a masked scan and the debias divides
        by a row of the precomputed device table — no host work, no
        recompile per distinct t_c. Accounting is NOT done here: the fused
        executor logs the whole schedule in closed form
        (CommLedger.log_gossip_rounds).
        """
        if isinstance(t_c, (int, np.integer)) and t_c > t_max:
            raise ValueError(f"t_c={t_c} exceeds the scan length t_max={t_max}")
        if table is None:
            table = self.debias_table(t_max)
        return debiased_gossip(self._w, table, z_stack, t_c, t_max)


@dataclasses.dataclass
class SparseConsensus(DenseConsensus):
    """Forced-sparse gossip engine: CSR/ELL mixing regardless of size.

    A ``DenseConsensus`` whose weight storage is always ``SparseW`` —
    every gossip round is an ELL SpMM (Pallas kernel on TPU, gather/
    einsum fallback elsewhere) and the debias table builds by sparse
    matvec. Plugs into every fused executor through the same ``_w`` /
    ``debias_table`` operand seam, so S-DOT/SA-DOT/F-DOT/B-DOT and the
    baselines run sparse without touching their Program definitions.

    ``payload_dtype="bfloat16"`` additionally quantizes the gossip
    payload (the neighbor messages, not each node's own state) to bf16
    with f32 accumulation; the comm ledger then prices bytes at 2/elem
    (``benchmarks/sparse_gossip_bench.py`` measures the accuracy-vs-bytes
    curve this trades on).
    """

    def __post_init__(self):
        if self.sparse is False:
            raise ValueError("SparseConsensus is the forced-sparse engine;"
                             " use DenseConsensus for dense mixing")
        self.sparse = True
        super().__post_init__()


class SpmdConsensus:
    """Gossip over a mesh axis using lax collectives inside shard_map.

    Node i's block lives on mesh position i along ``axis``. For a ring
    topology, W is circulant: one round is
        z <- w_self * z + w_left * ppermute(z, +1) + w_right * ppermute(z, -1)
    For general graphs one round is an all_gather + local weighted mix —
    correct everywhere, cheaper only when the payload is small (which it is:
    the paper's payloads are d x r with r << d, and F-DOT's are r x r).
    """

    def __init__(self, mesh: Mesh, axis: str, graph: Optional[Graph] = None,
                 weights: Optional[np.ndarray] = None):
        self.mesh = mesh
        self.axis = axis
        self.n = mesh.shape[axis]
        self.graph = graph if graph is not None else ring(self.n)
        self.weights = weights if weights is not None else local_degree_weights(self.graph)
        if self.weights.shape != (self.n, self.n):
            raise ValueError("weight matrix does not match mesh axis size")
        self._is_ring = self._detect_ring()
        self._w = jnp.asarray(self.weights)
        self._debias_tables = {}  # t_max -> (t_max+1, N) device table

    def _detect_ring(self) -> bool:
        return np.array_equal(self.graph.adjacency, ring(self.n).adjacency)

    def _ring_coeffs(self):
        w = self.weights
        n = self.n
        w_self = float(w[0, 0])
        w_next = float(w[0, (0 + 1) % n])
        w_prev = float(w[0, (0 - 1) % n])
        return w_self, w_prev, w_next

    def gossip_rounds(self, z: jnp.ndarray, t_c: int) -> jnp.ndarray:
        """t_c gossip rounds; z is the *local* block inside shard_map."""
        axis = self.axis
        if self._is_ring and self.n > 2:
            w_self, w_prev, w_next = self._ring_coeffs()
            fwd = [(i, (i + 1) % self.n) for i in range(self.n)]
            bwd = [(i, (i - 1) % self.n) for i in range(self.n)]

            def round_(zz, _):
                zp = jax.lax.ppermute(zz, axis, fwd)   # receives from i-1
                zn = jax.lax.ppermute(zz, axis, bwd)   # receives from i+1
                return w_self * zz + w_prev * zp + w_next * zn, None

            out, _ = jax.lax.scan(round_, z, None, length=t_c)
            return out
        # general topology: gather all blocks, mix with my row of W^{t_c}? No —
        # one round at a time keeps semantics identical to DenseConsensus.
        wj = jnp.asarray(self.weights, z.dtype)
        idx = jax.lax.axis_index(axis)

        def round_(zz, _):
            allz = jax.lax.all_gather(zz, axis)            # (N, ...)
            row = jax.lax.dynamic_slice_in_dim(wj, idx, 1, 0)[0]  # (N,)
            mixed = jnp.tensordot(row, allz, axes=(0, 0))
            return mixed, None

        out, _ = jax.lax.scan(round_, z, None, length=t_c)
        return out

    def gossip_rounds_masked(self, z: jnp.ndarray, t_c: jnp.ndarray,
                             t_max: int) -> jnp.ndarray:
        """``t_c`` gossip rounds inside shard_map where ``t_c`` is *traced*.

        The SPMD twin of ``masked_gossip``: the scan always runs the static
        ``t_max`` rounds and masks rounds past t_c, so a per-outer-iteration
        consensus budget read from a schedule array stays inside ONE compiled
        whole-run program per mesh — this is the inner scan of the fused
        S-DOT SPMD executor (sdot.sdot_spmd). Round i < t_c applies exactly
        the same update as gossip_rounds, in the same order.
        """
        axis = self.axis
        if self._is_ring and self.n > 2:
            w_self, w_prev, w_next = self._ring_coeffs()
            fwd = [(i, (i + 1) % self.n) for i in range(self.n)]
            bwd = [(i, (i - 1) % self.n) for i in range(self.n)]

            def round_(zz, i):
                zp = jax.lax.ppermute(zz, axis, fwd)   # receives from i-1
                zn = jax.lax.ppermute(zz, axis, bwd)   # receives from i+1
                mixed = w_self * zz + w_prev * zp + w_next * zn
                return jnp.where(i < t_c, mixed, zz), None

            out, _ = jax.lax.scan(round_, z, jnp.arange(t_max))
            return out
        wj = jnp.asarray(self.weights, z.dtype)
        idx = jax.lax.axis_index(axis)

        def round_(zz, i):
            allz = jax.lax.all_gather(zz, axis)            # (N, ...)
            row = jax.lax.dynamic_slice_in_dim(wj, idx, 1, 0)[0]  # (N,)
            mixed = jnp.tensordot(row, allz, axes=(0, 0))
            return jnp.where(i < t_c, mixed, zz), None

        out, _ = jax.lax.scan(round_, z, jnp.arange(t_max))
        return out

    def debias_table(self, t_max: int) -> jnp.ndarray:
        """Cached (t_max + 1, N) device table of [W^t e_1] rows.

        Same contract as DenseConsensus.debias_table; rows are indexed by the
        traced per-iteration budget inside the fused SPMD scan instead of a
        host matrix_power per outer iteration.
        """
        t_max = int(t_max)
        if t_max not in self._debias_tables:
            self._debias_tables[t_max] = debias_table(self._w, t_max)
        return self._debias_tables[t_max]

    def debias_by_table(self, z: jnp.ndarray, table: jnp.ndarray,
                        t_c: jnp.ndarray) -> jnp.ndarray:
        """Traceable twin of ``debias`` (inside shard_map): divide the local
        block by table[t_c][mesh position]. ``table`` must be passed in as a
        replicated shard_map operand so the row gather stays device-side."""
        idx = jax.lax.axis_index(self.axis)
        scale = jnp.take(table, t_c, axis=0)               # (N,)
        s = jax.lax.dynamic_slice_in_dim(scale, idx, 1, 0)[0]
        return z / s.astype(z.dtype)

    def debias(self, z: jnp.ndarray, t_c: int) -> jnp.ndarray:
        """Divide the local block by [W^{t_c} e_1]_i (inside shard_map)."""
        scale = jnp.asarray(debias_weights(self.weights, int(t_c)), z.dtype)
        idx = jax.lax.axis_index(self.axis)
        s = jax.lax.dynamic_slice_in_dim(scale, idx, 1, 0)[0]
        return z / s

    def build_debiased_sum(self, t_c: int):
        """Returns a jitted f(z_stacked) -> per-node approx of sum_j Z_j.

        z_stacked: (N, ...) array sharded so that axis 0 maps to the mesh
        axis. Output has the same sharding. This is the SPMD twin of
        DenseConsensus.run_debiased and is numerically identical for the
        same W (verified in tests/test_consensus_spmd.py).
        """
        mesh, axis = self.mesh, self.axis

        def local_fn(z):  # z: (1, ...) local block
            zz = z[0]
            zz = self.gossip_rounds(zz, t_c)
            zz = self.debias(zz, t_c)
            return zz[None]

        spec = P(axis)
        fn = shard_map(local_fn, mesh=mesh, in_specs=(spec,), out_specs=spec)
        return jax.jit(fn)


def two_level_reduce(z: jnp.ndarray, *, intra_axis: str, inter: "SpmdConsensus",
                     t_c: int) -> jnp.ndarray:
    """TPU-native S-DOT consensus (DESIGN.md sec.2): exact psum over the fast
    intra-pod axis followed by t_c gossip rounds + debias over the slow
    cross-pod axis. Call inside shard_map with both axes visible."""
    z = jax.lax.psum(z, intra_axis)
    z = inter.gossip_rounds(z, t_c)
    return inter.debias(z, t_c)
