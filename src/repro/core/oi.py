"""Centralized orthogonal iteration (paper's reference algorithm)."""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .linalg import cholesky_qr2

__all__ = ["orthogonal_iteration", "oi_trace"]


@functools.partial(jax.jit, static_argnums=(2,))
def orthogonal_iteration(m: jnp.ndarray, q_init: jnp.ndarray, t_outer: int) -> jnp.ndarray:
    """t_outer iterations of Q <- qr(M Q). Linear convergence at rate
    |lambda_{r+1}/lambda_r| (Golub & Van Loan)."""

    def step(q, _):
        v = m @ q
        q_new, _ = cholesky_qr2(v)
        return q_new, None

    q, _ = jax.lax.scan(step, q_init, None, length=t_outer)
    return q


def oi_trace(m: jnp.ndarray, q_init: jnp.ndarray, t_outer: int,
             metric: Optional[Callable] = None):
    """Like orthogonal_iteration but returns the per-iteration metric trace."""

    def step(q, _):
        v = m @ q
        q_new, _ = cholesky_qr2(v)
        out = metric(q_new) if metric is not None else jnp.zeros(())
        return q_new, out

    q, trace = jax.lax.scan(step, q_init, None, length=t_outer)
    return q, trace
