"""Self-healing gossip: a seeded, declarative NETWORK-fault layer.

``AsyncConsensus`` models i.i.d. node sleeping — the paper's straggler
study. Real overlays fail per-*link*, in bursts, and nodes crash and
rejoin; this module extends the realized-mixing machinery from node masks
to general EDGE masks so the whole algorithm zoo survives:

* **link drops** — each directed pair fails i.i.d. with ``p_drop`` per
  round (sampled symmetrically: a dropped link is dropped both ways, which
  is what keeps the realized round matrix doubly stochastic);
* **bursty outages** — a two-state Gilbert–Elliott Markov chain per edge
  (``p_bad`` to enter the bad state, ``p_good`` to recover, mean burst
  length 1/p_good); the per-edge state rides in the scan carry, across
  rounds AND outer iterations, so a chunked resume replays bursts exactly;
* **crash/rejoin** — a node leaves for a contiguous window of outer
  iterations (``crash_windows``): all its edges are masked, its iterate is
  frozen by the executors, and on rejoin it re-syncs from its neighbors
  through ordinary gossip;
* **payload corruption** — a node's outbound messages are scaled by
  ``corrupt_scale`` (or NaN-poisoned) with probability ``p_corrupt`` per
  round, and every receiver runs a detect-and-reject guard (NaN/norm
  screen, threshold ``guard_norm``): a poisoned round degrades to a
  dropped one — the sender's edges are masked both ways and its message is
  zeroed before mixing (so a NaN can never reach the einsum) — instead of
  diverging.

Every realized round renormalizes the surviving weights over the masked
edge set (``consensus.realized_round_weights`` — doubly stochastic for any
symmetric mask) and the realized mixing product ``p = Pi W e_1`` is
carried through the scan, so the exact debias of Alg. 1 applies under
arbitrary fault mixes and S-DOT/F-DOT/SA-DOT stay convergent
(``benchmarks/netfaults_bench.py`` measures the debiased-vs-uncorrected
gap). ``safe_debias_scale`` guards the all-links-down degenerate rounds.

Execution modes (same architecture as ``AsyncConsensus``):
  * fused — all per-round fault draws for an outer iteration are
    pre-sampled as ``(t_max, N, N)`` / ``(t_max, N)`` uniforms (the edge
    twin of ``sample_awake``'s node masks) and the realized rounds run in
    one ``lax.scan`` (``masked_faulty_rounds``), embeddable in the
    whole-run executors of sdot.py / fdot.py;
  * eager per-round (``run_rounds_eager``) — the same round function
    dispatched once per round from a Python loop; matches the fused scan
    bit-for-bit (pinned in tests/test_netfaults.py);
  * host (``fused=False``) — a pure-NumPy mirror of the round math, the
    human-auditable seeded oracle (identical masks, float32 arithmetic in
    the same operation order).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .consensus import (_record_engine_metrics, debias_table,
                        realized_round_weights, safe_debias_scale)
from .metrics import CommLedger
from .sparse import SparseW, auto_sparse
from .topology import Graph, local_degree_weights

__all__ = ["NetFaultModel", "FaultyConsensus", "masked_faulty_rounds",
           "sample_fault_blocks", "realized_debias"]

_CORRUPT_MODES = ("scale", "nan")
_DEBIAS_MODES = ("realized", "nominal")


# ---------------------------------------------------------------------------
# declarative fault model
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NetFaultModel:
    """Declarative network-fault configuration (all faults compose).

    The scalar knobs pack into a small device vector (``params()``), so a
    sweep can stack one row per case and vmap the SAME compiled body over a
    fault grid — fault parameters are sweepable lane data, not recompile
    triggers. ``crash_windows`` is (node, start_iter, n_iters) triples at
    outer-iteration granularity; ``node_up(t_outer, n)`` lowers them to a
    (T, N) schedule operand.
    """

    p_drop: float = 0.0          # i.i.d. per-link drop prob per round
    p_bad: float = 0.0           # Gilbert–Elliott: good -> bad per round
    p_good: float = 1.0          # Gilbert–Elliott: bad -> good per round
    p_corrupt: float = 0.0       # per-node outbound corruption prob/round
    corrupt_mode: str = "scale"  # "scale" | "nan"
    corrupt_scale: float = 1e9   # payload blow-up factor in "scale" mode
    guard_norm: float = 1e6      # receiver reject threshold (max |entry|)
    crash_windows: Tuple[Tuple[int, int, int], ...] = ()

    def validate(self, n_nodes: Optional[int] = None,
                 t_outer: Optional[int] = None) -> "NetFaultModel":
        for name in ("p_drop", "p_bad", "p_good", "p_corrupt"):
            v = float(getattr(self, name))
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}: must be in [0, 1], got {v}")
        if self.p_bad > 0.0 and self.p_good <= 0.0:
            raise ValueError("p_good: must be > 0 when p_bad > 0 "
                             "(a burst must be able to end)")
        if self.corrupt_mode not in _CORRUPT_MODES:
            raise ValueError(f"corrupt_mode: expected one of "
                             f"{_CORRUPT_MODES}, got {self.corrupt_mode!r}")
        if not float(self.corrupt_scale) > 0.0:
            raise ValueError(f"corrupt_scale: must be > 0, "
                             f"got {self.corrupt_scale}")
        if not float(self.guard_norm) > 0.0:
            raise ValueError(f"guard_norm: must be > 0, "
                             f"got {self.guard_norm}")
        for k, win in enumerate(self.crash_windows):
            if len(win) != 3:
                raise ValueError(f"crash_windows[{k}]: expected "
                                 "(node, start, len)")
            node, start, length = (int(x) for x in win)
            if node < 0 or (n_nodes is not None and node >= n_nodes):
                raise ValueError(f"crash_windows[{k}].node: {node} out of "
                                 f"range for {n_nodes} nodes")
            if start < 0:
                raise ValueError(f"crash_windows[{k}].start: must be >= 0, "
                                 f"got {start}")
            if length <= 0:
                raise ValueError(f"crash_windows[{k}].len: must be > 0, "
                                 f"got {length}")
            if t_outer is not None and start >= t_outer:
                raise ValueError(f"crash_windows[{k}].start: {start} is "
                                 f"past t_outer={t_outer}")
        return self

    def params(self) -> jnp.ndarray:
        """(6,) float32 device vector of the per-round scalar knobs.

        Layout: [p_drop, p_bad, p_good, p_corrupt, corrupt_value,
        guard_norm] — corrupt_value is NaN in "nan" mode so one compiled
        body serves both corruption modes.
        """
        cval = (np.nan if self.corrupt_mode == "nan"
                else float(self.corrupt_scale))
        return jnp.asarray([self.p_drop, self.p_bad, self.p_good,
                            self.p_corrupt, cval, self.guard_norm],
                           jnp.float32)

    def node_up(self, t_outer: int, n: int) -> np.ndarray:
        """(t_outer, N) float32 schedule: 0.0 while a node is crashed."""
        up = np.ones((max(int(t_outer), 1), int(n)), np.float32)
        for node, start, length in self.crash_windows:
            up[int(start):int(start) + int(length), int(node)] = 0.0
        return up[:int(t_outer)] if t_outer else up[:0]

    @property
    def mean_burst_len(self) -> float:
        return 1.0 / float(self.p_good) if self.p_good > 0 else float("inf")


# ---------------------------------------------------------------------------
# seeded pre-sampling (the edge-mask twin of AsyncConsensus.sample_awake)
# ---------------------------------------------------------------------------
def _sym_uniform(key, rows: int, n: int) -> jnp.ndarray:
    """(rows, N, N) uniforms, symmetrized by mirroring the upper triangle —
    one draw per undirected edge per round, so link faults hit both
    directions together (the symmetry that preserves double stochasticity).
    The diagonal is left at 0 (never read: masks only gate off-diagonal
    weights)."""
    u = jax.random.uniform(key, (rows, n, n))
    up = jnp.triu(u, 1)
    return up + jnp.swapaxes(up, 1, 2)


def sample_fault_blocks(key, n: int, rows: int):
    """Pre-sample one outer iteration's fault draws from a split key.

    Returns ``(u_drop, u_burst, u_corrupt)``: two (rows, N, N) symmetric
    uniform blocks (i.i.d. drops, Gilbert–Elliott transitions) and one
    (rows, N) uniform block (per-node payload corruption). The fused
    executors call this inside their outer scan with ``rows = t_max``
    (static shape); the eager oracle draws with the same padded shape and
    slices — a (t_c, ...) threefry draw is NOT a prefix of the
    (t_max, ...) one, exactly as with ``sample_awake``.
    """
    ku, kb, kc = jax.random.split(key, 3)
    return (_sym_uniform(ku, rows, n), _sym_uniform(kb, rows, n),
            jax.random.uniform(kc, (rows, n)))


# ---------------------------------------------------------------------------
# realized faulty rounds (traceable; the edge-mask twin of
# masked_async_rounds)
# ---------------------------------------------------------------------------
def _faulty_round(wz, adj_b, off, params, up_pair, node_up, z, p, ge,
                  u_drop, u_burst, u_cor):
    """One realized faulty round: mask -> renormalize -> mix -> account.

    Shared verbatim by the fused scan (``masked_faulty_rounds``) and the
    eager per-round oracle (``FaultyConsensus.run_rounds_eager``) so the
    two execution modes cannot drift — they apply the identical jaxpr per
    round and match bit for bit.
    """
    p_drop, p_bad, p_good, p_cor, cval, guard = (params[i]
                                                 for i in range(6))
    bshape = (-1,) + (1,) * (z.ndim - 1)
    axes = tuple(range(1, z.ndim))
    # Gilbert–Elliott per-edge chain: transition first, then the new state
    # gates this round (a burst that starts this round already bites)
    ge_next = jnp.where(ge, u_burst >= p_good, u_burst < p_bad)
    # payload corruption + receiver-side detect-and-reject screen
    factor = jnp.where(u_cor < p_cor, cval, jnp.float32(1.0))
    msg = z * factor.astype(z.dtype).reshape(bshape)
    finite = jnp.all(jnp.isfinite(msg), axis=axes)
    peak = jnp.max(jnp.abs(msg), axis=axes)          # NaN -> valid False
    valid = finite & (peak <= guard)
    # the surviving symmetric edge set: real edges between up nodes, not
    # dropped, not in a burst, and neither endpoint's payload rejected (a
    # poisoned sender degrades to a dropped node for this round)
    mask = (adj_b & up_pair & ~ge_next & (u_drop >= p_drop)
            & valid[:, None] & valid[None, :])
    w_off, dd = realized_round_weights(wz, mask, off)
    # zero rejected payloads BEFORE the einsum: a masked weight times a NaN
    # is still NaN — the screen must whiten the message, not just the edge
    msg_clean = jnp.where(valid.reshape(bshape), msg,
                          jnp.zeros((), z.dtype))
    # split form: the diagonal applies each node's OWN (uncorrupted) state,
    # off-diagonal weights apply the screened messages
    z_next = dd.reshape(bshape) * z + jnp.einsum("ij,j...->i...", w_off,
                                                 msg_clean)
    p_next = dd * p + w_off @ p
    sends = jnp.sum(jnp.where(off & mask, 1.0, 0.0))
    count = jnp.sum(node_up)
    return z_next, p_next, ge_next, sends, count


def _sparse_faulty_round(sw, slot_ok, params, up, node_up_f, z, p, ge,
                         u_drop, u_burst, u_cor):
    """ELL-form twin of ``_faulty_round``: edge masks become (N, L) mask
    vectors over the stored slots.

    The round draws are the SAME dense symmetric uniforms the dense engine
    pre-samples — gathered at the ELL slots (``take_along_axis`` with the
    neighbor indices), so a sparse engine realizes bit-identical fault
    masks to its dense oracle and only the float reduction ORDER differs
    (gather-sum over L slots instead of an N-wide einsum row). Dropped
    mass returns to the diagonal per row (the sparse image of
    ``realized_round_weights``), with the same exactly-1.0 pin for a
    fully-isolated node. The Gilbert–Elliott state rides in ELL form
    (N, L): both directions of an edge gather the same symmetric uniform
    from an all-good start, so the slot states stay mirror-consistent with
    the dense (N, N) chain.
    """
    p_drop, p_bad, p_good, p_cor, cval, guard = (params[i]
                                                 for i in range(6))
    bshape = (-1,) + (1,) * (z.ndim - 1)
    axes = tuple(range(1, z.ndim))
    idx = sw.ell_idx
    ud = jnp.take_along_axis(u_drop, idx, axis=1)
    ub = jnp.take_along_axis(u_burst, idx, axis=1)
    ge_next = jnp.where(ge, ub >= p_good, ub < p_bad)
    factor = jnp.where(u_cor < p_cor, cval, jnp.float32(1.0))
    msg = z * factor.astype(z.dtype).reshape(bshape)
    finite = jnp.all(jnp.isfinite(msg), axis=axes)
    peak = jnp.max(jnp.abs(msg), axis=axes)          # NaN -> valid False
    valid = finite & (peak <= guard)
    # surviving slots: real (non-padded) edges between up nodes, not
    # dropped, not in a burst, neither endpoint's payload rejected
    mask = (slot_ok & up[:, None] & up[idx] & ~ge_next & (ud >= p_drop)
            & valid[:, None] & valid[idx])
    wv = sw.ell_val.astype(z.dtype)
    zero = jnp.zeros((), z.dtype)
    w_off = jnp.where(mask, wv, zero)
    dropped = jnp.where(slot_ok & ~mask, wv, zero).sum(axis=1)
    dd = sw.diag.astype(z.dtype) + dropped
    dd = jnp.where(mask.any(axis=1), dd, jnp.ones((), z.dtype))
    msg_clean = jnp.where(valid.reshape(bshape), msg, zero)
    # split form as in the dense round: diagonal applies the node's OWN
    # (uncorrupted, full-precision) state; masked off-diagonal slots apply
    # the screened neighbor messages through the SpMM hook
    z_next = (dd.reshape(bshape) * z
              + sw.offdiag_mix(jnp.zeros_like(sw.diag), w_off, msg_clean))
    p_next = dd * p + jnp.sum(w_off * jnp.take(p, idx), axis=1)
    sends = jnp.sum(jnp.where(mask, 1.0, 0.0))
    count = jnp.sum(node_up_f)
    return z_next, p_next, ge_next, sends, count


def _masked_sparse_faulty_rounds(sw, params, node_up, ge0, blocks, t_c,
                                 z_stack):
    """Sparse branch of ``masked_faulty_rounds`` (ge0: (N, L) ELL-form)."""
    n = sw.n
    slot_ok = (jnp.arange(sw.ell_width)[None, :]
               < sw.row_nnz[:, None])
    up = node_up > 0
    node_up_f = node_up.astype(jnp.float32)

    def round_(carry, inp):
        z, p, ge = carry
        u_drop, u_burst, u_cor, i = inp
        live = i < t_c
        z_next, p_next, ge_next, sends, count = _sparse_faulty_round(
            sw, slot_ok, params, up, node_up_f, z, p, ge,
            u_drop, u_burst, u_cor)
        z = jnp.where(live, z_next, z)
        p = jnp.where(live, p_next, p)
        ge = jnp.where(live, ge_next, ge)
        return (z, p, ge), (jnp.where(live, sends, 0.0),
                            jnp.where(live, count, 0.0))

    u_drop, u_burst, u_cor = blocks
    e1 = jnp.zeros((n,), z_stack.dtype).at[0].set(1.0)
    (z, p, ge), (sends, counts) = jax.lax.scan(
        round_, (z_stack, e1, ge0),
        (u_drop, u_burst, u_cor, jnp.arange(u_drop.shape[0])))
    return z, p, ge, sends, counts


def masked_faulty_rounds(w, adj, params, node_up, ge0, blocks, t_c,
                         z_stack):
    """Traceable faulty gossip: ``t_c`` realized edge-mask rounds.

    w: (N, N) nominal weights OR a ``SparseW`` (the sparse branch gathers
    the same dense fault draws at its ELL slots, so realized masks match
    the dense engine exactly; its ge0 is the engine's (N, L) ELL-form
    state); adj: (N, N) 0/1 adjacency (unused by the sparse branch — the
    structure lives in the SparseW); params: (6,)
    ``NetFaultModel.params()``; node_up: (N,) 0/1 crash mask for this outer
    iteration; ge0: (N, N) bool Gilbert–Elliott bad-state at entry (carried
    across calls); blocks: pre-sampled draws from ``sample_fault_blocks``
    (first axis >= t_c; rounds i >= t_c are masked out of every recursion
    exactly like ``masked_async_rounds``, so traced budgets work inside the
    whole-run executors). z_stack: (N, ...).

    Returns ``(z, p, ge, sends, counts)``: the UNdebiased mixed stack, the
    realized mixing product column ``p = Pi W e_1`` (divide via
    ``realized_debias`` for the exact correction, or by a nominal W^t e_1
    table row for the uncorrected arm benchmarks measure), the final burst
    state, and per-round send/up-node counts (masked rounds report 0.0).
    """
    if isinstance(w, SparseW):
        return _masked_sparse_faulty_rounds(w, params, node_up, ge0,
                                            blocks, t_c, z_stack)
    n = w.shape[0]
    off = ~jnp.eye(n, dtype=bool)
    wz = w.astype(z_stack.dtype)
    adj_b = adj > 0
    up = node_up > 0
    up_pair = up[:, None] & up[None, :]
    node_up_f = node_up.astype(jnp.float32)

    def round_(carry, inp):
        z, p, ge = carry
        u_drop, u_burst, u_cor, i = inp
        live = i < t_c
        z_next, p_next, ge_next, sends, count = _faulty_round(
            wz, adj_b, off, params, up_pair, node_up_f, z, p, ge,
            u_drop, u_burst, u_cor)
        z = jnp.where(live, z_next, z)
        p = jnp.where(live, p_next, p)
        ge = jnp.where(live, ge_next, ge)
        return (z, p, ge), (jnp.where(live, sends, 0.0),
                            jnp.where(live, count, 0.0))

    u_drop, u_burst, u_cor = blocks
    e1 = jnp.zeros((n,), z_stack.dtype).at[0].set(1.0)
    (z, p, ge), (sends, counts) = jax.lax.scan(
        round_, (z_stack, e1, ge0),
        (u_drop, u_burst, u_cor, jnp.arange(u_drop.shape[0])))
    return z, p, ge, sends, counts


def realized_debias(z, p):
    """Exact per-node debias by the realized mixing product (guarded)."""
    bshape = (-1,) + (1,) * (z.ndim - 1)
    return z / safe_debias_scale(p).astype(z.dtype).reshape(bshape)


@functools.partial(jax.jit, static_argnums=())
def _fused_faulty_run(w, adj, params, node_up, ge0, u_drop, u_burst, u_cor,
                      z_stack):
    """All rounds of the pre-sampled blocks, one dispatch (t_c == T)."""
    return masked_faulty_rounds(w, adj, params, node_up, ge0,
                                (u_drop, u_burst, u_cor),
                                jnp.int32(u_drop.shape[0]), z_stack)


@jax.jit
def _one_faulty_round(wz, adj_b, off, params, up_pair, node_up, z, p, ge,
                      u_drop, u_burst, u_cor):
    return _faulty_round(wz, adj_b, off, params, up_pair, node_up, z, p,
                         ge, u_drop, u_burst, u_cor)


@jax.jit
def _one_sparse_faulty_round(sw, slot_ok, params, up, node_up, z, p, ge,
                             u_drop, u_burst, u_cor):
    return _sparse_faulty_round(sw, slot_ok, params, up, node_up, z, p,
                                ge, u_drop, u_burst, u_cor)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FaultyConsensus:
    """Gossip under the full network-fault taxonomy of ``NetFaultModel``.

    Wraps any explicit graph with seeded link drops, bursty outages,
    crash/rejoin and payload corruption, renormalizing every realized round
    (doubly stochastic by construction) and tracking the realized mixing
    product for the exact debias — the edge-mask generalization of
    ``AsyncConsensus``. The Gilbert–Elliott burst state and the RNG key
    persist on the engine between calls, mirroring how the fused whole-run
    executors carry both through their scan.

    ``debias``: "realized" divides by the carried ``Pi W e_1`` (the
    self-healing correction); "nominal" divides by the fault-free
    ``W^t e_1`` table row — the uncorrected arm whose error floor the
    benchmark shows plateauing ~10x higher.
    """

    graph: Graph
    faults: NetFaultModel = dataclasses.field(default_factory=NetFaultModel)
    seed: int = 0
    fused: bool = True           # device rounds vs host NumPy oracle
    debias: str = "realized"     # "realized" | "nominal"
    sparse: Optional[bool] = None         # None = auto_sparse policy
    payload_dtype: Optional[str] = None   # e.g. "bfloat16" (sparse only)

    def __post_init__(self):
        if self.debias not in _DEBIAS_MODES:
            raise ValueError(f"debias: expected one of {_DEBIAS_MODES}, "
                             f"got {self.debias!r}")
        self.faults.validate(self.graph.n_nodes)
        self.weights = local_degree_weights(self.graph)
        self._sparse = auto_sparse(self.graph.n_nodes, self.graph.density,
                                   self.sparse)
        if self._sparse and not self.fused:
            raise ValueError("sparse=True requires fused=True: the NumPy "
                             "host oracle is dense-only (use a dense "
                             "engine as the oracle instead)")
        if self.payload_dtype is not None and not self._sparse:
            raise ValueError("payload_dtype (bf16 gossip) requires the "
                             "sparse mixing path (sparse=True)")
        if self._sparse:
            self._w = SparseW.from_dense(self.weights,
                                         self.graph.adjacency,
                                         payload_dtype=self.payload_dtype)
            _record_engine_metrics(self._w)
        else:
            self._w = jnp.asarray(self.weights, jnp.float32)
        self._adj = jnp.asarray(self.graph.adjacency, jnp.float32)
        self._params = self.faults.params()
        self._debias_tables = {}
        self.reset()
        from ..obs import get_journal
        get_journal().event(
            "netfault_model", "chaos", n_nodes=self.graph.n_nodes,
            seed=int(self.seed), debias=self.debias,
            p_drop=float(self.faults.p_drop),
            p_bad=float(self.faults.p_bad),
            p_corrupt=float(self.faults.p_corrupt),
            n_crash_windows=len(self.faults.crash_windows))

    @property
    def n_nodes(self) -> int:
        return self.graph.n_nodes

    @property
    def is_sparse(self) -> bool:
        return self._sparse

    @property
    def payload_bytes_per_elem(self) -> float:
        """Wire bytes per payload element (2.0 under bf16 gossip)."""
        return 2.0 if self.payload_dtype == "bfloat16" else 4.0

    def reset(self) -> None:
        """Rewind the fault stream: fresh key, all links in the good state
        (ELL-form (N, L) burst state for sparse engines)."""
        self._key = jax.random.PRNGKey(self.seed)
        if isinstance(self._w, SparseW):
            self._ge = jnp.zeros((self.graph.n_nodes, self._w.ell_width),
                                 bool)
        else:
            self._ge = jnp.zeros((self.graph.n_nodes,) * 2, bool)

    def debias_row(self, t_c: int) -> jnp.ndarray:
        """Nominal (fault-free) debias row [W^{t_c} e_1] — the uncorrected
        arm's divisor (cached per t_c via the shared device table)."""
        t_c = int(t_c)
        if t_c not in self._debias_tables:
            self._debias_tables[t_c] = debias_table(self._w, t_c)[t_c]
        return self._debias_tables[t_c]

    def sample_faults(self, t_c: int, t_max: Optional[int] = None):
        """Pre-sample the next iteration's fault blocks, advancing the
        engine's jax.random stream exactly as the fused executors do (one
        split per outer iteration; ``t_max`` pads the draw shape for
        bit-level replay — see ``sample_fault_blocks``)."""
        self._key, sub = jax.random.split(self._key)
        rows = int(t_c if t_max is None else t_max)
        blocks = sample_fault_blocks(sub, self.graph.n_nodes, rows)
        return tuple(b[:int(t_c)] for b in blocks)

    def run_debiased(self, z_stack, t_c: int,
                     ledger: Optional[CommLedger] = None,
                     faults=None, node_up=None) -> jnp.ndarray:
        """``t_c`` realized faulty rounds + debias (realized or nominal).

        ``faults`` optionally injects pre-sampled blocks (the eager
        executors pass the padded draws so seeded eager runs replay the
        fused scan); ``node_up`` injects the (N,) crash mask for the
        current outer iteration (default: everyone up). The burst state
        advances on the engine across calls.
        """
        t_c = int(t_c)
        if faults is None:
            faults = self.sample_faults(t_c)
        else:
            faults = tuple(b[:t_c] for b in faults)
        if node_up is None:
            node_up = jnp.ones((self.graph.n_nodes,), jnp.float32)
        node_up = jnp.asarray(node_up, jnp.float32)
        z = jnp.asarray(z_stack, jnp.float32)
        if self.fused:
            zz, p, ge, sends, counts = _fused_faulty_run(
                self._w, self._adj, self._params, node_up, self._ge,
                *[jnp.asarray(b) for b in faults], z)
        else:
            zz, p, ge, sends, counts = self._run_host(z, node_up, faults)
        self._ge = ge
        if ledger is not None:
            sends_np = np.asarray(sends, np.float64)
            payload = float(np.prod(z_stack.shape[1:]))
            total = float(sends_np.sum())
            ledger.p2p += total
            ledger.matrices += total
            ledger.scalars += total * payload
            ledger.payload_bytes += (total * payload
                                     * self.payload_bytes_per_elem)
            ledger.log_awake_rounds(np.asarray(counts))
        if self.debias == "realized":
            return realized_debias(zz, p)
        bshape = (-1,) + (1,) * (z.ndim - 1)
        row = self.debias_row(t_c).astype(zz.dtype)
        return zz / row.reshape(bshape)

    def run_rounds_eager(self, z_stack, node_up, faults):
        """The per-round eager twin of the fused scan: one jitted dispatch
        of the SAME round function per round. Matches
        ``masked_faulty_rounds`` bit for bit (tests/test_netfaults.py) —
        the execution-mode oracle for the whole-run executors."""
        n = self.graph.n_nodes
        z = jnp.asarray(z_stack, jnp.float32)
        node_up = jnp.asarray(node_up, jnp.float32)
        up = node_up > 0
        p = jnp.zeros((n,), z.dtype).at[0].set(1.0)
        ge = self._ge
        u_drop, u_burst, u_cor = faults
        sends, counts = [], []
        if isinstance(self._w, SparseW):
            slot_ok = (jnp.arange(self._w.ell_width)[None, :]
                       < self._w.row_nnz[:, None])
            for t in range(u_drop.shape[0]):
                z, p, ge, s, c = _one_sparse_faulty_round(
                    self._w, slot_ok, self._params, up, node_up, z, p,
                    ge, u_drop[t], u_burst[t], u_cor[t])
                sends.append(s)
                counts.append(c)
            return z, p, ge, jnp.stack(sends), jnp.stack(counts)
        off = ~jnp.eye(n, dtype=bool)
        wz = self._w.astype(z.dtype)
        adj_b = self._adj > 0
        up_pair = up[:, None] & up[None, :]
        for t in range(u_drop.shape[0]):
            z, p, ge, s, c = _one_faulty_round(
                wz, adj_b, off, self._params, up_pair, node_up, z, p, ge,
                u_drop[t], u_burst[t], u_cor[t])
            sends.append(s)
            counts.append(c)
        return z, p, ge, jnp.stack(sends), jnp.stack(counts)

    def _run_host(self, z_stack, node_up, faults):
        """Pure-NumPy float32 oracle: identical masks and operation order
        as ``_faulty_round``, written independently for auditability."""
        n = self.graph.n_nodes
        off = ~np.eye(n, dtype=bool)
        w = np.asarray(self.weights, np.float32)
        adj_b = np.asarray(self.graph.adjacency) > 0
        p_drop, p_bad, p_good, p_cor, cval, guard = np.asarray(
            self._params, np.float32)
        node_up = np.asarray(node_up, np.float32)
        up = node_up > 0
        up_pair = np.outer(up, up)
        z = np.asarray(z_stack, np.float32)
        bshape = (-1,) + (1,) * (z.ndim - 1)
        axes = tuple(range(1, z.ndim))
        p = np.zeros((n,), np.float32)
        p[0] = 1.0
        ge = np.asarray(self._ge, bool)
        u_drop, u_burst, u_cor = (np.asarray(b) for b in faults)
        sends, counts = [], []
        for t in range(u_drop.shape[0]):
            ge = np.where(ge, u_burst[t] >= p_good, u_burst[t] < p_bad)
            factor = np.where(u_cor[t] < p_cor, cval,
                              np.float32(1.0)).astype(np.float32)
            msg = z * factor.reshape(bshape)
            with np.errstate(invalid="ignore"):
                finite = np.all(np.isfinite(msg), axis=axes)
                peak = np.max(np.abs(msg), axis=axes)
                valid = finite & (peak <= guard)
            mask = (adj_b & up_pair & ~ge & (u_drop[t] >= p_drop)
                    & valid[:, None] & valid[None, :])
            w_off = np.where(off & mask, w, np.float32(0.0))
            dd = (np.diag(w)
                  + np.where(off & ~mask, w, np.float32(0.0)).sum(axis=1))
            # degenerate-row guard (mirrors realized_round_weights): a
            # fully-isolated node's diagonal is exactly 1, not 1 +- ulp
            dd = np.where((off & mask).any(axis=1), dd, np.float32(1.0))
            msg_clean = np.where(valid.reshape(bshape), msg,
                                 np.float32(0.0))
            z = (dd.reshape(bshape) * z
                 + np.einsum("ij,j...->i...", w_off, msg_clean))
            p = dd * p + w_off @ p
            sends.append(float((off & mask).sum()))
            counts.append(float(node_up.sum()))
        return (jnp.asarray(z), jnp.asarray(p), jnp.asarray(ge),
                jnp.asarray(np.asarray(sends, np.float32)),
                jnp.asarray(np.asarray(counts, np.float32)))

    def realized_round_matrix(self, mask: np.ndarray) -> np.ndarray:
        """Host reference: the (N, N) realized doubly-stochastic round
        matrix for a given symmetric surviving-edge mask (used by tests to
        check stochasticity and mass conservation)."""
        n = self.graph.n_nodes
        off = ~np.eye(n, dtype=bool)
        mask = np.asarray(mask, bool)
        w = np.where(off & mask, self.weights, 0.0)
        dd = (self.weights.diagonal()
              + np.where(off & ~mask, self.weights, 0.0).sum(axis=1))
        np.fill_diagonal(w, np.where((off & mask).any(axis=1), dd, 1.0))
        return w
