"""Observability layer: journal robustness, registry math, CLI forensics.

The journal's load-bearing properties: appends are whole-line atomic under
concurrency, a SIGKILL-torn tail reads cleanly, relaunches open NEW
attempt-scoped files, and — above all — tracing is strictly out-of-band:
the same chunked run produces bit-identical device results with the
journal installed or disabled.
"""
import json
import os
import threading

import numpy as np
import pytest

import repro.obs as obs
from repro.obs import (Histogram, Journal, MetricsRegistry, install,
                      journal_files, merge_journals, read_journal)
from repro.obs.cli import (build_exposition, forensics_report, main,
                           phase_summary, render_gantt, resolve_obs_dir)


@pytest.fixture(autouse=True)
def _isolated_obs(monkeypatch):
    """Every test gets a pristine process journal/registry and a clean env
    (no cross-test leakage through the module globals)."""
    monkeypatch.delenv(obs.ENV_OBS, raising=False)
    monkeypatch.delenv(obs.ENV_DIR, raising=False)
    yield
    obs.set_journal(Journal.noop())


# ---------------------------------------------------------------------------
# journal: record schema, spans, robustness
# ---------------------------------------------------------------------------
def test_journal_records_and_span_pairing(tmp_path):
    j = Journal.open(str(tmp_path), "worker_s0", run="r1")
    j.event("chunk", "runtime", step=4)
    with j.span("ckpt_save", "checkpoint", step=4) as sp:
        sp.add(blocking=False)
    j.close()
    recs = read_journal(os.path.join(tmp_path, "worker_s0.a0.jsonl"))
    assert [r["kind"] for r in recs] == ["event", "span_start", "span"]
    ev, start, end = recs
    assert ev["name"] == "chunk" and ev["phase"] == "runtime"
    assert ev["step"] == 4 and ev["run"] == "r1"      # static field rides
    assert start["sid"] == end["sid"]
    assert end["ok"] is True and end["dur_s"] >= 0.0
    assert end["blocking"] is False                   # add() landed
    assert {"ts", "mono", "proc", "pid", "attempt"} <= set(ev)


def test_journal_reserved_field_names_never_raise(tmp_path):
    # "kind"/"name"/... are record schema; a colliding caller field is
    # prefixed instead of clobbering it (observability never raises)
    j = Journal.open(str(tmp_path), "p")
    j.event("fired", "chaos", kind="kill", name="x", pid=9)
    j.close()
    (rec,) = read_journal(os.path.join(tmp_path, "p.a0.jsonl"))
    assert rec["kind"] == "event" and rec["name"] == "fired"
    assert rec["f_kind"] == "kill" and rec["f_name"] == "x"
    assert rec["f_pid"] == 9 and rec["pid"] == os.getpid()


def test_torn_tail_skipped_cleanly(tmp_path):
    path = str(tmp_path / "w.a0.jsonl")
    j = Journal(path, "w")
    for i in range(3):
        j.event("e", step=i)
    j.close()
    with open(path, "ab") as f:                 # SIGKILL mid-append debris
        f.write(b'{"ts": 1.0, "kind": "eve')
    recs = read_journal(path)
    assert [r["step"] for r in recs] == [0, 1, 2]
    # and a second writer appending AFTER the torn line still parses: the
    # torn line has no newline, so the next append glues to it — both are
    # lost together, later lines survive
    j2 = Journal(path, "w")
    j2.event("e", step=3)
    j2.event("e", step=4)
    j2.close()
    steps = [r["step"] for r in read_journal(path) if "step" in r]
    assert steps[-1] == 4


def test_concurrent_appends_interleave_whole_lines(tmp_path):
    path = str(tmp_path / "shared.a0.jsonl")
    writers = [Journal(path, f"t{i}") for i in range(4)]

    def pound(j, tid):
        for i in range(200):
            j.event("e", tid=tid, i=i, pad="x" * 64)

    threads = [threading.Thread(target=pound, args=(w, i))
               for i, w in enumerate(writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for w in writers:
        w.close()
    recs = read_journal(path)
    assert len(recs) == 4 * 200                 # nothing torn or merged
    for tid in range(4):
        mine = [r["i"] for r in recs if r["tid"] == tid]
        assert mine == list(range(200))         # per-writer order kept


def test_attempt_scoped_journals_never_clobber(tmp_path):
    j0 = Journal.open(str(tmp_path), "fleet_w0")
    j0.event("before_crash")
    j0.close()
    j1 = Journal.open(str(tmp_path), "fleet_w0")      # the relaunch
    j1.event("after_crash")
    j1.close()
    files = journal_files(str(tmp_path))
    assert [(p, a) for _, p, a in files] == [("fleet_w0", 0),
                                             ("fleet_w0", 1)]
    assert read_journal(files[0][0])[0]["name"] == "before_crash"
    assert read_journal(files[1][0])[0]["name"] == "after_crash"


def test_merge_journals_orders_by_wall_clock(tmp_path):
    a = Journal.open(str(tmp_path), "a")
    b = Journal.open(str(tmp_path), "b")
    a.event("first")
    b.event("second")
    a.event("third")
    a.close(), b.close()
    names = [r["name"] for r in merge_journals(str(tmp_path))]
    assert names == ["first", "second", "third"]


def test_noop_journal_is_inert(tmp_path):
    j = Journal.noop()
    assert not j.enabled
    j.event("e", step=1)
    with j.span("s", "p") as sp:
        sp.add(x=1)
    sp.end()                                    # double end: fine
    assert list(tmp_path.iterdir()) == []


def test_install_disabled_by_env(tmp_path, monkeypatch):
    monkeypatch.setenv(obs.ENV_OBS, "0")
    j = install(str(tmp_path), "service")
    assert not j.enabled and obs.get_journal() is j
    assert obs.obs_dir_for(str(tmp_path)) is None
    assert not (tmp_path / "obs").exists()


def test_install_opens_attempt_scoped_journal(tmp_path):
    j = install(str(tmp_path), "service")
    assert j.enabled and j.attempt == 0
    j.event("tick")
    j.close()
    j2 = install(str(tmp_path), "service")
    assert j2.attempt == 1
    with j2.span("work", "serving"):
        pass
    j2.close()
    # span durations fed the (fresh) process registry
    h = obs.metrics().histogram("span_work_seconds")
    assert h.count == 1


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_histogram_percentiles_and_merge():
    h = Histogram()
    for v in np.linspace(0.001, 0.1, 100):
        h.observe(float(v))
    assert 0.03 < h.p50 < 0.07
    assert 0.08 < h.p99 <= 0.1
    assert h.mean == pytest.approx(np.mean(np.linspace(0.001, 0.1, 100)))
    h2 = Histogram()
    h2.merge(h.snapshot())
    h2.merge(h.snapshot())
    assert h2.count == 200 and h2.max == h.max
    with pytest.raises(ValueError):
        Histogram(bounds=[1.0, 2.0]).merge(h.snapshot())


def test_histogram_empty_and_degenerate():
    h = Histogram()
    assert h.p50 is None and h.p99 is None and h.mean is None
    h.observe(0.0)                              # below the lowest bound
    assert h.p50 == 0.0 and h.p99 == 0.0       # clamped to observed range


def test_registry_dump_load_merge_prom(tmp_path):
    reg = MetricsRegistry()
    reg.counter("query_shed_total").inc(3)
    reg.gauge("staleness_ticks").set(7)
    reg.histogram("lat_seconds").observe(0.01)
    path = reg.dump(str(tmp_path / "metrics.a.json"))
    back = MetricsRegistry.load(path)
    assert back.counter("query_shed_total").value == 3
    back.merge_snapshot(reg.snapshot())         # fold a second process in
    assert back.counter("query_shed_total").value == 6
    assert back.gauge("staleness_ticks").value == 7
    assert back.histogram("lat_seconds").count == 2
    prom = back.to_prom()
    assert "# TYPE repro_query_shed_total counter" in prom
    assert "repro_query_shed_total 6" in prom
    assert 'repro_lat_seconds_bucket{le="+Inf"} 2' in prom
    assert "repro_lat_seconds_p99" in prom


def test_registry_type_conflict():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


# ---------------------------------------------------------------------------
# CLI: forensics, attribution, summaries, gantt
# ---------------------------------------------------------------------------
def _synthetic_crash_dir(tmp_path):
    """worker_s0.a0 dies inside ckpt_save with fault #0 fired in it;
    worker_s0.a1 completes cleanly. Fault #1 never fires anywhere."""
    d = str(tmp_path / "obs")
    j = Journal.open(d, "worker_s0")
    sp = j.begin("shard_run", "worker", shard=0)
    inner = j.begin("ckpt_save", "checkpoint", step=4)
    j.event("chaos_fired", "chaos", fault=0, fault_kind="kill", boundary=2,
            shard=0)
    del sp, inner                               # SIGKILL: spans never end
    j.close()
    j = Journal.open(d, "worker_s0")
    with j.span("shard_run", "worker", shard=0):
        pass
    j.close()
    plan = {"seed": 0, "faults": [
        {"kind": "kill", "shard": 0, "boundary": 2},
        {"kind": "corrupt", "shard": 1, "boundary": 3}]}
    plan_path = str(tmp_path / "plan.json")
    with open(plan_path, "w") as f:
        json.dump(plan, f)
    return d, plan_path


def test_forensics_names_death_phase_and_attributes_faults(tmp_path):
    d, plan_path = _synthetic_crash_dir(tmp_path)
    text, ok = forensics_report(d, plan_path=plan_path)
    assert "died during shard_run[worker] > ckpt_save[checkpoint]" in text
    assert "kill(shard=0) -> worker_s0.a0" in text
    assert "during ckpt_save/checkpoint" in text
    assert "fault #1 corrupt(shard=1) -> NO TRACE" in text
    assert "1/2 plan faults attributed" in text
    assert ok is False                          # fault #1 unattributed
    text2, ok2 = forensics_report(d)            # no plan: always ok
    assert ok2 is True and "no open spans" in text2


def test_cli_exit_codes_and_dir_resolution(tmp_path, capsys):
    d, plan_path = _synthetic_crash_dir(tmp_path)
    # workdir containing obs/ resolves too
    assert resolve_obs_dir(str(tmp_path)) == d
    assert main(["forensics", str(tmp_path), "--plan", plan_path]) == 1
    assert main(["forensics", d]) == 0
    assert main(["timeline", d, "--last", "3"]) == 0
    assert main(["summary", d]) == 0
    assert main(["prom", d]) == 0
    assert main(["gantt", d]) == 0
    capsys.readouterr()
    with pytest.raises(SystemExit):
        resolve_obs_dir(str(tmp_path / "nope"))


def test_phase_summary_and_exposition(tmp_path):
    d, _ = _synthetic_crash_dir(tmp_path)
    summary = phase_summary(merge_journals(d))
    assert summary[("worker", "shard_run")]["count"] == 1   # only the closed one
    assert summary[("chaos", "chaos_fired")]["events"] == 1
    reg = build_exposition(d)
    assert reg.counter("event_chaos_fired_total").value == 1
    assert reg.histogram("span_shard_run_seconds").count == 1
    # a metrics.*.json dump in the dir is merged in
    extra = MetricsRegistry()
    extra.counter("query_shed_total").inc(5)
    extra.dump(os.path.join(d, "metrics.service.json"))
    assert build_exposition(d).counter("query_shed_total").value == 5


def test_gantt_renders_rows_and_fault_marks(tmp_path):
    d, _ = _synthetic_crash_dir(tmp_path)
    out = render_gantt(d, width=32)
    assert "worker_s0.a0" in out and "worker_s0.a1" in out
    assert "X" in out                           # the chaos firing column


# ---------------------------------------------------------------------------
# out-of-band: tracing never changes device results
# ---------------------------------------------------------------------------
def test_chunked_run_bit_identical_with_tracing(tmp_path, monkeypatch):
    import jax.numpy as jnp

    from repro.checkpoint.manager import CheckpointManager
    from repro.core.consensus import DenseConsensus
    from repro.core.runtime import run_chunked
    from repro.core.sdot import sdot_program
    from repro.core.topology import erdos_renyi

    rng = np.random.default_rng(5)
    x = rng.standard_normal((10, 120)).astype(np.float32)
    covs = jnp.stack([jnp.asarray(b @ b.T / b.shape[1])
                      for b in np.split(x, 4, axis=1)])

    def one_run(tag, enabled):
        if enabled:
            monkeypatch.setenv(obs.ENV_DIR, str(tmp_path / "obs"))
        else:
            monkeypatch.setenv(obs.ENV_OBS, "0")
        install(str(tmp_path), "worker_s0")
        prog = sdot_program(covs=covs, engine=DenseConsensus(
            erdos_renyi(4, 0.6, seed=1)), r=2, t_outer=8, t_c=8)
        mgr = CheckpointManager(str(tmp_path / f"ckpt_{tag}"))
        res = run_chunked(prog, mgr, chunk_size=3)
        obs.get_journal().close()
        monkeypatch.delenv(obs.ENV_DIR, raising=False)
        monkeypatch.delenv(obs.ENV_OBS, raising=False)
        return np.asarray(res.q_nodes)

    q_traced = one_run("on", enabled=True)
    q_plain = one_run("off", enabled=False)
    np.testing.assert_array_equal(q_traced, q_plain)
    recs = merge_journals(str(tmp_path / "obs"))
    assert any(r["name"] == "chunk" for r in recs)
    assert any(r["name"] == "ckpt_save" and r["kind"] == "span"
               for r in recs)
