"""SparseW / ELL-SpMM gossip: representation, kernels, engine seams.

The contract under test: a sparse engine is a drop-in replacement for the
dense einsum engine over the SAME graph and weights — every algorithm in
the zoo (fused and eager), the netfault layer, and chunked resume must
agree with the dense path to f32 tolerance (and the realized fault MASKS
must match exactly, since the sparse round gathers the same pre-sampled
draws at its ELL slots).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology as topo
from repro.core.consensus import DenseConsensus, SparseConsensus, gossip_mix
from repro.core.metrics import CommLedger
from repro.core.sparse import (AUTO_MAX_DENSITY, AUTO_MIN_NODES, SparseW,
                               auto_sparse)
from repro.kernels.ops import ell_spmm, ell_spmm_path
from repro.kernels.ref import ell_spmm_ref, ell_spmm_scan_ref


def _graph(n=24, seed=3):
    return topo.watts_strogatz(n, k=4, p=0.2, seed=seed)


def _principal_angle_f64(q1, q2):
    """Max principal angle between the spans, computed in float64 after
    re-orthonormalization (f32 arccos quantizes angles below ~3e-4)."""
    a = np.linalg.qr(np.asarray(q1, np.float64))[0]
    b = np.linalg.qr(np.asarray(q2, np.float64))[0]
    s = np.linalg.svd(a.T @ b, compute_uv=False)
    return float(np.arccos(np.clip(s, -1.0, 1.0)).max())


# ---------------------------------------------------------------------------
# representation
# ---------------------------------------------------------------------------
def test_from_dense_roundtrip_and_csr():
    g = _graph()
    w = topo.local_degree_weights(g)
    sw = SparseW.from_dense(w, g.adjacency)
    np.testing.assert_allclose(np.asarray(sw.to_dense()), w, atol=1e-7)
    indptr, indices, data = sw.csr()
    assert indptr[-1] == indices.size == data.size
    dense = np.zeros_like(w)
    for i in range(g.n_nodes):
        dense[i, indices[indptr[i]:indptr[i + 1]]] = \
            data[indptr[i]:indptr[i + 1]]
    np.fill_diagonal(dense, np.asarray(sw.diag))
    np.testing.assert_allclose(dense, w, atol=1e-7)
    stats = sw.row_stats()
    assert stats["nnz"] == sw.nnz
    assert stats["row_nnz_max"] == sw.ell_width
    assert 0 < sw.density <= 1


def test_from_dense_rejects_asymmetric():
    w = np.eye(4)
    w[0, 1] = 0.5
    with pytest.raises(ValueError, match="symmetric"):
        SparseW.from_dense(w)


def test_zero_weight_edges_kept_via_adjacency():
    """A real edge whose weight happens to be 0 must stay in the structure
    (fault-model send accounting counts it)."""
    g = topo.ring(6)
    w = topo.local_degree_weights(g).copy()
    w[0, 1] = w[1, 0] = 0.0
    sw = SparseW.from_dense(w, g.adjacency)
    assert sw.nnz == int(g.adjacency.sum()) + 6
    sw2 = SparseW.from_dense(w)          # structure from nonzeros only
    assert sw2.nnz == sw.nnz - 2


def test_mix_matches_dense_and_host():
    g = _graph()
    sw = SparseW.from_graph(g)
    w = np.asarray(sw.to_dense())
    rng = np.random.default_rng(0)
    for shape in [(g.n_nodes,), (g.n_nodes, 7), (g.n_nodes, 3, 2)]:
        z = rng.standard_normal(shape).astype(np.float32)
        want = np.einsum("ij,j...->i...", w, z)
        np.testing.assert_allclose(np.asarray(sw.mix(jnp.asarray(z))), want,
                                   atol=1e-5)
        if z.ndim <= 2:       # mix_host is the matvec/matmat oracle
            np.testing.assert_allclose(sw.mix_host(z), want, atol=1e-5)


def test_stack_and_getitem():
    g1, g2 = topo.ring(10), topo.erdos_renyi(10, 0.4, seed=1)
    s1, s2 = SparseW.from_graph(g1), SparseW.from_graph(g2)
    assert s1.ell_width != s2.ell_width   # forces the widening path
    st = SparseW.stack([s1, s2])
    z = jnp.asarray(np.random.default_rng(1)
                    .standard_normal((10, 4)).astype(np.float32))
    for k, s in enumerate((s1, s2)):
        np.testing.assert_allclose(np.asarray(st[k].mix(z)),
                                   np.asarray(s.mix(z)), atol=1e-6)
    with pytest.raises(ValueError, match="matching"):
        SparseW.stack([s1, SparseW.from_graph(topo.ring(12))])


def test_sparsew_is_pytree():
    sw = SparseW.from_graph(_graph())
    leaves, treedef = jax.tree_util.tree_flatten(sw)
    # 4 ELL children, plus the dense off-diagonal mirror when the graph is
    # past the densify crossover (None contributes no leaf below it)
    assert len(leaves) == 4 + (sw.dense_off is not None)
    sw2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert sw2.n == sw.n and sw2.ell_width == sw.ell_width

    @jax.jit
    def f(w, z):
        return w.mix(z)

    z = jnp.ones((sw.n, 3))
    np.testing.assert_allclose(np.asarray(f(sw, z)), np.asarray(sw.mix(z)),
                               atol=1e-6)


def test_power_iteration_spectral_gap_matches_exact():
    g = _graph(30)
    w = topo.local_degree_weights(g)
    exact = topo.spectral_gap(w, method="exact")
    sw = SparseW.from_dense(w, g.adjacency)
    assert abs(sw.spectral_gap(iters=3000) - exact) < 1e-3
    # the duck-typed seam: spectral_gap(w) accepts the SparseW directly
    assert abs(topo.spectral_gap(sw) - exact) < 1e-3


# ---------------------------------------------------------------------------
# auto-selection policy
# ---------------------------------------------------------------------------
def test_auto_sparse_policy(monkeypatch):
    monkeypatch.delenv("REPRO_SPARSE_GOSSIP", raising=False)
    assert auto_sparse(AUTO_MIN_NODES, AUTO_MAX_DENSITY) is True
    assert auto_sparse(AUTO_MIN_NODES - 1, AUTO_MAX_DENSITY) is False
    assert auto_sparse(AUTO_MIN_NODES, AUTO_MAX_DENSITY * 2) is False
    assert auto_sparse(16, 0.9, sparse=True) is True     # explicit wins
    monkeypatch.setenv("REPRO_SPARSE_GOSSIP", "1")
    assert auto_sparse(16, 0.9) is True
    assert auto_sparse(16, 0.9, sparse=False) is False   # explicit still wins
    monkeypatch.setenv("REPRO_SPARSE_GOSSIP", "0")
    assert auto_sparse(10_000, 0.001) is False


def test_small_dense_engines_stay_dense():
    """The repo's N <= 200 seeded suite must keep the dense einsum."""
    eng = DenseConsensus(topo.erdos_renyi(20, 0.25, seed=0))
    assert not eng.is_sparse
    assert isinstance(eng._w, jnp.ndarray)


# ---------------------------------------------------------------------------
# kernels: pallas (interpret) vs gather vs scan vs dense oracle
# ---------------------------------------------------------------------------
def test_ell_spmm_paths_agree():
    g = _graph(40, seed=9)
    sw = SparseW.from_graph(g)
    w = np.asarray(sw.to_dense())
    z = np.random.default_rng(2).standard_normal((40, 8)).astype(np.float32)
    want = w @ z
    got_gather = ell_spmm(sw.ell_idx, sw.ell_val, sw.diag, jnp.asarray(z),
                          use_pallas=False)
    got_pallas = ell_spmm(sw.ell_idx, sw.ell_val, sw.diag, jnp.asarray(z),
                          use_pallas=True, interpret=True, block_rows=16)
    got_ref = ell_spmm_ref(sw.ell_idx, sw.ell_val, sw.diag, z, z)
    got_scan = ell_spmm_scan_ref(sw.ell_idx, sw.ell_val, sw.diag, z, z)
    for got in (got_gather, got_pallas, got_ref, got_scan):
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


def test_ell_spmm_bf16_quantizes_the_gather_source():
    g = _graph(16)
    sw = SparseW.from_graph(g)
    z = np.random.default_rng(4).standard_normal((16, 5)).astype(np.float32)
    zb = np.asarray(jnp.asarray(z).astype(jnp.bfloat16).astype(jnp.float32))
    # oracle: neighbor messages quantized, own-state diagonal full precision
    want = (np.asarray(sw.diag)[:, None] * z
            + np.einsum("nl,nlk->nk", np.asarray(sw.ell_val),
                        zb[np.asarray(sw.ell_idx)]))
    got = ell_spmm(sw.ell_idx, sw.ell_val, sw.diag, jnp.asarray(z),
                   payload_dtype="bfloat16")
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)
    assert not np.allclose(np.asarray(got),
                           np.asarray(sw.to_dense()) @ z, atol=1e-6)


def test_ell_spmm_path_policy():
    assert ell_spmm_path(100, 4, 8, use_pallas=True) == "pallas"
    assert ell_spmm_path(100, 4, 8, use_pallas=False) == "fallback_gather"
    # huge gather footprint falls back to the slot scan
    assert ell_spmm_path(1 << 20, 64, 64,
                         use_pallas=False) == "fallback_scan"


# ---------------------------------------------------------------------------
# engine seams
# ---------------------------------------------------------------------------
def test_gossip_mix_dispatch():
    g = _graph()
    w = jnp.asarray(topo.local_degree_weights(g), jnp.float32)
    sw = SparseW.from_graph(g)
    z = jnp.asarray(np.random.default_rng(5)
                    .standard_normal((g.n_nodes, 3)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(gossip_mix(w, z)),
                               np.asarray(gossip_mix(sw, z)), atol=1e-5)


def test_engine_equivalence_run_and_debiased():
    g = _graph()
    ed = DenseConsensus(g, sparse=False)
    es = SparseConsensus(g)
    assert not ed.is_sparse and es.is_sparse
    z = jnp.asarray(np.random.default_rng(6)
                    .standard_normal((g.n_nodes, 4)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(ed.run(z, 6)),
                               np.asarray(es.run(z, 6)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(ed.run_debiased(z, 6)),
                               np.asarray(es.run_debiased(z, 6)), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(ed.debias_table(8)),
                               np.asarray(es.debias_table(8)), atol=1e-6)
    # traceable twin == eager on the sparse engine (same jaxpr per round)
    np.testing.assert_array_equal(
        np.asarray(es.run_debiased_scan(z, jnp.int32(6), t_max=6)),
        np.asarray(es.run_debiased(z, 6)))


def test_bf16_payload_requires_sparse_and_halves_ledger_bytes():
    g = _graph()
    with pytest.raises(ValueError, match="sparse"):
        DenseConsensus(g, sparse=False, payload_dtype="bfloat16")
    z = jnp.asarray(np.random.default_rng(7)
                    .standard_normal((g.n_nodes, 4)).astype(np.float32))
    lf, lb = CommLedger(), CommLedger()
    DenseConsensus(g, sparse=True).run_debiased(z, 4, lf)
    DenseConsensus(g, sparse=True,
                   payload_dtype="bfloat16").run_debiased(z, 4, lb)
    assert lb.payload_bytes == lf.payload_bytes / 2.0
    assert lf.scalars == lb.scalars          # same element count moved


def test_sparse_engine_records_metrics():
    from repro.obs import metrics
    reg = metrics()

    def values():
        return {k: v["value"] for k, v in reg.snapshot().items()
                if k.startswith("gossip_")}

    before = values()
    eng = SparseConsensus(_graph())
    after = values()
    assert after["gossip_sparse_nnz"] == eng._w.nnz
    assert 0 < after["gossip_sparse_density"] <= 1
    key = f"gossip_kernel_{ell_spmm_path(eng._w.n, eng._w.ell_width, 1)}_total"
    assert after[key] > before.get(key, 0)


# ---------------------------------------------------------------------------
# zoo equivalence (fused + eager)
# ---------------------------------------------------------------------------
def _psa_problem(n=20, d=12, r=3, seed=5):
    g = _graph(n, seed=1)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d, 30)).astype(np.float32)
    covs = jnp.asarray(np.einsum("nds,nes->nde", x, x) / 30.0)
    m = np.asarray(covs.mean(0))
    q_true = jnp.asarray(np.linalg.eigh(m)[1][:, ::-1][:, :r].copy())
    return g, covs, q_true, r


@pytest.mark.parametrize("fused", [True, False])
def test_sdot_sparse_vs_dense(fused):
    from repro.core.sdot import sdot
    g, covs, q_true, r = _psa_problem()
    kw = dict(covs=covs, r=r, t_outer=10, t_c=8, q_true=q_true, fused=fused)
    rd = sdot(engine=DenseConsensus(g, sparse=False), **kw)
    rs = sdot(engine=SparseConsensus(g), **kw)
    assert _principal_angle_f64(rd.q_nodes[0], rs.q_nodes[0]) <= 1e-5
    np.testing.assert_allclose(rd.error_trace, rs.error_trace, atol=1e-6)


@pytest.mark.parametrize("name", ["dsa", "dpgd", "deepca", "seq_dist_pm"])
def test_baselines_sparse_vs_dense_fused_and_eager(name):
    from repro.core import baselines as bl
    g, covs, q_true, r = _psa_problem()
    fn = getattr(bl, name)
    kw = (dict(iters_per_vec=4, t_c=8) if name == "seq_dist_pm"
          else dict(t_outer=8))
    for fused in (True, False):
        qd, _ = fn(covs, DenseConsensus(g, sparse=False), r, q_true=q_true,
                   fused=fused, **kw)
        qs, _ = fn(covs, SparseConsensus(g), r, q_true=q_true,
                   fused=fused, **kw)
        np.testing.assert_allclose(np.asarray(qd), np.asarray(qs),
                                   atol=1e-5)


@pytest.mark.parametrize("fused", [True, False])
def test_fdot_sparse_vs_dense(fused):
    from repro.core.fdot import fdot
    rng = np.random.default_rng(8)
    dims = [4, 4, 4, 4, 4]
    r = 3
    blocks = [jnp.asarray(rng.standard_normal((di, 40)).astype(np.float32))
              for di in dims]
    xf = np.concatenate([np.asarray(b) for b in blocks], 0)
    q_true = jnp.asarray(
        np.linalg.eigh(xf @ xf.T / 40)[1][:, ::-1][:, :r].copy())
    g = topo.ring(5)
    kw = dict(data_blocks=blocks, r=r, t_outer=6, t_c=10, q_true=q_true,
              fused=fused)
    rd = fdot(engine=DenseConsensus(g, sparse=False), **kw)
    rs = fdot(engine=SparseConsensus(g), **kw)
    np.testing.assert_allclose(np.asarray(rd.q_full), np.asarray(rs.q_full),
                               atol=1e-5)


def test_bdot_sparse_stacked_engines():
    from repro.core.bdot import bdot
    rng = np.random.default_rng(9)
    r = 3
    dims_i, ns_j = [5, 4, 3], [12, 10, 14]
    grid = [[jnp.asarray(rng.standard_normal((di, nj)).astype(np.float32))
             for nj in ns_j] for di in dims_i]
    xb = np.concatenate(
        [np.concatenate([np.asarray(b) for b in row], 1) for row in grid], 0)
    q_true = jnp.asarray(
        np.linalg.eigh(xb @ xb.T / xb.shape[1])[1][:, ::-1][:, :r].copy())
    gi, gj = topo.ring(3), topo.ring(3)
    kw = dict(blocks=grid, r=r, t_outer=5, t_c=10, q_true=q_true)
    rd = bdot(col_engines=[DenseConsensus(gi, sparse=False)] * 3,
              row_engines=[DenseConsensus(gj, sparse=False)] * 3, **kw)
    rs = bdot(col_engines=[SparseConsensus(gi) for _ in range(3)],
              row_engines=[SparseConsensus(gj) for _ in range(3)], **kw)
    assert _principal_angle_f64(rd.q_full, rs.q_full) <= 1e-5
    # mixed dense/sparse per stage has no batched representation
    with pytest.raises(ValueError, match="mixes sparse and dense"):
        bdot(col_engines=[SparseConsensus(gi), SparseConsensus(gi),
                          DenseConsensus(gi, sparse=False)],
             row_engines=[DenseConsensus(gj, sparse=False)] * 3, **kw)


def test_sweep_rejects_sparse_engines():
    from repro.core.sweep import sdot_sweep
    g, covs, q_true, r = _psa_problem()
    with pytest.raises(ValueError, match="sparse"):
        sdot_sweep(covs=covs, engines=[SparseConsensus(g)],
                   schedules=[np.full(4, 4)], r=r, t_outer=4, t_c=4,
                   seeds=[0], q_true=q_true)


# ---------------------------------------------------------------------------
# netfaults: realized masks match the dense engine exactly
# ---------------------------------------------------------------------------
def _fault_setup():
    from repro.core.netfaults import NetFaultModel
    g = _graph()
    fm = NetFaultModel(p_drop=0.15, p_bad=0.1, p_good=0.5, p_corrupt=0.1,
                       corrupt_mode="nan", crash_windows=((3, 0, 2),))
    return g, fm


def test_faulty_sparse_vs_dense_masks_and_values():
    from repro.core.netfaults import FaultyConsensus
    g, fm = _fault_setup()
    z = np.random.default_rng(0).standard_normal((g.n_nodes, 6, 2)) \
        .astype(np.float32)
    ed = FaultyConsensus(g, fm, seed=7, sparse=False)
    es = FaultyConsensus(g, fm, seed=7, sparse=True)
    node_up = fm.node_up(3, g.n_nodes)
    ld, ls = CommLedger(), CommLedger()
    zd, zs = jnp.asarray(z), jnp.asarray(z)
    for it in range(3):
        zd = ed.run_debiased(zd, 5, ledger=ld, node_up=node_up[it])
        zs = es.run_debiased(zs, 5, ledger=ls, node_up=node_up[it])
    assert ld.p2p == ls.p2p          # identical realized fault masks
    rel = np.max(np.abs(np.asarray(zd) - np.asarray(zs))
                 / (np.abs(np.asarray(zd)) + 1e-3))
    assert rel < 1e-5                # same algebra, reordered reductions


def test_faulty_sparse_eager_matches_fused_bitwise():
    from repro.core.netfaults import FaultyConsensus, realized_debias
    g, fm = _fault_setup()
    z = jnp.asarray(np.random.default_rng(1)
                    .standard_normal((g.n_nodes, 5)).astype(np.float32))
    up = jnp.ones((g.n_nodes,), jnp.float32)
    e1 = FaultyConsensus(g, fm, seed=7, sparse=True)
    e2 = FaultyConsensus(g, fm, seed=7, sparse=True)
    f1, f2 = e1.sample_faults(5), e2.sample_faults(5)
    z_fused = e1.run_debiased(z, 5, faults=f1, node_up=up)
    out = e2.run_rounds_eager(z, up, f2)
    np.testing.assert_array_equal(np.asarray(z_fused),
                                  np.asarray(realized_debias(out[0],
                                                             out[1])))
    # ELL-form Gilbert-Elliott state advanced identically
    np.testing.assert_array_equal(np.asarray(e1._ge), np.asarray(out[2]))


def test_faulty_sparse_engine_guards():
    from repro.core.netfaults import FaultyConsensus
    g, fm = _fault_setup()
    with pytest.raises(ValueError, match="fused"):
        FaultyConsensus(g, fm, sparse=True, fused=False)
    with pytest.raises(ValueError, match="sparse"):
        FaultyConsensus(g, fm, sparse=False, payload_dtype="bfloat16")
    eng = FaultyConsensus(g, fm, sparse=True)
    assert eng._ge.shape == (g.n_nodes, eng._w.ell_width)
    eng.reset()
    assert eng._ge.shape == (g.n_nodes, eng._w.ell_width)


def test_sdot_faulty_sparse_vs_dense():
    """The whole-run fused executor with a sparse faulty engine: the
    (N, L) burst state rides the scan carry transparently."""
    from repro.core.netfaults import FaultyConsensus, NetFaultModel
    from repro.core.sdot import sdot
    g, covs, q_true, r = _psa_problem()
    fm = NetFaultModel(p_drop=0.2, p_bad=0.05, p_good=0.5)
    kw = dict(covs=covs, r=r, t_outer=8, t_c=6, q_true=q_true)
    rd = sdot(engine=FaultyConsensus(g, fm, seed=3, sparse=False), **kw)
    rs = sdot(engine=FaultyConsensus(g, fm, seed=3, sparse=True), **kw)
    np.testing.assert_allclose(rd.error_trace, rs.error_trace, atol=1e-5)
    assert _principal_angle_f64(rd.q_nodes[0], rs.q_nodes[0]) <= 1e-4


# ---------------------------------------------------------------------------
# chunked resume: bit-identical on the sparse engine
# ---------------------------------------------------------------------------
def test_sparse_run_chunked_resume_bit_identical(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    from repro.core.runtime import run_chunked, run_monolithic
    from repro.core.sdot import sdot_program
    g, covs, q_true, r = _psa_problem()

    def program():
        return sdot_program(covs=covs, engine=SparseConsensus(g), r=r,
                            t_outer=9, t_c=6, q_true=q_true)

    mono = run_monolithic(program())
    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    run_chunked(program(), mgr, chunk_size=3, max_chunks=2)   # "killed"
    resumed = run_chunked(program(), mgr, chunk_size=3)       # restart
    np.testing.assert_array_equal(np.asarray(mono.q_nodes),
                                  np.asarray(resumed.q_nodes))
    np.testing.assert_array_equal(mono.error_trace, resumed.error_trace)
