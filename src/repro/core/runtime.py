"""Unified executor runtime: one Program protocol, three generic drivers.

PRs 1-4 fused every algorithm in the zoo (S-DOT/SA-DOT, F-DOT, B-DOT, the
five baselines) into whole-run scans, then hand-wired each capability per
family: ``streaming/resume.py`` carried four near-identical chunk drivers,
``core/sweep.py`` re-implemented case/seed vmapping three times, and B-DOT
plus the baselines could not checkpoint at all. This module extracts the
shared shape of all of those executors into one protocol:

    Program = (build_body, operands, statics, xs, q0, key0, tail, ...)

* ``build_body(operands, **statics) -> body`` is a MODULE-LEVEL builder
  (its identity is the jit cache key) returning the unified scan body
  ``body((carry, key), x) -> ((carry', key'), (err, sends, counts))``.
  Sync families thread the key through untouched and emit zero-shaped
  sends/counts; async families split the key per outer iteration and emit
  their realized per-round send/awake counts. ``carry`` is an arbitrary
  pytree (a (N, d, r) iterate for S-DOT, padded slabs for F-DOT/B-DOT, a
  (q, s, mq_prev) triple for DeEPCA, stacked column estimates for the
  sequential-deflation baselines, an (iterate, Gilbert–Elliott edge
  state, step) triple for the net-fault families) — because the carry is
  opaque to the drivers, new families like ``core/netfaults.py``'s
  edge-mask fault programs get chunked resume and sweeping for free.
* ``operands`` is a flat tuple of device arrays closed over by the body —
  weight matrices, debias tables, data stacks, ground truth.
* ``statics`` is a hashable tuple of (name, value) pairs — the static
  configuration (t_max, trace_err, mode, ...) forwarded to ``build_body``.
* ``xs`` is the host-side scan input: a (T,) consensus schedule, a
  flattened (vector, inner-iteration) index, or a (C, T) per-case stack.

Three drivers execute any Program:

* ``run_monolithic`` — the whole run as ONE jitted scan chunk (the default
  execution mode of ``sdot``/``fdot``/``bdot``/the fused baselines, which
  are now thin shims over it);
* ``run_chunked`` — the scan executed ``chunk_size`` iterations at a time
  over a checkpointed ``RunState`` pytree; kill-at-any-chunk-boundary
  resume is BIT-identical to the uninterrupted run (chunking a
  ``lax.scan`` is exact, the RNG key rides in the state, and the async
  ledger is rebuilt from the checkpointed buffers). Because the driver is
  generic, every registered family — including B-DOT and all five
  baselines — is restartable;
* ``run_sweep`` — the same chunk program vmapped over a case x seed grid
  (case-stacked operands via ``Program.case_axes``, per-seed inits in the
  leading axes of ``q0``). Sweeps accept the same ``manager``/
  ``chunk_size`` as ``run_chunked``, so a killed multi-day sweep resumes
  mid-grid from its checkpointed sweep-RunState, bitwise equal to the
  uninterrupted sweep.

The jitted chunk program is shared by ALL of the above: its cache key is
(build_body, statics, case_axes, seeded, shapes), so a monolithic run and a
chunked run of the same Program reuse one compiled program per distinct
chunk length, and repeated runs across Program instances with equal
configuration recompile nothing.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..obs import get_journal
from .metrics import CommLedger

__all__ = ["RunState", "Program", "sync_body", "run_monolithic",
           "run_chunked", "run_sweep", "async_ledger"]


def sync_body(inner):
    """Lift a synchronous outer body ``(carry, x) -> (carry', err)`` into
    the unified scan signature: the RNG key threads through untouched and
    the per-step send/count outputs are zero-shaped (so sync and async
    programs share one RunState layout and one chunk driver)."""

    def body(carry_key, x):
        carry, key = carry_key
        carry, err = inner(carry, x)
        return (carry, key), (err, jnp.zeros(()), jnp.zeros(()))

    return body


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RunState:
    """Everything a fused run needs to continue from a chunk boundary.

    Registered pytree: checkpoints through ``checkpoint/manager.py`` with no
    ad-hoc field plucking, and flows through the jitted chunk programs as a
    native container. Sync runs carry zero-size send/count buffers; async
    runs carry the full (T_o, ...) stacked outputs so the realized ledger
    survives a crash. Sweep programs carry leading (case, seed) lane axes
    on every buffer (and on each leaf of ``q``).
    """

    q: Any                    # algorithm carry pytree (iterate, slabs, ...)
    key: jnp.ndarray          # async RNG carry (zeros for sync runs)
    step: jnp.ndarray         # () int32 — outer iterations completed
    errs: jnp.ndarray         # (lanes..., T_o) error-trace buffer
    sends: jnp.ndarray        # async (lanes..., T_o, *tail) per-round sends
    counts: jnp.ndarray       # async (lanes..., T_o, *tail) awake counts

    def tree_flatten(self):
        return ((self.q, self.key, self.step, self.errs, self.sends,
                 self.counts), None)

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)


@dataclasses.dataclass
class Program:
    """One algorithm family's run, in the form every driver understands.

    Families construct these via their ``*_program`` registrars
    (``core/sdot.sdot_program``, ``core/fdot.fdot_program``,
    ``core/bdot.bdot_program``, ``core/baselines.baseline_program``, and
    the sweep constructors in ``core/sweep.py``), which reuse the exact
    ``_prepare_*`` / outer-body pairs of the monolithic executors — so a
    Program run under any driver starts from literally the same device
    values and steps through literally the same per-iteration math.
    """

    build_body: Callable      # module-level: (operands, **statics) -> body
    operands: Tuple           # flat tuple of device arrays
    statics: Tuple            # hashable ((name, value), ...) for build_body
    xs: np.ndarray            # (T,) or (C, T) host-side scan inputs
    q0: Any                   # initial carry pytree (lanes leading in sweeps)
    key0: Optional[jnp.ndarray] = None   # async RNG key; None -> sync dummy
    tail: Tuple[int, ...] = ()           # per-step sends/counts shape
    case_axes: Optional[Tuple] = None    # per-operand vmap axes (sweeps)
    n_cases: int = 0          # 0 -> no case axis; else leading C on q0/xs
    n_seeds: int = 0          # 0 -> no seed axis; else next S axis on q0
    finalize: Optional[Callable] = None  # (state, done) -> family result
    restored_step: int = 0    # set by the driver: step actually restored
                              # from the manager (0 = fresh start)

    @property
    def t_outer(self) -> int:
        return int(self.xs.shape[-1])

    @property
    def lane_shape(self) -> Tuple[int, ...]:
        lanes = ()
        if self.n_cases:
            lanes += (self.n_cases,)
        if self.n_seeds:
            lanes += (self.n_seeds,)
        return lanes


# ---------------------------------------------------------------------------
# the ONE jitted chunk program (shared by every family and driver)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("build", "statics", "case_axes",
                                    "seeded"))
def _chunk_program(state, operands, xs_chunk, *, build, statics, case_axes,
                   seeded):
    """Advance ``state`` by one jitted scan over ``xs_chunk``.

    The body is constructed inside the trace from the static
    ``(build, statics)`` pair, so the jit cache is keyed on the family +
    configuration rather than on per-run closures — monolithic, chunked,
    and sweep executions of the same Program share compiled programs.
    Optional vmaps lift the same body over the seed axis (carry/key axis 0,
    operands broadcast) and the case axis (per-operand ``case_axes``, the
    scan inputs stacked per case).
    """
    kw = dict(statics)

    def lane(ops, carry, key, xs):
        body = build(ops, **kw)
        (c, k), outs = jax.lax.scan(body, (carry, key), xs)
        return c, k, outs

    fn = lane
    if seeded:
        fn = jax.vmap(fn, in_axes=(tuple(None for _ in operands), 0, 0,
                                   None))
    if case_axes is not None:
        fn = jax.vmap(fn, in_axes=(case_axes, 0, 0, 0))
    carry, key, (errs, sends, counts) = fn(operands, state.q, state.key,
                                           xs_chunk)
    lanes = errs.ndim - 1
    at_errs = (jnp.int32(0),) * lanes + (state.step,)
    at_tail = at_errs + (jnp.int32(0),) * (state.sends.ndim - lanes - 1)
    return RunState(
        q=carry, key=key,
        step=state.step + xs_chunk.shape[-1],
        errs=jax.lax.dynamic_update_slice(state.errs, errs, at_errs),
        sends=jax.lax.dynamic_update_slice(state.sends, sends, at_tail),
        counts=jax.lax.dynamic_update_slice(state.counts, counts, at_tail))


# ---------------------------------------------------------------------------
# state init / restore / drive
# ---------------------------------------------------------------------------
def _init_state(program: Program) -> RunState:
    lanes = program.lane_shape
    t_outer = program.t_outer
    key = (program.key0 if program.key0 is not None
           else jnp.zeros(lanes, jnp.uint32))
    return RunState(
        q=program.q0,
        key=key,
        step=jnp.int32(0),
        errs=jnp.zeros(lanes + (t_outer,), jnp.float32),
        sends=jnp.zeros(lanes + (t_outer,) + program.tail, jnp.float32),
        counts=jnp.zeros(lanes + (t_outer,) + program.tail, jnp.float32),
    )


def _restore_any(manager: Optional[CheckpointManager], like: RunState):
    """Newest restorable snapshot, skipping corrupt/half-written steps.

    A crashed writer can leave the latest step directory unreadable (the
    manager's atomic rename protects against *partial* publishes, but a
    torn disk or an operator cp can still corrupt shards). Walk the steps
    newest-first; the first one that restores wins; none -> fresh start."""
    if manager is None:
        return None
    steps = manager.all_steps()
    for step in reversed(steps):
        try:
            state, _ = manager.restore(like, step=step)
        except Exception:
            continue
        # restore_tree checks tree structure, not shapes — a snapshot from
        # a run with a different t_outer (or engine size) unflattens fine
        # but its buffers are the wrong length; reject it here so stale
        # directories can't silently produce truncated/overwritten traces
        shapes_ok = all(jax.tree.leaves(jax.tree.map(
            lambda a, b: np.shape(a) == np.shape(b), state, like)))
        if shapes_ok:
            return state
    if steps:
        # every snapshot rejected — distinguish "fresh directory" from a
        # probable operator error (e.g. resuming with a different t_outer
        # or engine shape, which changes the RunState buffer shapes)
        warnings.warn(
            f"{len(steps)} checkpoint step(s) in {manager.root} exist but "
            "none restored against this run's RunState shapes — starting "
            "from iteration 0 (wrong t_outer / engine for this directory?)")
    return None


def _drive_chunks(state: RunState, program: Program, chunk_size: int,
                  manager: Optional[CheckpointManager],
                  max_chunks: Optional[int],
                  target_step: Optional[int] = None) -> RunState:
    """The outer chunk loop: scan a chunk, checkpoint, repeat.

    The completed-step counter is mirrored on the host (read from the
    device exactly once, at restore) so chunk programs enqueue back-to-back
    with NO per-chunk device sync — without checkpointing, a chunked run is
    pure dispatch pipelining over the monolithic scan. Saves are async
    (``blocking=False``) so serialization overlaps the next chunk's
    compute; the manager's atomic rename guarantees a kill mid-save leaves
    the previous step intact. ``max_chunks`` lets tests and benchmarks
    simulate a job killed at a chunk boundary. ``target_step`` stops at an
    ABSOLUTE outer step instead of a relative chunk count — the idempotent
    form an incremental caller wants: if the restored state is already at
    (or past) the target, nothing runs, so re-executing a crashed
    increment can never double-advance the run."""
    t_outer = program.t_outer
    seeded = program.n_seeds > 0
    case_axes = program.case_axes if program.n_cases else None
    step = int(state.step)                   # the one host sync (restore)
    done = 0
    # Out-of-band tracing: journal writes are host-side appends with no
    # device sync, so the dispatch pipelining above is preserved. Per-chunk
    # "dispatch_s" is enqueue time only; a jit-cache-size delta separates
    # compile chunks from steady-state ones.
    j = get_journal()
    step0, t_start = step, time.monotonic()
    while step < t_outer:
        if max_chunks is not None and done >= max_chunks:
            break
        if target_step is not None and step >= target_step:
            break
        length = min(chunk_size, t_outer - step)
        if target_step is not None:
            length = min(length, target_step - step)
        xs_chunk = jnp.asarray(program.xs[..., step:step + length],
                               jnp.int32)
        if j.enabled:
            n_compiled, t0 = _chunk_program._cache_size(), time.monotonic()
        state = _chunk_program(state, program.operands, xs_chunk,
                               build=program.build_body,
                               statics=program.statics,
                               case_axes=case_axes, seeded=seeded)
        step += length
        if j.enabled:
            j.event("chunk", phase="runtime", step=step, length=length,
                    dispatch_s=round(time.monotonic() - t0, 6),
                    compiled=_chunk_program._cache_size() > n_compiled)
        if manager is not None:
            manager.save(step, state, blocking=False)
        done += 1
    if manager is not None:
        manager.wait()
    if j.enabled and step > step0:
        wall = time.monotonic() - t_start    # incl. the final save barrier
        j.event("chunks_done", phase="runtime", steps=step - step0,
                chunks=done, wall_s=round(wall, 6),
                steps_per_s=round((step - step0) / wall, 3) if wall > 0
                else None)
    return state


def _run(program: Program, manager: Optional[CheckpointManager],
         chunk_size: int, max_chunks: Optional[int],
         target_step: Optional[int] = None):
    like = _init_state(program)
    restored = _restore_any(manager, like)
    # the step the run ACTUALLY resumed from (a corrupt/stale newest
    # checkpoint falls back, so this can differ from manager.latest_step())
    program.restored_step = int(restored.step) if restored is not None else 0
    state = restored if restored is not None else like
    state = _drive_chunks(state, program, chunk_size, manager, max_chunks,
                          target_step)
    done = int(state.step)
    if program.finalize is None:
        return state
    return program.finalize(state, done)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------
def run_monolithic(program: Program):
    """The whole run as one jitted scan chunk (the fused default path)."""
    return _run(program, None, max(program.t_outer, 1), None)


def run_chunked(program: Program, manager: Optional[CheckpointManager],
                chunk_size: int = 10, max_chunks: Optional[int] = None,
                target_step: Optional[int] = None):
    """The run executed ``chunk_size`` iterations at a time with the
    RunState checkpointed through ``manager`` at every chunk boundary.
    Resume from a kill at any boundary is bit-identical to the
    uninterrupted run; ``max_chunks`` simulates the kill. ``target_step``
    stops at an absolute outer step (idempotent incremental execution —
    the serving layer's warm re-solve advances a few chunks per service
    tick this way while the incumbent subspace keeps answering queries)."""
    return _run(program, manager, chunk_size, max_chunks, target_step)


def run_sweep(program: Program, manager: Optional[CheckpointManager] = None,
              chunk_size: Optional[int] = None,
              max_chunks: Optional[int] = None):
    """Execute a case x seed sweep Program (same driver, vmapped body).

    Without ``manager``/``chunk_size`` this is one compiled program and one
    device call — the monolithic sweep. With them, the sweep-RunState
    (lane axes on every buffer) is checkpointed at chunk boundaries so a
    killed sweep worker resumes mid-grid, bitwise equal to the
    uninterrupted sweep."""
    if not (program.n_cases and program.n_seeds):
        raise ValueError("run_sweep needs a Program with case and seed axes"
                         " (use run_monolithic/run_chunked for single runs)")
    size = chunk_size if chunk_size is not None else max(program.t_outer, 1)
    return _run(program, manager, size, max_chunks)


# ---------------------------------------------------------------------------
# ledger reconstruction
# ---------------------------------------------------------------------------
def async_ledger(sched_np, sends, counts, payload_fn, slices) -> CommLedger:
    """Rebuild the realized async ledger from the RunState buffers."""
    ledger = CommLedger()
    sends_np = np.asarray(sends, np.float64)
    counts_np = np.asarray(counts)
    total = float(sends_np.sum())
    ledger.p2p += total
    ledger.matrices += total
    ledger.scalars += payload_fn(sends_np)
    for t in range(len(sched_np)):
        for sl, rounds in slices(int(sched_np[t])):
            ledger.log_awake_rounds(counts_np[t][sl][:rounds])
    return ledger
