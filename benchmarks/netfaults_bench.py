"""Network-fault degradation curves: self-healing gossip vs naive gossip.

``core/netfaults.py`` makes every realized gossip round *self-healing*:
the surviving symmetric edge mask renormalizes the mixing matrix (dropped
mass absorbed into the diagonal, so the round stays doubly stochastic) and
the realized mixing product debiases the result. This benchmark measures
what that buys on S-DOT over ER(16) under three fault families, against
an UNCORRECTED comparator that models what naive gossip does under the
same faults — dropped contributions are simply lost (the nominal weights
are applied with dead links zeroed), so the realized mixing is no longer
doubly stochastic and every round re-weights the nodes' data by a random
biased mixture. A per-node scalar error would be washed out by the QR
step; the uncorrected bias is NOT a per-node scalar, so it shows up as an
error plateau orders of magnitude above the fault-free floor:

* **drop curve** — i.i.d. link-drop rate 0 -> 0.4: the self-healing run
  tracks the fault-free error floor (acceptance: within 1e-6 at drop rate
  0.2) while the uncorrected plateau is >= 10x above it;
* **burst curve** — Gilbert-Elliott bursty outages at a FIXED stationary
  down-fraction (0.2) with mean burst length 1 -> 10 rounds: burstiness
  at equal average loss costs extra iterations, self-healing still
  converges;
* **crash curve** — 0 -> 4 of 16 nodes crash for a mid-run window and
  rejoin: realized renormalization over the surviving clique keeps the
  remaining nodes converging; the comm ledger shows the saved sends.

Every row also reports iterations-to-eps and the realized per-node P2P
cost from the engine's CommLedger (faults make realized sends CHEAPER
than nominal — dropped links move no payload). Walltime overhead of the
fault layer is measured with interleaved best-of timing (this container
jitters +-20%).

Usage:
    PYTHONPATH=src python -m benchmarks.netfaults_bench [--smoke]

Writes BENCH_netfaults.json (or .smoke.json) next to the repo root; the
full run asserts the acceptance inequalities above, the smoke run asserts
the 3-fault scenario (drops + bursts + crash) keeps the self-healing
error strictly below the uncorrected one.
"""
from __future__ import annotations

import json
import pathlib
import sys

import jax
import numpy as np

from repro.core.consensus import consensus_schedule, local_degree_weights
from repro.core.metrics import mean_subspace_error
from repro.core.netfaults import FaultyConsensus, NetFaultModel
from repro.core.sdot import sdot
from repro.core.topology import erdos_renyi

from .common import interleaved_best_of, sample_problem

N, R, D = 16, 4, 20
EPS = 1e-6          # iterations-to-eps threshold


def uncorrected_sdot(covs, q_true, graph, model: NetFaultModel, t_outer,
                     t_c, seed):
    """Naive gossip under the same fault model: NO renormalization.

    Dead links are zeroed in the nominal weight matrix and their mass is
    LOST (rows no longer sum to 1); crashed nodes contribute nothing and
    freeze their iterate. Pure NumPy, seeded — the benchmark's control
    arm, deliberately kept out of the production module.
    """
    covs = np.asarray(covs, np.float32)
    q_true = np.asarray(q_true, np.float32)
    n = graph.n_nodes
    w = np.asarray(local_degree_weights(graph), np.float32)
    adj = np.asarray(graph.adjacency, bool)
    off = ~np.eye(n, dtype=bool)
    w_diag = np.diag(np.diag(w))
    node_up = np.asarray(model.node_up(t_outer, n)) > 0
    rng = np.random.default_rng(seed)
    q = np.tile(np.linalg.qr(
        rng.standard_normal((covs.shape[1], q_true.shape[1])))[0]
        .astype(np.float32), (n, 1, 1))
    ge = np.zeros((n, n), bool)
    errs = []
    for t in range(t_outer):
        up = node_up[t]
        z = np.einsum("nij,njr->nir", covs, q).astype(np.float32)
        for _ in range(t_c):
            u = rng.random((n, n))
            u = np.triu(u, 1)
            u = u + u.T
            ub = rng.random((n, n))
            ub = np.triu(ub, 1)
            ub = ub + ub.T
            ge = np.where(ge, ub >= model.p_good, ub < model.p_bad)
            mask = (adj & ~ge & (u >= model.p_drop)
                    & up[:, None] & up[None, :])
            w_unc = np.where(off & mask, w, 0.0) + w_diag
            z = np.einsum("ij,jdr->idr", w_unc.astype(np.float32), z)
        q_new = np.stack([np.linalg.qr(z[i])[0] for i in range(n)])
        q = np.where(up.reshape((-1, 1, 1)), q_new, q)
        errs.append(float(mean_subspace_error(q_true, q)))
    return np.asarray(errs)


def _iters_to_eps(trace, eps=EPS):
    hit = np.nonzero(np.asarray(trace) <= eps)[0]
    return int(hit[0]) + 1 if hit.size else None


def _run_pair(covs, q_true, graph, model, t_outer, t_c, seed):
    """(self-healing trace + ledger, uncorrected trace) under one model."""
    sched = consensus_schedule("const", t_outer, t_max=t_c)
    eng = FaultyConsensus(graph=graph, faults=model, seed=seed)
    res = sdot(covs=covs, engine=eng, r=R, t_outer=t_outer, schedule=sched,
               q_true=q_true)
    unc = uncorrected_sdot(covs, q_true, graph, model, t_outer, t_c, seed)
    return res, unc


def _row(case, res, unc, ff_tail, t_outer):
    return {
        "case": case,
        "healed_err": float(res.error_trace[-1]),
        "uncorrected_err": float(unc[-1]),
        "faultfree_err": ff_tail,
        "healed_iters_to_eps": _iters_to_eps(res.error_trace),
        "uncorrected_iters_to_eps": _iters_to_eps(unc),
        "healed_p2p_per_node_k": round(res.ledger.per_node_p2p(N) / 1e3, 3),
        "uncorrected_over_floor_x": round(float(unc[-1]) / max(ff_tail,
                                                               1e-12), 1),
    }


def run_bench(smoke: bool = False):
    t_outer, t_c = (12, 10) if smoke else (60, 20)
    covs, q_true = sample_problem(d=D, r=R, n_nodes=N, n_per=300, gap=0.7,
                                  seed=0)
    g = erdos_renyi(N, 0.4, seed=1)
    sched = consensus_schedule("const", t_outer, t_max=t_c)
    ff = sdot(covs=covs, engine=FaultyConsensus(graph=g), r=R,
              t_outer=t_outer, schedule=sched, q_true=q_true)
    ff_tail = float(ff.error_trace[-1])
    ff_p2p = round(ff.ledger.per_node_p2p(N) / 1e3, 3)
    results = {"faultfree": {"err": ff_tail, "p2p_per_node_k": ff_p2p,
                             "iters_to_eps": _iters_to_eps(ff.error_trace)}}

    if smoke:
        # the CI scenario: all three fault families at once; self-healing
        # must beat naive gossip outright
        model = NetFaultModel(p_drop=0.2, p_bad=0.05, p_good=0.5,
                              crash_windows=((0, 3, 3),))
        res, unc = _run_pair(covs, q_true, g, model, t_outer, t_c, seed=7)
        row = _row("smoke/drop0.2+burst+crash1", res, unc, ff_tail, t_outer)
        assert row["healed_err"] < row["uncorrected_err"], row
        results["scenario"] = row
        return results

    # -- drop curve ------------------------------------------------------
    drop = []
    for p in (0.1, 0.2, 0.3, 0.4):
        model = NetFaultModel(p_drop=p)
        res, unc = _run_pair(covs, q_true, g, model, t_outer, t_c, seed=7)
        drop.append(_row(f"drop/p={p}", res, unc, ff_tail, t_outer))
    results["drop_curve"] = drop

    # acceptance at drop rate 0.2: self-healing reaches the fault-free
    # floor; naive gossip plateaus an order of magnitude (or more) above
    r02 = next(r for r in drop if r["case"] == "drop/p=0.2")
    assert abs(r02["healed_err"] - ff_tail) <= 1e-6, r02
    assert r02["uncorrected_err"] >= 10.0 * max(ff_tail, 1e-12), r02

    # -- burst curve (fixed stationary down-fraction 0.2) ----------------
    burst = []
    for mean_len in (1, 2, 5, 10):
        p_good = 1.0 / mean_len
        p_bad = 0.25 * p_good          # pi_bad = p_bad/(p_bad+p_good) = 0.2
        model = NetFaultModel(p_bad=p_bad, p_good=p_good)
        res, unc = _run_pair(covs, q_true, g, model, t_outer, t_c, seed=7)
        row = _row(f"burst/len={mean_len}", res, unc, ff_tail, t_outer)
        row["p_bad"], row["p_good"] = round(p_bad, 4), round(p_good, 4)
        burst.append(row)
    results["burst_curve"] = burst

    # -- crash curve -----------------------------------------------------
    crash = []
    for k in (1, 2, 4):
        wins = tuple((i, t_outer // 4, t_outer // 4) for i in range(k))
        model = NetFaultModel(crash_windows=wins)
        res, unc = _run_pair(covs, q_true, g, model, t_outer, t_c, seed=7)
        crash.append(_row(f"crash/{k}of{N}", res, unc, ff_tail, t_outer))
    results["crash_curve"] = crash

    # -- fault-layer walltime overhead (interleaved best-of) -------------
    model = NetFaultModel(p_drop=0.2)
    f_eng = FaultyConsensus(graph=g, faults=model, seed=7)
    run_ff = lambda: sdot(covs=covs, engine=FaultyConsensus(graph=g), r=R,
                          t_outer=t_outer, schedule=sched, q_true=q_true)
    run_f = lambda: sdot(covs=covs, engine=f_eng, r=R, t_outer=t_outer,
                         schedule=sched, q_true=q_true)
    run_ff(), run_f()                             # compile both
    best, _ = interleaved_best_of(
        [("faultfree", run_ff), ("faulty", run_f)], repeats=5,
        sync=lambda r: jax.block_until_ready(r.q_nodes))
    results["walltime"] = {
        "faultfree_ms": round(best["faultfree"] * 1e3, 2),
        "faulty_ms": round(best["faulty"] * 1e3, 2),
        "fault_layer_overhead_x": round(best["faulty"]
                                        / best["faultfree"], 2),
    }
    return results


def main():
    smoke = "--smoke" in sys.argv
    out = {
        "bench": "netfaults",
        "scale": {"n_nodes": N, "r": R, "d": D,
                  "topology": "er(16, p=0.4, seed=1)"},
        "smoke": smoke,
        "backend": jax.default_backend(),
        "results": run_bench(smoke=smoke),
    }
    print(json.dumps(out, indent=2))
    name = "BENCH_netfaults.smoke.json" if smoke else "BENCH_netfaults.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
