"""Shared ragged-N utilities for the vmapped sweep engines.

The Table-II connectivity axis mixes node counts (ER N=10 next to ring
N=20).  To stack such cases into ONE vmapped program every case is padded to
N_max with nodes that provably cannot perturb the real ones:

* **weights** — W becomes block-diag(W, I).  A real node's gossip row has
  exact zeros against every padded column, so padded nodes never mix with
  real ones; the padded subgraph is a set of isolated self-loops.
* **covariances** (sample-partitioned algorithms) — padded nodes get
  *identity* covariances, NOT zeros: a zero cov would drive the padded
  iterate into the Cholesky of a singular Gram and the resulting NaNs would
  poison the padded lanes.  A node mask keeps the padded estimates out of
  the error trace (``metrics.mean_subspace_error`` /
  ``baselines``' masked node mean).
* **feature slabs** (feature-partitioned algorithms) — padded nodes get
  *all-zero* slabs.  Zero slabs are self-masking: they contribute exactly
  nothing to the partial products, the consensus sums (their W rows are
  identity), the Gram matrices (the 1e-10 jitter keeps the Cholesky
  finite), and the error cross term — so no node mask is needed and the
  padded trace is bit-comparable to the unpadded per-case run.

These helpers were grown inside ``sdot_sweep`` first (PR 3) and are now the
shared substrate of ``sdot_sweep``, ``fdot_sweep``, and ``baseline_sweep``.
"""
from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = [
    "pad_weights_identity",
    "pad_covs_identity",
    "pad_zero_nodes",
    "case_node_masks",
    "broadcast_per_case",
]


def pad_weights_identity(w: np.ndarray, n_max: int) -> np.ndarray:
    """block-diag(W, I): identity-padding rows keep padded nodes isolated
    (a real node's row has exact zeros against every padded column, so the
    padded subgraph never perturbs the real gossip)."""
    out = np.eye(n_max)
    out[:w.shape[0], :w.shape[0]] = w
    return out


def pad_covs_identity(covs: jnp.ndarray, n_max: int) -> jnp.ndarray:
    """Pad a (N, d, d) cov stack to (N_max, d, d) with identity covariances
    (NOT zeros: a zero cov would drive the padded iterate to the Cholesky of
    a singular Gram and the resulting NaNs would poison the padded lanes)."""
    pad = n_max - covs.shape[0]
    if pad == 0:
        return covs
    d = covs.shape[1]
    eye = jnp.broadcast_to(jnp.eye(d, dtype=covs.dtype), (pad, d, d))
    return jnp.concatenate([covs, eye], axis=0)


def pad_zero_nodes(stack: jnp.ndarray, n_max: int) -> jnp.ndarray:
    """Pad the leading node axis of a slab stack with all-zero entries.

    Used by the ragged-N F-DOT sweep: a zero feature slab is exact padding
    for every product in Alg. 2 (see the module docstring)."""
    pad = n_max - stack.shape[0]
    if pad == 0:
        return stack
    return jnp.pad(stack, ((0, pad),) + ((0, 0),) * (stack.ndim - 1))


def case_node_masks(n_list: Sequence[int], n_max: int) -> jnp.ndarray:
    """(C, N_max) float mask: 1.0 for real nodes, 0.0 for padded ones."""
    return jnp.asarray(
        np.arange(n_max)[None, :] < np.asarray(list(n_list))[:, None],
        jnp.float32)


def broadcast_per_case(items, n_cases: int, what: str) -> List:
    """Zip-broadcast a per-case list against the case axis (1 -> n_cases)."""
    items = list(items)
    if len(items) == 1:
        items = items * n_cases
    if len(items) != n_cases:
        raise ValueError(f"per-case {what} must zip-broadcast with the "
                         f"cases: got {len(items)} for {n_cases} cases")
    return items
