"""End-to-end driver — LM training with the paper's technique in the loop.

Trains a ~100M-param qwen2-family model with the production trainer
(checkpoint/restart, async saves) and PSA-compressed cross-pod gradient
reduction: each pod is one "node" of the paper's network, S-DOT maintains
the shared gradient subspace, and cross-pod traffic shrinks ~d/r.

CPU note: the default flags train a scaled-down model for 60 steps so the
example finishes in minutes; pass --full-100m --steps 300 on real hardware.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/train_lm_psa_compress.py
"""
import argparse
import dataclasses
import os
import sys
import tempfile

# multi-pod needs >= 4 placeholder devices BEFORE jax initializes
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

from repro.configs import get_arch, reduced_config  # noqa: E402
from repro.launch.train import train  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full-100m", action="store_true",
                    help="~100M-param config (use on real hardware)")
    ap.add_argument("--ckpt-dir", default="")
    args_in = ap.parse_args()

    ckpt = args_in.ckpt_dir or tempfile.mkdtemp(prefix="psa_train_")

    # assemble trainer args (same namespace the CLI builds)
    targs = argparse.Namespace(
        arch="qwen2-7b", reduced=True, mesh="multipod",
        steps=args_in.steps, batch=4, seq=64, lr=1e-3, warmup=10,
        seed=0, data_seed=0, psa=True, psa_rank=16,
        ckpt_dir=ckpt, ckpt_every=20, keep_last=2, log_every=10)

    if args_in.full_100m:
        # ~100M params: d_model=768, 12 layers, vocab 32k
        import repro.launch.train as T
        base = get_arch("qwen2-7b")
        cfg100 = dataclasses.replace(
            reduced_config(base), d_model=768, n_layers=12, n_heads=12,
            n_kv_heads=4, d_ff=2048, vocab_size=32_000, head_dim=None)
        T.get_arch = lambda _aid: cfg100           # inject
        targs.reduced = False
        targs.batch, targs.seq = 8, 512

    out = train(targs)
    print(f"\ntrain summary: {out}")
    assert out["last_loss"] < out["first_loss"], "loss must decrease"
    print(f"checkpoints in {ckpt}: restart the same command to auto-resume")
    print("OK")


if __name__ == "__main__":
    main()
