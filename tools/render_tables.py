"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
artifacts under experiments/.  Usage:
    PYTHONPATH=src python tools/render_tables.py > /tmp/tables.md
"""
import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "experiments")


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table():
    print("| arch | shape | mesh | status | compile_s | arg bytes/dev | "
          "temp bytes/dev | wire bytes/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for f in sorted(glob.glob(os.path.join(ROOT, "dryrun", "*.json"))):
        d = json.load(open(f))
        mesh = "2x16x16" if d.get("multi_pod") else "16x16"
        if d["status"] != "ok":
            print(f"| {d['arch']} | {d['shape']} | {mesh} | SKIPPED: "
                  f"{d.get('reason','')} | | | | |")
            continue
        mem = d.get("memory") or {}
        print(f"| {d['arch']} | {d['shape']} | {mesh} | ok | "
              f"{d['compile_s']} | {fmt_bytes(mem.get('argument_bytes'))} | "
              f"{fmt_bytes(mem.get('temp_bytes'))} | "
              f"{fmt_bytes(d['collectives']['wire_bytes_per_dev'])} |")


MOVE_HINTS = {
    ("compute",): "already compute-bound — larger per-chip batch or bf16 "
                  "throughput tricks",
    ("memory", "train"): "less remat recompute traffic / fused optimizer "
                         "update (bytes are CPU-HLO upper bounds)",
    ("memory", "decode"): "KV/state cache quantization (int8 kv_quant) and "
                          "batched-request decode to amortize weight reads",
    ("memory", "prefill"): "activation layout fusion; flash-attention Pallas "
                           "path on real TPU",
    ("collective", "train"): "sharding that divides head/expert counts "
                             "evenly; reduce-scatter-based ZeRO; PSA "
                             "cross-pod compression",
    ("collective", "prefill"): "head-aligned TP sharding; sequence "
                               "parallelism for norms",
    ("collective", "decode"): "replicate small weights instead of TP-"
                              "sharding them at batch-1 compute intensity",
}


def roofline_table():
    print("| arch | shape | t_compute_s | t_memory_s | t_collective_s | "
          "dominant | MODEL_FLOPs/HLO_FLOPs | MFU@bound | what moves the "
          "dominant term |")
    print("|---|---|---|---|---|---|---|---|---|")
    for f in sorted(glob.glob(os.path.join(ROOT, "roofline", "*.json"))):
        d = json.load(open(f))
        if d.get("status") != "ok":
            print(f"| {d['arch']} | {d['shape']} | SKIPPED (full-attention "
                  f"500k) | | | | | | |")
            continue
        t = d["roofline"]
        kind = ("train" if d["shape"].startswith("train") else
                "prefill" if d["shape"].startswith("prefill") else "decode")
        hint = MOVE_HINTS.get((t["dominant"], kind)) or \
            MOVE_HINTS.get((t["dominant"],))
        uf = d.get("useful_flops_frac")
        mfu = d.get("mfu_at_bound")
        print(f"| {d['arch']} | {d['shape']} | {t['t_compute_s']:.3f} | "
              f"{t['t_memory_s']:.3f} | {t['t_collective_s']:.3f} | "
              f"**{t['dominant']}** | {uf:.2f} | {mfu*100:.2f}% | {hint} |")


if __name__ == "__main__":
    print("### Dry-run table (80 cells)\n")
    dryrun_table()
    print("\n### Roofline table (40 cells, single-pod 16x16)\n")
    roofline_table()
