"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0: xLSTM blocks carry their own up/down projections (mLSTM factor 2,
sLSTM gated FFN factor 4/3). mLSTM uses fixed 128-dim heads (DESIGN.md);
the pool's "4H (GQA kv=4)" is attention-family metadata with no attention
blocks present. Sub-quadratic => runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50_304,
    block_pattern=("mlstm", "slstm"),
    subquadratic=True,
)
