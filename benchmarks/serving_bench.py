"""Serving-layer benchmark: warm re-solve payoff + query tail under chaos.

Three acceptance measurements for the always-fresh PSA serving layer
(serving/service.py):

1. **Warm vs cold reconvergence** — after the drifting stream's seeded
   spectrum shift, a re-solve warm-started from the incumbent subspace
   (solved on pre-shift covariances) must reach the serving-grade residual
   in **< 0.5x** the outer iterations of a cold random start, per seed and
   in aggregate.  This is the number that justifies drift-triggered warm
   re-solves over periodic cold solves.  Walltime-to-target is measured
   alongside (interleaved, best-of) to price the same win in seconds.

2. **Tick phase walltimes** — the three phases a service tick interleaves
   (sketch ingest, one chunked re-solve increment with its atomic
   checkpoint, one batched query drain) measured individually: shows the
   re-solve increment dominates and the query path rides along ~free.

3. **Query tail latency under chaos** — a full fault-free service run vs
   the same config under a ``delay_query`` fault plan: the chaos run must
   serve the *bit-identical* subspace trajectory (delays never touch
   state), degrade only the tail (expired > 0, answered latencies still
   sub-deadline), and a burst 4x over queue capacity must shed explicitly
   rather than block.

Usage:
    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
    PYTHONPATH=src python -m benchmarks.run serving_bench

Writes BENCH_serving.json (or .smoke.json) next to the repo root.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import sys
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.consensus import DenseConsensus
from repro.core.linalg import eigh_topr, orthonormal_init
from repro.core.metrics import subspace_error
from repro.core.runtime import run_chunked, run_monolithic
from repro.core.sdot import sdot_program
from repro.core.topology import erdos_renyi
from repro.data.pipeline import drifting_eigengap_stream
from repro.serving.query import QueryPath
from repro.serving.service import PSAService, ServiceConfig, service_summary
from repro.streaming.chaos import FaultPlan
from repro.streaming.ingest import StreamingIngestor

from .common import Row, interleaved_best_of

D, R, N = 12, 3, 4
T_C = 12
# serving-grade residual: the drift detector re-solves at residual ~0.05
# (one post-shift batch in the blend), so reconverging to well under that
# is what "fresh again" means; 5e-3 is 10x under the trigger point.
TARGET = 5e-3


def _shifted_problem(seed: int):
    """Pre-shift covs (what the incumbent was solved on) and covs frozen
    one batch past the shift (what the drift-triggered re-solve faces)."""
    batch_fn, _, _ = drifting_eigengap_stream(
        D, R, 0.6, shift_at=6, seed=seed, lead=3.0, shift_lead=6.0)
    ing = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn, batch_size=32)
    ing.ingest(6)
    covs_pre = ing.cov_stack()
    ing.ingest(1)
    covs_post = ing.cov_stack()
    return covs_pre, covs_post


def _prog(covs, engine, q_init, q_true=None, t_outer=12):
    return sdot_program(covs=covs, engine=engine, r=R, t_outer=t_outer,
                        t_c=T_C, q_init=q_init, q_true=q_true)


def bench_reconverge(seed: int, repeats: int):
    """Iterations-to-target and walltime-to-target, warm vs cold."""
    engine = DenseConsensus(erdos_renyi(N, 0.6, seed=1))
    covs_pre, covs_post = _shifted_problem(seed)
    _, q_true = eigh_topr(covs_post.sum(0), R)
    warm_q = run_monolithic(_prog(
        covs_pre, engine, orthonormal_init(jax.random.PRNGKey(3), D, R),
        t_outer=25)).q_nodes.mean(axis=0)
    drift = float(subspace_error(q_true, warm_q))

    t_long = 40
    cold_trace = run_monolithic(_prog(
        covs_post, engine, orthonormal_init(jax.random.PRNGKey(4), D, R),
        q_true=q_true, t_outer=t_long)).error_trace
    warm_trace = run_monolithic(_prog(
        covs_post, engine, warm_q, q_true=q_true,
        t_outer=t_long)).error_trace
    assert cold_trace.min() < TARGET and warm_trace.min() < TARGET
    it_cold = int(np.argmax(cold_trace < TARGET)) + 1
    it_warm = int(np.argmax(warm_trace < TARGET)) + 1

    # walltime to the same target: each variant runs exactly the outer
    # iterations it needs, interleaved so machine noise hits both equally
    cold_run = lambda: run_monolithic(_prog(
        covs_post, engine, orthonormal_init(jax.random.PRNGKey(4), D, R),
        t_outer=it_cold))
    warm_run = lambda: run_monolithic(_prog(
        covs_post, engine, warm_q, t_outer=it_warm))
    sync = lambda out: jax.block_until_ready(out.q_nodes)
    cold_run(), warm_run()                           # warmup compile
    best, _ = interleaved_best_of(
        [("cold", cold_run), ("warm", warm_run)], repeats, sync=sync)

    return {
        "case": f"reconverge/seed{seed}",
        "drift_at_trigger": round(drift, 4),
        "target_residual": TARGET,
        "iters_cold": it_cold,
        "iters_warm": it_warm,
        "iter_ratio": round(it_warm / it_cold, 3),
        "cold_ms": round(best["cold"] * 1e3, 2),
        "warm_ms": round(best["warm"] * 1e3, 2),
    }


def bench_tick_phases(repeats: int):
    """The three phases of a service tick, priced individually."""
    engine = DenseConsensus(erdos_renyi(N, 0.6, seed=1))
    batch_fn, _, _ = drifting_eigengap_stream(
        D, R, 0.6, shift_at=6, seed=0, lead=3.0, shift_lead=6.0)
    ing = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn, batch_size=32)
    ing.ingest(7)
    covs = ing.cov_stack()
    q_init = orthonormal_init(jax.random.PRNGKey(7), D, R)
    chunk = 3
    ckpt_dir = tempfile.mkdtemp(prefix="bench_serve_ckpt_")

    def ingest_phase():
        return ing.ingest(1)

    def resolve_phase():
        # one increment: advance the re-solve by one chunk from a restored
        # snapshot, atomic checkpoint included — exactly what a service
        # tick pays while a re-solve is active
        shutil.rmtree(ckpt_dir, ignore_errors=True)
        mgr = CheckpointManager(ckpt_dir, keep_last=2)
        return run_chunked(_prog(covs, engine, q_init, t_outer=12), mgr,
                           chunk_size=chunk, target_step=chunk)

    qp = QueryPath(capacity=64, max_batch=8, deadline_s=10.0)
    qp.warmup(D, R)
    served = np.asarray(q_init, np.float32)
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((8, D)).astype(np.float32)

    def query_phase():
        for j in range(8):
            qp.submit(j, xs[j])
        return qp.process(served)

    ingest_phase(), resolve_phase(), query_phase()   # warmup compile
    try:
        best, _ = interleaved_best_of(
            [("ingest", ingest_phase), ("resolve", resolve_phase),
             ("query", query_phase)], repeats)
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    return {
        "case": "tick_phases",
        "ingest_batch_ms": round(best["ingest"] * 1e3, 2),
        "resolve_increment_ms": round(best["resolve"] * 1e3, 2),
        "query_drain8_ms": round(best["query"] * 1e3, 2),
        "note": "resolve increment = one chunk (3 outer iters) advanced "
                "from a restored snapshot + atomic checkpoint",
    }


def _service_cfg(total_ticks: int) -> ServiceConfig:
    return ServiceConfig(
        d=10, r=2, n_nodes=4, batch_size=24, gap=0.6, lead=3.0,
        shift_lead=6.0, shift_at=5, holdout_m=256, total_ticks=total_ticks,
        t_outer=8, t_c=10, resolve_chunk=2, chunks_per_tick=2,
        topology={"kind": "er", "n": 4, "p": 0.6, "seed": 1},
        warmup_ticks=1, drift_threshold=0.3, drift_warmup=2,
        queries_per_tick=4, max_batch=4, staleness_bound=12, keep_last=3)


def bench_query_chaos(total_ticks: int):
    """Full service runs: fault-free vs delay_query chaos, + burst shed."""
    cfg = _service_cfg(total_ticks)
    plan = FaultPlan(seed=0, faults=[
        {"kind": "delay_query", "p": 0.4, "delay": 0.5}])

    # compile the batched projection at the service's exact shapes first,
    # else whichever run goes first books one jit trace as query latency
    qp0 = QueryPath(max_batch=cfg.max_batch, deadline_s=10.0)
    for j in range(cfg.queries_per_tick):
        qp0.submit(j, np.zeros(cfg.d, np.float32))
    qp0.process(np.zeros((cfg.d, cfg.r), np.float32))

    root = tempfile.mkdtemp(prefix="bench_serve_svc_")
    try:
        # throwaway run: the first service pays every remaining jit trace
        # (ingest covs, re-solve chunks, gate eigs) mid-tick, which would
        # poison the first measured run's query percentiles
        PSAService(cfg, f"{root}/warmup").run(until=4)
        t0 = time.perf_counter()
        PSAService(cfg, f"{root}/clean").run().finalize()
        clean_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        PSAService(cfg, f"{root}/chaos", plan=plan).run().finalize()
        chaos_s = time.perf_counter() - t0
        clean = service_summary(f"{root}/clean")
        chaos = service_summary(f"{root}/chaos")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # acceptance: query chaos touches only the query path, never the
    # served-subspace trajectory
    assert chaos["served_sha256"] == clean["served_sha256"]
    assert chaos["swap_ticks"] == clean["swap_ticks"]
    assert clean["queries"]["expired"] == 0
    assert chaos["queries"]["expired"] > 0, chaos["queries"]

    # burst 4x over capacity: bounded admission sheds, never blocks
    qp = QueryPath(capacity=16, max_batch=8, deadline_s=10.0)
    qp.warmup(D, R)
    rng = np.random.default_rng(1)
    for j in range(64):
        qp.submit(j, rng.standard_normal(D).astype(np.float32))
    while len(qp):
        qp.process(np.eye(D, R, dtype=np.float32))
    burst = qp.summary()
    assert burst["shed"] == 48 and burst["answered"] == 16

    q = {"clean": clean["queries"], "chaos": chaos["queries"]}
    return {
        "case": f"query_chaos/{total_ticks}ticks",
        "trajectory_bitwise_equal": True,
        "swaps": clean["swaps"],
        "max_staleness": clean["max_staleness"],
        "clean_p50_us": round(q["clean"]["p50_s"] * 1e6, 1),
        "clean_p99_us": round(q["clean"]["p99_s"] * 1e6, 1),
        "chaos_p50_us": round(q["chaos"]["p50_s"] * 1e6, 1),
        "chaos_p99_us": round(q["chaos"]["p99_s"] * 1e6, 1),
        "clean_answered": q["clean"]["answered"],
        "chaos_answered": q["chaos"]["answered"],
        "chaos_expired": q["chaos"]["expired"],
        "burst_shed": burst["shed"],
        "clean_run_s": round(clean_s, 2),
        "chaos_run_s": round(chaos_s, 2),
        "note": "chaos delays expire against the deadline (never served "
                "late, never block the tick); answered latencies stay "
                "sub-deadline in both runs",
    }


def run_bench(smoke: bool = False):
    if smoke:
        recon = [bench_reconverge(seed=s, repeats=1) for s in (0, 1)]
        phases = [bench_tick_phases(repeats=1)]
        chaos = [bench_query_chaos(total_ticks=10)]
    else:
        recon = [bench_reconverge(seed=s, repeats=5) for s in range(5)]
        phases = [bench_tick_phases(repeats=5)]
        chaos = [bench_query_chaos(total_ticks=14)]
    agg = {
        "case": "reconverge/aggregate",
        "iters_cold_total": sum(r["iters_cold"] for r in recon),
        "iters_warm_total": sum(r["iters_warm"] for r in recon),
        "iter_ratio": round(sum(r["iters_warm"] for r in recon)
                            / sum(r["iters_cold"] for r in recon), 3),
        "worst_seed_ratio": max(r["iter_ratio"] for r in recon),
    }
    return recon + [agg] + phases + chaos


def run():
    """benchmarks.run entry point."""
    rows = []
    for rec in run_bench(smoke=False):
        if rec["case"].startswith("reconverge/seed"):
            rows.append(Row(
                f"serving/{rec['case']}", rec["warm_ms"] * 1e3,
                {"cold_ms": rec["cold_ms"], "iter_ratio": rec["iter_ratio"],
                 "iters": f"{rec['iters_warm']}/{rec['iters_cold']}"}))
        elif rec["case"] == "tick_phases":
            rows.append(Row(
                f"serving/{rec['case']}",
                rec["resolve_increment_ms"] * 1e3,
                {"ingest_ms": rec["ingest_batch_ms"],
                 "query_ms": rec["query_drain8_ms"]}))
        elif rec["case"].startswith("query_chaos"):
            rows.append(Row(
                f"serving/{rec['case']}", rec["chaos_p99_us"],
                {"clean_p99_us": rec["clean_p99_us"],
                 "expired": rec["chaos_expired"],
                 "shed": rec["burst_shed"]}))
    return rows


def main():
    smoke = "--smoke" in sys.argv
    results = run_bench(smoke=smoke)
    out = {
        "bench": "serving",
        "scale": {"d": D, "r": R, "n_nodes": N, "target": TARGET},
        "smoke": smoke,
        "backend": jax.default_backend(),
        "results": results,
    }
    print(json.dumps(out, indent=2))
    name = "BENCH_serving.smoke.json" if smoke else "BENCH_serving.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")
    agg = next(r for r in results if r["case"] == "reconverge/aggregate")
    if not smoke and agg["iter_ratio"] >= 0.5:
        print(f"# WARNING: warm/cold iteration ratio {agg['iter_ratio']} "
              "above the 0.5x bar")
        sys.exit(1)


if __name__ == "__main__":
    main()
