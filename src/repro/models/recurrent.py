"""Recurrent token mixers: xLSTM (mLSTM/sLSTM) and RG-LRU (RecurrentGemma).

All three support a full-sequence training path and an O(1)-state decode path
(this is what makes their architectures runnable at long_500k).

* mLSTM — matrix-memory LSTM == gated linear attention. Implemented in
  *chunked* form: within a chunk the decay-weighted quadratic form, across
  chunks a (hd_k x hd_v) state recurrence. Sub-quadratic in sequence length
  and MXU-friendly (three matmuls per chunk).
* sLSTM — scalar-memory LSTM with exponential gating and recurrent (head
  block-diagonal) connections; genuinely sequential -> lax.scan over time.
* RG-LRU — gated diagonal linear recurrence (Griffin); full-sequence path
  uses an associative scan, decode carries the diagonal state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import init_dense

__all__ = [
    "init_mlstm", "apply_mlstm", "init_mlstm_state",
    "init_slstm", "apply_slstm", "init_slstm_state",
    "init_rglru", "apply_rglru", "init_rglru_state",
]


# ===========================================================================
# mLSTM
# ===========================================================================
MLSTM_HEAD_DIM = 128  # MXU-native; head count = up_proj / 128 (see DESIGN.md)


def _mlstm_hd(cfg: ModelConfig) -> int:
    return min(MLSTM_HEAD_DIM, 2 * cfg.d_model)


def mlstm_heads(cfg: ModelConfig) -> int:
    return (2 * cfg.d_model) // _mlstm_hd(cfg)


def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    up = 2 * d
    h = mlstm_heads(cfg)
    hd = _mlstm_hd(cfg)
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 7)
    return {
        "w_up": init_dense(ks[0], d, up, dt),
        "w_gate": init_dense(ks[1], d, up, dt),
        # per-head block-diagonal projections (xLSTM-style): (h, hd, hd)
        "w_q": (jax.random.normal(ks[2], (h, hd, hd)) * hd ** -0.5).astype(dt),
        "w_k": (jax.random.normal(ks[3], (h, hd, hd)) * hd ** -0.5).astype(dt),
        "w_v": (jax.random.normal(ks[4], (h, hd, hd)) * hd ** -0.5).astype(dt),
        "w_if": init_dense(ks[5], up, 2 * h, dt, scale=0.01),  # input/forget gates
        "b_if": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]).astype(dt),
        "w_down": init_dense(ks[6], up, d, dt),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int, n_layers: int):
    h = mlstm_heads(cfg)
    hd = _mlstm_hd(cfg)
    return {
        "c": jnp.zeros((n_layers, batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((n_layers, batch, h, hd), jnp.float32),
    }


def _mlstm_chunk_scan(q, k, v, li, lf, chunk: int):
    """Chunked gated linear attention.

    q,k,v: (b, h, s, hd); li: log input gate (b, h, s); lf: log forget gate.
    Returns (out, final_state c, final n). State c: (b,h,hd,hd), n: (b,h,hd).
    """
    b, h, s, hd = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    qc = q.reshape(b, h, nc, chunk, hd).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    kc = k.reshape(b, h, nc, chunk, hd).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    vc = v.reshape(b, h, nc, chunk, hd).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    lic = li.reshape(b, h, nc, chunk).transpose(2, 0, 1, 3).astype(jnp.float32)
    lfc = lf.reshape(b, h, nc, chunk).transpose(2, 0, 1, 3).astype(jnp.float32)
    scale = hd ** -0.5

    def step(carry, inp):
        c_state, n_state = carry                     # (b,h,hd,hd), (b,h,hd)
        qb, kb, vb, lib, lfb = inp
        f_cum = jnp.cumsum(lfb, axis=-1)             # (b,h,L) log prod of forgets
        f_tot = f_cum[..., -1:]
        # inter-chunk: q_t decayed by all forgets up to t
        q_dec = qb * jnp.exp(f_cum)[..., None] * scale
        inter = jnp.einsum("bhld,bhde->bhle", q_dec, c_state)
        n_inter = jnp.einsum("bhld,bhd->bhl", q_dec, n_state)
        # intra-chunk: A_ts = exp(F_t - F_s + i_s) (q_t . k_s), s <= t
        w = f_cum[..., :, None] - f_cum[..., None, :] + lib[..., None, :]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(causal[None, None], w, -jnp.inf)
        a = jnp.exp(w) * jnp.einsum("bhld,bhmd->bhlm", qb * scale, kb)
        a = jnp.where(causal[None, None], a, 0.0)
        intra = jnp.einsum("bhlm,bhmd->bhld", a, vb)
        # normalizer: n_t = q_t . (decayed sum of i_s k_s) == SIGNED row sums
        # of a (the one-step recurrence computes q.n with signs; abs here
        # would diverge from the decode path)
        n_in = a.sum(-1)
        denom = jnp.maximum(jnp.abs(n_inter + n_in), 1.0)
        out = (inter + intra) / denom[..., None]
        # state update: C' = exp(F_L) C + sum_s exp(F_L - F_s + i_s) k_s v_s^T
        decay_s = jnp.exp(f_tot - f_cum + lib)       # (b,h,L)
        k_dec = kb * decay_s[..., None]
        c_new = jnp.exp(f_tot)[..., None] * c_state + \
            jnp.einsum("bhld,bhle->bhde", k_dec, vb)
        n_new = jnp.exp(f_tot) * n_state + k_dec.sum(axis=2)
        return (c_new, n_new), out

    c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    (c_fin, n_fin), outs = jax.lax.scan(step, (c0, n0), (qc, kc, vc, lic, lfc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, hd)
    return out, c_fin, n_fin


def apply_mlstm(p, x, cfg: ModelConfig, *, state=None, chunk: int | None = None):
    """Full-seq (state None) or one-step decode (state = {"c","n"})."""
    b, s, d = x.shape
    h = mlstm_heads(cfg)
    up = 2 * d
    hd = _mlstm_hd(cfg)
    u = x @ p["w_up"]
    g = jax.nn.silu(x @ p["w_gate"])
    uh = u.reshape(b, s, h, hd)
    q = jnp.einsum("bshd,hde->bhse", uh, p["w_q"])
    k = jnp.einsum("bshd,hde->bhse", uh, p["w_k"])
    v = jnp.einsum("bshd,hde->bhse", uh, p["w_v"])
    gates = u @ p["w_if"] + p["b_if"]                 # (b, s, 2h)
    li = jax.nn.log_sigmoid(gates[..., :h]).transpose(0, 2, 1)   # (b,h,s)
    lf = jax.nn.log_sigmoid(gates[..., h:]).transpose(0, 2, 1)

    if state is None:
        out, c_fin, n_fin = _mlstm_chunk_scan(
            q, k, v, li, lf, chunk or cfg.mlstm_chunk)
        new_state = {"c": c_fin, "n": n_fin}
    else:
        # one token: C' = f C + i k v^T ; out = (q.C') / max(|q.n'|, 1)
        fi = jnp.exp(lf[..., 0])[..., None, None]     # (b,h,1,1)
        ii = jnp.exp(li[..., 0])[..., None, None]
        kv = jnp.einsum("bhd,bhe->bhde", k[:, :, 0].astype(jnp.float32),
                        v[:, :, 0].astype(jnp.float32))
        c_new = fi * state["c"] + ii * kv
        n_new = fi[..., 0] * state["n"] + ii[..., 0] * k[:, :, 0].astype(jnp.float32)
        qv = q[:, :, 0].astype(jnp.float32) * (hd ** -0.5)
        num = jnp.einsum("bhd,bhde->bhe", qv, c_new)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qv, n_new)), 1.0)
        out = (num / den[..., None])[:, :, None, :]   # (b,h,1,hd)
        new_state = {"c": c_new, "n": n_new}

    out = out.transpose(0, 2, 1, 3).reshape(b, s, up).astype(x.dtype)
    return (out * g) @ p["w_down"], new_state


# ===========================================================================
# sLSTM
# ===========================================================================
SLSTM_HEAD_DIM = 128


def _slstm_hd(d: int) -> int:
    return min(SLSTM_HEAD_DIM, d)


def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 4)
    f_up = 4 * d // 3
    hd = _slstm_hd(d)
    nh = d // hd
    return {
        "w_gates": init_dense(ks[0], d, 4 * d, dt),           # i,f,z,o from x
        # recurrent connections are head block-diagonal (xLSTM-style)
        "r_gates": (jax.random.normal(ks[1], (nh, hd, 4 * hd))
                    * 0.5 * hd ** -0.5).astype(dt),
        "b_gates": jnp.zeros((4 * d,), dt),
        "w_ffn_up": init_dense(ks[2], d, 2 * f_up, dt),       # gated ffn
        "w_ffn_down": init_dense(ks[3], f_up, d, dt),
    }


def init_slstm_state(cfg: ModelConfig, batch: int, n_layers: int):
    d = cfg.d_model
    z = jnp.zeros((n_layers, batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z}


def _slstm_cell(p, d, carry, xt):
    c, n, hprev = carry
    b = xt.shape[0]
    hd = _slstm_hd(d)
    nh = d // hd
    # recurrent term: per-head block-diagonal, laid out as (b, 4, h, hd)
    hh = hprev.astype(xt.dtype).reshape(b, nh, hd)
    gr = jnp.einsum("bhd,hde->bhe", hh, p["r_gates"])        # (b, h, 4*hd)
    gr = gr.reshape(b, nh, 4, hd).transpose(0, 2, 1, 3).reshape(b, 4 * d)
    gx = (xt @ p["w_gates"]).reshape(b, 4, nh, hd).reshape(b, 4 * d)
    gates = gx + gr + p["b_gates"]
    gates = gates.astype(jnp.float32)
    i = jnp.exp(jnp.minimum(gates[..., :d], 8.0))           # exp input gate
    f = jax.nn.sigmoid(gates[..., d:2 * d])
    z = jnp.tanh(gates[..., 2 * d:3 * d])
    o = jax.nn.sigmoid(gates[..., 3 * d:])
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (c_new, n_new, h_new), h_new


def apply_slstm(p, x, cfg: ModelConfig, *, state=None):
    b, s, d = x.shape
    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        carry = (zeros, zeros, zeros)
        xs = x.transpose(1, 0, 2)                            # (s, b, d)
        carry, hs = jax.lax.scan(lambda cr, xt: _slstm_cell(p, d, cr, xt), carry, xs)
        h = hs.transpose(1, 0, 2).astype(x.dtype)
        new_state = {"c": carry[0], "n": carry[1], "h": carry[2]}
    else:
        carry = (state["c"], state["n"], state["h"])
        carry, hnew = _slstm_cell(p, d, carry, x[:, 0])
        h = hnew[:, None].astype(x.dtype)
        new_state = {"c": carry[0], "n": carry[1], "h": carry[2]}
    # small gated FFN (xLSTM post-up/down, factor 4/3)
    f_up = p["w_ffn_down"].shape[0]
    u = h @ p["w_ffn_up"]
    out = (jax.nn.silu(u[..., :f_up]) * u[..., f_up:]) @ p["w_ffn_down"]
    return out, new_state


# ===========================================================================
# RG-LRU (Griffin recurrent block)
# ===========================================================================
def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 6)
    return {
        "w_in": init_dense(ks[0], d, d, dt),          # recurrence branch
        "w_gate_in": init_dense(ks[1], d, d, dt),     # multiplicative branch
        "conv_w": (jax.random.normal(ks[2], (4, d), jnp.float32) * 0.1).astype(dt),
        "w_rgate": init_dense(ks[3], d, d, dt, scale=0.01),
        "w_igate": init_dense(ks[4], d, d, dt, scale=0.01),
        "lam": (8.0 * jnp.ones((d,))).astype(jnp.float32),   # softplus param
        "w_out": init_dense(ks[5], d, d, dt),
    }


def init_rglru_state(cfg: ModelConfig, batch: int, n_layers: int):
    d = cfg.d_model
    return {
        "h": jnp.zeros((n_layers, batch, d), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, 3, d), jnp.float32),
    }


_RGLRU_C = 8.0


def apply_rglru(p, x, cfg: ModelConfig, *, state=None):
    b, s, d = x.shape
    u = x @ p["w_in"]
    gate = jax.nn.gelu(x @ p["w_gate_in"])

    if state is None:
        # temporal conv (width 4, causal) via shifted adds
        pads = jnp.pad(u, ((0, 0), (3, 0), (0, 0)))
        conv = sum(pads[:, 3 - i:s + 3 - i] * p["conv_w"][i] for i in range(4))
        r = jax.nn.sigmoid(conv @ p["w_rgate"]).astype(jnp.float32)
        i_g = jax.nn.sigmoid(conv @ p["w_igate"]).astype(jnp.float32)
        log_a = -_RGLRU_C * r * jax.nn.softplus(p["lam"])      # (b,s,d)
        a = jnp.exp(log_a)
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
        bx = beta * (i_g * conv.astype(jnp.float32))

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        a_sc, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
        h_fin = h[:, -1]
        conv_state = u[:, -3:].astype(jnp.float32) if s >= 3 else jnp.pad(
            u.astype(jnp.float32), ((0, 0), (3 - s, 0), (0, 0)))
        new_state = {"h": h_fin, "conv": conv_state}
        out = h.astype(x.dtype)
    else:
        conv_buf = jnp.concatenate(
            [state["conv"], u[:, 0:1].astype(jnp.float32)], axis=1)   # (b,4,d)
        # buf is oldest->newest; conv_w[i] weights the token i steps back, so
        # the newest entry (buf[3]) takes conv_w[0] — reverse the kernel.
        conv = (conv_buf * p["conv_w"][::-1].astype(jnp.float32)).sum(axis=1)
        r = jax.nn.sigmoid(conv @ p["w_rgate"].astype(jnp.float32))
        i_g = jax.nn.sigmoid(conv @ p["w_igate"].astype(jnp.float32))
        log_a = -_RGLRU_C * r * jax.nn.softplus(p["lam"])
        a = jnp.exp(log_a)
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
        h_new = a * state["h"] + beta * (i_g * conv)
        new_state = {"h": h_new, "conv": conv_buf[:, 1:]}
        out = h_new[:, None].astype(x.dtype)

    return (out * gate) @ p["w_out"], new_state
