"""Chunked-resumable fused runs: whole-run scans that outlast a job.

PRs 1-3 fused entire runs into one ``lax.scan`` — maximal throughput, but a
run killed at iteration 900/1000 restarted from zero. PR 4 made S-DOT and
F-DOT restartable with four hand-written chunk drivers; the unified
executor runtime (``core/runtime.py``) replaced those with ONE generic
chunked driver, so this module is now a set of thin entry points:

    <family>_program (core/sdot|fdot|bdot|baselines)
      -> runtime.run_chunked(program, manager, chunk_size)
         - restore latest valid RunState (or init fresh)
         - per chunk: one jitted scan over xs[step : step+chunk] built from
           the SAME outer-iteration body as the monolithic executor,
           trace buffers updated in place via dynamic_update_slice
         - checkpoint (atomic, async) at every chunk boundary
      -> the family's finalize() assembles the usual result object

Because the driver is generic, chunked-resume now covers the WHOLE
algorithm zoo: ``bdot_chunked`` and ``baseline_chunked`` (all five
baselines) exist with zero family-specific chunking code, and the sweep
engines accept ``manager``/``chunk_size`` directly (``core/sweep.py``) for
mid-grid resumable sweeps.

**Resume invariant** (pinned in tests/test_streaming.py): a run killed at
any chunk boundary, restored, and continued produces the *bit-identical*
error trace, iterate, and comm ledger of the uninterrupted run.  Three
things make this exact rather than approximate:

* chunking a ``lax.scan`` is exact — the chunk program is compiled from the
  same outer body, and XLA's per-iteration arithmetic does not depend on
  the scan length (verified bitwise on CPU);
* the async RNG key rides in ``RunState`` — each outer iteration's awake
  draw depends only on the carried key, so the restored run continues the
  straggler realization mid-stream with no replay;
* the async ledger is derived from the (T_o, ...) send/count buffers in
  ``RunState``, not from host accumulation, so it survives the crash too.

A corrupt or half-written latest checkpoint (crashed writer) is skipped:
the runtime walks the manager's steps newest-first and falls back to the
newest restorable snapshot, or a fresh start.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..core.baselines import BaselineResult, baseline_program
from ..core.bdot import BDOTResult, bdot_program
from ..core.fdot import FDOTResult, fdot_program
from ..core.runtime import RunState, run_chunked
from ..core.sdot import SDOTResult, sdot_program

__all__ = ["RunState", "sdot_chunked", "fdot_chunked", "bdot_chunked",
           "baseline_chunked"]


def sdot_chunked(
    *,
    covs=None,
    data: Optional[Sequence[jnp.ndarray]] = None,
    engine,
    r: int,
    t_outer: int,
    schedule: Optional[np.ndarray] = None,
    t_c: int = 50,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
    chunk_size: int = 10,
    manager: Optional[CheckpointManager] = None,
    max_chunks: Optional[int] = None,
) -> SDOTResult:
    """Chunked-resumable S-DOT/SA-DOT: the fused run, restartable.

    Same contract as ``core.sdot.sdot(fused=True)`` — bit-identical trace,
    iterate, and ledger — but the whole-run scan is executed
    ``chunk_size`` outer iterations at a time with the ``RunState``
    checkpointed through ``manager`` at every chunk boundary.  If
    ``manager`` already holds a snapshot of this run, execution resumes
    from it (callers own directory hygiene: one run per checkpoint root).
    ``max_chunks`` stops after that many chunks (simulating a killed job)
    — the return value then covers only the completed prefix.
    """
    return run_chunked(
        sdot_program(covs=covs, data=data, engine=engine, r=r,
                     t_outer=t_outer, schedule=schedule, t_c=t_c,
                     q_init=q_init, q_true=q_true, seed=seed),
        manager, chunk_size=chunk_size, max_chunks=max_chunks)


def fdot_chunked(
    *,
    data_blocks: Sequence[jnp.ndarray],
    engine,
    r: int,
    t_outer: int,
    t_c: int = 50,
    t_c_qr: Optional[int] = None,
    schedule: Optional[np.ndarray] = None,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
    chunk_size: int = 10,
    manager: Optional[CheckpointManager] = None,
    max_chunks: Optional[int] = None,
) -> FDOTResult:
    """Chunked-resumable F-DOT: ``core.fdot.fdot(fused=True)``, restartable.

    Same resume contract as ``sdot_chunked`` (bit-identical trace / slabs /
    ledger across kill-and-restore at chunk boundaries), including async
    engines — the three-per-iteration RNG splits ride in the checkpointed
    key."""
    return run_chunked(
        fdot_program(data_blocks=data_blocks, engine=engine, r=r,
                     t_outer=t_outer, t_c=t_c, t_c_qr=t_c_qr,
                     schedule=schedule, q_init=q_init, q_true=q_true,
                     seed=seed),
        manager, chunk_size=chunk_size, max_chunks=max_chunks)


def bdot_chunked(
    *,
    blocks: Sequence[Sequence[jnp.ndarray]],
    col_engines,
    row_engines,
    r: int,
    t_outer: int,
    t_c: int = 50,
    t_c_qr: Optional[int] = None,
    schedule: Optional[np.ndarray] = None,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
    chunk_size: int = 10,
    manager: Optional[CheckpointManager] = None,
    max_chunks: Optional[int] = None,
) -> BDOTResult:
    """Chunked-resumable B-DOT: ``core.bdot.bdot(fused=True)``, restartable.

    New with the unified runtime — the block-partitioned executor gains
    kill-at-any-chunk-boundary bit-identical resume from the generic
    driver, with zero B-DOT-specific chunking code."""
    return run_chunked(
        bdot_program(blocks=blocks, col_engines=col_engines,
                     row_engines=row_engines, r=r, t_outer=t_outer, t_c=t_c,
                     t_c_qr=t_c_qr, schedule=schedule, q_init=q_init,
                     q_true=q_true, seed=seed),
        manager, chunk_size=chunk_size, max_chunks=max_chunks)


def baseline_chunked(
    name: str,
    *,
    covs=None,
    data_blocks: Optional[Sequence[jnp.ndarray]] = None,
    engine,
    r: int,
    t_outer: Optional[int] = None,
    iters_per_vec: Optional[int] = None,
    lr: float = 0.1,
    t_mix: int = 3,
    t_c: int = 50,
    q_true=None,
    seed: int = 0,
    chunk_size: int = 10,
    manager: Optional[CheckpointManager] = None,
    max_chunks: Optional[int] = None,
) -> BaselineResult:
    """Chunked-resumable fused baseline (any of the five distributed ones).

    ``name``: dsa | dpgd | deepca | seq_dist_pm | d_pm, with the same
    problem arguments as ``core.baselines.baseline_program``. The
    sequential-deflation methods chunk over the flattened (vector,
    inner-iteration) index, so a kill mid-deflation resumes exactly where
    the Gram-Schmidt order left off. Returns a ``BaselineResult`` whose
    ledger covers the completed prefix."""
    return run_chunked(
        baseline_program(name, covs=covs, data_blocks=data_blocks,
                         engine=engine, r=r, t_outer=t_outer,
                         iters_per_vec=iters_per_vec, lr=lr, t_mix=t_mix,
                         t_c=t_c, q_true=q_true, seed=seed),
        manager, chunk_size=chunk_size, max_chunks=max_chunks)
