"""Network topologies and doubly-stochastic weight matrices.

Reproduces the graph constructions used in the paper's experiments
(Erdos-Renyi, ring, star) plus a 2-D torus that models a TPU pod-level
DCI interconnect. Weight matrices follow the "local-degree weights"
method of Xiao & Boyd '04 (paper ref [16]), which the paper uses for
all consensus experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "Graph",
    "erdos_renyi",
    "ring",
    "star",
    "torus2d",
    "complete",
    "local_degree_weights",
    "metropolis_weights",
    "mixing_time",
    "spectral_gap",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected graph over N nodes with an adjacency matrix (no self loops)."""

    adjacency: np.ndarray  # (N, N) 0/1 symmetric, zero diagonal

    @property
    def n_nodes(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1)

    @property
    def n_edges(self) -> int:
        return int(self.adjacency.sum()) // 2

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adjacency[i])[0]

    def is_connected(self) -> bool:
        n = self.n_nodes
        seen = np.zeros(n, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            u = stack.pop()
            for v in self.neighbors(u):
                if not seen[v]:
                    seen[v] = True
                    stack.append(int(v))
        return bool(seen.all())


def erdos_renyi(n: int, p: float, seed: int = 0, ensure_connected: bool = True) -> Graph:
    """Erdos-Renyi G(n, p); resamples until connected (as in the paper)."""
    rng = np.random.default_rng(seed)
    for _ in range(10_000):
        upper = rng.random((n, n)) < p
        adj = np.triu(upper, k=1)
        adj = (adj | adj.T).astype(np.float64)
        g = Graph(adj)
        if not ensure_connected or g.is_connected():
            return g
    raise RuntimeError(f"could not sample a connected ER graph (n={n}, p={p})")


def ring(n: int) -> Graph:
    adj = np.zeros((n, n))
    idx = np.arange(n)
    adj[idx, (idx + 1) % n] = 1.0
    adj[(idx + 1) % n, idx] = 1.0
    if n == 2:  # avoid double edge
        adj = np.clip(adj, 0.0, 1.0)
    return Graph(adj)


def star(n: int) -> Graph:
    adj = np.zeros((n, n))
    adj[0, 1:] = 1.0
    adj[1:, 0] = 1.0
    return Graph(adj)


def torus2d(rows: int, cols: int) -> Graph:
    """2-D torus — the topology of a TPU ICI/DCI slice."""
    n = rows * cols
    adj = np.zeros((n, n))

    def nid(r: int, c: int) -> int:
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            u = nid(r, c)
            for v in (nid(r + 1, c), nid(r, c + 1)):
                if u != v:
                    adj[u, v] = adj[v, u] = 1.0
    return Graph(adj)


def complete(n: int) -> Graph:
    adj = np.ones((n, n)) - np.eye(n)
    return Graph(adj)


def local_degree_weights(g: Graph) -> np.ndarray:
    """Doubly-stochastic W via local-degree (max-degree of edge endpoints).

    w_ij = 1 / (1 + max(deg_i, deg_j)) for (i,j) in E, w_ii = 1 - sum_j w_ij.
    This is the construction from Xiao & Boyd used by the paper.
    """
    a = g.adjacency
    deg = g.degrees
    n = g.n_nodes
    w = np.zeros((n, n))
    pair_max = np.maximum(deg[:, None], deg[None, :])
    mask = a > 0
    w[mask] = 1.0 / (1.0 + pair_max[mask])
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def metropolis_weights(g: Graph) -> np.ndarray:
    """Metropolis-Hastings weights; also doubly stochastic, slightly different mixing."""
    a = g.adjacency
    deg = g.degrees
    n = g.n_nodes
    w = np.zeros((n, n))
    mask = a > 0
    pair_max = np.maximum(deg[:, None], deg[None, :])
    w[mask] = 1.0 / (1.0 + pair_max[mask])
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def spectral_gap(w: np.ndarray) -> float:
    """1 - |lambda_2(W)|; gossip contraction factor per round."""
    ev = np.linalg.eigvals(w)
    ev = np.sort(np.abs(ev))[::-1]
    second = ev[1] if len(ev) > 1 else 0.0
    return float(1.0 - second)


def mixing_time(w: np.ndarray, max_t: int = 100_000) -> Optional[int]:
    """tau_mix per paper eq. (5): first t with max_i ||e_i^T W^t - 1/N|| <= 1/2.

    Returns None when the chain is periodic / non-mixing (e.g. even ring),
    mirroring the paper's observation that tau_mix -> inf for ring topologies.
    """
    n = w.shape[0]
    target = np.full((n, n), 1.0 / n)
    wt = np.eye(n)
    for t in range(1, max_t + 1):
        wt = wt @ w
        dev = np.linalg.norm(wt - target, axis=1).max()
        if dev <= 0.5:
            return t
        if t > 64 and dev > 0.999:  # not contracting at all
            break
    return None
