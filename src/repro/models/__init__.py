"""Model zoo: unified decoder stack + per-family token mixers + sharding."""
from . import attention, layers, moe, recurrent, sharding, transformer  # noqa: F401
from .transformer import (decode_step, forward, init_decode_state,  # noqa: F401
                          init_params)
