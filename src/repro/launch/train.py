"""Production training driver.

Fault-tolerance contract (see DESIGN.md §5):
  * auto-resume: on start, the latest valid checkpoint under --ckpt-dir is
    restored (params + optimizer + PSA state + step counter). The data
    stream is stateless-seeded, so the restarted run replays the exact
    batch sequence — restart is bitwise identical (tests/test_checkpoint_data).
  * atomic saves: step directories are tmp+rename published; a killed writer
    can never corrupt "latest".
  * async saves: serialization runs off the critical path.
  * elastic re-mesh: --mesh can change between runs; restore re-shards.

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
      --steps 50 --batch 4 --seq 32 --ckpt-dir /tmp/ckpt
Multi-pod PSA-compressed (the paper's technique in the optimizer):
  ... --psa --mesh multipod
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs import get_arch, get_psa_config, reduced_config
from ..data.pipeline import make_lm_batch
from ..models import sharding as shd
from ..models.transformer import init_params
from ..optim.adamw import AdamWConfig, adamw_init
from ..optim.psa_compress import compression_ratio, psa_init
from ..train.step import make_psa_train_step, make_train_step
from .mesh import make_test_mesh


def build_mesh(kind: str):
    if kind == "single":
        return make_test_mesh(multi_pod=False)
    if kind == "multipod":
        return make_test_mesh(multi_pod=True)
    raise ValueError(kind)


def train(args) -> dict:
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = build_mesh(args.mesh)
    opt = AdamWConfig(lr=args.lr, warmup_steps=args.warmup)
    psa = get_psa_config() if args.psa else None
    if psa is not None and args.psa_rank:
        import dataclasses
        psa = dataclasses.replace(psa, rank=args.psa_rank)

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_state = adamw_init(params, opt)
    psa_state = psa_init(params, psa) if psa else None

    if psa:
        step_fn, refresh_fn, bspecs = make_psa_train_step(
            cfg, mesh, opt, psa, global_batch=args.batch)
        print(f"[psa] cross-pod compression ratio: "
              f"{compression_ratio(params, psa):.4f}")
    else:
        step_fn, bspecs = make_train_step(
            cfg, mesh, opt, global_batch=args.batch, donate=False)

    mgr = CheckpointManager(args.ckpt_dir, keep_last=args.keep_last) \
        if args.ckpt_dir else None
    start_step = 0
    if mgr is not None:
        tree = {"params": params, "opt": opt_state}
        if psa_state is not None:
            tree["psa"] = psa_state
        restored, step = mgr.restore(tree)
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            psa_state = restored.get("psa", psa_state)
            start_step = step
            print(f"[resume] restored step {step} from {args.ckpt_dir}")

    losses = []
    t0 = time.time()
    with mesh:
        for t in range(start_step, args.steps):
            batch = make_lm_batch(cfg, args.data_seed, t, args.batch, args.seq)
            if psa:
                if t % psa.refresh_every == 0:
                    psa_state = refresh_fn(params, psa_state, batch)
                params, opt_state, psa_state, metrics = step_fn(
                    params, opt_state, psa_state, batch)
            else:
                params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if not np.isfinite(loss):
                raise RuntimeError(f"non-finite loss at step {t}")
            if t % args.log_every == 0:
                dt = time.time() - t0
                print(f"step {t:5d}  loss {loss:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"({dt:.1f}s)", flush=True)
            if mgr is not None and (t + 1) % args.ckpt_every == 0:
                tree = {"params": params, "opt": opt_state}
                if psa_state is not None:
                    tree["psa"] = psa_state
                mgr.save(t + 1, tree, blocking=False)   # off the critical path
    if mgr is not None:
        mgr.wait()
        tree = {"params": params, "opt": opt_state}
        if psa_state is not None:
            tree["psa"] = psa_state
        mgr.save(args.steps, tree)
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps_run": len(losses)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--psa", action="store_true",
                    help="PSA-compressed cross-pod gradient reduction")
    ap.add_argument("--psa-rank", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--keep-last", type=int, default=3)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    out = train(args)
    print(f"done: {out}")


if __name__ == "__main__":
    main()
