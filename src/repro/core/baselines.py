"""Baseline algorithms the paper compares against (Figs. 4-6).

Centralized:
  * ``seq_pm``       — sequential power method with deflation (SeqPM)
Distributed, sample-partitioned:
  * ``seq_dist_pm``  — SeqPM with consensus-averaged matvecs (SeqDistPM, [13])
  * ``dsa``          — distributed Sanger's algorithm (Hebbian, [19])
  * ``dpgd``         — distributed projected gradient descent ([35]-style)
  * ``deepca``       — gradient-tracking power iteration (DeEPCA, [27])
Distributed, feature-partitioned:
  * ``d_pm``         — sequential distributed power method of [10]

All return (q_estimate(s), error_trace) with the paper's metric (11) traced
per *outer* iteration so plots match the paper's x-axis conventions
(inner x outer for consensus-based methods — callers scale accordingly).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .consensus import DenseConsensus
from .linalg import cholesky_qr2, orthonormal_init
from .metrics import CommLedger, subspace_error
from .sdot import local_cov_apply

__all__ = ["seq_pm", "seq_dist_pm", "dsa", "dpgd", "deepca", "d_pm"]


def _trace(q_true, q):
    return float(subspace_error(q_true, q)) if q_true is not None else np.nan


# --------------------------------------------------------------------------
# centralized sequential power method
# --------------------------------------------------------------------------
def seq_pm(m: jnp.ndarray, r: int, iters_per_vec: int, q_true=None, seed: int = 0):
    """Power method + deflation, one eigenvector at a time.

    The error trace is recorded against the *full* current estimate (later
    columns still at their random init), reproducing the paper's observation
    that sequential methods plateau high until the last vector converges.
    """
    d = m.shape[0]
    q = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    cols = [q[:, i] for i in range(r)]
    errs = []
    m_defl = m
    for k in range(r):
        v = cols[k]
        for _ in range(iters_per_vec):
            v = m_defl @ v
            # re-orthogonalize against converged columns for stability
            for j in range(k):
                v = v - cols[j] * (cols[j] @ v)
            v = v / jnp.linalg.norm(v)
            errs.append(_trace(q_true, jnp.stack(cols[:k] + [v] + cols[k + 1:], 1)))
        cols[k] = v
        # deflate with the projector onto the complement of converged columns
        p = jnp.eye(d)
        for j in range(k + 1):
            p = p - jnp.outer(cols[j], cols[j])
        m_defl = p @ m @ p
    return jnp.stack(cols, axis=1), np.asarray(errs)


# --------------------------------------------------------------------------
# distributed sequential power method (SeqDistPM)
# --------------------------------------------------------------------------
def seq_dist_pm(covs: jnp.ndarray, engine: DenseConsensus, r: int,
                iters_per_vec: int, t_c: int = 50, q_true=None, seed: int = 0,
                ledger: Optional[CommLedger] = None):
    n, d, _ = covs.shape
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    cols = [jnp.broadcast_to(q0[:, k][None], (n, d)) for k in range(r)]  # per-node
    errs = []
    done: list = []
    for k in range(r):
        v = cols[k]  # (n, d)
        for _ in range(iters_per_vec):
            z = jnp.einsum("nde,ne->nd", covs, v)
            z = engine.run_debiased(z, t_c, ledger)
            # deflate against converged vectors (per node)
            for u in done:
                z = z - u * jnp.sum(u * z, axis=1, keepdims=True)
            v = z / jnp.linalg.norm(z, axis=1, keepdims=True)
            cur = [c if i != k else v for i, c in enumerate(cols)]
            qm = jnp.stack([c.mean(0) for c in cur], axis=1)
            errs.append(_trace(q_true, qm))
        cols[k] = v
        done.append(v)
    q_nodes = jnp.stack(cols, axis=2)  # (n, d, r)
    return q_nodes, np.asarray(errs)


# --------------------------------------------------------------------------
# distributed Sanger's algorithm (DSA)
# --------------------------------------------------------------------------
def dsa(covs: jnp.ndarray, engine: DenseConsensus, r: int, t_outer: int,
        lr: float = 0.1, q_true=None, seed: int = 0,
        ledger: Optional[CommLedger] = None):
    """Q_i <- sum_j w_ij Q_j + lr * (M_i Q_i - Q_i UT(Q_i^T M_i Q_i)).

    Converges linearly to a *neighborhood* of the truth (paper Fig. 4/5).
    One gossip round per iteration (as in [19]).
    """
    n, d, _ = covs.shape
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    q = jnp.broadcast_to(q0[None], (n, d, r))
    errs = []
    for _ in range(t_outer):
        mixed = engine.run(q, 1)
        if ledger is not None:
            ledger.log_gossip_round(engine.graph.adjacency, d * r)
        mq = local_cov_apply(covs, q)
        qmq = jnp.einsum("ndr,nds->nrs", q, mq)
        upper = jnp.triu(qmq)
        sanger = mq - jnp.einsum("ndr,nrs->nds", q, upper)
        q = mixed + lr * sanger
        errs.append(_trace(q_true, q.mean(0)))
    return q, np.asarray(errs)


# --------------------------------------------------------------------------
# distributed projected gradient descent (DPGD)
# --------------------------------------------------------------------------
def dpgd(covs: jnp.ndarray, engine: DenseConsensus, r: int, t_outer: int,
         lr: float = 0.1, q_true=None, seed: int = 0,
         ledger: Optional[CommLedger] = None):
    """Trace-maximization DGD + QR retraction (converges to a neighborhood)."""
    n, d, _ = covs.shape
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    q = jnp.broadcast_to(q0[None], (n, d, r))
    errs = []
    for _ in range(t_outer):
        mixed = engine.run(q, 1)
        if ledger is not None:
            ledger.log_gossip_round(engine.graph.adjacency, d * r)
        grad = local_cov_apply(covs, q)  # d/dQ Tr(Q^T M_i Q) = 2 M_i Q
        v = mixed + lr * grad
        q = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)
        errs.append(_trace(q_true, q.mean(0)))
    return q, np.asarray(errs)


# --------------------------------------------------------------------------
# DeEPCA — gradient tracking + power iteration
# --------------------------------------------------------------------------
def deepca(covs: jnp.ndarray, engine: DenseConsensus, r: int, t_outer: int,
           t_mix: int = 3, q_true=None, seed: int = 0,
           ledger: Optional[CommLedger] = None):
    """Gradient-tracking power iteration (Ye & Zhang '21, paper ref [27]).

    s_i tracks (1/N) sum_j M_j Q_j exactly in the limit; a constant number of
    FastMix/gossip rounds per outer iteration suffices — that is the log-factor
    advantage over S-DOT the paper's Remark 1 concedes.
    """
    n, d, _ = covs.shape
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    q = jnp.broadcast_to(q0[None], (n, d, r))
    mq_prev = local_cov_apply(covs, q)
    s = mq_prev
    errs = []
    for _ in range(t_outer):
        s = engine.run(s, t_mix)
        if ledger is not None:
            for _ in range(t_mix):
                ledger.log_gossip_round(engine.graph.adjacency, d * r)
        # sign-fixed orthonormalization (DeEPCA's rounding keeps tracking valid)
        q_new = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(s)
        # align signs with previous iterate for smooth tracking
        sign = jnp.sign(jnp.einsum("ndr,ndr->nr", q_new, q))
        sign = jnp.where(sign == 0, 1.0, sign)
        q_new = q_new * sign[:, None, :]
        mq_new = local_cov_apply(covs, q_new)
        s = s + mq_new - mq_prev       # gradient tracking correction
        mq_prev, q = mq_new, q_new
        errs.append(_trace(q_true, q.mean(0)))
    return q, np.asarray(errs)


# --------------------------------------------------------------------------
# d-PM — sequential distributed power method for feature-partitioned data
# --------------------------------------------------------------------------
def d_pm(data_blocks: Sequence[jnp.ndarray], engine: DenseConsensus, r: int,
         iters_per_vec: int, t_c: int = 50, q_true=None, seed: int = 0,
         ledger: Optional[CommLedger] = None):
    """Scaglione et al. [10]: estimate eigenvectors one at a time, each via
    power iterations on M = X X^T executed feature-wise with consensus."""
    dims = [int(x.shape[0]) for x in data_blocks]
    d = sum(dims)
    offs = np.cumsum([0] + dims)
    n_nodes = len(data_blocks)
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    blocks = [[q0[offs[i]:offs[i + 1], k] for i in range(n_nodes)] for k in range(r)]
    errs = []
    done_full: list = []
    for k in range(r):
        vb = blocks[k]
        for _ in range(iters_per_vec):
            partial = jnp.stack([x.T @ v for x, v in zip(data_blocks, vb)])  # (N,n)
            ssum = engine.run_debiased(partial, t_c, ledger)
            vb = [x @ ssum[i] for i, x in enumerate(data_blocks)]
            vfull = jnp.concatenate(vb)
            for u in done_full:
                vfull = vfull - u * (u @ vfull)
            vfull = vfull / jnp.linalg.norm(vfull)
            vb = [vfull[offs[i]:offs[i + 1]] for i in range(n_nodes)]
            cur = jnp.stack(
                [jnp.concatenate(blocks[j]) if j != k else vfull for j in range(r)], 1)
            errs.append(_trace(q_true, cur))
        blocks[k] = vb
        done_full.append(jnp.concatenate(vb))
    q_full = jnp.stack([jnp.concatenate(b) for b in blocks], axis=1)
    return q_full, np.asarray(errs)
