"""Assigned-architecture configs must match the assignment table exactly."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_arch, get_psa_config, \
    reduced_config, valid_cells

# (arch, n_layers, d_model, n_heads, n_kv, d_ff, vocab)
TABLE = {
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
    "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
    "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
    "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
}


def test_all_ten_archs_registered():
    assert len(ARCH_IDS) == 10
    assert set(ARCH_IDS) == set(TABLE)


@pytest.mark.parametrize("aid", sorted(TABLE))
def test_arch_matches_assignment(aid):
    cfg = get_arch(aid)
    nl, dm, nh, nkv, dff, vs = TABLE[aid]
    assert cfg.n_layers == nl
    assert cfg.d_model == dm
    assert cfg.n_heads == nh
    assert cfg.n_kv_heads == nkv
    assert cfg.d_ff == dff
    assert cfg.vocab_size == vs


def test_moe_configs():
    kimi = get_arch("kimi-k2-1t-a32b")
    assert kimi.moe is not None and kimi.moe.n_experts == 384 \
        and kimi.moe.top_k == 8
    phi = get_arch("phi3.5-moe-42b-a6.6b")
    assert phi.moe is not None and phi.moe.n_experts == 16 and phi.moe.top_k == 2


def test_param_counts_in_range():
    """Headline parameter counts should land near the names on the tin."""
    expected = {
        "qwen2-7b": (6e9, 9e9),
        "internlm2-20b": (17e9, 23e9),
        "command-r-35b": (30e9, 40e9),
        "h2o-danube-1.8b": (1.4e9, 2.3e9),
        "recurrentgemma-2b": (2e9, 3.8e9),  # 256k vocab embed dominates
        "paligemma-3b": (1.8e9, 3.5e9),   # backbone only (SigLIP is a stub)
        "musicgen-medium": (1.2e9, 2.2e9),
        "xlstm-1.3b": (1.0e9, 1.8e9),
        "kimi-k2-1t-a32b": (0.85e12, 1.25e12),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
    }
    for aid, (lo, hi) in expected.items():
        n = get_arch(aid).param_count()
        assert lo <= n <= hi, f"{aid}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"


def test_active_params_moe():
    kimi = get_arch("kimi-k2-1t-a32b")
    act = kimi.active_param_count()
    assert 25e9 <= act <= 40e9, f"kimi active {act/1e9:.1f}B"
    phi = get_arch("phi3.5-moe-42b-a6.6b")
    act = phi.active_param_count()
    assert 4e9 <= act <= 9e9, f"phi active {act/1e9:.1f}B"
    dense = get_arch("qwen2-7b")
    assert dense.active_param_count() == dense.param_count()


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["prefill_32k"].kind == "prefill"
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].kind == "decode"


def test_valid_cells_40_with_documented_skips():
    cells = valid_cells()
    assert len(cells) == 40
    skips = {c["arch"] for c in cells if c["skip"]}
    subq = {"xlstm-1.3b", "h2o-danube-1.8b", "recurrentgemma-2b"}
    assert skips == set(ARCH_IDS) - subq
    for c in cells:
        if c["skip"]:
            assert c["shape"] == "long_500k" and c["reason"]


def test_subquadratic_flags():
    assert get_arch("xlstm-1.3b").subquadratic
    assert get_arch("h2o-danube-1.8b").subquadratic      # SWA
    assert get_arch("recurrentgemma-2b").subquadratic
    assert not get_arch("qwen2-7b").subquadratic


def test_block_patterns_tile_layers():
    for aid in ARCH_IDS:
        cfg = get_arch(aid)
        assert cfg.n_layers % len(cfg.block_pattern) == 0
        assert cfg.n_groups * len(cfg.block_pattern) == cfg.n_layers


def test_reduced_config_is_small_same_family():
    for aid in ARCH_IDS:
        cfg = get_arch(aid)
        small = reduced_config(cfg)
        assert small.family == cfg.family
        assert small.block_pattern == cfg.block_pattern
        assert small.param_count() < 3e7
        assert (small.moe is None) == (cfg.moe is None)


def test_psa_config_defaults():
    psa = get_psa_config()
    assert psa.rank >= 1 and psa.gossip_rounds >= 1 and psa.oi_iters >= 1
