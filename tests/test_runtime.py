"""Unified executor runtime: the Program protocol and its three drivers.

The family-level guarantees (fused == eager, chunked resume bitwise,
sweep == per-seed runs) are pinned in test_sdot_fused / test_fused_zoo /
test_streaming; this module pins the driver-level properties that make
them compose: one shared jitted chunk program, chunk-size invariance
across families, and the Program plumbing itself.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import runtime
from repro.core.bdot import bdot, bdot_program
from repro.core.consensus import DenseConsensus
from repro.core.linalg import eigh_topr
from repro.core.sdot import sdot, sdot_program
from repro.core.sweep import baseline_sweep, fdot_sweep, sdot_sweep
from repro.core.topology import complete, erdos_renyi, ring
from repro.data.pipeline import partition_features, partition_samples
from repro.streaming.resume import baseline_chunked, bdot_chunked

D, R, N = 12, 3, 6
T_OUTER, T_C = 9, 10


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((D, 360)), jnp.float32)
    covs = jnp.stack([b @ b.T / b.shape[1]
                      for b in partition_samples(x, N)])
    _, q_true = eigh_topr(covs.sum(0), R)
    d_rows, n_cols = [7, 5], [160, 120, 80]
    blocks, o = [], 0
    for di in d_rows:
        row, c = [], 0
        for nj in n_cols:
            row.append(x[o:o + di, c:c + nj])
            c += nj
        blocks.append(row)
        o += di
    return dict(x=x, covs=covs, q_true=q_true, grid=blocks,
                engine=DenseConsensus(erdos_renyi(N, 0.5, seed=1)),
                col_engines=[DenseConsensus(complete(2)) for _ in n_cols],
                row_engines=[DenseConsensus(ring(3)) for _ in d_rows])


def test_program_basics(problem):
    p = problem
    prog = sdot_program(covs=p["covs"], engine=p["engine"], r=R,
                        t_outer=T_OUTER, t_c=T_C, q_true=p["q_true"])
    assert prog.t_outer == T_OUTER
    assert prog.lane_shape == ()
    assert prog.key0 is None and prog.tail == ()
    res = runtime.run_monolithic(prog)
    ref = sdot(covs=p["covs"], engine=p["engine"], r=R, t_outer=T_OUTER,
               t_c=T_C, q_true=p["q_true"])
    np.testing.assert_array_equal(res.error_trace, ref.error_trace)


def test_run_sweep_requires_lane_axes(problem):
    p = problem
    prog = sdot_program(covs=p["covs"], engine=p["engine"], r=R,
                        t_outer=T_OUTER, t_c=T_C)
    with pytest.raises(ValueError, match="case and seed axes"):
        runtime.run_sweep(prog)


def test_sync_body_threads_key_and_zero_tails():
    inner = lambda carry, x: (carry + x, jnp.float32(0.5))
    body = runtime.sync_body(inner)
    key = jnp.asarray([3, 4], jnp.uint32)
    (carry, key_out), (err, sends, counts) = body(
        (jnp.float32(1.0), key), jnp.float32(2.0))
    assert float(carry) == 3.0 and float(err) == 0.5
    np.testing.assert_array_equal(np.asarray(key_out), np.asarray(key))
    assert sends.shape == () and counts.shape == ()


def test_monolithic_and_chunked_share_compiled_programs(problem):
    """A chunked run whose chunk covers the whole schedule hits the SAME
    jit-cache entry as the monolithic driver — there is only one chunk
    program, keyed on (build_body, statics, shapes)."""
    p = problem
    kw = dict(covs=p["covs"], engine=p["engine"], r=R, t_outer=T_OUTER,
              t_c=T_C, q_true=p["q_true"])
    sdot(**kw)                                   # compiles length-T chunk
    base = runtime._chunk_program._cache_size()
    from repro.streaming.resume import sdot_chunked
    sdot_chunked(chunk_size=T_OUTER, **kw)       # same length, same statics
    assert runtime._chunk_program._cache_size() == base


def test_bdot_chunk_size_invariance(problem):
    p = problem
    kw = dict(blocks=p["grid"], col_engines=p["col_engines"],
              row_engines=p["row_engines"], r=R, t_outer=T_OUTER, t_c=T_C,
              q_true=p["q_true"])
    mono = bdot(**kw)
    for chunk in (1, 4, T_OUTER + 5):
        res = bdot_chunked(chunk_size=chunk, **kw)
        np.testing.assert_array_equal(res.error_trace, mono.error_trace)
        np.testing.assert_array_equal(np.asarray(res.q_full),
                                      np.asarray(mono.q_full))


def test_bdot_program_rejects_eager_only_engines(problem):
    p = problem

    class Bare:
        pass

    with pytest.raises(ValueError, match="debias_table"):
        bdot_program(blocks=p["grid"], col_engines=[Bare()] * 3,
                     row_engines=p["row_engines"], r=R, t_outer=3)


def test_baseline_chunk_size_invariance(problem):
    p = problem
    from repro.core.baselines import deepca
    q_m, e_m = deepca(p["covs"], p["engine"], R, T_OUTER,
                      q_true=p["q_true"])
    for chunk in (1, 4, T_OUTER + 5):
        res = baseline_chunked("deepca", covs=p["covs"], engine=p["engine"],
                               r=R, t_outer=T_OUTER, q_true=p["q_true"],
                               chunk_size=chunk)
        np.testing.assert_array_equal(res.error_trace, e_m)
        np.testing.assert_array_equal(np.asarray(res.q), np.asarray(q_m))


def test_sweep_chunk_size_invariance(problem):
    """The sweep driver is the same chunk program vmapped over the lanes —
    chunking must not move a single bit of any lane's trace."""
    p = problem
    kw = dict(covs=p["covs"],
              engines=[p["engine"], DenseConsensus(ring(N))], r=R,
              t_outer=T_OUTER, t_c=T_C, seeds=[0, 1], q_true=p["q_true"])
    mono = sdot_sweep(**kw)
    for chunk in (2, 4):
        res = sdot_sweep(chunk_size=chunk, **kw)
        np.testing.assert_array_equal(res.error_traces, mono.error_traces)
        np.testing.assert_array_equal(np.asarray(res.q), np.asarray(mono.q))


def test_fdot_sweep_chunked_matches_monolithic(problem):
    p = problem
    blocks = partition_features(p["x"], 4)
    eng = DenseConsensus(erdos_renyi(4, 0.9, seed=1))
    kw = dict(data_blocks=blocks, engines=eng, r=R, t_outer=6, t_c=T_C,
              seeds=[0, 1], q_true=p["q_true"])
    mono = fdot_sweep(**kw)
    res = fdot_sweep(chunk_size=2, **kw)
    np.testing.assert_array_equal(res.error_traces, mono.error_traces)


def test_baseline_sweep_chunked_matches_monolithic(problem):
    p = problem
    kw = dict(covs=p["covs"], engine=p["engine"], r=R, t_outer=T_OUTER,
              seeds=[0, 1], q_true=p["q_true"])
    mono = baseline_sweep("dsa", **kw)
    res = baseline_sweep("dsa", chunk_size=3, **kw)
    np.testing.assert_array_equal(res.error_traces, mono.error_traces)


def test_killed_sweep_returns_prefix(problem):
    p = problem
    res = sdot_sweep(covs=p["covs"], engines=p["engine"], r=R,
                     t_outer=T_OUTER, t_c=T_C, seeds=[0, 1],
                     q_true=p["q_true"], chunk_size=4, max_chunks=1)
    assert res.steps_done == 4
    assert res.error_traces.shape == (2, 4)
    full = sdot_sweep(covs=p["covs"], engines=p["engine"], r=R,
                      t_outer=T_OUTER, t_c=T_C, seeds=[0, 1],
                      q_true=p["q_true"])
    np.testing.assert_array_equal(res.error_traces,
                                  full.error_traces[:, :4])
