"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
hypothesis sweeps over shapes and dtypes as required for every kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gram_update import gram_apply_pallas


# ---------------------------------------------------------------------------
# gram_apply: V = X (X^T Q) / n
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(
    d=st.sampled_from([16, 64, 128]),
    n=st.integers(10, 700),
    r=st.sampled_from([4, 16, 128]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 1000),
)
def test_gram_apply_matches_ref(d, n, r, dtype, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (d, n), jnp.float32).astype(dtype)
    q = jax.random.normal(k2, (d, r), jnp.float32).astype(dtype)
    out = ops.gram_apply(x, q, block_n=256, use_pallas=True)
    want = ref.gram_apply_ref(x, q)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_gram_apply_padding_exact():
    """n not a multiple of block_n: zero-padding must not change the result."""
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 513))
    q = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    out = ops.gram_apply(x, q, block_n=256, use_pallas=True)
    want = ref.gram_apply_ref(x, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


def test_gram_apply_kernel_direct():
    """Direct pallas_call path (no wrapper) on an aligned shape."""
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 1024))
    q = jax.random.normal(jax.random.PRNGKey(3), (128, 128))
    v = gram_apply_pallas(x, q, block_n=256, interpret=True)
    want = ref.gram_apply_ref(x, q, normalize=False)
    np.testing.assert_allclose(np.asarray(v), np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-3)


def test_gram_apply_equals_explicit_covariance():
    """The kernel IS Step 5 of Alg. 1: X(X^T Q)/n == (XX^T/n) Q."""
    x = jax.random.normal(jax.random.PRNGKey(4), (24, 512))
    q = jax.random.normal(jax.random.PRNGKey(5), (24, 4))
    m = x @ x.T / x.shape[1]
    out = ops.gram_apply(x, q, use_pallas=True, block_n=256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(m @ q), rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    hq=st.sampled_from([2, 4]),
    gqa=st.sampled_from([1, 2]),
    sq=st.sampled_from([128, 256, 300]),
    hd=st.sampled_from([32, 64]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 100),
)
def test_flash_attention_matches_ref(b, hq, gqa, sq, hd, dtype, seed):
    hkv = hq // gqa
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, hkv, sq, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, hkv, sq, hd), jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=True, use_pallas=True)
    kx = jnp.repeat(k, gqa, 1)
    vx = jnp.repeat(v, gqa, 1)
    want = ref.flash_attention_ref(q, kx, vx, causal=True)
    tol = 3e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 256, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 256, 32))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              use_pallas=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_cross_lengths():
    """Decode-style: sq < skv, positions aligned at the end."""
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 128, 32))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 384, 32))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 384, 32))
    out = ops.flash_attention(q, k, v, causal=True, use_pallas=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_small_falls_back():
    """Below one block the wrapper must use the oracle (still correct)."""
    q = jax.random.normal(jax.random.PRNGKey(6), (1, 2, 17, 16))
    k = jax.random.normal(jax.random.PRNGKey(7), (1, 2, 17, 16))
    v = jax.random.normal(jax.random.PRNGKey(8), (1, 2, 17, 16))
    out = ops.flash_attention(q, k, v, causal=True, use_pallas=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_flash_attention_rows_sum_to_one_property():
    """Output of attention over constant V equals that constant (softmax
    weights sum to 1 — catches masking/normalization bugs)."""
    q = jax.random.normal(jax.random.PRNGKey(9), (1, 2, 256, 32))
    k = jax.random.normal(jax.random.PRNGKey(10), (1, 2, 256, 32))
    v = jnp.ones((1, 2, 256, 32))
    out = ops.flash_attention(q, k, v, causal=True, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# gram_qr: G = V^T V (CholeskyQR hot matmul)
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(
    d=st.integers(10, 3000),
    r=st.sampled_from([2, 8, 64]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 1000),
)
def test_gram_qr_matches_ref(d, r, dtype, seed):
    from repro.kernels.gram_qr import gram_qr_pallas  # noqa: F401
    v = jax.random.normal(jax.random.PRNGKey(seed), (d, r),
                          jnp.float32).astype(dtype)
    out = ops.gram_qr(v, block_d=512, use_pallas=True)
    want = ref.gram_qr_ref(v)
    tol = 2e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol * max(d, 1))


def test_gram_qr_symmetric_psd():
    v = jax.random.normal(jax.random.PRNGKey(1), (2048, 16))
    g = np.asarray(ops.gram_qr(v, use_pallas=True))
    np.testing.assert_allclose(g, g.T, rtol=1e-6)
    assert np.linalg.eigvalsh(g).min() > -1e-3
