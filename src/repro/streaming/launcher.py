"""Multi-host Monte-Carlo sweep launcher with chaos-hardened supervision.

``core/sweep.py`` collapses a seeds x cases grid into one compiled program —
for one process.  This module shards that grid over *hosts* (subprocess
workers standing in for hosts in this container; the same spec/result
protocol maps onto one job per machine on a real fleet):

    launch_sweep(...)
      -> writes <workdir>/spec.json (topologies, schedules, shard seed
         lists — everything a worker needs to rebuild its slice) and
         <workdir>/problem.npz (cov stacks, optional ground truth)
      -> runs the case x seed grid as ``n_shards`` leasable shards
         (``core.sweep.slice_seed_shards``) over ``n_workers`` subprocess
         workers; each worker publishes its shard result atomically
         (checkpoint/manager.save_tree, CommLedger riding along as a
         registered pytree) into <workdir>/worker_<shard>/
      -> gathers the shard results and merges them along the seed axis
         into ONE SweepResult, equal to the single-process ``sdot_sweep``
         over the full seed list (lane-slices are arithmetically
         identical; XLA may schedule a width-1 vmap differently, so
         equality is pinned at float32 epsilon in tests/test_streaming.py
         and bit-for-bit when shard widths match the full sweep's).

Supervision is a CONCURRENT POLL LOOP, not a serial join: every worker is
polled against one shared deadline, a dead process is detected within one
poll interval, and a wedged-but-alive worker is detected by a stale
heartbeat (workers touch ``worker_<shard>/heartbeat`` at every chunk
boundary) and killed. Failed shards retry under a per-shard budget with
exponential backoff + jitter. A fleet of stragglers can therefore no
longer stall the launcher for ``n_workers x timeout`` — the old serial
``communicate(timeout=...)`` pass charged the full timeout to each worker
in turn.

``elastic=True`` switches to lease-based fleet execution
(``streaming/fleet.py``): workers are not pinned to shards but acquire
lease files (fencing tokens under ``<workdir>/leases/``), a worker that
finishes its shard STEALS the stalest expired lease and resumes the
victim's checkpointed sweep-RunState mid-grid, and membership is elastic —
start another ``python -m repro.streaming.worker <spec> --fleet`` at any
time to join a running sweep; a worker that dies simply lets its lease
expire. Because shard results are deterministic and published atomically,
stealing/duplication never changes the merged bits.

``chaos_plan`` injects a seeded ``streaming.chaos.FaultPlan`` into the
workers (SIGKILL at chunk boundaries, torn checkpoints, stragglers,
dropped results) via the ``REPRO_CHAOS_PLAN`` env var — the CI chaos-smoke
job asserts the merged result under faults equals the fault-free sweep.

Shard-granular fault tolerance: a shard that already published a valid
result is never recomputed (so a killed launcher resumes where it left
off), a crashed shard is retried with backoff, and only then does the
launch fail.

Topologies/schedules travel as small JSON specs (``build_engine`` /
``build_schedule``) because graph constructions are seed-deterministic —
the paper's experiment grid is fully reproducible from the spec file.
"""
from __future__ import annotations

import hashlib
import json
import os
import random
import shutil
import subprocess
import sys
import time
import zipfile
from typing import Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import restore_tree
from ..core.consensus import DenseConsensus, consensus_schedule
from ..core.metrics import CommLedger
from ..core.sweep import SweepResult, slice_seed_shards
from ..core.topology import complete, erdos_renyi, ring, star, torus2d
from ..obs import Journal, obs_dir_for
from .chaos import (ENV_PLAN, FaultPlan, net_faults_from_env,
                    validate_net_fault_doc)
from .fleet import LeaseStore, read_heartbeat

__all__ = ["build_engine", "build_schedule", "launch_sweep"]

_SPEC = "spec.json"
_PROBLEM = "problem.npz"
_CHAOS_PLAN = "chaos_plan.json"

# restore-time failure modes we EXPECT from an absent/stale/torn shard:
# missing files, truncated npz payloads, tree-structure mismatches. Anything
# else is surfaced on the resume report instead of silently recomputed.
_EXPECTED_RESTORE_ERRORS = (OSError, ValueError, KeyError, EOFError,
                            zipfile.BadZipFile)


def build_engine(topo: dict) -> DenseConsensus:
    """Topology spec -> consensus engine (seed-deterministic across hosts)."""
    kind = topo["kind"]
    if kind == "ring":
        g = ring(topo["n"])
    elif kind == "star":
        g = star(topo["n"])
    elif kind == "complete":
        g = complete(topo["n"])
    elif kind == "torus2d":
        g = torus2d(topo["rows"], topo["cols"])
    elif kind == "er":
        g = erdos_renyi(topo["n"], topo["p"], seed=topo.get("seed", 0))
    else:
        raise ValueError(f"unknown topology kind: {kind}")
    return DenseConsensus(g)


def build_schedule(sched: Optional[dict], t_outer: int,
                   t_c: int) -> np.ndarray:
    """Schedule spec -> (t_outer,) consensus budgets."""
    if sched is None:
        return consensus_schedule("const", t_outer, t_max=t_c)
    if "values" in sched:
        return np.asarray(sched["values"])[:t_outer]
    return consensus_schedule(sched["kind"], t_outer,
                              t_max=sched.get("t_max", t_c),
                              cap=sched.get("cap"))


def _worker_dir(workdir: str, shard: int) -> str:
    return os.path.join(workdir, f"worker_{shard}")


def _result_dir(workdir: str, shard: int) -> str:
    return os.path.join(_worker_dir(workdir, shard), "result")


def _heartbeat_path(workdir: str, shard: int) -> str:
    return os.path.join(_worker_dir(workdir, shard), "heartbeat")


def spec_fingerprint(spec: dict) -> int:
    """Stable 31-bit digest of the sweep spec (int32-safe: jax x64 is off).

    Stamped into every worker's published result and checked before a
    shard is reused, so rerunning a workdir with a *changed* spec (more
    seeds, different cases/t_outer) relaunches instead of silently merging
    stale shards. ``sweep_chunk`` is excluded: chunking is bit-exact by
    construction, so a resume may change the chunk size without
    invalidating published shards."""
    blob = json.dumps({k: v for k, v in spec.items() if k != "sweep_chunk"},
                      sort_keys=True).encode()
    return int.from_bytes(hashlib.sha256(blob).digest()[:4], "big") >> 1


def _result_like(spec: dict, with_resumed: bool = True):
    """Structure template for restore_tree (values are ignored)."""
    like = {"q": jnp.zeros(()), "seeds": jnp.zeros(()),
            "ledger": CommLedger(),
            "spec_fp": jnp.zeros((), jnp.int32)}
    if with_resumed:
        like["resumed_steps"] = jnp.zeros((), jnp.int32)
    if spec["has_q_true"]:
        like["error_traces"] = jnp.zeros(())
    if spec["ragged"]:
        like["node_counts"] = jnp.zeros(())
    return like


def _load_result(workdir: str, spec: dict, shard: int,
                 unexpected: Optional[dict] = None):
    """The shard's published result, or None if absent/stale/corrupt.

    A result published under a different spec (stale workdir reuse) fails
    either the tree-structure check or the fingerprint comparison and is
    discarded so the launcher recomputes it. Results published before the
    ``resumed_steps`` leaf existed still restore (never recompute a valid
    shard over a reporting field) and report 0.

    Only the EXPECTED restore failure modes are swallowed; anything else is
    recorded in ``unexpected`` (shard -> repr) so the launcher can surface
    it on the resume report instead of recomputing a possibly-valid shard
    without explanation."""
    path = _result_dir(workdir, shard)
    if not os.path.exists(os.path.join(path, "manifest.json")):
        return None
    tree = None
    for with_resumed in (True, False):
        try:
            tree = restore_tree(path, _result_like(spec, with_resumed))
            break
        except _EXPECTED_RESTORE_ERRORS:
            continue
        except Exception as e:                   # noqa: BLE001 — surfaced
            if unexpected is not None:
                unexpected[shard] = f"{type(e).__name__}: {e}"
            return None
    if tree is None:
        return None
    if int(tree["spec_fp"]) != spec_fingerprint(spec):
        return None
    tree.setdefault("resumed_steps", 0)
    return tree


def _spawn(args, env, log_path) -> subprocess.Popen:
    """Spawn a worker with stdout+stderr appended to ``log_path`` (a fleet
    can't funnel every worker through launcher pipes — full pipes would
    wedge exactly the workers we are supervising)."""
    os.makedirs(os.path.dirname(log_path), exist_ok=True)
    log = open(log_path, "ab")
    try:
        return subprocess.Popen(
            [sys.executable, "-m", "repro.streaming.worker", *args],
            stdout=log, stderr=log, env=env)
    finally:
        log.close()


def _tail(log_path: str, n: int = 2000) -> str:
    try:
        with open(log_path, "rb") as f:
            return f.read()[-n:].decode(errors="replace")
    except OSError:
        return "<no worker log>"


def _trace_tail(workdir: str, proc: str, n: int = 8) -> str:
    """The worker's journal tail — last spans plus any span left OPEN at
    death — so a failure report says what PHASE the worker died in, not
    just its last stdout lines. Empty-string when tracing is off or the
    worker never journaled."""
    from ..obs.cli import forensics_report
    obs_dir = obs_dir_for(workdir)
    if obs_dir is None or not os.path.isdir(obs_dir):
        return ""
    try:
        text, _ = forensics_report(obs_dir, last=n, proc=proc)
    except Exception:
        return ""
    return text.strip()


def _fail_report(workdir: str, proc: str, log_path: str) -> str:
    """stderr tail + journal tail, the launcher's full failure context."""
    out = f"last log tail:\n{_tail(log_path)}"
    trace = _trace_tail(workdir, proc)
    if trace:
        out += f"\njournal tail ({proc}):\n{trace}"
    return out


def _backoff(base: float, attempt: int, rng: random.Random) -> float:
    """Exponential backoff with jitter: base * 2^(attempt-1) * U[1, 1.25]."""
    return base * (2.0 ** max(0, attempt - 1)) * (1.0 + 0.25 * rng.random())


# ---------------------------------------------------------------------------
# supervision loops
# ---------------------------------------------------------------------------
def _supervise_pinned(spec_path, workdir, spec, pending, env, *, n_workers,
                      retries, timeout, stall_timeout, backoff_base,
                      poll_interval, results, unexpected, attempts,
                      journal=None):
    """Shard-pinned supervision: one worker process per pending shard,
    polled concurrently against one shared deadline (no serial
    ``communicate(timeout)`` accounting), stale-heartbeat kills, retry
    budgets with exponential backoff + jitter."""
    jl = journal if journal is not None else Journal.noop()
    rng = random.Random(0xC0FFEE)
    t0 = time.monotonic()
    deadline = t0 + timeout
    pending = set(pending)
    next_spawn = {i: 0.0 for i in pending}
    procs, spawn_wall, last_log = {}, {}, {}
    try:
        while pending:
            now = time.monotonic()
            if now > deadline:
                raise RuntimeError(
                    f"sweep launch exceeded its shared deadline "
                    f"({timeout:.0f}s) with shards {sorted(pending)} "
                    f"unfinished")
            # spawn/respawn shards whose backoff has elapsed, bounded by
            # the worker-slot budget (n_shards may exceed n_workers)
            for i in sorted(pending - set(procs)):
                if len(procs) >= n_workers:
                    break
                if now < next_spawn[i]:
                    continue
                log = os.path.join(_worker_dir(workdir, i),
                                   f"log_{attempts[i]}.txt")
                last_log[i] = log
                procs[i] = _spawn([spec_path, str(i)], env, log)
                spawn_wall[i] = time.time()
                jl.event("spawn", "launcher", shard=i,
                         launch_attempt=attempts[i], pid_child=procs[i].pid)
            reaped = []
            for i, p in procs.items():
                rc = p.poll()
                if rc is None and stall_timeout:
                    # heartbeats are PROGRESS beats (touched at chunk
                    # boundaries), so a worker becomes stall-killable only
                    # once it has beaten during THIS attempt — startup
                    # (jax import + compile) must not read as a stall, and
                    # a stale file from the previous attempt must not kill
                    # a fresh worker. Process death is caught by poll();
                    # the shared deadline backstops a worker that wedges
                    # before its first boundary.
                    try:
                        beat = os.path.getmtime(_heartbeat_path(workdir, i))
                    except OSError:
                        beat = None
                    if (beat is not None and beat > spawn_wall[i]
                            and time.time() - beat > stall_timeout):
                        # stall diagnostics carry the heartbeat's step
                        # payload — WHERE the worker went quiet, not just
                        # how long ago
                        hb = read_heartbeat(_heartbeat_path(workdir, i))
                        hb_step = None if hb is None else hb.get("step")
                        age = time.time() - beat
                        print(f"launcher: shard {i} heartbeat {age:.1f}s "
                              f"stale (last step "
                              f"{'?' if hb_step is None else hb_step}) — "
                              f"killing wedged worker")
                        jl.event("stall_kill", "launcher", shard=i,
                                 beat_age_s=round(age, 3), step=hb_step)
                        p.kill()
                        p.wait()
                        rc = p.returncode
                if rc is None:
                    continue
                reaped.append(i)
                # a worker may die AFTER publishing (e.g. killed between
                # publish and cleanup) — the published result always wins,
                # so load regardless of the exit code
                res = _load_result(workdir, spec, i, unexpected)
                attempts[i] += 1
                if res is not None:
                    results[i] = res
                    pending.discard(i)
                    jl.event("shard_done", "launcher", shard=i,
                             launch_attempts=attempts[i], rc=rc)
                    continue
                if attempts[i] > retries:
                    raise RuntimeError(
                        f"sweep shard {i} failed after {retries + 1} "
                        f"attempts; "
                        f"{_fail_report(workdir, f'worker_s{i}', last_log[i])}")
                next_spawn[i] = now + _backoff(backoff_base, attempts[i],
                                               rng)
                jl.event("retry", "launcher", shard=i, rc=rc,
                         launch_attempt=attempts[i],
                         backoff_s=round(next_spawn[i] - now, 3))
            for i in reaped:
                procs.pop(i)
            if pending:
                time.sleep(poll_interval)
    finally:
        for p in procs.values():
            if p.poll() is None:
                p.kill()


def _supervise_elastic(spec_path, workdir, spec, pending, env, *, n_workers,
                       retries, timeout, lease_ttl, backoff_base,
                       poll_interval, results, unexpected, attempts,
                       journal=None):
    """Elastic fleet supervision: ``n_workers`` un-pinned fleet workers
    lease-and-steal shards; the launcher only keeps worker SLOTS alive
    (respawning dead ones under a per-slot budget) and polls for published
    shard results. Extra workers may join from outside at any time; a
    worker leaving is just its leases expiring."""
    jl = journal if journal is not None else Journal.noop()
    rng = random.Random(0xE1A571C)
    deadline = time.monotonic() + timeout
    pending = set(pending)
    slot_attempts = {s: 0 for s in range(n_workers)}
    next_spawn = {s: 0.0 for s in range(n_workers)}
    procs, last_log = {}, {}
    try:
        while pending:
            now = time.monotonic()
            if now > deadline:
                raise RuntimeError(
                    f"elastic sweep launch exceeded its deadline "
                    f"({timeout:.0f}s) with shards {sorted(pending)} "
                    f"unfinished")
            for s in range(n_workers):
                p = procs.get(s)
                if p is not None:
                    if p.poll() is None:
                        continue
                    # a fleet worker exits 0 only once every shard is
                    # published; an exit with work still pending — clean or
                    # not — consumes this slot's retry budget
                    rc = p.returncode
                    procs.pop(s)
                    slot_attempts[s] += 1
                    jl.event("slot_exit", "launcher", slot=s, rc=rc,
                             slot_attempts=slot_attempts[s])
                    if slot_attempts[s] > retries:
                        continue  # slot exhausted; others may still finish
                    next_spawn[s] = now + _backoff(backoff_base,
                                                   slot_attempts[s], rng)
                    continue
                if now < next_spawn[s]:
                    continue
                log = os.path.join(workdir, f"fleet_w{s}",
                                   f"log_{slot_attempts[s]}.txt")
                last_log[s] = log
                procs[s] = _spawn(
                    [spec_path, "--fleet", "--worker", f"w{s}",
                     "--ttl", str(lease_ttl)], env, log)
                jl.event("spawn", "launcher", slot=s,
                         launch_attempt=slot_attempts[s],
                         pid_child=procs[s].pid)
            for i in sorted(pending):
                res = _load_result(workdir, spec, i, unexpected)
                if res is not None:
                    results[i] = res
                    attempts[i] += 1       # shard completed on some attempt
                    pending.discard(i)
            if pending:
                if not procs and all(a > retries
                                     for a in slot_attempts.values()):
                    tails = "\n".join(
                        _fail_report(workdir, f"fleet_w{s}", l)
                        for s, l in last_log.items())
                    raise RuntimeError(
                        f"all {n_workers} fleet worker slots exhausted "
                        f"their {retries + 1}-attempt budgets with shards "
                        f"{sorted(pending)} unfinished;\n{tails}")
                time.sleep(poll_interval)
    finally:
        # every shard is published (or we raised) — surviving fleet workers
        # are draining their own exit path; don't leave orphans behind
        for p in procs.values():
            if p.poll() is None:
                p.kill()


# ---------------------------------------------------------------------------
# launch
# ---------------------------------------------------------------------------
def launch_sweep(
    *,
    covs,
    cases: Sequence[dict],
    r: int,
    t_outer: int,
    t_c: int = 50,
    seeds: Sequence[int],
    q_true=None,
    workdir: str,
    n_workers: int = 2,
    n_shards: Optional[int] = None,
    retries: int = 1,
    timeout: float = 900.0,
    sweep_chunk: Optional[int] = None,
    elastic: bool = False,
    stall_timeout: Optional[float] = None,
    lease_ttl: float = 30.0,
    backoff_base: float = 0.5,
    poll_interval: float = 0.2,
    chaos_plan: Union[FaultPlan, dict, str, None] = None,
    net_faults: Union[dict, str, None] = None,
) -> SweepResult:
    """Shard a ``sdot_sweep`` case x seed grid over supervised workers.

    ``covs``: one (N, d, d) stack shared by every case, or a list with one
    stack per case (ragged node counts allowed — the workers run the same
    identity-padding path as single-process ``sdot_sweep``).  ``cases``:
    list of ``{"topology": {...}, "schedule": {...}}`` specs (see
    ``build_engine`` / ``build_schedule``).  The seed axis is split
    contiguously into ``n_shards`` lease-granular shards (default: one per
    worker), so the merged result preserves seed order and equals the
    single-process sweep exactly.

    Supervision (see module docstring): all workers are polled against ONE
    shared ``timeout`` deadline; a dead worker is respawned after
    exponential backoff with jitter under a ``retries`` budget; with
    ``sweep_chunk`` set, a worker whose heartbeat goes quiet for
    ``stall_timeout`` seconds (default 60; pass 0 to disable) is killed
    and retried. ``elastic=True`` runs un-pinned fleet workers that lease,
    steal, and resume shards (``lease_ttl`` controls when a silent shard
    becomes stealable) — workers can join or leave mid-sweep.

    ``sweep_chunk`` turns on MID-GRID fault tolerance: each worker runs its
    shard through the runtime's chunked driver, checkpointing the
    sweep-RunState into ``worker_<shard>/ckpt`` every ``sweep_chunk`` outer
    iterations — a killed (or robbed) worker resumes from the checkpoint
    (bitwise equal to the uninterrupted sweep) instead of recomputing its
    shard. The returned ``SweepResult.resume_report`` records reused
    shards, per-shard restored steps and attempt counts, stolen shards
    (elastic), and any unexpected restore errors.

    ``chaos_plan`` (a ``FaultPlan``, its dict form, or a path to one)
    injects seeded faults into the workers for robustness testing.

    ``net_faults`` (a net-fault document dict, or a path to one) makes
    every worker run its shard through ``core.netfaults.FaultyConsensus``
    — seeded link drops / bursty outages / crash-rejoin / payload
    corruption inside the gossip itself, with realized-mixing debias.
    Defaults from the ``REPRO_NET_FAULTS`` env var; the document enters
    the spec (and thus the fingerprint), so changing the fault model
    invalidates published shards just like changing the grid would.
    """
    os.makedirs(workdir, exist_ok=True)
    seeds = [int(s) for s in seeds]
    n_workers = max(1, min(int(n_workers), len(seeds)))
    shards = slice_seed_shards(seeds, n_shards if n_shards else n_workers)
    n_shards = len(shards)

    ragged = isinstance(covs, (list, tuple))
    if ragged and len(covs) not in (1, len(cases)):
        # enforce sdot_sweep's zip-broadcast contract before anything is
        # written, rather than as a KeyError inside every worker; a
        # 1-element list is written ONCE (not duplicated per case) and
        # broadcast worker-side by sdot_sweep itself
        raise ValueError(f"per-case covs must zip-broadcast with the "
                         f"cases: got {len(covs)} cov stacks for "
                         f"{len(cases)} cases")
    if net_faults is None:
        net_faults = net_faults_from_env()
    elif isinstance(net_faults, str):
        if net_faults.lstrip().startswith("{"):
            net_faults = json.loads(net_faults)
        else:
            with open(net_faults) as f:
                net_faults = json.load(f)
    if net_faults is not None:
        validate_net_fault_doc(net_faults)
        if ragged:
            # FaultyConsensus pre-samples (T, N, N) edge masks per case
            # lane; a ragged grid has no single N to sample against
            raise ValueError("net_faults requires a uniform node count "
                             "across cases (ragged per-case covs given)")
    if elastic and sweep_chunk is None:
        # stealing without checkpoints would recompute stolen shards from
        # scratch; default to chunked execution so a steal resumes mid-grid
        sweep_chunk = max(1, int(t_outer) // 5)
    spec = {
        "algo": "sdot",
        "r": int(r),
        "t_outer": int(t_outer),
        "t_c": int(t_c),
        "cases": list(cases),
        "shards": shards,
        "ragged": ragged,
        "n_cov_stacks": len(covs) if ragged else 1,
        "has_q_true": q_true is not None,
        "sweep_chunk": int(sweep_chunk) if sweep_chunk else None,
    }
    if net_faults is not None:
        # inside the spec -> inside spec_fingerprint: a changed fault
        # model invalidates published shards and intermediate checkpoints
        spec["net_faults"] = net_faults
    spec_path = os.path.join(workdir, _SPEC)
    with open(spec_path, "w") as f:
        json.dump(spec, f, indent=2)

    # a changed spec invalidates the workers' intermediate sweep
    # checkpoints (published results carry their own fingerprint stamp;
    # the ckpt dirs don't, so they are guarded here at the workdir level)
    fp = str(spec_fingerprint(spec))
    fp_path = os.path.join(workdir, "spec_fp")
    if os.path.exists(fp_path):
        with open(fp_path) as f:
            if f.read().strip() != fp:
                for name in os.listdir(workdir):
                    ckpt = os.path.join(workdir, name, "ckpt")
                    if name.startswith("worker_") and os.path.isdir(ckpt):
                        shutil.rmtree(ckpt, ignore_errors=True)
                shutil.rmtree(os.path.join(workdir, "leases"),
                              ignore_errors=True)
    with open(fp_path, "w") as f:
        f.write(fp)

    arrays = {}
    if ragged:
        for ci, c in enumerate(covs):
            arrays[f"covs_{ci}"] = np.asarray(c)
    else:
        arrays["covs"] = np.asarray(covs)
    if q_true is not None:
        arrays["q_true"] = np.asarray(q_true)
    np.savez(os.path.join(workdir, _PROBLEM), **arrays)

    env = dict(os.environ)
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    if chaos_plan is not None:
        if isinstance(chaos_plan, dict):
            chaos_plan = FaultPlan(chaos_plan.get("faults", []),
                                   seed=chaos_plan.get("seed", 0))
        if hasattr(chaos_plan, "dump"):   # FaultPlan, possibly the
            # __main__-module twin when chaos.py runs as a script
            chaos_plan = chaos_plan.dump(os.path.join(workdir, _CHAOS_PLAN))
        env[ENV_PLAN] = str(chaos_plan)
    else:
        env.pop(ENV_PLAN, None)

    if stall_timeout is None:
        stall_timeout = 60.0 if sweep_chunk else 0.0

    # published shards are reused only if their stamped spec fingerprint
    # matches; stale/corrupt ones are cleared and recomputed
    unexpected: dict = {}
    results = {i: _load_result(workdir, spec, i, unexpected)
               for i in range(n_shards)}
    pending = [i for i, t in results.items() if t is None]
    reused = sorted(i for i, t in results.items() if t is not None)
    for i in pending:
        shutil.rmtree(_result_dir(workdir, i), ignore_errors=True)
    attempts = {i: 0 for i in range(n_shards)}
    if pending:
        # the launcher keeps its OWN journal (not the process-global one:
        # launch_sweep is a library call — tests and services drive it from
        # processes whose journal belongs to them)
        obs_dir = obs_dir_for(workdir)
        jl = (Journal.open(obs_dir, "launcher") if obs_dir is not None
              else Journal.noop())
        supervise = _supervise_elastic if elastic else _supervise_pinned
        kw = ({"lease_ttl": lease_ttl} if elastic
              else {"stall_timeout": stall_timeout})
        try:
            with jl.span("supervise", "launcher", n_shards=n_shards,
                         n_workers=n_workers, elastic=elastic,
                         pending=sorted(pending),
                         chaos=chaos_plan is not None):
                supervise(spec_path, workdir, spec, pending, env,
                          n_workers=n_workers, retries=retries,
                          timeout=timeout, backoff_base=backoff_base,
                          poll_interval=poll_interval, results=results,
                          unexpected=unexpected, attempts=attempts,
                          journal=jl, **kw)
        finally:
            jl.close()

    # gather + merge along the seed axis (shards are contiguous slices)
    trees = [results[i] for i in range(n_shards)]
    resumed_steps = {i: int(t["resumed_steps"]) for i, t in enumerate(trees)}
    report = {
        # shards whose published result was reused wholesale — their whole
        # case x seed sub-grid was skipped
        "reused_shards": reused,
        "skipped_grid_points": sum(len(shards[i]) for i in reused)
        * len(cases),
        # outer step each shard's restored sweep-RunState already carried
        # (0 = computed from scratch)
        "worker_resumed_steps": resumed_steps,
        # attempts this launch spent per shard (0 = reused, 1 = first try)
        "attempts": attempts,
    }
    if unexpected:
        report["load_errors"] = dict(unexpected)
    if elastic:
        leases = LeaseStore(workdir, ttl=lease_ttl).snapshot()
        report["lease_owners"] = {s: l.owners for s, l in leases.items()}
        report["stolen_shards"] = sorted(
            s for s, l in leases.items() if len(set(l.owners)) > 1)
    return SweepResult.merge_shards(
        trees, n_cases=len(cases), has_err=spec["has_q_true"],
        ragged=spec["ragged"], resume_report=report)
