"""Graphs, weight matrices, mixing time — the substrate of every consensus."""
import numpy as np
import pytest

from repro.core.topology import (Graph, complete, erdos_renyi,
                                 local_degree_weights, metropolis_weights,
                                 mixing_time, ring, spectral_gap, star,
                                 torus2d)


@pytest.mark.parametrize("maker,n", [
    (lambda: erdos_renyi(20, 0.25, seed=0), 20),
    (lambda: ring(11), 11),
    (lambda: star(20), 20),
    (lambda: torus2d(4, 4), 16),
    (lambda: complete(8), 8),
])
def test_graph_basic(maker, n):
    g = maker()
    a = g.adjacency
    assert a.shape == (n, n)
    assert np.allclose(a, a.T), "adjacency must be symmetric"
    assert np.all(np.diag(a) == 0), "no self loops"
    assert g.is_connected()


def test_er_respects_p_extremes():
    g1 = erdos_renyi(12, 1.0, seed=3)
    assert g1.n_edges == 12 * 11 // 2
    # p small: still connected by resampling guarantee
    g2 = erdos_renyi(12, 0.15, seed=3)
    assert g2.is_connected()


@pytest.mark.parametrize("g", [erdos_renyi(20, 0.25, seed=0), ring(9),
                               star(10), torus2d(3, 5)])
def test_local_degree_weights_doubly_stochastic(g):
    w = local_degree_weights(g)
    assert np.allclose(w.sum(0), 1.0, atol=1e-12)
    assert np.allclose(w.sum(1), 1.0, atol=1e-12)
    assert np.all(w >= -1e-15)
    # support matches the graph (plus the diagonal)
    assert np.all((w > 1e-12)[~np.eye(g.n_nodes, dtype=bool)] <= (g.adjacency > 0)[~np.eye(g.n_nodes, dtype=bool)])


def test_metropolis_weights_doubly_stochastic():
    g = erdos_renyi(15, 0.3, seed=2)
    w = metropolis_weights(g)
    assert np.allclose(w.sum(0), 1.0)
    assert np.allclose(w.sum(1), 1.0)


def test_mixing_time_periodic_chain_is_none():
    """Paper §V: a periodic chain has tau_mix -> inf (returned as None).
    The 2-cycle swap matrix is the canonical periodic chain: e_1 W^t
    alternates between the two vertices and never approaches uniform."""
    pure = np.array([[0.0, 1.0], [1.0, 0.0]])
    assert mixing_time(pure, max_t=2000) is None
    # local-degree weights keep w_ii > 0 => aperiodic => mixes (slowly)
    assert mixing_time(local_degree_weights(ring(20)), max_t=100000) is not None


def test_mixing_time_ordering_with_connectivity():
    """Denser ER graphs mix faster (paper Table II narrative)."""
    t_dense = mixing_time(local_degree_weights(erdos_renyi(20, 0.5, seed=0)))
    t_sparse = mixing_time(local_degree_weights(erdos_renyi(20, 0.1, seed=0)))
    assert t_dense is not None and t_sparse is not None
    assert t_dense <= t_sparse


def test_spectral_gap_complete_is_best():
    gaps = {
        "complete": spectral_gap(local_degree_weights(complete(12))),
        "er.5": spectral_gap(local_degree_weights(erdos_renyi(12, 0.5, seed=0))),
        "ring": spectral_gap(local_degree_weights(ring(12))),
    }
    assert gaps["complete"] >= gaps["er.5"] >= gaps["ring"] > 0


def test_star_center_degree():
    g = star(20)
    assert g.degrees[0] == 19
    assert np.all(g.degrees[1:] == 1)
