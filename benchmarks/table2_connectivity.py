"""Table II / Fig. 2 — effect of ER connectivity p on P2P cost and the
convergence floor (denser graph -> more messages, better information
diffusion). Paper: N=20, r=5, gap 0.7, schedules {2t+1, 50}."""
from __future__ import annotations

from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.sdot import sdot
from repro.core.topology import erdos_renyi, local_degree_weights, mixing_time

from .common import Row, sample_problem, timed

N, R, T_O = 20, 5, 200


def run():
    rows = []
    covs, q_true = sample_problem(d=20, r=R, n_nodes=N, n_per=500, gap=0.7,
                                  seed=0)
    for p in (0.5, 0.25, 0.1):
        g = erdos_renyi(N, p, seed=1)
        eng = DenseConsensus(g)
        tau = mixing_time(local_degree_weights(g))
        for label, kind, cap in (("2t+1", "lin2", 50), ("50", "const", None)):
            sched = consensus_schedule(kind, T_O, t_max=50, cap=cap)
            res, us = timed(sdot, covs=covs, engine=eng, r=R, t_outer=T_O,
                            schedule=sched, q_true=q_true)
            rows.append(Row(
                f"table2/p{p}/Tc={label}", us,
                {"p2p_k": round(res.ledger.per_node_p2p(N) / 1e3, 2),
                 "tau_mix": tau,
                 "final_err": f"{res.error_trace[-1]:.2e}"}))
        # sparse graphs need the longer min(5t+1, 200) schedule (paper row)
        if p == 0.1:
            sched = consensus_schedule("lin5", T_O, cap=200)
            res, us = timed(sdot, covs=covs, engine=eng, r=R, t_outer=T_O,
                            schedule=sched, q_true=q_true)
            rows.append(Row(
                f"table2/p{p}/Tc=min(5t+1,200)", us,
                {"p2p_k": round(res.ledger.per_node_p2p(N) / 1e3, 2),
                 "tau_mix": tau,
                 "final_err": f"{res.error_trace[-1]:.2e}"}))
    return rows
