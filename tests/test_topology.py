"""Graphs, weight matrices, mixing time — the substrate of every consensus."""
import numpy as np
import pytest

from repro.core.topology import (Graph, barabasi_albert, complete,
                                 erdos_renyi, local_degree_weights,
                                 metropolis_weights, mixing_time,
                                 power_iteration_gap, random_geometric, ring,
                                 spectral_gap, star, torus2d,
                                 validate_adjacency, watts_strogatz)


@pytest.mark.parametrize("maker,n", [
    (lambda: erdos_renyi(20, 0.25, seed=0), 20),
    (lambda: ring(11), 11),
    (lambda: star(20), 20),
    (lambda: torus2d(4, 4), 16),
    (lambda: complete(8), 8),
    (lambda: watts_strogatz(30, k=4, p=0.2, seed=1), 30),
    (lambda: barabasi_albert(30, m=2, seed=1), 30),
    (lambda: random_geometric(30, seed=1), 30),
])
def test_graph_basic(maker, n):
    g = maker()
    a = g.adjacency
    assert a.shape == (n, n)
    assert np.allclose(a, a.T), "adjacency must be symmetric"
    assert np.all(np.diag(a) == 0), "no self loops"
    assert np.isin(a, (0, 1)).all()
    assert g.is_connected()


def test_er_respects_p_extremes():
    g1 = erdos_renyi(12, 1.0, seed=3)
    assert g1.n_edges == 12 * 11 // 2
    # p small: still connected by resampling guarantee
    g2 = erdos_renyi(12, 0.15, seed=3)
    assert g2.is_connected()


@pytest.mark.parametrize("g", [erdos_renyi(20, 0.25, seed=0), ring(9),
                               star(10), torus2d(3, 5)])
def test_local_degree_weights_doubly_stochastic(g):
    w = local_degree_weights(g)
    assert np.allclose(w.sum(0), 1.0, atol=1e-12)
    assert np.allclose(w.sum(1), 1.0, atol=1e-12)
    assert np.all(w >= -1e-15)
    # support matches the graph (plus the diagonal)
    assert np.all((w > 1e-12)[~np.eye(g.n_nodes, dtype=bool)] <= (g.adjacency > 0)[~np.eye(g.n_nodes, dtype=bool)])


def test_metropolis_weights_doubly_stochastic():
    g = erdos_renyi(15, 0.3, seed=2)
    w = metropolis_weights(g)
    assert np.allclose(w.sum(0), 1.0)
    assert np.allclose(w.sum(1), 1.0)


def test_mixing_time_periodic_chain_is_none():
    """Paper §V: a periodic chain has tau_mix -> inf (returned as None).
    The 2-cycle swap matrix is the canonical periodic chain: e_1 W^t
    alternates between the two vertices and never approaches uniform."""
    pure = np.array([[0.0, 1.0], [1.0, 0.0]])
    assert mixing_time(pure, max_t=2000) is None
    # local-degree weights keep w_ii > 0 => aperiodic => mixes (slowly)
    assert mixing_time(local_degree_weights(ring(20)), max_t=100000) is not None


def test_mixing_time_ordering_with_connectivity():
    """Denser ER graphs mix faster (paper Table II narrative)."""
    t_dense = mixing_time(local_degree_weights(erdos_renyi(20, 0.5, seed=0)))
    t_sparse = mixing_time(local_degree_weights(erdos_renyi(20, 0.1, seed=0)))
    assert t_dense is not None and t_sparse is not None
    assert t_dense <= t_sparse


def test_spectral_gap_complete_is_best():
    gaps = {
        "complete": spectral_gap(local_degree_weights(complete(12))),
        "er.5": spectral_gap(local_degree_weights(erdos_renyi(12, 0.5, seed=0))),
        "ring": spectral_gap(local_degree_weights(ring(12))),
    }
    assert gaps["complete"] >= gaps["er.5"] >= gaps["ring"] > 0


def test_star_center_degree():
    g = star(20)
    assert g.degrees[0] == 19
    assert np.all(g.degrees[1:] == 1)


def test_metropolis_distinct_from_local_degree_on_star():
    """The two weight rules differ exactly in the +1 laziness term: on a
    star, Metropolis gives every edge 1/(N-1) so the hub sheds ALL
    self-weight (w_00 = 0), while local-degree keeps w_00 = 1/N. A
    regression test for the bug where both rules shared one formula."""
    n = 10
    g = star(n)
    wm = metropolis_weights(g)
    wl = local_degree_weights(g)
    assert wm[0, 1] == pytest.approx(1.0 / (n - 1))
    assert wl[0, 1] == pytest.approx(1.0 / n)
    assert wm[0, 0] == pytest.approx(0.0)
    assert wl[0, 0] == pytest.approx(1.0 / n)
    assert not np.allclose(wm, wl)
    # both remain symmetric and doubly stochastic
    for w in (wm, wl):
        assert np.allclose(w, w.T)
        assert np.allclose(w.sum(1), 1.0)
        assert np.all(w >= -1e-15)


def test_ring_small_n():
    g2 = ring(2)
    assert g2.n_edges == 1                   # single edge, not double-counted
    assert np.array_equal(g2.adjacency, [[0, 1], [1, 0]])
    assert ring(1).n_edges == 0              # no self loop
    assert ring(0).n_nodes == 0


def test_validate_adjacency_rejections():
    with pytest.raises(ValueError, match="square"):
        validate_adjacency(np.zeros((3, 4)))
    bad = np.zeros((3, 3))
    bad[0, 1] = 1.0
    with pytest.raises(ValueError, match="symmetric"):
        Graph(bad)
    with pytest.raises(ValueError, match="diagonal"):
        Graph(np.eye(3))
    half = np.zeros((3, 3))
    half[0, 1] = half[1, 0] = 0.5
    with pytest.raises(ValueError, match="0 or 1"):
        Graph(half)


def test_watts_strogatz_degree_and_rewiring():
    g0 = watts_strogatz(40, k=4, p=0.0, seed=0)
    assert np.all(g0.degrees == 4)           # p=0: the pristine k-lattice
    g1 = watts_strogatz(40, k=4, p=0.5, seed=0)
    assert g1.n_edges == g0.n_edges          # rewiring preserves edge count
    assert not np.array_equal(g1.adjacency, g0.adjacency)


def test_barabasi_albert_is_hub_heavy():
    g = barabasi_albert(200, m=3, seed=0)
    deg = g.degrees
    # preferential attachment: max degree far above the median
    assert deg.max() >= 3 * np.median(deg)
    assert deg.min() >= 3


def test_power_iteration_gap_matches_exact():
    for g in (watts_strogatz(40, k=6, p=0.3, seed=2),
              barabasi_albert(40, m=2, seed=2)):
        w = local_degree_weights(g)
        exact = spectral_gap(w, method="exact")
        power = power_iteration_gap(lambda x: w @ x, g.n_nodes, iters=4000)
        assert abs(power - exact) < 1e-3
        assert abs(spectral_gap(w, method="power", iters=4000) - exact) < 1e-3


def test_mixing_time_bound_agrees_with_exact_order():
    w = local_degree_weights(erdos_renyi(24, 0.4, seed=1))
    t_exact = mixing_time(w)
    t_bound = mixing_time(w, method="bound")
    assert t_exact is not None and t_bound is not None
    # the contraction bound is conservative but the same order of magnitude
    assert t_exact <= t_bound <= 10 * t_exact + 5
