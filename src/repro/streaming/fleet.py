"""Fleet primitives: heartbeats, leasable shards, work stealing.

The sweep grid's unit of fault tolerance is the *shard* (a contiguous seed
slice of the case x seed grid, see ``core.sweep.slice_seed_shards``).  This
module makes shards **leasable** so fleet membership can be elastic:

* ``LeaseStore`` keeps one JSON lease per shard under
  ``<workdir>/leases/``. A lease carries a monotonically increasing
  **fencing token**, the current owner, renewal timestamps, and the owner
  history (every acquisition appends — stolen shards are visible in the
  resume report). Acquisition is write-then-verify: claimants atomically
  rename a nonce-stamped claim over the lease file and re-read it; the
  last rename wins and everyone else observes a foreign nonce and backs
  off. The residual split-brain window (A verifies before B renames) is
  HARMLESS here by construction: shard results are deterministic, and both
  checkpoint writes and the result publish are atomic renames of
  writer-unique tmp dirs — two owners can only duplicate work, never
  corrupt state or change the merged bits. The fencing token still fences
  *liveness*: a victim whose lease was stolen discovers the foreign token
  at its next chunk-boundary renewal and abandons the shard
  (``LeaseLost``) instead of computing to the end.

* **Heartbeats** are progress beats, not liveness timers: the worker
  touches ``<workdir>/worker_<shard>/heartbeat`` at every chunk boundary
  (wired through ``CheckpointManager.on_save``), so a wedged-but-alive
  worker goes stale and the launcher's supervision loop can kill and
  relaunch it in seconds — while plain process death is caught even faster
  by ``Popen.poll``.

* ``fleet_worker_loop`` is the elastic worker body: acquire any available
  shard (a lease we already hold first, then never-leased, then the
  STALEST expired lease — the straggler's), run it resuming from the
  victim's checkpointed
  sweep-RunState, publish, release, repeat; exit when every shard has a
  published result. Workers may join mid-sweep (just start another
  process: it takes leases) and leave mid-sweep (their leases expire and
  get stolen).
"""
from __future__ import annotations

import json
import os
import time
import uuid
from typing import Dict, List, Optional

from ..obs import get_journal

__all__ = ["LeaseLost", "LeaseStore", "Lease", "touch_heartbeat",
           "heartbeat_age", "read_heartbeat", "fleet_worker_loop"]

_LEASE_DIR = "leases"


class LeaseLost(RuntimeError):
    """Raised at a renewal that finds a foreign fencing token: the shard
    was stolen from us — stop computing it."""


def touch_heartbeat(path: str, step: int = 0) -> None:
    """Atomically (re)write the heartbeat file; staleness is its mtime."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = f"{path}.tmp-{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump({"pid": os.getpid(), "step": int(step),
                   "t": time.time()}, f)
    os.replace(tmp, path)


def heartbeat_age(path: str, now: Optional[float] = None) -> Optional[float]:
    """Seconds since the last beat, or None if no heartbeat exists yet."""
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return None
    return (time.time() if now is None else now) - mtime


def read_heartbeat(path: str) -> Optional[dict]:
    """The heartbeat's JSON payload ({pid, step, t}), or None if absent or
    torn mid-replace. ``touch_heartbeat`` has always written the worker's
    last completed step here — this reader surfaces it so stall-kill and
    stalest-lease diagnostics can say WHERE a silent worker stopped, not
    just how long ago (the mtime)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return None
    return doc if isinstance(doc, dict) else None


class Lease(dict):
    """A lease document (plain dict with typed accessors).

    Leases are stamped with BOTH clocks: ``renewed_at`` (wall) and
    ``renewed_mono`` (``time.monotonic()``). Expiry is computed from the
    monotonic pair whenever it is coherent — ``time.monotonic()`` is
    system-wide per boot, so any process on the same host can age a lease
    against its own monotonic reading, immune to NTP steps and operator
    ``date`` jumps that would make a wall-clock age negative (a live lease
    never expiring) or huge (a live lease instantly stolen). The wall
    stamp is the fallback for leases written by an older code version,
    read across a reboot (a monotonic stamp from a previous boot reads as
    the future — detected and ignored), or read on a different host.
    """

    @property
    def owner(self) -> str:
        return self.get("owner", "")

    @property
    def token(self) -> int:
        return int(self.get("token", 0))

    @property
    def renewed_at(self) -> float:
        return float(self.get("renewed_at", 0.0))

    @property
    def renewed_mono(self) -> Optional[float]:
        v = self.get("renewed_mono")
        return None if v is None else float(v)

    @property
    def owners(self) -> List[str]:
        return list(self.get("owners", []))

    def age(self, now: Optional[float] = None,
            now_mono: Optional[float] = None) -> float:
        """Seconds since the last renewal, from a jump-immune source.

        Prefers the monotonic pair when the stamp is coherent with our
        reading (not from a different boot/host, tolerating sub-second
        cross-process skew); falls back to wall-clock age otherwise."""
        mono = self.renewed_mono
        if mono is not None:
            nm = time.monotonic() if now_mono is None else now_mono
            if nm - mono >= -1.0:              # coherent monotonic pair
                return nm - mono
        return (time.time() if now is None else now) - self.renewed_at

    def expired(self, ttl: float, now: Optional[float] = None,
                now_mono: Optional[float] = None) -> bool:
        return self.age(now, now_mono) > ttl


class LeaseStore:
    """File-backed lease table, one lease per shard (see module docstring).

    All mutations are atomic renames; reads tolerate concurrent writers by
    treating an unreadable lease as absent (the writer will re-verify).
    """

    def __init__(self, workdir: str, ttl: float = 30.0):
        self.workdir = workdir
        self.root = os.path.join(workdir, _LEASE_DIR)
        self.ttl = float(ttl)
        os.makedirs(self.root, exist_ok=True)

    def _victim_step(self, shard: int) -> Optional[int]:
        """Last step the shard's previous owner heartbeat before going
        silent (pinned-layout heartbeat path; None if never beaten)."""
        doc = read_heartbeat(os.path.join(self.workdir, f"worker_{shard}",
                                          "heartbeat"))
        return None if doc is None else doc.get("step")

    def _path(self, shard: int) -> str:
        return os.path.join(self.root, f"shard_{int(shard)}.json")

    def read(self, shard: int) -> Optional[Lease]:
        try:
            with open(self._path(shard)) as f:
                return Lease(json.load(f))
        except (OSError, ValueError):
            return None

    def _write(self, shard: int, doc: dict) -> None:
        tmp = self._path(shard) + f".tmp-{os.getpid()}-{uuid.uuid4().hex[:6]}"
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._path(shard))

    def try_acquire(self, shard: int, owner: str) -> Optional[Lease]:
        """Acquire ``shard`` if it is unleased, expired, or already ours.

        Returns the lease we now hold (with a freshly bumped fencing
        token), or None if a live foreign owner holds it or a concurrent
        claimant out-renamed us."""
        now = time.time()
        now_mono = time.monotonic()
        cur = self.read(shard)
        if (cur is not None and cur.owner != owner
                and not cur.expired(self.ttl, now, now_mono)):
            return None
        nonce = uuid.uuid4().hex
        doc = Lease({
            "owner": owner,
            "token": (cur.token + 1) if cur else 1,
            "acquired_at": now,
            "renewed_at": now,
            "renewed_mono": now_mono,
            "nonce": nonce,
            "owners": (cur.owners if cur else []) + [owner],
        })
        self._write(shard, doc)
        got = self.read(shard)
        if got is None or got.get("nonce") != nonce:
            return None                       # out-renamed by another claimant
        stolen_from = (cur.owner if cur is not None and cur.owner
                       and cur.owner != owner else None)
        get_journal().event("lease_acquire", "fleet", shard=shard,
                            token=got.token, stolen_from=stolen_from)
        return got

    def renew(self, shard: int, owner: str, token: int) -> None:
        """Refresh our renewal stamp; raise ``LeaseLost`` on a foreign
        token (the shard was stolen — abandon it)."""
        cur = self.read(shard)
        if cur is None or cur.owner != owner or cur.token != int(token):
            get_journal().event(
                "lease_lost", "fleet", shard=shard, token=int(token),
                holder=cur.owner if cur else None,
                holder_token=cur.token if cur else None)
            raise LeaseLost(f"shard {shard}: lease lost to "
                            f"{cur.owner if cur else '<gone>'}")
        cur["renewed_at"] = time.time()
        cur["renewed_mono"] = time.monotonic()
        self._write(shard, cur)

    def release(self, shard: int, owner: str, token: int,
                done: bool = False) -> None:
        cur = self.read(shard)
        if cur is None or cur.owner != owner or cur.token != int(token):
            return                            # stolen meanwhile — nothing to do
        cur["owner"] = ""
        cur["done"] = bool(done)
        cur["renewed_at"] = 0.0               # immediately acquirable
        cur["renewed_mono"] = None            # (from either clock)
        self._write(shard, cur)
        get_journal().event("lease_release", "fleet", shard=shard,
                            token=int(token), done=bool(done))

    def pick(self, shards: List[int], owner: str) -> Optional[int]:
        """The next shard ``owner`` should take: a shard whose lease we
        ALREADY hold first (reclaiming our own work is always right, and
        the fencing token still protects it if someone stole it meanwhile),
        then a never-leased shard, else the STALEST expired lease (the
        worst straggler's)."""
        now = time.time()
        now_mono = time.monotonic()
        stalest, stalest_age, stalest_owner = None, -1.0, ""
        for s in shards:
            cur = self.read(s)
            if cur is not None and cur.owner == owner:
                return s
        for s in shards:
            cur = self.read(s)
            if cur is None:
                return s
            if cur.expired(self.ttl, now, now_mono):
                age = cur.age(now, now_mono)
                if age > stalest_age:
                    stalest, stalest_age, stalest_owner = s, age, cur.owner
        if stalest is not None and stalest_owner:
            # a steal of a live-owned-but-expired lease: say who the victim
            # was, how stale, and the last step it heartbeat — not just the
            # lease-file age
            step = self._victim_step(stalest)
            print(f"fleet {owner}: picking stalest shard {stalest} from "
                  f"{stalest_owner} (lease {stalest_age:.1f}s stale, last "
                  f"heartbeat step {'?' if step is None else step})")
            get_journal().event("lease_pick", "fleet", shard=stalest,
                                victim=stalest_owner,
                                age_s=round(stalest_age, 3),
                                victim_step=step)
        return stalest

    def snapshot(self) -> Dict[int, Lease]:
        out = {}
        for name in os.listdir(self.root):
            if name.startswith("shard_") and name.endswith(".json"):
                shard = int(name[len("shard_"):-len(".json")])
                lease = self.read(shard)
                if lease is not None:
                    out[shard] = lease
        return out


def fleet_worker_loop(spec: dict, workdir: str, worker_id: str, *,
                      ttl: float, poll: float = 0.2) -> int:
    """Elastic worker body: steal-and-run shards until all are published.

    Imported lazily by ``streaming.worker`` so the worker module keeps
    controlling its own jax flags before any heavy import."""
    from .launcher import _load_result
    from .worker import run_shard

    store = LeaseStore(workdir, ttl=ttl)
    shards = list(range(len(spec["shards"])))
    ran = 0
    while True:
        pending = [s for s in shards
                   if _load_result(workdir, spec, s) is None]
        if not pending:
            break
        shard = store.pick(pending, worker_id)
        if shard is None:
            time.sleep(poll)                 # all pending shards live-leased
            continue
        lease = store.try_acquire(shard, worker_id)
        if lease is None:
            time.sleep(poll)
            continue
        try:
            run_shard(spec, workdir, shard, worker=worker_id,
                      lease_store=store, lease=lease)
            ran += 1
            store.release(shard, worker_id, lease.token, done=True)
        except LeaseLost:
            print(f"fleet {worker_id}: shard {shard} stolen, moving on")
            continue
    print(f"fleet {worker_id}: all shards published ({ran} run here)")
    return 0
