"""Fig. 6 — F-DOT (feature-partitioned) vs OI, SeqPM and d-PM.

Paper setting: N=10 nodes, ER p=0.5, d=N (one feature per node), n=500
samples, varying r and eigengap.
"""
from __future__ import annotations

import jax

from repro.core.baselines import d_pm, seq_pm
from repro.core.consensus import DenseConsensus
from repro.core.fdot import fdot
from repro.core.linalg import eigh_topr, orthonormal_init
from repro.core.metrics import subspace_error
from repro.core.oi import oi_trace
from repro.core.topology import erdos_renyi
from repro.data.pipeline import gaussian_eigengap_data, partition_features

from .common import Row, timed

N = 10


def run():
    rows = []
    eng = DenseConsensus(erdos_renyi(N, 0.5, seed=1))
    for gap, r in ((0.5, 3), (0.8, 5)):
        x, _, _ = gaussian_eigengap_data(N, 500, r, gap, seed=0)
        m = x @ x.T
        _, q_true = eigh_topr(m, r)
        blocks = partition_features(x, N)
        tag = f"fig6/gap{gap}/r{r}"

        t_o = 100
        q0 = orthonormal_init(jax.random.PRNGKey(0), N, r)
        _, tr = oi_trace(m, q0, t_o,
                         metric=lambda q: subspace_error(q_true, q))
        rows.append(Row(f"{tag}/OI", 0.0,
                        {"final_err": f"{float(tr[-1]):.2e}"}))

        _, errs = seq_pm(m, r, iters_per_vec=t_o // r, q_true=q_true)
        rows.append(Row(f"{tag}/SeqPM", 0.0,
                        {"final_err": f"{errs[-1]:.2e}"}))

        res, us = timed(fdot, data_blocks=blocks, engine=eng, r=r,
                        t_outer=t_o, t_c=50, q_true=q_true)
        rows.append(Row(f"{tag}/F-DOT", us,
                        {"final_err": f"{res.error_trace[-1]:.2e}",
                         "p2p_k": round(res.ledger.per_node_p2p(N) / 1e3, 2)}))

        (_, errs), us = timed(d_pm, blocks, eng, r, iters_per_vec=t_o // r,
                              t_c=50, q_true=q_true)
        rows.append(Row(f"{tag}/d-PM", us,
                        {"final_err": f"{errs[-1]:.2e}"}))
    return rows
