"""AdamW and the PSA gradient-compression layer (paper technique in the
optimizer; single-process paths — the pod-axis path is covered by
test_spmd.py subprocess runs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PSAConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.psa_compress import (compress_grads, compressible,
                                      compression_ratio, psa_init,
                                      psa_refresh)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def test_adamw_quadratic_converges():
    w = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                    jnp.float32)
    params = {"w": jnp.zeros((8, 8))}
    opt = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=1)
    state = adamw_init(params, opt)

    def loss_fn(p):
        return jnp.sum((p["w"] - w) ** 2)

    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        params, state, _ = adamw_update(g, state, params, opt)
    assert float(loss_fn(params)) < 1e-2


def test_adamw_grad_clip():
    params = {"w": jnp.zeros((4,))}
    opt = AdamWConfig(grad_clip=1.0, warmup_steps=1)
    state = adamw_init(params, opt)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, gnorm = adamw_update(huge, state, params, opt)
    assert float(gnorm) == pytest.approx(2e6, rel=1e-3)  # reported pre-clip


def test_adamw_bf16_moments():
    params = {"w": jnp.zeros((16, 16), jnp.float32)}
    opt = AdamWConfig(moment_dtype="bfloat16")
    state = adamw_init(params, opt)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((16, 16))}
    _, state, _ = adamw_update(g, state, params, opt)
    assert state["m"]["w"].dtype == jnp.bfloat16


def test_adamw_warmup():
    opt = AdamWConfig(lr=1.0, warmup_steps=10, weight_decay=0.0)
    params = {"w": jnp.zeros(())}
    state = adamw_init(params, opt)
    g = {"w": jnp.ones(())}
    p1, state, _ = adamw_update(g, state, params, opt)
    # step 1 of 10 warmup: effective lr 0.1 -> |delta| ~ 0.1
    assert abs(float(p1["w"])) < 0.2


# ---------------------------------------------------------------------------
# PSA compression
# ---------------------------------------------------------------------------
def test_compressible_rule():
    cfg = PSAConfig(rank=4)
    assert compressible(jnp.zeros((64, 32)), 4)
    assert not compressible(jnp.zeros((8, 32)), 4)       # a < 4r
    assert not compressible(jnp.zeros((64,)), 4)         # 1-D


def test_full_rank_projection_is_lossless():
    """If the projector spans the full row space, compress->decompress = id."""
    cfg = PSAConfig(rank=16, error_feedback=True)
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
    params = {"w": g}
    st = psa_init(params, cfg)
    # replace projector with a basis containing the column space of g
    q, _ = jnp.linalg.qr(g)
    st["proj"]["w"] = q
    red, ef = compress_grads({"w": g}, st, cfg, pod_axis=None)
    np.testing.assert_allclose(np.asarray(red["w"]), np.asarray(g), atol=1e-4)
    assert float(jnp.abs(ef["w"]).max()) < 1e-4


def test_error_feedback_accumulates_residual():
    cfg = PSAConfig(rank=2, error_feedback=True)
    g = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    params = {"w": g}
    st = psa_init(params, cfg)
    red, ef = compress_grads({"w": g}, st, cfg, pod_axis=None)
    p = st["proj"]["w"]
    resid = g - p @ (p.T @ g)
    np.testing.assert_allclose(np.asarray(ef["w"]), np.asarray(resid),
                               atol=1e-5)
    # compressed + residual == original (lossless decomposition)
    np.testing.assert_allclose(np.asarray(red["w"] + ef["w"]),
                               np.asarray(g), atol=1e-5)


def test_error_feedback_preserves_signal_over_steps():
    """With EF, repeated compression of a CONSTANT gradient eventually
    transmits everything: sum of reduced grads -> t*g - bounded residual."""
    cfg = PSAConfig(rank=2, error_feedback=True)
    g = jax.random.normal(jax.random.PRNGKey(2), (32, 8))
    st = psa_init({"w": g}, cfg)
    total = jnp.zeros_like(g)
    e = st["ef"]
    for t in range(1, 21):
        red, e_new = compress_grads({"w": g}, {"proj": st["proj"], "ef": e},
                                    cfg, pod_axis=None)
        total = total + red["w"]
        e = e_new
    # ||sum red - t g|| = ||residual_t|| stays bounded by ||residual_1||
    resid_norm = float(jnp.linalg.norm(total - 20 * g))
    first = float(jnp.linalg.norm(e["w"]))
    assert resid_norm <= first + 1e-3


def test_psa_refresh_finds_gradient_subspace():
    """OI refresh on a fixed low-rank gradient must recover its row space —
    the paper's Theorem 1 at work inside the optimizer."""
    from repro.core.metrics import subspace_error
    cfg = PSAConfig(rank=4, oi_iters=30)
    u = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(3), (64, 4)))[0]
    b = jax.random.normal(jax.random.PRNGKey(4), (4, 16))
    g = u @ b                                     # rank-4 gradient
    st = psa_init({"w": g}, cfg)
    st2 = psa_refresh({"w": g}, st, cfg, pod_axis=None)
    err = float(subspace_error(u, st2["proj"]["w"]))
    assert err < 1e-4, err


def test_psa_grouped_projector():
    """Stacked (G, a, b) leaves share one projector per group."""
    cfg = PSAConfig(rank=2, oi_iters=5)
    g = jax.random.normal(jax.random.PRNGKey(5), (3, 32, 8))
    st = psa_init({"w": g}, cfg)
    assert st["proj"]["w"].shape == (3, 32, 2)
    red, ef = compress_grads({"w": g}, st, cfg, pod_axis=None)
    assert red["w"].shape == g.shape
    st2 = psa_refresh({"w": g}, st, cfg, pod_axis=None)
    assert st2["proj"]["w"].shape == (3, 32, 2)
    # each group projector orthonormal
    for i in range(3):
        p = st2["proj"]["w"][i]
        np.testing.assert_allclose(np.asarray(p.T @ p), np.eye(2), atol=1e-4)


def test_compression_ratio_math():
    cfg = PSAConfig(rank=4)
    params = {"big": jnp.zeros((128, 64)), "small": jnp.zeros((4, 4))}
    ratio = compression_ratio(params, cfg)
    expect = (4 * 64 + 16) / (128 * 64 + 16)
    assert ratio == pytest.approx(expect)


def test_uncompressible_leaves_pass_through():
    cfg = PSAConfig(rank=8)
    grads = {"scale": jnp.ones((16,)), "w": jnp.ones((64, 16))}
    st = psa_init(grads, cfg)
    assert st["proj"]["scale"] is None
    red, ef = compress_grads(grads, st, cfg, pod_axis=None)
    np.testing.assert_allclose(np.asarray(red["scale"]), 1.0)
    assert ef["scale"] is None
