"""Kernel microbenchmarks.

On this CPU container Pallas runs in interpret mode, so wall time is not a
TPU signal; what is reported per kernel is (a) oracle agreement across a
shape sweep and (b) the analytic arithmetic intensity of the chosen BlockSpec
tiling (FLOPs per HBM byte) — the quantity that decides MXU-bound vs
HBM-bound on the real chip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import Row, timed


def _gram_rows():
    rows = []
    for d, n, r, bn in ((128, 4096, 128, 512), (256, 8192, 64, 512),
                        (512, 2048, 128, 256)):
        x = jax.random.normal(jax.random.PRNGKey(0), (d, n))
        q = jax.random.normal(jax.random.PRNGKey(1), (d, r))
        out, us = timed(lambda: np.asarray(
            ops.gram_apply(x, q, block_n=bn, use_pallas=True)))
        want = np.asarray(ref.gram_apply_ref(x, q))
        err = float(np.abs(out - want).max())
        flops = 4 * d * n * r
        bytes_moved = (d * n + 2 * d * r) * 4          # stream X once, Q/V resident
        rows.append(Row(
            f"kernel/gram_apply/d{d}n{n}r{r}", us,
            {"max_err_vs_ref": f"{err:.1e}",
             "flops": flops,
             "arith_intensity_flops_per_byte": round(flops / bytes_moved, 1),
             "vmem_tile_kb": round((d * bn + d * r + bn * r) * 4 / 1024, 0)}))
    return rows


def _flash_rows():
    rows = []
    for b, h, s, hd in ((1, 4, 1024, 64), (2, 8, 512, 128)):
        q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, hd))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, hd))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, hd))
        out, us = timed(lambda: np.asarray(
            ops.flash_attention(q, k, v, causal=True, use_pallas=True)))
        want = np.asarray(ref.flash_attention_ref(q, k, v, causal=True))
        err = float(np.abs(out - want).max())
        flops = 4 * b * h * s * s // 2 * hd
        hbm = 4 * b * h * s * hd * 4
        rows.append(Row(
            f"kernel/flash_attn/b{b}h{h}s{s}hd{hd}", us,
            {"max_err_vs_ref": f"{err:.1e}",
             "arith_intensity_flops_per_byte": round(flops / hbm, 1)}))
    return rows


def _gram_qr_rows():
    rows = []
    for d, r, bd in ((8192, 64, 1024), (16384, 128, 2048)):
        v = jax.random.normal(jax.random.PRNGKey(0), (d, r))
        out, us = timed(lambda: np.asarray(
            ops.gram_qr(v, block_d=bd, use_pallas=True)))
        want = np.asarray(ref.gram_qr_ref(v))
        err = float(np.abs(out - want).max() / max(np.abs(want).max(), 1))
        flops = 2 * d * r * r
        rows.append(Row(
            f"kernel/gram_qr/d{d}r{r}", us,
            {"rel_err_vs_ref": f"{err:.1e}",
             "arith_intensity_flops_per_byte": round(flops / (d * r * 4), 1),
             "vmem_tile_kb": round((bd * r + r * r) * 4 / 1024, 0)}))
    return rows


def run():
    return _gram_rows() + _flash_rows() + _gram_qr_rows()
