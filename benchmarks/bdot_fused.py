"""Fused vs eager B-DOT executor benchmark (Fig.-6-style grid scale).

Measures the PR-3 tentpole win: one jitted lax.scan for a whole
block-partitioned run vs the eager per-iteration dispatch chain. The eager
loop issues, per outer iteration, J column-gossip dispatches + host debias
matrix_powers, I row-gossip dispatches + debiases, 2 QR gossips and a
float() error sync; the fused path issues one dispatch and one trailing
sync for the entire run.

Usage:
    PYTHONPATH=src python -m benchmarks.bdot_fused [--smoke]
    PYTHONPATH=src python -m benchmarks.run bdot_fused

Writes BENCH_bdot_fused.json next to the repo root (acceptance artifact:
speedup bar >= 10x at the d~1000, 3x2-grid config).
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import numpy as np

from repro.core.bdot import bdot
from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.linalg import eigh_topr
from repro.core.topology import erdos_renyi, ring
from repro.data.pipeline import (gaussian_eigengap_data, partition_features,
                                 partition_samples)

from .common import Row

# d ~ 1000 (the acceptance config); n chosen so the grid products don't
# drown the dispatch-overhead gap the bench exists to measure — at n=2000
# both paths are matmul-bound on CPU and the ratio collapses to ~5x
D, N_SAMP, R, I, J = 1000, 600, 5, 3, 2


def _problem(seed=0):
    x, _, _ = gaussian_eigengap_data(D, N_SAMP, R, 0.6, seed=seed)
    _, q_true = eigh_topr(x @ x.T, R)
    fslabs = partition_features(x, I)
    blocks = [partition_samples(sl, J) for sl in fslabs]
    return blocks, q_true


def _engines():
    cols = [DenseConsensus(erdos_renyi(I, 0.7, seed=j)) for j in range(J)]
    rows = [DenseConsensus(ring(J)) for _ in range(I)]
    return cols, rows


def _time(fn, repeats=1):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out.q_rows[0])
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_case(label, blocks, q_true, cols, rows, schedule, t_outer,
               repeats):
    run = lambda fused: bdot(blocks=blocks, col_engines=cols,
                             row_engines=rows, r=R, t_outer=t_outer,
                             schedule=schedule, q_true=q_true, fused=fused)
    _time(lambda: run(True))                      # warmup: compile fused
    fused_s, fres = _time(lambda: run(True), repeats)
    eager_s, eres = _time(lambda: run(False))     # eager: 1 rep (it's slow)
    np.testing.assert_allclose(fres.error_trace, eres.error_trace, rtol=1e-4,
                               atol=1e-5)         # same math, always
    assert fres.ledger.scalars == eres.ledger.scalars
    return {
        "case": label,
        "t_outer": t_outer,
        "fused_ms": round(fused_s * 1e3, 2),
        "eager_ms": round(eager_s * 1e3, 2),
        "speedup": round(eager_s / fused_s, 1),
        # eager host interactions per run: per outer iteration, (J + I + 2)
        # consensus dispatches each with a host matrix_power debias, plus
        # one float() error sync; fused: one dispatch + one trailing sync
        "eager_host_interactions": (J + I + 2 + 1) * t_outer,
        "fused_host_interactions": 2,
        "final_err": float(fres.error_trace[-1]),
    }


def run_bench(smoke: bool = False):
    t_outer = 6 if smoke else 30
    repeats = 1 if smoke else 3
    blocks, q_true = _problem()
    cols, rows = _engines()
    cases = [
        ("grid3x2/const/Tc=50",
         consensus_schedule("const", t_outer, t_max=50)),
        ("grid3x2/lin2cap50",
         consensus_schedule("lin2", t_outer, cap=50)),
    ]
    return [bench_case(label, blocks, q_true, cols, rows, sched, t_outer,
                       repeats)
            for label, sched in cases]


def run():
    """benchmarks.run entry point."""
    rows = []
    for rec in run_bench(smoke=False):
        rows.append(Row(
            f"bdot_fused/{rec['case']}", rec["fused_ms"] * 1e3,
            {"eager_ms": rec["eager_ms"], "speedup": rec["speedup"],
             "final_err": f"{rec['final_err']:.2e}"}))
    return rows


def main():
    smoke = "--smoke" in sys.argv
    results = run_bench(smoke=smoke)
    out = {
        "bench": "bdot_fused",
        "scale": {"d": D, "n": N_SAMP, "r": R, "grid": [I, J]},
        "smoke": smoke,
        "backend": jax.default_backend(),
        "results": results,
    }
    print(json.dumps(out, indent=2))
    # smoke results go to a sibling file so they never clobber the committed
    # full-scale artifact
    name = "BENCH_bdot_fused.smoke.json" if smoke else "BENCH_bdot_fused.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")
    worst = min(r["speedup"] for r in results)
    if not smoke and worst < 10.0:
        print(f"# WARNING: worst-case speedup {worst}x below the 10x bar")
        sys.exit(1)


if __name__ == "__main__":
    main()
