"""Observability overhead benchmark: tracing must be near-free.

The span journal (repro/obs) instruments the two hottest supervised
paths — the chunked-runtime driver (a journal event per chunk boundary
plus ckpt_save spans) and the serving tick loop (tick/ingest/query_drain
spans, registry counters, the query-latency histogram). Both are
host-side atomic file appends, strictly out-of-band of device math; this
benchmark prices them end to end and enforces the <3% bar:

* ``runtime``  — ``run_chunked`` over a fused S-DOT program with async
  checkpoints, traced (journal installed) vs untraced (noop journal);
* ``serving``  — a full ``PSAService`` run to ``total_ticks`` in a fresh
  workdir, traced (default-on ``<workdir>/obs``) vs ``REPRO_OBS=0``.

Both are measured with ``common.interleaved_best_of`` (this container
shows +-20% walltime jitter; rotating best-of-N is the low-noise
estimator) and every traced result is asserted bitwise equal to its
untraced twin — tracing that changed the math would fail here before it
failed a replay drill.

Usage:
    PYTHONPATH=src python -m benchmarks.obs_bench [--smoke]
    PYTHONPATH=src python -m benchmarks.run obs_bench

Writes BENCH_obs.json (or .smoke.json) next to the repo root.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.checkpoint.manager import CheckpointManager
from repro.core.consensus import DenseConsensus
from repro.core.runtime import run_chunked
from repro.core.sdot import sdot_program
from repro.core.topology import erdos_renyi

from .common import Row, interleaved_best_of, sample_problem

OVERHEAD_BAR_PCT = 3.0


def _bench_root(prefix: str) -> str:
    """Workdir for one bench case — on tmpfs when available.

    Both variants checkpoint identically (fsync'd manifest per boundary /
    tick), and on this container's disk that fsync latency variance is
    +-200 ms per run — larger than the entire instrumentation cost, so
    best-of minima never converge. tmpfs removes the disk jitter while
    keeping every syscall: the journal itself never fsyncs, so its appends
    are page-cache writes on either filesystem and its measured cost is
    unchanged."""
    base = "/dev/shm" if os.path.isdir("/dev/shm") else None
    return tempfile.mkdtemp(prefix=prefix, dir=base)


def _overhead(best: dict) -> float:
    return round((best["traced"] - best["plain"]) / best["plain"] * 100, 2)


def bench_runtime(d, r, n_nodes, t_outer, chunk_size, repeats):
    """Chunked driver + async checkpoints, journal on vs off."""
    covs, q_true = sample_problem(d=d, r=r, n_nodes=n_nodes, n_per=4 * d,
                                  gap=0.7)
    engine = DenseConsensus(erdos_renyi(n_nodes, 0.5, seed=1))
    root = _bench_root("bench_obs_rt_")

    def one(tag, journal):
        obs.set_journal(journal)
        try:
            ckpt = os.path.join(root, f"ckpt_{tag}")
            shutil.rmtree(ckpt, ignore_errors=True)
            prog = sdot_program(covs=covs, engine=engine, r=r,
                                t_outer=t_outer, t_c=20, q_true=q_true)
            res = run_chunked(prog, CheckpointManager(ckpt, keep_last=2),
                              chunk_size=chunk_size)
            jax.block_until_ready(res.q_nodes)
            return res
        finally:
            journal.close()
            obs.set_journal(obs.Journal.noop())

    def traced():
        return one("traced", obs.Journal.open(
            os.path.join(root, "obs"), "bench",
            registry=obs.MetricsRegistry()))

    def plain():
        return one("plain", obs.Journal.noop())

    plain()                                          # warmup compile
    try:
        best, outs = interleaved_best_of(
            [("traced", traced), ("plain", plain)], repeats)
        np.testing.assert_array_equal(np.asarray(outs["traced"].q_nodes),
                                      np.asarray(outs["plain"].q_nodes))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return {"case": f"runtime d={d} T={t_outer} chunk={chunk_size}",
            "traced_ms": round(best["traced"] * 1e3, 3),
            "plain_ms": round(best["plain"] * 1e3, 3),
            "overhead_pct": _overhead(best),
            "boundaries": -(-t_outer // chunk_size)}


def bench_serving(total_ticks, repeats, **cfg_kw):
    """Full service run (ingest/re-solve/gate/queries/checkpoint per tick),
    default-on tracing vs REPRO_OBS=0."""
    from repro.serving.service import PSAService, ServiceConfig

    cfg = ServiceConfig(total_ticks=total_ticks, **cfg_kw)
    root = _bench_root("bench_obs_sv_")
    counter = [0]

    def one(disable_obs):
        counter[0] += 1
        workdir = os.path.join(root, f"run{counter[0]}")
        prev = os.environ.get(obs.ENV_OBS)
        if disable_obs:
            os.environ[obs.ENV_OBS] = "0"
        try:
            svc = PSAService(cfg, workdir).run()
            return svc.finalize()
        finally:
            obs.get_journal().close()
            obs.set_journal(obs.Journal.noop())
            if disable_obs:
                if prev is None:
                    del os.environ[obs.ENV_OBS]
                else:
                    os.environ[obs.ENV_OBS] = prev

    one(True)                                        # warmup compile
    try:
        best, outs = interleaved_best_of(
            [("traced", lambda: one(False)), ("plain", lambda: one(True))],
            repeats)
        # tracing must not touch the served trajectory
        assert outs["traced"]["served_sha256"] == \
            outs["plain"]["served_sha256"], (outs["traced"], outs["plain"])
        assert outs["traced"]["swaps"] == outs["plain"]["swaps"]
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return {"case": f"serving ticks={total_ticks}",
            "traced_ms": round(best["traced"] * 1e3, 3),
            "plain_ms": round(best["plain"] * 1e3, 3),
            "overhead_pct": _overhead(best),
            "swaps": outs["traced"]["swaps"]}


def run_bench(smoke: bool = False):
    if smoke:
        return [
            bench_runtime(d=24, r=3, n_nodes=4, t_outer=30, chunk_size=10,
                          repeats=2),
            bench_serving(total_ticks=8, repeats=1),
        ]
    # sized >= ~1 s per measurement so per-boundary journal appends are
    # integrated over the container's throttling jitter; the serving config
    # is scaled up from the d=12 unit-test toy to a representative tick
    # (the instrumentation cost per tick is constant, so the toy would
    # price the journal against ~10 ms ticks no deployment runs)
    return [
        bench_runtime(d=96, r=5, n_nodes=6, t_outer=600, chunk_size=30,
                      repeats=9),
        bench_serving(total_ticks=26, repeats=7, d=96, batch_size=192,
                      holdout_m=2048, queries_per_tick=16),
    ]


def run():
    """benchmarks.run entry point."""
    return [Row(f"obs/{rec['case']}", rec["traced_ms"] * 1e3,
                {"plain_ms": rec["plain_ms"],
                 "overhead_pct": rec["overhead_pct"]})
            for rec in run_bench(smoke=False)]


def main():
    smoke = "--smoke" in sys.argv
    results = run_bench(smoke=smoke)
    out = {
        "bench": "obs",
        "smoke": smoke,
        "backend": jax.default_backend(),
        "overhead_bar_pct": OVERHEAD_BAR_PCT,
        "results": results,
    }
    print(json.dumps(out, indent=2))
    name = "BENCH_obs.smoke.json" if smoke else "BENCH_obs.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")
    if not smoke:
        worst = max(r["overhead_pct"] for r in results)
        if worst > OVERHEAD_BAR_PCT:
            print(f"# WARNING: tracing overhead {worst}% above the "
                  f"{OVERHEAD_BAR_PCT}% bar")
            sys.exit(1)


if __name__ == "__main__":
    main()
