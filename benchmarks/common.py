"""Shared benchmark plumbing.

Every benchmark module exposes ``run() -> list[Row]``; ``benchmarks.run``
executes them all and prints one CSV. P2P accounting follows the paper's MPI
counter: one point-to-point message per directed edge per gossip round,
reported per node in thousands (K), matching Tables I-IX.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.linalg import eigh_topr
from repro.core.topology import Graph, erdos_renyi, ring, star
from repro.data.pipeline import gaussian_eigengap_data, partition_samples


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float           # wall time of the measured run, microseconds
    derived: Dict[str, object]   # table-specific fields

    def csv(self) -> str:
        kv = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.name},{self.us_per_call:.1f},{kv}"


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def interleaved_best_of(variants, repeats: int, *,
                        sync: Optional[Callable] = None):
    """Best-of-N walltimes with the variant order rotated every round.

    This container shows +-20% walltime jitter and throttles over time, so
    a single-pass A-then-B comparison is unreliable: whichever variant runs
    later eats the throttling. Rotating the order each round spreads the
    machine noise over every variant and the per-variant MINIMUM is the
    least-noise estimate of its true cost.

    ``variants``: list of (name, thunk) pairs; each thunk runs one
    measurement and returns its result. ``sync`` (optional) is called on
    the result before the clock stops (e.g. ``jax.block_until_ready`` on
    the result's arrays) — omit it if the thunks block internally.

    Returns ``(best, outs)``: name -> best seconds, name -> last result.
    """
    variants = list(variants)
    best = {name: float("inf") for name, _ in variants}
    outs = {}
    for i in range(max(1, repeats)):
        k = i % len(variants)
        for name, fn in variants[k:] + variants[:k]:
            t0 = time.perf_counter()
            out = fn()
            if sync is not None:
                sync(out)
            best[name] = min(best[name], time.perf_counter() - t0)
            outs[name] = out
    return best, outs


def sample_problem(*, d: int, r: int, n_nodes: int, n_per: int, gap: float,
                   seed: int = 0, repeated_top: bool = False):
    """Sample-partitioned PSA problem + ground truth of the global covariance."""
    x, _, _ = gaussian_eigengap_data(d, n_nodes * n_per, r, gap, seed=seed,
                                     repeated_top=repeated_top)
    blocks = partition_samples(x, n_nodes)
    covs = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
    _, q_true = eigh_topr(covs.sum(0), r)
    return covs, q_true


def p2p_per_node_k(graph: Graph, rounds_total: int) -> float:
    """Average per-node P2P messages (K) after ``rounds_total`` gossip rounds."""
    return float(graph.adjacency.sum() / graph.n_nodes) * rounds_total / 1e3


def schedule_rounds(kind: str, t_outer: int, t_max: int = 50,
                    cap: Optional[int] = None) -> int:
    """Total consensus rounds for a schedule over t_outer outer iterations."""
    return int(consensus_schedule(kind, t_outer, t_max=t_max, cap=cap).sum())


# The paper's standard schedule set (Tables I-IV; cap = the experiment's
# max consensus iterations, implicitly 50 unless the table says otherwise).
PAPER_SCHEDULES = {
    "[0.5t+1]": ("lin_half", 50),
    "t+1": ("lin1", 50),
    "2t+1": ("lin2", 50),
    "50": ("const", None),
}
