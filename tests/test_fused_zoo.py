"""Fused whole-run executors for F-DOT, every distributed baseline, the
device-side AsyncConsensus, and the vmapped Monte-Carlo sweep engine — all
against their eager/host oracles (const + lin2 schedules, ring + ER
topologies, ledger equality)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.async_gossip import AsyncConsensus
from repro.core.baselines import d_pm, deepca, dpgd, dsa, seq_dist_pm
from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.fdot import fdot, pad_feature_slabs, unpad_feature_slabs
from repro.core.linalg import eigh_topr
from repro.core.metrics import CommLedger
from repro.core.sdot import sdot
from repro.core.sweep import baseline_sweep, fdot_sweep, sdot_sweep
from repro.core.topology import erdos_renyi, ring
from repro.data.pipeline import gaussian_eigengap_data, partition_features


@pytest.fixture(scope="module")
def fzoo():
    d, r, n_nodes = 20, 5, 10
    x, _, _ = gaussian_eigengap_data(d, 3000, r, 0.7, seed=0)
    _, q_true = eigh_topr(x @ x.T, r)
    fblocks = partition_features(x, n_nodes)
    return dict(d=d, r=r, n_nodes=n_nodes, x=x, fblocks=fblocks,
                q_true=q_true)


@pytest.fixture(scope="module")
def topologies(fzoo):
    n = fzoo["n_nodes"]
    return {
        "er": DenseConsensus(erdos_renyi(n, 0.5, seed=1)),
        "ring": DenseConsensus(ring(n)),
    }


def _assert_ledgers_equal(a: CommLedger, b: CommLedger):
    assert a.p2p == b.p2p
    assert a.matrices == b.matrices
    assert a.scalars == b.scalars


# ---------------------------------------------------------------------------
# fused F-DOT vs the eager oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("topo", ["er", "ring"])
@pytest.mark.parametrize("sched_kind", ["const", "lin2"])
def test_fdot_fused_matches_eager(fzoo, topologies, topo, sched_kind):
    eng = topologies[topo]
    sched = (None if sched_kind == "const"
             else consensus_schedule("lin2", 15, cap=50))
    kw = dict(data_blocks=fzoo["fblocks"], engine=eng, r=fzoo["r"],
              t_outer=15, t_c=50, schedule=sched, q_true=fzoo["q_true"])
    eager = fdot(fused=False, **kw)
    fused = fdot(fused=True, **kw)
    np.testing.assert_allclose(fused.error_trace, eager.error_trace,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(fused.q_full),
                               np.asarray(eager.q_full), rtol=1e-4,
                               atol=1e-5)
    _assert_ledgers_equal(fused.ledger, eager.ledger)


def test_fdot_fused_ragged_slabs(fzoo):
    """Uneven feature split: zero-row padding must not change the result."""
    blocks = partition_features(fzoo["x"], 7)
    eng = DenseConsensus(erdos_renyi(7, 0.6, seed=2))
    kw = dict(data_blocks=blocks, engine=eng, r=fzoo["r"], t_outer=12,
              t_c=40, q_true=fzoo["q_true"])
    eager = fdot(fused=False, **kw)
    fused = fdot(fused=True, **kw)
    np.testing.assert_allclose(fused.error_trace, eager.error_trace,
                               rtol=1e-4, atol=1e-6)
    for fb, eb in zip(fused.q_blocks, eager.q_blocks):
        assert fb.shape == eb.shape
        np.testing.assert_allclose(np.asarray(fb), np.asarray(eb), rtol=1e-4,
                                   atol=1e-5)


def test_fdot_short_schedule_rejected(fzoo, topologies):
    for fused in (True, False):
        with pytest.raises(ValueError, match="schedule"):
            fdot(data_blocks=fzoo["fblocks"], engine=topologies["er"],
                 r=fzoo["r"], t_outer=10, schedule=np.array([5, 5]),
                 fused=fused)


def test_pad_unpad_feature_slabs_roundtrip(fzoo):
    dims = [b.shape[0] for b in fzoo["fblocks"]]
    stack = pad_feature_slabs(fzoo["fblocks"])
    back = unpad_feature_slabs(stack, dims)
    for a, b in zip(back, fzoo["fblocks"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fused baselines vs the eager oracles (ledger equality included)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("topo", ["er", "ring"])
@pytest.mark.parametrize("name", ["dsa", "dpgd", "deepca", "seq_dist_pm"])
def test_baseline_fused_matches_eager(psa_problem, topologies, topo, name):
    p = psa_problem
    eng = topologies[topo]
    calls = {
        "dsa": lambda f, led: dsa(p["covs"], eng, p["r"], t_outer=40, lr=0.05,
                                  q_true=p["q_true"], ledger=led, fused=f),
        "dpgd": lambda f, led: dpgd(p["covs"], eng, p["r"], t_outer=40,
                                    lr=0.05, q_true=p["q_true"], ledger=led,
                                    fused=f),
        "deepca": lambda f, led: deepca(p["covs"], eng, p["r"], t_outer=30,
                                        t_mix=3, q_true=p["q_true"],
                                        ledger=led, fused=f),
        "seq_dist_pm": lambda f, led: seq_dist_pm(
            p["covs"], eng, p["r"], iters_per_vec=8, t_c=50,
            q_true=p["q_true"], ledger=led, fused=f),
    }
    led_e, led_f = CommLedger(), CommLedger()
    q_e, e_e = calls[name](False, led_e)
    q_f, e_f = calls[name](True, led_f)
    np.testing.assert_allclose(e_f, e_e, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(q_f), np.asarray(q_e), rtol=1e-4,
                               atol=1e-5)
    _assert_ledgers_equal(led_f, led_e)


@pytest.mark.parametrize("topo", ["er", "ring"])
def test_d_pm_fused_matches_eager(fzoo, topologies, topo):
    eng = topologies[topo]
    led_e, led_f = CommLedger(), CommLedger()
    q_e, e_e = d_pm(fzoo["fblocks"], eng, 3, iters_per_vec=10, t_c=50,
                    q_true=fzoo["q_true"][:, :3], ledger=led_e, fused=False)
    q_f, e_f = d_pm(fzoo["fblocks"], eng, 3, iters_per_vec=10, t_c=50,
                    q_true=fzoo["q_true"][:, :3], ledger=led_f, fused=True)
    np.testing.assert_allclose(e_f, e_e, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(q_f), np.asarray(q_e), rtol=1e-4,
                               atol=1e-5)
    _assert_ledgers_equal(led_f, led_e)


def test_seq_dist_pm_async_engine_logs_realized_sends(psa_problem):
    """With an async engine the eager fallback must log the realized
    (awake-dependent) sends per round, not the synchronous closed form."""
    p = psa_problem
    eng = AsyncConsensus(erdos_renyi(p["n_nodes"], 0.5, seed=1), p_awake=0.5,
                         seed=0)
    led = CommLedger()
    seq_dist_pm(p["covs"], eng, 2, iters_per_vec=2, t_c=10, ledger=led)
    rounds = 2 * 2 * 10
    assert len(led.awake_counts) == rounds
    sync_sends = float(eng.graph.adjacency.sum()) * rounds
    assert 0 < led.p2p < sync_sends      # ~p_awake^2 of the sync count


def test_baseline_fused_no_q_true_nan_trace(psa_problem, topologies):
    """Without ground truth both modes return the NaN trace convention."""
    _, errs = dsa(psa_problem["covs"], topologies["er"], psa_problem["r"],
                  t_outer=5, fused=True)
    assert errs.shape == (5,)
    assert np.all(np.isnan(errs))


# ---------------------------------------------------------------------------
# device-side AsyncConsensus vs the host NumPy oracle
# ---------------------------------------------------------------------------
def test_async_device_matches_host_on_shared_masks():
    g = erdos_renyi(10, 0.5, seed=1)
    rng = np.random.default_rng(3)
    z0 = jnp.asarray(rng.standard_normal((10, 6, 2)), jnp.float32)
    dev = AsyncConsensus(g, p_awake=0.6, seed=0)
    host = AsyncConsensus(g, p_awake=0.6, seed=0, fused=False)
    masks = np.asarray(dev.sample_awake(40))
    led_d, led_h = CommLedger(), CommLedger()
    out_d = dev.run_debiased(z0, 40, ledger=led_d, awake=jnp.asarray(masks))
    out_h = host.run_debiased(z0, 40, ledger=led_h, awake=masks)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_h),
                               rtol=1e-4, atol=1e-4)
    _assert_ledgers_equal(led_d, led_h)
    assert led_d.awake_counts == led_h.awake_counts
    assert len(led_d.awake_counts) == 40
    assert 0.0 <= led_d.mean_awake() <= 10.0


def test_async_fused_converges_to_sum():
    eng = AsyncConsensus(erdos_renyi(10, 0.5, seed=1), p_awake=0.7, seed=0)
    rng = np.random.default_rng(0)
    z0 = jnp.asarray(rng.standard_normal((10, 6, 2)), jnp.float32)
    out = eng.run_debiased(z0, 300)
    assert float(jnp.abs(out - z0.sum(0)[None]).max()) < 1e-3


def test_async_injected_masks_respect_t_c():
    """Only the first t_c injected mask rows are consumed (like the host
    loop); too few rows fail loudly in both modes."""
    g = erdos_renyi(10, 0.5, seed=1)
    z0 = jnp.asarray(np.random.default_rng(1).standard_normal((10, 4, 2)),
                     jnp.float32)
    dev = AsyncConsensus(g, p_awake=0.6, seed=0)
    host = AsyncConsensus(g, p_awake=0.6, seed=0, fused=False)
    masks = np.asarray(dev.sample_awake(40))
    out_long = dev.run_debiased(z0, 10, awake=jnp.asarray(masks))
    out_exact = dev.run_debiased(z0, 10, awake=jnp.asarray(masks[:10]))
    np.testing.assert_array_equal(np.asarray(out_long), np.asarray(out_exact))
    out_h = host.run_debiased(z0, 10, awake=masks)
    np.testing.assert_allclose(np.asarray(out_long), np.asarray(out_h),
                               rtol=1e-4, atol=1e-4)
    for eng in (dev, host):
        with pytest.raises(ValueError, match="awake"):
            eng.run_debiased(z0, 50, awake=jnp.asarray(masks))


def test_async_sample_awake_stream_advances():
    eng = AsyncConsensus(erdos_renyi(10, 0.5, seed=1), p_awake=0.5, seed=0)
    m1, m2 = np.asarray(eng.sample_awake(20)), np.asarray(eng.sample_awake(20))
    assert m1.shape == (20, 10)
    assert not np.array_equal(m1, m2)


# ---------------------------------------------------------------------------
# vmapped Monte-Carlo sweep engine == per-seed fused runs
# ---------------------------------------------------------------------------
def test_sdot_sweep_matches_per_seed_runs(psa_problem, topologies):
    p = psa_problem
    engines = [topologies["er"], topologies["ring"]]
    schedules = [consensus_schedule("const", 10, t_max=30),
                 consensus_schedule("lin2", 10, cap=30)]
    seeds = [0, 1, 2]
    sw = sdot_sweep(covs=p["covs"], engines=engines, schedules=schedules,
                    r=p["r"], t_outer=10, seeds=seeds, q_true=p["q_true"])
    assert sw.error_traces.shape == (2, 3, 10)
    led = CommLedger()
    for ci, (eng, sched) in enumerate(zip(engines, schedules)):
        for si, s in enumerate(seeds):
            res = sdot(covs=p["covs"], engine=eng, r=p["r"], t_outer=10,
                       schedule=sched, seed=s, q_true=p["q_true"])
            led = led.merged(res.ledger)
            np.testing.assert_allclose(sw.error_traces[ci, si],
                                       res.error_trace, rtol=1e-5,
                                       atol=1e-7)
    _assert_ledgers_equal(sw.ledger, led)
    assert sw.mean_trace.shape == (2, 10)
    assert sw.std_trace.shape == (2, 10)


def test_fdot_sweep_matches_per_seed_runs(fzoo, topologies):
    seeds = [0, 1]
    sw = fdot_sweep(data_blocks=fzoo["fblocks"], engines=topologies["er"],
                    r=fzoo["r"], t_outer=8, t_c=30, seeds=seeds,
                    q_true=fzoo["q_true"])
    assert sw.error_traces.shape == (2, 8)
    led = CommLedger()
    for si, s in enumerate(seeds):
        res = fdot(data_blocks=fzoo["fblocks"], engine=topologies["er"],
                   r=fzoo["r"], t_outer=8, t_c=30, seed=s,
                   q_true=fzoo["q_true"])
        led = led.merged(res.ledger)
        np.testing.assert_allclose(sw.error_traces[si], res.error_trace,
                                   rtol=1e-5, atol=1e-7)
    _assert_ledgers_equal(sw.ledger, led)


@pytest.mark.parametrize("name", ["dsa", "dpgd", "deepca", "seq_dist_pm"])
def test_baseline_sweep_matches_per_seed_runs(psa_problem, topologies, name):
    p = psa_problem
    eng = topologies["er"]
    seeds = [0, 1]
    sweep_kw = {
        "dsa": dict(t_outer=15, lr=0.05),
        "dpgd": dict(t_outer=15, lr=0.05),
        "deepca": dict(t_outer=15),
        "seq_dist_pm": dict(iters_per_vec=4, t_c=30),
    }[name]
    sw = baseline_sweep(name, covs=p["covs"], engine=eng, r=p["r"],
                        seeds=seeds, q_true=p["q_true"], **sweep_kw)
    fn = {"dsa": dsa, "dpgd": dpgd, "deepca": deepca,
          "seq_dist_pm": seq_dist_pm}[name]
    for si, s in enumerate(seeds):
        q_single, errs = fn(p["covs"], eng, p["r"], q_true=p["q_true"],
                            seed=s, **sweep_kw)
        np.testing.assert_allclose(sw.error_traces[si], errs, rtol=1e-5,
                                   atol=1e-7)
        np.testing.assert_allclose(np.asarray(sw.q[si]),
                                   np.asarray(q_single), rtol=1e-4,
                                   atol=1e-5)


def test_d_pm_sweep_matches_per_seed_runs(fzoo, topologies):
    eng = topologies["er"]
    seeds = [0, 1]
    q_true = fzoo["q_true"][:, :3]
    sw = baseline_sweep("d_pm", data_blocks=fzoo["fblocks"], engine=eng, r=3,
                        seeds=seeds, q_true=q_true, iters_per_vec=5, t_c=30)
    for si, s in enumerate(seeds):
        q_single, errs = d_pm(fzoo["fblocks"], eng, 3, iters_per_vec=5,
                              t_c=30, q_true=q_true, seed=s)
        np.testing.assert_allclose(sw.error_traces[si], errs, rtol=1e-5,
                                   atol=1e-7)
        np.testing.assert_allclose(np.asarray(sw.q[si]),
                                   np.asarray(q_single), rtol=1e-4,
                                   atol=1e-5)


def test_sweep_without_q_true_has_no_traces(psa_problem, topologies):
    sw = sdot_sweep(covs=psa_problem["covs"], engines=topologies["er"],
                    r=psa_problem["r"], t_outer=5, t_c=10, seeds=[0, 1])
    assert sw.error_traces is None
    with pytest.raises(ValueError, match="q_true"):
        sw.mean_trace


def test_sweep_rejects_mismatched_cases(psa_problem, topologies):
    with pytest.raises(ValueError, match="zip-broadcast"):
        sdot_sweep(covs=psa_problem["covs"],
                   engines=[topologies["er"], topologies["ring"]],
                   schedules=[consensus_schedule("const", 5, t_max=10)] * 3,
                   r=psa_problem["r"], t_outer=5, seeds=[0])


def test_sweep_rejects_mixed_node_counts(psa_problem, topologies):
    with pytest.raises(ValueError, match="node count"):
        sdot_sweep(covs=psa_problem["covs"],
                   engines=[topologies["er"], DenseConsensus(ring(7))],
                   r=psa_problem["r"], t_outer=5, seeds=[0])
