"""Baseline algorithms the paper compares against (Figs. 4-6).

Centralized:
  * ``seq_pm``       — sequential power method with deflation (SeqPM)
Distributed, sample-partitioned:
  * ``seq_dist_pm``  — SeqPM with consensus-averaged matvecs (SeqDistPM, [13])
  * ``dsa``          — distributed Sanger's algorithm (Hebbian, [19])
  * ``dpgd``         — distributed projected gradient descent ([35]-style)
  * ``deepca``       — gradient-tracking power iteration (DeEPCA, [27])
Distributed, feature-partitioned:
  * ``d_pm``         — sequential distributed power method of [10]

All return (q_estimate(s), error_trace) with the paper's metric (11) traced
per *outer* iteration so plots match the paper's x-axis conventions
(inner x outer for consensus-based methods — callers scale accordingly).

Every distributed baseline runs **fused by default** (same architecture as
sdot.py/fdot.py): the whole run is one jitted ``lax.scan``, the error trace
is computed on device, and communication is accounted in closed form
(CommLedger.log_gossip_rounds). The sequential-deflation methods
(``seq_dist_pm``, ``d_pm``) scan over the flattened (eigenvector k,
inner-iteration j) index with masked deflation — a ``fori_loop`` over
candidate deflation vectors replays the eager Gram-Schmidt order exactly, so
fused == eager to float tolerance. ``fused=False`` keeps the original eager
per-iteration loop as the correctness oracle (tests/test_fused_zoo.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import runtime
from .consensus import DenseConsensus, debiased_gossip, gossip_mix
from .linalg import cholesky_qr2, orthonormal_init
from .metrics import CommLedger, subspace_error, subspace_error_from_cross
from .sdot import local_cov_apply

__all__ = ["seq_pm", "seq_dist_pm", "dsa", "dpgd", "deepca", "d_pm",
           "baseline_program", "BaselineResult"]


def _trace(q_true, q):
    return float(subspace_error(q_true, q)) if q_true is not None else np.nan


def _masked_node_mean(q, node_mask):
    """Mean over the node axis restricted to ``node_mask > 0`` nodes.

    With a mask of ones this is exactly ``q.mean(0)`` (multiply-by-1.0 and
    divide-by-N reproduce the unmasked op order), so the plain sweeps are
    unchanged; the ragged-N sweep engine passes a real mask to keep the
    isolated identity-padding nodes out of the consensus-mean estimate the
    error trace is computed from."""
    m = node_mask.astype(q.dtype)
    bshape = (-1,) + (1,) * (q.ndim - 1)
    return jnp.sum(q * m.reshape(bshape), axis=0) / jnp.sum(m)


def _supports_fused(engine) -> bool:
    """Fused baselines need the engine's mixing weights — dense array or
    ``SparseW``, both flow through ``gossip_mix`` as Program operands —
    plus the debias table for the consensus-sum methods; engines without
    them (e.g. AsyncConsensus with host-side rounds disabled) fall back
    to the eager loop."""
    return hasattr(engine, "_w") and hasattr(engine, "debias_table")


def _finish_errs(errs, n_steps: int, trace_err: bool) -> np.ndarray:
    """Device trace -> host array; NaN-fill when no ground truth was given
    (matching the eager loop's per-iteration np.nan appends)."""
    return np.asarray(errs) if trace_err else np.full(n_steps, np.nan)


@dataclasses.dataclass
class BaselineResult:
    """A fused baseline run as the unified runtime reports it.

    ``q`` is the family-shaped estimate (stacked per-node (N, d, r) for the
    consensus methods, the assembled (d, r) basis for the sequential-
    deflation ones); ``error_trace`` is NaN-filled when no ground truth was
    given, matching the eager oracles; ``ledger`` is the closed-form
    accounting for the completed prefix (so a chunked run killed mid-way
    reports exactly what it spent)."""

    q: jnp.ndarray
    error_trace: np.ndarray
    ledger: CommLedger


# --------------------------------------------------------------------------
# centralized sequential power method
# --------------------------------------------------------------------------
def seq_pm(m: jnp.ndarray, r: int, iters_per_vec: int, q_true=None, seed: int = 0):
    """Power method + deflation, one eigenvector at a time.

    The error trace is recorded against the *full* current estimate (later
    columns still at their random init), reproducing the paper's observation
    that sequential methods plateau high until the last vector converges.
    """
    d = m.shape[0]
    q = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    cols = [q[:, i] for i in range(r)]
    errs = []
    m_defl = m
    # deflation projector P = I - sum_j Q_j Q_j^T, accumulated incrementally
    # (one rank-1 update per converged vector instead of an O(r d^2) rebuild)
    p = jnp.eye(d)
    for k in range(r):
        v = cols[k]
        for _ in range(iters_per_vec):
            v = m_defl @ v
            # re-orthogonalize against converged columns for stability
            for j in range(k):
                v = v - cols[j] * (cols[j] @ v)
            v = v / jnp.linalg.norm(v)
            errs.append(_trace(q_true, jnp.stack(cols[:k] + [v] + cols[k + 1:], 1)))
        cols[k] = v
        p = p - jnp.outer(v, v)
        m_defl = p @ m @ p
    return jnp.stack(cols, axis=1), np.asarray(errs)


# --------------------------------------------------------------------------
# distributed sequential power method (SeqDistPM)
# --------------------------------------------------------------------------
def _seq_dist_pm_build_body(operands, *, r: int, iters_per_vec: int,
                            t_c: int, t_max: int, trace_err: bool):
    """Runtime body for SeqDistPM: one step of the flattened (k, j) index.

    Carry: (r, N, d) per-node column estimates; the scan input is the
    flattened step index m (k = m // iters_per_vec). Deflation against
    converged vectors is a fori_loop masked to kk < k — same sequential
    Gram-Schmidt order as the eager loop.
    """
    covs, w, table, q_true = operands

    def body(cols, m):
        k = m // iters_per_vec
        v = jnp.take(cols, k, axis=0)                          # (N, d)
        z = jnp.einsum("nde,ne->nd", covs, v)
        z = debiased_gossip(w, table, z, jnp.int32(t_c), t_max)

        def defl(kk, zz):
            u = cols[kk]
            zz_d = zz - u * jnp.sum(u * zz, axis=1, keepdims=True)
            return jnp.where(kk < k, zz_d, zz)

        z = jax.lax.fori_loop(0, r, defl, z)
        v = z / jnp.linalg.norm(z, axis=1, keepdims=True)
        cols = cols.at[k].set(v)
        err = (subspace_error(q_true, cols.mean(axis=1).T) if trace_err
               else jnp.float32(0.0))
        return cols, err

    return runtime.sync_body(body)


def seq_dist_pm(covs: jnp.ndarray, engine: DenseConsensus, r: int,
                iters_per_vec: int, t_c: int = 50, q_true=None, seed: int = 0,
                ledger: Optional[CommLedger] = None, fused: bool = True):
    n, d, _ = covs.shape
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    closed_form = _supports_fused(engine)   # sync engines: every round equal
    fused = fused and closed_form
    n_steps = r * iters_per_vec
    if fused:
        run = runtime.run_monolithic(baseline_program(
            "seq_dist_pm", covs=covs, engine=engine, r=r,
            iters_per_vec=iters_per_vec, t_c=t_c, q_true=q_true, seed=seed))
        if ledger is not None:
            ledger.merge_from(run.ledger)
        return run.q, run.error_trace
    else:
        cols = [jnp.broadcast_to(q0[:, k][None], (n, d)) for k in range(r)]
        errs = []
        done: list = []
        for k in range(r):
            v = cols[k]  # (n, d)
            for _ in range(iters_per_vec):
                z = jnp.einsum("nde,ne->nd", covs, v)
                # async engines log realized (awake-dependent) sends per call;
                # sync engines are accounted in closed form below
                z = engine.run_debiased(z, t_c,
                                        None if closed_form else ledger)
                # deflate against converged vectors (per node)
                for u in done:
                    z = z - u * jnp.sum(u * z, axis=1, keepdims=True)
                v = z / jnp.linalg.norm(z, axis=1, keepdims=True)
                cur = [c if i != k else v for i, c in enumerate(cols)]
                qm = jnp.stack([c.mean(0) for c in cur], axis=1)
                errs.append(_trace(q_true, qm))
            cols[k] = v
            done.append(v)
        q_nodes = jnp.stack(cols, axis=2)  # (n, d, r)
        errs = np.asarray(errs)
    if ledger is not None and closed_form:
        ledger.log_gossip_rounds(np.full(n_steps, t_c),
                                 engine.graph.adjacency, d,
                                 bytes_per_elem=getattr(
                                     engine, "payload_bytes_per_elem", 4.0))
    return q_nodes, errs


# --------------------------------------------------------------------------
# distributed Sanger's algorithm (DSA)
# --------------------------------------------------------------------------
def _dsa_build_body(operands, *, trace_err: bool):
    covs, w, lr, q_true, node_mask = operands

    def body(q, _):
        mixed = gossip_mix(w.astype(q.dtype), q)
        mq = local_cov_apply(covs, q)
        qmq = jnp.einsum("ndr,nds->nrs", q, mq)
        upper = jnp.triu(qmq)
        sanger = mq - jnp.einsum("ndr,nrs->nds", q, upper)
        q_new = mixed + lr * sanger
        err = (subspace_error(q_true, _masked_node_mean(q_new, node_mask))
               if trace_err else jnp.float32(0.0))
        return q_new, err

    return runtime.sync_body(body)


def dsa(covs: jnp.ndarray, engine: DenseConsensus, r: int, t_outer: int,
        lr: float = 0.1, q_true=None, seed: int = 0,
        ledger: Optional[CommLedger] = None, fused: bool = True):
    """Q_i <- sum_j w_ij Q_j + lr * (M_i Q_i - Q_i UT(Q_i^T M_i Q_i)).

    Converges linearly to a *neighborhood* of the truth (paper Fig. 4/5).
    One gossip round per iteration (as in [19]).
    """
    n, d, _ = covs.shape
    if fused and _supports_fused(engine):
        run = runtime.run_monolithic(baseline_program(
            "dsa", covs=covs, engine=engine, r=r, t_outer=t_outer, lr=lr,
            q_true=q_true, seed=seed))
        if ledger is not None:
            ledger.merge_from(run.ledger)
        return run.q, run.error_trace
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    q = jnp.broadcast_to(q0[None], (n, d, r))
    errs = []
    for _ in range(t_outer):
        mixed = engine.run(q, 1)
        mq = local_cov_apply(covs, q)
        qmq = jnp.einsum("ndr,nds->nrs", q, mq)
        upper = jnp.triu(qmq)
        sanger = mq - jnp.einsum("ndr,nrs->nds", q, upper)
        q = mixed + lr * sanger
        errs.append(_trace(q_true, q.mean(0)))
    errs = np.asarray(errs)
    if ledger is not None:
        ledger.log_gossip_rounds(np.ones(t_outer), engine.graph.adjacency,
                                 d * r,
                                 bytes_per_elem=getattr(
                                     engine, "payload_bytes_per_elem", 4.0))
    return q, errs


# --------------------------------------------------------------------------
# distributed projected gradient descent (DPGD)
# --------------------------------------------------------------------------
def _dpgd_build_body(operands, *, trace_err: bool):
    covs, w, lr, q_true, node_mask = operands

    def body(q, _):
        mixed = gossip_mix(w.astype(q.dtype), q)
        grad = local_cov_apply(covs, q)
        v = mixed + lr * grad
        q_new = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)
        err = (subspace_error(q_true, _masked_node_mean(q_new, node_mask))
               if trace_err else jnp.float32(0.0))
        return q_new, err

    return runtime.sync_body(body)


def dpgd(covs: jnp.ndarray, engine: DenseConsensus, r: int, t_outer: int,
         lr: float = 0.1, q_true=None, seed: int = 0,
         ledger: Optional[CommLedger] = None, fused: bool = True):
    """Trace-maximization DGD + QR retraction (converges to a neighborhood)."""
    n, d, _ = covs.shape
    if fused and _supports_fused(engine):
        run = runtime.run_monolithic(baseline_program(
            "dpgd", covs=covs, engine=engine, r=r, t_outer=t_outer, lr=lr,
            q_true=q_true, seed=seed))
        if ledger is not None:
            ledger.merge_from(run.ledger)
        return run.q, run.error_trace
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    q = jnp.broadcast_to(q0[None], (n, d, r))
    errs = []
    for _ in range(t_outer):
        mixed = engine.run(q, 1)
        grad = local_cov_apply(covs, q)  # d/dQ Tr(Q^T M_i Q) = 2 M_i Q
        v = mixed + lr * grad
        q = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)
        errs.append(_trace(q_true, q.mean(0)))
    errs = np.asarray(errs)
    if ledger is not None:
        ledger.log_gossip_rounds(np.ones(t_outer), engine.graph.adjacency,
                                 d * r,
                                 bytes_per_elem=getattr(
                                     engine, "payload_bytes_per_elem", 4.0))
    return q, errs


# --------------------------------------------------------------------------
# DeEPCA — gradient tracking + power iteration
# --------------------------------------------------------------------------
def _deepca_build_body(operands, *, t_mix: int, trace_err: bool):
    """Carry: the (q, s, mq_prev) tracking triple — the runtime's carry is
    an arbitrary pytree, so DeEPCA's gradient-tracking state checkpoints
    through the generic chunk driver like any iterate."""
    covs, w, q_true, node_mask = operands

    def body(carry, _):
        q, s, mq_prev = carry
        wz = w.astype(s.dtype)

        def mix(z, _):
            return gossip_mix(wz, z), None

        s, _ = jax.lax.scan(mix, s, None, length=t_mix)
        # sign-fixed orthonormalization (DeEPCA's rounding keeps tracking valid)
        q_new = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(s)
        sign = jnp.sign(jnp.einsum("ndr,ndr->nr", q_new, q))
        sign = jnp.where(sign == 0, 1.0, sign)
        q_new = q_new * sign[:, None, :]
        mq_new = local_cov_apply(covs, q_new)
        s = s + mq_new - mq_prev       # gradient tracking correction
        err = (subspace_error(q_true, _masked_node_mean(q_new, node_mask))
               if trace_err else jnp.float32(0.0))
        return (q_new, s, mq_new), err

    return runtime.sync_body(body)


def deepca(covs: jnp.ndarray, engine: DenseConsensus, r: int, t_outer: int,
           t_mix: int = 3, q_true=None, seed: int = 0,
           ledger: Optional[CommLedger] = None, fused: bool = True):
    """Gradient-tracking power iteration (Ye & Zhang '21, paper ref [27]).

    s_i tracks (1/N) sum_j M_j Q_j exactly in the limit; a constant number of
    FastMix/gossip rounds per outer iteration suffices — that is the log-factor
    advantage over S-DOT the paper's Remark 1 concedes.
    """
    n, d, _ = covs.shape
    if fused and _supports_fused(engine):
        run = runtime.run_monolithic(baseline_program(
            "deepca", covs=covs, engine=engine, r=r, t_outer=t_outer,
            t_mix=t_mix, q_true=q_true, seed=seed))
        if ledger is not None:
            ledger.merge_from(run.ledger)
        return run.q, run.error_trace
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    q = jnp.broadcast_to(q0[None], (n, d, r))
    mq_prev = local_cov_apply(covs, q)
    s = mq_prev
    errs = []
    for _ in range(t_outer):
        s = engine.run(s, t_mix)
        q_new = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(s)
        # align signs with previous iterate for smooth tracking
        sign = jnp.sign(jnp.einsum("ndr,ndr->nr", q_new, q))
        sign = jnp.where(sign == 0, 1.0, sign)
        q_new = q_new * sign[:, None, :]
        mq_new = local_cov_apply(covs, q_new)
        s = s + mq_new - mq_prev       # gradient tracking correction
        mq_prev, q = mq_new, q_new
        errs.append(_trace(q_true, q.mean(0)))
    errs = np.asarray(errs)
    if ledger is not None:
        ledger.log_gossip_rounds(np.full(t_outer, t_mix),
                                 engine.graph.adjacency, d * r,
                                 bytes_per_elem=getattr(
                                     engine, "payload_bytes_per_elem", 4.0))
    return q, errs


# --------------------------------------------------------------------------
# d-PM — sequential distributed power method for feature-partitioned data
# --------------------------------------------------------------------------
def _d_pm_build_body(operands, *, r: int, iters_per_vec: int, t_c: int,
                     t_max: int, trace_err: bool):
    """Runtime body for d-PM: one step of the flattened (k, j) index.

    x_pad: (N, d_max, n) zero-padded feature slabs; carry: (r, N, d_max)
    per-vector padded slab estimates; qtrue_pad: (N, d_max, r_true). All
    dots/norms run over the padded layout — exact, padding entries are zero.
    """
    x_pad, w, table, qtrue_pad = operands

    def body(blocks, m):
        k = m // iters_per_vec
        vb = jnp.take(blocks, k, axis=0)                       # (N, d_max)
        partial = jnp.einsum("idn,id->in", x_pad, vb)          # (N, n)
        ssum = debiased_gossip(w, table, partial, jnp.int32(t_c), t_max)
        vb = jnp.einsum("idn,in->id", x_pad, ssum)

        def defl(kk, vv):
            u = blocks[kk]
            return jnp.where(kk < k, vv - u * jnp.sum(u * vv), vv)

        vb = jax.lax.fori_loop(0, r, defl, vb)
        vb = vb / jnp.linalg.norm(vb)
        blocks = blocks.at[k].set(vb)
        if trace_err:
            cross = jnp.einsum("ids,jid->sj", qtrue_pad, blocks)
            err = subspace_error_from_cross(cross)
        else:
            err = jnp.float32(0.0)
        return blocks, err

    return runtime.sync_body(body)


def d_pm(data_blocks: Sequence[jnp.ndarray], engine: DenseConsensus, r: int,
         iters_per_vec: int, t_c: int = 50, q_true=None, seed: int = 0,
         ledger: Optional[CommLedger] = None, fused: bool = True):
    """Scaglione et al. [10]: estimate eigenvectors one at a time, each via
    power iterations on M = X X^T executed feature-wise with consensus."""
    from .fdot import pad_feature_slabs, split_pad_rows

    dims = [int(x.shape[0]) for x in data_blocks]
    d = sum(dims)
    n_samples = int(data_blocks[0].shape[1])
    offs = np.cumsum([0] + dims)
    n_nodes = len(data_blocks)
    q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    closed_form = _supports_fused(engine)   # sync engines: every round equal
    fused = fused and closed_form
    n_steps = r * iters_per_vec
    if fused:
        run = runtime.run_monolithic(baseline_program(
            "d_pm", data_blocks=data_blocks, engine=engine, r=r,
            iters_per_vec=iters_per_vec, t_c=t_c, q_true=q_true, seed=seed))
        if ledger is not None:
            ledger.merge_from(run.ledger)
        return run.q, run.error_trace
    else:
        blocks = [[q0[offs[i]:offs[i + 1], k] for i in range(n_nodes)]
                  for k in range(r)]
        errs = []
        done_full: list = []
        for k in range(r):
            vb = blocks[k]
            for _ in range(iters_per_vec):
                partial = jnp.stack(
                    [x.T @ v for x, v in zip(data_blocks, vb)])  # (N,n)
                ssum = engine.run_debiased(partial, t_c,
                                           None if closed_form else ledger)
                vb = [x @ ssum[i] for i, x in enumerate(data_blocks)]
                vfull = jnp.concatenate(vb)
                for u in done_full:
                    vfull = vfull - u * (u @ vfull)
                vfull = vfull / jnp.linalg.norm(vfull)
                vb = [vfull[offs[i]:offs[i + 1]] for i in range(n_nodes)]
                cur = jnp.stack(
                    [jnp.concatenate(blocks[j]) if j != k else vfull
                     for j in range(r)], 1)
                errs.append(_trace(q_true, cur))
            blocks[k] = vb
            done_full.append(jnp.concatenate(vb))
        q_full = jnp.stack([jnp.concatenate(b) for b in blocks], axis=1)
        errs = np.asarray(errs)
    if ledger is not None and closed_form:
        ledger.log_gossip_rounds(np.full(n_steps, t_c),
                                 engine.graph.adjacency, n_samples,
                                 bytes_per_elem=getattr(
                                     engine, "payload_bytes_per_elem", 4.0))
    return q_full, errs


# --------------------------------------------------------------------------
# unified-runtime registration
# --------------------------------------------------------------------------
def baseline_program(
    name: str,
    *,
    covs: Optional[jnp.ndarray] = None,
    data_blocks: Optional[Sequence[jnp.ndarray]] = None,
    engine: Optional[DenseConsensus] = None,
    r: int,
    t_outer: Optional[int] = None,
    iters_per_vec: Optional[int] = None,
    lr: float = 0.1,
    t_mix: int = 3,
    t_c: int = 50,
    q_true=None,
    seed: int = 0,
) -> runtime.Program:
    """Register one fused baseline run with the unified executor runtime.

    ``name``: dsa | dpgd | deepca (need ``covs`` + ``t_outer``),
    seq_dist_pm (``covs`` + ``iters_per_vec``), or d_pm (``data_blocks`` +
    ``iters_per_vec``). ``runtime.run_monolithic`` reproduces the fused
    default paths of the public functions; ``runtime.run_chunked`` makes
    every baseline restartable (kill-at-chunk-boundary bit-identical
    resume) — a capability none of them had before the unified runtime.
    """
    if engine is None:
        raise ValueError("baseline_program needs an engine")
    if not _supports_fused(engine):
        raise ValueError(f"fused {name} needs a dense-weight engine with a "
                         "debias table")
    trace_err = q_true is not None

    if name in ("dsa", "dpgd", "deepca"):
        if covs is None or t_outer is None:
            raise ValueError(f"{name} needs covs and t_outer")
        n, d, _ = covs.shape
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        q0 = jnp.broadcast_to(
            orthonormal_init(jax.random.PRNGKey(seed), d, r)[None],
            (n, d, r))
        ones = jnp.ones((n,), jnp.float32)
        xs = np.zeros(t_outer, np.int32)          # bodies ignore the input
        payload = d * r
        if name == "deepca":
            build = _deepca_build_body
            statics = (("t_mix", t_mix), ("trace_err", trace_err))
            operands = (covs, engine._w, q_arg, ones)
            s0 = local_cov_apply(covs, q0)
            carry0 = (q0, s0, s0)
            rounds = lambda done: np.full(done, t_mix)
            to_q = lambda carry: carry[0]
        else:
            build = _dsa_build_body if name == "dsa" else _dpgd_build_body
            statics = (("trace_err", trace_err),)
            operands = (covs, engine._w, jnp.float32(lr), q_arg, ones)
            carry0 = q0
            rounds = lambda done: np.ones(done)
            to_q = lambda carry: carry
    elif name == "seq_dist_pm":
        if covs is None or iters_per_vec is None:
            raise ValueError("seq_dist_pm needs covs and iters_per_vec")
        n, d, _ = covs.shape
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        q0 = orthonormal_init(jax.random.PRNGKey(seed), d, r)
        carry0 = jnp.broadcast_to(q0.T[:, None, :], (r, n, d))
        build = _seq_dist_pm_build_body
        statics = (("r", r), ("iters_per_vec", iters_per_vec),
                   ("t_c", t_c), ("t_max", t_c), ("trace_err", trace_err))
        operands = (covs, engine._w, engine.debias_table(t_c), q_arg)
        xs = np.arange(r * iters_per_vec, dtype=np.int32)
        payload = d
        rounds = lambda done: np.full(done, t_c)
        to_q = lambda cols: jnp.transpose(cols, (1, 2, 0))     # (n, d, r)
    elif name == "d_pm":
        if data_blocks is None or iters_per_vec is None:
            raise ValueError("d_pm needs data_blocks and iters_per_vec")
        from .fdot import pad_feature_slabs, split_pad_rows

        dims = [int(x.shape[0]) for x in data_blocks]
        d = sum(dims)
        x_pad = pad_feature_slabs(data_blocks)
        q0_pad = split_pad_rows(
            orthonormal_init(jax.random.PRNGKey(seed), d, r), dims)
        carry0 = jnp.transpose(q0_pad, (2, 0, 1))              # (r, N, d_max)
        qtrue_pad = (split_pad_rows(q_true, dims) if trace_err
                     else jnp.zeros_like(q0_pad))
        build = _d_pm_build_body
        statics = (("r", r), ("iters_per_vec", iters_per_vec),
                   ("t_c", t_c), ("t_max", t_c), ("trace_err", trace_err))
        operands = (x_pad, engine._w, engine.debias_table(t_c), qtrue_pad)
        xs = np.arange(r * iters_per_vec, dtype=np.int32)
        payload = int(data_blocks[0].shape[1])                 # n_samples
        rounds = lambda done: np.full(done, t_c)
        to_q = lambda blocks: jnp.concatenate(
            [blocks[:, i, :di].T for i, di in enumerate(dims)], axis=0)
    else:
        raise ValueError(f"unknown baseline: {name}")

    def finalize(state: runtime.RunState, done: int) -> BaselineResult:
        ledger = CommLedger()
        ledger.log_gossip_rounds(rounds(done), engine.graph.adjacency,
                                 payload,
                                 bytes_per_elem=getattr(
                                     engine, "payload_bytes_per_elem", 4.0))
        return BaselineResult(
            q=to_q(state.q),
            error_trace=_finish_errs(state.errs[:done], done, trace_err),
            ledger=ledger,
        )

    return runtime.Program(
        build_body=build,
        operands=operands,
        statics=statics,
        xs=xs,
        q0=carry0,
        finalize=finalize,
    )
