"""JAX version-compatibility shims.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
(with ``check_rep``/``auto`` renamed to ``check_vma``/complement-of-
``axis_names``). This module exposes one callable with the *new* keyword
surface that works on both API generations, so the rest of the codebase can
write modern call sites unconditionally.
"""
from __future__ import annotations

from typing import Optional, Set

import jax

__all__ = ["shard_map", "LEGACY_SHARD_MAP"]

# True on jax < 0.5 (experimental shard_map). The legacy partitioner CHECK-
# crashes (hlo_sharding_util IsManualSubgroup) on sharding constraints that
# name auto axes inside a partial-auto manual region; callers use this flag
# to drop such perf-hint constraints there.
LEGACY_SHARD_MAP = not hasattr(jax, "shard_map")

if not LEGACY_SHARD_MAP:
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs,
                  axis_names: Optional[Set[str]] = None,
                  check_vma: Optional[bool] = None, **kwargs):
        """New-API facade over the pre-0.5 experimental shard_map.

        ``axis_names`` (manual axes) maps to the legacy ``auto`` argument
        (its complement); ``check_vma`` maps to ``check_rep``.
        """
        legacy = {}
        if axis_names is not None:
            legacy["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
        if check_vma is not None:
            legacy["check_rep"] = check_vma
        legacy.update(kwargs)
        return _legacy_shard_map(f, mesh, in_specs, out_specs, **legacy)
