import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
# The two lines above MUST run before any jax import — jax locks the device
# count at first backend init, and the production meshes need 512 host
# placeholder devices. (Tests/benchmarks never import this module, so they
# see the real single CPU device.)
"""Dry-run driver (see module header comment; docstring kept below the
XLA_FLAGS lines deliberately).

Per cell this proves, without hardware:
  * the sharding config is coherent (lower+compile succeeds, no sharding
    mismatch, no unsupported collective),
  * the memory plan fits (memory_analysis),
  * and it yields the roofline terms (cost_analysis + collective parsing).

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --multipod
  python -m repro.launch.dryrun --all --out experiments/dryrun   (subprocesses)
"""

import argparse
import json
import subprocess
import sys
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_arch, get_psa_config, valid_cells
from ..configs.base import ModelConfig, ShapeConfig
from ..models import sharding as shd
from ..models.transformer import init_decode_state, init_params
from ..optim.adamw import AdamWConfig, adamw_init
from ..optim.psa_compress import psa_init
from .hlo_analysis import collective_bytes, roofline_terms
from .mesh import HW, make_production_mesh

__all__ = ["input_specs", "abstract_state", "run_cell"]


def _sds(tree, mesh, specs):
    """ShapeDtypeStructs with shardings attached (no allocation)."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=NamedSharding(mesh, s)),
        tree, specs, is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    bspecs = shd.batch_specs(cfg, mesh, b)
    if shape.kind == "train":
        tshape = (b, s, cfg.n_codebooks) if cfg.frontend == "audio_codec" else (b, s)
        out = {
            "tokens": jax.ShapeDtypeStruct(
                tshape, jnp.int32, sharding=NamedSharding(mesh, bspecs["tokens"])),
            "labels": jax.ShapeDtypeStruct(
                tshape, jnp.int32, sharding=NamedSharding(mesh, bspecs["labels"])),
        }
        if cfg.frontend == "vlm_patches":
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_tokens, cfg.d_model), jnp.float32,
                sharding=NamedSharding(mesh, bspecs["patch_embeds"]))
        return out
    if shape.kind == "prefill":
        tshape = (b, s, cfg.n_codebooks) if cfg.frontend == "audio_codec" else (b, s)
        out = {"tokens": jax.ShapeDtypeStruct(
            tshape, jnp.int32, sharding=NamedSharding(mesh, bspecs["tokens"]))}
        if cfg.frontend == "vlm_patches":
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_tokens, cfg.d_model), jnp.float32,
                sharding=NamedSharding(mesh, bspecs["patch_embeds"]))
        return out
    # decode: one new token against a seq_len-deep cache
    tshape = (b, 1, cfg.n_codebooks) if cfg.frontend == "audio_codec" else (b, 1)
    return {"tokens": jax.ShapeDtypeStruct(
        tshape, jnp.int32, sharding=NamedSharding(mesh, bspecs["tokens"]))}


def abstract_state(cfg: ModelConfig, shape: ShapeConfig, mesh, opt: AdamWConfig,
                   *, psa=None):
    """Abstract (ShapeDtypeStruct) params / optimizer / decode state."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_shape = jax.eval_shape(lambda k: init_params(k, cfg), key)
    pspecs = shd.param_specs(params_shape, cfg, mesh)
    params_sds = _sds(params_shape, mesh, pspecs)
    out = {"params": params_sds, "pspecs": pspecs}
    if shape.kind == "train":
        opt_shape = jax.eval_shape(lambda p: adamw_init(p, opt), params_shape)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        out["opt"] = _sds(opt_shape, mesh, ospecs)
        if psa is not None:
            psa_shape = jax.eval_shape(
                lambda p: psa_init(p, psa), params_shape)
            # projectors / EF buffers are pod-replicated (P() everywhere)
            psa_specs = jax.tree.map(
                lambda l: P(*([None] * l.ndim)) if l is not None else None,
                psa_shape, is_leaf=lambda x: x is None or hasattr(x, "shape"))
            out["psa"] = jax.tree.map(
                lambda a, s: jax.ShapeDtypeStruct(
                    a.shape, a.dtype, sharding=NamedSharding(mesh, s))
                if a is not None else None,
                psa_shape, psa_specs,
                is_leaf=lambda x: x is None or hasattr(x, "shape"))
    else:
        cache_len = shape.seq_len
        st_shape = jax.eval_shape(
            lambda: init_decode_state(cfg, shape.global_batch, cache_len))
        st_specs = shd.decode_state_specs(st_shape, cfg, mesh, shape.global_batch)
        out["decode_state"] = _sds(st_shape, mesh, st_specs)
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6 N D (train) / 2 N D (prefill & decode), N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch     # one token per sequence


def run_cell(arch: str, shape_id: str, *, multi_pod: bool, psa: bool = False,
             use_pallas: bool = False, remat: bool = True,
             constrain_acts: bool = True,
             out_path: str | None = None) -> Dict[str, Any]:
    from ..train.step import loss_fn, make_psa_train_step  # late import
    from ..models.transformer import decode_step
    from ..optim.adamw import adamw_update

    cfg = get_arch(arch)
    shape = SHAPES[shape_id]
    if shape_id == "long_500k" and not cfg.subquadratic:
        res = {"arch": arch, "shape": shape_id, "multi_pod": multi_pod,
               "status": "skipped",
               "reason": "full-attention arch: 500k decode cache infeasible"}
        if out_path:
            json.dump(res, open(out_path, "w"), indent=1)
        return res

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    opt = AdamWConfig(moment_dtype="bfloat16" if cfg.param_count() > 2e11 else "float32")
    psa_cfg = get_psa_config() if psa else None
    abs_state = abstract_state(cfg, shape, mesh, opt, psa=psa_cfg)
    ins = input_specs(cfg, shape, mesh)
    aspecs = shd.activation_specs(cfg, mesh, shape.global_batch) \
        if constrain_acts else None

    t0 = time.time()
    if shape.kind == "train":
        if psa:
            step_fn, _, _ = make_psa_train_step(
                cfg, mesh, opt, psa_cfg, global_batch=shape.global_batch,
                use_pallas=use_pallas, remat=remat)
            lowered = step_fn.lower(abs_state["params"], abs_state["opt"],
                                    abs_state["psa"], ins)
        else:
            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(loss_fn)(
                    params, batch, cfg, use_pallas=use_pallas, remat=remat,
                    act_specs=aspecs)
                new_p, new_o, gn = adamw_update(grads, opt_state, params, opt)
                return new_p, new_o, {"loss": loss, "grad_norm": gn}

            with mesh:
                lowered = jax.jit(train_step).lower(
                    abs_state["params"], abs_state["opt"], ins)
    elif shape.kind == "prefill":
        from ..models.transformer import forward

        def prefill(params, batch):
            return forward(params, batch, cfg, use_pallas=use_pallas,
                           remat=False, act_specs=aspecs)

        with mesh:
            lowered = jax.jit(prefill).lower(abs_state["params"], ins)
    else:
        def serve_step(params, state, tokens):
            return decode_step(params, state, tokens, cfg, act_specs=aspecs)

        with mesh:
            lowered = jax.jit(serve_step).lower(
                abs_state["params"], abs_state["decode_state"], ins["tokens"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):  # jax < 0.5 returns a per-program list
        ca = ca[0] if ca else {}
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo, n_dev)
    pod_split = None
    if multi_pod:
        from .hlo_analysis import cross_pod_bytes
        pod_split = cross_pod_bytes(hlo, n_dev, 256)
    mf = model_flops(cfg, shape)
    total_flops = flops_dev * n_dev
    terms = roofline_terms(flops_per_dev=flops_dev, bytes_per_dev=bytes_dev,
                           wire_bytes_per_dev=coll.wire_bytes, hw=HW)
    res = {
        "arch": arch, "shape": shape_id, "multi_pod": multi_pod, "psa": psa,
        "status": "ok", "n_devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
        "total_flops": total_flops,
        "model_flops": mf,
        "useful_flops_frac": mf / total_flops if total_flops else None,
        "collectives": {"wire_bytes_per_dev": coll.wire_bytes,
                        "by_kind": coll.by_kind, "count": coll.count,
                        "pod_split": pod_split},
        "memory": mem_info,
        "roofline": terms,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if out_path:
        json.dump(res, open(out_path, "w"), indent=1)
    return res


def _run_all(out_dir: str, multi_pod_also: bool = True):
    import os as _os
    _os.makedirs(out_dir, exist_ok=True)
    cells = valid_cells()
    meshes = [False, True] if multi_pod_also else [False]
    failures = []
    for cell in cells:
        for mp in meshes:
            tag = f"{cell['arch']}__{cell['shape']}__{'mp' if mp else 'sp'}"
            out = _os.path.join(out_dir, tag + ".json")
            if _os.path.exists(out):
                print(f"[skip cached] {tag}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", cell["arch"], "--shape", cell["shape"],
                   "--out", out] + (["--multipod"] if mp else [])
            print(f"[run] {tag}", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures.append((tag, r.stderr[-2000:]))
                print(f"[FAIL] {tag}\n{r.stderr[-2000:]}", flush=True)
    print(f"done; {len(failures)} failures")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--psa", action="store_true",
                    help="PSA-compressed cross-pod gradient reduction")
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    if args.all:
        _run_all(args.out or "experiments/dryrun")
        return
    res = run_cell(args.arch, args.shape, multi_pod=args.multipod, psa=args.psa,
                   use_pallas=args.pallas, remat=not args.no_remat,
                   out_path=args.out)
    slim = {k: v for k, v in res.items() if k not in ("memory",)}
    print(json.dumps(slim, indent=1, default=str))
    if res.get("memory"):
        print("memory_analysis:", res["memory"])


if __name__ == "__main__":
    main()
