from .pipeline import (gaussian_eigengap_data, make_lm_batch,  # noqa: F401
                       partition_features, partition_samples, synthetic_lm_stream)
