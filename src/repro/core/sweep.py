"""Monte-Carlo sweep engine over the unified executor runtime.

The paper's Figs. 4-6 are Monte-Carlo averages over random initializations
(and the tables sweep topologies and consensus schedules). Each sweep here
is ONE ``runtime.Program`` with a stacked case axis (topology x schedule)
on its operands and (case, seed) lane axes on its carry —
``runtime.run_sweep`` vmaps the family's OWN scan body over the grid, so a
full sweep compiles once and runs in one device call:

* the **seed axis** vmaps per-seed orthonormal inits;
* the **case axis** vmaps the stacked weight matrices, debias tables, and
  schedule arrays — all dense (N, N) / (t_max+1, N) / (T_o,) arrays, so
  heterogeneous graphs stack as long as they share the node count;
* **ragged node counts** (the Table-II connectivity axis: ER N=10 next to
  ring N=20) stack too: ``sdot_sweep`` / ``baseline_sweep`` (dsa / dpgd /
  deepca) pad each per-case cov stack to N_max with *isolated identity
  nodes* (block-diag(W, I) weights, identity covs, node-masked error
  trace); ``fdot_sweep`` pads per-case slab lists with *all-zero slabs*,
  exact no-ops in every product of Alg. 2, so no mask is needed. See
  ``sweep_utils`` for why the padding is exact; padded traces match the
  unpadded per-case runs bit-comparably.

Because sweeps are ordinary runtime Programs they inherit the chunked
driver for free: pass ``manager``/``chunk_size`` and the sweep-RunState
checkpoints at chunk boundaries — a killed multi-day sweep worker resumes
MID-GRID, bitwise equal to the uninterrupted sweep
(``streaming/worker.py`` runs exactly this path).

Compare: the eager zoo runs seeds x cases x t_outer Python iterations with
a host sync each — the sweep engine runs one dispatch total and the whole
(C, S, T_o) error tensor comes back in one transfer (benchmarks/
sweep_bench.py measures the win; tests/test_fused_zoo.py pins sweep ==
per-seed fused runs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import runtime
from .baselines import (_d_pm_build_body, _deepca_build_body,
                        _dpgd_build_body, _dsa_build_body,
                        _seq_dist_pm_build_body)
from .consensus import DenseConsensus, consensus_schedule, debias_table
from .fdot import _fdot_build_body, pad_feature_slabs, split_pad_rows
from .linalg import orthonormal_init
from .metrics import CommLedger
from .sdot import _sdot_build_body, _stack_data
from .sweep_utils import (broadcast_per_case, case_node_masks,
                          pad_covs_identity, pad_weights_identity,
                          pad_zero_nodes)

__all__ = ["SweepResult", "sdot_sweep", "fdot_sweep", "baseline_sweep",
           "netfault_sweep", "slice_seed_shards"]


def slice_seed_shards(seeds: Sequence[int], n_shards: int) -> list:
    """Slice the Monte-Carlo seed axis into contiguous lease-granular shards.

    This is the fleet's unit of work (and of fault tolerance): each shard
    is one vmap lane-slice a worker computes, checkpoints, and publishes
    independently, so the multi-host launcher can retry, steal, or
    re-assign shards without touching the others. Contiguity is what makes
    the merged sweep equal the single-process sweep — concatenating the
    shard results along the seed axis preserves seed order exactly.
    ``n_shards`` may exceed the worker count (finer stealing granularity);
    it is clamped to the seed count so no shard is empty."""
    seeds = [int(s) for s in seeds]
    n_shards = max(1, min(int(n_shards), len(seeds)))
    return [list(map(int, s))
            for s in np.array_split(np.asarray(seeds), n_shards)]


@dataclasses.dataclass
class SweepResult:
    """Stacked outputs of a Monte-Carlo sweep.

    ``q`` and ``error_traces`` carry a leading case axis C (only when the
    sweep ran multiple topology/schedule cases) and a seed axis S.

    ``node_counts`` is set by ragged-N sweeps: ``q[c]`` then has node axis
    N_max and only the first ``node_counts[c]`` entries are real (the rest
    are the isolated identity-padding nodes).

    ``steps_done`` counts completed outer iterations (< t_outer only for a
    chunked sweep killed mid-grid; traces cover the completed prefix) and
    ``resumed_step`` is the outer step the restored sweep-RunState carried
    (0 = fresh). ``resume_report`` is filled by the multi-host launcher
    when resuming a workdir: reused shards + per-worker resumed steps.
    """

    q: jnp.ndarray                 # (C?, S, ...) final estimates
    error_traces: Optional[np.ndarray]   # (C?, S, T) per-seed error traces
    ledger: CommLedger             # aggregate communication over all runs
    seeds: np.ndarray
    node_counts: Optional[np.ndarray] = None
    steps_done: Optional[int] = None
    resumed_step: int = 0
    resume_report: Optional[dict] = None

    def _traces(self) -> np.ndarray:
        if self.error_traces is None:
            raise ValueError("sweep ran without q_true — no error traces "
                             "were recorded")
        return self.error_traces

    @property
    def mean_trace(self) -> np.ndarray:
        """Monte-Carlo mean over the seed axis."""
        return self._traces().mean(axis=-2)

    @property
    def std_trace(self) -> np.ndarray:
        return self._traces().std(axis=-2)

    @classmethod
    def merge_shards(cls, trees: Sequence[dict], *, n_cases: int,
                     has_err: bool, ragged: bool,
                     resume_report: Optional[dict] = None) -> "SweepResult":
        """Merge per-shard result trees along the seed axis.

        ``trees`` are the published shard payloads (``q``, ``seeds``,
        ``ledger``, optional ``error_traces`` / ``node_counts``) in shard
        order — contiguous seed slices from ``slice_seed_shards``, so
        concatenation reproduces the single-process sweep's seed order
        exactly and the merged result is arithmetically identical to it
        (bitwise when the shard lane widths match).

        Two classes of operator error are rejected instead of silently
        concatenated: shards published under DIFFERENT spec fingerprints
        (e.g. a workdir reused across sweep configurations), and shards
        whose seed slices OVERLAP (e.g. mixing shard files from two
        different ``n_shards`` partitionings of the same seed list) —
        either would yield a merged result that matches no single-process
        sweep."""
        fps = sorted({int(np.asarray(tree["spec_fp"])) for tree in trees
                      if "spec_fp" in tree})
        if len(fps) > 1:
            raise ValueError(
                "merge_shards: shards come from different sweep specs "
                f"(spec fingerprints {fps}) — refusing to merge results "
                "of different configurations")
        seen = {}
        for i, tree in enumerate(trees):
            for s in np.asarray(tree["seeds"]).reshape(-1).tolist():
                s = int(s)
                if s in seen:
                    raise ValueError(
                        f"merge_shards: seed {s} appears in shard "
                        f"{seen[s]} and shard {i} — overlapping seed "
                        "slices (mixed shard partitionings?)")
                seen[s] = i
        seed_axis = 1 if n_cases > 1 else 0
        qs, errs, counts, node_counts = [], [], [], None
        ledger = CommLedger()
        for tree in trees:
            qs.append(np.asarray(tree["q"]))
            counts.append(np.asarray(tree["seeds"]))
            ledger = ledger.merged(tree["ledger"])
            if has_err:
                errs.append(np.asarray(tree["error_traces"]))
            if ragged:
                node_counts = np.asarray(tree["node_counts"])
        return cls(
            q=jnp.asarray(np.concatenate(qs, axis=seed_axis)),
            error_traces=(np.concatenate(errs, axis=seed_axis)
                          if has_err else None),
            ledger=ledger,
            seeds=np.concatenate(counts),
            node_counts=node_counts,
            resume_report=resume_report,
        )


def _seed_inits(seeds: Sequence[int], d: int, r: int) -> jnp.ndarray:
    """(S, d, r) orthonormal inits, one per Monte-Carlo seed (vmapped QR)."""
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    return jax.vmap(lambda k: orthonormal_init(k, d, r))(keys)


def _broadcast_cases(engines, schedules, t_outer, t_c, allow_ragged=False):
    """Zip-broadcast engines x schedules into C aligned cases."""
    if isinstance(engines, DenseConsensus):
        engines = [engines]
    engines = list(engines)
    if schedules is None:
        schedules = [consensus_schedule("const", t_outer, t_max=t_c)]
    elif isinstance(schedules, np.ndarray) and schedules.ndim == 1:
        schedules = [schedules]
    schedules = [np.asarray(s) for s in schedules]
    for s in schedules:
        if len(s) < t_outer:
            raise ValueError(f"schedule has {len(s)} entries but "
                             f"t_outer={t_outer}")
    c = max(len(engines), len(schedules))
    if len(engines) == 1:
        engines = engines * c
    if len(schedules) == 1:
        schedules = schedules * c
    if len(engines) != len(schedules):
        raise ValueError("engines and schedules must zip-broadcast: got "
                         f"{len(engines)} vs {len(schedules)}")
    n_nodes = engines[0].graph.n_nodes
    if not allow_ragged and any(e.graph.n_nodes != n_nodes for e in engines):
        raise ValueError("all sweep engines must share the node count")
    return engines, [s[:t_outer] for s in schedules]


def _reject_sparse(engines) -> None:
    """Sweep fleets vmap over dense (C, N, N) weight stacks; sparse
    engines (``SparseW`` mixing) are not sweepable yet — fail with a
    clear message instead of a pytree-stacking TypeError deep in jnp."""
    if any(getattr(e, "is_sparse", False) for e in engines):
        raise ValueError(
            "sweeps require dense engines: construct with sparse=False "
            "(SparseW-backed engines are not vmappable across cases yet)")


def _case_stacks(engines, t_max):
    _reject_sparse(engines)
    ws = jnp.stack([e._w for e in engines])
    tables = jnp.stack([e.debias_table(t_max) for e in engines])
    return ws, tables


def _ragged_stacks(engines, t_max):
    """Identity-padded (C, N_max, N_max) weights + debias tables + masks for
    a mixed-node-count case axis."""
    _reject_sparse(engines)
    n_list = [e.graph.n_nodes for e in engines]
    n_max = max(n_list)
    ws = jnp.stack([jnp.asarray(pad_weights_identity(e.weights, n_max))
                    for e in engines])
    tables = jnp.stack([debias_table(w, t_max) for w in ws])
    masks = case_node_masks(n_list, n_max)                   # (C, N_max)
    return ws, tables, masks, n_list, n_max


def _check_case_covs(case_covs, engines):
    for c, e in zip(case_covs, engines):
        if c.shape[0] != e.graph.n_nodes:
            raise ValueError("per-case covs must match each engine's node "
                             f"count: got {c.shape[0]} covs for an "
                             f"{e.graph.n_nodes}-node graph")


def _squeeze_case(arr, single_case: bool):
    return arr[0] if single_case else arr


def _lane_q0(q0, n_cases: int):
    """Broadcast (S, ...) per-seed carry leaves to (C, S, ...) lanes."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_cases,) + a.shape), q0)


def _sweep_result(state, done, *, q_map, trace_err, single_case, ledger,
                  seeds, node_counts=None):
    errs = state.errs[..., :done]
    return SweepResult(
        q=_squeeze_case(q_map(state.q), single_case),
        error_traces=(np.asarray(_squeeze_case(errs, single_case))
                      if trace_err else None),
        ledger=ledger,
        seeds=np.asarray(list(seeds)),
        node_counts=node_counts,
        steps_done=done,
    )


def _run_sweep(build, operands, statics, xs, q0, case_axes, n_cases,
               n_seeds, finalize, manager, chunk_size, max_chunks,
               key0=None, tail=()):
    """Assemble the sweep Program and hand it to the runtime driver."""
    program = runtime.Program(
        build_body=build, operands=operands, statics=statics, xs=xs, q0=q0,
        key0=key0, tail=tail, case_axes=case_axes, n_cases=n_cases,
        n_seeds=n_seeds, finalize=finalize)
    result = runtime.run_sweep(program, manager=manager,
                               chunk_size=chunk_size, max_chunks=max_chunks)
    result.resumed_step = program.restored_step
    return result


def sdot_sweep(
    *,
    covs=None,
    data: Optional[Sequence[jnp.ndarray]] = None,
    engines: Union[DenseConsensus, Sequence[DenseConsensus]],
    r: int,
    t_outer: int,
    schedules=None,
    t_c: int = 50,
    seeds: Sequence[int] = (0,),
    q_true: Optional[jnp.ndarray] = None,
    manager=None,
    chunk_size: Optional[int] = None,
    max_chunks: Optional[int] = None,
) -> SweepResult:
    """Monte-Carlo S-DOT/SA-DOT sweep: seeds x (topology, schedule) cases in
    one compile + one device call.

    ``engines`` / ``schedules`` zip-broadcast into the case axis (pass one
    engine and k schedules, k engines and one schedule, or aligned lists).
    Each seed gets its own orthonormal init (the paper's Monte-Carlo axis).
    ``covs`` is one (N, d, d) stack shared by every case, or a list with
    one (N_c, d, d) stack per case (mixed node counts pad with isolated
    identity nodes — see the module docstring — and the result carries
    ``node_counts``). ``manager``/``chunk_size`` run the sweep through the
    chunked driver: the sweep-RunState checkpoints at chunk boundaries and
    a killed sweep (``max_chunks``) resumes mid-grid, bitwise equal to the
    uninterrupted sweep.
    """
    if (covs is None) == (data is None):
        raise ValueError("provide exactly one of covs / data")
    per_case_covs = covs is not None and isinstance(covs, (list, tuple))
    engines, schedules = _broadcast_cases(engines, schedules, t_outer, t_c,
                                          allow_ragged=per_case_covs)
    single_case = len(engines) == 1
    t_max = int(max(int(s.max()) for s in schedules)) if t_outer else 0
    trace_err = q_true is not None

    if per_case_covs:
        case_covs = broadcast_per_case([jnp.asarray(c) for c in covs],
                                       len(engines), "covs")
        _check_case_covs(case_covs, engines)
        d = int(case_covs[0].shape[1])
        ws, tables, masks, n_list, n_max = _ragged_stacks(engines, t_max)
        covs_pad = jnp.stack([pad_covs_identity(c, n_max)
                              for c in case_covs])           # (C, N_max, d, d)
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        operands = (covs_pad, ws, tables, q_arg, masks)
        case_axes = (0, 0, 0, None, 0)
        mode, n = "cov", n_max
        node_counts = np.asarray(n_list)
    else:
        n = engines[0].graph.n_nodes
        d = covs.shape[1] if covs is not None else data[0].shape[0]
        ws, tables = _case_stacks(engines, t_max)
        masks = jnp.ones((len(engines), n), jnp.float32)
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        if covs is not None:
            operands = (covs, ws, tables, q_arg, masks)
            case_axes = (None, 0, 0, None, 0)
            mode = "cov"
        else:
            x_stack, n_true = _stack_data(data)
            operands = (x_stack, n_true, ws, tables, q_arg, masks)
            case_axes = (None, None, 0, 0, None, 0)
            mode = "data"
        node_counts = None

    q0 = _seed_inits(seeds, d, r)                            # (S, d, r)
    q0_nodes = jnp.broadcast_to(q0[:, None], (len(seeds), n, d, r))

    ledger = CommLedger()
    payload = d * r

    def finalize(state, done):
        for eng, sched in zip(engines, schedules):
            for _ in seeds:
                ledger.log_gossip_rounds(sched[:done], eng.graph.adjacency,
                                         payload)
        return _sweep_result(state, done, q_map=lambda q: q,
                             trace_err=trace_err, single_case=single_case,
                             ledger=ledger, seeds=seeds,
                             node_counts=node_counts)

    return _run_sweep(
        _sdot_build_body, operands,
        (("mode", mode), ("t_max", t_max), ("trace_err", trace_err),
         ("is_async", False)),
        np.stack(schedules).astype(np.int64), _lane_q0(q0_nodes, len(engines)),
        case_axes, len(engines), len(list(seeds)), finalize,
        manager, chunk_size, max_chunks)


def netfault_sweep(
    *,
    covs,
    engines,
    r: int,
    t_outer: int,
    schedules=None,
    t_c: int = 50,
    seeds: Sequence[int] = (0,),
    q_true: Optional[jnp.ndarray] = None,
    manager=None,
    chunk_size: Optional[int] = None,
    max_chunks: Optional[int] = None,
) -> SweepResult:
    """Monte-Carlo S-DOT/SA-DOT sweep under network faults: seeds x
    (FaultyConsensus, schedule) cases in one compile + one device call.

    The case axis is a FAULT grid: each case is a ``FaultyConsensus``
    engine whose scalar fault knobs stack as (C, 6) lane data and whose
    crash windows lower to a (C, T, N) node-up stack — sweeping link-drop
    rate, burst length, or crash fraction recompiles NOTHING (one body, C
    lanes), which is what makes the degradation curves of
    ``benchmarks/netfaults_bench.py`` cheap. Per-lane RNG keys are derived
    by folding each seed VALUE into each case engine's key, so a sweep
    shard computes bitwise the same lanes whether it runs alone or inside
    the full grid (shard-merge independence, the fleet's requirement).
    All case engines must share the node count and the ``debias`` mode
    (``debias`` is a compile-time static of the shared body).

    ``manager``/``chunk_size`` run the sweep through the chunked driver —
    the Gilbert–Elliott state and iteration counter ride in the
    checkpointed carry, so a killed faulty sweep resumes mid-grid bitwise
    equal to the uninterrupted one.
    """
    if not isinstance(engines, (list, tuple)):
        engines = [engines]
    for e in engines:
        if not hasattr(e, "sample_faults"):
            raise ValueError("netfault_sweep needs FaultyConsensus engines")
    engines, schedules = _broadcast_cases(list(engines), schedules, t_outer,
                                          t_c)
    debias = engines[0].debias
    if any(e.debias != debias for e in engines):
        raise ValueError("all netfault_sweep engines must share the debias "
                         "mode (it is a compile-time static)")
    single_case = len(engines) == 1
    n = engines[0].graph.n_nodes
    d = covs.shape[1]
    t_max = int(max(int(s.max()) for s in schedules)) if t_outer else 0
    trace_err = q_true is not None
    s_list = [int(s) for s in seeds]

    _reject_sparse(engines)
    ws = jnp.stack([e._w for e in engines])
    adjs = jnp.stack([e._adj for e in engines])
    params = jnp.stack([e._params for e in engines])          # (C, 6)
    node_up = jnp.stack([
        jnp.asarray(e.faults.validate(n, t_outer).node_up(t_outer, n))
        for e in engines])                                    # (C, T, N)
    tables = jnp.stack([debias_table(e._w, t_max) for e in engines])
    q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
    operands = (covs, ws, adjs, params, node_up, tables, q_arg)
    case_axes = (None, 0, 0, 0, 0, 0, None)

    q0 = _seed_inits(s_list, d, r)                            # (S, d, r)
    q0_nodes = jnp.broadcast_to(q0[:, None], (len(s_list), n, d, r))
    ge0 = jnp.zeros((len(s_list), n, n), bool)
    t0 = jnp.zeros((len(s_list),), jnp.int32)
    q0_lane = _lane_q0((q0_nodes, ge0, t0), len(engines))
    # per-lane keys: fold the seed VALUE (not its grid position) into each
    # case engine's key — a shard covering seeds [2, 3] derives exactly the
    # lanes the full grid derives at those seeds
    key0 = jnp.stack([
        jnp.stack([jax.random.fold_in(e._key, s) for s in s_list])
        for e in engines])                                    # (C, S, 2)

    payload = d * r
    sched_stack = np.stack(schedules)

    def finalize(state, done):
        ledger = CommLedger()
        sends = np.asarray(state.sends[..., :done, :], np.float64)
        counts = np.asarray(state.counts[..., :done, :])
        total = float(sends.sum())
        ledger.p2p += total
        ledger.matrices += total
        ledger.scalars += total * payload
        for c in range(len(engines)):
            for s_i in range(len(s_list)):
                for t in range(done):
                    ledger.log_awake_rounds(
                        counts[c, s_i, t][:int(sched_stack[c][t])])
        return _sweep_result(state, done, q_map=lambda q: q[0],
                             trace_err=trace_err, single_case=single_case,
                             ledger=ledger, seeds=s_list)

    return _run_sweep(
        _sdot_build_body, operands,
        (("mode", "cov"), ("t_max", t_max), ("trace_err", trace_err),
         ("is_async", False), ("is_faulty", True), ("debias", debias)),
        sched_stack.astype(np.int64), q0_lane,
        case_axes, len(engines), len(s_list), finalize,
        manager, chunk_size, max_chunks, key0=key0, tail=(t_max,))


def fdot_sweep(
    *,
    data_blocks: Sequence,
    engines: Union[DenseConsensus, Sequence[DenseConsensus]],
    r: int,
    t_outer: int,
    schedules=None,
    t_c: int = 50,
    t_c_qr: Optional[int] = None,
    seeds: Sequence[int] = (0,),
    q_true: Optional[jnp.ndarray] = None,
    manager=None,
    chunk_size: Optional[int] = None,
    max_chunks: Optional[int] = None,
) -> SweepResult:
    """Monte-Carlo F-DOT sweep over padded feature slabs (Fig. 6 axis).

    ``data_blocks`` is one slab list shared by every case, or a list of
    slab *lists* with one per case (mixed node counts — different
    partitionings of the same d features — pad with all-zero slabs, exact
    no-ops in every product of Alg. 2, and the result carries
    ``node_counts``). ``manager``/``chunk_size`` enable the
    chunked-resumable driver, as in ``sdot_sweep``.
    """
    per_case = (len(data_blocks) > 0
                and isinstance(data_blocks[0], (list, tuple)))
    engines, schedules = _broadcast_cases(engines, schedules, t_outer, t_c,
                                          allow_ragged=per_case)
    single_case = len(engines) == 1
    t_c_qr = int(t_c if t_c_qr is None else t_c_qr)
    passes = 2
    t_max = int(max(max(int(s.max()) for s in schedules), t_c_qr))
    trace_err = q_true is not None

    if per_case:
        case_blocks = broadcast_per_case(data_blocks, len(engines),
                                         "data_blocks")
        n_list = []
        for blocks, e in zip(case_blocks, engines):
            if len(blocks) != e.graph.n_nodes:
                raise ValueError("per-case data_blocks must match each "
                                 f"engine's node count: got {len(blocks)} "
                                 f"slabs for an {e.graph.n_nodes}-node graph")
            n_list.append(e.graph.n_nodes)
        case_dims = [[int(x.shape[0]) for x in blocks]
                     for blocks in case_blocks]
        d = sum(case_dims[0])
        if any(sum(dims) != d for dims in case_dims):
            raise ValueError("every case must partition the same d features")
        n_samples = int(case_blocks[0][0].shape[1])
        ws, tables, _, _, n_max = _ragged_stacks(engines, t_max)
        d_slab = max(max(dims) for dims in case_dims)
        pad_case = lambda stack: pad_zero_nodes(
            jnp.pad(stack, ((0, 0), (0, d_slab - stack.shape[1]), (0, 0))),
            n_max)
        x_pad = jnp.stack([pad_case(pad_feature_slabs(blocks))
                           for blocks in case_blocks])  # (C, N_max, d_slab, n)
        q_seeds = _seed_inits(seeds, d, r)
        q0_pad = jnp.stack([
            jnp.stack([pad_case(split_pad_rows(q, dims)) for q in q_seeds])
            for dims in case_dims])                      # (C, S, N_max, ..)
        qtrue_pad = jnp.stack([
            (pad_case(split_pad_rows(q_true, dims)) if trace_err
             else jnp.zeros((n_max, d_slab, r), jnp.float32))
            for dims in case_dims])                      # (C, N_max, d_slab, r)
        operands = (x_pad, ws, tables, qtrue_pad)
        case_axes = (0, 0, 0, 0)
        node_counts = np.asarray(n_list)
    else:
        n_nodes = engines[0].graph.n_nodes
        if len(data_blocks) != n_nodes:
            raise ValueError("need one feature slab per node")
        dims = [int(x.shape[0]) for x in data_blocks]
        d = sum(dims)
        n_samples = int(data_blocks[0].shape[1])
        ws, tables = _case_stacks(engines, t_max)

        x_pad = pad_feature_slabs(data_blocks)
        q0_seed = jnp.stack([split_pad_rows(q, dims)
                             for q in _seed_inits(seeds, d, r)])
        qtrue_pad = (split_pad_rows(q_true, dims) if trace_err
                     else jnp.zeros_like(q0_seed[0]))
        q0_pad = _lane_q0(q0_seed, len(engines))
        operands = (x_pad, ws, tables, qtrue_pad)
        case_axes = (None, 0, 0, None)
        node_counts = None

    ledger = CommLedger()

    def finalize(state, done):
        for eng, sched in zip(engines, schedules):
            for _ in seeds:
                ledger.log_gossip_rounds(sched[:done], eng.graph.adjacency,
                                         n_samples * r)
                ledger.log_gossip_rounds(np.full(done, passes * t_c_qr),
                                         eng.graph.adjacency, r * r)
        return _sweep_result(state, done, q_map=lambda q: q,
                             trace_err=trace_err, single_case=single_case,
                             ledger=ledger, seeds=seeds,
                             node_counts=node_counts)

    return _run_sweep(
        _fdot_build_body, operands,
        (("t_max", t_max), ("t_c_qr", t_c_qr), ("passes", passes),
         ("trace_err", trace_err), ("is_async", False)),
        np.stack(schedules).astype(np.int64), q0_pad,
        case_axes, len(engines), len(list(seeds)), finalize,
        manager, chunk_size, max_chunks)


def baseline_sweep(
    name: str,
    *,
    covs=None,
    data_blocks: Optional[Sequence[jnp.ndarray]] = None,
    engine: Optional[DenseConsensus] = None,
    engines=None,
    r: int,
    seeds: Sequence[int] = (0,),
    q_true: Optional[jnp.ndarray] = None,
    t_outer: Optional[int] = None,
    iters_per_vec: Optional[int] = None,
    lr: float = 0.1,
    t_mix: int = 3,
    t_c: int = 50,
    manager=None,
    chunk_size: Optional[int] = None,
    max_chunks: Optional[int] = None,
) -> SweepResult:
    """Monte-Carlo sweep of one fused baseline over seeds (one device call).

    ``name``: dsa | dpgd | deepca (sample-partitioned, need ``covs`` +
    ``t_outer``), seq_dist_pm (``covs`` + ``iters_per_vec``), or d_pm
    (feature-partitioned, ``data_blocks`` + ``iters_per_vec``).

    The cov-based trio also accepts ``engines`` (a list) plus per-case
    ``covs`` (a list of (N_c, d, d) stacks) with mixed node counts — the
    same ragged-N identity-padding contract as ``sdot_sweep``; the result
    then carries a case axis and ``node_counts``. The sequential-deflation
    baselines (seq_dist_pm, d_pm) are single-case only.
    ``manager``/``chunk_size`` enable the chunked-resumable driver, as in
    ``sdot_sweep``.
    """
    if engines is not None and engine is not None:
        raise ValueError("pass engine or engines, not both")
    engine_list = None
    if engines is not None:
        if isinstance(engines, DenseConsensus):
            engine = engines
        else:
            engine_list = list(engines)
    if engine is None and engine_list is None:
        raise ValueError("baseline_sweep needs an engine")

    trace_err = q_true is not None
    s_count = len(list(seeds))
    node_counts = None
    squeeze_node_counts = False

    if engine_list is not None:
        if name not in ("dsa", "dpgd", "deepca"):
            raise ValueError(f"{name} does not support a ragged-N case axis "
                             "(sequential-deflation baselines are "
                             "single-case only)")
        if covs is None or t_outer is None:
            raise ValueError(f"{name} sweep needs covs and t_outer")
        if not isinstance(covs, (list, tuple)):
            covs = [covs]
        case_covs = broadcast_per_case([jnp.asarray(c) for c in covs],
                                       len(engine_list), "covs")
        _check_case_covs(case_covs, engine_list)
        ws, _, masks, n_list, n_max = _ragged_stacks(engine_list, 0)
        case_covs = jnp.stack([pad_covs_identity(c, n_max)
                               for c in case_covs])      # (C, N_max, d, d)
        node_counts = np.asarray(n_list)
        squeeze_node_counts = len(engine_list) == 1
    else:
        engine_list = [engine]
        if name in ("dsa", "dpgd", "deepca"):
            if covs is None or t_outer is None:
                raise ValueError(f"{name} sweep needs covs and t_outer")
        _reject_sparse(engine_list)
        ws = jnp.stack([engine._w])
        n_max = engine.graph.n_nodes
        masks = jnp.ones((1, n_max), jnp.float32)
        if covs is not None:
            case_covs = jnp.stack([jnp.asarray(covs)])   # (1, N, d, d)

    n_cases = len(engine_list)
    single_case = n_cases == 1
    ledger = CommLedger()

    if name in ("dsa", "dpgd", "deepca"):
        d = int(case_covs.shape[2])
        q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
        q0 = _seed_inits(seeds, d, r)
        q0_nodes = jnp.broadcast_to(q0[:, None], (s_count, n_max, d, r))
        q0_lane = _lane_q0(q0_nodes, n_cases)            # (C, S, N_max, d, r)
        xs = np.zeros((n_cases, t_outer), np.int64)
        if name == "deepca":
            build = _deepca_build_body
            statics = (("t_mix", t_mix), ("trace_err", trace_err))
            # s0 = M_i Q_i per (case, seed) lane, over the padded cov stacks
            s0 = jnp.einsum("cnde,csner->csndr", case_covs, q0_lane)
            q0_lane = (q0_lane, s0, s0)
            operands = (case_covs, ws, q_arg, masks)
            case_axes = (0, 0, None, 0)
            rounds = lambda done: np.full(done, t_mix)
        else:
            build = _dsa_build_body if name == "dsa" else _dpgd_build_body
            statics = (("trace_err", trace_err),)
            operands = (case_covs, ws, jnp.float32(lr), q_arg, masks)
            case_axes = (0, 0, None, None, 0)
            rounds = lambda done: np.ones(done)
        q_map = (lambda c: c[0]) if name == "deepca" else (lambda q: q)
        payload = d * r
    elif name in ("seq_dist_pm", "d_pm"):
        if iters_per_vec is None or (covs is None) == (data_blocks is None):
            raise ValueError(f"{name} sweep needs iters_per_vec and "
                             "covs (seq_dist_pm) / data_blocks (d_pm)")
        statics = (("r", r), ("iters_per_vec", iters_per_vec),
                   ("t_c", t_c), ("t_max", t_c), ("trace_err", trace_err))
        case_axes = (None, 0, 0, None)
        xs = np.arange(r * iters_per_vec, dtype=np.int64)[None]
        rounds = lambda done: np.full(done, t_c)
        tables = jnp.stack([engine.debias_table(t_c)])
        if name == "seq_dist_pm":
            n, d, _ = covs.shape
            q_arg = q_true if trace_err else jnp.zeros((d, r), jnp.float32)
            cols0 = jnp.broadcast_to(
                jnp.swapaxes(_seed_inits(seeds, d, r), 1, 2)[:, :, None, :],
                (s_count, r, n, d))
            q0_lane = _lane_q0(cols0, 1)
            build = _seq_dist_pm_build_body
            operands = (covs, ws, tables, q_arg)
            q_map = lambda cols: jnp.transpose(cols, (0, 1, 3, 4, 2))
            payload = d
        else:
            dims = [int(x.shape[0]) for x in data_blocks]
            d = sum(dims)
            x_pad = pad_feature_slabs(data_blocks)
            q0_pad = jnp.stack([split_pad_rows(q, dims)
                                for q in _seed_inits(seeds, d, r)])
            q0_lane = _lane_q0(jnp.transpose(q0_pad, (0, 3, 1, 2)), 1)
            qtrue_pad = (split_pad_rows(q_true, dims) if trace_err
                         else jnp.zeros_like(q0_pad[0]))
            build = _d_pm_build_body
            operands = (x_pad, ws, tables, qtrue_pad)
            # blocks: (C, S, r, N, d_max) -> concatenated (C, S, d, r)
            q_map = lambda blocks: jnp.concatenate(
                [jnp.swapaxes(blocks[:, :, :, i, :di], 2, 3)
                 for i, di in enumerate(dims)], axis=2)
            payload = int(data_blocks[0].shape[1])       # n_samples
    else:
        raise ValueError(f"unknown baseline: {name}")

    def finalize(state, done):
        for eng in engine_list:
            for _ in range(s_count):
                ledger.log_gossip_rounds(rounds(done), eng.graph.adjacency,
                                         payload)
        return _sweep_result(
            state, done, q_map=q_map, trace_err=trace_err,
            single_case=single_case, ledger=ledger, seeds=seeds,
            node_counts=(None if squeeze_node_counts else node_counts))

    return _run_sweep(build, operands, statics, xs, q0_lane, case_axes,
                      n_cases, s_count, finalize, manager, chunk_size,
                      max_chunks)
