"""PSA gradient compression — the paper's S-DOT doing real work in training.

Each TPU pod is one "node" of the paper's network. Per optimizer step, the
cross-pod gradient reduction for a weight matrix G in R^{a x b} ships the
projected U = P^T G in R^{r x b} instead of G (traffic / a/r); the projector
P spans the principal subspace of recent gradients. P itself is maintained by
*distributed orthogonal iteration with inter-pod consensus* — S-DOT verbatim,
with local second moments M_pod = G_pod G_pod^T applied gram-free
(Z = G (G^T P), same trick as the Pallas gram kernel) and gossip rounds over
the "pod" mesh axis standing in for the paper's MPI exchanges. Theorem 1 is
what licenses inexact consensus here: a bounded subspace mismatch across pods
perturbs only the *compressor*, and error feedback recycles whatever the
projector misses into the next step.

Compression targets leaves with trailing dims (a, b), a >= 4r; leading dims
(layer-group stack, MoE experts) share one projector per group — see
DESIGN.md. Everything else is psum'd uncompressed.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import PSAConfig

__all__ = ["psa_init", "compress_grads", "psa_refresh", "compressible"]


def compressible(leaf: jnp.ndarray, rank: int) -> bool:
    return leaf.ndim >= 2 and leaf.shape[-2] >= 4 * rank and leaf.shape[-1] >= rank


def _proj_shape(leaf: jnp.ndarray, rank: int):
    a = leaf.shape[-2]
    if leaf.ndim >= 3:           # stacked groups: one projector per group
        return (leaf.shape[0], a, rank)
    return (a, rank)


def psa_init(params, cfg: PSAConfig, seed: int = 0) -> Dict[str, Any]:
    """Projectors (orthonormal init) + error-feedback buffers.

    The embedding table is excluded: its gradient is produced by the
    gather-VJP scatter that runs outside the manual-pod region (see
    train/step.py) and is reduced densely.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(flat))

    def _names(path):
        return [str(getattr(k, "key", getattr(k, "name", k))) for k in path]

    def eligible(path, leaf):
        return compressible(leaf, cfg.rank) and "embed" not in _names(path)

    def init_one(key, path_leaf):
        path, leaf = path_leaf
        if not eligible(path, leaf):
            return None
        shape = _proj_shape(leaf, cfg.rank)
        q = jax.random.normal(key, shape, jnp.float32)
        qn, _ = jnp.linalg.qr(q)
        return qn

    projs = jax.tree_util.tree_unflatten(
        treedef, [init_one(k, pl) for k, pl in zip(keys, flat)])
    ef = jax.tree_util.tree_unflatten(
        treedef, [jnp.zeros(l.shape, jnp.float32) if eligible(p, l) else None
                  for p, l in flat])
    return {"proj": projs, "ef": ef}


def _bcast_proj(p: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a (g?, a, r) projector over extra leading dims of grad."""
    extra = g.ndim - p.ndim - (0 if p.ndim == 2 else 1)
    if p.ndim == 2:
        extra = g.ndim - 2
        return p.reshape((1,) * extra + p.shape) if extra else p
    # p: (G, a, r); g: (G, ..., a, b)
    mid = g.ndim - 2 - 1
    return p.reshape(p.shape[:1] + (1,) * mid + p.shape[1:]) if mid else p


def compress_grads(grads, psa_state, cfg: PSAConfig, *, pod_axis: str | None):
    """Per-pod gradient -> globally reduced gradient, compressed cross-pod.

    Must run where ``pod_axis`` is a *manual* (shard_map) axis. Returns
    (reduced_grads, new_ef). With pod_axis None (single pod) the projection/
    error-feedback path still runs (useful for tests); reduction is identity.
    """
    npods = jax.lax.psum(1, pod_axis) if pod_axis else 1

    def one(g, p, e):
        if p is None:
            if pod_axis:
                # f32 psum: numerically safer, and dodges XLA:CPU's
                # AllReducePromotion pass crashing on bf16 all-reduces
                # emitted inside shard_map sub-meshes
                out = (jax.lax.psum(g.astype(jnp.float32), pod_axis)
                       / npods).astype(g.dtype)
            else:
                out = g
            return out, None
        g32 = g.astype(jnp.float32)
        if cfg.error_feedback and e is not None:
            g32 = g32 + e
        pb = _bcast_proj(p, g32)
        u = jnp.einsum("...ar,...ab->...rb", pb, g32)       # compress
        if pod_axis:
            u = jax.lax.psum(u, pod_axis) / npods            # r*b traffic only
        ghat = jnp.einsum("...ar,...rb->...ab", pb, u)       # decompress
        new_e = (g32 - jnp.einsum("...ar,...rb->...ab", pb,
                                  jnp.einsum("...ar,...ab->...rb", pb, g32))) \
            if cfg.error_feedback else None
        return ghat.astype(g.dtype), new_e

    # proj/ef trees carry None at non-compressible leaves; traversal is driven
    # by the grads tree, so those Nones arrive as values of `p` / `e`.
    out = jax.tree.map(
        one, grads, psa_state["proj"], psa_state["ef"],
        is_leaf=lambda x: x is None)
    red = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return red, ef


def _ring_gossip(z: jnp.ndarray, axis: str, rounds: int, n: int) -> jnp.ndarray:
    """S-DOT inner loop over pods: ring gossip with local-degree weights.

    For a ring, local-degree W has w_self = w_left = w_right = 1/3 (n > 2)
    and the 2-pod ring degenerates to exact averaging in one round.
    """
    if n == 1:
        return z
    if n == 2:
        fwd = [(0, 1), (1, 0)]
        for _ in range(min(rounds, 1)):
            z = 0.5 * z + 0.5 * jax.lax.ppermute(z, axis, fwd)
        return z
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    for _ in range(rounds):
        z = (z + jax.lax.ppermute(z, axis, fwd) + jax.lax.ppermute(z, axis, bwd)) / 3.0
    return z


def psa_refresh(grads, psa_state, cfg: PSAConfig, *, pod_axis: str | None):
    """S-DOT subspace refresh: ``oi_iters`` orthogonal iterations, each with
    ``gossip_rounds`` of inter-pod consensus, gram-free local apply."""
    npods = jax.lax.psum(1, pod_axis) if pod_axis else 1

    def one(g, p):
        if p is None:
            return None
        g32 = g.astype(jnp.float32)
        q = p
        for _ in range(cfg.oi_iters):
            qb = _bcast_proj(q, g32)
            s = jnp.einsum("...ar,...ab->...rb", qb, g32)
            z = jnp.einsum("...ab,...rb->...ar", g32, s)      # local M_pod q
            # collapse extra leading dims (shared projector per group)
            if z.ndim > q.ndim:
                axes = tuple(range(1, z.ndim - 2)) if q.ndim == 3 else \
                    tuple(range(0, z.ndim - 2))
                z = z.sum(axis=axes)
            if pod_axis:
                z = _ring_gossip(z, pod_axis, cfg.gossip_rounds, npods)
            # CholeskyQR (vmapped over group dim if present)
            def cqr(v):
                gm = v.T @ v + 1e-12 * jnp.eye(v.shape[1])
                r_ = jnp.linalg.cholesky(gm).T
                return jax.scipy.linalg.solve_triangular(r_.T, v.T, lower=True).T
            q = jax.vmap(cqr)(z) if q.ndim == 3 else cqr(z)
        return q

    new_proj = jax.tree.map(one, grads, psa_state["proj"],
                            is_leaf=lambda x: x is None)
    return {"proj": new_proj, "ef": psa_state["ef"]}


def compression_ratio(params, cfg: PSAConfig) -> float:
    """Analytic cross-pod traffic ratio (compressed / dense)."""
    dense = 0
    comp = 0
    for leaf in jax.tree.leaves(params):
        n = leaf.size
        dense += n
        if compressible(leaf, cfg.rank):
            a = leaf.shape[-2]
            comp += n // a * cfg.rank
        else:
            comp += n
    return comp / dense
