"""Fused whole-run B-DOT vs the eager oracle, the in-scan async straggler
executors vs seeded eager replays, and the ragged-N sweep engine."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.async_gossip import AsyncConsensus
from repro.core.bdot import bdot, pad_grid_blocks
from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.fdot import fdot
from repro.core.linalg import eigh_topr
from repro.core.metrics import CommLedger
from repro.core.sdot import sdot
from repro.core.sweep import sdot_sweep
from repro.core.topology import erdos_renyi, ring
from repro.data.pipeline import (gaussian_eigengap_data, partition_features,
                                 partition_samples)


def _split_cols(x, sizes):
    offs = np.cumsum([0] + list(sizes))
    return [x[:, offs[k]:offs[k + 1]] for k in range(len(sizes))]


def _grid_problem(d=24, r=4, I=3, J=2, n=3000, ragged=False, seed=0):
    x, _, _ = gaussian_eigengap_data(d, n, r, 0.6, seed=seed)
    _, q_true = eigh_topr(x @ x.T, r)
    fslabs = partition_features(x, I)           # ragged d_i when I !| d
    if ragged:
        sizes = [n // J + 100 * (1 if k == 0 else -1) for k in range(J)]
        sizes[-1] = n - sum(sizes[:-1])
        blocks = [_split_cols(sl, sizes) for sl in fslabs]
    else:
        blocks = [partition_samples(sl, J) for sl in fslabs]
    return x, blocks, q_true


def _grid_engines(I, J, seed=0):
    cols = [DenseConsensus(erdos_renyi(I, 0.7, seed=seed + j)) if I > 2
            else DenseConsensus(ring(I)) for j in range(J)]
    rows = [DenseConsensus(erdos_renyi(J, 0.7, seed=seed + 10 + i)) if J > 2
            else DenseConsensus(ring(J)) for i in range(I)]
    return cols, rows


def _assert_ledgers_equal(a: CommLedger, b: CommLedger):
    assert a.p2p == b.p2p
    assert a.matrices == b.matrices
    assert a.scalars == b.scalars


# ---------------------------------------------------------------------------
# fused B-DOT vs the eager oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("grid", [(2, 2), (3, 2)])
@pytest.mark.parametrize("sched_kind", ["const", "lin2"])
def test_bdot_fused_matches_eager(grid, sched_kind):
    I, J = grid
    _, blocks, q_true = _grid_problem(I=I, J=J)
    cols, rows = _grid_engines(I, J)
    sched = (None if sched_kind == "const"
             else consensus_schedule("lin2", 12, cap=40))
    kw = dict(blocks=blocks, col_engines=cols, row_engines=rows, r=4,
              t_outer=12, t_c=40, schedule=sched, q_true=q_true)
    eager = bdot(fused=False, **kw)
    fused = bdot(fused=True, **kw)
    np.testing.assert_allclose(fused.error_trace, eager.error_trace,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fused.q_full),
                               np.asarray(eager.q_full), rtol=1e-4,
                               atol=1e-5)
    _assert_ledgers_equal(fused.ledger, eager.ledger)


def test_bdot_fused_ragged_grid():
    """Uneven d_i AND n_j: the (I, J, d_max, n_max) zero-padding must not
    change the result (d=25 over I=3 slabs, n split 1600/1400)."""
    _, blocks, q_true = _grid_problem(d=25, I=3, J=2, ragged=True)
    assert len({b.shape[0] for row in blocks for b in row}) > 1
    assert len({b.shape[1] for row in blocks for b in row}) > 1
    cols, rows = _grid_engines(3, 2, seed=5)
    kw = dict(blocks=blocks, col_engines=cols, row_engines=rows, r=4,
              t_outer=10, t_c=40, q_true=q_true)
    eager = bdot(fused=False, **kw)
    fused = bdot(fused=True, **kw)
    np.testing.assert_allclose(fused.error_trace, eager.error_trace,
                               rtol=1e-4, atol=1e-5)
    for fb, eb in zip(fused.q_rows, eager.q_rows):
        assert fb.shape == eb.shape
        np.testing.assert_allclose(np.asarray(fb), np.asarray(eb),
                                   rtol=1e-4, atol=1e-5)
    _assert_ledgers_equal(fused.ledger, eager.ledger)


def test_bdot_fused_converges():
    _, blocks, q_true = _grid_problem()
    cols, rows = _grid_engines(3, 2)
    res = bdot(blocks=blocks, col_engines=cols, row_engines=rows, r=4,
               t_outer=50, t_c=60, q_true=q_true)
    assert res.error_trace[-1] < 1e-5
    q = res.q_full
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(4), atol=1e-4)


def test_bdot_short_schedule_rejected():
    _, blocks, _ = _grid_problem()
    cols, rows = _grid_engines(3, 2)
    for fused in (True, False):
        with pytest.raises(ValueError, match="schedule"):
            bdot(blocks=blocks, col_engines=cols, row_engines=rows, r=4,
                 t_outer=10, schedule=np.array([5, 5]), fused=fused)


def test_pad_grid_blocks_layout():
    _, blocks, _ = _grid_problem(d=25, ragged=True)
    stack = pad_grid_blocks(blocks)
    I, J = len(blocks), len(blocks[0])
    d_max = max(row[0].shape[0] for row in blocks)
    n_max = max(b.shape[1] for b in blocks[0])
    assert stack.shape == (I, J, d_max, n_max)
    for i in range(I):
        for j in range(J):
            di, nj = blocks[i][j].shape
            np.testing.assert_array_equal(np.asarray(stack[i, j, :di, :nj]),
                                          np.asarray(blocks[i][j]))
            assert float(jnp.abs(stack[i, j, di:]).max() if di < d_max
                         else 0.0) == 0.0
            assert float(jnp.abs(stack[i, j, :, nj:]).max() if nj < n_max
                         else 0.0) == 0.0


# ---------------------------------------------------------------------------
# in-scan async straggler runs vs seeded eager replays
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def straggler_problem():
    d, r, n_nodes, n_per = 20, 5, 10, 400
    x, _, _ = gaussian_eigengap_data(d, n_nodes * n_per, r, 0.7, seed=0)
    blocks = partition_samples(x, n_nodes)
    covs = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
    _, q_true = eigh_topr(covs.sum(0), r)
    g = erdos_renyi(n_nodes, 0.5, seed=1)
    return dict(covs=covs, q_true=q_true, g=g, r=r)


@pytest.mark.parametrize("sched_kind", ["const", "lin2"])
def test_async_sdot_in_scan_matches_eager(straggler_problem, sched_kind):
    """Seeded whole-run in-scan async S-DOT == the eager per-iteration loop
    replaying the same padded mask blocks (Table-V straggler path)."""
    p = straggler_problem
    sched = (None if sched_kind == "const"
             else consensus_schedule("lin2", 15, cap=25))
    kw = dict(covs=p["covs"], r=p["r"], t_outer=15, t_c=25, schedule=sched,
              q_true=p["q_true"])
    a = AsyncConsensus(p["g"], p_awake=0.6, seed=3)
    b = AsyncConsensus(p["g"], p_awake=0.6, seed=3)
    fused = sdot(engine=a, fused=True, **kw)
    eager = sdot(engine=b, fused=False, **kw)
    np.testing.assert_allclose(fused.error_trace, eager.error_trace,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(fused.q_nodes),
                               np.asarray(eager.q_nodes), rtol=1e-4,
                               atol=1e-5)
    _assert_ledgers_equal(fused.ledger, eager.ledger)
    assert fused.ledger.awake_counts == eager.ledger.awake_counts
    # realized (awake-dependent) traffic, not the synchronous closed form
    rounds = sum(int(t) for t in fused.consensus_trace)
    assert len(fused.ledger.awake_counts) == rounds
    assert 0 < fused.ledger.p2p < float(p["g"].adjacency.sum()) * rounds
    # the fused run advanced the engine key exactly like t_outer eager draws
    assert bool(jnp.all(a._key == b._key))


def test_async_fdot_in_scan_matches_eager(straggler_problem):
    p = straggler_problem
    x, _, _ = gaussian_eigengap_data(20, 3000, p["r"], 0.7, seed=0)
    _, q_true = eigh_topr(x @ x.T, p["r"])
    fblocks = partition_features(x, 10)
    a = AsyncConsensus(p["g"], p_awake=0.7, seed=2)
    b = AsyncConsensus(p["g"], p_awake=0.7, seed=2)
    kw = dict(data_blocks=fblocks, r=p["r"], t_outer=8, t_c=30,
              q_true=q_true)
    fused = fdot(engine=a, fused=True, **kw)
    eager = fdot(engine=b, fused=False, **kw)
    np.testing.assert_allclose(fused.error_trace, eager.error_trace,
                               rtol=1e-4, atol=1e-6)
    _assert_ledgers_equal(fused.ledger, eager.ledger)
    assert fused.ledger.awake_counts == eager.ledger.awake_counts


def test_async_sdot_in_scan_reaches_floor(straggler_problem):
    p = straggler_problem
    eng = AsyncConsensus(p["g"], p_awake=0.7, seed=0)
    res = sdot(covs=p["covs"], engine=eng, r=p["r"], t_outer=60, t_c=50,
               q_true=p["q_true"])
    assert res.error_trace[-1] < 1e-5


# ---------------------------------------------------------------------------
# ragged-N sweep (Table-II connectivity axis in one vmapped call)
# ---------------------------------------------------------------------------
def _cov_problem(n_nodes, d=20, r=5, n_per=300):
    x, _, _ = gaussian_eigengap_data(d, n_nodes * n_per, r, 0.7, seed=0)
    blocks = partition_samples(x, n_nodes)
    covs = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
    _, q_true = eigh_topr(covs.sum(0), r)
    return covs, q_true


def test_ragged_sweep_matches_unpadded_runs():
    """ER N=10 and ring N=20 stacked in ONE vmapped call: identity padding
    must reproduce the per-case unpadded traces and estimates."""
    covs10, q_true = _cov_problem(10)
    covs20, _ = _cov_problem(20)
    cases = [(DenseConsensus(erdos_renyi(10, 0.5, seed=1)), covs10, 10),
             (DenseConsensus(ring(20)), covs20, 20)]
    seeds = [0, 1]
    sw = sdot_sweep(covs=[covs10, covs20],
                    engines=[c[0] for c in cases], r=5, t_outer=10, t_c=30,
                    seeds=seeds, q_true=q_true)
    assert sw.error_traces.shape == (2, 2, 10)
    np.testing.assert_array_equal(sw.node_counts, [10, 20])
    led = CommLedger()
    for ci, (eng, cv, nn) in enumerate(cases):
        for si, s in enumerate(seeds):
            res = sdot(covs=cv, engine=eng, r=5, t_outer=10, t_c=30,
                       seed=s, q_true=q_true)
            led = led.merged(res.ledger)
            np.testing.assert_allclose(sw.error_traces[ci, si],
                                       res.error_trace, rtol=1e-5,
                                       atol=1e-7)
            np.testing.assert_allclose(np.asarray(sw.q[ci, si, :nn]),
                                       np.asarray(res.q_nodes), rtol=1e-5,
                                       atol=1e-6)
    _assert_ledgers_equal(sw.ledger, led)


def test_ragged_sweep_rejects_mismatched_covs():
    covs10, q_true = _cov_problem(10)
    with pytest.raises(ValueError, match="node count"):
        sdot_sweep(covs=[covs10, covs10],
                   engines=[DenseConsensus(erdos_renyi(10, 0.5, seed=1)),
                            DenseConsensus(ring(20))],
                   r=5, t_outer=5, seeds=[0])
