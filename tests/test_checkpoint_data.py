"""Fault tolerance: atomic checkpoints, restart, retention; stateless data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, restore_tree, save_tree
from repro.configs import get_arch, reduced_config
from repro.data.pipeline import (gaussian_eigengap_data, make_lm_batch,
                                 partition_features, partition_samples,
                                 synthetic_lm_stream)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jnp.ones((), jnp.bfloat16)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = _tree()
    mgr.save(7, tree)
    got, step = mgr.restore(tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_step_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = _tree()
    for s in (1, 5, 9):
        mgr.save(s, tree)
    assert mgr.latest_step() == 9
    assert mgr.all_steps() == [5, 9]          # step 1 pruned


def test_pinned_step_survives_retention_churn(tmp_path):
    """Satellite: pin() exempts a step from keep_last GC until unpin() —
    the serving layer's last-good served subspace must outlive per-tick
    snapshot churn, across manager instances (pins are durable files)."""
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = _tree()
    mgr.save(1, tree)
    mgr.pin(1)
    for s in (2, 3, 4, 5, 6):
        mgr.save(s, tree)
    assert mgr.all_steps() == [1, 5, 6]       # pinned 1 outlives churn
    got, step = mgr.restore(tree, step=1)
    assert step == 1 and got is not None

    # a NEW manager over the same root sees the durable pin
    mgr2 = CheckpointManager(str(tmp_path), keep_last=2)
    assert mgr2.pinned_steps() == [1]
    mgr2.save(7, tree)
    assert 1 in mgr2.all_steps()

    mgr2.unpin(1)
    mgr2.unpin(1)                             # idempotent
    mgr2.save(8, tree)
    assert mgr2.all_steps() == [7, 8]         # unpinned -> GC'd


def test_corrupt_partial_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(3, tree)
    # a crashed writer leaves a .tmp and a manifest-less dir
    os.makedirs(tmp_path / "step_00000009.tmp")
    os.makedirs(tmp_path / "step_00000007")
    assert mgr.latest_step() == 3
    got, step = mgr.restore(tree)
    assert step == 3


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(2, tree, blocking=False)
    mgr.wait()
    got, step = mgr.restore(tree)
    assert step == 2


def test_restore_tree_mismatch_raises(tmp_path):
    p = str(tmp_path / "snap")
    save_tree(p, _tree(), 0)
    with pytest.raises(ValueError):
        restore_tree(p, {"different": jnp.zeros(3)})


def test_restore_empty_returns_none(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    got, step = mgr.restore(_tree())
    assert got is None and step is None


def test_training_restart_is_bitwise_identical(tmp_path):
    """Kill-and-restart reproduces the uninterrupted run exactly: the data
    stream is stateless-seeded and the checkpoint captures all state."""
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
    from repro.train.step import loss_fn

    cfg = reduced_config(get_arch("h2o-danube-1.8b"))
    from repro.models.transformer import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2)
    state = adamw_init(params, opt)

    @jax.jit
    def step_fn(params, state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg,
                                                  remat=False)
        p, s, _ = adamw_update(grads, state, params, opt)
        return p, s, loss

    def run(params, state, start, stop):
        for t in range(start, stop):
            batch = make_lm_batch(cfg, seed=42, step=t, batch=2, seq=8)
            params, state, loss = step_fn(params, state, batch)
        return params, state, float(loss)

    # uninterrupted 0..8
    p_ref, s_ref, loss_ref = run(params, state, 0, 8)

    # interrupted at 4 + restart from checkpoint
    p4, s4, _ = run(params, state, 0, 4)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(4, {"params": p4, "opt": s4})
    restored, step = mgr.restore({"params": p4, "opt": s4})
    p_re, s_re, loss_re = run(restored["params"], restored["opt"], step, 8)

    assert loss_re == loss_ref
    for a, b in zip(jax.tree.leaves(p_re), jax.tree.leaves(p_ref)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_eigengap_is_exact():
    d, r = 20, 5
    for gap in (0.3, 0.7, 0.9):
        _, c, _ = gaussian_eigengap_data(d, 10, r, gap, seed=0)
        ev = np.sort(np.linalg.eigvalsh(np.asarray(c)))[::-1]
        assert ev[r] / ev[r - 1] == pytest.approx(gap, rel=1e-4)


def test_repeated_top_spectrum():
    _, c, _ = gaussian_eigengap_data(20, 10, 4, 0.5, seed=0, repeated_top=True)
    ev = np.sort(np.linalg.eigvalsh(np.asarray(c)))[::-1]
    assert np.allclose(ev[:4], ev[0], rtol=1e-5)


def test_partitioners_cover_everything():
    x = jnp.arange(20 * 12, dtype=jnp.float32).reshape(20, 12)
    s = partition_samples(x, 4)
    assert sum(b.shape[1] for b in s) == 12
    f = partition_features(x, 3)
    assert sum(b.shape[0] for b in f) == 20
    np.testing.assert_array_equal(np.concatenate([np.asarray(b) for b in f]),
                                  np.asarray(x))


def test_lm_stream_stateless_reproducible():
    cfg = reduced_config(get_arch("qwen2-7b"))
    it1 = synthetic_lm_stream(cfg, seed=1, batch=2, seq=8, start_step=5)
    it2 = synthetic_lm_stream(cfg, seed=1, batch=2, seq=8, start_step=5)
    for _ in range(3):
        s1, b1 = next(it1)
        s2, b2 = next(it2)
        assert s1 == s2
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
    # different seed differs
    s3, b3 = next(synthetic_lm_stream(cfg, seed=2, batch=2, seq=8,
                                      start_step=5))
    assert not np.array_equal(np.asarray(b3["tokens"]), np.asarray(b1["tokens"]))


def test_labels_are_next_tokens():
    cfg = reduced_config(get_arch("qwen2-7b"))
    b = make_lm_batch(cfg, 0, 0, 2, 16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))
