"""Subspace error metrics and communication-cost accounting.

The error metric is the paper's eq. (11): the mean squared sine of the
principal angles between the estimated and true subspaces, equal (up to a
factor) to the chordal distance between the projectors.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "subspace_error",
    "subspace_error_from_cross",
    "mean_subspace_error",
    "projector_distance",
    "principal_angles",
    "CommLedger",
    "p2p_per_consensus_round",
]


def subspace_error(q_true, q_hat) -> jnp.ndarray:
    """Paper eq. (11): E = (1/r) * sum_i (1 - sigma_i^2(Q^T Qhat)).

    Invariant to right-rotation of either argument. 0 iff span(Q)==span(Qhat).
    """
    return subspace_error_from_cross(q_true.T @ q_hat)


def subspace_error_from_cross(cross) -> jnp.ndarray:
    """Eq. (11) from a precomputed cross product ``Q_true^T Q_hat``.

    The fused F-DOT/d-PM executors assemble the cross product directly from
    zero-padded per-node slabs (the padded rows contribute nothing), so the
    metric never needs the concatenated global estimate.
    """
    s = jnp.linalg.svd(cross, compute_uv=False)
    r = cross.shape[0]
    return jnp.mean(1.0 - jnp.clip(s[:r], 0.0, 1.0) ** 2)


def mean_subspace_error(q_true, q_nodes, node_mask=None) -> jnp.ndarray:
    """Mean of eq. (11) over stacked per-node estimates q_nodes: (N, d, r).

    Traceable (SVD of N tiny r x r matrices) — the fused S-DOT executor
    evaluates this *inside* its outer scan so the whole error trace comes
    back as one device array instead of T_o per-iteration host syncs.

    ``node_mask`` (N,) restricts the mean to mask > 0 nodes — the ragged-N
    sweep engine pads small networks with isolated identity nodes whose
    estimates must not pollute the trace. With a mask of ones the weighted
    mean reduces to exactly the unmasked expression (same op order).
    """
    errs = jax.vmap(lambda q: subspace_error(q_true, q))(q_nodes)
    if node_mask is None:
        return errs.mean()
    m = node_mask.astype(errs.dtype)
    return jnp.sum(errs * m) / jnp.sum(m)


def projector_distance(q_true, q_hat) -> jnp.ndarray:
    """||QQ^T - Qhat Qhat^T||_2 — the quantity bounded by Theorem 1."""
    p1 = q_true @ q_true.T
    p2 = q_hat @ q_hat.T
    return jnp.linalg.norm(p1 - p2, ord=2)


def principal_angles(q_true, q_hat) -> jnp.ndarray:
    s = jnp.linalg.svd(q_true.T @ q_hat, compute_uv=False)
    return jnp.arccos(jnp.clip(s, -1.0, 1.0))


def p2p_per_consensus_round(adjacency: np.ndarray) -> float:
    """Average point-to-point sends per node per consensus round.

    One gossip round Z_i <- sum_j w_ij Z_j requires each node to send its
    block to every neighbor: sum of degrees / N messages per node. Matches
    the paper's MPI P2P counter (its tables report per-node averages).
    """
    n = adjacency.shape[0]
    return float(adjacency.sum() / n)


@dataclasses.dataclass
class CommLedger:
    """Accumulates communication events for an algorithm run.

    p2p        : point-to-point messages (paper's 'P2P' column), total over nodes
    matrices   : number of d-x-r matrix sends (the paper's 'unit' cost)
    scalars    : payload element count actually moved (for byte-level rooflines)
    awake_counts: per-round awake-node counts logged by async engines
                  (empty for synchronous runs — every node is awake)
    payload_bytes: wire bytes actually moved — ``scalars`` priced at the
                  engine's payload element width (4 for f32 gossip, 2 when
                  a sparse engine quantizes payloads to bf16), so the
                  accuracy-vs-bytes tradeoff curve reads straight off the
                  ledger
    """

    p2p: float = 0.0
    matrices: float = 0.0
    scalars: float = 0.0
    awake_counts: list = dataclasses.field(default_factory=list)
    payload_bytes: float = 0.0

    def log_awake_rounds(self, counts) -> None:
        """Record realized per-round awake-node counts (async gossip)."""
        self.awake_counts.extend(int(c) for c in np.asarray(counts).ravel())

    def mean_awake(self) -> float:
        """Mean awake nodes per round over the logged async rounds."""
        return float(np.mean(self.awake_counts)) if self.awake_counts else float("nan")

    def log_gossip_round(self, adjacency: np.ndarray, payload_elems: int,
                         bytes_per_elem: float = 4.0) -> None:
        sends = float(adjacency.sum())  # directed messages this round
        self.p2p += sends
        self.matrices += sends
        self.scalars += sends * payload_elems
        self.payload_bytes += sends * payload_elems * bytes_per_elem

    def log_gossip_rounds(self, schedule: np.ndarray, adjacency: np.ndarray,
                          payload_elems: int,
                          bytes_per_elem: float = 4.0) -> None:
        """Closed-form accounting for a whole run's consensus schedule.

        Equivalent to calling log_gossip_round once per round of every outer
        iteration (all increments are equal, so the sum is exact), but O(1)
        instead of O(sum schedule) Python-loop iterations — this is what the
        fused executor logs after its single device dispatch.
        """
        rounds = float(np.asarray(schedule, dtype=np.float64).sum())
        sends = float(adjacency.sum()) * rounds
        self.p2p += sends
        self.matrices += sends
        self.scalars += sends * payload_elems
        self.payload_bytes += sends * payload_elems * bytes_per_elem

    def per_node_p2p(self, n_nodes: int) -> float:
        return self.p2p / n_nodes

    def merged(self, other: "CommLedger") -> "CommLedger":
        return CommLedger(
            self.p2p + other.p2p,
            self.matrices + other.matrices,
            self.scalars + other.scalars,
            self.awake_counts + other.awake_counts,
            self.payload_bytes + other.payload_bytes,
        )

    def merge_from(self, other: "CommLedger") -> None:
        """In-place accumulate ``other`` (callers that own a running ledger
        fold a finished run's accounting into it, e.g. the fused baselines
        merging their Program's closed-form ledger)."""
        self.p2p += other.p2p
        self.matrices += other.matrices
        self.scalars += other.scalars
        self.awake_counts.extend(other.awake_counts)
        self.payload_bytes += other.payload_bytes


def _ledger_flatten(ledger: CommLedger):
    # awake_counts travels as one float64 leaf so the whole ledger round-trips
    # through array-only channels (checkpoint shards, worker result files)
    return ((ledger.p2p, ledger.matrices, ledger.scalars,
             np.asarray(ledger.awake_counts, np.float64),
             ledger.payload_bytes), None)


def _ledger_unflatten(_aux, children):
    p2p, matrices, scalars, awake, payload_bytes = children
    return CommLedger(float(p2p), float(matrices), float(scalars),
                      [int(c) for c in np.asarray(awake).ravel()],
                      float(payload_bytes))


# Registered pytree: a CommLedger checkpoints through checkpoint/manager.py
# (and ships across the multi-host launcher boundary) without ad-hoc field
# plucking — restore rebuilds the list-valued awake_counts, so
# ``log_awake_rounds`` keeps extending it exactly as before.
jax.tree_util.register_pytree_node(CommLedger, _ledger_flatten,
                                   _ledger_unflatten)
