"""Multi-host PSA sweep entry point.

Ties the streaming subsystem together as one operational command: stream
micro-batches into per-node covariance sketches (streaming/ingest.py),
then shard the Monte-Carlo seed grid over subprocess workers
(streaming/launcher.py) and merge one SweepResult.

    PYTHONPATH=src python -m repro.launch.psa_sweep \
        --d 64 --nodes 20 --r 5 --seeds 8 --workers 4 \
        --topology er --p 0.25 --t-outer 50 --schedule lin2 \
        --workdir /tmp/psa_sweep

A killed launcher rerun with the same --workdir resumes: published worker
shards are never recomputed. ``--resume`` goes further — workers run their
shards through the unified runtime's chunked driver, checkpointing the
sweep-RunState into per-worker ckpt dirs every ``--sweep-chunk`` outer
iterations, so a killed *worker* resumes mid-grid (bitwise equal to the
uninterrupted sweep); the summary then reports how many grid points were
skipped via reused shards and how far each restored sweep-RunState
carried its worker.

Fleet robustness knobs (see streaming/launcher.py):

* ``--elastic`` runs un-pinned fleet workers that lease, steal, and resume
  shards; ``--shards`` sets the steal granularity (default: one per
  worker) and ``--lease-ttl`` how quickly a silent shard is stolen.
* ``--stall-timeout`` kills a worker whose heartbeat goes quiet (wedged
  but alive); ``--heartbeat-interval`` is the supervision poll period.
* ``--chaos-plan <plan.json>`` injects a seeded FaultPlan into the workers
  (kill/corrupt/slow/hang/drop — streaming/chaos.py) for fire drills.
* ``--net-faults <doc.json>`` runs the gossip itself under seeded network
  faults (link drops, bursty outages, node crash/rejoin, payload
  corruption) with realized-mixing debias — core/netfaults.py.
"""
from __future__ import annotations

import argparse
import json
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--r", type=int, default=5)
    ap.add_argument("--nodes", type=int, default=20)
    ap.add_argument("--gap", type=float, default=0.7)
    ap.add_argument("--batches", type=int, default=50,
                    help="micro-batches to ingest")
    ap.add_argument("--batch-size", type=int, default=None,
                    help="samples per micro-batch (default: 10 * nodes)")
    ap.add_argument("--topology", default="er",
                    choices=["er", "ring", "star", "complete"])
    ap.add_argument("--p", type=float, default=0.25, help="ER edge prob")
    ap.add_argument("--graph-seed", type=int, default=1)
    ap.add_argument("--schedule", default="const",
                    choices=["const", "lin_half", "lin1", "lin2", "lin5"])
    ap.add_argument("--t-outer", type=int, default=50)
    ap.add_argument("--t-c", type=int, default=50)
    ap.add_argument("--cap", type=int, default=None)
    ap.add_argument("--seeds", type=int, default=4,
                    help="Monte-Carlo seed count")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--workdir", required=True)
    ap.add_argument("--resume", action="store_true",
                    help="chunk-checkpoint each worker's sweep-RunState "
                         "into its ckpt dir and resume killed workers "
                         "mid-grid; report skipped grid points")
    ap.add_argument("--sweep-chunk", type=int, default=None,
                    help="outer iterations per sweep checkpoint chunk "
                         "(default: t_outer // 5, implies --resume)")
    ap.add_argument("--shards", type=int, default=None,
                    help="leasable seed shards (default: one per worker; "
                         "more shards = finer work stealing)")
    ap.add_argument("--elastic", action="store_true",
                    help="fleet mode: un-pinned workers lease/steal/resume "
                         "shards; workers may join or leave mid-sweep")
    ap.add_argument("--retries", type=int, default=1,
                    help="per-shard (pinned) / per-slot (elastic) retry "
                         "budget")
    ap.add_argument("--timeout", type=float, default=900.0,
                    help="shared wall-clock deadline for the whole launch")
    ap.add_argument("--stall-timeout", type=float, default=None,
                    help="kill a worker whose heartbeat is older than this "
                         "(default: 60s when chunked, 0 = off)")
    ap.add_argument("--heartbeat-interval", type=float, default=0.2,
                    help="supervision poll period in seconds")
    ap.add_argument("--lease-ttl", type=float, default=30.0,
                    help="elastic mode: seconds before a silent shard "
                         "lease becomes stealable")
    ap.add_argument("--chaos-plan", default=None,
                    help="path to a FaultPlan JSON to inject into workers "
                         "(fire-drill mode; see streaming/chaos.py)")
    ap.add_argument("--net-faults", default=None,
                    help="path to a net-fault JSON document (or inline "
                         "JSON): run the sweep's gossip under seeded link "
                         "drops / bursts / crash-rejoin / corruption with "
                         "realized-mixing debias (core/netfaults.py); "
                         "defaults from $REPRO_NET_FAULTS")
    args = ap.parse_args(argv)

    import jax.numpy as jnp
    import numpy as np

    from ..core.linalg import eigh_topr
    from ..data.pipeline import eigengap_stream
    from ..streaming.ingest import StreamingIngestor
    from ..streaming.launcher import launch_sweep

    batch_size = args.batch_size or 10 * args.nodes

    t0 = time.perf_counter()
    batch_fn, _, _ = eigengap_stream(args.d, args.r, args.gap, seed=0)
    ingestor = StreamingIngestor(n_nodes=args.nodes, d=args.d,
                                 batch_fn=batch_fn, batch_size=batch_size)
    ingestor.ingest(args.batches)
    covs = ingestor.cov_stack()
    _, q_true = eigh_topr(covs.sum(0), args.r)
    ingest_s = time.perf_counter() - t0

    topo = {"kind": args.topology, "n": args.nodes, "p": args.p,
            "seed": args.graph_seed}
    sched = {"kind": args.schedule, "t_max": args.t_c, "cap": args.cap}
    resume = args.resume or args.sweep_chunk is not None or args.elastic
    sweep_chunk = None
    if resume:
        sweep_chunk = args.sweep_chunk or max(1, args.t_outer // 5)
    t0 = time.perf_counter()
    sw = launch_sweep(covs=covs, cases=[{"topology": topo,
                                         "schedule": sched}],
                      r=args.r, t_outer=args.t_outer, t_c=args.t_c,
                      seeds=list(range(args.seeds)), q_true=q_true,
                      workdir=args.workdir, n_workers=args.workers,
                      n_shards=args.shards, sweep_chunk=sweep_chunk,
                      elastic=args.elastic, retries=args.retries,
                      timeout=args.timeout,
                      stall_timeout=args.stall_timeout,
                      poll_interval=args.heartbeat_interval,
                      lease_ttl=args.lease_ttl,
                      chaos_plan=args.chaos_plan,
                      net_faults=args.net_faults)
    sweep_s = time.perf_counter() - t0

    summary = {
        "ingested_samples_per_node": float(ingestor.samples_per_node[0]),
        "ingest_s": round(ingest_s, 3),
        "sweep_s": round(sweep_s, 3),
        "workers": args.workers,
        "seeds": args.seeds,
        "final_err_mean": float(np.asarray(sw.mean_trace)[-1]),
        "p2p_per_node_k": round(sw.ledger.per_node_p2p(args.nodes) / 1e3, 2),
    }
    if resume:
        rep = sw.resume_report
        summary["resume"] = {
            "sweep_chunk": sweep_chunk,
            "skipped_grid_points": rep["skipped_grid_points"],
            "reused_shards": rep["reused_shards"],
            "worker_resumed_steps": rep["worker_resumed_steps"],
            "attempts": rep["attempts"],
        }
        if "load_errors" in rep:
            summary["resume"]["load_errors"] = rep["load_errors"]
        if args.elastic:
            summary["resume"]["stolen_shards"] = rep.get("stolen_shards")
            summary["resume"]["lease_owners"] = rep.get("lease_owners")
    print(json.dumps(summary, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
