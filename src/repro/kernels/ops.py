"""Public jit'd wrappers around the Pallas kernels.

These handle padding to hardware-aligned tiles, GQA head expansion, CPU
fallback (interpret mode or the pure-jnp oracle), and normalization — so the
rest of the codebase never calls pallas_call directly.

On this CPU-only container the kernels run with ``interpret=True`` (the
kernel body executes in Python against the same BlockSpec tiling the TPU
would use); on TPU the identical code lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .ell_spmm import ell_spmm_pallas
from .flash_attention import flash_attention_pallas
from .gram_qr import gram_qr_pallas
from .gram_update import batched_gram_apply_pallas, gram_apply_pallas
from .slab_ops import (batched_slab_apply_pallas, batched_slab_tq_pallas,
                       grid_block_apply_pallas, grid_block_tq_pallas)

__all__ = ["gram_apply", "batched_gram_apply", "batched_slab_tq",
           "batched_slab_apply", "grid_block_tq", "grid_block_apply",
           "gram_qr", "flash_attention", "ell_spmm", "ell_spmm_path",
           "ell_densify_wins", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_n", "use_pallas", "interpret"))
def gram_apply(x: jnp.ndarray, q: jnp.ndarray, *, block_n: int = 512,
               use_pallas: bool = True, interpret: bool | None = None) -> jnp.ndarray:
    """V = X (X^T Q) / n. x: (d, n), q: (d, r) -> (d, r).

    Zero-padding n is exact (padded columns contribute X_b S_b = 0); the
    normalizer uses the true n.
    """
    d, n = x.shape
    if not use_pallas or d * block_n * 4 > 8 * 2**20:  # VMEM guard: fall back
        return ref.gram_apply_ref(x, q)
    interp = (not on_tpu()) if interpret is None else interpret
    xp = _pad_to(x, 1, block_n)
    v = gram_apply_pallas(xp, q, block_n=block_n, interpret=interp)
    return (v / n).astype(q.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "use_pallas", "interpret"))
def batched_gram_apply(x_stack: jnp.ndarray, q_stack: jnp.ndarray,
                       n_true: jnp.ndarray, *, block_n: int = 512,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None) -> jnp.ndarray:
    """V[i] = X_i (X_i^T Q_i) / n_i — batched Step 5 for all nodes at once.

    x_stack: (N, d, n) zero-padded blocks, q_stack: (N, d, r), n_true: (N,)
    true per-node sample counts (zero-padding is exact; the normalizer uses
    n_true). This is the dispatch point for the fused S-DOT executor's raw-
    data path: one call per outer iteration regardless of N.

    ``use_pallas=None`` auto-selects: the Pallas (node, column-block) kernel
    on TPU, the fused-einsum oracle elsewhere (interpret-mode Pallas unrolls
    the grid at trace time, which bloats the fused scan's XLA program on
    CPU for no speed win). Pass use_pallas=True + interpret=True in tests to
    exercise the kernel itself off-TPU.
    """
    n_nodes, d, n = x_stack.shape
    if use_pallas is None:
        use_pallas = on_tpu()
    vmem_bytes = (d * block_n + 2 * d * q_stack.shape[-1]) * 4
    if not use_pallas or vmem_bytes > 8 * 2**20:
        return ref.batched_gram_apply_ref(x_stack, q_stack, n_true)
    interp = (not on_tpu()) if interpret is None else interpret
    xp = _pad_to(x_stack, 2, block_n)
    v = batched_gram_apply_pallas(xp, q_stack, block_n=block_n,
                                  interpret=interp)
    acc = v.dtype
    v = v / n_true.astype(acc)[:, None, None]
    return v.astype(q_stack.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "use_pallas", "interpret"))
def batched_slab_tq(x_stack: jnp.ndarray, q_stack: jnp.ndarray, *,
                    block_n: int = 512, use_pallas: bool | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Z[i] = X_i^T Q_i — batched F-DOT step 1 for all nodes at once.

    x_stack: (N, d_max, n) zero-padded feature slabs, q_stack: (N, d_max, r)
    zero-row-padded iterates (padding exact in the product). This is the
    dispatch point for the fused F-DOT executor's partial-product step.

    ``use_pallas=None`` auto-selects: the Pallas (node, sample-block) kernel
    on TPU, the fused-einsum oracle elsewhere (same rationale as
    batched_gram_apply — interpret-mode Pallas unrolls the grid at trace
    time, bloating the fused scan's XLA program on CPU for no win).
    """
    n_nodes, d, n = x_stack.shape
    if use_pallas is None:
        use_pallas = on_tpu()
    vmem_bytes = (d * block_n + d * q_stack.shape[-1]
                  + block_n * q_stack.shape[-1]) * 4
    if not use_pallas or vmem_bytes > 8 * 2**20:
        return ref.batched_slab_tq_ref(x_stack, q_stack)
    interp = (not on_tpu()) if interpret is None else interpret
    xp = _pad_to(x_stack, 2, block_n)
    z = batched_slab_tq_pallas(xp, q_stack, block_n=block_n, interpret=interp)
    return z[:, :n].astype(q_stack.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "use_pallas", "interpret"))
def batched_slab_apply(x_stack: jnp.ndarray, s_stack: jnp.ndarray, *,
                       block_n: int = 512, use_pallas: bool | None = None,
                       interpret: bool | None = None) -> jnp.ndarray:
    """V[i] = X_i S_i — batched F-DOT step 3 for all nodes at once.

    x_stack: (N, d_max, n) zero-padded feature slabs, s_stack: (N, n, r)
    debiased consensus sums. The sample axis of both operands is padded
    together, so padded columns of X multiply zero rows of S — exact.
    """
    n_nodes, d, n = x_stack.shape
    if use_pallas is None:
        use_pallas = on_tpu()
    r = s_stack.shape[-1]
    vmem_bytes = (d * block_n + block_n * r + d * r) * 4
    if not use_pallas or vmem_bytes > 8 * 2**20:
        return ref.batched_slab_apply_ref(x_stack, s_stack)
    interp = (not on_tpu()) if interpret is None else interpret
    xp = _pad_to(x_stack, 2, block_n)
    sp = _pad_to(s_stack, 1, block_n)
    v = batched_slab_apply_pallas(xp, sp, block_n=block_n, interpret=interp)
    return v.astype(s_stack.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "use_pallas", "interpret"))
def grid_block_tq(x_grid: jnp.ndarray, q_stack: jnp.ndarray, *,
                  block_n: int = 512, use_pallas: bool | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Z[i, j] = X_ij^T Q_i — batched B-DOT stage 1 for the whole grid.

    x_grid: (I, J, d_max, n_max) zero-padded blocks, q_stack: (I, d_max, r)
    zero-row-padded row iterates (padding exact in the product). This is the
    dispatch point for the fused B-DOT executor's column-partial step.

    ``use_pallas=None`` auto-selects: the Pallas (row, column, sample-block)
    kernel on TPU, the fused-einsum oracle elsewhere (interpret-mode Pallas
    unrolls the grid at trace time, bloating the fused scan's XLA program on
    CPU for no win — same rationale as batched_slab_tq).
    """
    i_rows, j_cols, d, n = x_grid.shape
    if use_pallas is None:
        use_pallas = on_tpu()
    r = q_stack.shape[-1]
    vmem_bytes = (d * block_n + d * r + block_n * r) * 4
    if not use_pallas or vmem_bytes > 8 * 2**20:
        return ref.grid_block_tq_ref(x_grid, q_stack)
    interp = (not on_tpu()) if interpret is None else interpret
    xp = _pad_to(x_grid, 3, block_n)
    z = grid_block_tq_pallas(xp, q_stack, block_n=block_n, interpret=interp)
    return z[:, :, :n].astype(q_stack.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "use_pallas", "interpret"))
def grid_block_apply(x_grid: jnp.ndarray, s_stack: jnp.ndarray, *,
                     block_n: int = 512, use_pallas: bool | None = None,
                     interpret: bool | None = None) -> jnp.ndarray:
    """V[i, j] = X_ij S_j — batched B-DOT stage 2 for the whole grid.

    x_grid: (I, J, d_max, n_max) zero-padded blocks, s_stack: (J, n_max, r)
    per-column debiased consensus sums. The sample axis of both operands is
    padded together, so padded columns of X multiply zero rows of S — exact.
    """
    i_rows, j_cols, d, n = x_grid.shape
    if use_pallas is None:
        use_pallas = on_tpu()
    r = s_stack.shape[-1]
    vmem_bytes = (d * block_n + block_n * r + d * r) * 4
    if not use_pallas or vmem_bytes > 8 * 2**20:
        return ref.grid_block_apply_ref(x_grid, s_stack)
    interp = (not on_tpu()) if interpret is None else interpret
    xp = _pad_to(x_grid, 3, block_n)
    sp = _pad_to(s_stack, 1, block_n)
    v = grid_block_apply_pallas(xp, sp, block_n=block_n, interpret=interp)
    return v.astype(s_stack.dtype)


# Above this many gathered message elements (N * L * K) the one-shot
# gather/einsum fallback's (N, L, K) intermediate is worth trading for the
# slot-at-a-time scan's O(N K) peak memory.
_ELL_GATHER_ELEMS = 1 << 25

# Measured CPU crossover: past L ~ N / _ELL_DENSE_RATIO the gather path
# (O(N L K), poor constants) loses to scatter-to-dense + BLAS matmul
# (O(N^2 K), great constants). Hub-heavy graphs (Barabasi-Albert) pad ELL
# to the max degree, so small-N scale-free overlays land here.
_ELL_DENSE_RATIO = 11


def ell_densify_wins(n: int, ell_width: int) -> bool:
    """Host-side crossover test: for this (N, L) the densified BLAS matmul
    beats the ELL gather/scan fallbacks, so off-TPU callers that can hoist
    the scatter (``SparseW`` caches a dense off-diagonal mirror at
    construction) should mix through the mirror instead."""
    return ell_width * _ELL_DENSE_RATIO >= n


def ell_spmm_path(n: int, ell_width: int, k: int,
                  use_pallas: bool | None = None) -> str:
    """Which execution path ``ell_spmm`` will take for these shapes:
    'pallas' | 'fallback_gather' | 'fallback_scan' | 'fallback_dense'
    (host-side mirror of the traced dispatch below, for observability and
    benchmarks)."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas and n * k * 4 <= 8 * 2**20:
        return "pallas"
    if ell_densify_wins(n, ell_width):
        return "fallback_dense"
    if n * ell_width * k <= _ELL_GATHER_ELEMS:
        return "fallback_gather"
    return "fallback_scan"


@functools.partial(jax.jit,
                   static_argnames=("payload_dtype", "block_rows",
                                    "use_pallas", "interpret"))
def ell_spmm(ell_idx: jnp.ndarray, ell_val: jnp.ndarray, diag: jnp.ndarray,
             z: jnp.ndarray, *, payload_dtype: str | None = None,
             block_rows: int = 256, use_pallas: bool | None = None,
             interpret: bool | None = None) -> jnp.ndarray:
    """One sparse gossip round: out[i] = diag[i] z[i] + sum_l val[i,l]
    z[idx[i,l]]. ell_idx/ell_val: (N, L) padded ELL slots (weight 0 past
    the row degree), diag: (N,), z: (N, K) flattened payload -> (N, K)
    f32.

    ``payload_dtype`` (e.g. "bfloat16") quantizes the GATHER SOURCE — the
    neighbor messages that cross the wire — before the f32 accumulation;
    each node's own diagonal term stays full precision.

    ``use_pallas=None`` auto-selects: the Pallas row-block gather kernel
    on TPU (guarded by the full payload fitting VMEM), the gather/einsum
    oracle elsewhere — densifying to a BLAS matmul when the padded width
    approaches N (hub-heavy graphs) and degrading to a slot-at-a-time
    scan when the (N, L, K) gathered block would be large (see
    ``ell_spmm_path``).
    """
    n, k = z.shape
    ell_width = ell_idx.shape[1]
    z_src = z if payload_dtype is None else z.astype(payload_dtype)
    path = ell_spmm_path(n, ell_width, k, use_pallas)
    if path == "fallback_gather":
        return ref.ell_spmm_ref(ell_idx, ell_val, diag, z, z_src)
    if path == "fallback_dense":
        return ref.ell_spmm_dense_ref(ell_idx, ell_val, diag, z, z_src)
    if path == "fallback_scan":
        return ref.ell_spmm_scan_ref(ell_idx, ell_val, diag, z, z_src)
    interp = (not on_tpu()) if interpret is None else interpret
    idx_p = _pad_to(ell_idx, 0, block_rows)
    val_p = _pad_to(ell_val, 0, block_rows)
    diag_p = _pad_to(diag, 0, block_rows)
    z_p = _pad_to(z, 0, block_rows)
    out = ell_spmm_pallas(idx_p, val_p, diag_p, z_p, z_src,
                          block_rows=block_rows, interpret=interp)
    return out[:n]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "use_pallas", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    use_pallas: bool = True,
                    interpret: bool | None = None) -> jnp.ndarray:
    """GQA-aware attention. q: (b, hq, sq, hd); k/v: (b, hkv, skv, hd).

    hq % hkv == 0; kv heads are expanded to query heads before the kernel
    (on real TPU the broadcast is free — the expanded operand is an HLO
    broadcast the partitioner keeps unmaterialized per-shard).
    """
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, "query heads must be a multiple of kv heads"
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)

    small = sq < block_q or skv < block_k
    if not use_pallas or small:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)

    interp = (not on_tpu()) if interpret is None else interpret
    # back-pad both streams; real positions are communicated to the kernel
    # via q_offset (first real query's position in the key stream) and
    # kv_valid (number of real keys), so padding never leaks into the mask.
    qp = _pad_to(q, 2, block_q)
    kp = _pad_to(k, 2, block_k)
    vp = _pad_to(v, 2, block_k)
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        q_offset=skv - sq, kv_valid=skv, interpret=interp)
    return out[:, :, :sq, :]


@functools.partial(jax.jit, static_argnames=("block_d", "use_pallas", "interpret"))
def gram_qr(v: jnp.ndarray, *, block_d: int = 1024, use_pallas: bool = True,
            interpret: bool | None = None) -> jnp.ndarray:
    """G = V^T V. v: (d, r) -> (r, r) f32. Zero-padding d is exact."""
    d, r = v.shape
    if not use_pallas or d < block_d:
        return ref.gram_qr_ref(v)
    interp = (not on_tpu()) if interpret is None else interpret
    vp = _pad_to(v, 0, block_d)
    return gram_qr_pallas(vp, block_d=block_d, interpret=interp)
