"""S-DOT and SA-DOT — sample-wise distributed orthogonal iteration (Alg. 1).

The two algorithms share one implementation; they differ only in the
per-outer-iteration consensus budget ``schedule`` (constant for S-DOT,
increasing for SA-DOT — see ``consensus_schedule``).

Engines:
  * ``sdot`` — simulation over an explicit graph (DenseConsensus). All N node
    states are carried as a stacked (N, d, r) array; this is what reproduces
    the paper's tables.
  * ``sdot_spmd_step`` — the building block used when node == TPU pod; exact
    psum intra-pod, gossip inter-pod (see optim/psa_compress.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .consensus import DenseConsensus, consensus_schedule
from .linalg import cholesky_qr2, orthonormal_init
from .metrics import CommLedger, subspace_error

__all__ = ["SDOTResult", "sdot", "sadot", "local_cov_apply"]


@dataclasses.dataclass
class SDOTResult:
    q_nodes: jnp.ndarray            # (N, d, r) final per-node estimates
    error_trace: Optional[np.ndarray]   # (T_o,) mean subspace error vs q_true
    consensus_trace: np.ndarray     # (T_o,) consensus rounds used per outer iter
    ledger: CommLedger              # communication accounting

    @property
    def q_mean(self) -> jnp.ndarray:
        """Consensus-averaged estimate (for reporting; nodes already agree)."""
        return self.q_nodes.mean(axis=0)


def local_cov_apply(covs: jnp.ndarray, q_nodes: jnp.ndarray) -> jnp.ndarray:
    """Step 5 of Alg. 1 at every node: Z_i = M_i Q_i. covs: (N,d,d)."""
    return jnp.einsum("nde,ner->ndr", covs, q_nodes)


def _make_data_apply(xs: Sequence[jnp.ndarray]) -> Callable:
    """Gram-free Step 5: Z_i = X_i (X_i^T Q_i), never forming M_i (d x d)."""

    def apply(q_nodes):
        zs = [x @ (x.T @ q_nodes[i]) / x.shape[1] for i, x in enumerate(xs)]
        return jnp.stack(zs, axis=0)

    return apply


def sdot(
    *,
    covs: Optional[jnp.ndarray] = None,
    data: Optional[Sequence[jnp.ndarray]] = None,
    engine: DenseConsensus,
    r: int,
    t_outer: int,
    schedule: Optional[np.ndarray] = None,
    t_c: int = 50,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
) -> SDOTResult:
    """Run S-DOT / SA-DOT over a simulated network.

    Exactly one of ``covs`` (N, d, d) or ``data`` (list of (d, n_i)) must be
    given. ``schedule`` overrides ``t_c`` (constant) and makes this SA-DOT.
    """
    if (covs is None) == (data is None):
        raise ValueError("provide exactly one of covs / data")
    n = engine.graph.n_nodes
    if covs is not None:
        d = covs.shape[1]
        apply_fn = lambda q: local_cov_apply(covs, q)
        if covs.shape[0] != n:
            raise ValueError("covs leading dim must equal number of nodes")
    else:
        d = data[0].shape[0]
        apply_fn = _make_data_apply(data)
        if len(data) != n:
            raise ValueError("need one data block per node")

    if schedule is None:
        schedule = consensus_schedule("const", t_outer, t_max=t_c)
    if q_init is None:
        q_init = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    # all nodes start from the same Q_init (Theorem 1 requires it)
    q_nodes = jnp.broadcast_to(q_init[None], (n, d, r))

    ledger = CommLedger()
    errs = [] if q_true is not None else None

    for t in range(t_outer):
        z0 = apply_fn(q_nodes)                                   # (N, d, r)
        v = engine.run_debiased(z0, int(schedule[t]), ledger)    # approx sum_j M_j Q_j
        q_nodes = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)    # per-node QR
        if errs is not None:
            e = jax.vmap(lambda qq: subspace_error(q_true, qq))(q_nodes)
            errs.append(float(e.mean()))

    return SDOTResult(
        q_nodes=q_nodes,
        error_trace=np.asarray(errs) if errs is not None else None,
        consensus_trace=np.asarray(schedule[:t_outer]),
        ledger=ledger,
    )


def sadot(*, schedule_kind: str = "lin2", cap: Optional[int] = None,
          t_outer: int, **kw) -> SDOTResult:
    """SA-DOT convenience wrapper: increasing consensus schedule."""
    sched = consensus_schedule(schedule_kind, t_outer, cap=cap)
    return sdot(t_outer=t_outer, schedule=sched, **kw)
