"""TPU communication model — roofline terms per dry-run cell plus the PSA
gradient-compression cross-pod traffic model (the paper's algorithm applied
to distributed training, DESIGN.md §2).

Reads experiments/dryrun/*.json if present (produced by
``python -m repro.launch.dryrun --all``); silently reports what exists.
"""
from __future__ import annotations

import glob
import json
import os

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import PSAConfig
from repro.optim.psa_compress import compression_ratio, psa_init

from .common import Row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def _dryrun_rows(limit: int = 12):
    rows = []
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*__sp.json")))
    for f in files[:limit]:
        try:
            d = json.load(open(f))
        except Exception:
            continue
        if d.get("status") != "ok":
            continue
        t = d["roofline"]
        rows.append(Row(
            f"tpu/{d['arch']}/{d['shape']}", 0.0,
            {"dominant": t["dominant"],
             "t_compute_ms": round(t["t_compute_s"] * 1e3, 3),
             "t_memory_ms": round(t["t_memory_s"] * 1e3, 3),
             "t_collective_ms": round(t["t_collective_s"] * 1e3, 3)}))
    return rows


def _psa_rows():
    """Cross-pod bytes per step: dense all-reduce vs PSA-compressed."""
    rows = []
    for aid in ("qwen2-7b", "h2o-danube-1.8b", "musicgen-medium"):
        cfg = get_arch(aid)
        from repro.configs import reduced_config
        # build the REAL param tree shapes via eval_shape (no allocation)
        from repro.models.transformer import init_params
        shapes = jax.eval_shape(
            lambda k: init_params(k, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        for rank in (32, 64, 128):
            psa = PSAConfig(rank=rank)
            ratio = compression_ratio(shapes, psa)
            n = cfg.param_count()
            dense_gb = n * 4 / 2**30
            rows.append(Row(
                f"psa_traffic/{aid}/r{rank}", 0.0,
                {"compression": round(ratio, 4),
                 "dense_crosspod_gb_per_step": round(dense_gb, 2),
                 "psa_crosspod_gb_per_step": round(dense_gb * ratio, 3),
                 "reduction_x": round(1 / ratio, 1)}))
    return rows


def run():
    return _dryrun_rows() + _psa_rows()
