"""Always-fresh subspace serving: the crash-resumable PSA service loop.

The paper solves ONE principal-subspace problem; a deployment serves the
subspace of a stream whose population changes under it. ``PSAService``
closes that loop as a sequence of deterministic *ticks*:

    ingest -> drift detect -> (warm re-solve, a few chunks) -> quality gate
           -> atomic swap -> answer queries -> checkpoint

* **Ingest** — one micro-batch per tick into a ``StreamingIngestor``
  (``track_top=r``), whose tracked Ritz spectrum feeds the drift detector.
* **Drift -> warm re-solve** — when ``drift.DriftDetector`` triggers, the
  service freezes the current cov stack and starts an S-DOT re-solve
  **warm-started from the currently-served iterate**, driven through
  ``core.runtime.run_chunked(..., target_step=...)`` a few chunks per tick:
  the re-solve's RunState lives in its own checkpoint directory, so a kill
  at any chunk boundary resumes bit-identically, and because the per-tick
  target is an ABSOLUTE step, re-executing a crashed tick never
  double-advances the solve. The incumbent subspace keeps answering
  queries the whole time — staleness is a surfaced metric, never a stall.
* **Quality gate -> atomic swap** — a finished candidate must be finite,
  orthonormal, and explain at least as much variance as the incumbent on a
  *held-out* sample batch (fresh draws from the same population, keyed by
  the current stream step). Pass: the swap is atomic (one reference
  assignment; queries batch against one Q at a time) and the tick's
  service snapshot is **pinned** in the checkpoint manager so retention
  churn can never GC the last-good served subspace. Fail (NaN/diverged/
  chaos-mangled): the candidate is *never served* — the incumbent stays,
  the reject is counted, and a cold re-solve starts from a fresh seed.
* **Queries** — ``query.QueryPath``: bounded admission, per-request
  deadlines, explicit shedding, p50/p99 accounting.
* **Checkpoint** — the whole service state (ingest sketches + Ritz track,
  served subspace, re-solve bookkeeping, counters) is ONE fixed-structure
  pytree saved at every tick boundary. Every tick is a pure function of
  the restored state (streams are stateless-seeded, the re-solve target is
  absolute), so a SIGKILL anywhere re-executes at most one tick and the
  served-subspace trajectory — swap ticks and served bits — is IDENTICAL
  to the uninterrupted run's.

``run_supervised`` wraps the loop in the fleet's supervision idiom:
subprocess + heartbeat-staleness watchdog + relaunch with backoff.
``run_smoke`` is the CI scenario: the same config run (a) fault-free,
(b) under a kill/kill/hang FaultPlan with supervision, asserting the
served trajectory is bit-identical and every restore matched the pinned
last-good snapshot, and (c) under a corrupt-candidate + delay-query plan,
asserting the gate rejected the mangled candidate, a cold re-solve
recovered, and delayed queries expired instead of blocking.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import subprocess
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..core.linalg import cholesky_qr2, orthonormal_init
from ..core.runtime import run_chunked
from ..core.sdot import sdot_program
from ..data.pipeline import drifting_eigengap_stream
from ..obs import install as obs_install
from ..obs import metrics as obs_metrics
from ..obs import obs_dir_for
from ..streaming.chaos import ENV_PLAN, ChaosHooks, FaultPlan
from ..streaming.ingest import StreamingIngestor
from ..streaming.launcher import build_engine
from .drift import DriftDetector
from .query import QueryPath

__all__ = ["ServiceConfig", "PSAService", "run_supervised", "run_smoke",
           "service_summary"]

_STATE = "state"          # <workdir>/state: per-tick service snapshots
_RESOLVE = "resolve"      # <workdir>/resolve: active re-solve RunState
_EVENTS = "events.jsonl"
_FINAL = "final.json"
_HEARTBEAT = "heartbeat"


@dataclasses.dataclass
class ServiceConfig:
    """Everything a service run needs, JSON-round-trippable for the
    supervisor's subprocess handoff. The drifting stream is part of the
    config (not an injected callable) so a relaunched process rebuilds the
    *identical* pure (seed, step) stream."""

    d: int = 12
    r: int = 3
    n_nodes: int = 4
    batch_size: int = 32
    # drifting stream: population C0 (lead) until stream step shift_at,
    # then an independently rotated C1 (shift_lead) — shift_lead > lead
    # makes the post-shift directions dominate the blended sketch quickly
    gap: float = 0.6
    lead: float = 3.0
    shift_lead: float = 6.0
    shift_at: int = 8
    stream_seed: int = 0
    # held-out gate mass: fresh draws from the same population at the
    # current stream step (never fed to the ingestor)
    holdout_seed: int = 777
    holdout_m: int = 512
    total_ticks: int = 26
    # re-solve: t_outer S-DOT iterations advanced resolve_chunk *
    # chunks_per_tick steps per service tick through run_chunked
    t_outer: int = 12
    t_c: int = 12
    resolve_chunk: int = 3
    chunks_per_tick: int = 1
    topology: dict = dataclasses.field(default_factory=lambda: {
        "kind": "er", "n": 4, "p": 0.6, "seed": 1})
    warmup_ticks: int = 2          # ticks before the initial cold solve
    drift_threshold: float = 0.25  # residual trigger (above sampling noise)
    drift_warmup: int = 3          # post-swap ticks with no trigger
    # query path
    queries_per_tick: int = 8
    queue_capacity: int = 32
    max_batch: int = 8
    deadline_s: float = 0.25
    query_mode: str = "project"
    staleness_bound: int = 20      # asserted ceiling on served staleness
    keep_last: int = 4
    seed: int = 0

    def to_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(dataclasses.asdict(self), f, indent=2)
        return path

    @classmethod
    def from_json(cls, path: str) -> "ServiceConfig":
        with open(path) as f:
            return cls(**json.load(f))


def _touch(path: str) -> None:
    with open(path, "w") as f:
        f.write(str(time.time()))


class PSAService:
    """The tick loop (see module docstring). One instance == one process
    attempt; construct + ``run()`` resumes from the newest restorable
    service snapshot in ``workdir`` or starts fresh."""

    def __init__(self, cfg: ServiceConfig, workdir: str,
                 plan: Optional[FaultPlan] = None):
        self.cfg = cfg
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        # process journal + metrics registry for this service attempt; the
        # runtime/checkpoint/chaos seams pick the journal up via
        # get_journal(), and the query path shares the registry so its
        # latency histogram lands in the finalize dump
        self.journal = obs_install(workdir, "service")
        self.registry = obs_metrics()
        state_root = os.path.join(workdir, _STATE)
        self.resolve_root = os.path.join(workdir, _RESOLVE)
        chaos_dir = os.path.join(workdir, "chaos_state")
        # two hook instances over ONE plan: faults target the service tick
        # boundary (worker "service") or the re-solve chunk boundary
        # (worker "resolve"); both anchor to absolute step numbers so a
        # plan reads the same before and after a crash
        self.hooks = ChaosHooks(plan, worker="service",
                                n_boundaries=cfg.total_ticks,
                                ckpt_root=state_root, state_dir=chaos_dir,
                                step_boundaries=True)
        self.resolve_hooks = ChaosHooks(plan, worker="resolve",
                                        n_boundaries=cfg.t_outer,
                                        ckpt_root=self.resolve_root,
                                        state_dir=chaos_dir,
                                        step_boundaries=True)
        self.state_mgr = CheckpointManager(
            state_root, keep_last=cfg.keep_last, on_save=self._on_tick_save)

        # pure (seed, step) drifting stream — a relaunch rebuilds the
        # identical stream, shift included
        batch_fn, (c0, _), (c1, self.q_post) = drifting_eigengap_stream(
            cfg.d, cfg.r, cfg.gap, cfg.shift_at, seed=cfg.stream_seed,
            lead=cfg.lead, shift_lead=cfg.shift_lead)
        self._hold_chol = (
            np.linalg.cholesky(np.asarray(c0, np.float64)
                               + 1e-12 * np.eye(cfg.d)),
            np.linalg.cholesky(np.asarray(c1, np.float64)
                               + 1e-12 * np.eye(cfg.d)))
        self.ingestor = StreamingIngestor(
            n_nodes=cfg.n_nodes, d=cfg.d, batch_fn=batch_fn,
            batch_size=cfg.batch_size, track_top=cfg.r, ritz_seed=cfg.seed)
        self.engine = build_engine(cfg.topology)
        self.detector = DriftDetector(residual_threshold=cfg.drift_threshold,
                                      warmup=cfg.drift_warmup)
        self.queries = QueryPath(capacity=cfg.queue_capacity,
                                 max_batch=cfg.max_batch,
                                 deadline_s=cfg.deadline_s,
                                 mode=cfg.query_mode, hooks=self.hooks,
                                 registry=self.registry)
        self.queries.warmup(cfg.d, cfg.r)
        self.history: list = []      # per-tick metrics (host-only)

        # -- mutable service state (the checkpointed tree) ------------------
        self.tick = -1                           # last COMPLETED tick
        self.served_q = np.asarray(orthonormal_init(
            jax.random.PRNGKey(cfg.seed), cfg.d, cfg.r), np.float32)
        self.served_at = -1                      # tick of last swap
        self.served_stream_step = 0              # freeze step of served Q
        self.swaps = 0
        self.gate_rejects = 0
        self.cold_resolves = 0                   # gate-fallback cold starts
        self.max_staleness = 0
        self.baseline_gap = 0.0
        self.resolve_active = False
        self.resolve_cold = True
        self.resolve_id = -1                     # id of the ACTIVE resolve
        self.resolve_done = 0                    # absolute steps completed
        self.resolve_frozen_step = 0
        self.resolve_covs = np.zeros((cfg.n_nodes, cfg.d, cfg.d), np.float32)
        self.resolve_qinit = np.zeros((cfg.d, cfg.r), np.float32)
        self._restore()

    # -- checkpointing ------------------------------------------------------
    def _tree(self) -> dict:
        return {
            "tick": jnp.int32(self.tick),
            "served_q": jnp.asarray(self.served_q),
            "served_at": jnp.int32(self.served_at),
            "served_stream_step": jnp.int32(self.served_stream_step),
            "swaps": jnp.int32(self.swaps),
            "gate_rejects": jnp.int32(self.gate_rejects),
            "cold_resolves": jnp.int32(self.cold_resolves),
            "max_staleness": jnp.int32(self.max_staleness),
            "baseline_gap": jnp.float32(self.baseline_gap),
            "resolve": {
                "active": jnp.int32(self.resolve_active),
                "cold": jnp.int32(self.resolve_cold),
                "id": jnp.int32(self.resolve_id),
                "done": jnp.int32(self.resolve_done),
                "frozen_step": jnp.int32(self.resolve_frozen_step),
                "covs": jnp.asarray(self.resolve_covs),
                "qinit": jnp.asarray(self.resolve_qinit),
            },
            "ingest": self.ingestor.state(),
        }

    def _adopt(self, tree: dict) -> None:
        self.tick = int(tree["tick"])
        self.served_q = np.asarray(tree["served_q"], np.float32)
        self.served_at = int(tree["served_at"])
        self.served_stream_step = int(tree["served_stream_step"])
        self.swaps = int(tree["swaps"])
        self.gate_rejects = int(tree["gate_rejects"])
        self.cold_resolves = int(tree["cold_resolves"])
        self.max_staleness = int(tree["max_staleness"])
        self.baseline_gap = float(tree["baseline_gap"])
        res = tree["resolve"]
        self.resolve_active = bool(int(res["active"]))
        self.resolve_cold = bool(int(res["cold"]))
        self.resolve_id = int(res["id"])
        self.resolve_done = int(res["done"])
        self.resolve_frozen_step = int(res["frozen_step"])
        self.resolve_covs = np.asarray(res["covs"], np.float32)
        self.resolve_qinit = np.asarray(res["qinit"], np.float32)
        self.ingestor.restore(tree["ingest"])

    def _restore(self) -> None:
        """Adopt the newest restorable snapshot (corrupt steps skipped) and
        record whether the restored served subspace matches the pinned
        last-good one bitwise — the serving twin of runtime._restore_any."""
        template = self._tree()
        steps = self.state_mgr.all_steps()
        for step in reversed(steps):
            try:
                tree, _ = self.state_mgr.restore(template, step=step)
            except Exception:
                continue
            self._adopt(tree)
            pinned = self.state_mgr.pinned_steps()
            match = None
            if pinned:
                try:
                    ptree, _ = self.state_mgr.restore(template,
                                                      step=pinned[-1])
                    match = bool(np.array_equal(
                        np.asarray(ptree["served_q"], np.float32),
                        self.served_q))
                except Exception:
                    match = False
            self._event({"type": "restore", "tick": self.tick,
                         "from_step": step, "pinned_match": match})
            return

    def _on_tick_save(self, step: int) -> None:
        # beat BEFORE chaos: a hang fault must leave a stale (not fresh)
        # heartbeat for the supervisor's watchdog to see
        _touch(os.path.join(self.workdir, _HEARTBEAT))
        self.hooks.at_boundary(step)

    def _on_resolve_save(self, step: int) -> None:
        _touch(os.path.join(self.workdir, _HEARTBEAT))
        self.resolve_hooks.at_boundary(step)

    def _event(self, doc: dict) -> None:
        # append-only across restarts; a re-executed tick appends an
        # identical duplicate, which summarization dedups keep-first
        with open(os.path.join(self.workdir, _EVENTS), "a") as f:
            f.write(json.dumps(doc) + "\n")

    # -- held-out quality gate ----------------------------------------------
    def _holdout_cov(self) -> np.ndarray:
        """Fresh (d, d) sample covariance from the CURRENT population —
        independent draws the ingestor never saw, keyed by the stream step
        so the gate is a pure function of service state."""
        cfg = self.cfg
        step = self.ingestor.step
        chol = self._hold_chol[0 if step < cfg.shift_at else 1]
        rng = np.random.default_rng(cfg.holdout_seed * 9973 + step)
        x = chol @ rng.standard_normal((cfg.d, cfg.holdout_m))
        return (x @ x.T / cfg.holdout_m).astype(np.float32)

    def _gate(self, candidate: np.ndarray) -> tuple:
        """(accept, reason, cand_ev, inc_ev): candidate must be finite,
        orthonormal, and explain >= the incumbent's variance on held-out
        mass (small relative slack so a statistically-equal candidate from
        a fresher freeze still lands)."""
        if not np.all(np.isfinite(candidate)):
            return False, "nonfinite", float("nan"), float("nan")
        gram = candidate.T @ candidate
        ortho = float(np.linalg.norm(gram - np.eye(self.cfg.r)))
        if ortho > 1e-2:
            return False, f"nonorthonormal({ortho:.2e})", float("nan"), \
                float("nan")
        c_hold = self._holdout_cov()
        cand_ev = float(np.trace(candidate.T @ c_hold @ candidate))
        inc_ev = float(np.trace(self.served_q.T @ c_hold @ self.served_q))
        if cand_ev < inc_ev * (1.0 - 1e-3):
            return False, "worse_than_incumbent", cand_ev, inc_ev
        return True, "ok", cand_ev, inc_ev

    # -- re-solve lifecycle -------------------------------------------------
    def _start_resolve(self, *, cold: bool) -> None:
        cfg = self.cfg
        self.resolve_id += 1
        self.resolve_active = True
        self.resolve_cold = cold
        self.resolve_done = 0
        self.resolve_frozen_step = self.ingestor.step
        self.resolve_covs = np.asarray(self.ingestor.cov_stack(), np.float32)
        if cold:
            self.resolve_qinit = np.asarray(orthonormal_init(
                jax.random.PRNGKey(cfg.seed * 7 + 100 + self.resolve_id),
                cfg.d, cfg.r), np.float32)
        else:
            self.resolve_qinit = self.served_q.copy()
        shutil.rmtree(self.resolve_root, ignore_errors=True)
        self._event({"type": "start", "tick": self.tick + 1,
                     "resolve_id": self.resolve_id, "cold": cold,
                     "frozen_step": self.resolve_frozen_step})
        self.journal.event("resolve_start", "resolve",
                           tick=self.tick + 1, resolve_id=self.resolve_id,
                           cold=cold, frozen_step=self.resolve_frozen_step)

    def _advance_resolve(self) -> None:
        """A few chunks of the active re-solve, to an ABSOLUTE target step —
        a crashed tick's re-execution restores the re-solve RunState at (or
        past) the same target and can never double-advance it."""
        cfg = self.cfg
        target = min(self.resolve_done + cfg.resolve_chunk
                     * cfg.chunks_per_tick, cfg.t_outer)
        mgr = CheckpointManager(self.resolve_root, keep_last=3,
                                on_save=self._on_resolve_save)
        program = sdot_program(
            covs=jnp.asarray(self.resolve_covs), engine=self.engine,
            r=cfg.r, t_outer=cfg.t_outer, t_c=cfg.t_c,
            q_init=jnp.asarray(self.resolve_qinit))
        with self.journal.span("resolve_increment", "resolve",
                               tick=self.tick + 1,
                               resolve_id=self.resolve_id,
                               target=target, cold=self.resolve_cold):
            result = run_chunked(program, mgr, chunk_size=cfg.resolve_chunk,
                                 target_step=target)
        self.resolve_done = target
        if target < cfg.t_outer:
            return
        # complete: consensus-average the node iterates, re-orthonormalize,
        # hand the candidate to chaos (the gate's adversary), then gate it
        candidate = np.asarray(
            cholesky_qr2(result.q_nodes.mean(axis=0))[0], np.float32)
        candidate = np.asarray(self.hooks.mangle_candidate(
            candidate, self.resolve_id), np.float32)
        gate_sp = self.journal.begin("gate", "resolve",
                                     tick=self.tick + 1,
                                     resolve_id=self.resolve_id)
        accept, reason, cand_ev, inc_ev = self._gate(candidate)
        gate_sp.end(accept=accept, reason=reason)
        if accept:
            # the atomic swap: one assignment; queries only ever batch
            # against a fully-published Q
            self.served_q = candidate
            self.served_at = self.tick + 1
            self.served_stream_step = self.resolve_frozen_step
            self.swaps += 1
            self.baseline_gap = self.ingestor.eigengap
            self.resolve_active = False
            self._event({"type": "swap", "tick": self.tick + 1,
                         "resolve_id": self.resolve_id,
                         "cold": self.resolve_cold,
                         "cand_ev": round(cand_ev, 6),
                         "inc_ev": round(inc_ev, 6),
                         "frozen_step": self.resolve_frozen_step})
            self.journal.event("swap", "resolve", tick=self.tick + 1,
                               resolve_id=self.resolve_id,
                               frozen_step=self.resolve_frozen_step)
            self.registry.counter("serving_swaps_total").inc()
        else:
            # never served: incumbent stays, cold re-solve from fresh seed
            self.gate_rejects += 1
            self.cold_resolves += 1
            self._event({"type": "reject", "tick": self.tick + 1,
                         "resolve_id": self.resolve_id, "reason": reason,
                         "cand_ev": cand_ev, "inc_ev": inc_ev})
            self.journal.event("reject", "resolve", tick=self.tick + 1,
                               resolve_id=self.resolve_id, reason=reason)
            self.registry.counter("serving_gate_rejects_total").inc()
            self._start_resolve(cold=True)

    # -- the tick -----------------------------------------------------------
    def _run_tick(self) -> None:
        cfg = self.cfg
        tick = self.tick + 1
        jl = self.journal
        # one span per tick; a chaos kill mid-tick leaves it (and the phase
        # span it died inside) orphaned — that pair IS the forensics answer
        # to "what was the service doing when it died"
        tick_sp = jl.begin("tick", "serving", tick=tick)

        # 1) ingest this tick's micro-batch (pure in (seed, step))
        with jl.span("ingest", "serving", tick=tick):
            self.ingestor.ingest(1)

        # 2) re-solve lifecycle: advance the active one, or decide to start
        if self.resolve_active:
            self._advance_resolve()
        elif self.swaps == 0:
            if tick >= cfg.warmup_ticks:
                self._start_resolve(cold=True)
                self._advance_resolve()
        else:
            with jl.span("drift_read", "serving", tick=tick) as dsp:
                stats = self.detector.read(
                    self.ingestor, jnp.asarray(self.served_q),
                    baseline_gap=self.baseline_gap,
                    ticks_since_swap=tick - self.served_at)
                dsp.add(triggered=bool(stats.triggered))
            if stats.triggered:
                self._start_resolve(cold=False)   # warm: from the served Q
                self._advance_resolve()

        # 3) queries against whatever is served right now
        with jl.span("query_drain", "serving", tick=tick) as qsp:
            rng = np.random.default_rng(cfg.seed * 31 + 17 + tick)
            for j in range(cfg.queries_per_tick):
                req_id = tick * cfg.queries_per_tick + j
                self.queries.submit(req_id, rng.standard_normal(cfg.d))
            answered = len(self.queries.process(self.served_q))
            expired = self.queries.drain_expired()
            qsp.add(answered=answered, drain_expired=expired)

        # 4) staleness: served-from freeze step vs ingested step — a
        #    surfaced metric, never a stall
        staleness = (self.ingestor.step - self.served_stream_step
                     if self.swaps else 0)
        self.max_staleness = max(self.max_staleness, staleness)
        self.registry.gauge("serving_staleness_ticks").set(staleness)
        self.history.append({
            "tick": tick, "staleness": staleness, "swaps": self.swaps,
            "resolve_active": self.resolve_active,
            "resolve_done": self.resolve_done if self.resolve_active else 0})

        # 5) commit the tick (blocking: pins must follow a published step);
        #    a kill at this boundary re-executes the whole tick, which is a
        #    pure function of the previous snapshot
        self.tick = tick
        self.state_mgr.save(tick, self._tree(), blocking=True)
        if self.served_at == tick:
            # pin the snapshot holding the just-swapped subspace; retire
            # older pins so exactly the last-good generation survives GC
            self.state_mgr.pin(tick)
            for s in self.state_mgr.pinned_steps():
                if s != tick:
                    self.state_mgr.unpin(s)
        tick_sp.end(staleness=staleness, swaps=self.swaps)

    def run(self, until: Optional[int] = None) -> "PSAService":
        stop = self.cfg.total_ticks if until is None else until
        while self.tick + 1 < stop:
            self._run_tick()
        return self

    # -- reporting ----------------------------------------------------------
    def summary(self) -> dict:
        served = np.asarray(self.served_q, np.float32)
        return {
            "tick": self.tick,
            "swaps": self.swaps,
            "gate_rejects": self.gate_rejects,
            "cold_resolves": self.cold_resolves,
            "served_at": self.served_at,
            "served_stream_step": self.served_stream_step,
            "max_staleness": self.max_staleness,
            "served_sha256": hashlib.sha256(served.tobytes()).hexdigest(),
            "queries": self.queries.summary(),
        }

    def finalize(self) -> dict:
        """Publish the completion marker the supervisor looks for."""
        doc = self.summary()
        with open(os.path.join(self.workdir, _FINAL), "w") as f:
            json.dump(doc, f, indent=2)
        obs_dir = obs_dir_for(self.workdir)
        if obs_dir is not None:
            # the aggregate twin of the journal: the obs CLI merges this
            # dump (query latency histogram, swap/reject counters) into its
            # exposition alongside journal-derived span durations
            self.registry.dump(os.path.join(obs_dir, "metrics.service.json"))
        return doc


# ---------------------------------------------------------------------------
# event-log digest (trajectory comparison across runs)
# ---------------------------------------------------------------------------
def service_summary(workdir: str) -> dict:
    """final.json + the deduplicated event trajectory.

    Events are append-only across restarts, so a re-executed tick appends
    byte-identical duplicates; dedup keeps the FIRST occurrence per
    (type, tick, resolve_id) key. The swap/reject tick lists are the
    served-subspace trajectory two runs are compared on."""
    with open(os.path.join(workdir, _FINAL)) as f:
        doc = json.load(f)
    events, seen = [], set()
    path = os.path.join(workdir, _EVENTS)
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                ev = json.loads(line)
                key = (ev["type"], ev["tick"], ev.get("resolve_id"))
                if key in seen:
                    continue
                seen.add(key)
                events.append(ev)
    doc["swap_ticks"] = [e["tick"] for e in events if e["type"] == "swap"]
    doc["reject_ticks"] = [e["tick"] for e in events if e["type"] == "reject"]
    doc["restores"] = [e for e in events if e["type"] == "restore"]
    return doc


# ---------------------------------------------------------------------------
# supervision: subprocess + heartbeat watchdog + relaunch with backoff
# ---------------------------------------------------------------------------
def run_supervised(cfg: ServiceConfig, workdir: str, *,
                   stall_timeout: float = 8.0, startup_timeout: float = 240.0,
                   poll: float = 0.3, max_relaunches: int = 6,
                   backoff: float = 0.25, env: Optional[dict] = None,
                   verbose: bool = False) -> dict:
    """Run the service to completion in a supervised subprocess.

    The child heartbeats at every service-tick and re-solve-chunk save; the
    supervisor kills it when the beat goes stale (a wedged process — e.g. a
    chaos ``hang`` — stops beating but never exits) and relaunches with
    linear backoff. Beats are PROGRESS beats: a beat older than this
    attempt's spawn counts as "not yet started", judged against the more
    generous ``startup_timeout`` (first tick pays jax import + compile)."""
    os.makedirs(workdir, exist_ok=True)
    spec = os.path.join(workdir, "service.json")
    cfg.to_json(spec)
    beat_path = os.path.join(workdir, _HEARTBEAT)
    final_path = os.path.join(workdir, _FINAL)
    attempts, relaunches = 0, 0
    while True:
        attempts += 1
        spawn_t = time.time()
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.serving.service", "--run", spec,
             "--workdir", workdir],
            env=dict(env) if env is not None else os.environ.copy())
        while True:
            rc = proc.poll()
            if rc is not None:
                break
            now = time.time()
            beat = os.path.getmtime(beat_path) \
                if os.path.exists(beat_path) else 0.0
            if beat > spawn_t:
                stale = now - beat > stall_timeout
            else:
                stale = now - spawn_t > startup_timeout
            if stale:
                proc.kill()
                proc.wait()
                rc = "stalled"
                break
            time.sleep(poll)
        if verbose:
            print(f"[supervisor] attempt {attempts}: rc={rc}")
        if rc == 0 and os.path.exists(final_path):
            break
        if relaunches >= max_relaunches:
            raise RuntimeError(
                f"service did not complete within {max_relaunches} "
                f"relaunches (last rc={rc})")
        relaunches += 1
        time.sleep(backoff * relaunches)
    doc = service_summary(workdir)
    doc["attempts"] = attempts
    doc["relaunches"] = relaunches
    return doc


# ---------------------------------------------------------------------------
# seeded serving-chaos smoke scenario (CI entry point)
# ---------------------------------------------------------------------------
def run_smoke(workdir: str, *, verbose: bool = True) -> dict:
    """The CI serving-chaos scenario (see module docstring).

    (a) fault-free in-process reference run;
    (b) the same config supervised under kill-mid-service /
        kill-mid-re-solve / hang faults — the served trajectory (swap
        ticks AND served bits) must be identical to (a), every restore
        must match the pinned last-good snapshot, and the three faults
        must cost exactly three relaunches;
    (c) a corrupt-candidate + delay-query plan in-process — the gate must
        reject the mangled candidate (never serving it), recover through a
        cold re-solve to a subspace close to the post-shift truth, and
        delayed queries must expire against their deadline instead of
        blocking the loop.
    """
    cfg = ServiceConfig()
    os.makedirs(workdir, exist_ok=True)

    # (a) fault-free reference
    ref_dir = os.path.join(workdir, "ref")
    svc = PSAService(cfg, ref_dir).run()
    ref = svc.finalize()
    ref = service_summary(ref_dir)
    assert ref["swaps"] >= 2, ref          # initial solve + >=1 drift swap
    assert ref["gate_rejects"] == 0, ref
    assert ref["max_staleness"] <= cfg.staleness_bound, ref
    assert ref["queries"]["answered"] > 0, ref

    # (b) kill/kill/hang under supervision: trajectory must be identical
    chaos_dir = os.path.join(workdir, "chaos")
    os.makedirs(chaos_dir, exist_ok=True)
    plan = FaultPlan(seed=0, faults=[
        # tick-7 save killed: the tick (ingest + resolve increment) is
        # lost and re-executed after relaunch
        {"kind": "kill", "worker": "service", "boundary": 7},
        # re-solve chunk-boundary save at absolute step 6 killed: the
        # re-solve resumes bit-identically from its RunState checkpoint
        {"kind": "kill", "worker": "resolve", "boundary": 6},
        # wedge at tick 12 without exiting: the heartbeat goes stale and
        # the supervisor's watchdog kills + relaunches
        {"kind": "hang", "worker": "service", "boundary": 12, "sleep": 60},
    ])
    plan_path = plan.dump(os.path.join(chaos_dir, "plan.json"))
    env = os.environ.copy()
    env[ENV_PLAN] = plan_path
    t0 = time.perf_counter()
    chaos = run_supervised(cfg, chaos_dir, env=env, verbose=verbose)
    chaos_s = time.perf_counter() - t0
    assert chaos["relaunches"] == 3, chaos
    # the served-subspace trajectory is BIT-identical to the reference
    assert chaos["served_sha256"] == ref["served_sha256"], (chaos, ref)
    assert chaos["swap_ticks"] == ref["swap_ticks"], (chaos, ref)
    assert chaos["swaps"] == ref["swaps"], (chaos, ref)
    assert chaos["gate_rejects"] == 0, chaos
    assert chaos["max_staleness"] <= cfg.staleness_bound, chaos
    # every restore that had a pin matched it bitwise; at least one did
    matches = [e["pinned_match"] for e in chaos["restores"]]
    assert all(m is not False for m in matches), chaos["restores"]
    assert any(m is True for m in matches), chaos["restores"]

    # (c) corrupt-candidate + delayed queries, in-process
    gate_dir = os.path.join(workdir, "gate")
    gate_plan = FaultPlan(seed=0, faults=[
        # mangle the FIRST drift-triggered warm re-solve's candidate
        {"kind": "corrupt_candidate", "mode": "nan", "resolve": 1},
        # and delay ~40% of queries past their deadline
        {"kind": "delay_query", "p": 0.4, "delay": 0.5},
    ])
    svc = PSAService(cfg, gate_dir, plan=gate_plan).run()
    gate = svc.finalize()
    assert gate["gate_rejects"] == 1, gate       # the mangled candidate
    assert gate["cold_resolves"] == 1, gate      # ... fell back cold
    assert gate["swaps"] >= 2, gate              # ... and recovered
    assert np.all(np.isfinite(svc.served_q))     # NaN never served
    from ..core.metrics import subspace_error
    post_err = float(subspace_error(svc.q_post,
                                    jnp.asarray(svc.served_q)))
    assert post_err < 0.2, post_err              # recovered to the truth
    assert gate["queries"]["expired"] > 0, gate  # delays expired, not slept
    assert gate["max_staleness"] <= cfg.staleness_bound, gate

    summary = {
        "ref": {k: ref[k] for k in ("swaps", "swap_ticks", "served_sha256",
                                    "max_staleness")},
        "chaos": {"relaunches": chaos["relaunches"],
                  "restores": len(chaos["restores"]),
                  "trajectory_bitwise_equal": True,
                  "wall_s": round(chaos_s, 2)},
        "gate": {"gate_rejects": gate["gate_rejects"],
                 "cold_resolves": gate["cold_resolves"],
                 "post_shift_subspace_err": round(post_err, 4),
                 "queries": gate["queries"]},
    }
    if verbose:
        print(json.dumps(summary, indent=2))
    return summary


def main(argv=None) -> int:
    import argparse
    import tempfile

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--run", metavar="SPEC",
                    help="run a service to total_ticks from a JSON config")
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="run the seeded serving-chaos CI scenario")
    args = ap.parse_args(argv)
    if args.smoke:
        workdir = args.workdir or tempfile.mkdtemp(prefix="serving_smoke_")
        run_smoke(workdir)
        return 0
    if not args.run:
        ap.error("nothing to do (pass --run SPEC or --smoke)")
    cfg = ServiceConfig.from_json(args.run)
    workdir = args.workdir or os.path.dirname(os.path.abspath(args.run))
    plan_path = os.environ.get(ENV_PLAN)
    plan = FaultPlan.load(plan_path) if plan_path else None
    svc = PSAService(cfg, workdir, plan=plan).run()
    svc.finalize()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
