"""Beyond-paper: B-DOT (block-partitioned DOT — the paper's §VI future-work
direction). Compares B-DOT on an I x J grid against S-DOT (sample-only, each
node must hold ALL d features) and F-DOT (feature-only, each node must hold
ALL n samples) on the same data, reporting the per-node storage and the
communication payload — the two resources block partitioning is for.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.bdot import bdot
from repro.core.consensus import DenseConsensus
from repro.core.fdot import fdot
from repro.core.linalg import eigh_topr
from repro.core.sdot import sdot
from repro.core.topology import erdos_renyi
from repro.data.pipeline import (gaussian_eigengap_data, partition_features,
                                 partition_samples)

from .common import Row, timed

D, N_SAMP, R, I, J = 40, 4000, 5, 4, 5


def run():
    rows = []
    x, _, _ = gaussian_eigengap_data(D, N_SAMP, R, 0.6, seed=0)
    _, q_true = eigh_topr(x @ x.T, R)

    # --- B-DOT on a 4x5 grid (20 nodes, each holds a (10, 800) block)
    fslabs = partition_features(x, I)
    blocks = [partition_samples(sl, J) for sl in fslabs]
    cols = [DenseConsensus(erdos_renyi(I, 0.7, seed=j)) for j in range(J)]
    rws = [DenseConsensus(erdos_renyi(J, 0.7, seed=10 + i)) for i in range(I)]
    res, us = timed(bdot, blocks=blocks, col_engines=cols, row_engines=rws,
                    r=R, t_outer=60, t_c=50, q_true=q_true)
    rows.append(Row("bdot/grid4x5", us, {
        "final_err": f"{res.error_trace[-1]:.2e}",
        "node_storage_elems": (D // I) * (N_SAMP // J),
        # per-gossip-round message size: the quantity that scales;
        # B-DOT's is max(n/J, d/I) x r vs S-DOT's d x r and F-DOT's n x r —
        # block partitioning wins when BOTH d and n are large
        "per_round_msg_elems": max(N_SAMP // J, D // I) * R,
        "payload_elems_moved": int(res.ledger.scalars)}))

    # --- S-DOT with 20 sample-shards (each node holds all 40 features)
    sblocks = partition_samples(x, I * J)
    covs = jnp.stack([b @ b.T / b.shape[1] for b in sblocks])
    eng = DenseConsensus(erdos_renyi(I * J, 0.3, seed=1))
    res_s, us = timed(sdot, covs=covs, engine=eng, r=R, t_outer=60, t_c=50,
                      q_true=q_true)
    rows.append(Row("bdot/sdot_ref", us, {
        "final_err": f"{res_s.error_trace[-1]:.2e}",
        "node_storage_elems": D * (N_SAMP // (I * J)),
        "per_round_msg_elems": D * R,
        "payload_elems_moved": int(res_s.ledger.scalars)}))

    # --- F-DOT with 20 feature-slabs (each node holds all 4000 samples)
    fblocks = partition_features(x, I * J)
    res_f, us = timed(fdot, data_blocks=fblocks, engine=eng, r=R, t_outer=60,
                      t_c=50, q_true=q_true)
    rows.append(Row("bdot/fdot_ref", us, {
        "final_err": f"{res_f.error_trace[-1]:.2e}",
        "node_storage_elems": (D // (I * J)) * N_SAMP,
        "per_round_msg_elems": N_SAMP * R,
        "payload_elems_moved": int(res_f.ledger.scalars)}))
    return rows
