"""Sweep worker: one shard of a sharded Monte-Carlo sweep, fleet-hardened.

Usage (spawned by ``streaming/launcher.py``; runnable by hand for debugs):

    python -m repro.streaming.worker <workdir>/spec.json <shard_idx>
    python -m repro.streaming.worker <workdir>/spec.json --fleet \
        --worker w0 [--ttl 30]

The pinned form runs exactly one shard. The ``--fleet`` form runs the
elastic loop (``streaming/fleet.py``): acquire any available shard lease,
run it — resuming from whatever sweep-RunState checkpoint the previous
owner published — release, steal the next, and exit once every shard has a
published result. New fleet workers can join a sweep at any time; leaving
is just letting the lease expire.

Rebuilds its engines/schedules from the spec (seed-deterministic graph
constructions — no pickled objects cross the host boundary), loads the cov
stacks from ``problem.npz``, runs ``sdot_sweep`` over its shard's seed
slice, and publishes ``{q, error_traces, seeds, ledger}`` atomically into
``<workdir>/worker_<shard>/result``. If a valid result is already
published the worker exits immediately (idempotent relaunch) — and also
sweeps away any leftover ``ckpt`` dir, closing the crash window between
result publish and checkpoint cleanup: the published result ALWAYS wins
over a stale intermediate checkpoint.

Robustness wiring (all no-ops outside a supervised launch):

* a **heartbeat** file ``worker_<shard>/heartbeat`` is touched at every
  chunk boundary (via ``CheckpointManager.on_save``) and just before the
  result publish, so the launcher's supervision loop can spot a wedged
  worker by staleness (it is a PROGRESS beat: the launcher only treats a
  worker as stalled once it has beaten at least once this attempt, so
  import/compile startup never reads as a stall);
* **chaos hooks** (``streaming/chaos.py``) are installed from the
  ``REPRO_CHAOS_PLAN`` env var — production code carries no fault-injection
  branches;
* under a **lease** (fleet mode) every chunk boundary renews the lease;
  a foreign fencing token raises ``LeaseLost`` and the shard is abandoned
  mid-run instead of wasting compute on stolen work.

With ``spec["sweep_chunk"]`` set, the shard runs through the unified
runtime's CHUNKED driver: the sweep-RunState (case x seed lane axes riding
on every buffer) checkpoints into ``<workdir>/worker_<shard>/ckpt`` every
``sweep_chunk`` outer iterations, so a killed worker — or a stealing
neighbour — resumes MID-GRID, bitwise equal to the uninterrupted sweep.
The published result records ``resumed_steps`` for the launcher's resume
report.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys


def run_shard(spec: dict, workdir: str, shard: int, *, worker=None,
              lease_store=None, lease=None) -> int:
    """Compute and publish one shard (idempotent; resumes from checkpoints).

    ``worker`` is the process identity for chaos targeting and lease
    ownership (defaults to the shard index). ``lease_store``/``lease``
    wire per-chunk-boundary lease renewal in fleet mode."""
    from repro.obs import get_journal
    from repro.streaming.launcher import _load_result, _worker_dir

    shard = int(shard)
    shard_dir = _worker_dir(workdir, shard)
    out_dir = os.path.join(shard_dir, "result")
    ckpt_dir = os.path.join(shard_dir, "ckpt")
    worker_id = str(worker) if worker is not None else str(shard)

    # idempotent relaunch — but only for a result stamped with THIS spec's
    # fingerprint: a hand-run worker in a reused workdir must not keep a
    # shard computed under an older spec. The published result always wins;
    # any ckpt dir a crash left behind next to it is stale by definition
    # and is cleaned up here, making the publish->cleanup pair idempotent.
    if _load_result(workdir, spec, shard) is not None:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
        get_journal().event("shard_skip", "worker", shard=shard)
        print(f"worker {shard}: result already published, nothing to do")
        return 0
    shutil.rmtree(out_dir, ignore_errors=True)

    # the whole shard is ONE span: a chaos kill (or LeaseLost abandon)
    # leaves it open in the journal, which is how forensics names the work
    # a dead/robbed worker was doing
    sp = get_journal().begin("shard_run", "worker", shard=shard,
                             worker=worker_id)

    try:
        return _run_shard_body(spec, workdir, shard, worker_id, sp,
                               lease_store, lease)
    except BaseException:
        # close the span for survivable aborts (LeaseLost, raised errors) —
        # a SIGKILL never reaches here and leaves the span_start orphaned,
        # by design
        sp.end(ok=False)
        raise


def _run_shard_body(spec, workdir, shard, worker_id, sp, lease_store,
                    lease) -> int:
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint.manager import CheckpointManager, save_tree
    from repro.core.sweep import netfault_sweep, sdot_sweep
    from repro.obs import get_journal
    from repro.streaming import chaos
    from repro.streaming.fleet import touch_heartbeat
    from repro.streaming.launcher import (_worker_dir, build_engine,
                                          build_schedule, spec_fingerprint)

    shard_dir = _worker_dir(workdir, shard)
    out_dir = os.path.join(shard_dir, "result")
    ckpt_dir = os.path.join(shard_dir, "ckpt")
    hb_path = os.path.join(shard_dir, "heartbeat")

    seeds = spec["shards"][shard]
    if not seeds:
        raise ValueError(f"worker {shard} got an empty seed shard")
    problem = np.load(os.path.join(workdir, "problem.npz"))
    engines = [build_engine(c["topology"]) for c in spec["cases"]]
    schedules = [build_schedule(c.get("schedule"), spec["t_outer"],
                                spec["t_c"]) for c in spec["cases"]]
    if spec["ragged"]:
        # a 1-element list is stored once; sdot_sweep zip-broadcasts it
        covs = [jnp.asarray(problem[f"covs_{ci}"])
                for ci in range(spec["n_cov_stacks"])]
    else:
        covs = jnp.asarray(problem["covs"])
    q_true = (jnp.asarray(problem["q_true"]) if spec["has_q_true"]
              else None)

    sweep_chunk = spec.get("sweep_chunk")
    n_boundaries = (-(-spec["t_outer"] // sweep_chunk) if sweep_chunk else 1)
    hooks = chaos.hooks_from_env(shard=shard, worker=worker_id,
                                 n_boundaries=n_boundaries,
                                 ckpt_root=ckpt_dir, workdir=workdir)

    def on_boundary(step: int) -> None:
        # chunk-boundary side effects, in supervision order: inject faults
        # first (a killed worker must not beat), then beat, then renew the
        # lease (a stolen lease aborts the run via LeaseLost)
        hooks.at_boundary(step)
        touch_heartbeat(hb_path, step=step)
        if lease_store is not None and lease is not None:
            lease_store.renew(shard, worker_id, lease.token)

    manager = None
    if sweep_chunk:
        # chunked-resumable shard: the sweep-RunState checkpoints at every
        # chunk boundary, and a restarted (or stealing) worker continues
        # mid-grid from it
        manager = CheckpointManager(ckpt_dir, on_save=on_boundary)

    if spec.get("net_faults"):
        # gossip-layer fault injection: wrap every case engine in a
        # FaultyConsensus built from the spec's net-fault document — the
        # document is part of the spec fingerprint, so every worker (and
        # every resume) runs the identical seeded fault realization
        from repro.core.netfaults import FaultyConsensus
        model, fseed, debias = chaos.net_fault_model_from_dict(
            spec["net_faults"])
        engines = [FaultyConsensus(graph=e.graph, faults=model, seed=fseed,
                                   debias=debias) for e in engines]
        sw = netfault_sweep(covs=covs, engines=engines,
                            schedules=schedules, r=spec["r"],
                            t_outer=spec["t_outer"], t_c=spec["t_c"],
                            seeds=seeds, q_true=q_true,
                            manager=manager, chunk_size=sweep_chunk)
    else:
        sw = sdot_sweep(covs=covs, engines=engines, schedules=schedules,
                        r=spec["r"], t_outer=spec["t_outer"],
                        t_c=spec["t_c"], seeds=seeds, q_true=q_true,
                        manager=manager, chunk_size=sweep_chunk)
    # the step the runtime ACTUALLY restored (a corrupt/stale newest
    # checkpoint falls back, so this can be less than the dir's latest step)
    resumed_steps = sw.resumed_step

    # the stamped fingerprint lets the launcher reject this result if the
    # workdir is later reused with a different spec
    tree = {"q": sw.q, "seeds": jnp.asarray(np.asarray(seeds)),
            "ledger": sw.ledger,
            "resumed_steps": jnp.asarray(resumed_steps, jnp.int32),
            "spec_fp": jnp.asarray(spec_fingerprint(spec), jnp.int32)}
    if spec["has_q_true"]:
        tree["error_traces"] = jnp.asarray(sw.error_traces)
    if spec["ragged"]:
        tree["node_counts"] = jnp.asarray(sw.node_counts)
    touch_heartbeat(hb_path, step=spec["t_outer"])
    save_tree(out_dir, tree, step=shard)
    get_journal().event("publish", "worker", shard=shard,
                        n_seeds=len(seeds),
                        resumed_steps=int(resumed_steps))
    hooks.after_publish(out_dir)
    # the published result supersedes the intermediate sweep state; a kill
    # landing between the publish above and this cleanup is benign — the
    # relaunch path at the top of this function redoes the cleanup and the
    # result always wins over the stale checkpoint
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    sp.end(n_seeds=len(seeds), resumed_steps=int(resumed_steps))
    print(f"worker {shard}: published {len(seeds)} seed lanes -> {out_dir}"
          + (f" (resumed from outer step {resumed_steps})"
             if resumed_steps else ""))
    return 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("spec", help="path to <workdir>/spec.json")
    ap.add_argument("shard", nargs="?", default=None,
                    help="shard index (pinned mode)")
    ap.add_argument("--fleet", action="store_true",
                    help="elastic mode: lease-and-steal shards until the "
                         "whole grid is published")
    ap.add_argument("--worker", default=None,
                    help="fleet worker identity (e.g. w0)")
    ap.add_argument("--ttl", type=float, default=30.0,
                    help="lease time-to-live in seconds (fleet mode)")
    args = ap.parse_args(argv)
    if args.fleet == (args.shard is not None):
        ap.error("pass a shard index (pinned) or --fleet (elastic), not both")

    workdir = os.path.dirname(os.path.abspath(args.spec))
    with open(args.spec) as f:
        spec = json.load(f)

    from repro.obs import install

    if args.fleet:
        from repro.streaming.fleet import fleet_worker_loop
        worker_id = args.worker or f"w{os.getpid()}"
        # attempt-scoped journal: a respawned slot opens fleet_w0.a1.jsonl
        # next to the crashed attempt's fleet_w0.a0.jsonl
        install(workdir, f"fleet_{worker_id}")
        return fleet_worker_loop(spec, workdir, worker_id, ttl=args.ttl)
    install(workdir, f"worker_s{int(args.shard)}")
    return run_shard(spec, workdir, int(args.shard), worker=args.worker)


if __name__ == "__main__":
    sys.exit(main())
