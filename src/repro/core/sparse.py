"""Sparse mixing weights for large gossip networks (padded ELL + CSR).

Every consensus path in this repo historically multiplied a dense (N, N)
mixing matrix per gossip round — O(N^2 k) flops and O(N^2) bytes touched
per round — which caps practical simulations at N ~ 200 nodes. The
overlay topologies the paper's tradeoffs are about (Erdos-Renyi at the
connectivity threshold, small-world, scale-free, geometric) have O(N)
edges at the 1k-10k-node scale, so the mixing matrix is >99% zeros.
``SparseW`` stores exactly the nonzero structure:

* **padded ELL form** — ``ell_idx``/``ell_val``: (N, L) with L = max row
  degree. Slot (i, l) holds node i's l-th neighbor (ascending index);
  slots past ``row_nnz[i]`` self-point with weight 0, so every row does
  identical work and no raggedness leaks into ``lax.scan``. The diagonal
  is a separate (N,) vector — fault models return dropped mass to it
  without touching the off-diagonal storage.
* **CSR view** (``csr()``) — host indptr/indices/data, the interchange
  format for external tooling; ``to_dense()`` is the round-trip oracle
  the equivalence tests pin against.

``SparseW`` is a registered pytree: it flows through ``jax.jit``
arguments, scan carries, ``vmap`` (B-DOT's stacked per-subnetwork
engines) and the runtime ``Program`` operand tuple exactly like the
dense array it replaces. One gossip round is ``mix(z)``, dispatched to
the Pallas ELL-SpMM kernel on TPU and a gather/einsum fallback elsewhere
(``kernels/ops.ell_spmm``); the dense einsum engine remains the
correctness oracle.

Mixed precision: ``payload_dtype="bfloat16"`` models bf16 gossip
payloads — neighbor messages (the bytes that cross the wire) are
quantized to bf16 before the f32 accumulation, while each node's own
state stays full precision. The comm ledger prices the halved bytes via
``payload_bytes_per_elem``.

Symmetry is REQUIRED (and checked at construction): the debias table
recursion uses W^T = W, and every weight rule in ``core/topology``
(local-degree, Metropolis) is symmetric.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops

__all__ = ["SparseW", "auto_sparse"]

# Auto-selection policy for DenseConsensus(sparse=None): sparse mixing
# only ever kicks in ABOVE the network sizes the paper's table
# reproductions (and this repo's seeded test suite) run at, so every
# existing N <= 200 result keeps the dense einsum bit for bit.
AUTO_MIN_NODES = 256
AUTO_MAX_DENSITY = 0.05
_ENV_FLAG = "REPRO_SPARSE_GOSSIP"


def auto_sparse(n_nodes: int, density: float,
                sparse: Optional[bool] = None) -> bool:
    """Resolve the engine-level ``sparse`` tri-state.

    ``True``/``False`` are explicit; ``None`` auto-enables when the
    network is both large (>= AUTO_MIN_NODES) and sparse
    (<= AUTO_MAX_DENSITY off-diagonal density). ``REPRO_SPARSE_GOSSIP=0``
    or ``=1`` overrides the auto rule from the environment (explicit
    arguments still win).
    """
    if sparse is not None:
        return bool(sparse)
    import os
    env = os.environ.get(_ENV_FLAG, "").strip().lower()
    if env in ("0", "false", "off"):
        return False
    if env in ("1", "true", "on"):
        return True
    return n_nodes >= AUTO_MIN_NODES and density <= AUTO_MAX_DENSITY


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparseW:
    """Symmetric doubly-stochastic mixing matrix in padded-ELL form."""

    ell_idx: jnp.ndarray      # (N, L) int32 neighbor indices (self past nnz)
    ell_val: jnp.ndarray      # (N, L) off-diagonal weights (0 past nnz)
    diag: jnp.ndarray         # (N,)   diagonal weights
    row_nnz: jnp.ndarray      # (N,)   int32 true neighbor count per row
    n: int                    # static: node count
    ell_width: int            # static: L (max row degree, >= 1)
    payload_dtype: Optional[str] = None   # static: e.g. "bfloat16"
    # (N, N) f32 off-diagonal mirror, present only past the measured CPU
    # crossover L ~ N/11 (hub-heavy graphs pad ELL toward dense work with
    # worse constants than BLAS): materialized ONCE at construction so the
    # scatter is hoisted out of every fused scan, and mixed through by
    # ``mix`` instead of the ELL kernel. Off-diagonal only — the separate
    # diagonal keeps bf16 payload semantics (neighbor messages quantized,
    # own state full precision).
    dense_off: Optional[jnp.ndarray] = None

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return ((self.ell_idx, self.ell_val, self.diag, self.row_nnz,
                 self.dense_off),
                (self.n, self.ell_width, self.payload_dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        ell_idx, ell_val, diag, row_nnz, dense_off = children
        n, ell_width, payload_dtype = aux
        return cls(ell_idx, ell_val, diag, row_nnz, n, ell_width,
                   payload_dtype, dense_off)

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_dense(cls, w: np.ndarray, adjacency: Optional[np.ndarray] = None,
                   *, payload_dtype: Optional[str] = None) -> "SparseW":
        """Build from a host (N, N) weight matrix (symmetric, e.g. the
        local-degree or Metropolis construction).

        ``adjacency`` fixes the stored structure (a real edge is kept even
        if its weight happens to be 0, so fault-model send accounting
        matches the dense engine); without it the structure is the nonzero
        off-diagonal pattern of ``w``.
        """
        w = np.asarray(w, np.float64)
        n = int(w.shape[0])
        if w.shape != (n, n):
            raise ValueError(f"w must be square, got {w.shape}")
        if not np.allclose(w, w.T, atol=1e-12):
            raise ValueError("SparseW requires a symmetric weight matrix "
                             "(the debias recursion uses W^T = W)")
        if adjacency is not None:
            struct = np.asarray(adjacency) > 0
        else:
            struct = w != 0.0
        struct = np.array(struct, bool, copy=True)
        np.fill_diagonal(struct, False)
        struct |= struct.T
        row_nnz = struct.sum(axis=1).astype(np.int32)
        ell_width = max(int(row_nnz.max(initial=0)), 1)
        # row-major nonzero scan -> per-row slots in ascending neighbor order
        rows, cols = np.nonzero(struct)
        indptr = np.zeros(n + 1, np.int64)
        np.cumsum(row_nnz, out=indptr[1:])
        slots = np.arange(rows.size) - indptr[rows]
        ell_idx = np.tile(np.arange(n, dtype=np.int32)[:, None],
                          (1, ell_width))
        ell_val = np.zeros((n, ell_width), np.float32)
        ell_idx[rows, slots] = cols.astype(np.int32)
        ell_val[rows, slots] = w[rows, cols].astype(np.float32)
        dense_off = None
        if not kops.on_tpu() and kops.ell_densify_wins(n, ell_width):
            off = w.astype(np.float32).copy()
            np.fill_diagonal(off, 0.0)
            dense_off = jnp.asarray(off)
        return cls(jnp.asarray(ell_idx), jnp.asarray(ell_val),
                   jnp.asarray(np.diagonal(w).astype(np.float32)),
                   jnp.asarray(row_nnz), n, ell_width, payload_dtype,
                   dense_off)

    @classmethod
    def from_graph(cls, graph, weights: Optional[np.ndarray] = None, *,
                   payload_dtype: Optional[str] = None) -> "SparseW":
        """Build from a ``topology.Graph`` (default: local-degree weights)."""
        if weights is None:
            from .topology import local_degree_weights
            weights = local_degree_weights(graph)
        return cls.from_dense(weights, graph.adjacency,
                              payload_dtype=payload_dtype)

    @classmethod
    def stack(cls, sws: Sequence["SparseW"]) -> "SparseW":
        """Stack same-N engines into one batched SparseW (leading axis on
        every child), padding ELL widths to the common max — the sparse
        twin of ``jnp.stack([e._w for e in engines])`` that B-DOT's
        vmapped per-subnetwork gossip uses."""
        sws = list(sws)
        n = sws[0].n
        pd = sws[0].payload_dtype
        if any(s.n != n or s.payload_dtype != pd for s in sws):
            raise ValueError("stack needs matching n and payload_dtype")
        width = max(s.ell_width for s in sws)

        def widen(s: "SparseW"):
            extra = width - s.ell_width
            if extra == 0:
                return s.ell_idx, s.ell_val
            selfp = jnp.tile(jnp.arange(n, dtype=jnp.int32)[:, None],
                             (1, extra))
            return (jnp.concatenate([s.ell_idx, selfp], axis=1),
                    jnp.pad(s.ell_val, ((0, 0), (0, extra))))

        idx, val = zip(*(widen(s) for s in sws))
        # mirror presence must be uniform across the batch (pytree
        # structure); the crossover is monotone in L, so decide by the
        # common (max) width and fill in any member's missing mirror
        dense_off = None
        if not kops.on_tpu() and kops.ell_densify_wins(n, width):
            dense_off = jnp.stack([s.dense_off if s.dense_off is not None
                                   else s._scatter_off() for s in sws])
        return cls(jnp.stack(idx), jnp.stack(val),
                   jnp.stack([s.diag for s in sws]),
                   jnp.stack([s.row_nnz for s in sws]), n, width, pd,
                   dense_off)

    def __getitem__(self, k) -> "SparseW":
        """Index the leading batch axis of a ``stack``-ed SparseW."""
        off = None if self.dense_off is None else self.dense_off[k]
        return SparseW(self.ell_idx[k], self.ell_val[k], self.diag[k],
                       self.row_nnz[k], self.n, self.ell_width,
                       self.payload_dtype, off)

    # -- array-protocol shims (the surface consensus.py relies on) ----------
    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n, self.n)

    @property
    def dtype(self):
        return self.ell_val.dtype

    def astype(self, dtype) -> "SparseW":
        """Cast the stored weights (structure untouched) — the gossip
        seams call ``w.astype(z.dtype)`` before mixing."""
        if dtype == self.ell_val.dtype:
            return self
        return SparseW(self.ell_idx, self.ell_val.astype(dtype),
                       self.diag.astype(dtype), self.row_nnz, self.n,
                       self.ell_width, self.payload_dtype, self.dense_off)

    @property
    def T(self) -> "SparseW":
        """W^T == W: symmetry is enforced at construction."""
        return self

    def with_payload_dtype(self, payload_dtype: Optional[str]) -> "SparseW":
        return SparseW(self.ell_idx, self.ell_val, self.diag, self.row_nnz,
                       self.n, self.ell_width, payload_dtype, self.dense_off)

    def _scatter_off(self) -> jnp.ndarray:
        """Scatter the ELL slots to the (N, N) off-diagonal matrix (padded
        slots self-point with weight 0, so scatter-add is exact)."""
        rows = jnp.broadcast_to(
            jnp.arange(self.n, dtype=jnp.int32)[:, None],
            (self.n, self.ell_width))
        return jnp.zeros((self.n, self.n), jnp.float32).at[
            rows, self.ell_idx].add(self.ell_val.astype(jnp.float32))

    # -- the gossip round ---------------------------------------------------
    def mix(self, z: jnp.ndarray, *, use_pallas: Optional[bool] = None,
            interpret: Optional[bool] = None) -> jnp.ndarray:
        """One gossip application ``out_i = diag_i z_i + sum_l val_il
        z_{idx_il}`` over an arbitrary payload z: (N, ...). f32
        accumulation; bf16 payload quantization when ``payload_dtype`` is
        set. Traceable — this is the inner op of every fused executor's
        scan when the engine is sparse.

        When the cached dense mirror is present (hub-heavy graphs past the
        CPU crossover — see ``kernels/ops.ell_densify_wins``) the round is
        the BLAS matmul against the mirror; ``use_pallas=True`` still
        forces the ELL kernel for kernel-level tests."""
        zf = z.reshape(self.n, -1)
        if self.dense_off is not None and not use_pallas:
            z_src = (zf if self.payload_dtype is None
                     else zf.astype(self.payload_dtype))
            out = (self.diag.astype(jnp.float32)[:, None]
                   * zf.astype(jnp.float32)
                   + self.dense_off @ z_src.astype(jnp.float32))
        else:
            out = kops.ell_spmm(self.ell_idx, self.ell_val, self.diag, zf,
                                payload_dtype=self.payload_dtype,
                                use_pallas=use_pallas, interpret=interpret)
        return out.astype(z.dtype).reshape(z.shape)

    def offdiag_mix(self, diag: jnp.ndarray, val: jnp.ndarray,
                    z: jnp.ndarray) -> jnp.ndarray:
        """Mixing round with OVERRIDDEN per-round diagonal and slot values
        (same structure): the fault models renormalize every realized
        round by masking ``ell_val`` and returning dropped mass to the
        diagonal, then mix through this hook."""
        zf = z.reshape(self.n, -1)
        out = kops.ell_spmm(self.ell_idx, val, diag, zf,
                            payload_dtype=self.payload_dtype)
        return out.astype(z.dtype).reshape(z.shape)

    # -- stats / views (host-side) ------------------------------------------
    @property
    def nnz(self) -> int:
        """Stored entries (off-diagonal edges + the N diagonal entries)."""
        return int(np.asarray(self.row_nnz).sum()) + self.n

    @property
    def density(self) -> float:
        return self.nnz / float(self.n * self.n)

    def row_stats(self) -> dict:
        nnz = np.asarray(self.row_nnz)
        return {"n": self.n, "ell_width": self.ell_width,
                "nnz": self.nnz, "density": self.density,
                "row_nnz_min": int(nnz.min()), "row_nnz_max": int(nnz.max()),
                "row_nnz_mean": float(nnz.mean())}

    def csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Host CSR view (indptr, indices, data) of the off-diagonal part
        (rows in ascending-neighbor order, matching the ELL slots)."""
        idx = np.asarray(self.ell_idx)
        val = np.asarray(self.ell_val)
        nnz = np.asarray(self.row_nnz)
        keep = np.arange(self.ell_width)[None, :] < nnz[:, None]
        indptr = np.zeros(self.n + 1, np.int64)
        np.cumsum(nnz, out=indptr[1:])
        return indptr, idx[keep].astype(np.int64), val[keep]

    def to_dense(self) -> jnp.ndarray:
        """Dense (N, N) round-trip oracle (padded slots add 0 on the
        diagonal, so no masking is needed)."""
        rows = jnp.broadcast_to(
            jnp.arange(self.n, dtype=jnp.int32)[:, None],
            (self.n, self.ell_width))
        dense = jnp.zeros((self.n, self.n), self.ell_val.dtype)
        dense = dense.at[rows, self.ell_idx].add(self.ell_val)
        ar = jnp.arange(self.n)
        return dense.at[ar, ar].add(self.diag)

    def mix_host(self, x: np.ndarray) -> np.ndarray:
        """NumPy matvec/matmat (host): the oracle for power-iteration
        spectral estimates without materializing the dense matrix."""
        x = np.asarray(x)
        idx = np.asarray(self.ell_idx)
        val = np.asarray(self.ell_val)
        diag = np.asarray(self.diag)
        gathered = x[idx]                       # (N, L) or (N, L, K)
        if x.ndim == 1:
            return diag * x + (val * gathered).sum(axis=1)
        return diag[:, None] * x + (val[..., None] * gathered).sum(axis=1)

    def spectral_gap(self, iters: int = 1000, seed: int = 0) -> float:
        """1 - |lambda_2(W)| via deflated power iteration (O(nnz)/iter)."""
        from .topology import power_iteration_gap
        return power_iteration_gap(self.mix_host, self.n, iters=iters,
                                   seed=seed)
