"""Asynchronous gossip (paper §V future work): average conservation,
consensus convergence, and straggler-tolerant S-DOT."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.async_gossip import AsyncConsensus, straggler_wall_clock
from repro.core.sdot import sdot
from repro.core.topology import erdos_renyi


def _z(n=10, d=6, r=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, d, r)), jnp.float32)


def test_round_matrix_doubly_stochastic():
    eng = AsyncConsensus(erdos_renyi(10, 0.5, seed=1), p_awake=0.6, seed=0)
    for _ in range(20):
        w, awake = eng._round_matrix()
        assert np.allclose(w.sum(0), 1.0, atol=1e-12)
        assert np.allclose(w.sum(1), 1.0, atol=1e-12)
        # sleeping nodes do not mix: their row is e_i
        for i in np.nonzero(~awake)[0]:
            assert w[i, i] == pytest.approx(1.0)


def test_async_consensus_converges_to_sum():
    eng = AsyncConsensus(erdos_renyi(10, 0.5, seed=1), p_awake=0.7, seed=0)
    z0 = _z()
    out = eng.run_debiased(z0, 300)
    assert float(jnp.abs(out - z0.sum(0)[None]).max()) < 1e-4


def test_all_asleep_rounds_are_exact_identity():
    """The degenerate round: nobody awake -> every realized round matrix
    renormalizes to exact identity, zero sends are logged, and the debias
    clamp never divides by ~0 — the input comes back BIT-FOR-BIT."""
    from repro.core.metrics import CommLedger
    eng = AsyncConsensus(erdos_renyi(10, 0.5, seed=1), p_awake=0.0, seed=0)
    z0 = _z(seed=6)
    ledger = CommLedger()
    out = eng.run_debiased(z0, 25, ledger)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(z0))
    assert np.all(np.isfinite(np.asarray(out)))
    assert ledger.p2p == 0.0 and ledger.scalars == 0.0
    assert ledger.awake_counts and max(ledger.awake_counts) == 0.0


def test_async_slower_than_sync_in_rounds():
    """Dropped rounds cost contraction: async error at equal round count is
    no better than synchronous."""
    from repro.core.consensus import DenseConsensus
    g = erdos_renyi(10, 0.4, seed=2)
    z0 = _z(seed=3)
    e_sync = float(jnp.abs(DenseConsensus(g).run_debiased(z0, 30)
                           - z0.sum(0)[None]).max())
    errs = []
    for seed in range(5):
        eng = AsyncConsensus(g, p_awake=0.5, seed=seed)
        errs.append(float(jnp.abs(eng.run_debiased(z0, 30)
                                  - z0.sum(0)[None]).max()))
    assert np.median(errs) >= e_sync * 0.9


def test_async_sdot_reaches_floor(psa_problem):
    p = psa_problem
    eng = AsyncConsensus(erdos_renyi(p["n_nodes"], 0.5, seed=1),
                         p_awake=0.7, seed=0)
    res = sdot(covs=p["covs"], engine=eng, r=p["r"], t_outer=60, t_c=50,
               q_true=p["q_true"])
    assert res.error_trace[-1] < 1e-5


def test_straggler_wall_clock_model():
    wc = straggler_wall_clock(n_nodes=10, t_round=0.001, delay=0.01,
                              rounds_sync=1000, rounds_async=1000)
    assert wc["sync_s"] == pytest.approx(11.0)
    assert wc["async_s"] == pytest.approx(1.0)
    assert wc["speedup"] == pytest.approx(11.0)
