"""AdamW with shard-friendly, dtype-configurable moments.

Moments inherit the parameter sharding (ZeRO-style: FSDP-sharded params give
FSDP-sharded optimizer state for free under pjit). ``moment_dtype="bfloat16"``
halves optimizer HBM — the knob that matters for the 1T-param Kimi-K2 cell.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100


def _lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_init(params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = _lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
