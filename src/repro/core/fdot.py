"""F-DOT — feature-wise distributed orthogonal iteration (Alg. 2).

Node i holds a feature slab X_i in R^{d_i x n}. One outer iteration:
  1. Z_i = X_i^T Q_i                              (local, n x r)
  2. consensus-average + debias -> S ~= sum_j X_j^T Q_j at every node
  3. V_i = X_i S                                  (local, d_i x r)
  4. distributed QR of the stacked V via distributed CholeskyQR2:
       G_i = V_i^T V_i ; G = consensus-sum G_i (r x r traffic only);
       R = chol(G)^T ; Q_i = V_i R^{-1}     (x2 passes)

Step 4 replaces the push-sum Householder scheme of paper ref [12] with a
TPU-native equivalent (DESIGN.md sec.2): identical span, MXU-friendly, and the
per-round network payload shrinks from d_i x r to r x r.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .consensus import DenseConsensus
from .linalg import orthonormal_init
from .metrics import CommLedger, subspace_error

__all__ = ["FDOTResult", "fdot", "distributed_cholesky_qr"]


@dataclasses.dataclass
class FDOTResult:
    q_blocks: List[jnp.ndarray]     # per-node slabs Q_{f,i} (d_i x r)
    error_trace: Optional[np.ndarray]
    ledger: CommLedger

    @property
    def q_full(self) -> jnp.ndarray:
        return jnp.concatenate(self.q_blocks, axis=0)


def distributed_cholesky_qr(
    v_blocks: Sequence[jnp.ndarray],
    engine: DenseConsensus,
    t_c: int,
    ledger: Optional[CommLedger] = None,
    passes: int = 2,
) -> List[jnp.ndarray]:
    """Distributed QR of row-partitioned V = [V_1; ...; V_N] via CholeskyQR.

    Only r x r Gram matrices cross the network. With passes=2 this is
    CholeskyQR2 and the result is orthonormal to ~machine precision.
    """
    r = v_blocks[0].shape[1]
    blocks = [v.astype(jnp.float32) for v in v_blocks]
    for _ in range(passes):
        grams = jnp.stack([b.T @ b for b in blocks])              # (N, r, r)
        gsum = engine.run_debiased(grams, t_c, ledger)            # approx sum
        new_blocks = []
        for i, b in enumerate(blocks):
            g = 0.5 * (gsum[i] + gsum[i].T) + 1e-10 * jnp.eye(r, dtype=b.dtype)
            rr = jnp.linalg.cholesky(g).T
            new_blocks.append(
                jax.scipy.linalg.solve_triangular(rr.T, b.T, lower=True).T)
        blocks = new_blocks
    return blocks


def fdot(
    *,
    data_blocks: Sequence[jnp.ndarray],   # node i: X_i (d_i x n)
    engine: DenseConsensus,
    r: int,
    t_outer: int,
    t_c: int = 50,
    t_c_qr: Optional[int] = None,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
) -> FDOTResult:
    """Run F-DOT over a simulated network (Alg. 2)."""
    n_nodes = engine.graph.n_nodes
    if len(data_blocks) != n_nodes:
        raise ValueError("need one feature slab per node")
    dims = [int(x.shape[0]) for x in data_blocks]
    d = sum(dims)
    n_samples = data_blocks[0].shape[1]
    t_c_qr = t_c if t_c_qr is None else t_c_qr

    if q_init is None:
        q_init = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    # split the common init into per-node slabs
    offs = np.cumsum([0] + dims)
    q_blocks = [q_init[offs[i]:offs[i + 1]] for i in range(n_nodes)]

    ledger = CommLedger()
    errs = [] if q_true is not None else None

    for _ in range(t_outer):
        # step 1-2: consensus over the (n x r) partial products
        z0 = jnp.stack([x.T @ q for x, q in zip(data_blocks, q_blocks)])  # (N,n,r)
        s = engine.run_debiased(z0, t_c, ledger)                          # (N,n,r)
        # step 3: local expansion
        v_blocks = [x @ s[i] for i, x in enumerate(data_blocks)]
        # step 4: distributed orthonormalization
        q_blocks = distributed_cholesky_qr(v_blocks, engine, t_c_qr, ledger)
        if errs is not None:
            q_full = jnp.concatenate(q_blocks, axis=0)
            errs.append(float(subspace_error(q_true, q_full)))

    return FDOTResult(
        q_blocks=q_blocks,
        error_trace=np.asarray(errs) if errs is not None else None,
        ledger=ledger,
    )
