"""F-DOT — feature-wise partitioned PSA (the paper's Alg. 2).

A sensor-array setting: each of 10 nodes observes 2 of the 20 features of a
common signal. Together they estimate the top-4 principal subspace of the
global covariance; each node only ever learns ITS OWN rows of the basis.

Run:  PYTHONPATH=src python examples/feature_partitioned_fdot.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.consensus import DenseConsensus
from repro.core.fdot import fdot
from repro.core.linalg import eigh_topr
from repro.core.metrics import subspace_error
from repro.core.topology import erdos_renyi
from repro.data.pipeline import gaussian_eigengap_data, partition_features

D, R, N_NODES, N_SAMPLES = 20, 4, 10, 4000


def main():
    x, _, _ = gaussian_eigengap_data(D, N_SAMPLES, R, 0.6, seed=0)
    _, q_true = eigh_topr(x @ x.T, R)
    blocks = partition_features(x, N_NODES)
    print(f"{N_NODES} nodes, {blocks[0].shape[0]} features each, "
          f"{N_SAMPLES} shared samples")

    engine = DenseConsensus(erdos_renyi(N_NODES, p=0.5, seed=1))
    res = fdot(data_blocks=blocks, engine=engine, r=R, t_outer=80, t_c=50,
               q_true=q_true)

    q = res.q_full
    print(f"final subspace error: {res.error_trace[-1]:.2e}")
    print(f"orthonormality |Q^T Q - I|_max: "
          f"{float(jnp.abs(q.T @ q - jnp.eye(R)).max()):.2e}")
    print(f"P2P per node: {res.ledger.per_node_p2p(N_NODES)/1e3:.1f}K "
          f"(consensus payloads: n x r partials + r x r Grams only)")
    assert res.error_trace[-1] < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
