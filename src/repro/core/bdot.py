"""B-DOT — block-partitioned distributed orthogonal iteration.

The paper's §VI names "randomly block-wise partitioned data, i.e., data
partitioned by both samples and features" as the open direction for data
that is massive in BOTH d and n. This module implements it — a beyond-paper
extension composing the two mechanisms the paper develops:

Nodes form an I x J grid; node (i, j) holds the block X_ij in
R^{d_i x n_j} (feature slab i of sample shard j). Node (i, j) estimates the
rows Q_i of the global eigenspace basis. One outer iteration computes the
OI update  V = X X^T Q  block-wise:

    S_j   = sum_i X_ij^T Q_i          consensus along grid COLUMN j
            (the F-DOT partial-product trick, payload n_j x r)
    W_i   = sum_j X_ij S_j            consensus along grid ROW i
            (the S-DOT sum-of-local-products trick, payload d_i x r)
    Q_i   = distributed CholeskyQR over the row representatives
            (r x r Gram traffic only)

Every consensus runs on a sub-network of the grid (its column or row), so
the scheme inherits S-DOT's Theorem-1-style behaviour on each stage: with
enough consensus rounds per stage the iterate matches centralized OI.
Communication per outer iteration per node is O((n_j + d_i + r) r) — never
a full d x r or d x n object, which is the point of block partitioning.

Execution modes (``fused`` flag, same architecture as sdot.py/fdot.py):

  * fused (default) — the whole t_outer loop is ONE jitted ``lax.scan``.
    Padded-stack layout: the ragged grid blocks are zero-padded into one
    ``(I, J, d_max, n_max)`` stack and the row iterates into ``(I, d_max,
    r)``. The masking invariants that make the padding exact:

      - padded FEATURE rows (d_i..d_max) are zero in both X_ij and Q_i, so
        they are null in the stage-1 product X_ij^T Q_i, produce zero rows
        of V in stage 2, and add nothing to the stage-3 Grams;
      - padded SAMPLE columns (n_j..n_max) of X_ij meet zero rows of Z/S:
        column j's partials Z_ij = X_ij^T Q_i have zero rows past n_j at
        every node of the column, gossip is a convex row mix so the rows
        STAY zero through any number of rounds (and through the debias
        row-scaling), hence stage 2's X_ij S_j never reads garbage.

    Stage-1 column gossip and stage-2 row gossip are batched masked scans —
    ``debiased_gossip`` vmapped over the J column engines (stacked
    (J, I, I) weights + (J, t_max+1, I) device debias tables) and the I row
    engines — so per-sub-network topologies stay heterogeneous inside one
    compiled program; the per-iteration budget is read from the schedule
    array. Stage 3 is the in-scan distributed CholeskyQR over the column-0
    engine. The grid block products dispatch once per stage through
    ``kernels/ops.grid_block_tq`` / ``grid_block_apply`` (Pallas
    (row, column, sample-block) kernels on TPU, fused einsum elsewhere).
    The error trace is computed on device from the padded stacks and
    communication is accounted in closed form.

  * eager (``fused=False``) — the original per-iteration Python loop over
    the ragged block lists. Kept as the correctness oracle
    (tests/test_bdot_fused.py) and for step-by-step debugging.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import runtime
from .consensus import DenseConsensus, consensus_schedule, debiased_gossip
from .fdot import _qr_pass, distributed_cholesky_qr, split_pad_rows
from .linalg import orthonormal_init
from .metrics import CommLedger, subspace_error, subspace_error_from_cross
from .sparse import SparseW
from ..kernels import ops as kops

__all__ = ["BDOTResult", "bdot", "bdot_program", "pad_grid_blocks"]


def _stack_weights(engines: Sequence[DenseConsensus]):
    """Stack per-sub-network mixing weights for the vmapped gossip stages.

    All-dense engines stack to a (B, N, N) array; all-sparse engines stack
    to one batched ``SparseW`` (``SparseW.stack`` pads ELL widths to the
    common max) — ``jax.vmap`` maps over its leading-axis leaves exactly
    like the dense stack. Mixing dense and sparse engines in one stage has
    no common batched representation, so it is rejected loudly.
    """
    ws = [e._w for e in engines]
    n_sparse = sum(isinstance(w, SparseW) for w in ws)
    if n_sparse == 0:
        return jnp.stack(ws)
    if n_sparse != len(ws):
        raise ValueError(
            "B-DOT stage mixes sparse and dense engines; pass sparse=True "
            "or sparse=False uniformly per stage")
    return SparseW.stack(ws)


@dataclasses.dataclass
class BDOTResult:
    q_rows: List[jnp.ndarray]       # per feature-slab Q_i (d_i x r), consensus
    error_trace: Optional[np.ndarray]
    ledger: CommLedger

    @property
    def q_full(self) -> jnp.ndarray:
        return jnp.concatenate(self.q_rows, axis=0)


def pad_grid_blocks(blocks: Sequence[Sequence[jnp.ndarray]]) -> jnp.ndarray:
    """Zero-pad an I x J grid of ragged (d_i, n_j) blocks to one
    (I, J, d_max, n_max) stack (see the module docstring for why the
    padding is exact through all three B-DOT stages)."""
    d_max = max(int(row[0].shape[0]) for row in blocks)
    n_max = max(int(b.shape[1]) for b in blocks[0])
    return jnp.stack([
        jnp.stack([
            jnp.pad(b, ((0, d_max - b.shape[0]), (0, n_max - b.shape[1])))
            for b in row])
        for row in blocks])


def _bdot_outer_body(x_grid, w_col, tab_col, w_row, tab_row, qtrue_pad, *,
                     t_max: int, t_c_qr: int, passes: int, trace_err: bool):
    """Build the per-outer-iteration body ``(q_pad, t_c) -> (q_new, err)``.

    x_grid: (I, J, d_max, n_max) zero-padded blocks; w_col/tab_col:
    (J, I, I) column weights + (J, t_max+1, I) debias tables; w_row/tab_row:
    (I, J, J) + (I, t_max+1, J) for the row stage; t_c_qr: static constant
    budget per QR pass (gossiped over the column-0 engine, exactly as the
    eager oracle does); qtrue_pad: (I, d_max, r) zero-row-padded slabs.
    One definition feeds every runtime driver (monolithic, chunked), so a
    run split at chunk boundaries replays the monolithic scan bit for bit.
    """
    gossip_cols = jax.vmap(debiased_gossip, in_axes=(0, 0, 0, None, None))
    gossip_rows = jax.vmap(debiased_gossip, in_axes=(0, 0, 0, None, None))

    def outer(q_pad, t_c):
        # stage 1: column-wise consensus over the (n_max, r) partials
        z = kops.grid_block_tq(x_grid, q_pad)          # (I, J, n_max, r)
        z = jnp.swapaxes(z, 0, 1)                      # (J, I, n_max, r)
        s = gossip_cols(w_col, tab_col, z, t_c, t_max).mean(axis=1)
        # stage 2: row-wise consensus over the (d_max, r) expansions
        v = kops.grid_block_apply(x_grid, s)           # (I, J, d_max, r)
        v = gossip_rows(w_row, tab_row, v, t_c, t_max).mean(axis=1)
        # stage 3: distributed CholeskyQR across the I feature slabs
        v = v.astype(jnp.float32)
        for _ in range(passes):
            v = _qr_pass(w_col[0], tab_col[0], v, jnp.int32(t_c_qr), t_c_qr)
        if trace_err:
            cross = jnp.einsum("idr,ids->rs", qtrue_pad, v)      # Q^T Qhat
            err = subspace_error_from_cross(cross)
        else:
            err = jnp.float32(0.0)
        return v, err

    return outer


def _bdot_build_body(operands, *, t_max: int, t_c_qr: int, passes: int,
                     trace_err: bool):
    """Runtime body builder for B-DOT (the Program protocol's
    ``build_body``). B-DOT is sync-only: the key threads through."""
    x_grid, w_col, tab_col, w_row, tab_row, qtrue_pad = operands
    return runtime.sync_body(
        _bdot_outer_body(x_grid, w_col, tab_col, w_row, tab_row, qtrue_pad,
                         t_max=t_max, t_c_qr=t_c_qr, passes=passes,
                         trace_err=trace_err))


def _prepare_bdot(*, blocks, col_engines, row_engines, r, t_outer, t_c,
                  t_c_qr, schedule, q_init, q_true, seed):
    """Validate + normalize a B-DOT run's inputs into device-ready pieces.

    Shared by ``bdot`` (eager oracle) and ``bdot_program`` (every runtime
    driver), so a chunked run starts from literally the same device values
    as the monolithic one.
    """
    n_rows = len(blocks)
    n_cols = len(blocks[0])
    if len(col_engines) != n_cols or len(row_engines) != n_rows:
        raise ValueError("need one column engine per grid column and one "
                         "row engine per grid row")
    dims = [int(blocks[i][0].shape[0]) for i in range(n_rows)]
    n_samps = [int(blocks[0][j].shape[1]) for j in range(n_cols)]
    d = sum(dims)
    t_c_qr = int(t_c if t_c_qr is None else t_c_qr)
    passes = 2

    if schedule is None:
        schedule = consensus_schedule("const", t_outer, t_max=t_c)
    elif len(schedule) < t_outer:
        raise ValueError(f"schedule has {len(schedule)} entries but "
                         f"t_outer={t_outer}")
    schedule = np.asarray(schedule[:t_outer])

    if q_init is None:
        q_init = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    offs = np.cumsum([0] + dims)
    # every node of row i starts from the same slab Q_i
    q_rows = [q_init[offs[i]:offs[i + 1]] for i in range(n_rows)]
    t_max = int(max(schedule.max(), t_c_qr)) if t_outer else t_c_qr
    trace_err = q_true is not None

    def pads():
        # built lazily: only the fused/chunked executors consume the padded
        # stacks — the eager oracle iterates the ragged blocks directly
        x_grid = pad_grid_blocks(blocks)
        q0_pad = split_pad_rows(q_init, dims)            # (I, d_max, r)
        qtrue_pad = (split_pad_rows(q_true, dims) if trace_err
                     else jnp.zeros_like(q0_pad))
        return x_grid, q0_pad, qtrue_pad

    return dict(
        n_rows=n_rows, n_cols=n_cols, dims=dims, n_samps=n_samps, d=d,
        t_c_qr=t_c_qr, passes=passes, schedule=schedule, q_rows=q_rows,
        t_max=t_max, trace_err=trace_err, pads=pads,
    )


def bdot_program(
    *,
    blocks: Sequence[Sequence[jnp.ndarray]],
    col_engines: Sequence[DenseConsensus],
    row_engines: Sequence[DenseConsensus],
    r: int,
    t_outer: int,
    t_c: int = 50,
    t_c_qr: Optional[int] = None,
    schedule: Optional[np.ndarray] = None,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
) -> runtime.Program:
    """Register a B-DOT run with the unified executor runtime.

    ``runtime.run_monolithic`` reproduces ``bdot(fused=True)``;
    ``runtime.run_chunked`` makes the block-partitioned runs restartable —
    a capability B-DOT never had before the unified runtime, and it comes
    from the generic driver rather than bespoke wiring.
    """
    if not all(hasattr(e, "debias_table")
               for e in list(col_engines) + list(row_engines)):
        raise ValueError("fused B-DOT needs fused-capable engines "
                         "(debias_table) on every row and column")
    prep = _prepare_bdot(blocks=blocks, col_engines=col_engines,
                         row_engines=row_engines, r=r, t_outer=t_outer,
                         t_c=t_c, t_c_qr=t_c_qr, schedule=schedule,
                         q_init=q_init, q_true=q_true, seed=seed)
    x_grid, q0_pad, qtrue_pad = prep["pads"]()
    t_max, t_c_qr, passes = prep["t_max"], prep["t_c_qr"], prep["passes"]
    trace_err = prep["trace_err"]
    sched_np = prep["schedule"]
    dims, n_samps = prep["dims"], prep["n_samps"]
    w_col = _stack_weights(col_engines)                  # (J, I, I)
    tab_col = jnp.stack([e.debias_table(t_max) for e in col_engines])
    w_row = _stack_weights(row_engines)                  # (I, J, J)
    tab_row = jnp.stack([e.debias_table(t_max) for e in row_engines])

    def finalize(state: runtime.RunState, done: int) -> BDOTResult:
        ledger = CommLedger()
        for j, eng in enumerate(col_engines):
            ledger.log_gossip_rounds(sched_np[:done], eng.graph.adjacency,
                                     n_samps[j] * r,
                                     bytes_per_elem=getattr(
                                         eng, "payload_bytes_per_elem", 4.0))
        for i, eng in enumerate(row_engines):
            ledger.log_gossip_rounds(sched_np[:done], eng.graph.adjacency,
                                     dims[i] * r,
                                     bytes_per_elem=getattr(
                                         eng, "payload_bytes_per_elem", 4.0))
        ledger.log_gossip_rounds(np.full(done, passes * t_c_qr),
                                 col_engines[0].graph.adjacency, r * r,
                                 bytes_per_elem=getattr(
                                     col_engines[0],
                                     "payload_bytes_per_elem", 4.0))
        return BDOTResult(
            q_rows=[state.q[i, :di] for i, di in enumerate(dims)],
            error_trace=(np.asarray(state.errs[:done]) if trace_err
                         else None),
            ledger=ledger,
        )

    return runtime.Program(
        build_body=_bdot_build_body,
        operands=(x_grid, w_col, tab_col, w_row, tab_row, qtrue_pad),
        statics=(("t_max", t_max), ("t_c_qr", t_c_qr), ("passes", passes),
                 ("trace_err", trace_err)),
        xs=sched_np,
        q0=q0_pad,
        finalize=finalize,
    )


def bdot(
    *,
    blocks: Sequence[Sequence[jnp.ndarray]],   # blocks[i][j]: (d_i, n_j)
    col_engines: Sequence[DenseConsensus],
    row_engines: Sequence[DenseConsensus],
    r: int,
    t_outer: int,
    t_c: int = 50,
    t_c_qr: Optional[int] = None,
    schedule: Optional[np.ndarray] = None,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
    fused: bool = True,
) -> BDOTResult:
    """Run B-DOT over a simulated I x J node grid.

    ``col_engines[j]`` is the gossip engine over the I nodes of column j
    (they exchange n_j x r partials); ``row_engines[i]`` gossips over the J
    nodes of row i (d_i x r partials). The final QR gossips r x r Grams over
    a column engine (one representative per feature slab; any connected
    overlay works).

    ``schedule`` overrides ``t_c`` with per-outer-iteration consensus
    budgets for stages 1-2 (the QR stage keeps the constant ``t_c_qr``,
    default ``t_c``). ``fused=True`` (default) executes the whole run as a
    single compiled scan over the zero-padded block stack (a thin shim over
    ``runtime.run_monolithic``); ``fused=False`` is the eager
    per-iteration oracle.
    """
    if fused and all(hasattr(e, "debias_table")
                     for e in list(col_engines) + list(row_engines)):
        return runtime.run_monolithic(bdot_program(
            blocks=blocks, col_engines=col_engines, row_engines=row_engines,
            r=r, t_outer=t_outer, t_c=t_c, t_c_qr=t_c_qr, schedule=schedule,
            q_init=q_init, q_true=q_true, seed=seed))

    prep = _prepare_bdot(blocks=blocks, col_engines=col_engines,
                         row_engines=row_engines, r=r, t_outer=t_outer,
                         t_c=t_c, t_c_qr=t_c_qr, schedule=schedule,
                         q_init=q_init, q_true=q_true, seed=seed)
    n_rows, n_cols = prep["n_rows"], prep["n_cols"]
    t_c_qr, passes = prep["t_c_qr"], prep["passes"]
    schedule, q_rows = prep["schedule"], prep["q_rows"]
    trace_err = prep["trace_err"]

    ledger = CommLedger()
    errs = [] if trace_err else None
    for t in range(t_outer):
        t_c_t = int(schedule[t])
        # --- stage 1: per column j, consensus-sum the (n_j x r) partials
        s_cols = []
        for j in range(n_cols):
            z0 = jnp.stack([blocks[i][j].T @ q_rows[i]
                            for i in range(n_rows)])      # (I, n_j, r)
            s = col_engines[j].run_debiased(z0, t_c_t, ledger)
            s_cols.append(s.mean(0))   # all column members now agree (≈)

        # --- stage 2: per row i, consensus-sum the (d_i x r) expansions
        new_rows = []
        for i in range(n_rows):
            z0 = jnp.stack([blocks[i][j] @ s_cols[j]
                            for j in range(n_cols)])      # (J, d_i, r)
            w = row_engines[i].run_debiased(z0, t_c_t, ledger)
            new_rows.append(w.mean(0))

        # --- stage 3: distributed CholeskyQR across feature slabs
        q_rows = distributed_cholesky_qr(new_rows, col_engines[0],
                                         t_c_qr, ledger, passes=passes)
        if errs is not None:
            errs.append(float(subspace_error(
                q_true, jnp.concatenate(q_rows, axis=0))))
    error_trace = np.asarray(errs) if errs is not None else None

    return BDOTResult(
        q_rows=q_rows,
        error_trace=error_trace,
        ledger=ledger,
    )
