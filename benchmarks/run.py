"""Benchmark aggregator — one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [module ...]
Prints ``name,us_per_call,derived`` CSV (one row per measurement).
"""
from __future__ import annotations

import importlib
import sys
import time
import traceback

MODULES = [
    "sdot_fused",
    "bdot_fused",
    "sweep_bench",
    "streaming_bench",
    "runtime_bench",
    "serving_bench",
    "table1_eigengap_p2p",
    "table2_connectivity",
    "table3_ring",
    "table4_star",
    "table5_straggler",
    "fig45_baselines",
    "fig6_fdot",
    "table69_realworld",
    "tpu_comm_model",
    "kernel_bench",
    "bdot_blockwise",
    "async_straggler",
]


def main() -> None:
    args = sys.argv[1:]
    mods = args if args else MODULES
    print("name,us_per_call,derived")
    failures = []
    for name in mods:
        t0 = time.time()
        try:
            mod = importlib.import_module(f".{name}", __package__)
            for row in mod.run():
                print(row.csv(), flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
