"""Hypothesis sweep for CholeskyQR2 — split out of test_linalg_metrics.py so
the deterministic numerics tests collect even without ``hypothesis``."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.linalg import cholesky_qr2


@settings(max_examples=25, deadline=None)
@given(d=st.integers(4, 64), r=st.integers(1, 8), seed=st.integers(0, 10_000))
def test_cholesky_qr2_orthonormal_property(d, r, seed):
    r = min(r, d)
    v = jax.random.normal(jax.random.PRNGKey(seed), (d, r)) * 10.0
    q, rr = cholesky_qr2(v)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(q @ rr), np.asarray(v), rtol=2e-4,
                               atol=2e-4)
    # R upper triangular
    assert np.allclose(np.tril(np.asarray(rr), -1), 0.0, atol=1e-5)
