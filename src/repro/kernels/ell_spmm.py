"""Pallas TPU kernel: ELL sparse-matrix x dense-block gossip round.

One gossip round on a sparse topology is

    out[i] = diag[i] * z[i] + sum_l val[i, l] * z[idx[i, l]]

with (idx, val) the padded-ELL neighbor slots of ``core/sparse.SparseW``
(slots past the row's degree self-point with weight 0, so no masking is
needed inside the multiply-accumulate) and z the (N, K) flattened gossip
payload. The grid tiles the ROW axis; each step owns its (block_rows, K)
output tile:

* the row tile of z (the node's own state) feeds the diagonal term at
  full precision;
* the FULL payload stays resident as a second input block (gossip
  payloads are small — (N, k_payload) with k_payload = d*r/N-ish — so at
  the 1k-10k-node target sizes it fits VMEM comfortably; ops.py guards
  the bytes and falls back otherwise) and per-slot rows are gathered
  from it with dynamic indices;
* accumulation is f32 (``preferred_element_type`` on the FMA chain);
  a bf16 payload mode is implemented OUTSIDE the kernel by quantizing
  the gather source (ops.py) — the kernel is precision-agnostic about
  its gather operand and always accumulates f32.

The ELL width L is a static Python int, so the slot loop unrolls at
trace time (L = max row degree, single digits on the sparse topologies
this targets). Call through ``ops.ell_spmm``, which pads rows to a block
multiple and falls back to the gather/einsum oracle off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ell_spmm_pallas"]


def _ell_spmm_kernel(idx_ref, val_ref, diag_ref, zrow_ref, zfull_ref, o_ref,
                     *, ell_width: int):
    """One row-block grid step: gather + FMA over the ELL slot columns."""
    zrow = zrow_ref[...].astype(jnp.float32)            # (br, K) own state
    zfull = zfull_ref[...]                              # (N, K) payload
    acc = diag_ref[...].astype(jnp.float32)[:, None] * zrow
    for l in range(ell_width):                          # static unroll
        cols = idx_ref[:, l]                            # (br,) int32
        w = val_ref[:, l].astype(jnp.float32)           # (br,)
        msgs = jnp.take(zfull, cols, axis=0)            # dynamic row gather
        acc = acc + w[:, None] * msgs.astype(jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret"))
def ell_spmm_pallas(ell_idx: jnp.ndarray, ell_val: jnp.ndarray,
                    diag: jnp.ndarray, z_own: jnp.ndarray,
                    z_src: jnp.ndarray, *, block_rows: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    """out = diag*z_own + ELL-gather-sum over z_src, f32.

    ell_idx/ell_val: (Np, L) with Np % block_rows == 0 (ops.py pads rows
    with weight-0 self-pointing slots and diag 0, so padded output rows
    are exactly zero and slicing them off is exact). z_own: (Np, K) the
    row-aligned payload; z_src: (N, K) the gather source (bf16 in payload-
    quantized mode, otherwise the same array as z_own's first N rows).
    """
    n_pad, ell_width = ell_idx.shape
    n_src, k = z_src.shape
    assert n_pad % block_rows == 0, "ops.py pads rows to a block multiple"
    n_blocks = n_pad // block_rows

    return pl.pallas_call(
        functools.partial(_ell_spmm_kernel, ell_width=ell_width),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, ell_width), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, ell_width), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((n_src, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, k), jnp.float32),
        interpret=interpret,
    )(ell_idx, ell_val, diag, z_own, z_src)
