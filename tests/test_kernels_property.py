"""Hypothesis shape/dtype sweeps for the Pallas kernels.

Kept separate from tests/test_kernels.py so the deterministic kernel tests
collect and run even where ``hypothesis`` is not installed (the property
sweeps are skipped there, not errored).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@settings(max_examples=12, deadline=None)
@given(
    d=st.sampled_from([16, 64, 128]),
    n=st.integers(10, 700),
    r=st.sampled_from([4, 16, 128]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 1000),
)
def test_gram_apply_matches_ref(d, n, r, dtype, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (d, n), jnp.float32).astype(dtype)
    q = jax.random.normal(k2, (d, r), jnp.float32).astype(dtype)
    out = ops.gram_apply(x, q, block_n=256, use_pallas=True)
    want = ref.gram_apply_ref(x, q)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=8, deadline=None)
@given(
    n_nodes=st.sampled_from([2, 3, 5]),
    d=st.sampled_from([16, 64]),
    n=st.integers(10, 600),
    r=st.sampled_from([4, 16]),
    seed=st.integers(0, 1000),
)
def test_batched_gram_apply_matches_per_node(n_nodes, d, n, r, seed):
    """Batched (node, column-block) kernel == per-node oracle, ragged n_i."""
    rng = np.random.default_rng(seed)
    n_true = rng.integers(max(1, n // 2), n + 1, size=n_nodes)
    n_max = int(n_true.max())
    x_stack = np.zeros((n_nodes, d, n_max), np.float32)
    for i, ni in enumerate(n_true):
        x_stack[i, :, :ni] = rng.standard_normal((d, ni))
    q = jnp.asarray(rng.standard_normal((n_nodes, d, r)), jnp.float32)
    out = ops.batched_gram_apply(jnp.asarray(x_stack), q,
                                 jnp.asarray(n_true, jnp.float32),
                                 block_n=256, use_pallas=True, interpret=True)
    for i, ni in enumerate(n_true):
        want = ref.gram_apply_ref(jnp.asarray(x_stack[i, :, :ni]), q[i])
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    hq=st.sampled_from([2, 4]),
    gqa=st.sampled_from([1, 2]),
    sq=st.sampled_from([128, 256, 300]),
    hd=st.sampled_from([32, 64]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 100),
)
def test_flash_attention_matches_ref(b, hq, gqa, sq, hd, dtype, seed):
    hkv = hq // gqa
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, hkv, sq, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, hkv, sq, hd), jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=True, use_pallas=True)
    kx = jnp.repeat(k, gqa, 1)
    vx = jnp.repeat(v, gqa, 1)
    want = ref.flash_attention_ref(q, kx, vx, causal=True)
    tol = 3e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=12, deadline=None)
@given(
    d=st.integers(10, 3000),
    r=st.sampled_from([2, 8, 64]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 1000),
)
def test_gram_qr_matches_ref(d, r, dtype, seed):
    v = jax.random.normal(jax.random.PRNGKey(seed), (d, r),
                          jnp.float32).astype(dtype)
    out = ops.gram_qr(v, block_d=512, use_pallas=True)
    want = ref.gram_qr_ref(v)
    tol = 2e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol * max(d, 1))
