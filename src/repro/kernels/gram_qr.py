"""Pallas TPU kernel: tall-skinny Gram  G = V^T V  (CholeskyQR's hot matmul).

Step 12 of Alg. 1 (and both passes of CholeskyQR2, and F-DOT's distributed
QR) reduce a tall (d x r) iterate to its (r x r) Gram. For large d the MXU
wants V streamed through VMEM in row blocks with the (r x r) accumulator
resident:

    for each row block V_b (bd x r):   G += V_b^T V_b

Arithmetic intensity: 2*bd*r^2 FLOPs per bd*r*4 bytes = r/2 FLOPs/byte —
memory-bound for small r, which is exactly why the accumulator must stay in
VMEM and V must be read once. Accumulation over the sequential TPU grid is
safe (same out block revisited).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gram_qr_pallas"]


def _gram_qr_kernel(v_ref, g_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    v = v_ref[...]                                   # (bd, r)
    g_ref[...] += jax.lax.dot_general(
        v, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(g_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gram_qr_pallas(v: jnp.ndarray, *, block_d: int = 1024,
                   interpret: bool = False) -> jnp.ndarray:
    """G = V^T V. v: (d, r) with d % block_d == 0 (ops.py pads)."""
    d, r = v.shape
    assert d % block_d == 0
    return pl.pallas_call(
        _gram_qr_kernel,
        grid=(d // block_d,),
        in_specs=[pl.BlockSpec((block_d, r), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((r, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, r), jnp.float32),
        interpret=interpret,
    )(v)
