"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8, 1 shared expert
(paper-table scale) [arXiv:2501.kimi2; unverified]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163_840, head_dim=128,
    block_pattern=("attn",),
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, n_shared_experts=1),
)
