"""Quickstart — distributed PSA with S-DOT / SA-DOT (the paper's Alg. 1).

Ten nodes on an Erdős–Rényi network each hold 500 samples of 20-dim data;
every node estimates the top-5 eigenspace of the GLOBAL covariance without
any raw-data exchange, then we compare against centralized orthogonal
iteration and report the communication bill.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.consensus import DenseConsensus
from repro.core.linalg import eigh_topr, orthonormal_init
from repro.core.metrics import subspace_error
from repro.core.oi import orthogonal_iteration
from repro.core.sdot import sadot, sdot
from repro.core.topology import erdos_renyi
from repro.data.pipeline import gaussian_eigengap_data, partition_samples

D, R, N_NODES, N_PER, GAP = 20, 5, 10, 500, 0.7


def main():
    # --- data, partitioned by samples across the network
    x, _, _ = gaussian_eigengap_data(D, N_NODES * N_PER, R, GAP, seed=0)
    blocks = partition_samples(x, N_NODES)
    import jax.numpy as jnp
    covs = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
    _, q_true = eigh_topr(covs.sum(0), R)

    # --- the network: ER graph, local-degree gossip weights
    graph = erdos_renyi(N_NODES, p=0.5, seed=1)
    engine = DenseConsensus(graph)
    print(f"network: N={N_NODES} ER(p=0.5), {graph.n_edges} edges")

    # --- S-DOT: fixed 50 consensus rounds per orthogonal iteration
    res = sdot(covs=covs, engine=engine, r=R, t_outer=60, t_c=50,
               q_true=q_true)
    print(f"S-DOT : final subspace error {res.error_trace[-1]:.2e}  "
          f"P2P/node {res.ledger.per_node_p2p(N_NODES)/1e3:.1f}K")

    # --- SA-DOT: adaptive schedule (2t+1, capped at 50) — fewer messages
    res_a = sadot(covs=covs, engine=engine, r=R, t_outer=60,
                  schedule_kind="lin2", cap=50, q_true=q_true)
    print(f"SA-DOT: final subspace error {res_a.error_trace[-1]:.2e}  "
          f"P2P/node {res_a.ledger.per_node_p2p(N_NODES)/1e3:.1f}K")

    # --- centralized OI reference (needs all data at one place)
    q0 = orthonormal_init(jax.random.PRNGKey(0), D, R)
    q_oi = orthogonal_iteration(covs.sum(0), q0, 60)
    print(f"OI    : final subspace error "
          f"{float(subspace_error(q_true, q_oi)):.2e}  (centralized)")

    # every node agrees with every other (consensus)
    worst = max(float(subspace_error(res.q_nodes[0], res.q_nodes[i]))
                for i in range(1, N_NODES))
    print(f"worst cross-node disagreement: {worst:.2e}")
    assert res.error_trace[-1] < 1e-5
    print("OK")


if __name__ == "__main__":
    main()
