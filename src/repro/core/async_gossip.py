"""Asynchronous / straggler-tolerant consensus — the paper's §V future work.

The paper measures (Table V) that one slow node stalls the whole synchronous
network every iteration and concludes that mitigating stragglers "requires
dealing with asynchronicity in the networks", left as future work. This
module implements it:

* ``AsyncConsensus`` — a gossip engine in which every round each node is
  awake independently with probability ``p_awake``; sleeping nodes neither
  send nor mix (their neighbors renormalize their weights over the awake
  subgraph, preserving double stochasticity per round, so the average is
  conserved and the iteration remains a valid consensus step).
* ``straggler_wall_clock`` — a wall-clock model comparing the synchronous
  network (every round costs the slowest node's delay) with the async one
  (a delayed node simply misses rounds; the round time stays nominal but
  more rounds are needed for the same contraction).

The headline result (benchmarks/async_straggler.py): with one persistent
straggler of delay D >> t_round, synchronous S-DOT pays (t_round + D) per
round while async S-DOT pays t_round per round and only ~1/N of the mixing
opportunities are lost — wall-clock speedup approaching (t_round + D) /
t_round for large networks, at a modest increase in rounds-to-floor.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .metrics import CommLedger
from .topology import Graph, local_degree_weights

__all__ = ["AsyncConsensus", "straggler_wall_clock"]


@dataclasses.dataclass
class AsyncConsensus:
    """Gossip with per-round random node availability.

    Each round, node i is awake w.p. ``p_awake[i]``. The effective mixing
    matrix for the round keeps only edges between awake nodes and returns
    every skipped weight to the diagonal — doubly stochastic by
    construction, so sum_i Z_i is invariant and the debiasing of Alg. 1
    still applies (we track the realized product of mixing matrices for the
    exact per-node debias weight).
    """

    graph: Graph
    p_awake: np.ndarray          # (N,) probability each node is awake
    seed: int = 0

    def __post_init__(self):
        self.weights = local_degree_weights(self.graph)
        self._rng = np.random.default_rng(self.seed)
        if np.isscalar(self.p_awake) or np.ndim(self.p_awake) == 0:
            self.p_awake = np.full(self.graph.n_nodes, float(self.p_awake))

    def _round_matrix(self) -> np.ndarray:
        awake = self._rng.random(self.graph.n_nodes) < self.p_awake
        w = self.weights.copy()
        n = self.graph.n_nodes
        mask = np.outer(awake, awake)
        off = ~np.eye(n, dtype=bool)
        dropped = np.where(off & ~mask, w, 0.0)
        w = np.where(off & mask, w, 0.0)
        np.fill_diagonal(w, self.weights.diagonal() + dropped.sum(axis=1))
        return w, awake

    def run_debiased(self, z_stack: jnp.ndarray, t_c: int,
                     ledger: Optional[CommLedger] = None):
        """t_c async rounds + exact realized debias: approximates sum_j Z_j."""
        n = self.graph.n_nodes
        z = np.asarray(z_stack, np.float64)
        prod = np.eye(n)
        for _ in range(int(t_c)):
            w, awake = self._round_matrix()
            z = np.einsum("ij,j...->i...", w, z)
            prod = w @ prod
            if ledger is not None:
                sends = float((w > 0).sum() - n)   # off-diagonal messages
                ledger.p2p += sends
                ledger.matrices += sends
                ledger.scalars += sends * np.prod(z_stack.shape[1:])
        scale = np.maximum(prod[:, 0], 1e-6)       # realized [Pi W e_1]_i
        bshape = (-1,) + (1,) * (z_stack.ndim - 1)
        return jnp.asarray(z / scale.reshape(bshape), jnp.float32)


def straggler_wall_clock(*, n_nodes: int, t_round: float, delay: float,
                         rounds_sync: int, rounds_async: int) -> dict:
    """Wall-clock model, one persistent straggler (paper Table V setting).

    Synchronous: every round blocks on the straggler -> (t_round + delay).
    Asynchronous: rounds never block (the straggler is simply asleep while
    busy); it is awake a fraction t_round/(t_round+delay) of rounds.
    """
    sync = rounds_sync * (t_round + delay)
    async_ = rounds_async * t_round
    return {
        "sync_s": sync,
        "async_s": async_,
        "speedup": sync / async_ if async_ else float("inf"),
        "straggler_duty_cycle": t_round / (t_round + delay),
    }
